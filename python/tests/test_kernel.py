"""Pallas kernel vs pure-jnp oracle: the CORE correctness signal.

hypothesis sweeps batch sizes, block sizes, substep counts and random
(physically-plausible) parameter vectors for every template; the kernel
must match the oracle to float32 tolerance because both run the SAME rhs
-- any mismatch is a tiling/indexing bug in the Pallas code.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import circuits, device
from compile.kernels import gcram_step, ref

TEMPLATES = {name: f() for name, f in circuits.TEMPLATES.items()}


def rand_params(rng, t: circuits.Template, b: int) -> np.ndarray:
    """Physically-plausible random parameter matrix for a template."""
    p = np.zeros((b, t.npar), np.float32)
    cards = [device.SI_NMOS, device.SI_PMOS, device.OS_NMOS,
             device.SI_NMOS_HVT]
    for name in t.pnames:
        j = t.pnames.index(name)
        if name.endswith(".kp"):
            c = cards[rng.integers(len(cards))]
            wl = rng.uniform(0.5, 8.0)
            for k, key in enumerate(("kp", "vt", "n", "lam")):
                p[:, j + k] = c[key] * rng.uniform(0.8, 1.2, b)
            p[:, j + 4] = wl
            p[:, j + 5] = c["sign"]
        elif name.endswith(".c"):
            p[:, j] = rng.uniform(0.05, 0.5, b) * 1e-15
        elif name.endswith(".g"):
            p[:, j] = rng.uniform(0.0, 2.0, b) * 1e-9
        elif name.endswith(".i"):
            p[:, j] = rng.uniform(-1.0, 1.0, b) * 1e-9
    return p


def rand_state(rng, t: circuits.Template, b: int):
    v = rng.uniform(0.0, 1.2, (b, t.nf)).astype(np.float32)
    vs = rng.uniform(0.0, 1.5, (b, t.ns)).astype(np.float32)
    dvs = rng.uniform(-1e10, 1e10, (b, t.ns)).astype(np.float32)
    cinv = rng.uniform(1 / 50e-15, 1 / 0.5e-15, (b, t.nf)).astype(np.float32)
    # dt scaled to the fastest RC in the random range so random parameter
    # sets stay numerically stable (explicit RK2)
    dt = np.full((b, 1), rng.uniform(0.02e-12, 0.2e-12), np.float32)
    return v, vs, dvs, cinv, dt


@pytest.mark.parametrize("mode", ["heun", "expdecay"])
@pytest.mark.parametrize("tname", sorted(TEMPLATES))
@given(seed=st.integers(0, 2**31 - 1),
       bmult=st.integers(1, 3),
       block=st.sampled_from([32, 64, 128]),
       k=st.sampled_from([1, 2, 4]))
@settings(max_examples=10, deadline=None)
def test_step_matches_ref(tname, mode, seed, bmult, block, k):
    t = TEMPLATES[tname]
    rng = np.random.default_rng(seed)
    b = block * bmult
    v, vs, dvs, cinv, dt = rand_state(rng, t, b)
    p = rand_params(rng, t, b)

    got = gcram_step.make_step(t, k, block, mode)(v, vs, dvs, p, cinv, dt)
    want = ref.make_step_ref(t, k, mode)(v, vs, dvs, p, cinv, dt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-6)


def test_expdecay_matches_heun_for_small_dt():
    """Both integrators solve the same ODE: with dt << C/g they agree."""
    t = TEMPLATES["retention"]
    rng = np.random.default_rng(11)
    b = 128
    v, vs, dvs, cinv, _ = rand_state(rng, t, b)
    vs[:] = 0.0
    dvs[:] = 0.0
    p = rand_params(rng, t, b)
    dt = np.full((b, 1), 1e-15, np.float32)
    heun = gcram_step.make_step(t, 4, 64, "heun")(v, vs, dvs, p, cinv, dt)
    expd = gcram_step.make_step(t, 4, 64, "expdecay")(v, vs, dvs, p, cinv, dt)
    np.testing.assert_allclose(np.asarray(heun), np.asarray(expd),
                               rtol=1e-4, atol=1e-7)


def test_expdecay_stable_and_monotone_at_huge_dt():
    """expdecay must neither oscillate nor go negative when dt >> C/g."""
    t = TEMPLATES["retention"]
    rng = np.random.default_rng(5)
    b = 128
    v, vs, dvs, cinv, _ = rand_state(rng, t, b)
    v = np.abs(v).astype(np.float32)
    vs[:] = 0.0
    dvs[:] = 0.0
    p = rand_params(rng, t, b)
    p[:, TEMPLATES["retention"].pnames.index("idist.i")] = 0.0
    step = gcram_step.make_step(t, 4, 64, "expdecay")
    cur = v
    for dt_s in (1e-9, 1e-6, 1e-3, 1.0, 100.0):
        dt = np.full((b, 1), dt_s, np.float32)
        nxt = np.asarray(step(cur, vs, dvs, p, cinv, dt))
        assert np.all(nxt <= cur + 1e-7), dt_s
        assert np.all(nxt >= -1e-6), dt_s
        assert np.all(np.isfinite(nxt)), dt_s
        cur = nxt


@pytest.mark.parametrize("tname", sorted(TEMPLATES))
def test_multi_step_trajectory_matches_ref(tname):
    """Longer trajectories (stimulus sweeping, varying dt) stay aligned."""
    t = TEMPLATES[tname]
    rng = np.random.default_rng(7)
    b, steps = 128, 24
    v, vs, dvs, cinv, _ = rand_state(rng, t, b)
    p = rand_params(rng, t, b)
    kstep = gcram_step.make_step(t, 4, 64)
    rstep = ref.make_step_ref(t, 4)
    vk = vr = jnp.asarray(v)
    for i in range(steps):
        dt = np.full((b, 1), (0.05 + 0.02 * i) * 1e-12, np.float32)
        vs_i = vs * (0.5 + 0.5 * np.sin(i / 3.0))
        vk = kstep(vk, vs_i, dvs, p, cinv, dt)
        vr = rstep(vr, vs_i, dvs, p, cinv, dt)
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr),
                               rtol=5e-5, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1), g=st.sampled_from([16, 64, 96]))
@settings(max_examples=10, deadline=None)
def test_idvg_matches_ref(seed, g):
    rng = np.random.default_rng(seed)
    b = 128
    cards = np.zeros((b, 6), np.float32)
    for i, c in enumerate((device.SI_NMOS, device.SI_PMOS, device.OS_NMOS)):
        sl = slice(i * b // 3, (i + 1) * b // 3)
        cards[sl] = [c["kp"], c["vt"], c["n"], c["lam"], 2.0, c["sign"]]
    cards[-1] = cards[0]
    vg = np.linspace(-1.2, 1.2, g).astype(np.float32)
    vds = rng.uniform(-1.1, 1.1, (b, 1)).astype(np.float32)
    got = gcram_step.make_idvg(g)(cards, vg, vds)
    want = ref.idvg_ref(cards, vg, vds)
    # broadcast/fusion order differs between blocked and unblocked
    # evaluation; 2e-4 relative is float32 round-off, not a logic bug
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-18)


def test_pinned_node_stays_pinned():
    """cinv = 0 must freeze a node exactly (how rails are modeled)."""
    t = TEMPLATES["write"]
    rng = np.random.default_rng(3)
    b = 128
    v, vs, dvs, cinv, dt = rand_state(rng, t, b)
    cinv[:, 0] = 0.0
    p = rand_params(rng, t, b)
    out = gcram_step.make_step(t, 4, 64)(v, vs, dvs, p, cinv, dt)
    np.testing.assert_array_equal(np.asarray(out)[:, 0], v[:, 0])


def test_bad_batch_multiple_rejected():
    t = TEMPLATES["retention"]
    rng = np.random.default_rng(0)
    v, vs, dvs, cinv, dt = rand_state(rng, t, 96)
    p = rand_params(rng, t, 96)
    with pytest.raises(AssertionError):
        gcram_step.make_step(t, 1, 128)(v, vs, dvs, p, cinv, dt)
