"""Device-model property tests: the EKV expression itself."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import device

VDD = device.SG40_VDD


def ids(vd, vg, vs, card, wl=2.0):
    c = device.card_vec(card, wl)
    return float(device.mos_ids_card(
        jnp.float32(vd), jnp.float32(vg), jnp.float32(vs), c))


def test_nmos_on_current_magnitude():
    # Ion at VGS=VDS=VDD for W/L=1: tens-of-uA class for this EKV card
    # (absolute calibration is not the target -- Ion/Ioff ratios are)
    i = ids(VDD, VDD, 0.0, device.SI_NMOS, wl=1.0)
    assert 2e-5 < i < 2e-3, i


def test_nmos_off_current_magnitude():
    # Ioff at VGS=0, VDS=VDD: nA-class for Si
    i = ids(VDD, 0.0, 0.0, device.SI_NMOS, wl=1.0)
    assert 1e-13 < i < 1e-9, i


def test_os_off_current_below_1e15():
    # OS HVT card: the paper's <1e-18 A/um class device
    i = ids(VDD, 0.0, 0.0, device.OS_NMOS_HVT, wl=1.0)
    assert i < 1e-18, i


def test_pmos_mirror_of_nmos():
    # A PMOS with the same card magnitudes must mirror the NMOS exactly
    n = dict(device.SI_NMOS)
    p = dict(n, sign=-1.0)
    i_n = ids(1.0, 0.8, 0.0, n)
    i_p = ids(-1.0, -0.8, 0.0, p)
    assert np.isclose(i_n, -i_p, rtol=1e-6)


@given(
    vg=st.floats(0.0, 1.2),
    vd=st.floats(0.0, 1.2),
    vs=st.floats(0.0, 1.2),
)
@settings(max_examples=60, deadline=None)
def test_ds_antisymmetry(vg, vd, vs):
    """Swapping drain and source must negate the current (lam=0)."""
    card = dict(device.SI_NMOS, lam=0.0)
    i1 = ids(vd, vg, vs, card)
    i2 = ids(vs, vg, vd, card)
    assert np.isclose(i1, -i2, rtol=1e-5, atol=1e-18), (i1, i2)


@given(vg=st.floats(0.0, 1.0))
@settings(max_examples=40, deadline=None)
def test_monotonic_in_vg(vg):
    card = device.SI_NMOS
    i1 = ids(VDD, vg, 0.0, card)
    i2 = ids(VDD, vg + 0.05, 0.0, card)
    assert i2 > i1


@pytest.mark.parametrize("card", [device.SI_NMOS, device.OS_NMOS])
def test_subthreshold_slope(card):
    """SS extracted from the model must equal n * phi_t * ln(10)."""
    vt = card["vt"]
    v1, v2 = vt - 0.30, vt - 0.20  # deep subthreshold decade
    i1 = ids(VDD, v1, 0.0, card)
    i2 = ids(VDD, v2, 0.0, card)
    ss = (v2 - v1) / np.log10(i2 / i1)  # V/decade
    expect = card["n"] * device.PHI_T * np.log(10.0)
    assert np.isclose(ss, expect, rtol=0.05), (ss, expect)


def test_zero_vds_zero_current():
    assert abs(ids(0.5, 0.9, 0.5, device.SI_NMOS)) < 1e-12


def test_saturation_flat_vs_triode():
    """dI/dVds in saturation should be << dI/dVds in triode."""
    card = dict(device.SI_NMOS, lam=0.0)
    g_tri = (ids(0.10, VDD, 0.0, card) - ids(0.05, VDD, 0.0, card)) / 0.05
    g_sat = (ids(1.10, VDD, 0.0, card) - ids(1.05, VDD, 0.0, card)) / 0.05
    assert g_sat < 0.05 * g_tri
