"""Behavioral tests of the L2 transient graphs: does the physics of each
artifact entry point reproduce the effects the paper builds on?

 - write: SN-'1' saturates near VWWL - VT; WWLLS raises it; WWL-fall
   coupling droops it (paper SS V-A / V-C).
 - read: stored-'0' and stored-'1' crossings separate in time; RWL edge
   boosts SN for the NP flavor and droops it for the NN flavor.
 - retention: Si ~ us, OS ~ ms, OS-HVT > 10 s (Fig. 8b/c/e); higher
   write-VT monotonically lengthens retention (Fig. 8c).
"""

import numpy as np
import pytest

from compile import circuits, device, model, stimulus

VDD = device.SG40_VDD
B = 128


def card_row(c, wl):
    return np.array([c["kp"], c["vt"], c["n"], c["lam"], wl, c["sign"]],
                    np.float32)


def set_card(p, t, tag, c, wl):
    j = t.pnames.index(f"{tag}.kp")
    p[:, j:j + 6] = card_row(c, wl)


def run_write(wwl_boost=0.0, vt_shift=0.0, t_steps=192, csn=1.2e-15):
    t = circuits.write_template()
    p = np.zeros((B, t.npar), np.float32)
    wr = dict(device.SI_NMOS)
    wr["vt"] += vt_shift
    set_card(p, t, "mwr", wr, 2.0)
    set_card(p, t, "mdrvp", device.SI_PMOS, 8.0)
    set_card(p, t, "mdrvn", device.SI_NMOS, 4.0)
    p[:, t.pnames.index("cwwl_sn.c")] = 0.15e-15
    p[:, t.pnames.index("gwbl.g")] = 1e-9
    cinv = np.tile([1 / csn, 1 / 20e-15], (B, 1)).astype(np.float32)
    v0 = np.zeros((B, 2), np.float32)
    amp = np.tile([VDD + wwl_boost, 0.0, VDD, 0.0], (B, 1)).astype(np.float32)
    dt = stimulus.uniform_dt(t_steps, 5e-12)
    times = stimulus.times_from_dt(dt, model.K_SUBSTEPS)
    wave = np.zeros((t_steps, 4), np.float32)
    dwave = np.zeros((t_steps, 4), np.float32)
    stimulus.pulse(wave, dwave, times, 0, 0.2e-9, 0.75 * times[-1], 0.1e-9)
    stimulus.constant(wave, 2)
    out = model.write_op(v0, amp, p, cinv, wave, dwave, dt)
    return [np.asarray(o) for o in out]


class TestWrite:
    def test_stored_one_near_vdd_minus_vt(self):
        _, _, sn_final, t_wr, sn_peak = run_write()
        target = VDD - device.SI_NMOS["vt"]
        assert target - 0.15 < sn_peak[0] < target + 0.05
        assert t_wr[0] < 2e-9

    def test_wwlls_boost_raises_stored_one(self):
        _, _, _, _, peak_nom = run_write(0.0)
        _, _, _, _, peak_ls = run_write(0.4)
        assert peak_ls[0] > peak_nom[0] + 0.2

    def test_coupling_droop_at_wwl_fall(self):
        _, _, sn_final, _, sn_peak = run_write()
        droop = sn_peak[0] - sn_final[0]
        # Cc/(Cc+Csn) * VDD = 0.15/1.35 * 1.1 ~ 0.12 V
        assert 0.05 < droop < 0.2, droop

    def test_larger_csn_reduces_droop(self):
        _, _, f1, _, p1 = run_write(csn=1.2e-15)
        _, _, f2, _, p2 = run_write(csn=3.0e-15)
        assert (p2[0] - f2[0]) < (p1[0] - f1[0])

    def test_higher_write_vt_slows_write(self):
        _, _, _, t_nom, _ = run_write(vt_shift=0.0)
        _, _, _, t_hvt, _ = run_write(vt_shift=0.15)
        assert t_hvt[0] > t_nom[0]

    def test_write_zero_settles_low(self):
        t = circuits.write_template()
        p = np.zeros((B, t.npar), np.float32)
        set_card(p, t, "mwr", device.SI_NMOS, 2.0)
        set_card(p, t, "mdrvp", device.SI_PMOS, 8.0)
        set_card(p, t, "mdrvn", device.SI_NMOS, 4.0)
        p[:, t.pnames.index("cwwl_sn.c")] = 0.15e-15
        cinv = np.tile([1 / 1.2e-15, 1 / 20e-15], (B, 1)).astype(np.float32)
        v0 = np.tile([0.6, 0.0], (B, 1)).astype(np.float32)  # SN was '1'
        amp = np.tile([VDD, VDD, VDD, 0.0], (B, 1)).astype(np.float32)
        dt = stimulus.uniform_dt(192, 5e-12)
        times = stimulus.times_from_dt(dt, model.K_SUBSTEPS)
        wave = np.zeros((192, 4), np.float32)
        dwave = np.zeros((192, 4), np.float32)
        stimulus.pulse(wave, dwave, times, 0, 0.2e-9, 0.75 * times[-1], 0.1e-9)
        stimulus.constant(wave, 1)  # dinb high -> drive WBL low -> write 0
        stimulus.constant(wave, 2)
        out = model.write_op(v0, amp, p, cinv, wave, dwave, dt)
        sn_final = np.asarray(out[2])
        assert sn_final[0] < 0.1


def run_read(sn_level, flavor="np", t_steps=192, rows=256, crbl=40e-15):
    t = circuits.read_template()
    p = np.zeros((B, t.npar), np.float32)
    if flavor == "np":
        rd_card, snu = device.SI_PMOS, 0.55
    elif flavor == "nn":
        rd_card, snu = device.SI_NMOS, 0.0
    else:  # os
        rd_card, snu = device.OS_NMOS, 0.0
    set_card(p, t, "mrd", rd_card, 2.0)
    set_card(p, t, "mrbl_leak", rd_card, 2.0 * (rows - 1))
    p[:, t.pnames.index("crwl_sn.c")] = 0.10e-15
    p[:, t.pnames.index("grbl.g")] = 1e-9
    cinv = np.tile([1 / 1.2e-15, 1 / crbl], (B, 1)).astype(np.float32)
    dt = stimulus.uniform_dt(t_steps, 6e-12)
    times = stimulus.times_from_dt(dt, model.K_SUBSTEPS)
    wave = np.zeros((t_steps, 4), np.float32)
    dwave = np.zeros((t_steps, 4), np.float32)
    v0 = np.zeros((B, 2), np.float32)
    v0[:, 0] = sn_level
    if flavor == "np":
        # predischarge: RBL starts 0; RWL swings 0 -> VDD
        amp = np.tile([VDD, 0.0, snu, 0.0], (B, 1)).astype(np.float32)
        stimulus.pulse(wave, dwave, times, 0, 0.2e-9, 10.0, 0.1e-9)
        stimulus.constant(wave, 2)
    else:
        # precharge: RBL starts VDD; RWL idles VDD, falls to 0
        amp = np.tile([VDD, VDD, snu if snu else 0.0, 0.0], (B, 1))
        amp = amp.astype(np.float32)
        stimulus.fall(wave, dwave, times, 0, 0.2e-9, 0.1e-9)
        stimulus.constant(wave, 1)
        v0[:, 1] = VDD
    out = model.read_op(v0, amp, p, cinv, wave, dwave, dt)
    return [np.asarray(o) for o in out]


class TestRead:
    def test_np_read_zero_charges_rbl(self):
        _, _, t_rise, _, rbl_f, _ = run_read(0.05, "np")
        assert t_rise[0] < 2e-9
        assert rbl_f[0] > 0.5 * VDD

    def test_np_read_discrimination_window(self):
        _, _, t0, _, _, _ = run_read(0.05, "np")
        _, _, t1, _, _, _ = run_read(0.65, "np")
        assert t1[0] > 1.5 * t0[0]  # '1' crossing much later than '0'

    def test_np_wwlls_widens_window(self):
        _, _, t_nom, _, _, _ = run_read(0.65, "np")
        _, _, t_ls, _, _, _ = run_read(0.95, "np")
        assert t_ls[0] > t_nom[0]

    def test_np_rwl_boosts_sn(self):
        _, _, _, _, _, sn_f = run_read(0.60, "np")
        assert sn_f[0] > 0.60 + 0.03  # rising RWL couples SN upward

    def test_nn_read_one_discharges_rbl(self):
        # NN: active-low RWL, precharged RBL; stored '1' turns the read
        # tx on once RWL falls and discharges RBL. VGS ~ 0.6 V is only
        # moderate inversion, so give the window ~9 ns.
        _, _, _, t_fall, rbl_f, _ = run_read(0.65, "nn", t_steps=384)
        assert t_fall[0] < 8e-9
        assert rbl_f[0] < 0.5 * VDD

    def test_nn_rwl_droops_sn(self):
        _, _, _, _, _, sn_f = run_read(0.60, "nn")
        assert sn_f[0] < 0.60 - 0.03  # falling RWL couples SN downward

    def test_bigger_rbl_cap_slows_read(self):
        _, _, ta, _, _, _ = run_read(0.05, "np", crbl=20e-15)
        _, _, tb, _, _, _ = run_read(0.05, "np", crbl=80e-15)
        assert tb[0] > 1.5 * ta[0]


def run_retention(card, wl=2.0, gleak=1e-16, v0sn=0.6, t_steps=448):
    t = circuits.retention_template()
    p = np.zeros((B, t.npar), np.float32)
    set_card(p, t, "mwr", card, wl)
    p[:, t.pnames.index("gleak.g")] = gleak
    cinv = np.full((B, 1), 1 / 1.2e-15, np.float32)
    v0 = np.full((B, 1), v0sn, np.float32)
    amp = np.zeros((B, 4), np.float32)
    dt = stimulus.log_dt(t_steps, 1e-12, 1.082)
    wave = np.zeros((t_steps, 4), np.float32)
    dwave = np.zeros((t_steps, 4), np.float32)
    out = model.retention(v0, amp, p, cinv, wave, dwave, dt)
    return [np.asarray(o) for o in out]


class TestRetention:
    def test_si_retention_microseconds(self):
        _, _, t_ret, _ = run_retention(device.SI_NMOS)
        assert 1e-6 < t_ret[0] < 1e-3, t_ret[0]

    def test_os_retention_milliseconds(self):
        _, _, t_ret, _ = run_retention(device.OS_NMOS)
        assert 1e-3 < t_ret[0] < 1.0, t_ret[0]

    def test_os_hvt_retention_beyond_10s(self):
        _, _, t_ret, _ = run_retention(device.OS_NMOS_HVT, gleak=1e-17)
        assert t_ret[0] > 10.0, t_ret[0]

    def test_vt_monotonically_lengthens_retention(self):
        ts = []
        for dvt in (0.0, 0.1, 0.2, 0.3):
            c = dict(device.SI_NMOS)
            c["vt"] += dvt
            _, _, t_ret, _ = run_retention(c)
            ts.append(t_ret[0])
        assert all(b > a for a, b in zip(ts, ts[1:])), ts

    def test_decay_is_monotone(self):
        _, trace, _, _ = run_retention(device.SI_NMOS)
        sn = trace[:, 0, 0]
        assert np.all(np.diff(sn) <= 1e-6)

    def test_absolute_threshold_channel(self):
        """amp[vth] > 0 switches t_retain to an absolute threshold."""
        t = circuits.retention_template()
        p = np.zeros((B, t.npar), np.float32)
        set_card(p, t, "mwr", device.SI_NMOS, 2.0)
        p[:, t.pnames.index("gleak.g")] = 1e-16
        cinv = np.full((B, 1), 1 / 1.2e-15, np.float32)
        v0 = np.full((B, 1), 0.6, np.float32)
        amp = np.zeros((B, 4), np.float32)
        amp[:, t.node("vth") - t.nf] = 0.45  # higher bar than 0.5*v0=0.3
        dt = stimulus.log_dt(448, 1e-12, 1.082)
        zeros = np.zeros((448, 4), np.float32)
        out = model.retention(v0, amp, p, cinv, zeros, zeros, dt)
        t_abs = np.asarray(out[2])[0]
        amp[:, t.node("vth") - t.nf] = 0.0
        out2 = model.retention(v0, amp, p, cinv, zeros, zeros, dt)
        t_rel = np.asarray(out2[2])[0]
        assert t_abs < t_rel  # 0.45 V is crossed before 0.30 V

    def test_never_crossing_reports_big_time(self):
        # wl tiny + no gate leak + HVT OS -> does not decay in the window
        _, _, t_ret, _ = run_retention(device.OS_NMOS_HVT, wl=0.1, gleak=0.0,
                                       t_steps=128)
        assert t_ret[0] >= 0.99 * model.BIG_TIME  # float32 of the sentinel


class TestCrossTime:
    def test_interpolated_crossing(self):
        import jax.numpy as jnp
        times = jnp.asarray(np.array([1.0, 2.0, 3.0, 4.0], np.float32))
        sig = jnp.asarray(np.array([[0.0], [0.2], [0.6], [1.0]], np.float32))
        t = model._cross_time(times, sig, 0.4, rising=True)
        assert np.isclose(float(t[0]), 2.5, atol=1e-5)

    def test_initially_above_is_zero(self):
        import jax.numpy as jnp
        times = jnp.asarray(np.array([1.0, 2.0], np.float32))
        sig = jnp.asarray(np.array([[0.9], [1.0]], np.float32))
        t = model._cross_time(times, sig, 0.5, rising=True)
        assert float(t[0]) == 0.0

    def test_never_crossing(self):
        import jax.numpy as jnp
        times = jnp.asarray(np.array([1.0, 2.0], np.float32))
        sig = jnp.asarray(np.array([[0.1], [0.2]], np.float32))
        t = model._cross_time(times, sig, 0.5, rising=True)
        assert float(t[0]) >= 0.99 * model.BIG_TIME  # float32 rounding
