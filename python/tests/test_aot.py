"""AOT smoke tests: lowering produces loadable HLO text and a manifest
consistent with the circuit templates."""

import json

import pytest

from compile import aot, circuits


@pytest.fixture(scope="module")
def artifacts():
    return aot.build_all()


def test_all_artifacts_present(artifacts):
    assert set(artifacts) == {"idvg", "write", "read", "retention"}


@pytest.mark.parametrize("name", ["idvg", "write", "read", "retention"])
def test_hlo_text_shape(artifacts, name):
    text, _ = artifacts[name]
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # interpret-mode pallas must lower to plain HLO: no custom-calls that
    # the CPU PJRT client cannot execute
    assert "custom-call" not in text or "mosaic" not in text.lower()


@pytest.mark.parametrize(
    "name,template",
    [("write", circuits.write_template()),
     ("read", circuits.read_template()),
     ("retention", circuits.retention_template())],
)
def test_manifest_matches_template(artifacts, name, template):
    _, meta = artifacts[name]
    assert meta["free_nodes"] == template.free_nodes
    assert meta["stim_nodes"] == template.stim_nodes
    assert meta["params"] == template.pnames
    assert meta["batch"] % 128 == 0
    assert meta["k_substeps"] >= 1


def test_manifest_is_json_serializable(artifacts):
    manifest = {k: dict(v[1], file=f"{k}.hlo.txt")
                for k, v in artifacts.items()}
    s = json.dumps(manifest)
    assert json.loads(s) == manifest


def test_param_count_in_hlo_signature(artifacts):
    """The entry computation must take exactly the 7 transient inputs."""
    text, meta = artifacts["write"]
    header = text.splitlines()[0]  # HloModule ... entry_computation_layout=...
    sig = header.split("entry_computation_layout=")[1]
    args = sig.split("->")[0]
    assert args.count("f32[") == len(meta["inputs"])
