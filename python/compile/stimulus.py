"""Stimulus-schedule builders (python mirror of rust/src/runtime/stimulus.rs).

Artifacts take the stimulus as runtime inputs -- a normalized waveform
(T, NS) + per-design amplitudes -- so these builders exist on both sides
of the language boundary.  The python copies are used by the model tests
and by aot example-input generation; the Rust copies feed the PJRT
executions.  Keep the two in sync (test_model.py asserts the semantics).
"""

import numpy as np


def uniform_dt(t_steps: int, dt: float) -> np.ndarray:
    return np.full(t_steps, dt, np.float32)


def log_dt(t_steps: int, dt0: float, growth: float) -> np.ndarray:
    """Geometrically growing sub-step sizes for retention sweeps."""
    return (dt0 * growth ** np.arange(t_steps)).astype(np.float32)


def times_from_dt(dt: np.ndarray, k_substeps: int) -> np.ndarray:
    """Simulated time at the END of each scan step (model.py contract)."""
    return np.cumsum(dt * k_substeps).astype(np.float32)


def constant(wave: np.ndarray, ch: int, level: float = 1.0) -> None:
    wave[:, ch] = level


def pulse(wave: np.ndarray, dwave: np.ndarray, times: np.ndarray, ch: int,
          t_rise: float, t_fall: float, tr: float) -> None:
    """Unit pulse: 0 -> 1 at t_rise (linear ramp tr), 1 -> 0 at t_fall.

    t_fall beyond the window end leaves the channel high.  Slopes are
    exact derivatives of the piecewise-linear waveform (the coupling-cap
    stamps integrate C * slope, so slope consistency matters more than
    waveform smoothness).
    """
    for i, t in enumerate(times):
        if t < t_rise:
            v, s = 0.0, 0.0
        elif t < t_rise + tr:
            v, s = (t - t_rise) / tr, 1.0 / tr
        elif t < t_fall:
            v, s = 1.0, 0.0
        elif t < t_fall + tr:
            v, s = 1.0 - (t - t_fall) / tr, -1.0 / tr
        else:
            v, s = 0.0, 0.0
        wave[i, ch] = v
        dwave[i, ch] = s


def fall(wave: np.ndarray, dwave: np.ndarray, times: np.ndarray, ch: int,
         t_fall: float, tr: float) -> None:
    """Unit level that falls to 0 at t_fall (active-low wordlines)."""
    for i, t in enumerate(times):
        if t < t_fall:
            v, s = 1.0, 0.0
        elif t < t_fall + tr:
            v, s = 1.0 - (t - t_fall) / tr, -1.0 / tr
        else:
            v, s = 0.0, 0.0
        wave[i, ch] = v
        dwave[i, ch] = s
