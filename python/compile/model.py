"""L2: batched transient-simulation graphs for the GCRAM critical paths.

Each public function here is one AOT artifact entry point: a jax.jit-able
function over fixed shapes (batch B, time steps T) that scans the L1
Pallas step kernel over a stimulus schedule and computes the measurements
the Rust characterizer consumes (threshold-crossing times, final levels,
downsampled waveforms for the figures).

Contract with the Rust side (mirrored in artifacts/manifest.json):

  inputs (all f32):
    v0     (B, NF)   initial free-node voltages
    amp    (B, NS)   per-design stimulus amplitudes
    params (B, P)    stamped element parameters (see circuits param names)
    cinv   (B, NF)   1/C per free node (0 pins a node to v0)
    wave   (T, NS)   normalized stimulus waveform (unit amplitude)
    dwave  (T, NS)   normalized stimulus slope (1/s)
    dt     (T,)      per-step sub-step size; each step advances K*dt[t]

  outputs: tuple, see each entry point's docstring.

Stimulus timing lives in runtime *inputs*, so the Rust coordinator can
retarget pulse widths / edges / levels without recompiling the artifact.
"""

import jax
import jax.numpy as jnp

from . import circuits
from .kernels import gcram_step

K_SUBSTEPS = 4
TRACE_DS = 4  # trace downsample factor for waveform outputs

BIG_TIME = 1e12  # "never crossed" sentinel, seconds


def _scan_transient(template, v0, amp, params, cinv, wave, dwave, dt,
                    block_b=gcram_step.DEFAULT_BLOCK_B, mode="heun"):
    """Run the full transient; returns (times (T,), trace (T, B, NF)).

    times[t] is the simulated time at the END of scan step t (each scan
    step advances K_SUBSTEPS * dt[t]).
    """
    step = gcram_step.make_step(template, K_SUBSTEPS, block_b, mode)
    b = v0.shape[0]

    def body(v, xs):
        w, dw, dt_t = xs
        vs = w[None, :] * amp
        dvs = dw[None, :] * amp
        v = step(v, vs, dvs, params, cinv, jnp.full((b, 1), dt_t))
        return v, v

    _, trace = jax.lax.scan(body, v0, (wave, dwave, dt))
    times = jnp.cumsum(dt * K_SUBSTEPS)
    return times, trace


def _cross_time(times, sig, thresh, rising: bool):
    """First threshold crossing with linear interpolation.

    times (T,), sig (T, B), thresh (B,) or scalar -> (B,) seconds,
    BIG_TIME if never crossed.
    """
    above = sig >= thresh if rising else sig <= thresh
    idx = jnp.argmax(above, axis=0)  # first True along T
    ever = jnp.any(above, axis=0)
    idx0 = jnp.maximum(idx - 1, 0)
    t1 = times[idx]
    t0 = times[idx0]
    b = jnp.arange(sig.shape[1])
    v1 = sig[idx, b]
    v0 = sig[idx0, b]
    th = jnp.broadcast_to(thresh, v0.shape)
    frac = jnp.where(jnp.abs(v1 - v0) > 1e-12, (th - v0) / (v1 - v0), 1.0)
    frac = jnp.clip(frac, 0.0, 1.0)
    t = t0 + frac * (t1 - t0)
    t = jnp.where(idx == 0, jnp.where(above[0], 0.0, t), t)
    return jnp.where(ever, t, BIG_TIME)


def _ds(x):
    return x[::TRACE_DS]


# --------------------------------------------------------------------------
# Artifact entry points
# --------------------------------------------------------------------------


def idvg(cards, vg, vds):
    """Fig. 8a/d: Id-Vg surfaces.  cards (B,6), vg (G,), vds (B,1).

    Returns (ids (B, G),) -- drain current in A at the card's W/L.
    """
    fn = gcram_step.make_idvg(vg.shape[0])
    return (fn(cards, vg, vds),)


def write_op(v0, amp, params, cinv, wave, dwave, dt):
    """Write transient (write driver -> WBL -> write tx -> SN).

    Returns:
      times_ds   (T/DS,)      downsampled time axis
      trace_ds   (T/DS,B,NF)  downsampled waveforms [sn, wbl]
      sn_final   (B,)         SN after the full window (incl. WWL-fall
                              coupling droop) -- the stored level
      t_wr       (B,)         write completion time (90% of peak for a
                              rising write, 10%-of-initial for a falling)
      sn_peak    (B,)         max SN during the window
    """
    t = circuits.write_template()
    times, trace = _scan_transient(t, v0, amp, params, cinv, wave, dwave, dt)
    sn = trace[:, :, t.free("sn")]
    sn0 = v0[:, t.free("sn")]
    sn_peak = jnp.max(sn, axis=0)
    t_rise = _cross_time(times, sn, 0.9 * sn_peak, rising=True)
    t_fall = _cross_time(times, sn, 0.1 * jnp.maximum(sn0, 1e-3), rising=False)
    falling = sn_peak <= sn0 + 0.05
    t_wr = jnp.where(falling, t_fall, t_rise)
    return (_ds(times), _ds(trace), trace[-1, :, t.free("sn")], t_wr, sn_peak)


def read_op(v0, amp, params, cinv, wave, dwave, dt):
    """Read transient (read tx drives RBL against bitline leakage).

    vref for the crossing measurements is 0.5 * max(amp[rwl],
    amp[rwl_idle]) per design, which equals VDD/2 for every flavor
    (predischarge flavors swing RWL to VDD; precharge flavors idle the
    RWL rail at VDD).  The Rust side adds sense-amp offset margins.

    Returns:
      times_ds (T/DS,), trace_ds (T/DS,B,NF) with nodes [sn, rbl]
      t_rise   (B,)  RBL crossing vref upward   (charging read)
      t_fall   (B,)  RBL crossing vref downward (discharging read)
      rbl_final(B,)  RBL at window end
      sn_final (B,)  SN at window end (shows RWL coupling boost/droop)
    """
    t = circuits.read_template()
    times, trace = _scan_transient(t, v0, amp, params, cinv, wave, dwave, dt)
    rbl = trace[:, :, t.free("rbl")]
    vdd_eff = jnp.maximum(amp[:, t.node("rwl") - t.nf],
                          amp[:, t.node("rwl_idle") - t.nf])
    vref = 0.5 * vdd_eff
    t_rise = _cross_time(times, rbl, vref, rising=True)
    t_fall = _cross_time(times, rbl, vref, rising=False)
    return (
        _ds(times), _ds(trace), t_rise, t_fall,
        trace[-1, :, t.free("rbl")], trace[-1, :, t.free("sn")],
    )


def retention(v0, amp, params, cinv, wave, dwave, dt):
    """Hold-state decay on a log time grid (Fig. 8b/c/e).

    dt grows geometrically (set by the Rust side: sub-steps from ~1 ps,
    1.082x per step, spanning ~1e5 s over T steps with K substeps).
    Returns:
      times_ds (T/DS,), trace_ds (T/DS,B,NF) with node [sn]
      t_retain (B,)  time SN decays below the hold threshold
                     (0.5 * initial SN); BIG_TIME if it never does
      sn_final (B,)
    """
    t = circuits.retention_template()
    times, trace = _scan_transient(t, v0, amp, params, cinv, wave, dwave, dt,
                                   mode="expdecay")
    sn = trace[:, :, t.free("sn")]
    vth_abs = amp[:, t.node("vth") - t.nf]
    vhold = jnp.where(vth_abs > 0.0, vth_abs, 0.5 * v0[:, t.free("sn")])
    t_ret = _cross_time(times, sn, vhold, rising=False)
    return (_ds(times), _ds(trace), t_ret, trace[-1, :, t.free("sn")])
