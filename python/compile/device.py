"""Unified smooth MOSFET model shared by the Pallas kernel, the jnp oracle
and (parameter-for-parameter) the Rust MNA simulator.

The model is an EKV-style interpolation that is continuous and
differentiable across subthreshold / triode / saturation:

    i_f  = ln(1 + exp((vp - v_s) / (2 phi_t)))^2        (forward)
    i_r  = ln(1 + exp((vp - v_d) / (2 phi_t)))^2        (reverse)
    vp   = (v_g - vt) / n                               (pinch-off)
    I_DS = 2 n kp (W/L) phi_t^2 (i_f - i_r) (1 + lam |v_ds|)

All voltages are source/drain symmetric, so the expression is valid for
either current direction (i_f - i_r is antisymmetric under d<->s swap).
PMOS devices are evaluated with all node voltages negated (handled by the
`sign` parameter), so one expression serves both polarities.

The per-device "card" is the 6-vector used throughout the stack:

    [kp, vt, n, lam, w_over_l, sign]

sign = +1 for NMOS, -1 for PMOS.  Subthreshold swing follows from n:
SS = n * phi_t * ln(10).  Off-current follows from (vt, n), which is how
the ultra-low-leakage OS (ITO-like) card reaches < 1e-18 A/um.
"""

import jax.numpy as jnp

# Thermal voltage at 300 K.  Keep as a module constant so Rust mirrors it.
PHI_T = 0.02585

# Param-column layout of one MOS card inside a stamped parameter vector.
MOS_CARD_COLS = 6  # kp, vt, n, lam, w_over_l, sign


def softlog1pexp(x):
    """Numerically-stable ln(1 + exp(x)).

    For large x this is ~x, for very negative x it underflows to exp(x);
    jnp.logaddexp(0, x) implements exactly that.
    """
    return jnp.logaddexp(0.0, x)


def mos_ids(vd, vg, vs, kp, vt, n, lam, w_over_l, sign):
    """Drain current (A) flowing d -> s.  All args broadcastable arrays.

    `sign` folds NMOS/PMOS into one expression: node voltages are
    reflected for PMOS and the resulting current is reflected back.
    """
    vd_, vg_, vs_ = sign * vd, sign * vg, sign * vs
    vp = (vg_ - vt) / n
    i_f = softlog1pexp((vp - vs_) / (2.0 * PHI_T)) ** 2
    i_r = softlog1pexp((vp - vd_) / (2.0 * PHI_T)) ** 2
    i_spec = 2.0 * n * kp * w_over_l * PHI_T * PHI_T
    clm = 1.0 + lam * jnp.abs(vd_ - vs_)
    return sign * i_spec * (i_f - i_r) * clm


def mos_ids_card(vd, vg, vs, card):
    """`card` is (..., 6) laid out per MOS_CARD_COLS."""
    return mos_ids(
        vd, vg, vs,
        card[..., 0], card[..., 1], card[..., 2],
        card[..., 3], card[..., 4], card[..., 5],
    )


# --- Reference device cards (synthetic generic 40 nm node, `sg40`) -------
#
# Calibrated to public 40 nm-class numbers: Ion ~ 600/300 uA/um (N/P) at
# VDD = 1.1 V, SS ~ 85 mV/dec, Ioff ~ nA/um.  The OS (ITO-like) card has
# SS ~ 65 mV/dec, low mobility, VT ~ 0.9 V giving Ioff < 1e-18 A/um --
# matching the paper's "<1e-18 A/um" claim for oxide-semiconductor
# channels.  `kp` is in A/V^2 for W/L = 1.

SG40_VDD = 1.1

SI_NMOS = dict(kp=320e-6, vt=0.45, n=1.40, lam=0.08, sign=+1.0)
SI_PMOS = dict(kp=160e-6, vt=0.45, n=1.42, lam=0.10, sign=-1.0)
# High-VT flavors for retention modulation (Fig. 8c).
# SI_PMOS_HVT is the NP gain cell's read transistor: vt folds in the
# body effect of a source-at-VDD device (vt_eff ~ vt + (n-1)*vdd) that
# the bulk-referenced EKV form does not model explicitly.
SI_PMOS_HVT = dict(kp=140e-6, vt=0.90, n=1.38, lam=0.08, sign=-1.0)
SI_NMOS_HVT = dict(kp=280e-6, vt=0.60, n=1.36, lam=0.07, sign=+1.0)
SI_NMOS_LVT = dict(kp=360e-6, vt=0.32, n=1.45, lam=0.10, sign=+1.0)
# Oxide-semiconductor (ITO-like) n-type card; no p-type OS exists worth
# using (paper SS V-A), so OS-OS gain cells are NMOS-NMOS.  vt=0.35 puts
# the baseline OS-OS retention in the millisecond range (Fig. 8e, the
# TCAD-calibrated ITO device); the "VT/material engineering" variant
# below reaches the material's <1e-18 A/um floor and >10 s retention.
OS_NMOS = dict(kp=12e-6, vt=0.35, n=1.10, lam=0.02, sign=+1.0)
OS_NMOS_HVT = dict(kp=9e-6, vt=0.95, n=1.08, lam=0.02, sign=+1.0)


def card_vec(c, w_over_l):
    """Pack a card dict + geometry into the 6-column vector."""
    return jnp.array(
        [c["kp"], c["vt"], c["n"], c["lam"], w_over_l, c["sign"]],
        dtype=jnp.float32,
    )
