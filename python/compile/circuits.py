"""Stamped fixed-topology circuit templates for the GCRAM critical paths.

A *template* is a tiny circuit (<= a handful of nodes) whose topology is
fixed at trace time and whose element parameters are batched per design
point.  Node voltages split into NF *free* nodes (integrated by the
transient engine) and NS *stimulus* nodes (driven waveforms: wordlines,
rails, data inputs).  Stamps reference nodes by static index into the
concatenated vector [free | stim], so the generated HLO contains no
dynamic gathers -- everything is column slicing over (B,) vectors, which
is exactly the element-wise VPU work the Pallas kernel tiles.

Stamp kinds:

  MOS  (d, g, s, p0)  -- EKV device, 6 param columns at p0 (see device.py)
  CAPC (src, dst, p0) -- coupling cap from a *stimulus* node: the current
                         injected into free node `dst` is C * dV(src)/dt,
                         with the slope supplied by the stimulus input.
                         1 param column (C in F).
  RES  (a, b, p0)     -- linear conductance between two nodes.  1 column
                         (G in S).
  ISRC (dst, p0)      -- constant current into free node `dst` (signed).
                         1 column (A).

Templates defined here:

  retention -- storage node decaying through write-transistor subthreshold
               leakage + read-transistor gate leakage (Fig. 8b/c/e).
  write     -- write driver inverter -> WBL -> write transistor -> SN,
               with WWL->SN coupling cap (write delay, stored-'1' level,
               coupling droop at WWL fall).
  read      -- read transistor (source on RWL, gate on SN) driving RBL
               against bitline leakage, with RWL->SN coupling
               (boost for NP cells, droop for NN cells).  Polarity is
               entirely in the card sign + stimulus amplitudes, so one
               template serves Si-Si NP, Si-Si NN and OS-OS flavors.

The param layout of each template is reported by `param_names()` and is
mirrored by the Rust side via artifacts/manifest.json.
"""

from dataclasses import dataclass, field
from typing import List, Tuple

import jax.numpy as jnp

from . import device


@dataclass(frozen=True)
class Mos:
    d: int
    g: int
    s: int
    p0: int


@dataclass(frozen=True)
class CapCouple:
    src: int  # stimulus node index (in concat space)
    dst: int  # free node index
    p0: int


@dataclass(frozen=True)
class Res:
    a: int
    b: int
    p0: int


@dataclass(frozen=True)
class Isrc:
    dst: int
    p0: int


@dataclass
class Template:
    """A stamped circuit: topology + naming metadata."""

    name: str
    free_nodes: List[str]
    stim_nodes: List[str]
    stamps: List[object] = field(default_factory=list)
    pnames: List[str] = field(default_factory=list)

    @property
    def nf(self) -> int:
        return len(self.free_nodes)

    @property
    def ns(self) -> int:
        return len(self.stim_nodes)

    @property
    def npar(self) -> int:
        return len(self.pnames)

    def node(self, name: str) -> int:
        """Static index in the concatenated [free | stim] vector."""
        if name in self.free_nodes:
            return self.free_nodes.index(name)
        return self.nf + self.stim_nodes.index(name)

    def free(self, name: str) -> int:
        return self.free_nodes.index(name)

    # -- builders ---------------------------------------------------------
    def add_mos(self, tag: str, d: str, g: str, s: str):
        p0 = self.npar
        for c in ("kp", "vt", "n", "lam", "wl", "sign"):
            self.pnames.append(f"{tag}.{c}")
        self.stamps.append(Mos(self.node(d), self.node(g), self.node(s), p0))

    def add_capc(self, tag: str, src: str, dst: str):
        p0 = self.npar
        self.pnames.append(f"{tag}.c")
        self.stamps.append(CapCouple(self.node(src) - self.nf, self.free(dst), p0))

    def add_res(self, tag: str, a: str, b: str):
        p0 = self.npar
        self.pnames.append(f"{tag}.g")
        self.stamps.append(Res(self.node(a), self.node(b), p0))

    def add_isrc(self, tag: str, dst: str):
        p0 = self.npar
        self.pnames.append(f"{tag}.i")
        self.stamps.append(Isrc(self.free(dst), p0))


def make_rhs(t: Template):
    """Return f(v, vs, dvs, params) -> per-free-node current (B, NF).

    v:(B,NF) free node voltages, vs:(B,NS) stimulus voltages,
    dvs:(B,NS) stimulus slopes (V/s), params:(B,P).
    Shared verbatim by the Pallas kernel (on block values) and the jnp
    reference oracle, so there is a single source of truth for the RHS.
    """
    nf = t.nf
    stamps = tuple(t.stamps)

    def rhs(v, vs, dvs, params):
        vall = jnp.concatenate([v, vs], axis=-1)
        acc = [jnp.zeros(v.shape[:-1], v.dtype) for _ in range(nf)]

        def col(i):
            return vall[..., i]

        for st in stamps:
            if isinstance(st, Mos):
                card = params[..., st.p0 : st.p0 + device.MOS_CARD_COLS]
                ids = device.mos_ids_card(col(st.d), col(st.g), col(st.s), card)
                if st.d < nf:
                    acc[st.d] = acc[st.d] - ids
                if st.s < nf:
                    acc[st.s] = acc[st.s] + ids
            elif isinstance(st, CapCouple):
                c = params[..., st.p0]
                acc[st.dst] = acc[st.dst] + c * dvs[..., st.src]
            elif isinstance(st, Res):
                g = params[..., st.p0]
                i = g * (col(st.a) - col(st.b))
                if st.a < nf:
                    acc[st.a] = acc[st.a] - i
                if st.b < nf:
                    acc[st.b] = acc[st.b] + i
            elif isinstance(st, Isrc):
                acc[st.dst] = acc[st.dst] + params[..., st.p0]
            else:  # pragma: no cover - template construction guards this
                raise TypeError(st)
        return jnp.stack(acc, axis=-1)

    return rhs


# --------------------------------------------------------------------------
# Concrete templates.
# --------------------------------------------------------------------------


def retention_template() -> Template:
    """SN decay during hold (Fig. 8b/c/e).

    Worst case for stored '1': WWL at its hold level, WBL held at 0 by an
    idle write driver, so the write transistor's subthreshold current
    discharges SN; the read transistor's gate leak (a small conductance to
    a reference) adds to it.  An ISRC stamp models any extra disturb.
    """
    t = Template(
        name="retention",
        free_nodes=["sn"],
        # "vth" is a measurement-only pseudo-stimulus: its per-design
        # amplitude carries the absolute hold threshold for t_retain
        # (no stamp references it).  amp[vth] == 0 falls back to the
        # relative 0.5 * v0 threshold.
        stim_nodes=["wwl", "wbl", "gnd", "vth"],
    )
    t.add_mos("mwr", d="sn", g="wwl", s="wbl")
    t.add_res("gleak", a="sn", b="gnd")
    t.add_isrc("idist", dst="sn")
    return t


def write_template() -> Template:
    """Write path: driver inverter -> WBL (RC) -> write tx -> SN.

    The WWL waveform rises, holds, then *falls* inside the window so the
    recorded final SN includes the WWL->SN coupling droop the paper
    discusses (SS V-A).  A WWL level shifter is expressed purely through
    the WWL stimulus amplitude (VDD + boost).
    """
    t = Template(
        name="write",
        free_nodes=["sn", "wbl"],
        stim_nodes=["wwl", "dinb", "vdd", "gnd"],
    )
    t.add_mos("mwr", d="sn", g="wwl", s="wbl")
    t.add_mos("mdrvp", d="wbl", g="dinb", s="vdd")  # PMOS card expected
    t.add_mos("mdrvn", d="wbl", g="dinb", s="gnd")  # NMOS card expected
    t.add_capc("cwwl_sn", src="wwl", dst="sn")
    t.add_res("gwbl", a="wbl", b="gnd")  # WBL leakage of unselected cells
    return t


def read_template() -> Template:
    """Read path: read tx (source on RWL, gate on SN) drives RBL.

    Flavor polarity is data, not code:
      Si-Si NP : PMOS card, RBL predischarged to 0, RWL 0 -> VDD
                 (rising edge boosts SN through the coupling cap);
      Si-Si NN : NMOS card, RBL precharged to VDD, RWL VDD -> 0
                 (falling edge droops SN);
      OS-OS NN : NMOS OS card, precharge, active-low RWL.
    `mrbl_leak` aggregates the off-state leakage of the (rows-1)
    unselected cells sharing the bitline (w_over_l scaled by rows-1,
    gate tied to the unselected-SN worst-case stimulus level).
    """
    t = Template(
        name="read",
        free_nodes=["sn", "rbl"],
        stim_nodes=["rwl", "rwl_idle", "snu", "gnd"],
    )
    t.add_mos("mrd", d="rbl", g="sn", s="rwl")
    t.add_mos("mrbl_leak", d="rbl", g="snu", s="rwl_idle")
    t.add_capc("crwl_sn", src="rwl", dst="sn")
    t.add_res("grbl", a="rbl", b="gnd")
    return t


TEMPLATES = {
    "retention": retention_template,
    "write": write_template,
    "read": read_template,
}
