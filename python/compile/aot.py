"""AOT lowering: JAX/Pallas entry points -> HLO *text* artifacts.

Emit HLO text (NOT .serialize()): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which the xla crate's XLA (xla_extension 0.5.1)
rejects (`proto.id() <= INT_MAX`).  The text parser reassigns ids, so
text round-trips cleanly -- see /opt/xla-example/gen_hlo.py.

Also writes artifacts/manifest.json: the single source of truth the Rust
runtime reads for batch sizes, step counts, node/stimulus/param layouts
and output arity.  Rust never hard-codes a param column index.

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import circuits, model

# Fixed AOT shapes.  B must be a multiple of kernels.gcram_step block.
BATCH = 256
IDVG_BATCH = 128
IDVG_GRID = 64
T_WRITE = 384
T_READ = 384
T_RETENTION = 448


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _transient_specs(template, t_steps):
    nf, ns, p = template.nf, template.ns, template.npar
    return (
        _f32(BATCH, nf),      # v0
        _f32(BATCH, ns),      # amp
        _f32(BATCH, p),       # params
        _f32(BATCH, nf),      # cinv
        _f32(t_steps, ns),    # wave
        _f32(t_steps, ns),    # dwave
        _f32(t_steps),        # dt
    )


def _transient_manifest(template, t_steps, outputs, mode="heun"):
    return {
        "batch": BATCH,
        "steps": t_steps,
        "integrator": mode,
        "k_substeps": model.K_SUBSTEPS,
        "trace_ds": model.TRACE_DS,
        "big_time": model.BIG_TIME,
        "free_nodes": template.free_nodes,
        "stim_nodes": template.stim_nodes,
        "params": template.pnames,
        "inputs": ["v0", "amp", "params", "cinv", "wave", "dwave", "dt"],
        "outputs": outputs,
    }


def build_all():
    """Return {name: (hlo_text, manifest_entry)} for every artifact."""
    arts = {}

    # idvg
    lowered = jax.jit(model.idvg).lower(
        _f32(IDVG_BATCH, 6), _f32(IDVG_GRID), _f32(IDVG_BATCH, 1))
    arts["idvg"] = (to_hlo_text(lowered), {
        "batch": IDVG_BATCH,
        "grid": IDVG_GRID,
        "inputs": ["cards", "vg", "vds"],
        "outputs": ["ids"],
        "card_cols": ["kp", "vt", "n", "lam", "wl", "sign"],
    })

    # transients
    for name, fn, tmpl, t_steps, outs, mode in [
        ("write", model.write_op, circuits.write_template(), T_WRITE,
         ["times_ds", "trace_ds", "sn_final", "t_wr", "sn_peak"], "heun"),
        ("read", model.read_op, circuits.read_template(), T_READ,
         ["times_ds", "trace_ds", "t_rise", "t_fall", "rbl_final",
          "sn_final"], "heun"),
        ("retention", model.retention, circuits.retention_template(),
         T_RETENTION, ["times_ds", "trace_ds", "t_retain", "sn_final"],
         "expdecay"),
    ]:
        lowered = jax.jit(fn).lower(*_transient_specs(tmpl, t_steps))
        arts[name] = (to_hlo_text(lowered),
                      _transient_manifest(tmpl, t_steps, outs, mode))
    return arts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {}
    for name, (text, meta) in build_all().items():
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["file"] = f"{name}.hlo.txt"
        manifest[name] = meta
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
