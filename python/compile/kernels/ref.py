"""Pure-jnp correctness oracle for the Pallas transient kernel.

Same RHS (circuits.make_rhs), same Heun update, no pallas_call -- this is
the CORE correctness signal: python/tests/test_kernel.py sweeps shapes
and parameters with hypothesis and asserts allclose between this and
kernels.gcram_step.make_step.
"""

import jax.numpy as jnp

from .. import circuits, device


def make_step_ref(template: circuits.Template, k_substeps: int = 4,
                  mode: str = "heun"):
    """Reference step(v, vs, dvs, params, cinv, dt) -> v' (same contract
    as gcram_step.make_step, without batch-tiling restrictions)."""
    assert mode in ("heun", "expdecay"), mode
    rhs = circuits.make_rhs(template)

    def step(v, vs, dvs, params, cinv, dt):
        pinned = cinv == 0.0
        for _ in range(k_substeps):
            if mode == "heun":
                i1 = rhs(v, vs, dvs, params)
                v1 = jnp.where(pinned, v, v + dt * i1 * cinv)
                i2 = rhs(v1, vs, dvs, params)
                v = jnp.where(pinned, v,
                              v + (0.5 * dt) * (i1 + i2) * cinv)
            else:  # expdecay (see gcram_step._step_body)
                i1 = rhs(v, vs, dvs, params)
                dv = dt * i1 * cinv
                decaying = (dv < 0.0) & (v > 0.0)
                v_dec = v * jnp.exp(dv / jnp.maximum(v, 1e-6))
                v_chg = jnp.where(v <= 0.0,
                                  jnp.minimum(jnp.maximum(v + dv, v), 0.0),
                                  v + dv)
                v = jnp.where(pinned, v,
                              jnp.where(decaying, v_dec, v_chg))
        return v

    return step


def idvg_ref(cards, vg, vds):
    """Reference Id-Vg surface: cards (B,6), vg (G,), vds (B,1) -> (B,G)."""
    return device.mos_ids(
        vds, vg[None, :], 0.0,
        cards[:, 0:1], cards[:, 1:2], cards[:, 2:3],
        cards[:, 3:4], cards[:, 4:5], cards[:, 5:6],
    )


def simulate_ref(template, v0, amp, params, cinv, wave, dwave, dt,
                 k_substeps: int = 4):
    """Plain-python time loop used by model tests (slow, trustworthy).

    wave/dwave: (T, NS) normalized stimulus and slope; amp: (B, NS).
    dt: (T,) sub-step sizes (each scan step advances K * dt[t]).
    Returns trace (T, B, NF).
    """
    step = make_step_ref(template, k_substeps)
    out = []
    v = v0
    for t in range(wave.shape[0]):
        vs = wave[t][None, :] * amp
        dvs = dwave[t][None, :] * amp
        v = step(v, vs, dvs, params, cinv, jnp.full((v.shape[0], 1), dt[t]))
        out.append(v)
    return jnp.stack(out, axis=0)
