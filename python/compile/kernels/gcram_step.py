"""L1 Pallas kernel: batched Heun (RK2) transient integration step.

This is the compute hot-spot of the whole stack: every design point in a
DSE sweep integrates the same stamped circuit template, so the work is a
(B, NF) element-wise problem batched over thousands of designs.  The
kernel tiles the batch into VMEM-resident blocks and performs K Heun
sub-steps per grid step, amortizing HBM<->VMEM traffic K-fold (the
BlockSpec plays the role the paper's serial per-config HSPICE runs
played; see DESIGN.md section Hardware-Adaptation).

The circuit RHS is *shared* with the pure-jnp oracle (circuits.make_rhs),
so kernel and reference cannot drift.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom call
the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
VMEM footprint / utilization estimates live in DESIGN.md section 9.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import circuits

# Default batch tile.  128 designs/tile keeps the block comfortably in
# VMEM (see perf notes) while giving the VPU full lanes.
DEFAULT_BLOCK_B = 128


def _step_body(rhs, k_substeps, mode, v_ref, vs_ref, dvs_ref, p_ref,
               cinv_ref, dt_ref, o_ref):
    """One grid step: K integration sub-steps on a (BT, NF) tile.

    mode == "heun": explicit RK2.  Used for the short-window write/read
    transients where L3 picks dt well inside the fastest RC.

    mode == "expdecay": exponential-Euler toward 0 for discharging
    nodes -- exact for a linear leak, unconditionally stable, monotone.
    Used for retention, where dt grows geometrically over ~14 decades
    and explicit RK2 would go unstable once dt >> C/g.
    """
    v = v_ref[...]
    vs = vs_ref[...]
    dvs = dvs_ref[...]
    p = p_ref[...]
    cinv = cinv_ref[...]
    dt = dt_ref[...]  # (BT, 1) sub-step size

    # cinv == 0 pins a node (rails); the jnp.where guard (rather than
    # multiply-by-zero) keeps pinned nodes exact even if an unpinned
    # node produces inf/nan under a pathological parameter set.
    pinned = cinv == 0.0
    for _ in range(k_substeps):
        if mode == "heun":
            i1 = rhs(v, vs, dvs, p)
            v1 = jnp.where(pinned, v, v + dt * i1 * cinv)
            i2 = rhs(v1, vs, dvs, p)
            v = jnp.where(pinned, v, v + (0.5 * dt) * (i1 + i2) * cinv)
        else:  # expdecay
            i1 = rhs(v, vs, dvs, p)
            dv = dt * i1 * cinv
            decaying = (dv < 0.0) & (v > 0.0)
            v_dec = v * jnp.exp(dv / jnp.maximum(v, 1e-6))
            # below 0 only relaxation *toward* 0 is physical: float32
            # rounding noise in the rhs, amplified by huge dt, must not
            # drift a dead node further negative
            v_chg = jnp.where(v <= 0.0,
                              jnp.minimum(jnp.maximum(v + dv, v), 0.0),
                              v + dv)
            v = jnp.where(pinned, v, jnp.where(decaying, v_dec, v_chg))
    o_ref[...] = v


def make_step(template: circuits.Template, k_substeps: int = 4,
              block_b: int = DEFAULT_BLOCK_B, mode: str = "heun"):
    """Build the batched step function for one template.

    Returns step(v, vs, dvs, params, cinv, dt) -> v' where
      v     : (B, NF)  free-node voltages
      vs    : (B, NS)  stimulus voltages (held constant over the K substeps)
      dvs   : (B, NS)  stimulus slopes (V/s) for coupling-cap stamps
      params: (B, P)   stamped element parameters
      cinv  : (B, NF)  1/C per free node (0 pins a node)
      dt    : (B, 1)   sub-step size in seconds
    B must be a multiple of block_b (the AOT wrapper pads).
    """
    assert mode in ("heun", "expdecay"), mode
    rhs = circuits.make_rhs(template)
    nf, ns, npar = template.nf, template.ns, template.npar
    kern = functools.partial(_step_body, rhs, k_substeps, mode)

    def step(v, vs, dvs, params, cinv, dt):
        b = v.shape[0]
        assert b % block_b == 0, (b, block_b)
        grid = (b // block_b,)

        def bspec(width):
            return pl.BlockSpec((block_b, width), lambda i: (i, 0))

        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[bspec(nf), bspec(ns), bspec(ns), bspec(npar),
                      bspec(nf), bspec(1)],
            out_specs=bspec(nf),
            out_shape=jax.ShapeDtypeStruct((b, nf), jnp.float32),
            interpret=True,
        )(v, vs, dvs, params, cinv, dt)

    return step


def make_idvg(n_vg: int, block_b: int = DEFAULT_BLOCK_B):
    """Batched Id-Vg surface kernel: (B, 6) cards x (n_vg,) gate grid.

    Used by the `idvg` artifact (Fig. 8a/d) and by the Rust/Python device
    model parity test.  vd/vs are per-design scalars so the same artifact
    sweeps both linear (|VDS| small) and saturation regimes.
    """
    from .. import device

    def kern(card_ref, vg_ref, vds_ref, o_ref):
        card = card_ref[...]  # (BT, 6)
        vg = vg_ref[...]      # (1, n_vg) broadcast row
        vds = vds_ref[...]    # (BT, 1)
        o_ref[...] = device.mos_ids(
            vds, vg, 0.0,
            card[:, 0:1], card[:, 1:2], card[:, 2:3],
            card[:, 3:4], card[:, 4:5], card[:, 5:6],
        )

    def idvg(cards, vg, vds):
        b = cards.shape[0]
        assert b % block_b == 0, (b, block_b)
        grid = (b // block_b,)
        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_b, 6), lambda i: (i, 0)),
                pl.BlockSpec((1, n_vg), lambda i: (0, 0)),
                pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((block_b, n_vg), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((b, n_vg), jnp.float32),
            interpret=True,
        )(cards, vg.reshape(1, n_vg), vds)

    return idvg
