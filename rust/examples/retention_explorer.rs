//! Fig. 8 explorer: Id-Vg curves and retention modulation across write
//! transistor VT and channel material, via the batched XLA artifacts.
use opengcram::runtime::{engines, SharedRuntime};
use opengcram::tech::sg40;
use opengcram::util::eng;
use std::path::Path;

fn main() -> opengcram::Result<()> {
    let tech = sg40();
    let rt = SharedRuntime::auto(Path::new("artifacts"));
    println!("execution backend: {}", rt.backend_name());

    println!("== Fig. 8a/d: Id-Vg (|VDS| = 1.1 V) ==");
    let cards = vec![
        (*tech.card("si_nmos"), 2.0),
        (*tech.card("si_pmos"), 2.0),
        (*tech.card("os_nmos"), 1.5),
        (*tech.card("os_nmos_hvt"), 1.5),
    ];
    let (vg, rows) = rt.with(|r| engines::idvg(r, &cards, -0.2, 1.2, 1.1))?;
    let names = ["si_nmos", "si_pmos", "os_nmos", "os_nmos_hvt"];
    print!("{:>8}", "vg");
    for n in names {
        print!("{n:>14}");
    }
    println!();
    for i in (0..vg.len()).step_by(8) {
        print!("{:>8.2}", vg[i]);
        for r in &rows {
            print!("{:>14.3e}", r[i]);
        }
        println!();
    }

    println!("\n== Fig. 8b/c/e: retention vs write VT (batched sweep) ==");
    let mut pts = Vec::new();
    let mut labels = Vec::new();
    for vt in [0.35, 0.40, 0.45, 0.50, 0.55, 0.60, 0.65, 0.70] {
        pts.push(engines::RetentionPoint {
            write_card: tech.card("si_nmos").with_vt(vt),
            write_wl: 2.5,
            c_sn: 1.2e-15,
            g_gate_leak: 1e-16,
            i_disturb: 0.0,
            v0: 0.6,
            vth: 0.3,
        });
        labels.push(format!("Si vt={vt:.2}"));
    }
    // WWLLS variant: boosted write -> higher initial level, same decay
    pts.push(engines::RetentionPoint {
        write_card: *tech.card("si_nmos"),
        write_wl: 2.5,
        c_sn: 1.2e-15,
        g_gate_leak: 1e-16,
        i_disturb: 0.0,
        v0: 0.95,
        vth: 0.3,
    });
    labels.push("Si nominal + WWLLS".into());
    for (card, label) in [("os_nmos", "OS-OS (ITO)"), ("os_nmos_hvt", "OS-OS VT-engineered")] {
        pts.push(engines::RetentionPoint {
            write_card: *tech.card(card),
            write_wl: 1.2,
            c_sn: 1.2e-15,
            g_gate_leak: 1e-17,
            i_disturb: 0.0,
            v0: 0.6,
            vth: 0.3,
        });
        labels.push(label.into());
    }
    let res = rt.with(|r| engines::retention(r, &pts))?;
    for (l, r) in labels.iter().zip(&res) {
        println!("  {l:24} retention = {:>12}", eng(r.t_retain, "s"));
    }
    println!("\n(paper: Si-Si ~ us, OS-OS ~ ms, engineered OS > 10 s; VT raises retention monotonically)");
    Ok(())
}
