//! Fig. 1(a) methodology walk-through: "porting the compiler to a new
//! technology node" is pure data — the same compiler runs on the
//! relaxed sg130 node, and the same DRC/LVS/characterization gates
//! apply.  (Cell generators target sg40 pitches, so this example ports
//! the *flow*: tech script -> core cells -> checks -> bank estimate.)
use opengcram::layout::{cells, Library};
use opengcram::tech::{sg130, sg40, LayerRole};
use opengcram::util::eng;
use opengcram::{characterize, compiler, sim};

fn main() -> opengcram::Result<()> {
    println!("== step 1: technology scripts (layer stack + rules + cards) ==");
    for t in [sg40(), sg130()] {
        println!(
            "  {}: {} layers, vdd {} V, m1 pitch {} nm, {} device cards",
            t.name,
            t.layers.len(),
            t.vdd,
            t.rules.layer(LayerRole::Metal1).min_width_nm + t.rules.layer(LayerRole::Metal1).min_space_nm,
            t.cards.len()
        );
    }

    println!("\n== step 2: core custom cells on the home node (sg40) ==");
    let t40 = sg40();
    let mut lib = Library::default();
    for lc in [cells::gc2t_sisi(&t40, false), cells::sense_amp(&t40), cells::write_driver(&t40)] {
        let name = lc.layout.name.clone();
        lib.add(lc.layout.clone());
        let rects = lib.flatten(&name)?;
        let drc = opengcram::drc::check(&t40, &rects);
        let lvs = opengcram::lvs::check(&t40, &lib, &name, &lc.circuit)?;
        println!("  {name}: DRC {} / LVS {}", if drc.clean() { "clean" } else { "FAIL" }, if lvs.matched { "clean" } else { "FAIL" });
    }

    println!("\n== step 3: device model sanity on the ported node (sg130) ==");
    let t130 = sg130();
    for name in ["si_nmos", "si_pmos"] {
        let c = t130.card(name);
        println!(
            "  {name}: Ion {}  Ioff {}  (vdd {} V)",
            eng(sim::ion(c, 1.0, t130.vdd), "A"),
            eng(sim::ioff(c, 1.0, t130.vdd), "A"),
            t130.vdd
        );
    }

    println!("\n== step 4: analytical bank estimate on both nodes ==");
    for t in [sg40(), sg130()] {
        let cfg = compiler::Config::new(32, 32, compiler::CellFlavor::Sram6t);
        // sg130 lacks the OS layers; the SRAM flow needs none of them
        if let Ok(bank) = compiler::compile(&t, &cfg) {
            let p = characterize::analytical(&t, &bank);
            println!("  {}: f_op {}  leak {}", t.name, eng(p.f_op_hz, "Hz"), eng(p.leakage_w, "W"));
        } else {
            println!("  {}: compile skipped (cell generators target sg40 pitches)", t.name);
        }
    }
    println!("\nporting checklist (Fig. 1a): tech script -> core cells -> DRC/LVS iterate -> characterize");
    Ok(())
}
