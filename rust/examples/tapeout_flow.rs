//! Full tapeout-style flow for the Fig. 5 bank: compile -> DRC -> LVS
//! -> GDS export, with pass/fail reporting at each gate.
use opengcram::compiler::{compile, CellFlavor, Config};
use opengcram::layout::{cells, Library};
use opengcram::tech::sg40;
use std::path::Path;

fn main() -> opengcram::Result<()> {
    let tech = sg40();
    let cfg = Config::new(32, 32, CellFlavor::GcSiSiNp);
    println!("[1/4] compiling 32x32 dual-port Si-Si GCRAM bank (Fig. 5)...");
    let bank = compile(&tech, &cfg)?;

    println!("[2/4] DRC over the flattened bitcell array...");
    let rects = bank.library.flatten("bitcell_array")?;
    let rep = opengcram::drc::check(&tech, &rects);
    anyhow::ensure!(rep.clean(), "DRC FAILED: {} violations (first: {})", rep.violations.len(), rep.violations[0]);
    println!("      CLEAN over {} rects", rep.rects_checked);

    println!("[3/4] LVS on every leaf cell used by the bank...");
    for lc in [
        cells::gc2t_sisi(&tech, false),
        cells::sense_amp(&tech),
        cells::write_driver(&tech),
        cells::predischarge(&tech),
        cells::level_shifter(&tech),
    ] {
        let mut lib = Library::default();
        let name = lc.layout.name.clone();
        lib.add(lc.layout.clone());
        let r = opengcram::lvs::check(&tech, &lib, &name, &lc.circuit)?;
        anyhow::ensure!(r.matched, "LVS FAILED on {name}: {}", r.detail);
        println!("      {name}: clean");
    }

    println!("[4/4] GDS export...");
    let path = Path::new("/tmp/gcram_tapeout.gds");
    opengcram::layout::gds::write_file(&bank.library, &tech, "opengcram_bank", path)?;
    let bytes = std::fs::metadata(path)?.len();
    println!("      wrote {path:?} ({bytes} bytes) — tapeout-ready per the sg40 deck");
    Ok(())
}
