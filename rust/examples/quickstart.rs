//! Quickstart: compile a 32x32 GCRAM bank, characterize it on whichever
//! execution backend is available (AOT artifacts via PJRT, else the
//! native in-process solver), export SPICE + GDS.
//! Run: cargo run --release --example quickstart
use opengcram::compiler::{compile, CellFlavor, Config};
use opengcram::runtime::SharedRuntime;
use opengcram::tech::sg40;
use opengcram::util::eng;
use opengcram::{characterize, report};
use std::path::Path;

fn main() -> opengcram::Result<()> {
    let tech = sg40();
    let cfg = Config::new(32, 32, CellFlavor::GcSiSiNp);
    let bank = compile(&tech, &cfg)?;
    println!(
        "compiled 1 Kb GCRAM bank: {} um^2 total, {} um^2 array, {} delay-chain stages",
        bank.layout.total_area_um2().round(),
        bank.layout.array_area_um2().round(),
        bank.delay_chain_stages
    );
    std::fs::write("/tmp/gcram_bank.sp", opengcram::netlist::spice::emit(&bank.netlist))?;
    opengcram::layout::gds::write_file(&bank.library, &tech, "opengcram", Path::new("/tmp/gcram_bank.gds"))?;
    println!("wrote /tmp/gcram_bank.sp and /tmp/gcram_bank.gds");

    let rt = SharedRuntime::auto(Path::new("artifacts"));
    println!("execution backend: {}", rt.backend_name());
    // characterize_all packs designs into shared artifact batches; a
    // singleton list at window resolution 0 bitwise-matches the
    // single-design path (sweeps pass DEFAULT_WINDOW_RESOLUTION to
    // trade a bounded deviation for cross-design packing)
    let perf =
        characterize::characterize_all(&tech, &rt, std::slice::from_ref(&bank), 0.0)?.remove(0);
    println!(
        "f_op {}  bandwidth {} Gb/s  retention {}  leakage {}  functional {}",
        eng(perf.f_op_hz, "Hz"),
        report::gbps(perf.bandwidth_bps),
        eng(perf.retention_s, "s"),
        eng(perf.leakage_w, "W"),
        perf.functional
    );
    Ok(())
}
