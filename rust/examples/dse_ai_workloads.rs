//! END-TO-END DRIVER: the paper's headline use case (SS V-E).  Profiles
//! the seven Table-I AI workloads, sweeps GCRAM bank configurations
//! through the batch-first compile -> characterize pipeline (every
//! design's transient points pack into shared padded artifact batches
//! via the coordinator), prints the Fig. 10 shmoo plots and the
//! headline metric (largest passing bank per task), and runs the SS VI
//! co-optimizer — also batch-first — for an L1-cache target.
use opengcram::characterize::DEFAULT_WINDOW_RESOLUTION;
use opengcram::compiler::CellFlavor;
use opengcram::runtime::SharedRuntime;
use opengcram::tech::sg40;
use opengcram::util::eng;
use opengcram::{dse, report, workloads};
use std::path::Path;
use std::time::Instant;

fn main() -> opengcram::Result<()> {
    let tech = sg40();
    let rt = SharedRuntime::auto(Path::new("artifacts"));
    println!("execution backend: {}", rt.backend_name());
    let t0 = Instant::now();

    println!("== profiling Table-I workloads (GainSight-style) ==");
    for d in workloads::all_demands(&workloads::H100).iter().take(4) {
        println!(
            "  {:24} {:?}: {:>9} MHz, lifetime {}",
            d.task.name, d.level, report::mhz(d.read_freq_hz), eng(d.lifetime_s, "s")
        );
    }

    println!("\n== sweeping bank configs 16x16..128x128 (batch-first pipeline) ==");
    let cache = dse::EvalCache::new();
    let structs = opengcram::compiler::CompileCache::new();
    let evals = dse::evaluate_all_batched_cached(
        &tech,
        &rt,
        &dse::fig10_configs(CellFlavor::GcSiSiNp),
        opengcram::util::default_workers(),
        &cache,
        &structs,
        DEFAULT_WINDOW_RESOLUTION,
    )?;
    for e in &evals {
        println!(
            "  {:>3}x{:<3} f_op {:>9} MHz  retention {:>10}  area {:>9} um^2",
            e.config.word_size, e.config.num_words, report::mhz(e.perf.f_op_hz),
            eng(e.perf.retention_s, "s"), report::um2(e.area_um2)
        );
    }

    println!("\n== Fig. 10 shmoo (GT520M L1 / H100 L2) ==");
    for (level, m) in [
        (workloads::CacheLevel::L1, &workloads::GT520M),
        (workloads::CacheLevel::L2, &workloads::H100),
    ] {
        println!("-- {:?} on {} --", level, m.name);
        for task in &workloads::TASKS {
            let d = workloads::profile(task, level, m);
            let glyphs: String = evals.iter().map(|e| dse::shmoo_verdict(e, &d).glyph()).collect();
            // headline: largest passing bank (bigger = more density/bw)
            let best = evals
                .iter()
                .rev()
                .find(|e| dse::shmoo_verdict(e, &d).pass())
                .map(|e| format!("{}x{}", e.config.word_size, e.config.num_words))
                .unwrap_or_else(|| "none (multibank)".into());
            println!("  {:24} [{}] best bank: {}", task.name, glyphs, best);
        }
    }

    println!("\n== SS VI co-optimization (L1 target: 300 MHz, 10 us) ==");
    let weights = dse::CostWeights {
        w_delay: 1.0,
        w_area: 0.5,
        w_power: 0.2,
        f_min_hz: 3e8,
        t_retain_min_s: 1e-5,
    };
    let (best, nevals) = dse::optimize_batched(
        &tech,
        &rt,
        CellFlavor::GcSiSiNp,
        &weights,
        DEFAULT_WINDOW_RESOLUTION,
    )?;
    println!(
        "  best: {}x{} write_vt={:?} -> f_op {} MHz, retention {}, {} evals",
        best.config.word_size, best.config.num_words, best.config.write_vt,
        report::mhz(best.perf.f_op_hz), eng(best.perf.retention_s, "s"), nevals
    );
    println!("\nend-to-end DSE wall time: {:.1} s", t0.elapsed().as_secs_f64());
    println!("PJRT artifact executions (batched): {:?}", rt.call_counts());
    Ok(())
}
