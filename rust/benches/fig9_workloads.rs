//! Fig. 9 / Table I regenerator: workload cache demands per machine.
use opengcram::util::bench;
use opengcram::workloads::{all_demands, GT520M, H100};

fn main() {
    println!("machine,task,level,read_freq_mhz,lifetime_s");
    for m in [&H100, &GT520M] {
        for d in all_demands(m) {
            println!(
                "{},{},{:?},{:.1},{:.3e}",
                m.name, d.task.name, d.level, d.read_freq_hz / 1e6, d.lifetime_s
            );
        }
    }
    bench::run("profile_all_workloads", 0.5, || all_demands(&H100));
}
