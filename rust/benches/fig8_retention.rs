//! Fig. 8 regenerator: Id-Vg + retention modulation, and throughput of
//! the batched retention engine (design points per second) on whichever
//! backend is available (PJRT artifacts, else the native solver).
use opengcram::runtime::{engines, SharedRuntime};
use opengcram::tech::sg40;
use opengcram::util::bench;
use std::path::Path;

fn main() {
    let tech = sg40();
    let rt = SharedRuntime::auto(Path::new("artifacts"));
    println!("# execution backend: {}", rt.backend_name());
    println!("vt,si_retention_s");
    let pts: Vec<_> = (0..12)
        .map(|i| engines::RetentionPoint {
            write_card: tech.card("si_nmos").with_vt(0.35 + 0.03 * i as f64),
            write_wl: 2.5,
            c_sn: 1.2e-15,
            g_gate_leak: 1e-16,
            i_disturb: 0.0,
            v0: 0.6,
            vth: 0.3,
        })
        .collect();
    let res = rt.with(|r| engines::retention(r, &pts)).unwrap();
    for (i, r) in res.iter().enumerate() {
        println!("{:.2},{:.4e}", 0.35 + 0.03 * i as f64, r.t_retain);
    }
    println!("material,retention_s");
    for (card, gl) in [("os_nmos", 1e-17), ("os_nmos_hvt", 1e-17)] {
        let r = rt.with(|rt| engines::retention(
            rt,
            &[engines::RetentionPoint {
                write_card: *tech.card(card),
                write_wl: 1.2,
                c_sn: 1.2e-15,
                g_gate_leak: gl,
                i_disturb: 0.0,
                v0: 0.6,
                vth: 0.3,
            }],
        ))
        .unwrap();
        println!("{card},{:.4e}", r[0].t_retain);
    }
    // throughput: a full 256-point batch through the retention artifact
    let full: Vec<_> = (0..256)
        .map(|i| engines::RetentionPoint {
            write_card: tech.card("si_nmos").with_vt(0.35 + 0.001 * i as f64),
            write_wl: 2.5,
            c_sn: 1.2e-15,
            g_gate_leak: 1e-16,
            i_disturb: 0.0,
            v0: 0.6,
            vth: 0.3,
        })
        .collect();
    let s = bench::run("retention_batch_256", 3.0, || {
        rt.with(|r| engines::retention(r, &full)).unwrap()
    });
    println!("design_points_per_sec,{:.0}", 256.0 / s.median_s);
}
