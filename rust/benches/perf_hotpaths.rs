//! Performance pass (EXPERIMENTS.md SS Perf): hot-path throughput of
//! every layer the request path touches — L3 compiler/DRC/extraction,
//! the PJRT execution path per artifact, and the native sim baseline.
use opengcram::compiler::{compile, CellFlavor, Config};
use opengcram::layout::{cells, Library};
use opengcram::runtime::{engines, Runtime};
use opengcram::tech::sg40;
use opengcram::util::bench;
use opengcram::sim;
use std::path::Path;

fn main() {
    let tech = sg40();
    let rt = Runtime::load(Path::new("artifacts")).expect("make artifacts");

    // L3: compiler + geometry engines
    let s = bench::run("l3_compile_1kb_bank", 1.5, || {
        compile(&tech, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap()
    });
    println!("banks_per_sec,{:.1}", 1.0 / s.median_s);
    let bank = compile(&tech, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap();
    let rects = bank.library.flatten("bitcell_array").unwrap();
    let s = bench::run("l3_drc_1kb_array", 2.0, || opengcram::drc::check(&tech, &rects));
    println!("drc_rects_per_sec,{:.0}", rects.len() as f64 / s.median_s);
    let lc = cells::gc2t_sisi(&tech, false);
    let mut lib = Library::default();
    lib.add(lc.layout.clone());
    let (cr, cp) = lib.flatten_with_pins("gc2t_sisi").unwrap();
    bench::run("l3_lvs_extract_bitcell", 1.0, || {
        opengcram::lvs::extract(&tech, &cr, &cp, "gc2t_sisi").unwrap()
    });

    // L1/L2 via PJRT: batched artifact executions (per-design cost)
    let ret_pts: Vec<_> = (0..256)
        .map(|i| engines::RetentionPoint {
            write_card: tech.card("si_nmos").with_vt(0.35 + 0.001 * i as f64),
            write_wl: 2.5,
            c_sn: 1.2e-15,
            g_gate_leak: 1e-16,
            i_disturb: 0.0,
            v0: 0.6,
            vth: 0.3,
        })
        .collect();
    let s = bench::run("xla_retention_batch256", 3.0, || engines::retention(&rt, &ret_pts).unwrap());
    println!("retention_points_per_sec,{:.0}", 256.0 / s.median_s);
    let one = vec![ret_pts[0].clone()];
    let s1 = bench::run("xla_retention_batch1_padded", 3.0, || engines::retention(&rt, &one).unwrap());
    println!("batch_amortization,{:.1}x", s1.median_s * 256.0 / s.median_s);

    // native rust sim baseline (single design, same template)
    let t = sim::retention_template();
    let mut p = vec![0.0; t.npar];
    let si = tech.card("si_nmos");
    p[0..6].copy_from_slice(&[si.kp, si.vt, si.n, si.lam, 2.5, 1.0]);
    p[6] = 1e-16;
    let steps = 448;
    let mut dt = Vec::new();
    let mut d = 1e-12;
    for _ in 0..steps {
        dt.push(d);
        d *= 1.082;
    }
    let wave = vec![vec![0.0; 4]; steps];
    let s = bench::run("native_sim_retention_single", 2.0, || {
        sim::transient(&t, sim::Integrator::ExpDecay, 4, &[0.6], &[0.0; 4], &p, &[1.0 / 1.2e-15], &wave, &wave, &dt)
    });
    println!("native_points_per_sec,{:.0}", 1.0 / s.median_s);
}
