//! Performance pass (EXPERIMENTS.md, Hot paths): hot-path throughput of
//! every layer the request path touches — L3 compiler / flatten / DRC
//! (flat + hierarchical) / DSE, the per-artifact transient execution
//! path on a real backend (native EKV solver or PJRT artifacts — the
//! grouped-ceiling KPIs are asserted against its real call counters),
//! and the native sim baseline.
//!
//! Emits `BENCH_perf.json` (name, median_s, throughput) so the perf
//! trajectory is tracked across PRs.
//!
//! Env knobs:
//! * `PERF_SMOKE=1` — CI smoke: 32x32 bank, short targets, geometry +
//!   packing paths (no artifacts needed).
//! * `PERF_BANK=N`  — override the square bank size (default 128,
//!   32 under smoke).
//! * `PERF_BACKEND=native|pjrt|auto|none` — execution backend for the
//!   transient benches (default: auto outside smoke, native under
//!   smoke — a short native transient tier so CI exercises the real
//!   solver; the CI end-to-end step runs `PERF_SMOKE=1
//!   PERF_BACKEND=native` explicitly).
//! * `PERF_MIN_SOA_SPEEDUP=X` — minimum SoA-vs-scalar-reference
//!   speedup the transient solver must show on at least one op
//!   (default 1.5; the rows/sec series for both modes land in
//!   `BENCH_perf.json` regardless).
use opengcram::characterize::batch;
use opengcram::compiler::{compile, CellFlavor, CompileCache, Config};
use opengcram::coordinator::{BatchExec, Coordinator};
use opengcram::layout::{cells, FlattenCache, Library};
use opengcram::runtime::{engines, ExecBackend, NativeBackend, SharedRuntime};
use opengcram::tech::sg40;
use opengcram::util::bench;
use opengcram::variation::{self, VariationModel};
use opengcram::{characterize, drc, dse, sim};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn main() {
    let tech = sg40();
    let smoke = std::env::var("PERF_SMOKE").map(|v| v != "0").unwrap_or(false);
    let n: usize = std::env::var("PERF_BANK")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 32 } else { 128 });
    let t_short = if smoke { 0.2 } else { 1.5 };
    let t_long = if smoke { 0.3 } else { 2.0 };
    let mut records: Vec<(bench::Sample, f64)> = Vec::new();

    // ---- L3: compiler ----------------------------------------------------
    let s = bench::run("l3_compile_1kb_bank", t_short, || {
        compile(&tech, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap()
    });
    println!("banks_per_sec,{:.1}", 1.0 / s.median_s);
    records.push((s.clone(), s.per_sec()));
    let s = bench::run(&format!("l3_compile_{n}x{n}_bank"), t_long, || {
        compile(&tech, &Config::new(n, n, CellFlavor::GcSiSiNp)).unwrap()
    });
    records.push((s.clone(), s.per_sec()));

    // ---- L3: structure-keyed compile cache -------------------------------
    // Census pin on the real counters: the 5x5 optimizer grid spans 25
    // configs but only 5 distinct geometries (the VT axis is purely
    // electrical), so a cold sweep through the cache pays exactly one
    // geometry compile per distinct StructKey and serves the rest as
    // Arc clones of the shared structure.
    let grid = dse::grid_configs(CellFlavor::GcSiSiNp);
    let grid_refs: Vec<&Config> = grid.iter().collect();
    let distinct: std::collections::HashSet<_> = grid.iter().map(|c| c.struct_key()).collect();
    let census = CompileCache::new();
    census.compile_all(&tech, &grid_refs, 2).unwrap();
    let (census_hits, census_compiles) = census.stats();
    assert_eq!(
        census_compiles,
        distinct.len(),
        "grid sweep paid {census_compiles} geometry compiles for {} distinct structures",
        distinct.len()
    );
    assert_eq!(census_hits, grid.len() - distinct.len(), "every VT sibling must be a cache hit");
    println!("compile_cache_grid_compiles,{census_compiles}");
    println!("compile_cache_grid_hits,{census_hits}");
    let s = bench::run("compile_structure_cold_32x32", t_short, || {
        CompileCache::new().compile(&tech, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap()
    });
    records.push((s.clone(), s.per_sec()));
    let warm = CompileCache::new();
    warm.compile(&tech, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap();
    let mut vt_sibling = Config::new(32, 32, CellFlavor::GcSiSiNp);
    vt_sibling.write_vt = Some(0.55);
    let s = bench::run("compile_cached_vt_sibling_32x32", t_short, || {
        warm.compile(&tech, &vt_sibling).unwrap()
    });
    println!("compile_cached_banks_per_sec,{:.0}", 1.0 / s.median_s);
    records.push((s.clone(), s.per_sec()));

    // ---- L3: memoized flatten -------------------------------------------
    let bank = compile(&tech, &Config::new(n, n, CellFlavor::GcSiSiNp)).unwrap();
    let rects_cell = std::cell::RefCell::new(Vec::new());
    let s = bench::run(&format!("l3_flatten_{n}x{n}_array"), t_short, || {
        *rects_cell.borrow_mut() = bank.library.flatten("bitcell_array").unwrap();
    });
    let rects = rects_cell.into_inner();
    println!("flatten_rects_per_sec,{:.0}", rects.len() as f64 / s.median_s);
    let tput = rects.len() as f64 / s.median_s;
    records.push((s, tput));
    let mut shared_cache = FlattenCache::default();
    bank.library.flatten_cached("bitcell_array", &mut shared_cache).unwrap();
    let s = bench::run(&format!("l3_flatten_{n}x{n}_array_warm_cache"), t_short, || {
        bank.library.flatten_cached("bitcell_array", &mut shared_cache).unwrap()
    });
    records.push((s.clone(), rects.len() as f64 / s.median_s));

    // ---- L3: DRC, flat and hierarchical ---------------------------------
    let s = bench::run(&format!("l3_drc_{n}x{n}_array"), t_long, || {
        drc::check(&tech, &rects)
    });
    println!("drc_rects_per_sec,{:.0}", rects.len() as f64 / s.median_s);
    records.push((s.clone(), rects.len() as f64 / s.median_s));
    let flat_rep = drc::check(&tech, &rects);
    let s = bench::run(&format!("l3_drc_hier_{n}x{n}_array"), t_long, || {
        drc::hier::check_hier(&tech, &bank.library, "bitcell_array").unwrap()
    });
    println!("drc_hier_rects_per_sec,{:.0}", rects.len() as f64 / s.median_s);
    records.push((s.clone(), rects.len() as f64 / s.median_s));
    let hier_rep = drc::hier::check_hier(&tech, &bank.library, "bitcell_array").unwrap();
    println!(
        "# drc sanity: flat {} violations, hier {} violations on {} rects",
        flat_rep.violations.len(),
        hier_rep.violations.len(),
        rects.len()
    );
    assert_eq!(
        flat_rep.clean(),
        hier_rep.clean(),
        "flat and hierarchical DRC disagree on the generated array"
    );

    // ---- L3: DSE (analytical pipeline; no artifacts needed) -------------
    let shmoo_configs: Vec<Config> = dse::fig10_configs(CellFlavor::GcSiSiNp)
        .into_iter()
        .filter(|c| !smoke || c.word_size <= 32)
        .collect();
    let eval = |cfg: &Config| -> opengcram::Result<dse::Evaluated> {
        let b = compile(&tech, cfg)?;
        Ok(dse::Evaluated {
            config: cfg.clone(),
            perf: characterize::analytical(&tech, &b),
            area_um2: b.layout.total_area_um2(),
            quarantine: None,
        })
    };
    let workers = opengcram::util::default_workers();
    let s = bench::run("dse_shmoo_axis_serial", t_long, || {
        dse::evaluate_all(&shmoo_configs, 1, eval).unwrap()
    });
    let serial_s = s.median_s;
    records.push((s.clone(), shmoo_configs.len() as f64 / s.median_s));
    let s = bench::run(&format!("dse_shmoo_axis_parallel_x{workers}"), t_long, || {
        dse::evaluate_all(&shmoo_configs, workers, eval).unwrap()
    });
    println!("shmoo_parallel_speedup,{:.2}x", serial_s / s.median_s.max(1e-12));
    records.push((s.clone(), shmoo_configs.len() as f64 / s.median_s));
    let cache = dse::EvalCache::new();
    dse::evaluate_all_cached(&shmoo_configs, workers, &cache, eval).unwrap();
    let s = bench::run("dse_shmoo_axis_cached", t_short, || {
        dse::evaluate_all_cached(&shmoo_configs, workers, &cache, eval).unwrap()
    });
    records.push((s.clone(), shmoo_configs.len() as f64 / s.median_s));
    // the optimizer walk can reach 128x128 compiles; skip under smoke
    if !smoke {
        let w = dse::CostWeights {
            w_delay: 1.0,
            w_area: 0.5,
            w_power: 0.5,
            f_min_hz: 0.0,
            t_retain_min_s: 0.0,
        };
        let evals_cell = std::cell::Cell::new(0usize);
        let s = bench::run("dse_optimize_analytical", t_long, || {
            let (_, ev) = dse::optimize(CellFlavor::GcSiSiNp, &w, |cfg| eval(cfg)).unwrap();
            evals_cell.set(ev);
        });
        let evals = evals_cell.get();
        println!("optimize_pipeline_evals,{evals}");
        records.push((s.clone(), evals as f64 / s.median_s));
    }

    // ---- L3: LVS extraction ---------------------------------------------
    let lc = cells::gc2t_sisi(&tech, false);
    let mut lib = Library::default();
    lib.add(lc.layout.clone());
    let (cr, cp) = lib.flatten_with_pins("gc2t_sisi").unwrap();
    let s = bench::run("l3_lvs_extract_bitcell", if smoke { 0.2 } else { 1.0 }, || {
        opengcram::lvs::extract(&tech, &cr, &cp, "gc2t_sisi").unwrap()
    });
    records.push((s.clone(), s.per_sec()));

    // ---- coordinator batch packing (runtime-free; runs in CI smoke) -----
    // a fig10-size sweep (one retention point per design) routed
    // through the coordinator must issue ceil(points/cap) artifact
    // calls — not one per point, which was the pre-batching behavior
    coordinator_packing_records(&mut records);

    // ---- window-quantization packing (runtime-free; runs in CI smoke) ---
    quantization_packing_records(&tech, &mut records);

    // ---- cross-flavor composition plan (runtime-free; runs in CI smoke) -
    compose_packing_records(&tech, smoke, &mut records);

    // ---- transient engine benches over a real execution backend ---------
    // PERF_BACKEND=native|pjrt|auto|none picks the backend for the
    // grouped-ceiling KPI asserts (real per-artifact call counters, not
    // a counting mock).  Default: auto outside smoke — artifacts when
    // they load, the native solver otherwise, so there is no
    // "skipping: no artifacts" branch anymore — and native under smoke
    // (a short transient tier; smoke used to skip transients entirely).
    let backend = std::env::var("PERF_BACKEND").ok();
    let rt = match backend.as_deref() {
        Some("none") => None,
        Some("native") => Some(SharedRuntime::native()),
        Some("pjrt") => match SharedRuntime::load(Path::new("artifacts")) {
            Ok(rt) => Some(rt),
            Err(e) => {
                println!("# PERF_BACKEND=pjrt unavailable ({e}); skipping transient benches");
                None
            }
        },
        Some("auto") => Some(SharedRuntime::auto(Path::new("artifacts"))),
        Some(other) => panic!("unknown PERF_BACKEND '{other}' (expected native|pjrt|auto|none)"),
        None if smoke => {
            println!("# PERF_SMOKE: native transient tier (set PERF_BACKEND=none to skip)");
            Some(SharedRuntime::native())
        }
        None => Some(SharedRuntime::auto(Path::new("artifacts"))),
    };
    if let Some(rt) = &rt {
        println!("# execution backend: {}", rt.backend_name());
        transient_benches(&tech, rt, smoke, &mut records);
        mc_yield_records(&tech, rt, smoke, &mut records);
        soa_speedup_records(&tech, smoke, &mut records);
    }
    if !smoke {
        native_sim_bench(&tech, &mut records);
    }

    let json_path = Path::new("BENCH_perf.json");
    bench::write_json(json_path, &records).expect("write BENCH_perf.json");
    println!("# wrote {} ({} benches)", json_path.display(), records.len());
}

/// Mock executor standing in for the retention engine: counts the
/// artifact calls the coordinator would issue.
struct CountingExec {
    cap: usize,
    calls: Arc<AtomicUsize>,
}

impl BatchExec<usize, usize> for CountingExec {
    fn run(&mut self, jobs: &[usize]) -> opengcram::Result<Vec<usize>> {
        self.calls.fetch_add(1, Ordering::SeqCst);
        Ok(jobs.to_vec())
    }
    fn max_batch(&self) -> usize {
        self.cap
    }
}

fn coordinator_packing_records(records: &mut Vec<(bench::Sample, f64)>) {
    let cap = 256; // the AOT artifacts' manifest batch size
    let fig10_points = dse::fig10_configs(CellFlavor::GcSiSiNp).len();
    for (name, points) in [
        ("coord_retention_packing_fig10_axis", fig10_points),
        ("coord_retention_packing_1k_sweep", 1000),
    ] {
        let calls = Arc::new(AtomicUsize::new(0));
        let calls_in = calls.clone();
        let s = bench::run(name, 0.05, || {
            calls_in.store(0, Ordering::SeqCst);
            let c = Coordinator::spawn(CountingExec { cap, calls: calls_in.clone() });
            c.run_all((0..points).collect()).unwrap()
        });
        let got = calls.load(Ordering::SeqCst);
        let want = batch::calls_for(points, cap);
        assert_eq!(
            got, want,
            "{points}-point sweep through the coordinator must issue ceil(points/cap) = \
             {want} artifact calls, got {got}"
        );
        let occupancy = points as f64 / (got * cap) as f64;
        println!("batch_calls_{points}pt,{got}");
        println!("batch_occupancy_{points}pt,{occupancy:.4}");
        // throughput column records occupancy so the packing trajectory
        // lands in BENCH_perf.json alongside the timing series
        records.push((s, occupancy));
    }
}

/// Tentpole KPI for the window-quantized batcher, checked without any
/// runtime: a fine rows-axis sweep (whose exact windows all differ)
/// must collapse its write/read windows into fewer buckets than
/// designs at the default resolution, and every bucket must stay
/// conservative within one step.
fn quantization_packing_records(
    tech: &opengcram::tech::Tech,
    records: &mut Vec<(bench::Sample, f64)>,
) {
    use opengcram::characterize::{
        quantization_axis, window_group_counts, CharPlan, DEFAULT_WINDOW_RESOLUTION,
    };
    let n_designs = 8usize;
    // rows pinned >= 180 (mux 1): both windows sit above their floor
    // clamps, so every exact window is distinct and grouping is the
    // quantizer's doing, not the clamp's
    let banks: Vec<_> = quantization_axis(n_designs, 180, 2)
        .iter()
        .map(|cfg| compile(tech, cfg).unwrap())
        .collect();
    let s = bench::run("char_plan_quantized_rows_axis", 0.05, || {
        banks
            .iter()
            .map(|b| CharPlan::with_resolution(tech, b, DEFAULT_WINDOW_RESOLUTION))
            .collect::<Vec<_>>()
    });
    for b in &banks {
        let (we, re) = CharPlan::new(tech, b).window_bits().unwrap();
        let (wq, rq) =
            CharPlan::with_resolution(tech, b, DEFAULT_WINDOW_RESOLUTION).window_bits().unwrap();
        let bound = (1.0 + DEFAULT_WINDOW_RESOLUTION) * (1.0 + 1e-9);
        assert!(f64::from_bits(wq) >= f64::from_bits(we));
        assert!(f64::from_bits(wq) <= f64::from_bits(we) * bound);
        assert!(f64::from_bits(rq) >= f64::from_bits(re));
        assert!(f64::from_bits(rq) <= f64::from_bits(re) * bound);
    }
    let (wr_exact, rd_exact) = window_group_counts(tech, &banks, 0.0);
    assert_eq!(wr_exact, n_designs, "write floors clamp: axis too small");
    assert_eq!(rd_exact, n_designs, "read floors clamp: axis too small");
    // rows 180..194 span barely one 10 % step, so the bucket grid
    // holds the axis in <= 2 write and read groups — the grouped
    // ceiling a characterize_all sweep pays, instead of one per design
    let (wr_groups, rd_groups) = window_group_counts(tech, &banks, DEFAULT_WINDOW_RESOLUTION);
    assert!(
        wr_groups < n_designs && rd_groups < n_designs,
        "size axis did not collapse: wr {wr_groups} rd {rd_groups} of {n_designs}"
    );
    println!("quantized_write_groups_{n_designs}designs,{wr_groups}");
    println!("quantized_read_groups_{n_designs}designs,{rd_groups}");
    // throughput column records designs-per-write-group so the packing
    // trajectory lands in BENCH_perf.json
    records.push((s, n_designs as f64 / wr_groups as f64));
}

/// Tentpole KPI for the composition engine's cross-flavor mega-sweep,
/// checked without any runtime: all flavors' retention points must
/// pack into one shared grouped-ceiling batch sequence — not
/// per-flavor x per-design executions — and the mock coordinator must
/// agree with the plan arithmetic.  The packing arithmetic is
/// size-independent, so the bench caps the grid (32 under smoke, 64
/// otherwise) rather than re-compiling 128x128 banks every iteration;
/// the full grid is exercised by `fig10_shmoo` and the integration
/// tests.
fn compose_packing_records(
    tech: &opengcram::tech::Tech,
    smoke: bool,
    records: &mut Vec<(bench::Sample, f64)>,
) {
    use opengcram::compose;
    let cap = 256; // the AOT artifacts' manifest batch size
    let max_words = if smoke { 32 } else { 64 };
    let grid: Vec<Config> = compose::design_grid()
        .into_iter()
        .filter(|c| c.word_size <= max_words)
        .collect();
    let res = characterize::DEFAULT_WINDOW_RESOLUTION;
    let plan_cell = std::cell::RefCell::new(None);
    let s = bench::run("compose_crossflavor_plan", 0.05, || {
        *plan_cell.borrow_mut() = Some(compose::plan(tech, &grid, res, cap).unwrap());
    });
    let plan = plan_cell.into_inner().expect("bench ran at least once");
    assert!(plan.transient_flavors >= 3, "all GC flavors must contribute transient points");
    assert_eq!(
        plan.retention_calls,
        batch::calls_for(plan.transient, cap),
        "cross-flavor retention must pay the grouped ceiling over ALL flavors' points"
    );
    assert!(
        plan.retention_calls < plan.retention_calls_per_flavor,
        "shared sweep ({}) must beat per-flavor batching ({})",
        plan.retention_calls,
        plan.retention_calls_per_flavor
    );
    let mock = compose::mock_retention_calls(plan.transient, cap).unwrap();
    assert_eq!(mock, plan.retention_calls, "mock coordinator diverged from the plan");
    println!("compose_retention_calls,{}", plan.retention_calls);
    println!("compose_retention_calls_per_flavor,{}", plan.retention_calls_per_flavor);
    println!("compose_write_groups,{}", plan.write_groups);
    println!("compose_read_groups,{}", plan.read_groups);
    // throughput column records transient designs per retention call
    // so the cross-flavor packing trajectory lands in BENCH_perf.json
    records.push((s, plan.transient as f64 / plan.retention_calls.max(1) as f64));
}

fn transient_benches(
    tech: &opengcram::tech::Tech,
    rt: &SharedRuntime,
    smoke: bool,
    records: &mut Vec<(bench::Sample, f64)>,
) {
    // short targets under smoke: the KPI asserts are the point there,
    // the timing series comes from full runs
    let t_eng = if smoke { 0.2 } else { 3.0 };
    // batched artifact executions (per-design cost)
    let cap256 = rt.batch_cap("retention").unwrap();
    let ret_pts: Vec<_> = (0..cap256)
        .map(|i| engines::RetentionPoint {
            write_card: tech.card("si_nmos").with_vt(0.35 + 0.001 * i as f64),
            write_wl: 2.5,
            c_sn: 1.2e-15,
            g_gate_leak: 1e-16,
            i_disturb: 0.0,
            v0: 0.6,
            vth: 0.3,
        })
        .collect();
    let s = bench::run("engine_retention_full_batch", t_eng, || {
        rt.with(|r| engines::retention(r, &ret_pts)).unwrap()
    });
    println!("retention_points_per_sec,{:.0}", cap256 as f64 / s.median_s);
    records.push((s.clone(), cap256 as f64 / s.median_s));
    let one = vec![ret_pts[0].clone()];
    let s1 = bench::run("engine_retention_batch1_padded", t_eng, || {
        rt.with(|r| engines::retention(r, &one)).unwrap()
    });
    println!("batch_amortization,{:.1}x", s1.median_s * cap256 as f64 / s.median_s);
    records.push((s1.clone(), 1.0 / s1.median_s));

    // ---- batch-first transient sweep over real artifacts ----------------
    // characterize_all packs a write-VT retention axis (same geometry,
    // shared windows) — assert the artifact-call KPI and record the
    // measured occupancy
    let banks: opengcram::Result<Vec<_>> = [None, Some(0.40), Some(0.45), Some(0.50), Some(0.55)]
        .iter()
        .map(|&vt| {
            let mut cfg = Config::new(32, 32, CellFlavor::GcSiSiNp);
            cfg.write_vt = vt;
            compile(tech, &cfg)
        })
        .collect();
    let banks = banks.unwrap();
    let res = characterize::DEFAULT_WINDOW_RESOLUTION;
    let before = rt.call_count("retention");
    let perfs = characterize::characterize_all(tech, rt, &banks, res).unwrap();
    assert_eq!(perfs.len(), banks.len());
    let ret_calls = (rt.call_count("retention") - before) as usize;
    let cap = rt.batch_cap("retention").unwrap();
    let want = batch::calls_for(banks.len(), cap);
    assert!(
        ret_calls <= want,
        "characterize_all issued {ret_calls} retention executions for {} designs (<= {want} expected)",
        banks.len()
    );
    println!("char_batched_retention_calls,{ret_calls}");
    let s = bench::run("char_batched_vt_axis_5designs", t_eng, || {
        characterize::characterize_all(tech, rt, &banks, res).unwrap()
    });
    records.push((s.clone(), banks.len() as f64 / s.median_s));

    // ---- window-quantized size axis over real artifacts -----------------
    // rows 180..196 (mux 1, above both window floors): every design's
    // exact windows differ, so the pre-quantization batcher paid one
    // write and one read execution per design; the bucket grid must
    // pay exactly the grouped ceiling
    let size_banks: Vec<_> = characterize::quantization_axis(5, 180, 4)
        .iter()
        .map(|cfg| compile(tech, cfg).unwrap())
        .collect();
    let (wr_groups, rd_groups) = characterize::window_group_counts(tech, &size_banks, res);
    let wr_before = rt.call_count("write");
    let rd_before = rt.call_count("read");
    let perfs = characterize::characterize_all(tech, rt, &size_banks, res).unwrap();
    assert_eq!(perfs.len(), size_banks.len());
    let wr_calls = (rt.call_count("write") - wr_before) as usize;
    let rd_calls = (rt.call_count("read") - rd_before) as usize;
    assert_eq!(
        wr_calls, wr_groups,
        "size-axis sweep issued {wr_calls} write executions for {wr_groups} buckets"
    );
    assert_eq!(
        rd_calls, rd_groups,
        "size-axis sweep issued {rd_calls} read executions for {rd_groups} buckets"
    );
    assert!(
        wr_calls < size_banks.len() && rd_calls < size_banks.len(),
        "quantization failed to pack the size axis: wr {wr_calls} rd {rd_calls} of {}",
        size_banks.len()
    );
    println!("char_sizeaxis_write_calls,{wr_calls}");
    println!("char_sizeaxis_read_calls,{rd_calls}");
    let s = bench::run("char_batched_size_axis_5designs", t_eng, || {
        characterize::characterize_all(tech, rt, &size_banks, res).unwrap()
    });
    records.push((s.clone(), size_banks.len() as f64 / s.median_s));
}

/// Tentpole KPI for the Monte-Carlo variation mega-batch (EXPERIMENTS.md,
/// Yield sweep): `K x D` sampled variants through one packed sweep must
/// pay exactly the grouped-ceiling execution counts that
/// [`variation::plan_call_counts`] predicts — asserted against the
/// backend's *real* per-artifact counters, never one execution per
/// variant per engine.  The `mc_yield_rows_per_sec` series (sampled
/// variant rows per second, nominal included) lands in
/// `BENCH_perf.json` so the MC throughput trajectory is tracked.
fn mc_yield_records(
    tech: &opengcram::tech::Tech,
    rt: &SharedRuntime,
    smoke: bool,
    records: &mut Vec<(bench::Sample, f64)>,
) {
    let t_eng = if smoke { 0.2 } else { 2.0 };
    let k = if smoke { 8 } else { 32 };
    // rows >= 180 (mux 1): windows sit above the floor clamps, so each
    // variant's exact windows genuinely differ and the quantizer (not
    // the clamp) earns the packing
    let cfgs = characterize::quantization_axis(3, 180, 8);
    let model = VariationModel::from_tech(tech, k, variation::DEFAULT_SEED);
    let res = characterize::DEFAULT_WINDOW_RESOLUTION;
    let caps = (
        rt.batch_cap("write").unwrap(),
        rt.batch_cap("read").unwrap(),
        rt.batch_cap("retention").unwrap(),
    );
    let (want_w, want_r, want_t) =
        variation::plan_call_counts(tech, &cfgs, &model, res, caps.0, caps.1, caps.2).unwrap();
    let variants = cfgs.len() * (k + 1);
    assert_eq!(want_t, batch::calls_for(variants, caps.2), "retention must always pack");

    let before = (rt.call_count("write"), rt.call_count("read"), rt.call_count("retention"));
    let (dys, health) =
        variation::yield_sweep_health(tech, rt, &cfgs, &model, 2, res, &CompileCache::new())
            .unwrap();
    assert!(health.is_clean(), "{}", health.summary());
    assert_eq!(dys.len(), cfgs.len());
    let got_w = (rt.call_count("write") - before.0) as usize;
    let got_r = (rt.call_count("read") - before.1) as usize;
    let got_t = (rt.call_count("retention") - before.2) as usize;
    assert_eq!(got_w, want_w, "MC write occupancy model diverged from real counters");
    assert_eq!(got_r, want_r, "MC read occupancy model diverged from real counters");
    assert_eq!(got_t, want_t, "MC retention occupancy model diverged from real counters");
    assert!(
        got_w < variants,
        "mega-batch paid {got_w} write executions for {variants} variant plans"
    );
    println!("mc_write_calls_{variants}variants,{got_w}");
    println!("mc_read_calls_{variants}variants,{got_r}");
    println!("mc_retention_calls_{variants}variants,{got_t}");

    let s = bench::run(&format!("mc_yield_sweep_{}designs_k{k}", cfgs.len()), t_eng, || {
        variation::yield_sweep_health(tech, rt, &cfgs, &model, 2, res, &CompileCache::new()).unwrap()
    });
    println!("mc_yield_rows_per_sec,{:.0}", variants as f64 / s.median_s);
    records.push((s.clone(), variants as f64 / s.median_s));
}

/// Time one transient op in both native execution modes and record the
/// rows/sec series for each; returns the SoA-over-scalar speedup.
fn soa_pair<A, B>(
    op: &str,
    n: usize,
    t_eng: f64,
    records: &mut Vec<(bench::Sample, f64)>,
    scalar_f: impl FnMut() -> A,
    soa_f: impl FnMut() -> B,
) -> f64 {
    let s = bench::run(&format!("soa_{op}_scalar_reference"), t_eng, scalar_f);
    let rps_scalar = n as f64 / s.median_s;
    records.push((s, rps_scalar));
    let s = bench::run(&format!("soa_{op}_batched"), t_eng, soa_f);
    let rps_soa = n as f64 / s.median_s;
    records.push((s, rps_soa));
    let speedup = rps_soa / rps_scalar.max(1e-12);
    println!("soa_{op}_scalar_rows_per_sec,{rps_scalar:.0}");
    println!("soa_{op}_rows_per_sec,{rps_soa:.0}");
    println!("soa_{op}_speedup,{speedup:.2}x");
    speedup
}

/// Tentpole KPI for the SoA transient solver (EXPERIMENTS.md, SoA
/// execution model): scalar-reference vs SoA rows/sec on full-capacity
/// batches of every transient op.  Both series land in
/// `BENCH_perf.json`; the best per-op speedup is asserted against
/// `PERF_MIN_SOA_SPEEDUP` (default 1.5 — a CI smoke floor, full runs
/// land far higher).
fn soa_speedup_records(
    tech: &opengcram::tech::Tech,
    smoke: bool,
    records: &mut Vec<(bench::Sample, f64)>,
) {
    let t_eng = if smoke { 0.2 } else { 3.0 };
    let min_speedup: f64 = std::env::var("PERF_MIN_SOA_SPEEDUP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.5);
    let scalar = NativeBackend::new().with_scalar_reference();
    let soa = NativeBackend::new();
    let cap = |op: &str| soa.manifest().get(op).unwrap().batch;

    let n_ret = cap("retention");
    let ret_pts: Vec<_> = (0..n_ret)
        .map(|i| engines::RetentionPoint {
            write_card: tech.card("si_nmos").with_vt(0.35 + 0.001 * i as f64),
            write_wl: 2.5,
            c_sn: 1.2e-15,
            g_gate_leak: 1e-16,
            i_disturb: 0.0,
            v0: 0.6,
            vth: 0.3,
        })
        .collect();
    let su_ret = soa_pair(
        "retention",
        n_ret,
        t_eng,
        records,
        || engines::retention(&scalar, &ret_pts).unwrap(),
        || engines::retention(&soa, &ret_pts).unwrap(),
    );

    let n_wr = cap("write");
    let wr_pts: Vec<_> = (0..n_wr)
        .map(|i| engines::WritePoint {
            write_card: tech.card("si_nmos").with_vt(0.35 + 0.001 * i as f64),
            write_wl: 2.5,
            drv_p: (*tech.card("si_pmos"), 8.0),
            drv_n: (*tech.card("si_nmos"), 4.0),
            c_sn: 1.2e-15,
            c_wbl: 20e-15,
            c_wwl_sn: 0.15e-15,
            g_wbl_leak: 1e-9,
            vdd: 1.1,
            v_wwl: 1.5,
            one: true,
            sn0: 0.0,
        })
        .collect();
    let su_wr = soa_pair(
        "write",
        n_wr,
        t_eng,
        records,
        || engines::write_op(&scalar, &wr_pts, 6e-9).unwrap(),
        || engines::write_op(&soa, &wr_pts, 6e-9).unwrap(),
    );

    let n_rd = cap("read");
    let rd_pts: Vec<_> = (0..n_rd)
        .map(|i| engines::ReadPoint {
            read_card: tech.card("si_nmos").with_vt(0.35 + 0.001 * i as f64),
            read_wl: 3.5,
            sn0: 0.62,
            sn_unsel: 0.0,
            rows: 32,
            c_sn: 1.2e-15,
            c_rbl: 20e-15,
            c_rwl_sn: 0.1e-15,
            g_rbl_leak: 1e-9,
            vdd: 1.1,
            pull_up: false,
        })
        .collect();
    let su_rd = soa_pair(
        "read",
        n_rd,
        t_eng,
        records,
        || engines::read_op(&scalar, &rd_pts, 8e-9).unwrap(),
        || engines::read_op(&soa, &rd_pts, 8e-9).unwrap(),
    );

    let best = su_ret.max(su_wr).max(su_rd);
    assert!(
        best >= min_speedup,
        "SoA transient solver must beat the scalar reference by >= {min_speedup}x on at \
         least one op (retention {su_ret:.2}x, write {su_wr:.2}x, read {su_rd:.2}x)"
    );
}

fn native_sim_bench(tech: &opengcram::tech::Tech, records: &mut Vec<(bench::Sample, f64)>) {
    // native rust sim baseline (single design, same template)
    let t = sim::retention_template();
    let mut p = vec![0.0; t.npar];
    let si = tech.card("si_nmos");
    p[0..6].copy_from_slice(&[si.kp, si.vt, si.n, si.lam, 2.5, 1.0]);
    p[6] = 1e-16;
    let steps = 448;
    let mut dt = Vec::new();
    let mut d = 1e-12;
    for _ in 0..steps {
        dt.push(d);
        d *= 1.082;
    }
    let wave = vec![vec![0.0; 4]; steps];
    let s = bench::run("native_sim_retention_single", 2.0, || {
        sim::transient(&t, sim::Integrator::ExpDecay, 4, &[0.6], &[0.0; 4], &p, &[1.0 / 1.2e-15], &wave, &wave, &dt)
    });
    println!("native_points_per_sec,{:.0}", 1.0 / s.median_s);
    records.push((s.clone(), 1.0 / s.median_s));
}
