//! Fig. 10 regenerator: shmoo of GCRAM bank configs against the
//! Table-I demands, plus end-to-end DSE throughput.
//!
//! The per-config compile+characterize pipeline fans out across
//! `std::thread::scope` workers through the shared [`dse::EvalCache`];
//! the PJRT runtime itself is serialized behind `SharedRuntime` (the
//! XLA client is single-threaded) but compilation and geometry — the
//! bulk of each evaluation — run concurrently.
use opengcram::compiler::{compile, CellFlavor, Config};
use opengcram::runtime::SharedRuntime;
use opengcram::tech::sg40;
use opengcram::util::bench;
use opengcram::{characterize, dse, workloads};
use std::path::Path;

fn main() {
    let tech = sg40();
    let rt = match SharedRuntime::load(Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            // distinguishes the unlinked-PJRT stub build from a
            // genuinely missing `make artifacts`
            println!("# fig10_shmoo needs the PJRT runtime and artifacts/: {e}");
            return;
        }
    };
    let cache = dse::EvalCache::new();
    let eval = |cfg: &Config| -> opengcram::Result<dse::Evaluated> {
        let bank = compile(&tech, cfg)?;
        let perf = rt.with(|rt| characterize::characterize(&tech, rt, &bank))?;
        Ok(dse::Evaluated { config: cfg.clone(), perf, area_um2: bank.layout.total_area_um2() })
    };
    let configs = dse::fig10_configs(CellFlavor::GcSiSiNp);
    let workers = dse::default_workers();
    let evals = dse::evaluate_all_cached(&configs, workers, &cache, eval).unwrap();
    println!("machine,level,task,c16,c32,c64,c96,c128");
    for (level, m) in [
        (workloads::CacheLevel::L1, &workloads::GT520M),
        (workloads::CacheLevel::L2, &workloads::H100),
    ] {
        for task in &workloads::TASKS {
            let d = workloads::profile(task, level, m);
            let glyphs: Vec<String> = evals
                .iter()
                .map(|e| dse::shmoo_verdict(e, &d).glyph().to_string())
                .collect();
            println!("{},{:?},{},{}", m.name, level, task.name, glyphs.join(","));
        }
    }
    // cold sweep (fresh cache) vs cached re-sweep: the caching win
    let s_cold = bench::run("dse_shmoo_axis_cold_parallel", 3.0, || {
        let fresh = dse::EvalCache::new();
        dse::evaluate_all_cached(&configs, workers, &fresh, eval).unwrap()
    });
    let s_hot = bench::run("dse_shmoo_axis_cached", 1.0, || {
        dse::evaluate_all_cached(&configs, workers, &cache, eval).unwrap()
    });
    println!("shmoo_cache_speedup,{:.1}x", s_cold.median_s / s_hot.median_s.max(1e-9));
    bench::run("dse_full_pipeline_one_config", 3.0, || {
        let cfg = Config::new(32, 32, CellFlavor::GcSiSiNp);
        let bank = compile(&tech, &cfg).unwrap();
        rt.with(|r| characterize::characterize(&tech, r, &bank)).unwrap()
    });
}
