//! Fig. 10 regenerator: shmoo of GCRAM bank configs against the
//! Table-I demands, plus end-to-end DSE throughput.
//!
//! The sweep is batch-first: `dse::evaluate_all_batched` compiles the
//! configs across `std::thread::scope` workers and characterizes them
//! in one `characterize_all` pass, packing every design's transient
//! points into shared padded artifact batches through the coordinator
//! — workers never serialize on the `SharedRuntime` mutex themselves.
//! The legacy per-design path (each worker running `characterize`
//! under the runtime lock) is kept as a comparison series, and the
//! artifact-call KPIs are asserted: a sweep of N designs must issue
//! ceil(N/batch) retention executions (not N), and with window
//! quantization a fine size axis must issue grouped-ceiling write and
//! read executions (not N either).
use opengcram::characterize::batch;
use opengcram::compiler::{compile, CellFlavor, CompileCache, Config};
use opengcram::runtime::SharedRuntime;
use opengcram::tech::sg40;
use opengcram::util::bench;
use opengcram::{characterize, compose, dse, workloads};
use std::path::Path;

fn main() {
    let tech = sg40();
    // auto: PJRT over artifacts when they load, native solver otherwise
    // — the KPI asserts below run against real execution counters either
    // way (no more "skipping: no artifacts" branch)
    let rt = SharedRuntime::auto(Path::new("artifacts"));
    println!("# execution backend: {}", rt.backend_name());
    let configs = dse::fig10_configs(CellFlavor::GcSiSiNp);
    let workers = opengcram::util::default_workers();

    let window_res = characterize::DEFAULT_WINDOW_RESOLUTION;

    // ---- batch-first sweep with artifact-call accounting ----------------
    let ret_cap = rt.batch_cap("retention").unwrap();
    let ret_before = rt.call_count("retention");
    let cache = dse::EvalCache::new();
    let structs = CompileCache::new();
    let evals =
        dse::evaluate_all_batched_cached(&tech, &rt, &configs, workers, &cache, &structs, window_res)
            .unwrap();
    let ret_calls = (rt.call_count("retention") - ret_before) as usize;
    let want_calls = batch::calls_for(configs.len(), ret_cap);
    assert!(
        ret_calls <= want_calls,
        "batched sweep issued {ret_calls} retention executions for {} designs (cap {ret_cap}); \
         the batcher guarantees <= {want_calls}",
        configs.len()
    );
    println!("retention_calls_per_sweep,{ret_calls}");
    println!(
        "retention_batch_occupancy,{:.4}",
        configs.len() as f64 / (ret_calls.max(1) * ret_cap) as f64
    );

    println!("machine,level,task,c16,c32,c64,c96,c128");
    for (level, m) in [
        (workloads::CacheLevel::L1, &workloads::GT520M),
        (workloads::CacheLevel::L2, &workloads::H100),
    ] {
        for task in &workloads::TASKS {
            let d = workloads::profile(task, level, m);
            let glyphs: Vec<String> = evals
                .iter()
                .map(|e| dse::shmoo_verdict(e, &d).glyph().to_string())
                .collect();
            println!("{},{:?},{},{}", m.name, level, task.name, glyphs.join(","));
        }
    }

    // ---- window-quantized mixed-geometry packing ------------------------
    // a fine rows axis, pinned >= 180 rows (mux 1) so both windows sit
    // above their floor clamps: every design's exact windows differ,
    // so the pre-quantization batcher issued one write and one read
    // execution per design; the bucket grid must collapse them to the
    // grouped ceiling computed from the plans' own window bits
    let axis_cfgs: Vec<Config> = characterize::quantization_axis(5, 180, 4);
    let axis_banks: Vec<_> = axis_cfgs.iter().map(|c| compile(&tech, c).unwrap()).collect();
    let (wr_groups, rd_groups) =
        characterize::window_group_counts(&tech, &axis_banks, window_res);
    let wr_before = rt.call_count("write");
    let rd_before = rt.call_count("read");
    let axis_cache = dse::EvalCache::new();
    let axis_evals = dse::evaluate_all_batched_cached(
        &tech,
        &rt,
        &axis_cfgs,
        workers,
        &axis_cache,
        &CompileCache::new(),
        window_res,
    )
    .unwrap();
    assert_eq!(axis_evals.len(), axis_cfgs.len());
    let wr_calls = (rt.call_count("write") - wr_before) as usize;
    let rd_calls = (rt.call_count("read") - rd_before) as usize;
    // each bucket holds <= 2N points << cap, so calls == groups; and
    // rows 180..196 span less than two 10 % steps, so groups < designs
    assert_eq!(
        wr_calls, wr_groups,
        "size-axis sweep issued {wr_calls} write executions for {wr_groups} window buckets"
    );
    assert_eq!(
        rd_calls, rd_groups,
        "size-axis sweep issued {rd_calls} read executions for {rd_groups} window buckets"
    );
    assert!(
        wr_calls < axis_cfgs.len() && rd_calls < axis_cfgs.len(),
        "quantization failed to pack the size axis: wr {wr_calls} rd {rd_calls} of {}",
        axis_cfgs.len()
    );
    println!("sizeaxis_write_calls,{wr_calls}");
    println!("sizeaxis_read_calls,{rd_calls}");
    println!(
        "sizeaxis_designs_per_write_call,{:.2}",
        axis_cfgs.len() as f64 / wr_calls.max(1) as f64
    );

    // ---- cross-flavor composition mega-sweep ----------------------------
    // the compose subsystem's KPI over real artifacts: all four
    // flavors' designs go through ONE evaluate_all_batched_cached pass
    // and their retention points share one grouped-ceiling batch
    // sequence — not per-flavor x per-design executions
    let grid = compose::design_grid();
    let transient = grid.iter().filter(|c| c.flavor.is_gc()).count();
    let ret_before = rt.call_count("retention");
    let comp_cache = dse::EvalCache::new();
    let comp_structs = CompileCache::new();
    let comp_evals = dse::evaluate_all_batched_cached(
        &tech,
        &rt,
        &grid,
        workers,
        &comp_cache,
        &comp_structs,
        window_res,
    )
    .unwrap();
    assert_eq!(comp_evals.len(), grid.len());
    let ret_calls = (rt.call_count("retention") - ret_before) as usize;
    let want = batch::calls_for(transient, ret_cap);
    assert_eq!(
        ret_calls, want,
        "cross-flavor sweep issued {ret_calls} retention executions for {transient} transient \
         designs; the shared batch sequence guarantees the grouped ceiling {want}"
    );
    println!("compose_retention_calls,{ret_calls}");
    println!(
        "compose_retention_occupancy,{:.4}",
        transient as f64 / (ret_calls.max(1) * ret_cap) as f64
    );
    // the composition itself rides the same cache: selecting for a
    // machine pays zero additional pipeline evaluations
    let mut spec = compose::ComposeSpec::new(&workloads::H100);
    spec.window_resolution = window_res;
    let comp = compose::compose_cached(&tech, &rt, &spec, &comp_cache, &comp_structs).unwrap();
    assert_eq!(comp.cache_misses, 0, "composition re-ran the sweep instead of reusing the cache");
    let served = comp.per_demand.iter().filter(|s| s.choice.is_some()).count();
    println!("compose_h100_demands_served,{served}/{}", comp.per_demand.len());

    // ---- batched vs legacy-serialized sweep (both cold) -----------------
    // the legacy arm models the pre-batching behavior: every worker's
    // per-design characterize serializes on ONE execution lane.  On
    // pjrt that serialization is the SharedRuntime mutex itself; the
    // native backend has no lock, so give the legacy arm a dedicated
    // single-worker backend — otherwise each of `workers` eval threads
    // would nest a full-width par_map inside execute() and the series
    // would stop measuring the batching win
    let legacy_rt = match &rt {
        SharedRuntime::Native(_) => {
            SharedRuntime::Native(opengcram::runtime::NativeBackend::new().with_workers(1))
        }
        // PJRT is known to load here (the primary rt did; auto wraps it
        // in the failover breaker); a failed second load must not
        // silently swap this series onto a full-parallelism native
        // backend
        SharedRuntime::Pjrt(_) | SharedRuntime::Failover(_) => {
            SharedRuntime::load(Path::new("artifacts"))
                .expect("second PJRT load for the legacy arm")
        }
        SharedRuntime::Fault(_) => {
            unreachable!("the bench never wraps its runtime in fault injection")
        }
    };
    let legacy_eval = |cfg: &Config| -> opengcram::Result<dse::Evaluated> {
        let bank = compile(&tech, cfg)?;
        let perf = legacy_rt.with(|r| characterize::characterize(&tech, r, &bank))?;
        Ok(dse::Evaluated {
            config: cfg.clone(),
            perf,
            area_um2: bank.layout.total_area_um2(),
            quarantine: None,
        })
    };
    let s_legacy = bench::run("dse_shmoo_axis_legacy_mutex", 3.0, || {
        dse::evaluate_all(&configs, workers, legacy_eval).unwrap()
    });
    // resolution 0 keeps this series apples-to-apples with the legacy
    // arm (and with pre-quantization runs): it isolates the
    // coordinator-batching win from the quantization packing win,
    // which gets its own series below
    let s_batched = bench::run("dse_shmoo_axis_batched", 3.0, || {
        dse::evaluate_all_batched(&tech, &rt, &configs, workers, 0.0).unwrap()
    });
    println!(
        "shmoo_batched_speedup,{:.2}x",
        s_legacy.median_s / s_batched.median_s.max(1e-12)
    );
    let s_quant = bench::run("dse_shmoo_axis_batched_quantized", 3.0, || {
        dse::evaluate_all_batched(&tech, &rt, &configs, workers, window_res).unwrap()
    });
    println!(
        "shmoo_quantized_speedup,{:.2}x",
        s_batched.median_s / s_quant.median_s.max(1e-12)
    );

    // cached re-sweep: the caching win on top of batching
    let s_hot = bench::run("dse_shmoo_axis_cached", 1.0, || {
        dse::evaluate_all_batched_cached(&tech, &rt, &configs, workers, &cache, &structs, window_res)
            .unwrap()
    });
    println!("shmoo_cache_speedup,{:.1}x", s_batched.median_s / s_hot.median_s.max(1e-9));
    bench::run("dse_full_pipeline_one_config", 3.0, || {
        let cfg = Config::new(32, 32, CellFlavor::GcSiSiNp);
        let bank = compile(&tech, &cfg).unwrap();
        characterize::characterize_all(&tech, &rt, std::slice::from_ref(&bank), window_res)
            .unwrap()
    });
}
