//! Fig. 10 regenerator: shmoo of GCRAM bank configs against the
//! Table-I demands, plus end-to-end DSE throughput.
//!
//! The sweep is batch-first: `dse::evaluate_all_batched` compiles the
//! configs across `std::thread::scope` workers and characterizes them
//! in one `characterize_all` pass, packing every design's transient
//! points into shared padded artifact batches through the coordinator
//! — workers never serialize on the `SharedRuntime` mutex themselves.
//! The legacy per-design path (each worker running `characterize`
//! under the runtime lock) is kept as a comparison series, and the
//! artifact-call KPI is asserted: a sweep of N designs must issue
//! ceil(N/batch) retention executions, not N.
use opengcram::characterize::batch;
use opengcram::compiler::{compile, CellFlavor, Config};
use opengcram::runtime::SharedRuntime;
use opengcram::tech::sg40;
use opengcram::util::bench;
use opengcram::{characterize, dse, workloads};
use std::path::Path;

fn main() {
    let tech = sg40();
    let rt = match SharedRuntime::load(Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            // distinguishes the unlinked-PJRT stub build from a
            // genuinely missing `make artifacts`
            println!("# fig10_shmoo needs the PJRT runtime and artifacts/: {e}");
            return;
        }
    };
    let configs = dse::fig10_configs(CellFlavor::GcSiSiNp);
    let workers = dse::default_workers();

    // ---- batch-first sweep with artifact-call accounting ----------------
    let ret_cap = rt.batch_cap("retention").unwrap();
    let ret_before = rt.call_count("retention");
    let cache = dse::EvalCache::new();
    let evals =
        dse::evaluate_all_batched_cached(&tech, &rt, &configs, workers, &cache).unwrap();
    let ret_calls = (rt.call_count("retention") - ret_before) as usize;
    let want_calls = batch::calls_for(configs.len(), ret_cap);
    assert!(
        ret_calls <= want_calls,
        "batched sweep issued {ret_calls} retention executions for {} designs (cap {ret_cap}); \
         the batcher guarantees <= {want_calls}",
        configs.len()
    );
    println!("retention_calls_per_sweep,{ret_calls}");
    println!(
        "retention_batch_occupancy,{:.4}",
        configs.len() as f64 / (ret_calls.max(1) * ret_cap) as f64
    );

    println!("machine,level,task,c16,c32,c64,c96,c128");
    for (level, m) in [
        (workloads::CacheLevel::L1, &workloads::GT520M),
        (workloads::CacheLevel::L2, &workloads::H100),
    ] {
        for task in &workloads::TASKS {
            let d = workloads::profile(task, level, m);
            let glyphs: Vec<String> = evals
                .iter()
                .map(|e| dse::shmoo_verdict(e, &d).glyph().to_string())
                .collect();
            println!("{},{:?},{},{}", m.name, level, task.name, glyphs.join(","));
        }
    }

    // ---- batched vs legacy-mutex sweep (both cold) ----------------------
    let legacy_eval = |cfg: &Config| -> opengcram::Result<dse::Evaluated> {
        let bank = compile(&tech, cfg)?;
        let perf = rt.with(|r| characterize::characterize(&tech, r, &bank))?;
        Ok(dse::Evaluated { config: cfg.clone(), perf, area_um2: bank.layout.total_area_um2() })
    };
    let s_legacy = bench::run("dse_shmoo_axis_legacy_mutex", 3.0, || {
        dse::evaluate_all(&configs, workers, legacy_eval).unwrap()
    });
    let s_batched = bench::run("dse_shmoo_axis_batched", 3.0, || {
        dse::evaluate_all_batched(&tech, &rt, &configs, workers).unwrap()
    });
    println!(
        "shmoo_batched_speedup,{:.2}x",
        s_legacy.median_s / s_batched.median_s.max(1e-12)
    );

    // cached re-sweep: the caching win on top of batching
    let s_hot = bench::run("dse_shmoo_axis_cached", 1.0, || {
        dse::evaluate_all_batched_cached(&tech, &rt, &configs, workers, &cache).unwrap()
    });
    println!("shmoo_cache_speedup,{:.1}x", s_batched.median_s / s_hot.median_s.max(1e-9));
    bench::run("dse_full_pipeline_one_config", 3.0, || {
        let cfg = Config::new(32, 32, CellFlavor::GcSiSiNp);
        let bank = compile(&tech, &cfg).unwrap();
        characterize::characterize_all(&tech, &rt, std::slice::from_ref(&bank)).unwrap()
    });
}
