//! Fig. 10 regenerator: shmoo of GCRAM bank configs against the
//! Table-I demands, plus end-to-end DSE throughput.
use opengcram::compiler::{compile, CellFlavor, Config};
use opengcram::runtime::Runtime;
use opengcram::tech::sg40;
use opengcram::util::bench;
use opengcram::{characterize, dse, workloads};
use std::path::Path;

fn main() {
    let tech = sg40();
    let rt = Runtime::load(Path::new("artifacts")).expect("make artifacts");
    let evals: Vec<dse::Evaluated> = dse::fig10_configs(CellFlavor::GcSiSiNp)
        .into_iter()
        .map(|cfg| {
            let bank = compile(&tech, &cfg).unwrap();
            let perf = characterize::characterize(&tech, &rt, &bank).unwrap();
            dse::Evaluated { config: cfg, perf, area_um2: bank.layout.total_area_um2() }
        })
        .collect();
    println!("machine,level,task,c16,c32,c64,c96,c128");
    for (level, m) in [
        (workloads::CacheLevel::L1, &workloads::GT520M),
        (workloads::CacheLevel::L2, &workloads::H100),
    ] {
        for task in &workloads::TASKS {
            let d = workloads::profile(task, level, m);
            let glyphs: Vec<String> = evals
                .iter()
                .map(|e| dse::shmoo_verdict(e, &d).glyph().to_string())
                .collect();
            println!("{},{:?},{},{}", m.name, level, task.name, glyphs.join(","));
        }
    }
    bench::run("dse_full_pipeline_one_config", 3.0, || {
        let cfg = Config::new(32, 32, CellFlavor::GcSiSiNp);
        let bank = compile(&tech, &cfg).unwrap();
        characterize::characterize(&tech, &rt, &bank).unwrap()
    });
}
