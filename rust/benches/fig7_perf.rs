//! Fig. 7 regenerator: operating frequency, effective bandwidth and
//! leakage across sizes/flavors.  The whole figure is one batch-first
//! `characterize_all` pass: all 15 designs' transient points pack into
//! shared padded artifact batches through the coordinator.
use opengcram::compiler::{compile, CellFlavor, Config};
use opengcram::runtime::SharedRuntime;
use opengcram::tech::sg40;
use opengcram::util::bench;
use opengcram::{characterize, report};
use std::path::Path;

fn main() {
    let tech = sg40();
    let rt = SharedRuntime::auto(Path::new("artifacts"));
    println!("# execution backend: {}", rt.backend_name());
    let mut labels: Vec<(String, &'static str, usize)> = Vec::new();
    let mut banks = Vec::new();
    for (w, n, label) in [
        (16usize, 16usize, "256b_1to1"),
        (32, 32, "1kb_1to1"),
        (64, 64, "4kb_1to1"),
        (128, 32, "4kb_4to1"),
        (128, 128, "16kb_1to1"),
    ] {
        for (fl, name) in [
            (CellFlavor::Sram6t, "sram"),
            (CellFlavor::GcSiSiNp, "gc"),
        ] {
            let bank = compile(&tech, &Config::new(w, n, fl)).unwrap();
            labels.push((label.to_string(), name, bank.delay_chain_stages));
            banks.push(bank);
        }
        let mut cfg = Config::new(w, n, CellFlavor::GcSiSiNp);
        cfg.wwlls = true;
        let bank = compile(&tech, &cfg).unwrap();
        labels.push((label.to_string(), "gc_wwlls", bank.delay_chain_stages));
        banks.push(bank);
    }
    let res = characterize::DEFAULT_WINDOW_RESOLUTION;
    let perfs = characterize::characterize_all(&tech, &rt, &banks, res).unwrap();
    println!("config,flavor,f_op_mhz,bw_gbps,leak_nw,stages");
    for ((label, name, stages), p) in labels.iter().zip(&perfs) {
        println!(
            "{label},{name},{:.1},{},{:.2},{stages}",
            p.f_op_hz / 1e6,
            report::gbps(p.bandwidth_bps),
            p.leakage_w * 1e9,
        );
    }
    let bank = compile(&tech, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap();
    bench::run("characterize_1kb_transient", 2.0, || {
        rt.with(|r| characterize::characterize(&tech, r, &bank)).unwrap()
    });
    bench::run("characterize_all_fig7_15designs", 3.0, || {
        characterize::characterize_all(&tech, &rt, &banks, res).unwrap()
    });
    println!("# artifact executions: {:?}", rt.call_counts());
}
