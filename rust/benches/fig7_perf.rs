//! Fig. 7 regenerator: operating frequency, effective bandwidth and
//! leakage across sizes/flavors (transient-backed characterization).
use opengcram::compiler::{compile, CellFlavor, Config};
use opengcram::runtime::Runtime;
use opengcram::tech::sg40;
use opengcram::util::bench;
use opengcram::characterize;
use std::path::Path;

fn main() {
    let tech = sg40();
    let rt = Runtime::load(Path::new("artifacts")).expect("make artifacts");
    println!("config,flavor,f_op_mhz,bw_gbps,leak_nw,stages");
    for (w, n, label) in [
        (16usize, 16usize, "256b_1to1"),
        (32, 32, "1kb_1to1"),
        (64, 64, "4kb_1to1"),
        (128, 32, "4kb_4to1"),
        (128, 128, "16kb_1to1"),
    ] {
        for (fl, name) in [
            (CellFlavor::Sram6t, "sram"),
            (CellFlavor::GcSiSiNp, "gc"),
        ] {
            let bank = compile(&tech, &Config::new(w, n, fl)).unwrap();
            let p = characterize::characterize(&tech, &rt, &bank).unwrap();
            println!(
                "{label},{name},{:.1},{:.2},{:.2},{}",
                p.f_op_hz / 1e6,
                p.bandwidth_bps / 1e9,
                p.leakage_w * 1e9,
                bank.delay_chain_stages
            );
        }
        let mut cfg = Config::new(w, n, CellFlavor::GcSiSiNp);
        cfg.wwlls = true;
        let bank = compile(&tech, &cfg).unwrap();
        let p = characterize::characterize(&tech, &rt, &bank).unwrap();
        println!(
            "{label},gc_wwlls,{:.1},{:.2},{:.2},{}",
            p.f_op_hz / 1e6,
            p.bandwidth_bps / 1e9,
            p.leakage_w * 1e9,
            bank.delay_chain_stages
        );
    }
    let bank = compile(&tech, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap();
    bench::run("characterize_1kb_transient", 2.0, || {
        characterize::characterize(&tech, &rt, &bank).unwrap()
    });
}
