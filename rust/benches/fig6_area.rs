//! Fig. 6 regenerator + timing: bank/array areas across sizes with
//! extrapolation to the crossover (paper: GCRAM bank < SRAM > 256 Kb).
use opengcram::compiler::{compile, CellFlavor, Config};
use opengcram::tech::sg40;
use opengcram::util::bench;

fn main() {
    let tech = sg40();
    println!("bits,sram_um2,gc_um2,gc_wwlls_um2,os_um2,gc_array_um2,sram_array_um2,gc_eff,ratio");
    for (w, n) in [(32usize, 32usize), (64, 64), (128, 128), (256, 256), (512, 512)] {
        let sram = compile(&tech, &Config::new(w, n, CellFlavor::Sram6t)).unwrap();
        let gc = compile(&tech, &Config::new(w, n, CellFlavor::GcSiSiNp)).unwrap();
        let mut cl = Config::new(w, n, CellFlavor::GcSiSiNp);
        cl.wwlls = true;
        let gcls = compile(&tech, &cl).unwrap();
        let os = compile(&tech, &Config::new(w, n, CellFlavor::GcOsOs)).unwrap();
        println!(
            "{},{:.0},{:.0},{:.0},{:.0},{:.0},{:.0},{:.3},{:.3}",
            w * n,
            sram.layout.total_area_um2(),
            gc.layout.total_area_um2(),
            gcls.layout.total_area_um2(),
            os.layout.total_area_um2(),
            gc.layout.array_area_um2(),
            sram.layout.array_area_um2(),
            gc.layout.array_efficiency(),
            gc.layout.total_area_um2() / sram.layout.total_area_um2()
        );
    }
    bench::run("compile_1kb_gc_bank", 1.0, || {
        compile(&tech, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap()
    });
    bench::run("compile_16kb_gc_bank", 1.5, || {
        compile(&tech, &Config::new(128, 128, CellFlavor::GcSiSiNp)).unwrap()
    });
}
