//! Ablation: GEMTOO-class analytical model vs the transient-backed
//! characterization (the paper quotes <=15 % deviation for GEMTOO;
//! our stand-in reports its own deviation per size) + speed ratio.
//! The transient column is one batch-first `characterize_all` pass.
use opengcram::compiler::{compile, CellFlavor, Config};
use opengcram::runtime::SharedRuntime;
use opengcram::tech::sg40;
use opengcram::util::bench;
use opengcram::characterize;
use std::path::Path;

fn main() {
    let tech = sg40();
    let rt = SharedRuntime::auto(Path::new("artifacts"));
    println!("# execution backend: {}", rt.backend_name());
    let banks: Vec<_> = [(16usize, 16usize), (32, 32), (64, 64), (128, 128)]
        .iter()
        .map(|&(w, n)| compile(&tech, &Config::new(w, n, CellFlavor::GcSiSiNp)).unwrap())
        .collect();
    let transients = characterize::characterize_all(
        &tech,
        &rt,
        &banks,
        characterize::DEFAULT_WINDOW_RESOLUTION,
    )
    .unwrap();
    println!("bits,f_analytical_mhz,f_transient_mhz,deviation_pct");
    for (bank, c) in banks.iter().zip(&transients) {
        let a = characterize::analytical(&tech, bank);
        println!(
            "{},{:.1},{:.1},{:.1}",
            bank.config.bits(),
            a.f_op_hz / 1e6,
            c.f_op_hz / 1e6,
            100.0 * (a.f_op_hz - c.f_op_hz).abs() / c.f_op_hz
        );
    }
    let bank = compile(&tech, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap();
    let sa = bench::run("analytical_model", 1.0, || characterize::analytical(&tech, &bank));
    let st = bench::run("transient_model", 2.0, || {
        rt.with(|r| characterize::characterize(&tech, r, &bank)).unwrap()
    });
    println!("speedup_analytical_over_transient,{:.0}x", st.median_s / sa.median_s);
}
