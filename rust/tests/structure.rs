//! Soundness pins for the structure-keyed compile cache
//! ([`opengcram::compiler::CompileCache`]).
//!
//! The cache's entire claim is that [`Config::struct_key`] captures
//! *exactly* the geometry-determining fields: two configs with equal
//! struct keys may share one compiled [`BankStructure`] by `Arc`.
//! These tests pin that claim from both sides:
//!
//! * **VT siblings are bitwise-identical geometry**: configs differing
//!   only in `write_vt` compile — through the *uncached* full path —
//!   to byte-identical GDS, identical SPICE text, and bit-identical
//!   area/parasitics/delay-chain, across sizes, flavors, and WWLLS.
//! * **Key discrimination**: every geometric field flip moves the
//!   struct key; the electrical knob does not; an explicit mux factor
//!   aliases with the `None` policy that resolves to the same value.
//! * **Census KPI**: a size x VT sweep pays exactly one geometry
//!   compile per distinct struct key — 5 for the 5x5 optimizer grid,
//!   20 for the 80-config cross-flavor composition grid.
//! * **Cache transparency**: `Evaluated` outputs with a shared
//!   (pre-warmed) structure cache are bitwise-equal to the
//!   throwaway-cache sweep.

use opengcram::compiler::{compile, CellFlavor, CompileCache, Config};
use opengcram::layout::gds;
use opengcram::netlist::spice;
use opengcram::runtime::SharedRuntime;
use opengcram::tech::sg40;
use opengcram::{compose, dse};
use std::collections::HashSet;

/// Bitwise comparison of everything a [`BankStructure`] derives from
/// geometry, via the uncached compile path (each side rebuilt from
/// scratch — no shared `Arc` to make the comparison vacuous).
fn assert_same_structure(t: &opengcram::tech::Tech, a: &Config, b: &Config, what: &str) {
    let ba = compile(t, a).unwrap();
    let bb = compile(t, b).unwrap();
    assert_eq!(a.struct_key(), b.struct_key(), "{what}: struct keys must match");
    assert_eq!(
        gds::write_bytes(&ba.library, t, "bank"),
        gds::write_bytes(&bb.library, t, "bank"),
        "{what}: GDS bytes diverged"
    );
    assert_eq!(
        spice::emit(&ba.netlist),
        spice::emit(&bb.netlist),
        "{what}: SPICE netlist diverged"
    );
    assert_eq!(
        ba.layout.total_area_um2().to_bits(),
        bb.layout.total_area_um2().to_bits(),
        "{what}: area diverged"
    );
    let pa = &ba.parasitics;
    let pb = &bb.parasitics;
    for (name, x, y) in [
        ("c_sn", pa.c_sn, pb.c_sn),
        ("c_wbl", pa.c_wbl, pb.c_wbl),
        ("c_rbl", pa.c_rbl, pb.c_rbl),
        ("r_wl", pa.r_wl, pb.r_wl),
        ("c_wl", pa.c_wl, pb.c_wl),
        ("c_wwl_sn", pa.c_wwl_sn, pb.c_wwl_sn),
        ("c_rwl_sn", pa.c_rwl_sn, pb.c_rwl_sn),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: parasitics.{name} diverged");
    }
    assert_eq!(ba.delay_chain_stages, bb.delay_chain_stages, "{what}: delay chain diverged");
}

#[test]
fn structure_vt_siblings_compile_to_bitwise_identical_geometry() {
    // the soundness property behind Arc sharing, checked through the
    // old full path (plain `compile` rebuilds per call): write_vt
    // must be invisible to every geometry product
    let t = sg40();
    for flavor in [CellFlavor::GcSiSiNp, CellFlavor::GcSiSiNn, CellFlavor::GcOsOs] {
        for (w, n) in [(16, 16), (32, 32), (16, 64)] {
            for wwlls in [false, true] {
                let mut base = Config::new(w, n, flavor);
                base.wwlls = wwlls;
                let mut sib = base.clone();
                sib.write_vt = Some(0.45);
                let what = format!("{w}x{n} {flavor:?} wwlls={wwlls}");
                assert_same_structure(&t, &base, &sib, &what);
            }
        }
    }
    // SRAM has no write transistor to re-thread, but the key still
    // must not see the knob
    let base = Config::new(32, 32, CellFlavor::Sram6t);
    let mut sib = base.clone();
    sib.write_vt = Some(0.6);
    assert_same_structure(&t, &base, &sib, "32x32 Sram6t");
}

#[test]
fn structure_key_discriminates_geometry_and_ignores_electrical() {
    let base = Config::new(32, 64, CellFlavor::GcSiSiNp);
    let key = base.struct_key();

    // every geometric field flip must move the key
    let mut c = base.clone();
    c.word_size = 16;
    assert_ne!(c.struct_key(), key, "word_size is geometric");
    let mut c = base.clone();
    c.num_words = 128;
    assert_ne!(c.struct_key(), key, "num_words is geometric");
    let mut c = base.clone();
    c.flavor = CellFlavor::GcOsOs;
    assert_ne!(c.struct_key(), key, "flavor is geometric");
    let mut c = base.clone();
    c.wwlls = true;
    assert_ne!(c.struct_key(), key, "wwlls is geometric");
    let mut c = base.clone();
    c.mux_factor = Some(4);
    assert_ne!(c.struct_key(), key, "a non-policy mux factor is geometric");

    // the electrical knob must not
    let mut c = base.clone();
    c.write_vt = Some(0.38);
    assert_eq!(c.struct_key(), key, "write_vt is electrical");

    // an explicit mux factor equal to the resolved policy value
    // aliases to the same structure (the key stores the resolution)
    let mut c = base.clone();
    c.mux_factor = Some(base.mux_factor());
    assert_eq!(c.struct_key(), key, "explicit policy mux must alias");
    assert_eq!(key.mux_factor, base.mux_factor(), "key stores the resolved factor");

    // the key's representative config resolves back to itself
    assert_eq!(key.to_config().struct_key(), key, "to_config must round-trip");
}

#[test]
fn structure_census_grid_sweep_pays_one_compile_per_distinct_key() {
    // runtime-free census over the full cross-flavor composition grid:
    // 80 configs (the SRAM slice keeps only VT-free entries), 20
    // distinct geometries — compiles must equal the census, hits the
    // remainder
    let t = sg40();
    let grid = compose::design_grid();
    let distinct: HashSet<_> = grid.iter().map(|c| c.struct_key()).collect();
    assert!(distinct.len() < grid.len(), "grid must exercise struct-key aliasing");
    let refs: Vec<&Config> = grid.iter().collect();
    let structs = CompileCache::new();
    let banks = structs.compile_all(&t, &refs, 2).unwrap();
    assert_eq!(banks.len(), grid.len());
    let (hits, compiles) = structs.stats();
    assert_eq!(compiles, distinct.len(), "compiles must equal the distinct-structure census");
    assert_eq!(hits, grid.len() - distinct.len());
    assert_eq!(structs.len(), distinct.len());
    // VT siblings share the structure by pointer, not by copy
    for (cfg, bank) in grid.iter().zip(&banks) {
        let rep = banks[grid.iter().position(|c| c.struct_key() == cfg.struct_key()).unwrap()]
            .structure
            .clone();
        assert!(std::sync::Arc::ptr_eq(&bank.structure, &rep), "siblings must share one Arc");
    }
    // a repeat batch is all hits, zero new compiles
    structs.compile_all(&t, &refs, 2).unwrap();
    assert_eq!(structs.stats(), (2 * hits + compiles, compiles), "repeat sweep recompiled");
}

#[test]
fn structure_cache_is_transparent_to_evaluated_outputs() {
    // full-pipeline pins on a size x VT axis: the sweep pays one
    // geometry compile per distinct size, and every Evaluated output
    // is bitwise-identical to the throwaway-cache sweep
    let t = sg40();
    let mut configs = Vec::new();
    for (w, n) in [(16, 16), (32, 32)] {
        for vt in [None, Some(0.38), Some(0.52)] {
            let mut c = Config::new(w, n, CellFlavor::GcSiSiNp);
            c.write_vt = vt;
            configs.push(c);
        }
    }

    let rt = SharedRuntime::native();
    let cache = dse::EvalCache::new();
    let structs = CompileCache::new();
    let (evals, health) =
        dse::evaluate_all_batched_cached_health(&t, &rt, &configs, 2, &cache, &structs, 0.0)
            .unwrap();
    assert!(health.is_clean(), "{}", health.summary());
    assert_eq!(evals.len(), configs.len());
    let (hits, compiles) = structs.stats();
    assert_eq!(compiles, 2, "six configs span two geometries");
    assert_eq!(hits, 4, "every VT sibling must ride a struct hit");

    // reference arm: throwaway caches (the pre-tentpole behavior)
    let ref_rt = SharedRuntime::native();
    let reference = dse::evaluate_all_batched(&t, &ref_rt, &configs, 2, 0.0).unwrap();
    for (a, b) in evals.iter().zip(&reference) {
        let what = format!("{:?}", a.config);
        assert_eq!(a.config.key(), b.config.key(), "{what}: sweep order diverged");
        assert_eq!(a.area_um2.to_bits(), b.area_um2.to_bits(), "{what}: area diverged");
        assert_eq!(a.quarantine, b.quarantine, "{what}: quarantine diverged");
        for (name, x, y) in [
            ("f_read_hz", a.perf.f_read_hz, b.perf.f_read_hz),
            ("f_write_hz", a.perf.f_write_hz, b.perf.f_write_hz),
            ("f_op_hz", a.perf.f_op_hz, b.perf.f_op_hz),
            ("bandwidth_bps", a.perf.bandwidth_bps, b.perf.bandwidth_bps),
            ("retention_s", a.perf.retention_s, b.perf.retention_s),
            ("leakage_w", a.perf.leakage_w, b.perf.leakage_w),
            ("e_read_j", a.perf.e_read_j, b.perf.e_read_j),
            ("t_decoder_s", a.perf.t_decoder_s, b.perf.t_decoder_s),
            ("t_cell_read_s", a.perf.t_cell_read_s, b.perf.t_cell_read_s),
            ("stored_one_v", a.perf.stored_one_v, b.perf.stored_one_v),
        ] {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: {name} diverged");
        }
        assert_eq!(a.perf.functional, b.perf.functional, "{what}: verdict diverged");
    }
    // the VT axis must actually bite (the retention knob works), or
    // the sharing claim above was tested on dead inputs
    assert_ne!(
        evals[0].perf.retention_s.to_bits(),
        evals[1].perf.retention_s.to_bits(),
        "write_vt override did not change retention — electrical axis is dead"
    );

    // 5x5 optimizer grid KPI: 25 configs, 5 structures
    let grid = dse::grid_configs(CellFlavor::GcSiSiNp);
    let grid_keys: HashSet<_> = grid.iter().map(|c| c.struct_key()).collect();
    assert_eq!(grid.len(), 25);
    assert_eq!(grid_keys.len(), 5, "the VT axis must be invisible to the struct key");
}
