//! Service-layer acceptance tier: the session refactor must not move
//! a single bit of the one-shot results, and the `serve` front end
//! must deliver its two scale KPIs on REAL native call counters —
//! N concurrent single-design clients pay the grouped-ceiling census
//! of ONE union sweep, and a server restarted over the same on-disk
//! store re-serves an identical sweep with zero characterization
//! executions.

use opengcram::characterize::{self, DEFAULT_WINDOW_RESOLUTION};
use opengcram::compiler::{compile, CellFlavor, Config};
use opengcram::runtime::SharedRuntime;
use opengcram::service::serve::{self, ServeOpts};
use opengcram::service::Session;
use opengcram::tech::sg40;
use opengcram::util::json::Json;
use opengcram::dse;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

/// Unique scratch path (no tempfile crate in the offline registry).
fn scratch(name: &str) -> PathBuf {
    static N: AtomicUsize = AtomicUsize::new(0);
    std::env::temp_dir().join(format!(
        "opengcram-serve-test-{}-{}-{}",
        name,
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Run `body` against a live server over `session`, then shut the
/// server down cleanly.  A panicking body still shuts the server down
/// (so the scope join can't deadlock) before resuming the panic.
fn with_server<R>(
    session: &Session,
    socket: &Path,
    gather_ms: u64,
    body: impl FnOnce() -> R,
) -> R {
    let opts = ServeOpts { socket: socket.to_path_buf(), gather_ms };
    std::thread::scope(|s| {
        let server = s.spawn(|| serve::serve(session, &opts));
        for _ in 0..1000 {
            if socket.exists() {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(socket.exists(), "server did not come up on {}", socket.display());
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(body));
        let down = serve::client_request(socket, r#"{"cmd":"shutdown"}"#);
        server.join().expect("server thread").expect("clean serve exit");
        match out {
            Ok(r) => {
                down.expect("shutdown handshake");
                r
            }
            Err(p) => std::panic::resume_unwind(p),
        }
    })
}

fn parse_ok(resp: &str) -> Json {
    let j = Json::parse(resp).unwrap_or_else(|e| panic!("bad response {resp}: {e}"));
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "error response: {resp}");
    j
}

fn calls_of(j: &Json, field: &str) -> BTreeMap<String, u64> {
    j.get(field)
        .and_then(Json::as_obj)
        .map(|m| {
            m.iter()
                .map(|(k, v)| (k.clone(), v.as_f64().expect("numeric counter") as u64))
                .collect()
        })
        .unwrap_or_default()
}

fn char_line(cfg: &Config, gather: usize) -> String {
    format!(
        r#"{{"cmd":"char","config":{},"gather":{}}}"#,
        serve::config_json(cfg).dump(),
        gather
    )
}

/// The acceptance KPI: three concurrent single-design clients share
/// ONE batched sweep — each response reports the full party and a
/// sweep census equal to a reference single-mega-batch run of the
/// same three designs on a private runtime (grouped ceiling: one
/// retention execution for the whole party, not one per client).
#[test]
fn concurrent_clients_pay_grouped_ceiling_census() {
    let t = sg40();
    let configs = [
        Config::new(16, 16, CellFlavor::GcSiSiNp),
        Config::new(32, 32, CellFlavor::GcSiSiNp),
        Config::new(16, 32, CellFlavor::GcSiSiNp),
    ];

    // reference: the same three designs as one batched sweep on a
    // private runtime — real counters, no other test can touch them
    let rt_ref = SharedRuntime::native();
    let (expected, _h) = dse::evaluate_all_batched_health(
        &t,
        &rt_ref,
        &configs,
        1,
        DEFAULT_WINDOW_RESOLUTION,
    )
    .unwrap();
    let expected_calls = rt_ref.call_counts();
    assert_eq!(
        expected_calls.get("retention").copied(),
        Some(1),
        "3 designs must share one retention execution: {expected_calls:?}"
    );

    let session = Session::new(&t, SharedRuntime::native(), DEFAULT_WINDOW_RESOLUTION).unwrap();
    let socket = scratch("census.sock");
    let responses: Vec<(usize, Json)> = with_server(&session, &socket, 10_000, || {
        std::thread::scope(|s| {
            let handles: Vec<_> = configs
                .iter()
                .enumerate()
                .map(|(i, cfg)| {
                    let socket = socket.as_path();
                    let line = char_line(cfg, configs.len());
                    s.spawn(move || (i, parse_ok(&serve::client_request(socket, &line).unwrap())))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    });

    for (i, resp) in &responses {
        assert_eq!(
            resp.get("party").and_then(Json::as_usize),
            Some(configs.len()),
            "client {i} must report the full party: {resp:?}"
        );
        // the shared census IS the reference mega-batch census
        assert_eq!(calls_of(resp, "sweep_calls"), expected_calls, "client {i}");
        // ...and so is the structure census: three distinct geometries
        // in one batch, reported to every party member
        assert_eq!(
            resp.get("struct_compiles").and_then(Json::as_usize),
            Some(configs.len()),
            "client {i} struct census: {resp:?}"
        );
        // and each client's numbers are its design's, bit-for-bit
        // (decimal JSON round-trips f64 exactly)
        let perf = resp.get("eval").and_then(|e| e.get("perf")).expect("perf");
        let want = &expected[*i].perf;
        for (name, w) in [
            ("f_op_hz", want.f_op_hz),
            ("retention_s", want.retention_s),
            ("leakage_w", want.leakage_w),
            ("stored_one_v", want.stored_one_v),
        ] {
            let got = perf.get(name).and_then(Json::as_f64).expect(name);
            assert_eq!(got.to_bits(), w.to_bits(), "client {i} {name}");
        }
        assert_eq!(resp.get("eval").and_then(|e| e.get("quarantine")), Some(&Json::Null));
    }

    // session telemetry agrees: one union sweep, three pipeline misses,
    // three geometry compiles (all distinct structures, zero struct hits)
    let stats = session.stats();
    assert_eq!(stats.call_counts, expected_calls);
    assert_eq!(stats.cache_misses, configs.len());
    assert_eq!(stats.cache_hits, 0);
    assert_eq!(stats.structures, configs.len());
    assert_eq!(stats.struct_compiles, configs.len());
    assert_eq!(stats.struct_hits, 0);
}

/// The tentpole KPI at the socket: a VT-only sibling sweep
/// re-characterizes (the eval cache keys on the full config) but
/// compiles ZERO new structures, and an identical repeat request pays
/// nothing at all — `"struct_compiles"` makes both protocol-assertable
/// the way `"sweep_calls"` made execution counts assertable.
#[test]
fn repeated_and_vt_sibling_requests_pay_zero_struct_compiles() {
    let t = sg40();
    let session = Session::new(&t, SharedRuntime::native(), 0.0).unwrap();
    let socket = scratch("structkpi.sock");
    let base = [
        Config::new(16, 16, CellFlavor::GcSiSiNp),
        Config::new(32, 32, CellFlavor::GcSiSiNp),
    ];
    let sibling: Vec<Config> = base
        .iter()
        .map(|c| {
            let mut s = c.clone();
            s.write_vt = Some(0.5);
            s
        })
        .collect();
    let dse_line = |cfgs: &[Config]| {
        let objs: Vec<String> = cfgs.iter().map(|c| serve::config_json(c).dump()).collect();
        format!(r#"{{"cmd":"dse","configs":[{}]}}"#, objs.join(","))
    };

    let (cold, vt, repeat, stats) = with_server(&session, &socket, 10, || {
        let cold = parse_ok(&serve::client_request(&socket, &dse_line(&base)).unwrap());
        let vt = parse_ok(&serve::client_request(&socket, &dse_line(&sibling)).unwrap());
        let repeat = parse_ok(&serve::client_request(&socket, &dse_line(&base)).unwrap());
        let stats = parse_ok(&serve::client_request(&socket, r#"{"cmd":"stats"}"#).unwrap());
        (cold, vt, repeat, stats)
    });

    // cold: both geometries compiled, sweep executed
    assert_eq!(cold.get("struct_compiles").and_then(Json::as_usize), Some(2), "{cold:?}");
    assert!(!calls_of(&cold, "sweep_calls").is_empty());
    // VT siblings: the characterizer runs (new ConfigKeys, real
    // executions) but the geometry axis is free
    assert_eq!(vt.get("struct_compiles").and_then(Json::as_usize), Some(0), "{vt:?}");
    assert!(!calls_of(&vt, "sweep_calls").is_empty(), "siblings must re-characterize");
    // repeat: fully served from the eval cache — nothing runs at all
    assert_eq!(repeat.get("struct_compiles").and_then(Json::as_usize), Some(0), "{repeat:?}");
    assert!(calls_of(&repeat, "sweep_calls").is_empty(), "repeat must be a pure cache hit");
    // stats surface the cache shape: 2 structures, 2 compiles, and the
    // sibling sweep's 2 struct hits
    let compile = stats.get("compile").expect("compile stats");
    assert_eq!(compile.get("structures").and_then(Json::as_usize), Some(2));
    assert_eq!(compile.get("compiles").and_then(Json::as_usize), Some(2));
    assert_eq!(compile.get("hits").and_then(Json::as_usize), Some(2));
}

/// Bitwise pin of the refactor: `Session::evaluate` (no store) must
/// reproduce `dse::evaluate_all_batched_health` exactly, and the
/// session `char` body at resolution 0 must reproduce the historical
/// per-design `characterize::characterize` path exactly.
#[test]
fn session_paths_are_bitwise_identical_to_preservice_pipelines() {
    let t = sg40();
    let mut vt = Config::new(16, 16, CellFlavor::GcSiSiNp);
    vt.write_vt = Some(0.45);
    let configs = [
        Config::new(16, 16, CellFlavor::GcSiSiNp),
        Config::new(32, 32, CellFlavor::GcOsOs),
        vt.clone(),
        Config::new(16, 16, CellFlavor::GcSiSiNp), // repeat: cache path
    ];

    let rt_old = SharedRuntime::native();
    let (old, old_health) =
        dse::evaluate_all_batched_health(&t, &rt_old, &configs, 2, DEFAULT_WINDOW_RESOLUTION)
            .unwrap();
    assert!(old_health.is_clean());

    let session = Session::new(&t, SharedRuntime::native(), DEFAULT_WINDOW_RESOLUTION)
        .unwrap()
        .with_workers(2);
    let (new, new_health) = session.evaluate(&configs).unwrap();
    assert!(new_health.is_clean());
    assert_eq!(session.runtime().call_counts(), rt_old.call_counts(), "same execution census");
    assert_eq!(old.len(), new.len());
    for (a, b) in old.iter().zip(&new) {
        assert_eq!(a.config.key(), b.config.key());
        assert_eq!(a.area_um2.to_bits(), b.area_um2.to_bits());
        let pairs = [
            (a.perf.f_read_hz, b.perf.f_read_hz),
            (a.perf.f_write_hz, b.perf.f_write_hz),
            (a.perf.f_op_hz, b.perf.f_op_hz),
            (a.perf.bandwidth_bps, b.perf.bandwidth_bps),
            (a.perf.retention_s, b.perf.retention_s),
            (a.perf.leakage_w, b.perf.leakage_w),
            (a.perf.e_read_j, b.perf.e_read_j),
            (a.perf.t_decoder_s, b.perf.t_decoder_s),
            (a.perf.t_cell_read_s, b.perf.t_cell_read_s),
            (a.perf.stored_one_v, b.perf.stored_one_v),
        ];
        for (i, (x, y)) in pairs.iter().enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "field {i} of {:?}", a.config.key());
        }
        assert_eq!(a.perf.functional, b.perf.functional);
    }

    // char body: exact-window session == historical singleton path
    let cfg = Config::new(16, 16, CellFlavor::GcSiSiNn);
    let bank = compile(&t, &cfg).unwrap();
    let rt_single = SharedRuntime::native();
    let direct = rt_single.with(|b| characterize::characterize(&t, b, &bank)).unwrap();
    let char_session = Session::new(&t, SharedRuntime::native(), 0.0).unwrap();
    let via = char_session.characterize_config(&cfg).unwrap();
    assert_eq!(via.perf.f_op_hz.to_bits(), direct.f_op_hz.to_bits());
    assert_eq!(via.perf.retention_s.to_bits(), direct.retention_s.to_bits());
    assert_eq!(via.perf.stored_one_v.to_bits(), direct.stored_one_v.to_bits());
    assert_eq!(via.area_um2.to_bits(), bank.layout.total_area_um2().to_bits());
}

/// Restart KPI at the socket level: a second server process (fresh
/// session, fresh runtime) over the same store directory answers an
/// identical sweep purely from disk — zero characterization
/// executions — with a response identical to the cold run's.
#[test]
fn server_restart_serves_identical_sweep_from_disk() {
    let t = sg40();
    let dir = scratch("restart-store");
    let socket = scratch("restart.sock");
    let dse_line = format!(
        r#"{{"cmd":"dse","configs":[{},{}]}}"#,
        serve::config_json(&Config::new(16, 16, CellFlavor::GcSiSiNp)).dump(),
        serve::config_json(&Config::new(32, 32, CellFlavor::GcSiSiNp)).dump(),
    );

    // cold server: pays the pipeline, persists
    let s1 = Session::new(&t, SharedRuntime::native(), 0.0).unwrap().with_store(&dir).unwrap();
    let (cold, cold_stats) = with_server(&s1, &socket, 10, || {
        let r = parse_ok(&serve::client_request(&socket, &dse_line).unwrap());
        let st = parse_ok(&serve::client_request(&socket, r#"{"cmd":"stats"}"#).unwrap());
        (r, st)
    });
    assert!(
        calls_of(&cold_stats, "calls").values().sum::<u64>() > 0,
        "cold run must execute: {cold_stats:?}"
    );
    assert!(!calls_of(&cold, "sweep_calls").is_empty());

    // restarted server: new session + runtime, same store
    let s2 = Session::new(&t, SharedRuntime::native(), 0.0).unwrap().with_store(&dir).unwrap();
    let (warm, warm_stats) = with_server(&s2, &socket, 10, || {
        let r = parse_ok(&serve::client_request(&socket, &dse_line).unwrap());
        let st = parse_ok(&serve::client_request(&socket, r#"{"cmd":"stats"}"#).unwrap());
        (r, st)
    });
    assert_eq!(
        calls_of(&warm_stats, "calls").values().sum::<u64>(),
        0,
        "warm restart must pay zero characterization executions: {warm_stats:?}"
    );
    assert!(calls_of(&warm, "sweep_calls").is_empty(), "no executions in the warm sweep");
    // the disk tier satisfies the eval cache before compile-time work
    // is scheduled, so the warm sweep compiles zero structures too
    assert_eq!(warm.get("struct_compiles").and_then(Json::as_usize), Some(0));
    assert_eq!(warm_stats.get("cache_misses").and_then(Json::as_usize), Some(0));
    let store = warm_stats.get("store").expect("store stats");
    assert_eq!(store.get("hits").and_then(Json::as_usize), Some(2));
    assert_eq!(store.get("rejects").and_then(Json::as_usize), Some(0));
    // identical evaluations, field for field (finite values round-trip
    // decimal JSON exactly, so Json equality is bit equality here)
    assert_eq!(cold.get("evals"), warm.get("evals"));

    let _ = std::fs::remove_dir_all(&dir);
}

/// Protocol robustness: a garbage line gets an `"ok": false` response
/// carrying the parse context, and the SAME connection then serves a
/// valid request — one bad client line must never poison a session.
#[test]
fn malformed_lines_error_without_killing_the_connection() {
    let t = sg40();
    let session = Session::new(&t, SharedRuntime::native(), 0.0).unwrap();
    let socket = scratch("robust.sock");
    let (bad, unknown, stats) = with_server(&session, &socket, 10, || {
        let stream = std::os::unix::net::UnixStream::connect(&socket).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        let mut ask = |line: &str| {
            writer.write_all(line.as_bytes()).unwrap();
            writer.write_all(b"\n").unwrap();
            writer.flush().unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            resp.trim_end().to_string()
        };
        let bad = ask(r#"{"cmd": oops-not-json}"#);
        let unknown = ask(r#"{"cmd":"explode"}"#);
        let stats = ask(r#"{"cmd":"stats"}"#);
        (bad, unknown, stats)
    });
    let j = Json::parse(&bad).unwrap();
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
    let err = j.get("error").and_then(Json::as_str).unwrap();
    assert!(err.contains("oops"), "parse error must carry the offending input: {err}");
    let j = Json::parse(&unknown).unwrap();
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
    assert!(j.get("error").and_then(Json::as_str).unwrap().contains("unknown cmd"));
    parse_ok(&stats); // the connection survived both bad lines
}

/// The warm per-design flatten memo: repeat DRC of one design through
/// the session reuses its memo (same clean report, memo count stays
/// at one design), and the report matches a fresh hierarchical check.
#[test]
fn session_drc_memo_is_warm_and_correct() {
    let t = sg40();
    let session = Session::new(&t, SharedRuntime::native(), 0.0).unwrap();
    let cfg = Config::new(16, 16, CellFlavor::GcSiSiNp);
    let r1 = session.drc_check(&cfg).unwrap();
    let r2 = session.drc_check(&cfg).unwrap();
    assert_eq!(r1.violations.len(), r2.violations.len());
    assert_eq!(r1.rects_checked, r2.rects_checked);
    assert_eq!(session.stats().flatten_configs, 1);

    // the memo keys on the structure, so a VT-only sibling shares it
    // (and the structure itself is a cache hit, not a recompile)
    let mut sibling = cfg.clone();
    sibling.write_vt = Some(0.5);
    let r3 = session.drc_check(&sibling).unwrap();
    assert_eq!(r3.rects_checked, r1.rects_checked);
    assert_eq!(session.stats().flatten_configs, 1, "VT sibling must reuse the memo");
    assert_eq!(session.stats().struct_compiles, 1, "VT sibling must not recompile");

    let bank = compile(&t, &cfg).unwrap();
    let fresh = opengcram::drc::hier::check_hier(&t, &bank.library, "bank").unwrap();
    assert_eq!(fresh.violations.len(), r1.violations.len());
    assert_eq!(fresh.rects_checked, r1.rects_checked);
}
