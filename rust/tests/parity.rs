//! Backend-equivalence parity for the native execution backend.
//!
//! Three layers of pinning, none of which needs artifacts on disk:
//!
//! 1. **Batched == singleton** through the public engine entry points:
//!    a padded multi-point execution returns bit-identical results to
//!    running each point alone (batching, padding and thread chunking
//!    are invisible).
//! 2. **Engine == direct `sim::transient`**: for each transient op the
//!    test re-assembles the inputs independently — f32-rounded exactly
//!    as the tensor boundary rounds them — runs the raw solver, applies
//!    the `model.py` measurement block by hand, and demands bitwise
//!    equality with what the engine returned.  This is the same role
//!    the Python test suite plays against the XLA artifacts: an
//!    independent implementation agreeing to the last bit.
//! 3. **`characterize_all` == `characterize`** on the native backend
//!    for every cell flavor (including the analytical SRAM path), plus
//!    grouped-ceiling call-count KPIs against the backend's *real*
//!    per-artifact counters.
//!
//! The native backend has two execution modes and the pins are split
//! accordingly: layer 1 holds bitwise **within each mode** (default SoA
//! and `with_scalar_reference()`), layer 2 is pinned against the scalar
//! reference (whose per-row op order is exactly `sim::transient`'s),
//! and a fourth layer bounds SoA-vs-scalar drift to a documented
//! tolerance — the SoA path's polynomial `exp`/`ln1p` kernels agree
//! with libm to ~1e-15 relative, far below the f32 output quantization,
//! and retention's frozen post-crossing tail only moves `sn_final`
//! (never `t_retain`), which no downstream consumer reads.

use opengcram::compiler::{compile, CellFlavor, Config};
use opengcram::runtime::stimulus as st;
use opengcram::runtime::{engines, ExecBackend, NativeBackend, SharedRuntime};
use opengcram::tech::sg40;
use opengcram::{characterize, sim};

/// Round through the f32 tensor boundary (what every input value pays).
fn f32r(x: f64) -> f64 {
    x as f32 as f64
}

/// Round a waveform matrix through f32, mirroring `stimulus::flatten`
/// followed by the backend's widening.
fn roundtrip(w: &[Vec<f64>]) -> Vec<Vec<f64>> {
    w.iter().map(|r| r.iter().map(|&v| f32r(v)).collect()).collect()
}

fn write_points(t: &opengcram::tech::Tech) -> Vec<engines::WritePoint> {
    [(0.45, 1.1, true, 0.0), (0.55, 1.5, true, 0.0), (0.38, 1.1, false, 0.62)]
        .iter()
        .map(|&(vt, v_wwl, one, sn0)| engines::WritePoint {
            write_card: t.card("si_nmos").with_vt(vt),
            write_wl: 2.5,
            drv_p: (*t.card("si_pmos"), 8.0),
            drv_n: (*t.card("si_nmos"), 4.0),
            c_sn: 1.2e-15,
            c_wbl: 20e-15,
            c_wwl_sn: 0.15e-15,
            g_wbl_leak: 1e-9,
            vdd: 1.1,
            v_wwl,
            one,
            sn0,
        })
        .collect()
}

fn read_points(t: &opengcram::tech::Tech, pull_up: bool) -> Vec<engines::ReadPoint> {
    let card = if pull_up { *t.card("si_pmos_hvt") } else { *t.card("si_nmos") };
    [0.05, 0.62]
        .iter()
        .map(|&sn0| engines::ReadPoint {
            read_card: card,
            read_wl: 3.5,
            sn0,
            sn_unsel: if pull_up { 0.62 } else { 0.0 },
            rows: 32,
            c_sn: 1.2e-15,
            c_rbl: 20e-15,
            c_rwl_sn: 0.1e-15,
            g_rbl_leak: 1e-9,
            vdd: 1.1,
            pull_up,
        })
        .collect()
}

fn retention_points(t: &opengcram::tech::Tech) -> Vec<engines::RetentionPoint> {
    [("si_nmos", 1e-16, 0.3), ("os_nmos", 1e-17, 0.3), ("si_nmos", 1e-16, 0.0)]
        .iter()
        .map(|&(card, gl, vth)| engines::RetentionPoint {
            write_card: *t.card(card),
            write_wl: 2.5,
            c_sn: 1.2e-15,
            g_gate_leak: gl,
            i_disturb: 0.0,
            v0: 0.6,
            vth,
        })
        .collect()
}

#[test]
fn batched_execution_is_bitwise_equal_to_singletons() {
    // per-row work is independent of batch position, block composition
    // and thread chunking in BOTH execution modes
    for scalar_mode in [false, true] {
        batched_equals_singletons(scalar_mode);
    }
}

fn batched_equals_singletons(scalar_mode: bool) {
    let t = sg40();
    let b = if scalar_mode {
        NativeBackend::new().with_scalar_reference()
    } else {
        NativeBackend::new()
    };

    let wpts = write_points(&t);
    let window = 6e-9;
    let batched = engines::write_op(&b, &wpts, window).unwrap();
    for (pt, want) in wpts.iter().zip(&batched) {
        let single = engines::write_op(&b, std::slice::from_ref(pt), window).unwrap();
        assert_eq!(single[0].sn_final.to_bits(), want.sn_final.to_bits(), "write sn_final");
        assert_eq!(single[0].t_wr.to_bits(), want.t_wr.to_bits(), "write t_wr");
        assert_eq!(single[0].sn_peak.to_bits(), want.sn_peak.to_bits(), "write sn_peak");
    }

    for pull_up in [true, false] {
        let rpts = read_points(&t, pull_up);
        let batched = engines::read_op(&b, &rpts, 8e-9).unwrap();
        for (pt, want) in rpts.iter().zip(&batched) {
            let single = engines::read_op(&b, std::slice::from_ref(pt), 8e-9).unwrap();
            assert_eq!(single[0].t_rise.to_bits(), want.t_rise.to_bits(), "read t_rise");
            assert_eq!(single[0].t_fall.to_bits(), want.t_fall.to_bits(), "read t_fall");
            assert_eq!(single[0].rbl_final.to_bits(), want.rbl_final.to_bits(), "read rbl");
            assert_eq!(single[0].sn_final.to_bits(), want.sn_final.to_bits(), "read sn");
        }
    }

    let tpts = retention_points(&t);
    let batched = engines::retention(&b, &tpts).unwrap();
    for (pt, want) in tpts.iter().zip(&batched) {
        let single = engines::retention(&b, std::slice::from_ref(pt)).unwrap();
        assert_eq!(single[0].t_retain.to_bits(), want.t_retain.to_bits(), "retention t");
        assert_eq!(single[0].sn_final.to_bits(), want.sn_final.to_bits(), "retention sn");
    }
}

#[test]
fn native_retention_matches_direct_sim_transient() {
    // the scalar reference mode keeps sim::transient's exact per-row
    // op order, so this pin is bitwise
    let t = sg40();
    let b = NativeBackend::new().with_scalar_reference();
    let meta = b.manifest().get("retention").unwrap().clone();
    let pts = retention_points(&t);
    let got = engines::retention(&b, &pts).unwrap();

    // independent reconstruction: same column layout as circuits.py,
    // every input rounded through the f32 tensor boundary
    let tmpl = sim::retention_template();
    for (pt, got) in pts.iter().zip(&got) {
        let mut p = vec![0.0f64; tmpl.npar];
        for (k, v) in pt.write_card.to_row(pt.write_wl).iter().enumerate() {
            p[k] = *v as f64;
        }
        p[6] = f32r(pt.g_gate_leak);
        p[7] = f32r(pt.i_disturb);
        let dt: Vec<f64> = st::log_dt(meta.steps, 1e-12, 1.082).iter().map(|&d| f32r(d)).collect();
        let wave = st::zeros(meta.steps, tmpl.ns);
        let amp = [0.0, 0.0, 0.0, f32r(pt.vth)]; // [wwl, wbl, gnd, vth]
        let (times, trace) = sim::transient(
            &tmpl,
            sim::Integrator::ExpDecay,
            meta.k_substeps,
            &[f32r(pt.v0)],
            &amp,
            &p,
            &[f32r(1.0 / pt.c_sn)],
            &wave,
            &wave,
            &dt,
        );
        let sn: Vec<f64> = trace.iter().map(|r| r[0]).collect();
        let vhold = if f32r(pt.vth) > 0.0 { f32r(pt.vth) } else { 0.5 * f32r(pt.v0) };
        let want_t = sim::cross_time(&times, &sn, vhold, false).unwrap_or(meta.big_time);
        assert_eq!(got.t_retain.to_bits(), f32r(want_t).to_bits(), "t_retain diverged");
        assert_eq!(got.sn_final.to_bits(), f32r(*sn.last().unwrap()).to_bits(), "sn_final");
    }
}

#[test]
fn native_write_matches_direct_sim_transient() {
    let t = sg40();
    let b = NativeBackend::new().with_scalar_reference();
    let meta = b.manifest().get("write").unwrap().clone();
    let pts = write_points(&t);
    let window = 6e-9;
    let got = engines::write_op(&b, &pts, window).unwrap();

    let tmpl = sim::write_template();
    let steps = meta.steps;
    // the engine authors the waveform from the *unrounded* f64 grid,
    // then it crosses the tensor boundary; mirror both steps
    let dt64 = st::uniform_dt(steps, window / (steps as f64 * meta.k_substeps as f64));
    let wave_times = st::times_from_dt(&dt64, meta.k_substeps);
    let mut wave = st::zeros(steps, tmpl.ns);
    let mut dwave = st::zeros(steps, tmpl.ns);
    st::pulse(&mut wave, &mut dwave, &wave_times, 0, 0.05 * window, 0.75 * window, 0.05 * window);
    st::constant(&mut wave, 2, 1.0); // vdd
    st::constant(&mut wave, 1, 1.0); // dinb (amplitude carries the data)
    let wave = roundtrip(&wave);
    let dwave = roundtrip(&dwave);
    let dt: Vec<f64> = dt64.iter().map(|&d| f32r(d)).collect();

    for (pt, got) in pts.iter().zip(&got) {
        let mut p = vec![0.0f64; tmpl.npar];
        for (k, v) in pt.write_card.to_row(pt.write_wl).iter().enumerate() {
            p[k] = *v as f64;
        }
        for (k, v) in pt.drv_p.0.to_row(pt.drv_p.1).iter().enumerate() {
            p[6 + k] = *v as f64;
        }
        for (k, v) in pt.drv_n.0.to_row(pt.drv_n.1).iter().enumerate() {
            p[12 + k] = *v as f64;
        }
        p[18] = f32r(pt.c_wwl_sn);
        p[19] = f32r(pt.g_wbl_leak);
        let amp = [
            f32r(pt.v_wwl),
            if pt.one { 0.0 } else { f32r(pt.vdd) },
            f32r(pt.vdd),
            0.0,
        ];
        let v0 = [f32r(pt.sn0), 0.0];
        let cinv = [f32r(1.0 / pt.c_sn), f32r(1.0 / pt.c_wbl)];
        let (times, trace) = sim::transient(
            &tmpl,
            sim::Integrator::Heun,
            meta.k_substeps,
            &v0,
            &amp,
            &p,
            &cinv,
            &wave,
            &dwave,
            &dt,
        );
        let sn: Vec<f64> = trace.iter().map(|r| r[0]).collect();
        let sn_peak = sn.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let t_rise = sim::cross_time(&times, &sn, 0.9 * sn_peak, true).unwrap_or(meta.big_time);
        let t_fall =
            sim::cross_time(&times, &sn, 0.1 * v0[0].max(1e-3), false).unwrap_or(meta.big_time);
        let want_t_wr = if sn_peak <= v0[0] + 0.05 { t_fall } else { t_rise };
        assert_eq!(got.sn_final.to_bits(), f32r(*sn.last().unwrap()).to_bits(), "sn_final");
        assert_eq!(got.t_wr.to_bits(), f32r(want_t_wr).to_bits(), "t_wr");
        assert_eq!(got.sn_peak.to_bits(), f32r(sn_peak).to_bits(), "sn_peak");
    }
}

#[test]
fn native_read_matches_direct_sim_transient_both_polarities() {
    let t = sg40();
    let b = NativeBackend::new().with_scalar_reference();
    let meta = b.manifest().get("read").unwrap().clone();
    let window = 8e-9;
    let tmpl = sim::read_template();
    let steps = meta.steps;

    for pull_up in [true, false] {
        let pts = read_points(&t, pull_up);
        let got = engines::read_op(&b, &pts, window).unwrap();

        let dt64 = st::uniform_dt(steps, window / (steps as f64 * meta.k_substeps as f64));
        let wave_times = st::times_from_dt(&dt64, meta.k_substeps);
        let mut wave = st::zeros(steps, tmpl.ns);
        let mut dwave = st::zeros(steps, tmpl.ns);
        if pull_up {
            st::pulse(&mut wave, &mut dwave, &wave_times, 0, 0.05 * window, 10.0 * window, 0.03 * window);
        } else {
            st::fall(&mut wave, &mut dwave, &wave_times, 0, 0.05 * window, 0.03 * window);
            st::constant(&mut wave, 1, 1.0); // rwl_idle
        }
        st::constant(&mut wave, 2, 1.0); // snu
        let wave = roundtrip(&wave);
        let dwave = roundtrip(&dwave);
        let dt: Vec<f64> = dt64.iter().map(|&d| f32r(d)).collect();

        for (pt, got) in pts.iter().zip(&got) {
            let mut p = vec![0.0f64; tmpl.npar];
            for (k, v) in pt.read_card.to_row(pt.read_wl).iter().enumerate() {
                p[k] = *v as f64;
            }
            let leak_wl = pt.read_wl * (pt.rows - 1) as f64;
            for (k, v) in pt.read_card.to_row(leak_wl).iter().enumerate() {
                p[6 + k] = *v as f64;
            }
            p[12] = f32r(pt.c_rwl_sn);
            p[13] = f32r(pt.g_rbl_leak);
            let amp = [
                f32r(pt.vdd),
                if pull_up { 0.0 } else { f32r(pt.vdd) },
                f32r(pt.sn_unsel),
                0.0,
            ];
            let v0 = [f32r(pt.sn0), if pull_up { 0.0 } else { f32r(pt.vdd) }];
            let cinv = [f32r(1.0 / pt.c_sn), f32r(1.0 / pt.c_rbl)];
            let (times, trace) = sim::transient(
                &tmpl,
                sim::Integrator::Heun,
                meta.k_substeps,
                &v0,
                &amp,
                &p,
                &cinv,
                &wave,
                &dwave,
                &dt,
            );
            let rbl: Vec<f64> = trace.iter().map(|r| r[1]).collect();
            let sn: Vec<f64> = trace.iter().map(|r| r[0]).collect();
            let vref = 0.5 * amp[0].max(amp[1]);
            let want_rise = sim::cross_time(&times, &rbl, vref, true).unwrap_or(meta.big_time);
            let want_fall = sim::cross_time(&times, &rbl, vref, false).unwrap_or(meta.big_time);
            let what = format!("pull_up={pull_up} sn0={}", pt.sn0);
            assert_eq!(got.t_rise.to_bits(), f32r(want_rise).to_bits(), "{what}: t_rise");
            assert_eq!(got.t_fall.to_bits(), f32r(want_fall).to_bits(), "{what}: t_fall");
            assert_eq!(got.rbl_final.to_bits(), f32r(*rbl.last().unwrap()).to_bits(), "{what}: rbl");
            assert_eq!(got.sn_final.to_bits(), f32r(*sn.last().unwrap()).to_bits(), "{what}: sn");
        }
    }
}

/// SoA-vs-scalar drift bound: `rel` covers the polynomial-kernel
/// arithmetic difference (~1e-15, amplified only to the f32 output
/// quantization of ~6e-8 relative), `abs` floors it for near-zero
/// values.
fn assert_close(what: &str, soa: f64, scalar: f64, rel: f64, abs: f64) {
    assert!(
        (soa - scalar).abs() <= rel * scalar.abs() + abs,
        "{what}: soa {soa} vs scalar {scalar}"
    );
}

/// Crossing times additionally agree on the "never crossed" sentinel.
fn assert_time(what: &str, soa: f64, scalar: f64, big: f64) {
    if scalar == big {
        assert_eq!(soa, big, "{what}: sentinel diverged (soa {soa})");
    } else {
        assert_close(what, soa, scalar, 1e-4, 1e-12);
    }
}

#[test]
fn soa_matches_scalar_reference_within_tolerance() {
    // the documented cross-mode contract, over all three ops and both
    // read polarities on the same fixtures as the bitwise pins
    let t = sg40();
    let soa = NativeBackend::new();
    let scal = NativeBackend::new().with_scalar_reference();
    let big = f32r(soa.manifest().get("write").unwrap().big_time);

    let wpts = write_points(&t);
    let a = engines::write_op(&soa, &wpts, 6e-9).unwrap();
    let b = engines::write_op(&scal, &wpts, 6e-9).unwrap();
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_close(&format!("write {i}: sn_final"), x.sn_final, y.sn_final, 1e-4, 1e-6);
        assert_close(&format!("write {i}: sn_peak"), x.sn_peak, y.sn_peak, 1e-4, 1e-6);
        assert_time(&format!("write {i}: t_wr"), x.t_wr, y.t_wr, big);
    }

    for pull_up in [true, false] {
        let rpts = read_points(&t, pull_up);
        let a = engines::read_op(&soa, &rpts, 8e-9).unwrap();
        let b = engines::read_op(&scal, &rpts, 8e-9).unwrap();
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            let w = format!("read pull_up={pull_up} {i}");
            assert_time(&format!("{w}: t_rise"), x.t_rise, y.t_rise, big);
            assert_time(&format!("{w}: t_fall"), x.t_fall, y.t_fall, big);
            assert_close(&format!("{w}: rbl_final"), x.rbl_final, y.rbl_final, 1e-4, 1e-6);
            assert_close(&format!("{w}: sn_final"), x.sn_final, y.sn_final, 1e-4, 1e-6);
        }
    }

    let tpts = retention_points(&t);
    let a = engines::retention(&soa, &tpts).unwrap();
    let b = engines::retention(&scal, &tpts).unwrap();
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_time(&format!("retention {i}: t_retain"), x.t_retain, y.t_retain, big);
        // sn_final is deliberately NOT compared: the SoA path freezes a
        // retired retention row at its crossing instead of decaying the
        // tail further — the one documented cross-mode deviation, and
        // no downstream consumer reads retention sn_final
        assert!(x.sn_final.is_finite() && y.sn_final.is_finite(), "retention {i}");
    }
}

/// Field-by-field bitwise comparison (same contract as the integration
/// suite: batched-vs-single equivalence is exact, not approximate).
fn assert_perf_bits_eq(a: &characterize::BankPerf, b: &characterize::BankPerf, what: &str) {
    let fields = [
        ("f_read_hz", a.f_read_hz, b.f_read_hz),
        ("f_write_hz", a.f_write_hz, b.f_write_hz),
        ("f_op_hz", a.f_op_hz, b.f_op_hz),
        ("bandwidth_bps", a.bandwidth_bps, b.bandwidth_bps),
        ("retention_s", a.retention_s, b.retention_s),
        ("leakage_w", a.leakage_w, b.leakage_w),
        ("e_read_j", a.e_read_j, b.e_read_j),
        ("t_decoder_s", a.t_decoder_s, b.t_decoder_s),
        ("t_cell_read_s", a.t_cell_read_s, b.t_cell_read_s),
        ("stored_one_v", a.stored_one_v, b.stored_one_v),
    ];
    for (name, x, y) in fields {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: {name} diverged ({x} vs {y})");
    }
    assert_eq!(a.functional, b.functional, "{what}: functional verdict diverged");
}

#[test]
fn characterize_on_native_backend_matches_singleton_path_per_flavor() {
    let t = sg40();
    let rt = SharedRuntime::native();
    assert_eq!(rt.backend_name(), "native");
    for flavor in [
        CellFlavor::Sram6t,
        CellFlavor::GcSiSiNp,
        CellFlavor::GcSiSiNn,
        CellFlavor::GcOsOs,
    ] {
        let bank = compile(&t, &Config::new(32, 32, flavor)).unwrap();
        let single = rt.with(|b| characterize::characterize(&t, b, &bank)).unwrap();
        let batched =
            characterize::characterize_all(&t, &rt, std::slice::from_ref(&bank), 0.0).unwrap();
        assert_eq!(batched.len(), 1);
        assert_perf_bits_eq(&single, &batched[0], &format!("{flavor:?}"));
        // native physics must still discriminate on the paper's
        // workhorse flavor (the integration suite pins the same claim
        // end-to-end); every GC flavor gets a positive retention figure
        if flavor == CellFlavor::GcSiSiNp {
            assert!(single.functional, "{flavor:?} non-functional: {single:?}");
        }
        if flavor != CellFlavor::Sram6t {
            assert!(single.retention_s > 0.0, "{flavor:?}: {}", single.retention_s);
        }
    }
}

#[test]
fn native_counters_record_grouped_ceiling_executions() {
    // the KPI contract on the *real* native counters (not a counting
    // mock): a same-geometry write-VT axis shares one write window and
    // one pull-up read group, and retention always packs — so the whole
    // sweep pays exactly one execution per engine
    let t = sg40();
    let rt = SharedRuntime::native();
    let banks: Vec<_> = [None, Some(0.40), Some(0.45), Some(0.50), Some(0.55)]
        .iter()
        .map(|&vt| {
            let mut cfg = Config::new(32, 32, CellFlavor::GcSiSiNp);
            cfg.write_vt = vt;
            compile(&t, &cfg).unwrap()
        })
        .collect();
    let perfs = characterize::characterize_all(&t, &rt, &banks, 0.0).unwrap();
    assert_eq!(perfs.len(), banks.len());
    assert_eq!(rt.call_count("write"), 1, "VT axis shares one write window");
    assert_eq!(rt.call_count("read"), 1, "same-geometry NP reads share one group");
    assert_eq!(rt.call_count("retention"), 1, "retention always packs");
    let counts = rt.call_counts();
    assert_eq!(counts.get("write"), Some(&1));
    assert_eq!(counts.get("idvg"), Some(&0));
}
