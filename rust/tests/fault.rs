//! Chaos suite: the fault-isolating execution pipeline under
//! deterministic fault injection ([`opengcram::runtime::fault`]).
//!
//! The acceptance pin lives here: a seeded plan injecting one poisoned
//! output row and one transient executor error into a five-design
//! cross-flavor sweep must (a) quarantine exactly one design point with
//! a reason, (b) leave every healthy design's `BankPerf`
//! bitwise-identical to the fault-free run, and (c) with an empty plan
//! the wrapper must be execution-count-transparent — zero faults means
//! zero extra artifact executions.
//!
//! Every test is `fault_`-prefixed so CI's chaos step
//! (`cargo test --release fault`) selects the whole suite by filter.

use opengcram::compiler::{CellFlavor, CompileCache, Config};
use opengcram::runtime::engines;
use opengcram::runtime::fault::{FaultBackend, FaultPlan};
use opengcram::runtime::{FailoverBackend, NativeBackend, SharedRuntime};
use opengcram::tech::sg40;
use opengcram::{compose, dse, variation, workloads};

/// The cross-flavor sweep of the chaos parity pin: five transient GC
/// designs spanning all three gain-cell flavors and two geometries.
fn chaos_configs() -> Vec<Config> {
    vec![
        Config::new(32, 32, CellFlavor::GcSiSiNp),
        Config::new(32, 32, CellFlavor::GcOsOs),
        Config::new(32, 32, CellFlavor::GcSiSiNn),
        Config::new(16, 16, CellFlavor::GcSiSiNp),
        Config::new(16, 16, CellFlavor::GcOsOs),
    ]
}

fn perf_bits_eq(a: &opengcram::characterize::BankPerf, b: &opengcram::characterize::BankPerf, what: &str) {
    let fields = [
        ("f_read_hz", a.f_read_hz, b.f_read_hz),
        ("f_write_hz", a.f_write_hz, b.f_write_hz),
        ("f_op_hz", a.f_op_hz, b.f_op_hz),
        ("bandwidth_bps", a.bandwidth_bps, b.bandwidth_bps),
        ("retention_s", a.retention_s, b.retention_s),
        ("leakage_w", a.leakage_w, b.leakage_w),
        ("e_read_j", a.e_read_j, b.e_read_j),
        ("t_decoder_s", a.t_decoder_s, b.t_decoder_s),
        ("t_cell_read_s", a.t_cell_read_s, b.t_cell_read_s),
        ("stored_one_v", a.stored_one_v, b.stored_one_v),
    ];
    for (name, x, y) in fields {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: {name} diverged ({x} vs {y})");
    }
    assert_eq!(a.functional, b.functional, "{what}: functional verdict diverged");
}

#[test]
fn fault_chaos_parity_pin() {
    // the PR's acceptance criterion, end to end over the real pipeline
    let t = sg40();
    let cfgs = chaos_configs();
    let workers = 2;

    // fault-free baseline on a private native runtime
    let base_rt = SharedRuntime::native();
    let (base, base_health) =
        dse::evaluate_all_batched_health(&t, &base_rt, &cfgs, workers, 0.0).unwrap();
    assert!(base_health.is_clean(), "baseline not clean: {}", base_health.summary());
    assert!(base.iter().all(|e| e.quarantine.is_none()));

    // chaos run: NaN-poison row 0 of the first write execution (a solver
    // blowup confined to one design point) plus a transient executor
    // error on the first retention execution (healed by retry)
    let plan = FaultPlan::new().poison_row("write", 1, 0).error_on("retention", 1);
    let rt = SharedRuntime::native().with_faults(plan);
    assert_eq!(rt.backend_name(), "fault");
    let (evals, health) = dse::evaluate_all_batched_health(&t, &rt, &cfgs, workers, 0.0).unwrap();
    assert_eq!(evals.len(), cfgs.len());

    // (a) exactly one quarantined point, with stage and reason
    assert_eq!(health.quarantined.len(), 1, "health: {}", health.summary());
    let q = &health.quarantined[0];
    assert_eq!(q.stage, "write");
    assert!(q.reason.contains("non-finite write output"), "{}", q.reason);
    assert!(!q.design.is_empty());
    let quarantined: Vec<usize> =
        (0..evals.len()).filter(|&i| evals[i].quarantine.is_some()).collect();
    assert_eq!(quarantined, vec![q.index], "health report and evals disagree");
    let bad = &evals[q.index];
    assert!(bad.quarantine.as_deref().unwrap().contains("write"));
    assert!(!bad.perf.functional);

    // quarantined points are infeasible-with-reason in the shmoo
    let d = workloads::profile(&workloads::TASKS[0], workloads::CacheLevel::L1, &workloads::GT520M);
    let v = dse::shmoo_verdict(bad, &d);
    assert_eq!(v.glyph(), 'q');
    assert!(!v.pass());

    // (b) healthy designs are bitwise identical to the fault-free run
    for (i, (e, b)) in evals.iter().zip(&base).enumerate() {
        assert_eq!(e.config.key(), b.config.key(), "sweep order diverged");
        if i != q.index {
            assert!(e.quarantine.is_none());
            perf_bits_eq(&e.perf, &b.perf, &format!("design {i} {:?}", e.config));
        }
    }

    // the transient retention error healed through retry, not bisection
    assert!(health.retries >= 1, "transient error should cost a retry: {}", health.summary());
    assert_eq!(health.bisect_execs, 0, "no Err-batch should have needed bisection");
    assert_eq!(health.failovers, 0);
    // the faulted retention attempt never reached the inner backend, so
    // real retention executions match the baseline exactly
    assert_eq!(rt.call_count("retention"), base_rt.call_count("retention"));
    assert_eq!(rt.call_count("write"), base_rt.call_count("write"));
    // quarantining can only ever shrink downstream batches
    assert!(rt.call_count("read") <= base_rt.call_count("read"));
}

#[test]
fn fault_empty_plan_is_execution_count_transparent() {
    // (c) zero faults => zero extra executions, identical results
    let t = sg40();
    let cfgs = chaos_configs();
    let base_rt = SharedRuntime::native();
    let (base, _) = dse::evaluate_all_batched_health(&t, &base_rt, &cfgs, 2, 0.0).unwrap();
    let rt = SharedRuntime::native().with_faults(FaultPlan::new());
    let (evals, health) = dse::evaluate_all_batched_health(&t, &rt, &cfgs, 2, 0.0).unwrap();
    assert!(health.is_clean(), "{}", health.summary());
    assert_eq!(
        rt.call_counts(),
        base_rt.call_counts(),
        "an empty fault plan must not change the artifact call census"
    );
    for (e, b) in evals.iter().zip(&base) {
        assert!(e.quarantine.is_none());
        perf_bits_eq(&e.perf, &b.perf, &format!("{:?}", e.config));
    }
}

#[test]
fn fault_degenerate_input_quarantines_its_row_only() {
    // a non-physical design point (c_sn <= 0) is rejected per row with
    // a reason; healthy co-batched rows still resolve
    let t = sg40();
    let mk = |c_sn: f64| engines::WritePoint {
        write_card: *t.card("si_nmos"),
        write_wl: 2.5,
        drv_p: (*t.card("si_pmos"), 8.0),
        drv_n: (*t.card("si_nmos"), 4.0),
        c_sn,
        c_wbl: 20e-15,
        c_wwl_sn: 0.15e-15,
        g_wbl_leak: 1e-9,
        vdd: 1.1,
        v_wwl: 1.5,
        one: true,
        sn0: 0.0,
    };
    let rt = SharedRuntime::native();
    let pts = [mk(1.2e-15), mk(0.0), mk(-1.0e-15)];
    let rows = rt.with(|r| engines::write_rows(r, &pts, 6e-9)).unwrap();
    assert_eq!(rows.len(), 3);
    let good = rows[0].as_ref().expect("healthy row must survive its neighbors");
    assert!(good.sn_final.is_finite() && good.t_wr.is_finite());
    for bad in [&rows[1], &rows[2]] {
        let f = bad.as_ref().expect_err("c_sn <= 0 must be quarantined");
        assert!(f.reason.contains("degenerate write input"), "{}", f.reason);
        assert!(f.reason.contains("c_sn"), "{}", f.reason);
    }
    // the strict all-or-nothing wrapper names the offending point
    let err = rt.with(|r| engines::write_op(r, &pts, 6e-9)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("write point 1"), "{msg}");
    assert!(msg.contains("c_sn"), "{msg}");
}

#[test]
fn fault_poison_row_on_soa_path_quarantines_that_row_only() {
    // PoisonRow semantics survive the SoA rewrite: poisoning one output
    // row of a retention execution quarantines exactly that design
    // point, and its co-batched (same SoA block) neighbors stay bitwise
    // identical to the fault-free run
    let t = sg40();
    let mk = |gl: f64| engines::RetentionPoint {
        write_card: *t.card("si_nmos"),
        write_wl: 2.5,
        c_sn: 1.2e-15,
        g_gate_leak: gl,
        i_disturb: 0.0,
        v0: 0.6,
        vth: 0.3,
    };
    let pts = [mk(1e-16), mk(2e-16), mk(3e-16)];
    let clean = NativeBackend::new();
    let want = engines::retention_rows(&clean, &pts).unwrap();
    let fb = FaultBackend::new(
        Box::new(NativeBackend::new()),
        FaultPlan::new().poison_row("retention", 1, 1),
    );
    let rows = engines::retention_rows(&fb, &pts).unwrap();
    assert_eq!(rows.len(), 3);
    let bad = rows[1].as_ref().expect_err("poisoned row must quarantine");
    assert!(bad.reason.contains("non-finite retention output"), "{}", bad.reason);
    for i in [0, 2] {
        let a = rows[i].as_ref().expect("healthy neighbor row must survive");
        let b = want[i].as_ref().unwrap();
        assert_eq!(a.t_retain.to_bits(), b.t_retain.to_bits(), "row {i}: t_retain");
        assert_eq!(a.sn_final.to_bits(), b.sn_final.to_bits(), "row {i}: sn_final");
    }
}

#[test]
fn fault_failover_serves_failed_request_from_native_fallback() {
    // a terminal primary failure trips the breaker: the very request
    // that failed is served from the native fallback, and so is all
    // remaining work — with exactly one logged failover transition
    let t = sg40();
    let pts = [engines::RetentionPoint {
        write_card: *t.card("si_nmos"),
        write_wl: 2.5,
        c_sn: 1.2e-15,
        g_gate_leak: 1e-16,
        i_disturb: 0.0,
        v0: 0.6,
        vth: 0.3,
    }];
    let plain = NativeBackend::new();
    let want = engines::retention(&plain, &pts).unwrap();
    // primary = native wrapped in a hard error on its first execution
    let primary =
        FaultBackend::new(Box::new(NativeBackend::new()), FaultPlan::new().error_on("retention", 1));
    let fo = FailoverBackend::new(Box::new(primary));
    assert!(!fo.tripped());
    let got = engines::retention(&fo, &pts).unwrap();
    assert!(fo.tripped(), "primary error must trip the breaker");
    assert_eq!(fo.failovers(), 1);
    assert_eq!(got[0].t_retain.to_bits(), want[0].t_retain.to_bits());
    assert_eq!(got[0].sn_final.to_bits(), want[0].sn_final.to_bits());
    // later work stays on the fallback without re-tripping
    let again = engines::retention(&fo, &pts).unwrap();
    assert_eq!(again[0].t_retain.to_bits(), want[0].t_retain.to_bits());
    assert_eq!(fo.failovers(), 1);
}

#[test]
fn fault_poisoned_variant_lowers_yield_by_exactly_one_over_k() {
    // Monte-Carlo chaos pin: poison one sampled variant inside the
    // variation mega-batch.  A zero-sigma model keeps every variant
    // bitwise-nominal (so the baseline is fully functional by the
    // parity suite's guarantee), and 16/32-row designs sit on the
    // transient window floor clamps, so ALL write jobs share one
    // group: the first write execution's rows follow plan order
    // [d0 nom, d0 s0..s3, d1 nom, d1 s0..s3], making row 2 design 0's
    // sample 1, deterministically.
    let t = sg40();
    let cfgs = vec![
        Config::new(32, 32, CellFlavor::GcSiSiNp),
        Config::new(16, 16, CellFlavor::GcSiSiNp),
    ];
    let k = 4;
    let model = variation::VariationModel::zero(k, 0xFA11, t.vdd);

    let base_rt = SharedRuntime::native();
    let (base, bh) =
        variation::yield_sweep_health(&t, &base_rt, &cfgs, &model, 2, 0.0, &CompileCache::new())
            .unwrap();
    assert!(bh.is_clean(), "{}", bh.summary());
    assert_eq!(base[0].stats.functional.passed, k, "baseline must be fully functional");

    let rt = SharedRuntime::native().with_faults(FaultPlan::new().poison_row("write", 1, 2));
    let (dys, health) =
        variation::yield_sweep_health(&t, &rt, &cfgs, &model, 2, 0.0, &CompileCache::new()).unwrap();

    // exactly one quarantined variant, named and reasoned in RunHealth
    assert_eq!(health.quarantined.len(), 1, "{}", health.summary());
    let q = &health.quarantined[0];
    assert_eq!(q.index, 2, "plan-order index of design 0, sample 1");
    assert!(q.design.ends_with("[s1]"), "{}", q.design);
    assert_eq!(q.stage, "write");
    assert!(q.reason.contains("non-finite write output"), "{}", q.reason);

    // ... and mirrored into the design's own yield stats with a reason
    assert_eq!(dys[0].stats.quarantined.len(), 1);
    let (si, reason) = &dys[0].stats.quarantined[0];
    assert_eq!(*si, 1, "sample index");
    assert!(reason.contains("non-finite write output"), "{reason}");
    assert!(dys[1].stats.quarantined.is_empty());

    // functional yield drops by exactly 1/K for the poisoned design
    let (b0, a0) = (&base[0].stats.functional, &dys[0].stats.functional);
    assert_eq!(a0.samples, b0.samples);
    assert_eq!(b0.passed - a0.passed, 1, "exactly one sample lost");
    assert!((b0.p - a0.p - 1.0 / k as f64).abs() < 1e-12, "{} -> {}", b0.p, a0.p);
    // ... and by exactly one pass in every demand-joint yield the
    // poisoned sample used to satisfy
    for d in workloads::all_demands(&workloads::GT520M) {
        let lost = dse::shmoo_verdict(&base[0].samples[1], &d).pass() as usize;
        assert_eq!(
            base[0].yield_for(&d).passed - dys[0].yield_for(&d).passed,
            lost,
            "{} {:?}",
            d.task.name,
            d.level
        );
    }
    assert_eq!(dys[1].stats.functional.passed, base[1].stats.functional.passed);

    // sibling variants and the other design stay bitwise identical
    for (di, (dy, b)) in dys.iter().zip(&base).enumerate() {
        perf_bits_eq(&dy.nominal.perf, &b.nominal.perf, &format!("design {di} [nom]"));
        for (i, (s, bs)) in dy.samples.iter().zip(&b.samples).enumerate() {
            if di == 0 && i == 1 {
                assert!(s.quarantine.is_some(), "poisoned variant must be quarantined");
                assert!(!s.perf.functional);
                continue;
            }
            assert!(s.quarantine.is_none(), "design {di} [s{i}]");
            perf_bits_eq(&s.perf, &bs.perf, &format!("design {di} [s{i}]"));
        }
    }
    // poisoning an output row never changes the write call census, and
    // quarantining can only shrink downstream batches
    assert_eq!(rt.call_count("write"), base_rt.call_count("write"));
    assert!(rt.call_count("read") <= base_rt.call_count("read"));
}

#[test]
fn fault_compose_treats_quarantined_points_as_infeasible() {
    // the composition engine rides the same health-threaded sweep: a
    // poisoned row quarantines one grid point, the report says so, and
    // the selection simply routes around it
    let t = sg40();
    let rt = SharedRuntime::native().with_faults(FaultPlan::new().poison_row("write", 1, 0));
    let mut spec = compose::ComposeSpec::new(&workloads::GT520M);
    spec.window_resolution = 0.0;
    let c = compose::compose(&t, &rt, &spec).unwrap();
    assert_eq!(c.health.quarantined.len(), 1, "{}", c.health.summary());
    assert_eq!(c.health.quarantined[0].stage, "write");
    assert!(!c.health.is_clean());
    // demands are still served by healthy grid points
    assert!(c.per_demand.iter().any(|s| s.choice.is_some()));
    for s in c.per_demand.iter().chain(c.per_level.iter()) {
        if let Some(ch) = &s.choice {
            assert!(ch.eval.quarantine.is_none(), "selected a quarantined design");
            assert!(ch.eval.perf.functional);
        }
    }
}
