//! End-to-end integration: AOT artifacts -> PJRT runtime -> compiler ->
//! characterization, plus cross-language model parity and full-flow
//! (netlist + layout + DRC + LVS + GDS) checks.
//!
//! Requires `make artifacts` (artifacts/ is gitignored).

use opengcram::compiler::{compile, CellFlavor, Config};
use opengcram::runtime::{engines, Runtime, SharedRuntime};
use opengcram::tech::sg40;
use opengcram::{characterize, dse, lvs, sim, workloads};
use std::path::PathBuf;
use std::sync::OnceLock;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn shared() -> &'static SharedRuntime {
    static RT: OnceLock<SharedRuntime> = OnceLock::new();
    RT.get_or_init(|| SharedRuntime::load(&artifacts_dir()).expect("run `make artifacts` first"))
}

/// Run a closure against the shared runtime (serialized).
fn with_rt<R>(f: impl FnOnce(&Runtime) -> R) -> R {
    shared().with(f)
}

#[test]
fn runtime_loads_and_reports_platform() {
    with_rt(|rt| {
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    });
}

#[test]
fn idvg_artifact_matches_native_ekv_model() {
    // cross-language parity: the XLA-compiled device model must agree
    // with the independent rust implementation to float32 accuracy
    let t = sg40();
    let cards = vec![
        (*t.card("si_nmos"), 2.0),
        (*t.card("si_pmos"), 2.0),
        (*t.card("os_nmos"), 1.5),
    ];
    let (vg, rows) = with_rt(|rt| engines::idvg(rt, &cards, -0.2, 1.2, 1.1)).unwrap();
    for ((card, wl), row) in cards.iter().zip(&rows) {
        for (x, got) in vg.iter().zip(row) {
            let want = sim::mos_ids(
                1.1 * card.sign(),
                *x,
                0.0,
                card.kp,
                card.vt,
                card.n,
                card.lam,
                *wl,
                card.sign(),
            );
            let tol = 1e-4 * want.abs().max(1e-15);
            assert!(
                (got - want).abs() < tol,
                "card {:?} vg={x}: xla {got} vs rust {want}",
                card.kind
            );
        }
    }
}

#[test]
fn retention_artifact_reproduces_fig8_ranges() {
    let t = sg40();
    let mk = |card: &str, vth: f64| engines::RetentionPoint {
        write_card: *t.card(card),
        write_wl: 2.5,
        c_sn: 1.2e-15,
        g_gate_leak: if card.starts_with("os") { 1e-17 } else { 1e-16 },
        i_disturb: 0.0,
        v0: 0.6,
        vth,
    };
    let res = with_rt(|rt| {
        engines::retention(rt, &[mk("si_nmos", 0.3), mk("os_nmos", 0.3), mk("os_nmos_hvt", 0.3)])
    })
    .unwrap();
    let (si, os, os_hvt) = (res[0].t_retain, res[1].t_retain, res[2].t_retain);
    assert!(si > 1e-6 && si < 1e-3, "Si-Si ~ us (Fig. 8b): {si}");
    assert!(os > 1e-3 && os < 10.0, "OS-OS ~ ms (Fig. 8e): {os}");
    assert!(os_hvt > 10.0, "engineered OS > 10 s (Fig. 8e): {os_hvt}");
}

#[test]
fn retention_increases_monotonically_with_write_vt() {
    // Fig. 8c: VT modulation of the write transistor
    let t = sg40();
    let pts: Vec<_> = [0.35, 0.45, 0.55, 0.65]
        .iter()
        .map(|&vt| engines::RetentionPoint {
            write_card: t.card("si_nmos").with_vt(vt),
            write_wl: 2.5,
            c_sn: 1.2e-15,
            g_gate_leak: 1e-16,
            i_disturb: 0.0,
            v0: 0.6,
            vth: 0.3,
        })
        .collect();
    let res = with_rt(|rt| engines::retention(rt, &pts)).unwrap();
    for w in res.windows(2) {
        assert!(w[1].t_retain > w[0].t_retain);
    }
}

#[test]
fn wwlls_boosts_stored_level_and_write_speed() {
    // Fig. 7a/8c: the WWL level shifter raises the stored '1'
    let t = sg40();
    let mk = |v_wwl: f64| engines::WritePoint {
        write_card: *t.card("si_nmos"),
        write_wl: 2.5,
        drv_p: (*t.card("si_pmos"), 8.0),
        drv_n: (*t.card("si_nmos"), 4.0),
        c_sn: 1.2e-15,
        c_wbl: 20e-15,
        c_wwl_sn: 0.15e-15,
        g_wbl_leak: 1e-9,
        vdd: 1.1,
        v_wwl,
        one: true,
        sn0: 0.0,
    };
    let res = with_rt(|rt| engines::write_op(rt, &[mk(1.1), mk(1.5)], 6e-9)).unwrap();
    assert!(res[1].sn_final > res[0].sn_final + 0.2, "{res:?}");
    assert!(res[1].t_wr <= res[0].t_wr * 1.05);
}

#[test]
fn full_characterization_of_a_1kb_gc_bank() {
    let t = sg40();
    let bank = compile(&t, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap();
    let perf = with_rt(|rt| characterize::characterize(&t, rt, &bank)).unwrap();
    assert!(perf.functional, "1 Kb GC bank must resolve: {perf:?}");
    assert!(perf.f_op_hz > 5e7 && perf.f_op_hz < 5e9, "{}", perf.f_op_hz);
    assert!(perf.retention_s > 1e-6 && perf.retention_s < 1e-2);
    assert!(perf.bandwidth_bps > perf.f_op_hz * 32.0);
}

#[test]
fn analytical_tracks_transient_within_bounds() {
    // the GEMTOO-style claim: analytical deviates but stays in the
    // same ballpark (paper: up to 15 % for GEMTOO; our stand-in stays
    // within a small constant factor -- the ablation bench reports the
    // actual deviation per size)
    let t = sg40();
    let bank = compile(&t, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap();
    let a = characterize::analytical(&t, &bank);
    let c = with_rt(|rt| characterize::characterize(&t, rt, &bank)).unwrap();
    let ratio = a.f_op_hz / c.f_op_hz;
    assert!(ratio > 0.2 && ratio < 5.0, "analytical/transient = {ratio}");
}

#[test]
fn shmoo_has_passes_and_failures() {
    // Fig. 10 structure: small banks serve most L1 demands; H100 L2
    // demands mostly exceed a single bank
    let t = sg40();
    let mut pass_l1 = 0;
    let mut fail_l2 = 0;
    let cfg = Config::new(32, 32, CellFlavor::GcSiSiNp);
    let bank = compile(&t, &cfg).unwrap();
    let perf = with_rt(|rt| characterize::characterize(&t, rt, &bank)).unwrap();
    let e = dse::Evaluated { config: cfg, perf, area_um2: bank.layout.total_area_um2() };
    for task in &workloads::TASKS {
        let l1 = workloads::profile(task, workloads::CacheLevel::L1, &workloads::GT520M);
        let l2 = workloads::profile(task, workloads::CacheLevel::L2, &workloads::H100);
        if dse::shmoo_verdict(&e, &l1).pass() {
            pass_l1 += 1;
        }
        if !dse::shmoo_verdict(&e, &l2).pass() {
            fail_l2 += 1;
        }
    }
    assert!(pass_l1 >= 4, "most GT520M L1 demands should pass: {pass_l1}");
    assert!(fail_l2 >= 4, "most H100 L2 demands need multibank: {fail_l2}");
}

#[test]
fn bank_layout_exports_gds_and_passes_drc_lvs_at_small_size() {
    let t = sg40();
    let bank = compile(&t, &Config::new(8, 8, CellFlavor::GcSiSiNp)).unwrap();
    // GDS round-trip
    let bytes = opengcram::layout::gds::write_bytes(&bank.library, &t, "bank");
    let summary = opengcram::layout::gds::read_summary(&bytes).unwrap();
    assert!(summary.structures.iter().any(|s| s == "bank"));
    assert!(summary.boundaries.len() > 100);
    // DRC on the flattened array (the generated tile)
    let rects = bank.library.flatten("bitcell_array").unwrap();
    let rep = opengcram::drc::check(&t, &rects);
    assert!(rep.clean(), "{} violations; first {}", rep.violations.len(), rep.violations[0]);
    // LVS array vs schematic
    let arr_pins = bank.library.get("bitcell_array").unwrap().pins.clone();
    let _ = arr_pins; // array pins propagate via bitcell abutment
    let mut nl = bank.netlist.clone();
    nl.top = "bitcell_array".into();
    let flat = nl.flatten().unwrap();
    assert_eq!(flat.mos_count(), 8 * 8 * 2);
    // extraction-level check: device count matches schematic
    let (rects, pins) = bank.library.flatten_with_pins("bitcell_array").unwrap();
    let ext = lvs::extract(&t, &rects, &pins, "bitcell_array").unwrap();
    assert_eq!(ext.circuit.mos_count(), flat.mos_count());
}

#[test]
fn coordinator_batches_retention_jobs_over_the_runtime() {
    use opengcram::coordinator::{BatchExec, Coordinator};
    struct RetExec {
        rt: &'static SharedRuntime,
        cap: usize,
    }
    impl BatchExec<engines::RetentionPoint, engines::RetentionResult> for RetExec {
        fn run(&mut self, jobs: &[engines::RetentionPoint]) -> opengcram::Result<Vec<engines::RetentionResult>> {
            self.rt.with(|rt| engines::retention(rt, jobs))
        }
        fn max_batch(&self) -> usize {
            self.cap
        }
    }
    let cap = with_rt(|rt| rt.manifest.get("retention").unwrap().batch);
    let t = sg40();
    let c = Coordinator::spawn(RetExec { rt: shared(), cap });
    let jobs: Vec<_> = (0..20)
        .map(|i| engines::RetentionPoint {
            write_card: t.card("si_nmos").with_vt(0.35 + 0.02 * i as f64),
            write_wl: 2.5,
            c_sn: 1.2e-15,
            g_gate_leak: 1e-16,
            i_disturb: 0.0,
            v0: 0.6,
            vth: 0.3,
        })
        .collect();
    let res = c.run_all(jobs).unwrap();
    assert_eq!(res.len(), 20);
    for w in res.windows(2) {
        assert!(w[1].t_retain >= w[0].t_retain * 0.99);
    }
}
