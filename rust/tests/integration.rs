//! End-to-end integration: execution backend -> compiler ->
//! characterization, plus cross-language model parity and full-flow
//! (netlist + layout + DRC + LVS + GDS) checks.
//!
//! Runs against whichever backend `SharedRuntime::auto` resolves: the
//! PJRT artifacts when `make artifacts` has been run, the native
//! in-process solver otherwise — so the whole suite passes on a clean
//! checkout (backend-equivalence itself is pinned by `tests/parity.rs`).

use opengcram::compiler::{compile, CellFlavor, Config};
use opengcram::runtime::{engines, ExecBackend, SharedRuntime};
use opengcram::tech::sg40;
use opengcram::{characterize, compose, dse, lvs, sim, workloads};
use std::path::PathBuf;
use std::sync::OnceLock;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn shared() -> &'static SharedRuntime {
    static RT: OnceLock<SharedRuntime> = OnceLock::new();
    RT.get_or_init(|| SharedRuntime::auto(&artifacts_dir()))
}

/// A private runtime of the same backend kind as [`shared`] (the
/// call-count-delta tests must not see executions from concurrently
/// running tests, and bitwise comparisons need like-for-like backends).
fn private_rt() -> SharedRuntime {
    SharedRuntime::auto(&artifacts_dir())
}

/// Run a closure against the shared runtime.
fn with_rt<R>(f: impl FnOnce(&dyn ExecBackend) -> R) -> R {
    shared().with(f)
}

#[test]
fn runtime_loads_and_reports_platform() {
    with_rt(|rt| {
        let p = rt.platform().to_lowercase();
        assert!(p.contains("cpu") || p.contains("native"), "unexpected platform {p}");
    });
}

#[test]
fn idvg_artifact_matches_native_ekv_model() {
    // cross-language parity: the XLA-compiled device model must agree
    // with the independent rust implementation to float32 accuracy
    let t = sg40();
    let cards = vec![
        (*t.card("si_nmos"), 2.0),
        (*t.card("si_pmos"), 2.0),
        (*t.card("os_nmos"), 1.5),
    ];
    let (vg, rows) = with_rt(|rt| engines::idvg(rt, &cards, -0.2, 1.2, 1.1)).unwrap();
    for ((card, wl), row) in cards.iter().zip(&rows) {
        for (x, got) in vg.iter().zip(row) {
            let want = sim::mos_ids(
                1.1 * card.sign(),
                *x,
                0.0,
                card.kp,
                card.vt,
                card.n,
                card.lam,
                *wl,
                card.sign(),
            );
            let tol = 1e-4 * want.abs().max(1e-15);
            assert!(
                (got - want).abs() < tol,
                "card {:?} vg={x}: xla {got} vs rust {want}",
                card.kind
            );
        }
    }
}

#[test]
fn retention_artifact_reproduces_fig8_ranges() {
    let t = sg40();
    let mk = |card: &str, vth: f64| engines::RetentionPoint {
        write_card: *t.card(card),
        write_wl: 2.5,
        c_sn: 1.2e-15,
        g_gate_leak: if card.starts_with("os") { 1e-17 } else { 1e-16 },
        i_disturb: 0.0,
        v0: 0.6,
        vth,
    };
    let res = with_rt(|rt| {
        engines::retention(rt, &[mk("si_nmos", 0.3), mk("os_nmos", 0.3), mk("os_nmos_hvt", 0.3)])
    })
    .unwrap();
    let (si, os, os_hvt) = (res[0].t_retain, res[1].t_retain, res[2].t_retain);
    assert!(si > 1e-6 && si < 1e-3, "Si-Si ~ us (Fig. 8b): {si}");
    assert!(os > 1e-3 && os < 10.0, "OS-OS ~ ms (Fig. 8e): {os}");
    assert!(os_hvt > 10.0, "engineered OS > 10 s (Fig. 8e): {os_hvt}");
}

#[test]
fn retention_increases_monotonically_with_write_vt() {
    // Fig. 8c: VT modulation of the write transistor
    let t = sg40();
    let pts: Vec<_> = [0.35, 0.45, 0.55, 0.65]
        .iter()
        .map(|&vt| engines::RetentionPoint {
            write_card: t.card("si_nmos").with_vt(vt),
            write_wl: 2.5,
            c_sn: 1.2e-15,
            g_gate_leak: 1e-16,
            i_disturb: 0.0,
            v0: 0.6,
            vth: 0.3,
        })
        .collect();
    let res = with_rt(|rt| engines::retention(rt, &pts)).unwrap();
    for w in res.windows(2) {
        assert!(w[1].t_retain > w[0].t_retain);
    }
}

#[test]
fn wwlls_boosts_stored_level_and_write_speed() {
    // Fig. 7a/8c: the WWL level shifter raises the stored '1'
    let t = sg40();
    let mk = |v_wwl: f64| engines::WritePoint {
        write_card: *t.card("si_nmos"),
        write_wl: 2.5,
        drv_p: (*t.card("si_pmos"), 8.0),
        drv_n: (*t.card("si_nmos"), 4.0),
        c_sn: 1.2e-15,
        c_wbl: 20e-15,
        c_wwl_sn: 0.15e-15,
        g_wbl_leak: 1e-9,
        vdd: 1.1,
        v_wwl,
        one: true,
        sn0: 0.0,
    };
    let res = with_rt(|rt| engines::write_op(rt, &[mk(1.1), mk(1.5)], 6e-9)).unwrap();
    assert!(res[1].sn_final > res[0].sn_final + 0.2, "{res:?}");
    assert!(res[1].t_wr <= res[0].t_wr * 1.05);
}

#[test]
fn full_characterization_of_a_1kb_gc_bank() {
    let t = sg40();
    let bank = compile(&t, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap();
    let perf = with_rt(|rt| characterize::characterize(&t, rt, &bank)).unwrap();
    assert!(perf.functional, "1 Kb GC bank must resolve: {perf:?}");
    assert!(perf.f_op_hz > 5e7 && perf.f_op_hz < 5e9, "{}", perf.f_op_hz);
    assert!(perf.retention_s > 1e-6 && perf.retention_s < 1e-2);
    assert!(perf.bandwidth_bps > perf.f_op_hz * 32.0);
}

#[test]
fn analytical_tracks_transient_within_bounds() {
    // the GEMTOO-style claim: analytical deviates but stays in the
    // same ballpark (paper: up to 15 % for GEMTOO; our stand-in stays
    // within a small constant factor -- the ablation bench reports the
    // actual deviation per size)
    let t = sg40();
    let bank = compile(&t, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap();
    let a = characterize::analytical(&t, &bank);
    let c = with_rt(|rt| characterize::characterize(&t, rt, &bank)).unwrap();
    let ratio = a.f_op_hz / c.f_op_hz;
    assert!(ratio > 0.2 && ratio < 5.0, "analytical/transient = {ratio}");
}

#[test]
fn shmoo_has_passes_and_failures() {
    // Fig. 10 structure: small banks serve most L1 demands; H100 L2
    // demands mostly exceed a single bank
    let t = sg40();
    let mut pass_l1 = 0;
    let mut fail_l2 = 0;
    let cfg = Config::new(32, 32, CellFlavor::GcSiSiNp);
    let bank = compile(&t, &cfg).unwrap();
    let perf = with_rt(|rt| characterize::characterize(&t, rt, &bank)).unwrap();
    let e = dse::Evaluated {
        config: cfg,
        perf,
        area_um2: bank.layout.total_area_um2(),
        quarantine: None,
    };
    for task in &workloads::TASKS {
        let l1 = workloads::profile(task, workloads::CacheLevel::L1, &workloads::GT520M);
        let l2 = workloads::profile(task, workloads::CacheLevel::L2, &workloads::H100);
        if dse::shmoo_verdict(&e, &l1).pass() {
            pass_l1 += 1;
        }
        if !dse::shmoo_verdict(&e, &l2).pass() {
            fail_l2 += 1;
        }
    }
    assert!(pass_l1 >= 4, "most GT520M L1 demands should pass: {pass_l1}");
    assert!(fail_l2 >= 4, "most H100 L2 demands need multibank: {fail_l2}");
}

#[test]
fn bank_layout_exports_gds_and_passes_drc_lvs_at_small_size() {
    let t = sg40();
    let bank = compile(&t, &Config::new(8, 8, CellFlavor::GcSiSiNp)).unwrap();
    // GDS round-trip
    let bytes = opengcram::layout::gds::write_bytes(&bank.library, &t, "bank");
    let summary = opengcram::layout::gds::read_summary(&bytes).unwrap();
    assert!(summary.structures.iter().any(|s| s == "bank"));
    assert!(summary.boundaries.len() > 100);
    // DRC on the flattened array (the generated tile)
    let rects = bank.library.flatten("bitcell_array").unwrap();
    let rep = opengcram::drc::check(&t, &rects);
    assert!(rep.clean(), "{} violations; first {}", rep.violations.len(), rep.violations[0]);
    // LVS array vs schematic
    let arr_pins = bank.library.get("bitcell_array").unwrap().pins.clone();
    let _ = arr_pins; // array pins propagate via bitcell abutment
    let mut nl = bank.netlist.clone();
    nl.top = "bitcell_array".into();
    let flat = nl.flatten().unwrap();
    assert_eq!(flat.mos_count(), 8 * 8 * 2);
    // extraction-level check: device count matches schematic
    let (rects, pins) = bank.library.flatten_with_pins("bitcell_array").unwrap();
    let ext = lvs::extract(&t, &rects, &pins, "bitcell_array").unwrap();
    assert_eq!(ext.circuit.mos_count(), flat.mos_count());
}

/// Field-by-field bitwise comparison of two BankPerf results — the
/// batched-vs-single equivalence contract is *exact*, not approximate.
fn assert_perf_bits_eq(a: &characterize::BankPerf, b: &characterize::BankPerf, what: &str) {
    let fields = [
        ("f_read_hz", a.f_read_hz, b.f_read_hz),
        ("f_write_hz", a.f_write_hz, b.f_write_hz),
        ("f_op_hz", a.f_op_hz, b.f_op_hz),
        ("bandwidth_bps", a.bandwidth_bps, b.bandwidth_bps),
        ("retention_s", a.retention_s, b.retention_s),
        ("leakage_w", a.leakage_w, b.leakage_w),
        ("e_read_j", a.e_read_j, b.e_read_j),
        ("t_decoder_s", a.t_decoder_s, b.t_decoder_s),
        ("t_cell_read_s", a.t_cell_read_s, b.t_cell_read_s),
        ("stored_one_v", a.stored_one_v, b.stored_one_v),
    ];
    for (name, x, y) in fields {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: {name} diverged ({x} vs {y})");
    }
    assert_eq!(a.functional, b.functional, "{what}: functional verdict diverged");
}

#[test]
fn batched_singleton_at_resolution_zero_matches_single_design_path_for_every_flavor() {
    // the equivalence contract: at window resolution 0 (exact,
    // unquantized windows) characterize_all(&[bank]) issues exactly
    // the artifact calls of characterize(bank), so results
    // bitwise-match for every cell flavor (including the analytical
    // SRAM reference path)
    let t = sg40();
    for flavor in [
        CellFlavor::Sram6t,
        CellFlavor::GcSiSiNp,
        CellFlavor::GcSiSiNn,
        CellFlavor::GcOsOs,
    ] {
        let bank = compile(&t, &Config::new(32, 32, flavor)).unwrap();
        let single = with_rt(|rt| characterize::characterize(&t, rt, &bank)).unwrap();
        let batched =
            characterize::characterize_all(&t, shared(), std::slice::from_ref(&bank), 0.0)
                .unwrap();
        assert_eq!(batched.len(), 1);
        assert_perf_bits_eq(&single, &batched[0], &format!("{flavor:?}"));
    }
}

#[test]
fn mixed_flavor_batch_splits_reads_and_packs_retention() {
    // regression for the read_op "mixed read flavors in one batch"
    // bail: NP (pull-up) and NN/OS (pull-down) designs in one list are
    // split into homogeneous read batches by the executor, while all
    // retention points pack into a single artifact execution
    let t = sg40();
    let mut np_vt = Config::new(32, 32, CellFlavor::GcSiSiNp);
    np_vt.write_vt = Some(0.52);
    let cfgs = vec![
        Config::new(32, 32, CellFlavor::GcSiSiNp),
        np_vt, // same geometry as the first: shares its read batch
        Config::new(32, 32, CellFlavor::GcOsOs),
        Config::new(32, 32, CellFlavor::GcSiSiNn),
        Config::new(16, 16, CellFlavor::GcSiSiNp),
    ];
    let banks: Vec<_> = cfgs.iter().map(|c| compile(&t, c).unwrap()).collect();
    // a private runtime: the call-count deltas below must not see
    // artifact executions from concurrently running tests
    let rt = private_rt();
    let read_before = rt.call_count("read");
    let ret_before = rt.call_count("retention");
    let batched = characterize::characterize_all(&t, &rt, &banks, 0.0).unwrap();
    let read_calls = rt.call_count("read") - read_before;
    let ret_calls = rt.call_count("retention") - ret_before;
    // every design's results still match its own single-design run
    for (bank, bp) in banks.iter().zip(&batched) {
        let single = with_rt(|r| characterize::characterize(&t, r, bank)).unwrap();
        assert_perf_bits_eq(&single, bp, &format!("{:?}", bank.config));
    }
    // read batches: at most one call per design (batching never adds
    // calls), and the two same-geometry NP designs share one
    assert!(read_calls <= 4, "expected <= 4 read executions, got {read_calls}");
    // retention: all five designs in one padded artifact call
    assert_eq!(ret_calls, 1, "retention points must pack into one execution");
}

#[test]
fn batched_sweep_matches_per_design_sweep() {
    let t = sg40();
    let mut vt = Config::new(16, 16, CellFlavor::GcSiSiNp);
    vt.write_vt = Some(0.5);
    // repeated config: the cache must dedupe it within the sweep
    let configs = vec![
        Config::new(16, 16, CellFlavor::GcSiSiNp),
        Config::new(32, 32, CellFlavor::GcSiSiNp),
        vt,
        Config::new(16, 16, CellFlavor::GcSiSiNp),
    ];
    let cache = dse::EvalCache::new();
    let structs = opengcram::compiler::CompileCache::new();
    let batched =
        dse::evaluate_all_batched_cached(&t, shared(), &configs, 2, &cache, &structs, 0.0).unwrap();
    assert_eq!(batched.len(), configs.len());
    assert_eq!(cache.len(), 3, "duplicate config evaluated twice");
    // 3 distinct configs, but the 16x16 VT variant shares the 16x16
    // structure: exactly 2 geometry compiles through the cache
    assert_eq!(structs.stats(), (1, 2), "expected 1 struct hit + 2 struct compiles");
    assert_eq!(structs.len(), 2);
    for (cfg, e) in configs.iter().zip(&batched) {
        assert_eq!(e.config.key(), cfg.key(), "sweep results out of order");
        let bank = compile(&t, cfg).unwrap();
        let single = with_rt(|rt| characterize::characterize(&t, rt, &bank)).unwrap();
        assert_perf_bits_eq(&single, &e.perf, &format!("{cfg:?}"));
        assert_eq!(e.area_um2, bank.layout.total_area_um2());
    }
}

#[test]
fn window_quantization_packs_size_axis_within_deviation_bound() {
    // the quantization accuracy contract (characterize module docs):
    // on a mixed-geometry rows axis the default resolution collapses
    // write/read executions to the bucket count, window-independent
    // fields are bitwise unchanged, and window-dependent fields stay
    // within one resolution step of the resolution-0 (exact) results
    let t = sg40();
    let res = characterize::DEFAULT_WINDOW_RESOLUTION;
    // rows pinned >= 180 (mux 1) keep both transient windows above
    // their floor clamps, so every design's exact windows differ and
    // the exact axis genuinely pays one execution per design
    let banks: Vec<_> = characterize::quantization_axis(5, 180, 4)
        .iter()
        .map(|cfg| compile(&t, cfg).unwrap())
        .collect();
    // a private runtime: the call-count deltas below must not see
    // artifact executions from concurrently running tests
    let rt = private_rt();
    let wr0 = rt.call_count("write");
    let rd0 = rt.call_count("read");
    let exact = characterize::characterize_all(&t, &rt, &banks, 0.0).unwrap();
    let exact_wr = rt.call_count("write") - wr0;
    let exact_rd = rt.call_count("read") - rd0;
    let wr1 = rt.call_count("write");
    let rd1 = rt.call_count("read");
    let quant = characterize::characterize_all(&t, &rt, &banks, res).unwrap();
    let quant_wr = rt.call_count("write") - wr1;
    let quant_rd = rt.call_count("read") - rd1;
    // the packing claim: the exact axis pays one write execution per
    // design (every window differs); the quantized axis pays the
    // grouped ceiling, which is strictly fewer on this fine axis
    assert_eq!(exact_wr as usize, banks.len(), "exact rows axis should not share windows");
    assert!(
        quant_wr < exact_wr && quant_rd < exact_rd,
        "quantization did not reduce executions: wr {exact_wr}->{quant_wr} rd {exact_rd}->{quant_rd}"
    );
    let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-300);
    for ((e, q), bank) in exact.iter().zip(&quant).zip(&banks) {
        let what = format!("{:?}", bank.config);
        // window-independent fields: bitwise identical
        assert_eq!(e.leakage_w.to_bits(), q.leakage_w.to_bits(), "{what}: leakage");
        assert_eq!(e.t_decoder_s.to_bits(), q.t_decoder_s.to_bits(), "{what}: t_decoder");
        assert_eq!(e.e_read_j.to_bits(), q.e_read_j.to_bits(), "{what}: e_read");
        // window-dependent fields: within one resolution step
        assert!(rel(q.f_read_hz, e.f_read_hz) <= res, "{what}: f_read {} vs {}", q.f_read_hz, e.f_read_hz);
        assert!(rel(q.f_write_hz, e.f_write_hz) <= res, "{what}: f_write {} vs {}", q.f_write_hz, e.f_write_hz);
        assert!(rel(q.f_op_hz, e.f_op_hz) <= res, "{what}: f_op {} vs {}", q.f_op_hz, e.f_op_hz);
        assert!(rel(q.bandwidth_bps, e.bandwidth_bps) <= res, "{what}: bandwidth");
        assert!(rel(q.retention_s, e.retention_s) <= res, "{what}: retention {} vs {}", q.retention_s, e.retention_s);
        assert!((q.stored_one_v - e.stored_one_v).abs() < 0.02, "{what}: stored1 {} vs {}", q.stored_one_v, e.stored_one_v);
        assert_eq!(e.functional, q.functional, "{what}: functional verdict flipped");
    }
}

#[test]
fn compose_selection_is_deterministic_at_resolution_zero() {
    // the composition contract: at window resolution 0 the mega-sweep
    // is bitwise-reproducible, so two independent compositions (fresh
    // caches, parallel compile fan-out) select identical hardware with
    // bit-identical costs
    let t = sg40();
    let mut spec = compose::ComposeSpec::new(&workloads::H100);
    spec.window_resolution = 0.0;
    let a = compose::compose(&t, shared(), &spec).unwrap();
    let b = compose::compose(&t, shared(), &spec).unwrap();
    assert_eq!(a.per_demand.len(), b.per_demand.len());
    assert_eq!(a.per_level.len(), b.per_level.len());
    for (x, y) in a.per_demand.iter().zip(&b.per_demand).chain(a.per_level.iter().zip(&b.per_level)) {
        let what = format!("{:?} {}", x.demand.level, x.demand.task.name);
        assert_eq!(x.feasible, y.feasible, "{what}: feasible count diverged");
        assert_eq!(x.front, y.front, "{what}: front size diverged");
        match (&x.choice, &y.choice) {
            (None, None) => {}
            (Some(cx), Some(cy)) => {
                assert_eq!(cx.eval.config.key(), cy.eval.config.key(), "{what}: choice diverged");
                assert_eq!(cx.cost.to_bits(), cy.cost.to_bits(), "{what}: cost diverged");
                assert_eq!(
                    cx.freq_margin.to_bits(),
                    cy.freq_margin.to_bits(),
                    "{what}: margin diverged"
                );
            }
            _ => panic!("{what}: choice presence diverged"),
        }
    }
    // the sweep must have found someone to serve
    assert!(a.per_demand.iter().any(|s| s.choice.is_some()), "no demand found a feasible bank");
}

#[test]
fn compose_choices_meet_their_demands() {
    let t = sg40();
    let spec = compose::ComposeSpec::new(&workloads::GT520M);
    let c = compose::compose(&t, shared(), &spec).unwrap();
    assert_eq!(c.per_demand.len(), 2 * workloads::TASKS.len());
    assert_eq!(c.per_level.len(), 2);
    assert_eq!(c.distinct, compose::design_grid().len(), "sweep must cover the whole grid");
    let grid = compose::design_grid();
    for s in c.per_demand.iter().chain(c.per_level.iter()) {
        assert!(s.front <= s.feasible);
        match &s.choice {
            Some(ch) => {
                assert!(s.feasible > 0 && s.front > 0);
                assert!(ch.eval.perf.functional);
                assert!(
                    ch.freq_margin >= 1.0 && ch.retention_margin >= 1.0,
                    "{}: infeasible choice (xf {}, xr {})",
                    s.demand.task.name,
                    ch.freq_margin,
                    ch.retention_margin
                );
                assert!(ch.cost.is_finite());
                // the choice really is a grid point
                assert!(grid.iter().any(|g| g.key() == ch.eval.config.key()));
            }
            None => assert_eq!(s.feasible, 0, "feasible points but no selection"),
        }
    }
    // GT520M is light enough that every L1 demand finds a bank (the
    // SRAM baseline alone serves them: infinite retention, fast)
    assert!(
        c.per_demand
            .iter()
            .filter(|s| s.demand.level == workloads::CacheLevel::L1)
            .all(|s| s.choice.is_some()),
        "every GT520M L1 demand should be served"
    );
}

#[test]
fn coordinator_batches_retention_jobs_over_the_runtime() {
    use opengcram::coordinator::{BatchExec, Coordinator};
    struct RetExec {
        rt: &'static SharedRuntime,
        cap: usize,
    }
    impl BatchExec<engines::RetentionPoint, engines::RetentionResult> for RetExec {
        fn run(&mut self, jobs: &[engines::RetentionPoint]) -> opengcram::Result<Vec<engines::RetentionResult>> {
            self.rt.with(|rt| engines::retention(rt, jobs))
        }
        fn max_batch(&self) -> usize {
            self.cap
        }
    }
    let cap = with_rt(|rt| rt.manifest().get("retention").unwrap().batch);
    let t = sg40();
    let c = Coordinator::spawn(RetExec { rt: shared(), cap });
    let jobs: Vec<_> = (0..20)
        .map(|i| engines::RetentionPoint {
            write_card: t.card("si_nmos").with_vt(0.35 + 0.02 * i as f64),
            write_wl: 2.5,
            c_sn: 1.2e-15,
            g_gate_leak: 1e-16,
            i_disturb: 0.0,
            v0: 0.6,
            vth: 0.3,
        })
        .collect();
    let res = c.run_all(jobs).unwrap();
    assert_eq!(res.len(), 20);
    for w in res.windows(2) {
        assert!(w[1].t_retain >= w[0].t_retain * 0.99);
    }
}
