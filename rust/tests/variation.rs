//! Statistical tier for the Monte-Carlo variation engine
//! ([`opengcram::variation`]).  Everything here is deterministic —
//! fixed seeds, substream-split draws — so none of it can flake:
//!
//! * **Zero-sigma == nominal, bitwise**: a zero-sigma model's samples
//!   are bit-identical to the non-MC batched sweep.
//! * **Mega-batch == singletons, bitwise**: every sampled variant run
//!   inside the packed `K x D` mega-batch matches its own singleton
//!   [`characterize_plan`] run to the last bit (batch packing is
//!   invisible to variant physics).
//! * **Reproducibility**: yields are bit-stable across worker counts
//!   and config batch order (substream labels key on design identity,
//!   not position).
//! * **Grouped-ceiling occupancy**: the mega-batch's real native
//!   artifact counters equal [`variation::plan_call_counts`]'s
//!   prediction — `K x D` variants never pay `K x D` executions per
//!   engine.
//! * **Closed-form yield in the Wilson interval**: sign/corner counts
//!   with known probability 0.5 land inside their 95 % Wilson score
//!   intervals at the pinned seed (the counts themselves were verified
//!   against an independent reimplementation of the PRNG).

use opengcram::characterize::{self, CharPlan};
use opengcram::compiler::{compile, CellFlavor, CompileCache, Config, ConfigKey};
use opengcram::runtime::SharedRuntime;
use opengcram::tech::sg40;
use opengcram::variation::{self, VariationModel};
use opengcram::{dse, workloads};
use std::collections::HashMap;

/// Bitwise `BankPerf` comparison — same contract as the parity suite.
fn perf_bits_eq(a: &characterize::BankPerf, b: &characterize::BankPerf, what: &str) {
    let fields = [
        ("f_read_hz", a.f_read_hz, b.f_read_hz),
        ("f_write_hz", a.f_write_hz, b.f_write_hz),
        ("f_op_hz", a.f_op_hz, b.f_op_hz),
        ("bandwidth_bps", a.bandwidth_bps, b.bandwidth_bps),
        ("retention_s", a.retention_s, b.retention_s),
        ("leakage_w", a.leakage_w, b.leakage_w),
        ("e_read_j", a.e_read_j, b.e_read_j),
        ("t_decoder_s", a.t_decoder_s, b.t_decoder_s),
        ("t_cell_read_s", a.t_cell_read_s, b.t_cell_read_s),
        ("stored_one_v", a.stored_one_v, b.stored_one_v),
    ];
    for (name, x, y) in fields {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: {name} diverged ({x} vs {y})");
    }
    assert_eq!(a.functional, b.functional, "{what}: functional verdict diverged");
}

#[test]
fn variation_zero_sigma_mc_is_bitwise_equal_to_nominal_sweep() {
    // acceptance pin (c): --mc with a zero-sigma model produces
    // bit-identical results to the nominal non-MC sweep.  K = 2 keeps
    // even the sample *means* exact (x + x = 2x and 2x / 2 = x are
    // both exact in binary floating point), so the reduced stats are
    // pinned bitwise too, not just the per-sample points.
    let t = sg40();
    let cfgs = vec![
        Config::new(32, 32, CellFlavor::GcSiSiNp),
        Config::new(32, 32, CellFlavor::GcOsOs),
    ];
    let model = VariationModel::zero(2, 0xFEED, t.vdd);

    let nom_rt = SharedRuntime::native();
    let nominal = dse::evaluate_all_batched(&t, &nom_rt, &cfgs, 2, 0.0).unwrap();

    let rt = SharedRuntime::native();
    let (dys, health) =
        variation::yield_sweep_health(&t, &rt, &cfgs, &model, 2, 0.0, &CompileCache::new()).unwrap();
    assert!(health.is_clean(), "{}", health.summary());
    assert_eq!(dys.len(), cfgs.len());

    for (dy, base) in dys.iter().zip(&nominal) {
        assert_eq!(dy.config.key(), base.config.key(), "sweep order diverged");
        let what = format!("{:?}", dy.config);
        perf_bits_eq(&dy.nominal.perf, &base.perf, &format!("{what} [nom]"));
        for (i, s) in dy.samples.iter().enumerate() {
            assert!(s.quarantine.is_none());
            perf_bits_eq(&s.perf, &base.perf, &format!("{what} [s{i}]"));
        }
        assert!(dy.stats.quarantined.is_empty());
        if base.perf.functional {
            assert_eq!(dy.stats.functional.p, 1.0, "{what}");
            // K = 2: the mean of two identical values is exact, and a
            // zero spread means an exactly-zero sigma
            assert_eq!(dy.stats.f_op_hz.mean.to_bits(), base.perf.f_op_hz.to_bits(), "{what}");
            assert_eq!(dy.stats.f_op_hz.sigma, 0.0, "{what}");
            assert_eq!(
                dy.stats.retention_s.mean.to_bits(),
                base.perf.retention_s.to_bits(),
                "{what}"
            );
        }
    }
    // the workhorse design must actually be functional for the p == 1.0
    // branch above to have bitten
    assert!(nominal[0].perf.functional, "32x32 GcSiSiNp should be functional");
}

#[test]
fn variation_mega_batch_matches_singleton_characterization_bitwise() {
    // acceptance pin: MC-through-characterize equals, bitwise, running
    // each sampled variant alone through the singleton path at exact
    // (resolution 0.0) windows — the same claim the backend parity
    // suite makes for nominal plans, extended to perturbed ones.
    let t = sg40();
    let cfgs = vec![
        Config::new(32, 32, CellFlavor::GcSiSiNp),
        Config::new(16, 16, CellFlavor::GcOsOs),
    ];
    let model = VariationModel::from_tech(&t, 3, 0xC0FFEE);

    let rt = SharedRuntime::native();
    let (dys, health) =
        variation::yield_sweep_health(&t, &rt, &cfgs, &model, 2, 0.0, &CompileCache::new()).unwrap();
    assert!(health.is_clean(), "{}", health.summary());

    let single_rt = SharedRuntime::native();
    for (dy, cfg) in dys.iter().zip(&cfgs) {
        let bank = compile(&t, cfg).unwrap();
        let what = format!("{cfg:?}");
        let nom = single_rt
            .with(|b| characterize::characterize_plan(b, CharPlan::with_resolution(&t, &bank, 0.0)))
            .unwrap();
        perf_bits_eq(&dy.nominal.perf, &nom, &format!("{what} [nom]"));
        for (i, s) in dy.samples.iter().enumerate() {
            let p = model.perturb(&t, cfg, i);
            let single = single_rt
                .with(|b| {
                    characterize::characterize_plan(b, CharPlan::with_variation(&t, &bank, 0.0, &p))
                })
                .unwrap();
            perf_bits_eq(&s.perf, &single, &format!("{what} [s{i}]"));
        }
    }
}

#[test]
fn variation_yields_reproducible_across_workers_and_batch_order() {
    // acceptance pin (b): seed-reproducible yield independent of worker
    // count and batch order.  Substream labels are built from design
    // identity, so reversing the config list or changing the compile
    // worker pool must not move a single bit.
    let t = sg40();
    let cfgs = vec![
        Config::new(32, 32, CellFlavor::GcSiSiNp),
        Config::new(16, 16, CellFlavor::GcSiSiNn),
        Config::new(32, 32, CellFlavor::GcOsOs),
    ];
    let model = VariationModel::from_tech(&t, 4, 0xBEEF);

    let run = |configs: &[Config], workers: usize| {
        let rt = SharedRuntime::native();
        let (dys, health) =
            variation::yield_sweep_health(&t, &rt, configs, &model, workers, 0.0, &CompileCache::new())
                .unwrap();
        assert!(health.is_clean(), "{}", health.summary());
        dys.into_iter().map(|dy| (dy.config.key(), dy)).collect::<HashMap<ConfigKey, _>>()
    };

    let base = run(&cfgs, 1);
    let mut reversed: Vec<Config> = cfgs.clone();
    reversed.reverse();
    for other in [run(&cfgs, 8), run(&reversed, 1)] {
        assert_eq!(other.len(), base.len());
        for (key, dy) in &base {
            let o = other.get(key).expect("design missing from re-ordered sweep");
            let what = format!("{:?}", dy.config);
            perf_bits_eq(&o.nominal.perf, &dy.nominal.perf, &format!("{what} [nom]"));
            assert_eq!(o.samples.len(), dy.samples.len());
            for (i, (a, b)) in o.samples.iter().zip(&dy.samples).enumerate() {
                perf_bits_eq(&a.perf, &b.perf, &format!("{what} [s{i}]"));
            }
            assert_eq!(o.stats.functional.passed, dy.stats.functional.passed);
            assert_eq!(o.stats.functional.samples, dy.stats.functional.samples);
            // demand-joint yields ride on the same samples
            for d in workloads::all_demands(&workloads::GT520M) {
                assert_eq!(
                    o.yield_for(&d).passed,
                    dy.yield_for(&d).passed,
                    "{what} {} {:?}",
                    d.task.name,
                    d.level
                );
            }
        }
    }
}

#[test]
fn variation_mega_batch_pays_grouped_ceiling_execution_counts() {
    // acceptance pin (a): grouped-ceiling execution counts for K x D
    // variants on the *real* native counters.  The rows-axis designs
    // sit above the window floor clamps, so their windows are genuinely
    // distinct and the quantizer (not the clamp) does the packing.
    let t = sg40();
    let cfgs = characterize::quantization_axis(3, 180, 8);
    let k = 6;
    let model = VariationModel::from_tech(&t, k, 0xA11CE);
    let res = characterize::DEFAULT_WINDOW_RESOLUTION;

    let rt = SharedRuntime::native();
    let caps = (
        rt.batch_cap("write").unwrap(),
        rt.batch_cap("read").unwrap(),
        rt.batch_cap("retention").unwrap(),
    );
    let (want_w, want_r, want_t) =
        variation::plan_call_counts(&t, &cfgs, &model, res, caps.0, caps.1, caps.2).unwrap();

    let (dys, health) =
        variation::yield_sweep_health(&t, &rt, &cfgs, &model, 2, res, &CompileCache::new()).unwrap();
    assert!(health.is_clean(), "{}", health.summary());
    assert_eq!(dys.len(), cfgs.len());

    assert_eq!(rt.call_count("write"), want_w as u64, "write occupancy model diverged");
    assert_eq!(rt.call_count("read"), want_r as u64, "read occupancy model diverged");
    assert_eq!(rt.call_count("retention"), want_t as u64, "retention occupancy model diverged");

    // the whole point: far under one-execution-per-variant-per-engine
    let naive = cfgs.len() * (k + 1);
    assert!(want_w < naive, "write: {want_w} groups for {naive} variant plans");
    assert_eq!(want_t, 1, "retention always packs ({naive} jobs, cap {})", caps.2);
    // two read jobs per plan share a (pull_up, window) group
    assert!(want_r <= naive, "read: {want_r} calls for {} jobs", 2 * naive);
}

#[test]
fn variation_sign_counts_sit_inside_wilson_intervals() {
    // closed-form yield check at a pinned seed: each of these events
    // has exact probability 1/2 by symmetry (the Box-Muller normal's
    // sign, and a two-corner uniform pick), so the observed count over
    // N = 400 substreams must put 0.5 inside its 95 % Wilson interval.
    // Deterministic: the counts at this seed are 193 (vt), 195 (kp)
    // and 200 (corner), verified against an independent
    // reimplementation of splitmix64/xoshiro256**/Box-Muller.
    let t = sg40();
    let n = 400;
    let cfg = Config::new(32, 32, CellFlavor::GcSiSiNp);

    // per-instance mismatch: P(vt_shift_wr > 0) = P(kp_scale > 1) = 1/2
    let m = VariationModel::from_tech(&t, n, 0xC0FFEE);
    let vt_up = (0..n).filter(|&i| m.perturb(&t, &cfg, i).vt_shift_wr > 0.0).count();
    let kp_up = (0..n).filter(|&i| m.perturb(&t, &cfg, i).kp_scale > 1.0).count();

    // corner mix: P(ss) = 1/2 with a two-corner uniform draw; zero
    // sigmas make the ss pick exactly recognizable by its VT shift
    let mut mc = VariationModel::zero(n, 0xC0FFEE, t.vdd);
    let ss = *t.corner("ss").unwrap();
    mc.corners.push(ss);
    let ss_picks =
        (0..n).filter(|&i| mc.perturb(&t, &cfg, i).vt_shift_wr == ss.vt_shift).count();

    for (what, count) in [("vt sign", vt_up), ("kp sign", kp_up), ("ss corner", ss_picks)] {
        let est = variation::wilson(count, n, variation::WILSON_Z);
        assert!(
            est.lo <= 0.5 && 0.5 <= est.hi,
            "{what}: closed-form p=0.5 outside Wilson interval [{}, {}] (count {count}/{n})",
            est.lo,
            est.hi
        );
        // and the estimate itself is sane, not degenerate
        assert!((150..=250).contains(&count), "{what}: count {count} wildly off");
    }
}
