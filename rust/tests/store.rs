//! On-disk evaluation-store integrity tier.
//!
//! The store is trusted to survive process lifetimes, so these tests
//! attack exactly the ways persisted state goes wrong: torn/corrupted
//! bytes, format-version drift, entries copied between identities
//! (tech / window resolution), and the interaction with the session's
//! cache hierarchy — a rejected entry must be *recomputed*, never
//! aliased, and a valid one must be served with **zero**
//! characterization executions (asserted on the real native
//! call counters).

use opengcram::compiler::{CellFlavor, Config};
use opengcram::dse::Evaluated;
use opengcram::runtime::SharedRuntime;
use opengcram::service::Session;
use opengcram::store::{decode_entry, encode_entry, DiskStore, StoreKey, FORMAT_VERSION};
use opengcram::tech::sg40;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Fresh scratch directory per test (no tempfile crate in the offline
/// registry) — unique per process AND per call so parallel tests never
/// share a store.
fn scratch(name: &str) -> PathBuf {
    static N: AtomicUsize = AtomicUsize::new(0);
    let dir = std::env::temp_dir().join(format!(
        "opengcram-store-test-{}-{}-{}",
        name,
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn sample_entry() -> (StoreKey, Evaluated) {
    let mut cfg = Config::new(32, 32, CellFlavor::GcSiSiNp);
    cfg.write_vt = Some(0.45);
    let perf = opengcram::characterize::BankPerf {
        f_read_hz: 1.1e9,
        f_write_hz: 2.2e9,
        f_op_hz: 1.1e9,
        bandwidth_bps: 3.52e10,
        retention_s: 1.0 / 3.0,
        leakage_w: 5e-324,
        e_read_j: 1.7e-13,
        t_decoder_s: 9.3e-11,
        t_cell_read_s: 4.4e-10,
        stored_one_v: 0.71,
        functional: true,
    };
    let e = Evaluated { config: cfg.clone(), perf, area_um2: 987.654321, quarantine: None };
    (StoreKey::new(cfg.key(), "sg40", 0.1), e)
}

#[test]
fn disk_round_trip_is_bitwise_and_counted() {
    let dir = scratch("roundtrip");
    let store = DiskStore::open(&dir).unwrap();
    let (key, e) = sample_entry();

    // cold store: a lookup is a miss, not an error
    assert!(store.load(&key).is_none());
    assert_eq!(store.stats().misses, 1);

    store.save(&key, &e);
    let back = store.load(&key).expect("saved entry loads");
    assert_eq!(store.stats().hits, 1);
    assert_eq!(back.config.key(), e.config.key());
    assert_eq!(back.area_um2.to_bits(), e.area_um2.to_bits());
    assert_eq!(back.perf.retention_s.to_bits(), e.perf.retention_s.to_bits());
    assert_eq!(back.perf.leakage_w.to_bits(), e.perf.leakage_w.to_bits(), "subnormals survive");
    assert_eq!(back.perf.functional, e.perf.functional);
    assert_eq!(back.quarantine, e.quarantine);
    assert_eq!(store.stats().rejects, 0);
    assert_eq!(store.stats().write_errors, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_bytes_are_rejected_not_served() {
    let dir = scratch("corrupt");
    let store = DiskStore::open(&dir).unwrap();
    let (key, e) = sample_entry();
    store.save(&key, &e);
    let path = dir.join(key.filename());

    // truncation (torn write survived a crash without the atomic rename)
    let full = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, &full[..full.len() / 2]).unwrap();
    assert!(store.load(&key).is_none(), "truncated entry must be rejected");
    assert_eq!(store.stats().rejects, 1);

    // bit-flip inside a hex field: still JSON, wrong payload width
    std::fs::write(&path, full.replace(&format!("{:016x}", e.area_um2.to_bits()), "zz")).unwrap();
    assert!(store.load(&key).is_none(), "malformed hex field must be rejected");
    assert_eq!(store.stats().rejects, 2);

    // a fresh save heals the slot
    store.save(&key, &e);
    assert!(store.load(&key).is_some());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn version_bump_invalidates_old_entries() {
    let dir = scratch("version");
    let store = DiskStore::open(&dir).unwrap();
    let (key, e) = sample_entry();
    store.save(&key, &e);
    let path = dir.join(key.filename());
    let line = std::fs::read_to_string(&path).unwrap();
    // simulate an entry written by a future (or past) format version:
    // both the version field and the embedded key carry the version,
    // so tampering either one alone must already reject
    let v = format!("\"version\":{FORMAT_VERSION}");
    assert!(line.contains(&v), "entry must embed its format version: {line}");
    std::fs::write(&path, line.replace(&v, &format!("\"version\":{}", FORMAT_VERSION + 1)))
        .unwrap();
    assert!(store.load(&key).is_none(), "future-version entry must be rejected");
    assert_eq!(store.stats().rejects, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tech_and_resolution_changes_never_alias() {
    let dir = scratch("identity");
    let store = DiskStore::open(&dir).unwrap();
    let (key, e) = sample_entry();
    store.save(&key, &e);

    // different tech / resolution → different filename → plain miss
    let mut other_tech = key.clone();
    other_tech.tech = "sg28".into();
    let mut other_res = key.clone();
    other_res.window_res_bits = 0.0f64.to_bits();
    for other in [&other_tech, &other_res] {
        assert_ne!(other.filename(), key.filename());
        assert!(store.load(other).is_none());
    }
    assert_eq!(store.stats().misses, 2);

    // an adversarially *copied* file (same bytes under the other key's
    // filename) parses fine but its embedded canonical key disagrees —
    // reject, never alias
    std::fs::copy(dir.join(key.filename()), dir.join(other_res.filename())).unwrap();
    assert!(store.load(&other_res).is_none(), "copied entry must not alias across resolutions");
    assert_eq!(store.stats().rejects, 1);

    // decode_entry level: same line, wrong key
    let line = encode_entry(&key, &e);
    assert!(decode_entry(&line, &other_tech).is_none());
    assert!(decode_entry(&line, &key).is_some());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The headline store KPI, on real counters: a second session over the
/// same store directory re-serves the sweep with ZERO characterization
/// executions; after corruption the same point is recomputed (paid
/// again), not served from the corpse.
#[test]
fn warm_restart_serves_from_disk_and_corruption_forces_recompute() {
    let t = sg40();
    let dir = scratch("warm");
    let configs = [
        Config::new(16, 16, CellFlavor::GcSiSiNp),
        Config::new(32, 32, CellFlavor::GcSiSiNp),
    ];

    // session 1: cold — pays the pipeline, persists to disk
    let s1 = Session::new(&t, SharedRuntime::native(), 0.0)
        .unwrap()
        .with_store(&dir)
        .unwrap();
    let (evals1, health1) = s1.evaluate(&configs).unwrap();
    assert!(health1.is_clean());
    let calls1 = s1.runtime().call_counts();
    assert!(calls1.values().sum::<u64>() > 0, "cold sweep must execute: {calls1:?}");

    // session 2 (a "restarted process"): fresh runtime, fresh memory
    // tier, same store — zero executions, bitwise-identical results
    let s2 = Session::new(&t, SharedRuntime::native(), 0.0)
        .unwrap()
        .with_store(&dir)
        .unwrap();
    let (evals2, health2) = s2.evaluate(&configs).unwrap();
    assert!(health2.is_clean());
    let calls2 = s2.runtime().call_counts();
    assert_eq!(calls2.values().sum::<u64>(), 0, "warm restart must not execute: {calls2:?}");
    for (a, b) in evals1.iter().zip(&evals2) {
        assert_eq!(a.config.key(), b.config.key());
        assert_eq!(a.area_um2.to_bits(), b.area_um2.to_bits());
        assert_eq!(a.perf.f_op_hz.to_bits(), b.perf.f_op_hz.to_bits());
        assert_eq!(a.perf.retention_s.to_bits(), b.perf.retention_s.to_bits());
        assert_eq!(a.perf.leakage_w.to_bits(), b.perf.leakage_w.to_bits());
    }
    let st2 = s2.stats();
    assert_eq!(st2.store.unwrap().hits, configs.len());
    assert_eq!(st2.cache_misses, 0, "disk promotion must not count as a pipeline miss");

    // corrupt one entry on disk: a third session must recompute that
    // point (and only pay for it, the healthy one still loads)
    let victim = StoreKey::new(configs[0].key(), t.name, 0.0);
    let path = dir.join(victim.filename());
    std::fs::write(&path, "{\"version\":999,\"garbage\":true}").unwrap();
    let s3 = Session::new(&t, SharedRuntime::native(), 0.0)
        .unwrap()
        .with_store(&dir)
        .unwrap();
    let (evals3, _h) = s3.evaluate(&configs).unwrap();
    assert!(
        s3.runtime().call_counts().values().sum::<u64>() > 0,
        "corrupted entry must be recomputed"
    );
    let st3 = s3.stats();
    assert_eq!(st3.store.as_ref().unwrap().rejects, 1);
    assert_eq!(st3.store.as_ref().unwrap().hits, 1);
    assert_eq!(st3.cache_misses, 1, "exactly the corrupted point re-pays the pipeline");
    // recomputed result is bitwise the original — and the heal is
    // persisted: a fourth session is all-warm again
    assert_eq!(evals3[0].perf.f_op_hz.to_bits(), evals1[0].perf.f_op_hz.to_bits());
    let s4 = Session::new(&t, SharedRuntime::native(), 0.0)
        .unwrap()
        .with_store(&dir)
        .unwrap();
    let _ = s4.evaluate(&configs).unwrap();
    assert_eq!(s4.runtime().call_counts().values().sum::<u64>(), 0, "store healed after rewrite");
    let _ = std::fs::remove_dir_all(&dir);
}
