//! DSE job coordinator: batches design-point jobs onto the (single)
//! PJRT runtime.
//!
//! The paper's contribution is the compiler, so L3 coordination is the
//! "thin driver" case: a bounded job queue feeding one executor thread
//! that assembles batches up to the artifact batch size.  The batching
//! logic is generic over the executor so its invariants are
//! property-tested with a mock.
//!
//! # Batching invariants
//!
//! * **No job lost, no result misrouted** — every submitted job
//!   produces exactly one result, delivered to its submitter's
//!   receiver in submission order; a result-count mismatch from the
//!   executor fails the whole batch rather than shifting results.
//! * **The cap is a hard ceiling** — a worker batch never exceeds
//!   [`BatchExec::max_batch`] (the artifact batch size from the
//!   manifest); the executor may *subdivide* further (e.g. by
//!   transient window or read flavor — see
//!   [`crate::characterize::batch`]) but never sees more jobs than the
//!   cap at once.
//! * **Group boundaries are flush boundaries** —
//!   [`Submitter::run_grouped`] flushes between groups, so no worker
//!   batch spans two homogeneity groups and the execution count is
//!   exactly `sum(ceil(group_len / cap))` over the groups — the
//!   occupancy model the benches assert
//!   ([`crate::characterize::batch::calls_for`]).
//!
//! Two spawn modes:
//! * [`Coordinator::spawn`] — detached worker for `'static` executors
//!   (owns its runtime handle; lives as long as the coordinator);
//! * [`scope`] — scoped worker for executors that *borrow* (the
//!   [`crate::characterize::characterize_all`] executors borrow the
//!   shared runtime), joined when the closure returns.
//!
//! Failure semantics (fault isolation): an executor `Err` is
//! recoverable.  The worker first **retries** the whole batch under the
//! executor's bounded [`RetryPolicy`] (transient faults heal invisibly
//! — co-batched submitters never see them), then **bisects** the
//! still-failing batch to quarantine the poisoned job(s): healthy
//! co-batched jobs still receive their results and only culprit jobs
//! get per-job errors carrying the executor's own cause.  Bisection
//! costs at most `2·ceil(log2 batch)` extra executions per poisoned
//! row, and a clean run pays **zero** extra executions (retry and
//! bisection only engage on `Err`), so the grouped-ceiling occupancy
//! model is unchanged when no faults fire.  [`CoordHealth`] counts
//! retries and bisect executions for the `RunHealth` report.
//!
//! An executor *panic* stays fatal: the panic payload is recorded as
//! the worker's epitaph, in-flight submitters get it as an error, and
//! later [`Submitter::submit`] / [`Submitter::flush`] calls fail fast
//! with the same underlying cause instead of handing out a receiver
//! that can only ever report a bare "worker died".

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Bounded retry/backoff applied by the worker before a failing batch
/// is bisected: up to `max_retries` re-runs, sleeping
/// `backoff × attempt` (linear) between attempts.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    pub max_retries: usize,
    pub backoff: std::time::Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 2, backoff: std::time::Duration::from_millis(5) }
    }
}

impl RetryPolicy {
    /// No retries: a failing batch goes straight to bisection.
    pub fn none() -> RetryPolicy {
        RetryPolicy { max_retries: 0, backoff: std::time::Duration::ZERO }
    }
}

/// Fault-isolation counters for one worker (shared across the scoped
/// stage workers of a sweep via `Arc`).  All-zero on a clean run.
#[derive(Debug, Default)]
pub struct CoordHealth {
    retries: AtomicU64,
    bisect_execs: AtomicU64,
}

impl CoordHealth {
    /// Batch retry attempts made (transient faults healed).
    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Extra executor runs spent bisecting failing batches.
    pub fn bisect_execs(&self) -> u64 {
        self.bisect_execs.load(Ordering::Relaxed)
    }
}

/// A batch executor: runs a slice of jobs, returns one result per job
/// in order.  The PJRT-backed implementations wrap runtime::engines
/// (see [`crate::characterize::batch`]); an executor may subdivide the
/// handed batch internally (e.g. by transient window or read flavor)
/// as long as results come back positionally.
///
/// Positional results are also what makes fault isolation composable:
/// the worker may re-run any contiguous sub-slice of a handed batch
/// (retry, bisection) and results still land on the right jobs, while
/// the executor's internal grouping keeps each sub-run on the normal
/// grouped-ceiling cost model.
pub trait BatchExec<J, R>: Send {
    fn run(&mut self, jobs: &[J]) -> crate::Result<Vec<R>>;
    fn max_batch(&self) -> usize;

    /// Retry/backoff bounds the worker applies before bisecting a
    /// failing batch.  Default-implemented so existing executors keep
    /// the grouped-ceiling occupancy model untouched on healthy runs;
    /// override (e.g. with [`RetryPolicy::none`]) to tune.
    fn retry_policy(&self) -> RetryPolicy {
        RetryPolicy::default()
    }
}

enum Msg<J, R> {
    Job(J, mpsc::Sender<crate::Result<R>>),
    Flush,
    Stop,
}

/// Why the worker stopped serving (executor panic), shared so late
/// submitters can report the original failure.
type Epitaph = Arc<Mutex<Option<String>>>;

/// Clonable submission handle.  `mpsc::Sender` is `Send` but not
/// `Sync`, so concurrent submitters (DSE sweep workers) each take
/// their own clone via [`Coordinator::handle`].
pub struct Submitter<J, R> {
    tx: mpsc::Sender<Msg<J, R>>,
    epitaph: Epitaph,
}

impl<J, R> Clone for Submitter<J, R> {
    fn clone(&self) -> Self {
        Submitter { tx: self.tx.clone(), epitaph: self.epitaph.clone() }
    }
}

impl<J: Send, R: Send> Submitter<J, R> {
    fn death_error(&self, context: &str) -> anyhow::Error {
        match self.epitaph.lock().unwrap_or_else(|p| p.into_inner()).clone() {
            Some(why) => anyhow::anyhow!("{context}: {why}"),
            None => anyhow::anyhow!("{context}: worker stopped"),
        }
    }

    /// Submit a job; returns a receiver for its result.  Fails fast —
    /// carrying the worker's recorded failure cause — once the worker
    /// is gone, instead of returning a forever-dead receiver.
    pub fn submit(&self, job: J) -> crate::Result<mpsc::Receiver<crate::Result<R>>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Job(job, rtx))
            .map_err(|_| self.death_error("coordinator worker is gone"))?;
        Ok(rrx)
    }

    /// Force the pending partial batch to execute.  Fails fast —
    /// carrying the worker's recorded failure cause — when the flush
    /// cannot be delivered because the worker is gone (it used to be
    /// silently swallowed, leaving callers to hang on `recv` semantics
    /// alone).
    pub fn flush(&self) -> crate::Result<()> {
        self.tx
            .send(Msg::Flush)
            .map_err(|_| self.death_error("coordinator worker is gone, flush undeliverable"))
    }

    /// Submit many jobs and wait for all results (flushes).
    pub fn run_all(&self, jobs: Vec<J>) -> crate::Result<Vec<R>> {
        self.run_grouped(std::iter::once(jobs))
    }

    /// Submit jobs group by group with a flush at every group boundary,
    /// then wait for all results (in submission order).  Boundary
    /// flushes keep a worker batch from spanning two groups — jobs of
    /// different groups can never share an artifact execution anyway
    /// (different window/waveform), so this costs nothing and makes the
    /// execution count exactly `sum(ceil(group_len / cap))`.
    ///
    /// Fails on the **first** per-job error; for per-job fault
    /// isolation (quarantined jobs reported individually while healthy
    /// jobs keep their results) use [`Submitter::run_grouped_each`].
    pub fn run_grouped(
        &self,
        groups: impl IntoIterator<Item = Vec<J>>,
    ) -> crate::Result<Vec<R>> {
        self.run_grouped_each(groups)?.into_iter().collect()
    }

    /// [`Submitter::run_grouped`] with per-job fault isolation: the
    /// outer `Err` fires only when submission itself fails fast (worker
    /// gone before all jobs were delivered); otherwise every job gets
    /// its own `Result` in submission order — quarantined jobs carry
    /// their per-job cause, jobs orphaned by worker death carry the
    /// epitaph, healthy co-batched jobs keep their results.
    pub fn run_grouped_each(
        &self,
        groups: impl IntoIterator<Item = Vec<J>>,
    ) -> crate::Result<Vec<crate::Result<R>>> {
        let mut rxs = Vec::new();
        for group in groups {
            for j in group {
                rxs.push(self.submit(j)?);
            }
            self.flush()?;
        }
        Ok(rxs
            .into_iter()
            .map(|rx| {
                rx.recv()
                    .unwrap_or_else(|_| Err(self.death_error("coordinator worker died")))
            })
            .collect())
    }
}

/// Handle owning a detached worker thread (joined on drop).
pub struct Coordinator<J, R> {
    sub: Submitter<J, R>,
    health: Arc<CoordHealth>,
    worker: Option<thread::JoinHandle<()>>,
}

impl<J: Send + 'static, R: Send + 'static> Coordinator<J, R> {
    /// Spawn the worker owning the executor.
    pub fn spawn<E: BatchExec<J, R> + 'static>(exec: E) -> Coordinator<J, R> {
        Self::spawn_with_health(exec, Arc::new(CoordHealth::default()))
    }

    /// [`Coordinator::spawn`] recording fault-isolation counters into a
    /// caller-provided [`CoordHealth`] (shared across workers).
    pub fn spawn_with_health<E: BatchExec<J, R> + 'static>(
        exec: E,
        health: Arc<CoordHealth>,
    ) -> Coordinator<J, R> {
        let (tx, rx) = mpsc::channel::<Msg<J, R>>();
        let epitaph: Epitaph = Arc::new(Mutex::new(None));
        let ep = epitaph.clone();
        let h = health.clone();
        let worker = thread::spawn(move || worker_loop(exec, rx, ep, h));
        Coordinator { sub: Submitter { tx, epitaph }, health, worker: Some(worker) }
    }

    /// A clonable [`Submitter`] for concurrent submission threads.
    pub fn handle(&self) -> Submitter<J, R> {
        self.sub.clone()
    }

    /// Fault-isolation counters of this worker.
    pub fn health(&self) -> &Arc<CoordHealth> {
        &self.health
    }

    /// See [`Submitter::submit`].
    pub fn submit(&self, job: J) -> crate::Result<mpsc::Receiver<crate::Result<R>>> {
        self.sub.submit(job)
    }

    /// See [`Submitter::flush`].
    pub fn flush(&self) -> crate::Result<()> {
        self.sub.flush()
    }

    /// See [`Submitter::run_all`].
    pub fn run_all(&self, jobs: Vec<J>) -> crate::Result<Vec<R>> {
        self.sub.run_all(jobs)
    }
}

impl<J, R> Drop for Coordinator<J, R> {
    fn drop(&mut self) {
        let _ = self.sub.tx.send(Msg::Stop);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Run `f` against a coordinator whose executor may borrow local state
/// (no `'static` bound): the worker runs on a scoped thread and is
/// flushed, stopped and joined when `f` returns — or panics (a guard
/// sends the stop message on unwind so the scope join cannot deadlock).
pub fn scope<J: Send, R: Send, E: BatchExec<J, R>, T>(
    exec: E,
    f: impl FnOnce(&Submitter<J, R>) -> T,
) -> T {
    scope_with_health(exec, Arc::new(CoordHealth::default()), f)
}

/// [`scope`] recording fault-isolation counters into a caller-provided
/// [`CoordHealth`] — how `characterize_all` shares one counter set
/// across its per-stage workers.
pub fn scope_with_health<J: Send, R: Send, E: BatchExec<J, R>, T>(
    exec: E,
    health: Arc<CoordHealth>,
    f: impl FnOnce(&Submitter<J, R>) -> T,
) -> T {
    let (tx, rx) = mpsc::channel::<Msg<J, R>>();
    let epitaph: Epitaph = Arc::new(Mutex::new(None));
    let sub = Submitter { tx, epitaph: epitaph.clone() };
    thread::scope(|s| {
        s.spawn(move || worker_loop(exec, rx, epitaph, health));
        struct StopGuard<J, R>(mpsc::Sender<Msg<J, R>>);
        impl<J, R> Drop for StopGuard<J, R> {
            fn drop(&mut self) {
                let _ = self.0.send(Msg::Stop);
            }
        }
        let _guard = StopGuard(sub.tx.clone());
        f(&sub)
    })
}

fn worker_loop<J, R, E: BatchExec<J, R>>(
    mut exec: E,
    rx: mpsc::Receiver<Msg<J, R>>,
    epitaph: Epitaph,
    health: Arc<CoordHealth>,
) {
    let cap = exec.max_batch().max(1);
    let mut jobs: Vec<J> = Vec::new();
    let mut replies: Vec<mpsc::Sender<crate::Result<R>>> = Vec::new();
    loop {
        match rx.recv() {
            Ok(Msg::Job(j, reply)) => {
                jobs.push(j);
                replies.push(reply);
                if jobs.len() >= cap
                    && flush_batch(&mut exec, &mut jobs, &mut replies, &epitaph, &health)
                        .is_err()
                {
                    return;
                }
            }
            Ok(Msg::Flush) => {
                if flush_batch(&mut exec, &mut jobs, &mut replies, &epitaph, &health).is_err() {
                    return;
                }
            }
            Ok(Msg::Stop) | Err(_) => {
                let _ = flush_batch(&mut exec, &mut jobs, &mut replies, &epitaph, &health);
                return;
            }
        }
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>, n: usize) -> String {
    let what = payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".into());
    format!("executor panicked on a batch of {n}: {what}")
}

/// Run the pending batch with fault isolation.  `Err(())` means the
/// executor panicked and the worker must stop (its state may be
/// inconsistent); the panic payload is recorded as the epitaph first so
/// every later submitter sees the underlying failure, not a bare
/// "worker died".
///
/// On executor `Err` the batch is retried under the executor's
/// [`RetryPolicy`] (transient faults heal with no submitter-visible
/// effect), then bisected ([`bisect`]) so only culprit jobs carry
/// errors.  The happy path is untouched: one `run`, no extra work.
fn flush_batch<J, R, E: BatchExec<J, R>>(
    exec: &mut E,
    jobs: &mut Vec<J>,
    replies: &mut Vec<mpsc::Sender<crate::Result<R>>>,
    epitaph: &Epitaph,
    health: &CoordHealth,
) -> Result<(), ()> {
    if jobs.is_empty() {
        return Ok(());
    }
    let n = jobs.len();
    let policy = exec.retry_policy();
    let mut attempt = 0usize;
    let root = loop {
        match std::panic::catch_unwind(AssertUnwindSafe(|| exec.run(jobs))) {
            Ok(Ok(results)) if results.len() == n => {
                for (r, tx) in results.into_iter().zip(replies.drain(..)) {
                    let _ = tx.send(Ok(r));
                }
                jobs.clear();
                return Ok(());
            }
            Ok(Ok(results)) => {
                // a miscounting executor loses the job<->result
                // bijection — a contract violation, not a transient:
                // fail the whole batch rather than misroute results
                for tx in replies.drain(..) {
                    let _ = tx.send(Err(anyhow::anyhow!(
                        "executor returned {} results for {n} jobs",
                        results.len()
                    )));
                }
                jobs.clear();
                return Ok(());
            }
            Ok(Err(e)) => {
                if attempt < policy.max_retries {
                    attempt += 1;
                    health.retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(policy.backoff * attempt as u32);
                    continue;
                }
                break e;
            }
            Err(payload) => {
                let msg = panic_message(payload, n);
                *epitaph.lock().unwrap_or_else(|p| p.into_inner()) = Some(msg.clone());
                for tx in replies.drain(..) {
                    let _ = tx.send(Err(anyhow::anyhow!("{msg}")));
                }
                jobs.clear();
                return Err(());
            }
        }
    };
    // Retries exhausted: quarantine the culprit(s) by bisection so
    // healthy co-batched jobs still get their results.
    let bjobs = std::mem::take(jobs);
    let breplies = std::mem::take(replies);
    bisect(exec, &bjobs, &breplies, &root, epitaph, health)
}

/// Deliver results for a batch that failed as a whole: split it in
/// halves, run each, recurse into failing halves.  A still-failing
/// singleton is the culprit and gets a per-job error carrying the
/// executor's own cause; healthy jobs get their results.  Sub-runs are
/// **not** retried (the whole batch already was), bounding the extra
/// cost at `2·ceil(log2 n)` executions per poisoned job.
fn bisect<J, R, E: BatchExec<J, R>>(
    exec: &mut E,
    jobs: &[J],
    replies: &[mpsc::Sender<crate::Result<R>>],
    err: &anyhow::Error,
    epitaph: &Epitaph,
    health: &CoordHealth,
) -> Result<(), ()> {
    if jobs.len() <= 1 {
        if let Some(tx) = replies.first() {
            let _ = tx.send(Err(anyhow::anyhow!("job quarantined by batch bisection: {err:#}")));
        }
        return Ok(());
    }
    let mid = jobs.len() / 2;
    for (j, r) in [(&jobs[..mid], &replies[..mid]), (&jobs[mid..], &replies[mid..])] {
        health.bisect_execs.fetch_add(1, Ordering::Relaxed);
        match std::panic::catch_unwind(AssertUnwindSafe(|| exec.run(j))) {
            Ok(Ok(results)) if results.len() == j.len() => {
                for (res, tx) in results.into_iter().zip(r) {
                    let _ = tx.send(Ok(res));
                }
            }
            Ok(Ok(results)) => {
                for tx in r {
                    let _ = tx.send(Err(anyhow::anyhow!(
                        "executor returned {} results for {} jobs",
                        results.len(),
                        j.len()
                    )));
                }
            }
            Ok(Err(e)) => bisect(exec, j, r, &e, epitaph, health)?,
            Err(payload) => {
                // fatal as ever: record the epitaph, fail this half's
                // jobs; the other half's submitters see the epitaph
                // through their dead receivers
                let msg = panic_message(payload, j.len());
                *epitaph.lock().unwrap_or_else(|p| p.into_inner()) = Some(msg.clone());
                for tx in r {
                    let _ = tx.send(Err(anyhow::anyhow!("{msg}")));
                }
                return Err(());
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{check, Rng};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// Mock executor: result = job * 10; records batch sizes.
    struct Mock {
        cap: usize,
        batches: Arc<AtomicUsize>,
        max_seen: Arc<AtomicUsize>,
    }

    impl BatchExec<u64, u64> for Mock {
        fn run(&mut self, jobs: &[u64]) -> crate::Result<Vec<u64>> {
            self.batches.fetch_add(1, Ordering::SeqCst);
            self.max_seen.fetch_max(jobs.len(), Ordering::SeqCst);
            Ok(jobs.iter().map(|j| j * 10).collect())
        }
        fn max_batch(&self) -> usize {
            self.cap
        }
    }

    #[test]
    fn all_jobs_get_their_own_result() {
        // property: result routing is a bijection for random job counts
        check("bijection", 20, |rng: &mut Rng| {
            let n = 1 + rng.below(200);
            let cap = 1 + rng.below(64);
            let batches = Arc::new(AtomicUsize::new(0));
            let max_seen = Arc::new(AtomicUsize::new(0));
            let c = Coordinator::spawn(Mock { cap, batches: batches.clone(), max_seen: max_seen.clone() });
            let jobs: Vec<u64> = (0..n as u64).collect();
            let results = c.run_all(jobs).unwrap();
            assert_eq!(results.len(), n);
            for (i, r) in results.iter().enumerate() {
                assert_eq!(*r, i as u64 * 10);
            }
            assert!(max_seen.load(Ordering::SeqCst) <= cap);
        });
    }

    #[test]
    fn interleaved_submitters_across_flushes_get_bijective_results() {
        // property: concurrent submitters sharing one worker, each
        // submitting multiple chunks (each chunk forces a flush), all
        // get exactly their own results back regardless of how their
        // jobs interleave into shared batches
        check("interleaved bijection", 8, |rng: &mut Rng| {
            let cap = 1 + rng.below(16);
            let nthreads = 2 + rng.below(4);
            let chunks = 1 + rng.below(6);
            let batches = Arc::new(AtomicUsize::new(0));
            let max_seen = Arc::new(AtomicUsize::new(0));
            let c = Coordinator::spawn(Mock { cap, batches, max_seen: max_seen.clone() });
            std::thread::scope(|s| {
                for t in 0..nthreads {
                    let sub = c.handle();
                    s.spawn(move || {
                        let mut next = t as u64 * 1_000_000;
                        for k in 0..chunks {
                            let len = 1 + ((t + k) % 9) as u64;
                            let jobs: Vec<u64> = (next..next + len).collect();
                            next += len;
                            let res = sub.run_all(jobs.clone()).unwrap();
                            let want: Vec<u64> = jobs.iter().map(|j| j * 10).collect();
                            assert_eq!(res, want, "thread {t} chunk {k}");
                        }
                    });
                }
            });
            assert!(max_seen.load(Ordering::SeqCst) <= cap);
        });
    }

    /// Mock standing in for the window-splitting engine executors: one
    /// "artifact call" per distinct key (job >= 1000) in a handed batch.
    struct KeyedMock {
        cap: usize,
        calls: Arc<AtomicUsize>,
    }

    impl BatchExec<u64, u64> for KeyedMock {
        fn run(&mut self, jobs: &[u64]) -> crate::Result<Vec<u64>> {
            let distinct: std::collections::HashSet<bool> =
                jobs.iter().map(|&j| j >= 1000).collect();
            self.calls.fetch_add(distinct.len(), Ordering::SeqCst);
            Ok(jobs.iter().map(|j| j * 10).collect())
        }
        fn max_batch(&self) -> usize {
            self.cap
        }
    }

    #[test]
    fn grouped_submission_pays_exactly_ceil_per_group() {
        // cap-straddle regression: group A = 1 job, group B = 256 jobs,
        // cap = 256.  Plain run_all batches [A + 255 B] + [1 B], so a
        // key-splitting executor pays 3 calls; run_grouped's boundary
        // flush isolates A and the cost is ceil(1/256) + ceil(256/256)
        // = 2 — the bound characterize_all documents.
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Coordinator::spawn(KeyedMock { cap: 256, calls: calls.clone() });
        let a: Vec<u64> = vec![1];
        let b: Vec<u64> = (1000..1256).collect();
        let all: Vec<u64> = a.iter().chain(&b).copied().collect();
        let res = c.run_all(all.clone()).unwrap();
        assert_eq!(res, all.iter().map(|j| j * 10).collect::<Vec<_>>());
        assert_eq!(calls.load(Ordering::SeqCst), 3, "un-grouped submission splits the big group");
        calls.store(0, Ordering::SeqCst);
        let res = c.run_grouped(vec![a.clone(), b.clone()]).unwrap();
        let want: Vec<u64> = all.iter().map(|j| j * 10).collect();
        assert_eq!(res, want);
        assert_eq!(calls.load(Ordering::SeqCst), 2, "boundary flushes keep groups whole");
    }

    #[test]
    fn partial_batches_flush() {
        let batches = Arc::new(AtomicUsize::new(0));
        let max_seen = Arc::new(AtomicUsize::new(0));
        let c = Coordinator::spawn(Mock { cap: 100, batches: batches.clone(), max_seen });
        let results = c.run_all((0..5u64).collect()).unwrap();
        assert_eq!(results, vec![0, 10, 20, 30, 40]);
        assert_eq!(batches.load(Ordering::SeqCst), 1);
    }

    struct FailingMock;
    impl BatchExec<u64, u64> for FailingMock {
        fn run(&mut self, _jobs: &[u64]) -> crate::Result<Vec<u64>> {
            anyhow::bail!("injected failure")
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    #[test]
    fn executor_failure_propagates_to_every_submitter() {
        let c = Coordinator::spawn(FailingMock);
        let r = c.run_all(vec![1, 2, 3]);
        let e = format!("{:#}", r.unwrap_err());
        assert!(e.contains("injected failure"), "original error lost: {e}");
        // executor errors are recoverable: the worker keeps serving
        let r2 = c.run_all(vec![4]);
        assert!(format!("{:#}", r2.unwrap_err()).contains("injected failure"));
    }

    struct PanickingMock;
    impl BatchExec<u64, u64> for PanickingMock {
        fn run(&mut self, _jobs: &[u64]) -> crate::Result<Vec<u64>> {
            panic!("executor blew up on purpose")
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    #[test]
    fn panic_is_preserved_and_submit_after_death_errors() {
        let c = Coordinator::spawn(PanickingMock);
        let err = format!("{:#}", c.run_all(vec![1, 2]).unwrap_err());
        assert!(err.contains("blew up on purpose"), "panic cause lost: {err}");
        // the worker is dead now: submit must fail fast with the cause,
        // not hand out a receiver that never resolves
        let sub = c.handle();
        // allow the worker thread to exit so the channel closes
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            match sub.submit(7) {
                Err(e) => {
                    let e = format!("{e:#}");
                    assert!(e.contains("blew up on purpose"), "late submit lost the cause: {e}");
                    break;
                }
                Ok(rx) => {
                    // raced the worker's exit; the receiver must still
                    // resolve to the recorded failure, not hang
                    let got = rx.recv();
                    assert!(
                        got.map(|r| r.is_err()).unwrap_or(true),
                        "job accepted after executor panic"
                    );
                }
            }
            assert!(std::time::Instant::now() < deadline, "worker never died");
            std::thread::yield_now();
        }
    }

    struct MiscountingMock;
    impl BatchExec<u64, u64> for MiscountingMock {
        fn run(&mut self, jobs: &[u64]) -> crate::Result<Vec<u64>> {
            Ok(vec![0; jobs.len() / 2])
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    #[test]
    fn result_count_mismatch_fails_the_batch_instead_of_misrouting() {
        let c = Coordinator::spawn(MiscountingMock);
        let err = format!("{:#}", c.run_all(vec![1, 2, 3, 4]).unwrap_err());
        assert!(err.contains("2 results for 4 jobs"), "{err}");
    }

    #[test]
    fn drop_flushes_and_joins() {
        let batches = Arc::new(AtomicUsize::new(0));
        let max_seen = Arc::new(AtomicUsize::new(0));
        let c = Coordinator::spawn(Mock { cap: 10, batches: batches.clone(), max_seen });
        let rx = c.submit(7).unwrap();
        drop(c);
        assert_eq!(rx.recv().unwrap().unwrap(), 70);
    }

    #[test]
    fn scoped_coordinator_borrows_its_executor_state() {
        // an executor borrowing stack-local state (what the
        // characterize_all executors do with the shared runtime)
        let offsets: Vec<u64> = vec![100, 200];
        struct Borrowing<'a> {
            offsets: &'a [u64],
        }
        impl BatchExec<u64, u64> for Borrowing<'_> {
            fn run(&mut self, jobs: &[u64]) -> crate::Result<Vec<u64>> {
                Ok(jobs.iter().map(|j| j + self.offsets[0]).collect())
            }
            fn max_batch(&self) -> usize {
                4
            }
        }
        let out = scope(Borrowing { offsets: &offsets }, |sub| {
            sub.run_all(vec![1, 2, 3, 4, 5]).unwrap()
        });
        assert_eq!(out, vec![101, 102, 103, 104, 105]);
    }

    /// Mock with one poisoned job value: any batch containing it fails
    /// (persistently — retries don't help), everything else succeeds.
    struct PoisonedMock {
        poison: u64,
        runs: Arc<AtomicUsize>,
    }
    impl BatchExec<u64, u64> for PoisonedMock {
        fn run(&mut self, jobs: &[u64]) -> crate::Result<Vec<u64>> {
            self.runs.fetch_add(1, Ordering::SeqCst);
            anyhow::ensure!(!jobs.contains(&self.poison), "poisoned job {}", self.poison);
            Ok(jobs.iter().map(|j| j * 10).collect())
        }
        fn max_batch(&self) -> usize {
            64
        }
        fn retry_policy(&self) -> RetryPolicy {
            RetryPolicy::none()
        }
    }

    #[test]
    fn bisection_quarantines_the_culprit_and_heals_cobatched_jobs() {
        let runs = Arc::new(AtomicUsize::new(0));
        let health = Arc::new(CoordHealth::default());
        let c = Coordinator::spawn_with_health(
            PoisonedMock { poison: 13, runs: runs.clone() },
            health.clone(),
        );
        let jobs: Vec<u64> = (0..32).collect();
        let results = c.handle().run_grouped_each(vec![jobs.clone()]).unwrap();
        assert_eq!(results.len(), 32);
        for (i, r) in results.iter().enumerate() {
            if i == 13 {
                let e = format!("{:#}", r.as_ref().unwrap_err());
                assert!(e.contains("quarantined"), "{e}");
                assert!(e.contains("poisoned job 13"), "culprit cause lost: {e}");
            } else {
                assert_eq!(*r.as_ref().unwrap(), i as u64 * 10, "healthy job {i} lost");
            }
        }
        // cost bound: 1 failing full run + ≤ 2·ceil(log2 32) bisection runs
        let bisects = health.bisect_execs();
        assert!(bisects >= 2 && bisects <= 10, "bisect cost {bisects} out of bound");
        assert_eq!(runs.load(Ordering::SeqCst) as u64, 1 + bisects);
        assert_eq!(health.retries(), 0, "RetryPolicy::none must skip retries");
    }

    /// Mock that fails its first N run attempts, then succeeds — the
    /// transient-fault shape retries are for.
    struct TransientMock {
        failures_left: usize,
        runs: Arc<AtomicUsize>,
    }
    impl BatchExec<u64, u64> for TransientMock {
        fn run(&mut self, jobs: &[u64]) -> crate::Result<Vec<u64>> {
            self.runs.fetch_add(1, Ordering::SeqCst);
            if self.failures_left > 0 {
                self.failures_left -= 1;
                anyhow::bail!("transient hiccup");
            }
            Ok(jobs.iter().map(|j| j * 10).collect())
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    #[test]
    fn transient_failures_heal_invisibly_under_retry() {
        let runs = Arc::new(AtomicUsize::new(0));
        let health = Arc::new(CoordHealth::default());
        let c = Coordinator::spawn_with_health(
            TransientMock { failures_left: 1, runs: runs.clone() },
            health.clone(),
        );
        // submitters never see the transient: plain Ok results
        assert_eq!(c.run_all(vec![1, 2, 3]).unwrap(), vec![10, 20, 30]);
        assert_eq!(health.retries(), 1);
        assert_eq!(health.bisect_execs(), 0);
        assert_eq!(runs.load(Ordering::SeqCst), 2);
        // and a healthy follow-up batch pays exactly one run
        assert_eq!(c.run_all(vec![4]).unwrap(), vec![40]);
        assert_eq!(runs.load(Ordering::SeqCst), 3);
        assert_eq!(health.retries(), 1, "no retries on the healthy batch");
    }

    #[test]
    fn flush_to_a_dead_worker_fails_fast_with_the_epitaph() {
        // regression: flush() used to swallow the send error, so
        // run_grouped on a dead worker relied on recv semantics alone
        let c = Coordinator::spawn(PanickingMock);
        let _ = c.run_all(vec![1, 2]); // kills the worker
        let sub = c.handle();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            // the empty group exercises the boundary flush alone (no
            // submits), the path the old code silently swallowed
            match sub.run_grouped(vec![Vec::new()]) {
                Err(e) => {
                    let e = format!("{e:#}");
                    assert!(e.contains("blew up on purpose"), "flush lost the epitaph: {e}");
                    break;
                }
                Ok(r) => assert!(r.is_empty(), "results from a dead worker"),
            }
            assert!(std::time::Instant::now() < deadline, "worker never died");
            std::thread::yield_now();
        }
    }

    /// Mock that succeeds on its first batch and panics on the second.
    struct SecondBatchPanicMock {
        batches: usize,
    }
    impl BatchExec<u64, u64> for SecondBatchPanicMock {
        fn run(&mut self, jobs: &[u64]) -> crate::Result<Vec<u64>> {
            self.batches += 1;
            if self.batches >= 2 {
                panic!("second batch blew up");
            }
            Ok(jobs.iter().map(|j| j * 10).collect())
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    #[test]
    fn panic_after_partial_flush_preserves_delivered_results() {
        let c = Coordinator::spawn(SecondBatchPanicMock { batches: 0 });
        let sub = c.handle();
        // group 1: submitted, flushed and delivered before the panic
        let first: Vec<_> = (0..3u64).map(|j| sub.submit(j).unwrap()).collect();
        sub.flush().unwrap();
        for (i, rx) in first.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().unwrap(), i as u64 * 10, "first group's results lost");
        }
        // group 2: the executor panics — in-flight submitters get the
        // epitaph as their error
        let rx4 = sub.submit(4).unwrap();
        let rx5 = sub.submit(5).unwrap();
        let _ = sub.flush(); // may or may not outrace the worker's death
        for rx in [rx4, rx5] {
            let got = rx.recv();
            let e = match got {
                Ok(r) => format!("{:#}", r.unwrap_err()),
                // sender dropped without a reply: the submitter-side
                // death_error path reports the epitaph instead
                Err(_) => format!("{:#}", sub.death_error("worker died")),
            };
            assert!(e.contains("second batch blew up"), "in-flight job lost the cause: {e}");
        }
        // late submits fail fast with the same cause
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            match sub.submit(9) {
                Err(e) => {
                    assert!(
                        format!("{e:#}").contains("second batch blew up"),
                        "late submit lost the cause: {e:#}"
                    );
                    break;
                }
                Ok(rx) => {
                    let got = rx.recv();
                    assert!(got.map(|r| r.is_err()).unwrap_or(true));
                }
            }
            assert!(std::time::Instant::now() < deadline, "worker never died");
            std::thread::yield_now();
        }
    }

    #[test]
    fn bisection_isolates_multiple_poisoned_jobs() {
        // property: for random batch sizes and up to 3 poisoned values,
        // exactly the poisoned jobs error and all others succeed
        check("multi-poison bisection", 10, |rng: &mut Rng| {
            let n = 2 + rng.below(60);
            let poisons: std::collections::HashSet<u64> =
                (0..1 + rng.below(3)).map(|_| rng.below(n) as u64).collect();
            struct MultiPoison {
                poisons: std::collections::HashSet<u64>,
            }
            impl BatchExec<u64, u64> for MultiPoison {
                fn run(&mut self, jobs: &[u64]) -> crate::Result<Vec<u64>> {
                    anyhow::ensure!(
                        !jobs.iter().any(|j| self.poisons.contains(j)),
                        "poisoned"
                    );
                    Ok(jobs.iter().map(|j| j * 10).collect())
                }
                fn max_batch(&self) -> usize {
                    64
                }
                fn retry_policy(&self) -> RetryPolicy {
                    RetryPolicy::none()
                }
            }
            let c = Coordinator::spawn(MultiPoison { poisons: poisons.clone() });
            let results = c
                .handle()
                .run_grouped_each(vec![(0..n as u64).collect::<Vec<_>>()])
                .unwrap();
            for (i, r) in results.iter().enumerate() {
                if poisons.contains(&(i as u64)) {
                    assert!(r.is_err(), "poisoned job {i} not quarantined");
                } else {
                    assert_eq!(*r.as_ref().unwrap(), i as u64 * 10);
                }
            }
        });
    }
}
