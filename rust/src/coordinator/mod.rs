//! DSE job coordinator: batches design-point jobs onto the (single)
//! PJRT runtime.
//!
//! The paper's contribution is the compiler, so L3 coordination is the
//! "thin driver" case: a bounded job queue feeding one executor thread
//! that assembles batches up to the artifact batch size.  The batching
//! logic is generic over the executor so its invariants (no job lost,
//! results map back to submitters in order, batches never exceed the
//! cap) are property-tested with a mock.

use std::sync::mpsc;
use std::thread;

/// A batch executor: runs a slice of jobs, returns one result per job
/// in order.  The PJRT-backed implementation wraps runtime::engines.
pub trait BatchExec<J, R>: Send {
    fn run(&mut self, jobs: &[J]) -> crate::Result<Vec<R>>;
    fn max_batch(&self) -> usize;
}

enum Msg<J, R> {
    Job(J, mpsc::Sender<crate::Result<R>>),
    Flush,
    Stop,
}

/// Handle for submitting jobs.
pub struct Coordinator<J, R> {
    tx: mpsc::Sender<Msg<J, R>>,
    worker: Option<thread::JoinHandle<()>>,
}

impl<J: Send + 'static, R: Send + 'static> Coordinator<J, R> {
    /// Spawn the worker owning the executor.
    pub fn spawn<E: BatchExec<J, R> + 'static>(mut exec: E) -> Coordinator<J, R> {
        let (tx, rx) = mpsc::channel::<Msg<J, R>>();
        let worker = thread::spawn(move || {
            let cap = exec.max_batch().max(1);
            let mut jobs: Vec<J> = Vec::new();
            let mut replies: Vec<mpsc::Sender<crate::Result<R>>> = Vec::new();
            let flush = |jobs: &mut Vec<J>, replies: &mut Vec<mpsc::Sender<crate::Result<R>>>, exec: &mut E| {
                if jobs.is_empty() {
                    return;
                }
                match exec.run(jobs) {
                    Ok(results) => {
                        for (r, tx) in results.into_iter().zip(replies.drain(..)) {
                            let _ = tx.send(Ok(r));
                        }
                    }
                    Err(e) => {
                        for tx in replies.drain(..) {
                            let _ = tx.send(Err(anyhow::anyhow!("batch failed: {e}")));
                        }
                    }
                }
                jobs.clear();
            };
            loop {
                match rx.recv() {
                    Ok(Msg::Job(j, reply)) => {
                        jobs.push(j);
                        replies.push(reply);
                        if jobs.len() >= cap {
                            flush(&mut jobs, &mut replies, &mut exec);
                        }
                    }
                    Ok(Msg::Flush) => flush(&mut jobs, &mut replies, &mut exec),
                    Ok(Msg::Stop) | Err(_) => {
                        flush(&mut jobs, &mut replies, &mut exec);
                        break;
                    }
                }
            }
        });
        Coordinator { tx, worker: Some(worker) }
    }

    /// Submit a job; returns a receiver for its result.
    pub fn submit(&self, job: J) -> mpsc::Receiver<crate::Result<R>> {
        let (rtx, rrx) = mpsc::channel();
        let _ = self.tx.send(Msg::Job(job, rtx));
        rrx
    }

    /// Force the pending partial batch to execute.
    pub fn flush(&self) {
        let _ = self.tx.send(Msg::Flush);
    }

    /// Submit many jobs and wait for all results (flushes).
    pub fn run_all(&self, jobs: Vec<J>) -> crate::Result<Vec<R>> {
        let rxs: Vec<_> = jobs.into_iter().map(|j| self.submit(j)).collect();
        self.flush();
        rxs.into_iter()
            .map(|rx| rx.recv().map_err(|_| anyhow::anyhow!("worker died"))?)
            .collect()
    }
}

impl<J, R> Drop for Coordinator<J, R> {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{check, Rng};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// Mock executor: result = job * 10; records batch sizes.
    struct Mock {
        cap: usize,
        batches: Arc<AtomicUsize>,
        max_seen: Arc<AtomicUsize>,
    }

    impl BatchExec<u64, u64> for Mock {
        fn run(&mut self, jobs: &[u64]) -> crate::Result<Vec<u64>> {
            self.batches.fetch_add(1, Ordering::SeqCst);
            self.max_seen.fetch_max(jobs.len(), Ordering::SeqCst);
            Ok(jobs.iter().map(|j| j * 10).collect())
        }
        fn max_batch(&self) -> usize {
            self.cap
        }
    }

    #[test]
    fn all_jobs_get_their_own_result() {
        // property: result routing is a bijection for random job counts
        check("bijection", 20, |rng: &mut Rng| {
            let n = 1 + rng.below(200);
            let cap = 1 + rng.below(64);
            let batches = Arc::new(AtomicUsize::new(0));
            let max_seen = Arc::new(AtomicUsize::new(0));
            let c = Coordinator::spawn(Mock { cap, batches: batches.clone(), max_seen: max_seen.clone() });
            let jobs: Vec<u64> = (0..n as u64).collect();
            let results = c.run_all(jobs).unwrap();
            assert_eq!(results.len(), n);
            for (i, r) in results.iter().enumerate() {
                assert_eq!(*r, i as u64 * 10);
            }
            assert!(max_seen.load(Ordering::SeqCst) <= cap);
        });
    }

    #[test]
    fn partial_batches_flush() {
        let batches = Arc::new(AtomicUsize::new(0));
        let max_seen = Arc::new(AtomicUsize::new(0));
        let c = Coordinator::spawn(Mock { cap: 100, batches: batches.clone(), max_seen });
        let results = c.run_all((0..5u64).collect()).unwrap();
        assert_eq!(results, vec![0, 10, 20, 30, 40]);
        assert_eq!(batches.load(Ordering::SeqCst), 1);
    }

    struct FailingMock;
    impl BatchExec<u64, u64> for FailingMock {
        fn run(&mut self, _jobs: &[u64]) -> crate::Result<Vec<u64>> {
            anyhow::bail!("injected failure")
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    #[test]
    fn executor_failure_propagates_to_every_submitter() {
        let c = Coordinator::spawn(FailingMock);
        let r = c.run_all(vec![1, 2, 3]);
        assert!(r.is_err());
    }

    #[test]
    fn drop_flushes_and_joins() {
        let batches = Arc::new(AtomicUsize::new(0));
        let max_seen = Arc::new(AtomicUsize::new(0));
        let c = Coordinator::spawn(Mock { cap: 10, batches: batches.clone(), max_seen });
        let rx = c.submit(7);
        drop(c);
        assert_eq!(rx.recv().unwrap().unwrap(), 70);
    }
}
