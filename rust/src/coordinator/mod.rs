//! DSE job coordinator: batches design-point jobs onto the (single)
//! PJRT runtime.
//!
//! The paper's contribution is the compiler, so L3 coordination is the
//! "thin driver" case: a bounded job queue feeding one executor thread
//! that assembles batches up to the artifact batch size.  The batching
//! logic is generic over the executor so its invariants are
//! property-tested with a mock.
//!
//! # Batching invariants
//!
//! * **No job lost, no result misrouted** — every submitted job
//!   produces exactly one result, delivered to its submitter's
//!   receiver in submission order; a result-count mismatch from the
//!   executor fails the whole batch rather than shifting results.
//! * **The cap is a hard ceiling** — a worker batch never exceeds
//!   [`BatchExec::max_batch`] (the artifact batch size from the
//!   manifest); the executor may *subdivide* further (e.g. by
//!   transient window or read flavor — see
//!   [`crate::characterize::batch`]) but never sees more jobs than the
//!   cap at once.
//! * **Group boundaries are flush boundaries** —
//!   [`Submitter::run_grouped`] flushes between groups, so no worker
//!   batch spans two homogeneity groups and the execution count is
//!   exactly `sum(ceil(group_len / cap))` over the groups — the
//!   occupancy model the benches assert
//!   ([`crate::characterize::batch::calls_for`]).
//!
//! Two spawn modes:
//! * [`Coordinator::spawn`] — detached worker for `'static` executors
//!   (owns its runtime handle; lives as long as the coordinator);
//! * [`scope`] — scoped worker for executors that *borrow* (the
//!   [`crate::characterize::characterize_all`] executors borrow the
//!   shared runtime), joined when the closure returns.
//!
//! Failure semantics: an executor `Err` is recoverable — every
//! submitter of the failed batch receives the executor's own error and
//! the worker keeps serving.  An executor *panic* is fatal: the panic
//! payload is recorded as the worker's epitaph, in-flight submitters
//! get it as an error, and later [`Submitter::submit`] calls fail fast
//! with the same underlying cause instead of handing out a receiver
//! that can only ever report a bare "worker died".

use std::panic::AssertUnwindSafe;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// A batch executor: runs a slice of jobs, returns one result per job
/// in order.  The PJRT-backed implementations wrap runtime::engines
/// (see [`crate::characterize::batch`]); an executor may subdivide the
/// handed batch internally (e.g. by transient window or read flavor)
/// as long as results come back positionally.
pub trait BatchExec<J, R>: Send {
    fn run(&mut self, jobs: &[J]) -> crate::Result<Vec<R>>;
    fn max_batch(&self) -> usize;
}

enum Msg<J, R> {
    Job(J, mpsc::Sender<crate::Result<R>>),
    Flush,
    Stop,
}

/// Why the worker stopped serving (executor panic), shared so late
/// submitters can report the original failure.
type Epitaph = Arc<Mutex<Option<String>>>;

/// Clonable submission handle.  `mpsc::Sender` is `Send` but not
/// `Sync`, so concurrent submitters (DSE sweep workers) each take
/// their own clone via [`Coordinator::handle`].
pub struct Submitter<J, R> {
    tx: mpsc::Sender<Msg<J, R>>,
    epitaph: Epitaph,
}

impl<J, R> Clone for Submitter<J, R> {
    fn clone(&self) -> Self {
        Submitter { tx: self.tx.clone(), epitaph: self.epitaph.clone() }
    }
}

impl<J: Send, R: Send> Submitter<J, R> {
    fn death_error(&self, context: &str) -> anyhow::Error {
        match self.epitaph.lock().unwrap_or_else(|p| p.into_inner()).clone() {
            Some(why) => anyhow::anyhow!("{context}: {why}"),
            None => anyhow::anyhow!("{context}: worker stopped"),
        }
    }

    /// Submit a job; returns a receiver for its result.  Fails fast —
    /// carrying the worker's recorded failure cause — once the worker
    /// is gone, instead of returning a forever-dead receiver.
    pub fn submit(&self, job: J) -> crate::Result<mpsc::Receiver<crate::Result<R>>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Job(job, rtx))
            .map_err(|_| self.death_error("coordinator worker is gone"))?;
        Ok(rrx)
    }

    /// Force the pending partial batch to execute.
    pub fn flush(&self) {
        let _ = self.tx.send(Msg::Flush);
    }

    /// Submit many jobs and wait for all results (flushes).
    pub fn run_all(&self, jobs: Vec<J>) -> crate::Result<Vec<R>> {
        self.run_grouped(std::iter::once(jobs))
    }

    /// Submit jobs group by group with a flush at every group boundary,
    /// then wait for all results (in submission order).  Boundary
    /// flushes keep a worker batch from spanning two groups — jobs of
    /// different groups can never share an artifact execution anyway
    /// (different window/waveform), so this costs nothing and makes the
    /// execution count exactly `sum(ceil(group_len / cap))`.
    pub fn run_grouped(
        &self,
        groups: impl IntoIterator<Item = Vec<J>>,
    ) -> crate::Result<Vec<R>> {
        let mut rxs = Vec::new();
        for group in groups {
            for j in group {
                rxs.push(self.submit(j)?);
            }
            self.flush();
        }
        rxs.into_iter()
            .map(|rx| rx.recv().map_err(|_| self.death_error("coordinator worker died"))?)
            .collect()
    }
}

/// Handle owning a detached worker thread (joined on drop).
pub struct Coordinator<J, R> {
    sub: Submitter<J, R>,
    worker: Option<thread::JoinHandle<()>>,
}

impl<J: Send + 'static, R: Send + 'static> Coordinator<J, R> {
    /// Spawn the worker owning the executor.
    pub fn spawn<E: BatchExec<J, R> + 'static>(exec: E) -> Coordinator<J, R> {
        let (tx, rx) = mpsc::channel::<Msg<J, R>>();
        let epitaph: Epitaph = Arc::new(Mutex::new(None));
        let ep = epitaph.clone();
        let worker = thread::spawn(move || worker_loop(exec, rx, ep));
        Coordinator { sub: Submitter { tx, epitaph }, worker: Some(worker) }
    }

    /// A clonable [`Submitter`] for concurrent submission threads.
    pub fn handle(&self) -> Submitter<J, R> {
        self.sub.clone()
    }

    /// See [`Submitter::submit`].
    pub fn submit(&self, job: J) -> crate::Result<mpsc::Receiver<crate::Result<R>>> {
        self.sub.submit(job)
    }

    /// See [`Submitter::flush`].
    pub fn flush(&self) {
        self.sub.flush()
    }

    /// See [`Submitter::run_all`].
    pub fn run_all(&self, jobs: Vec<J>) -> crate::Result<Vec<R>> {
        self.sub.run_all(jobs)
    }
}

impl<J, R> Drop for Coordinator<J, R> {
    fn drop(&mut self) {
        let _ = self.sub.tx.send(Msg::Stop);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Run `f` against a coordinator whose executor may borrow local state
/// (no `'static` bound): the worker runs on a scoped thread and is
/// flushed, stopped and joined when `f` returns — or panics (a guard
/// sends the stop message on unwind so the scope join cannot deadlock).
pub fn scope<J: Send, R: Send, E: BatchExec<J, R>, T>(
    exec: E,
    f: impl FnOnce(&Submitter<J, R>) -> T,
) -> T {
    let (tx, rx) = mpsc::channel::<Msg<J, R>>();
    let epitaph: Epitaph = Arc::new(Mutex::new(None));
    let sub = Submitter { tx, epitaph: epitaph.clone() };
    thread::scope(|s| {
        s.spawn(move || worker_loop(exec, rx, epitaph));
        struct StopGuard<J, R>(mpsc::Sender<Msg<J, R>>);
        impl<J, R> Drop for StopGuard<J, R> {
            fn drop(&mut self) {
                let _ = self.0.send(Msg::Stop);
            }
        }
        let _guard = StopGuard(sub.tx.clone());
        f(&sub)
    })
}

fn worker_loop<J, R, E: BatchExec<J, R>>(
    mut exec: E,
    rx: mpsc::Receiver<Msg<J, R>>,
    epitaph: Epitaph,
) {
    let cap = exec.max_batch().max(1);
    let mut jobs: Vec<J> = Vec::new();
    let mut replies: Vec<mpsc::Sender<crate::Result<R>>> = Vec::new();
    loop {
        match rx.recv() {
            Ok(Msg::Job(j, reply)) => {
                jobs.push(j);
                replies.push(reply);
                if jobs.len() >= cap
                    && flush_batch(&mut exec, &mut jobs, &mut replies, &epitaph).is_err()
                {
                    return;
                }
            }
            Ok(Msg::Flush) => {
                if flush_batch(&mut exec, &mut jobs, &mut replies, &epitaph).is_err() {
                    return;
                }
            }
            Ok(Msg::Stop) | Err(_) => {
                let _ = flush_batch(&mut exec, &mut jobs, &mut replies, &epitaph);
                return;
            }
        }
    }
}

/// Run the pending batch.  `Err(())` means the executor panicked and
/// the worker must stop (its state may be inconsistent); the panic
/// payload is recorded as the epitaph first so every later submitter
/// sees the underlying failure, not a bare "worker died".
fn flush_batch<J, R, E: BatchExec<J, R>>(
    exec: &mut E,
    jobs: &mut Vec<J>,
    replies: &mut Vec<mpsc::Sender<crate::Result<R>>>,
    epitaph: &Epitaph,
) -> Result<(), ()> {
    if jobs.is_empty() {
        return Ok(());
    }
    let n = jobs.len();
    match std::panic::catch_unwind(AssertUnwindSafe(|| exec.run(jobs))) {
        Ok(Ok(results)) if results.len() == n => {
            for (r, tx) in results.into_iter().zip(replies.drain(..)) {
                let _ = tx.send(Ok(r));
            }
            jobs.clear();
            Ok(())
        }
        Ok(Ok(results)) => {
            // a miscounting executor loses the job<->result bijection;
            // fail the whole batch rather than misroute results
            for tx in replies.drain(..) {
                let _ = tx.send(Err(anyhow::anyhow!(
                    "executor returned {} results for {n} jobs",
                    results.len()
                )));
            }
            jobs.clear();
            Ok(())
        }
        Ok(Err(e)) => {
            for tx in replies.drain(..) {
                let _ = tx.send(Err(anyhow::anyhow!("batch of {n} failed: {e:#}")));
            }
            jobs.clear();
            Ok(())
        }
        Err(payload) => {
            let what = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".into());
            let msg = format!("executor panicked on a batch of {n}: {what}");
            *epitaph.lock().unwrap_or_else(|p| p.into_inner()) = Some(msg.clone());
            for tx in replies.drain(..) {
                let _ = tx.send(Err(anyhow::anyhow!("{msg}")));
            }
            jobs.clear();
            Err(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{check, Rng};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// Mock executor: result = job * 10; records batch sizes.
    struct Mock {
        cap: usize,
        batches: Arc<AtomicUsize>,
        max_seen: Arc<AtomicUsize>,
    }

    impl BatchExec<u64, u64> for Mock {
        fn run(&mut self, jobs: &[u64]) -> crate::Result<Vec<u64>> {
            self.batches.fetch_add(1, Ordering::SeqCst);
            self.max_seen.fetch_max(jobs.len(), Ordering::SeqCst);
            Ok(jobs.iter().map(|j| j * 10).collect())
        }
        fn max_batch(&self) -> usize {
            self.cap
        }
    }

    #[test]
    fn all_jobs_get_their_own_result() {
        // property: result routing is a bijection for random job counts
        check("bijection", 20, |rng: &mut Rng| {
            let n = 1 + rng.below(200);
            let cap = 1 + rng.below(64);
            let batches = Arc::new(AtomicUsize::new(0));
            let max_seen = Arc::new(AtomicUsize::new(0));
            let c = Coordinator::spawn(Mock { cap, batches: batches.clone(), max_seen: max_seen.clone() });
            let jobs: Vec<u64> = (0..n as u64).collect();
            let results = c.run_all(jobs).unwrap();
            assert_eq!(results.len(), n);
            for (i, r) in results.iter().enumerate() {
                assert_eq!(*r, i as u64 * 10);
            }
            assert!(max_seen.load(Ordering::SeqCst) <= cap);
        });
    }

    #[test]
    fn interleaved_submitters_across_flushes_get_bijective_results() {
        // property: concurrent submitters sharing one worker, each
        // submitting multiple chunks (each chunk forces a flush), all
        // get exactly their own results back regardless of how their
        // jobs interleave into shared batches
        check("interleaved bijection", 8, |rng: &mut Rng| {
            let cap = 1 + rng.below(16);
            let nthreads = 2 + rng.below(4);
            let chunks = 1 + rng.below(6);
            let batches = Arc::new(AtomicUsize::new(0));
            let max_seen = Arc::new(AtomicUsize::new(0));
            let c = Coordinator::spawn(Mock { cap, batches, max_seen: max_seen.clone() });
            std::thread::scope(|s| {
                for t in 0..nthreads {
                    let sub = c.handle();
                    s.spawn(move || {
                        let mut next = t as u64 * 1_000_000;
                        for k in 0..chunks {
                            let len = 1 + ((t + k) % 9) as u64;
                            let jobs: Vec<u64> = (next..next + len).collect();
                            next += len;
                            let res = sub.run_all(jobs.clone()).unwrap();
                            let want: Vec<u64> = jobs.iter().map(|j| j * 10).collect();
                            assert_eq!(res, want, "thread {t} chunk {k}");
                        }
                    });
                }
            });
            assert!(max_seen.load(Ordering::SeqCst) <= cap);
        });
    }

    /// Mock standing in for the window-splitting engine executors: one
    /// "artifact call" per distinct key (job >= 1000) in a handed batch.
    struct KeyedMock {
        cap: usize,
        calls: Arc<AtomicUsize>,
    }

    impl BatchExec<u64, u64> for KeyedMock {
        fn run(&mut self, jobs: &[u64]) -> crate::Result<Vec<u64>> {
            let distinct: std::collections::HashSet<bool> =
                jobs.iter().map(|&j| j >= 1000).collect();
            self.calls.fetch_add(distinct.len(), Ordering::SeqCst);
            Ok(jobs.iter().map(|j| j * 10).collect())
        }
        fn max_batch(&self) -> usize {
            self.cap
        }
    }

    #[test]
    fn grouped_submission_pays_exactly_ceil_per_group() {
        // cap-straddle regression: group A = 1 job, group B = 256 jobs,
        // cap = 256.  Plain run_all batches [A + 255 B] + [1 B], so a
        // key-splitting executor pays 3 calls; run_grouped's boundary
        // flush isolates A and the cost is ceil(1/256) + ceil(256/256)
        // = 2 — the bound characterize_all documents.
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Coordinator::spawn(KeyedMock { cap: 256, calls: calls.clone() });
        let a: Vec<u64> = vec![1];
        let b: Vec<u64> = (1000..1256).collect();
        let all: Vec<u64> = a.iter().chain(&b).copied().collect();
        let res = c.run_all(all.clone()).unwrap();
        assert_eq!(res, all.iter().map(|j| j * 10).collect::<Vec<_>>());
        assert_eq!(calls.load(Ordering::SeqCst), 3, "un-grouped submission splits the big group");
        calls.store(0, Ordering::SeqCst);
        let res = c.run_grouped(vec![a.clone(), b.clone()]).unwrap();
        let want: Vec<u64> = all.iter().map(|j| j * 10).collect();
        assert_eq!(res, want);
        assert_eq!(calls.load(Ordering::SeqCst), 2, "boundary flushes keep groups whole");
    }

    #[test]
    fn partial_batches_flush() {
        let batches = Arc::new(AtomicUsize::new(0));
        let max_seen = Arc::new(AtomicUsize::new(0));
        let c = Coordinator::spawn(Mock { cap: 100, batches: batches.clone(), max_seen });
        let results = c.run_all((0..5u64).collect()).unwrap();
        assert_eq!(results, vec![0, 10, 20, 30, 40]);
        assert_eq!(batches.load(Ordering::SeqCst), 1);
    }

    struct FailingMock;
    impl BatchExec<u64, u64> for FailingMock {
        fn run(&mut self, _jobs: &[u64]) -> crate::Result<Vec<u64>> {
            anyhow::bail!("injected failure")
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    #[test]
    fn executor_failure_propagates_to_every_submitter() {
        let c = Coordinator::spawn(FailingMock);
        let r = c.run_all(vec![1, 2, 3]);
        let e = format!("{:#}", r.unwrap_err());
        assert!(e.contains("injected failure"), "original error lost: {e}");
        // executor errors are recoverable: the worker keeps serving
        let r2 = c.run_all(vec![4]);
        assert!(format!("{:#}", r2.unwrap_err()).contains("injected failure"));
    }

    struct PanickingMock;
    impl BatchExec<u64, u64> for PanickingMock {
        fn run(&mut self, _jobs: &[u64]) -> crate::Result<Vec<u64>> {
            panic!("executor blew up on purpose")
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    #[test]
    fn panic_is_preserved_and_submit_after_death_errors() {
        let c = Coordinator::spawn(PanickingMock);
        let err = format!("{:#}", c.run_all(vec![1, 2]).unwrap_err());
        assert!(err.contains("blew up on purpose"), "panic cause lost: {err}");
        // the worker is dead now: submit must fail fast with the cause,
        // not hand out a receiver that never resolves
        let sub = c.handle();
        // allow the worker thread to exit so the channel closes
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            match sub.submit(7) {
                Err(e) => {
                    let e = format!("{e:#}");
                    assert!(e.contains("blew up on purpose"), "late submit lost the cause: {e}");
                    break;
                }
                Ok(rx) => {
                    // raced the worker's exit; the receiver must still
                    // resolve to the recorded failure, not hang
                    let got = rx.recv();
                    assert!(
                        got.map(|r| r.is_err()).unwrap_or(true),
                        "job accepted after executor panic"
                    );
                }
            }
            assert!(std::time::Instant::now() < deadline, "worker never died");
            std::thread::yield_now();
        }
    }

    struct MiscountingMock;
    impl BatchExec<u64, u64> for MiscountingMock {
        fn run(&mut self, jobs: &[u64]) -> crate::Result<Vec<u64>> {
            Ok(vec![0; jobs.len() / 2])
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    #[test]
    fn result_count_mismatch_fails_the_batch_instead_of_misrouting() {
        let c = Coordinator::spawn(MiscountingMock);
        let err = format!("{:#}", c.run_all(vec![1, 2, 3, 4]).unwrap_err());
        assert!(err.contains("2 results for 4 jobs"), "{err}");
    }

    #[test]
    fn drop_flushes_and_joins() {
        let batches = Arc::new(AtomicUsize::new(0));
        let max_seen = Arc::new(AtomicUsize::new(0));
        let c = Coordinator::spawn(Mock { cap: 10, batches: batches.clone(), max_seen });
        let rx = c.submit(7).unwrap();
        drop(c);
        assert_eq!(rx.recv().unwrap().unwrap(), 70);
    }

    #[test]
    fn scoped_coordinator_borrows_its_executor_state() {
        // an executor borrowing stack-local state (what the
        // characterize_all executors do with the shared runtime)
        let offsets: Vec<u64> = vec![100, 200];
        struct Borrowing<'a> {
            offsets: &'a [u64],
        }
        impl BatchExec<u64, u64> for Borrowing<'_> {
            fn run(&mut self, jobs: &[u64]) -> crate::Result<Vec<u64>> {
                Ok(jobs.iter().map(|j| j + self.offsets[0]).collect())
            }
            fn max_batch(&self) -> usize {
                4
            }
        }
        let out = scope(Borrowing { offsets: &offsets }, |sub| {
            sub.run_all(vec![1, 2, 3, 4, 5]).unwrap()
        });
        assert_eq!(out, vec![101, 102, 103, 104, 105]);
    }
}
