//! Structure-of-arrays batched EKV transient stepper: the production
//! hot path behind [`crate::runtime::NativeBackend`].
//!
//! Where [`super::transient`] advances one row through all time steps,
//! this module advances **all rows of a block per time step**.  Node
//! voltages, parameters, `cinv`, and stimulus amplitudes live in
//! contiguous column-major buffers (`buf[col * rows + j]` for row `j`),
//! so every inner loop is a flat, branch-light pass over `rows`
//! consecutive `f64`s that LLVM can autovectorize on the SSE2 baseline.
//!
//! # Fast transcendentals
//!
//! The scalar reference calls libm (`exp`, `ln_1p`) per device per
//! substep; those calls do not vectorize.  [`exp_fast`] /
//! [`sl_fast`] replace them with branch-free polynomial kernels
//! (magic-shift range reduction + degree-12 Taylor for `exp`,
//! `atanh`-form odd series for `ln(1+e)`), accurate to ~1e-15 relative
//! — far below the f32 output quantization, but **not bitwise equal**
//! to libm.  This is the one arithmetic difference between the SoA path
//! and the scalar reference; `tests/parity.rs` pins it to a documented
//! tolerance while batched-vs-singleton and engine-vs-direct-sim pins
//! stay bitwise *within* each path.
//!
//! # Early-exit masks
//!
//! Rows retire (their `v` freezes, via real selects — never arithmetic
//! masking, which would launder NaN) under three sound conditions:
//!
//! * **zero-param padding rows** are pre-retired by the caller: every
//!   stamp's current scales with a parameter, so their trace is
//!   constant `v0` exactly;
//! * **[`ExitPolicy::Settle`]** (Heun, uniform grids): a row whose `v`
//!   is a bitwise fixed point across a whole step from
//!   [`Schedule::fixed_from`] onward repeats that step verbatim
//!   forever, so freezing is bitwise-identical to integrating on;
//! * **[`ExitPolicy::FallingCross`]** (retention tails): a row retires
//!   once its watched node samples at or below the row threshold — the
//!   first crossing is already in the recorded trace, and a frozen
//!   tail can only add crossings *after* it — or once the rhs is
//!   exactly zero at every node under constant stimulus (an identity
//!   step for any dt).
//!
//! When every row of a block has retired the block exits the time loop
//! and forward-fills the remaining trace with the frozen state.

use super::{Integrator, Stamp, Template, PHI_T};

const LOG2E: f64 = 1.4426950408889634;
// ln(2) split hi + lo so `a - k*ln2` stays exact to the last bit.
const LN2_HI: f64 = 0.6931471803691238;
const LN2_LO: f64 = 1.9082149292705877e-10;
// 1.5 * 2^52: adding it rounds |x| < 2^51 to the nearest integer in
// the mantissa field (the classic magic-shift; avoids `f64::round`,
// which lowers to a libm call on the SSE2 baseline and kills
// vectorization).
const SHIFT: f64 = 6755399441055744.0;

/// Vectorizable `e^a` for `a <= 0` (clamped below at -708, where the
/// result underflows anyway).  Magic-shift range reduction to
/// `a = k*ln2 + r`, `|r| <= ln2/2`, degree-12 Taylor for `e^r`
/// (remainder ~1.8e-16), then an exponent-field rebuild for `2^k`.
/// No branches, no libm, no f64->i64 packed casts (AVX-512 only).
#[inline(always)]
pub fn exp_fast(a: f64) -> f64 {
    let a = a.max(-708.0);
    let kf = a * LOG2E;
    let kshift = kf + SHIFT;
    let k = kshift - SHIFT; // nearest integer to kf, exactly, as f64
    let r = (a - k * LN2_HI) - k * LN2_LO;
    let p = 1.0 / 479001600.0;
    let p = p * r + 1.0 / 39916800.0;
    let p = p * r + 1.0 / 3628800.0;
    let p = p * r + 1.0 / 362880.0;
    let p = p * r + 1.0 / 40320.0;
    let p = p * r + 1.0 / 5040.0;
    let p = p * r + 1.0 / 720.0;
    let p = p * r + 1.0 / 120.0;
    let p = p * r + 1.0 / 24.0;
    let p = p * r + 1.0 / 6.0;
    let p = p * r + 0.5;
    let p = p * r + 1.0;
    let p = p * r + 1.0;
    // kshift's low mantissa bits hold 2^51 + k; rebuild 2^k directly
    // in the exponent field (k in [-1022, 0] keeps the bias positive).
    let m = (kshift.to_bits() & 0x000F_FFFF_FFFF_FFFF) as i64;
    let k_int = m - (1i64 << 51);
    let scale = f64::from_bits(((1023 + k_int) as u64) << 52);
    p * scale
}

/// Vectorizable `ln(1 + e)` for `e` in `[0, 1]`, via the `atanh` form
/// `2*atanh(e/(e+2))`: the argument `w <= 1/3` makes the odd series in
/// `u = w^2 <= 1/9` converge with truncation ~1.5e-17 at the `u^16`
/// term.
#[inline(always)]
fn ln1p_atanh(e: f64) -> f64 {
    let w = e / (e + 2.0);
    let u = w * w;
    let s = 1.0 / 33.0;
    let s = s * u + 1.0 / 31.0;
    let s = s * u + 1.0 / 29.0;
    let s = s * u + 1.0 / 27.0;
    let s = s * u + 1.0 / 25.0;
    let s = s * u + 1.0 / 23.0;
    let s = s * u + 1.0 / 21.0;
    let s = s * u + 1.0 / 19.0;
    let s = s * u + 1.0 / 17.0;
    let s = s * u + 1.0 / 15.0;
    let s = s * u + 1.0 / 13.0;
    let s = s * u + 1.0 / 11.0;
    let s = s * u + 1.0 / 9.0;
    let s = s * u + 1.0 / 7.0;
    let s = s * u + 1.0 / 5.0;
    let s = s * u + 1.0 / 3.0;
    let s = s * u + 1.0;
    2.0 * w * s
}

/// Vectorizable `ln(1 + e^x)` (the EKV soft clamp): `max(x, 0) +
/// ln(1 + e^{-|x|})`.  Same laundering of NaN inputs as the scalar
/// path's clamps: `max`/`min` return the non-NaN operand, so a NaN
/// `vp` (zero-param rows, `n = 0`) yields the same finite value for
/// both the forward and reverse channels and their difference is an
/// exact zero.
#[inline(always)]
pub fn sl_fast(x: f64) -> f64 {
    x.max(0.0) + ln1p_atanh(exp_fast(-x.abs()))
}

/// EKV drain current on the fast kernels; mirrors [`super::mos_ids`]
/// term for term with [`sl_fast`] in place of the libm soft clamp.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
pub fn mos_ids_fast(
    vd: f64,
    vg: f64,
    vs: f64,
    kp: f64,
    vt: f64,
    n: f64,
    lam: f64,
    w_over_l: f64,
    sign: f64,
) -> f64 {
    let (vd_, vg_, vs_) = (sign * vd, sign * vg, sign * vs);
    let vp = (vg_ - vt) / n;
    let f = sl_fast((vp - vs_) / (2.0 * PHI_T));
    let r = sl_fast((vp - vd_) / (2.0 * PHI_T));
    let i_spec = 2.0 * n * kp * w_over_l * PHI_T * PHI_T;
    let clm = 1.0 + lam * (vd_ - vs_).abs();
    sign * i_spec * (f * f - r * r) * clm
}

/// The shared stimulus schedule plus two precomputed early-exit
/// horizons (backward bitwise scans, done once per execute).
pub struct Schedule<'a> {
    /// Per-step stimulus waveform rows (`steps x ns`).
    pub wave: &'a [Vec<f64>],
    /// Per-step stimulus slew rows (`steps x ns`).
    pub dwave: &'a [Vec<f64>],
    /// Per-step substep durations.
    pub dt: &'a [f64],
    /// First step index from which `wave` and `dwave` are bitwise
    /// constant through the end (stimulus quiescence; rhs==0 exits are
    /// only sound from here on).
    pub stim_const_from: usize,
    /// Like [`Self::stim_const_from`] but additionally requiring `dt`
    /// constant — the horizon from which a bitwise fixed point of `v`
    /// repeats forever ([`ExitPolicy::Settle`]'s validity domain).
    /// On growing grids (retention's geometric dt) this is the last
    /// step, correctly disabling settle checks there.
    pub fixed_from: usize,
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

impl<'a> Schedule<'a> {
    /// Precompute the exit horizons for a stimulus schedule.
    pub fn new(wave: &'a [Vec<f64>], dwave: &'a [Vec<f64>], dt: &'a [f64]) -> Schedule<'a> {
        let steps = dt.len();
        let mut sc = steps.saturating_sub(1);
        while sc > 0 && bits_eq(&wave[sc - 1], &wave[sc]) && bits_eq(&dwave[sc - 1], &dwave[sc]) {
            sc -= 1;
        }
        let mut fx = steps.saturating_sub(1);
        while fx > 0 && dt[fx - 1].to_bits() == dt[fx].to_bits() {
            fx -= 1;
        }
        Schedule { wave, dwave, dt, stim_const_from: sc, fixed_from: fx.max(sc) }
    }
}

/// Row-retirement policy for [`run_block`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExitPolicy {
    /// Integrate every live row through every step.
    None,
    /// Retire rows at bitwise per-step fixed points of `v`, valid from
    /// [`Schedule::fixed_from`] (Heun ops on uniform grids).  Output
    /// traces are bitwise identical to [`ExitPolicy::None`].
    Settle,
    /// Retire rows whose free node `node` samples at or below the
    /// row's `thresh`, or whose rhs is exactly zero under constant
    /// stimulus (retention tails).  First-crossing times and never-
    /// crossed sentinels are preserved exactly; only the post-crossing
    /// tail of the trace (and thus the final node value) deviates.
    FallingCross {
        /// Watched free-node index.
        node: usize,
    },
}

/// One block of rows in SoA layout: every buffer is column-major,
/// `buf[col * rows + j]` for row `j`.
pub struct Block {
    /// Rows in this block.
    pub rows: usize,
    /// Free-node voltages (`nf x rows`), advanced in place.
    pub v: Vec<f64>,
    /// Inverse capacitances (`nf x rows`); a zero entry pins the node.
    pub cinv: Vec<f64>,
    /// Parameter columns (`npar x rows`).
    pub p: Vec<f64>,
    /// Stimulus amplitudes (`ns x rows`).
    pub amp: Vec<f64>,
    /// Per-row threshold for [`ExitPolicy::FallingCross`].
    pub thresh: Vec<f64>,
    /// Retirement mask; pre-set entries (zero-param padding) freeze a
    /// row from step 0.
    pub retired: Vec<bool>,
    /// Step index at which each row retired (meaningful where
    /// `retired`; pre-retired rows keep 0).
    pub retire_step: Vec<usize>,
}

impl Block {
    /// A zero-filled block for a template geometry.
    pub fn new(rows: usize, nf: usize, ns: usize, npar: usize) -> Block {
        Block {
            rows,
            v: vec![0.0; nf * rows],
            cinv: vec![0.0; nf * rows],
            p: vec![0.0; npar * rows],
            amp: vec![0.0; ns * rows],
            thresh: vec![0.0; rows],
            retired: vec![false; rows],
            retire_step: vec![0; rows],
        }
    }
}

/// One stimulus or free-node column as a `rows`-long slice.
#[inline(always)]
fn node_col<'a>(v: &'a [f64], vs: &'a [f64], nf: usize, rows: usize, c: usize) -> &'a [f64] {
    if c < nf { &v[c * rows..(c + 1) * rows] } else { &vs[(c - nf) * rows..(c - nf + 1) * rows] }
}

/// Net current into each free node for all rows at once: the SoA
/// counterpart of [`Template::rhs`], one flat row loop per stamp.
fn rhs_soa(
    t: &Template,
    rows: usize,
    v: &[f64],
    vs: &[f64],
    dvs: &[f64],
    p: &[f64],
    ist: &mut [f64],
    out: &mut [f64],
) {
    let nf = t.nf;
    out.fill(0.0);
    for st in &t.stamps {
        match *st {
            Stamp::Mos { d, g, s, p0 } => {
                let kp = &p[p0 * rows..(p0 + 1) * rows];
                let vt = &p[(p0 + 1) * rows..(p0 + 2) * rows];
                let nn = &p[(p0 + 2) * rows..(p0 + 3) * rows];
                let lam = &p[(p0 + 3) * rows..(p0 + 4) * rows];
                let wl = &p[(p0 + 4) * rows..(p0 + 5) * rows];
                let sg = &p[(p0 + 5) * rows..(p0 + 6) * rows];
                let vd = node_col(v, vs, nf, rows, d);
                let vg = node_col(v, vs, nf, rows, g);
                let vsr = node_col(v, vs, nf, rows, s);
                for j in 0..rows {
                    ist[j] = mos_ids_fast(
                        vd[j], vg[j], vsr[j], kp[j], vt[j], nn[j], lam[j], wl[j], sg[j],
                    );
                }
                if d < nf {
                    let o = &mut out[d * rows..(d + 1) * rows];
                    for j in 0..rows {
                        o[j] -= ist[j];
                    }
                }
                if s < nf {
                    let o = &mut out[s * rows..(s + 1) * rows];
                    for j in 0..rows {
                        o[j] += ist[j];
                    }
                }
            }
            Stamp::CapC { src, dst, p0 } => {
                let c = &p[p0 * rows..(p0 + 1) * rows];
                let dv = &dvs[src * rows..(src + 1) * rows];
                let o = &mut out[dst * rows..(dst + 1) * rows];
                for j in 0..rows {
                    o[j] += c[j] * dv[j];
                }
            }
            Stamp::Res { a, b, p0 } => {
                let g = &p[p0 * rows..(p0 + 1) * rows];
                let va = node_col(v, vs, nf, rows, a);
                let vb = node_col(v, vs, nf, rows, b);
                for j in 0..rows {
                    ist[j] = g[j] * (va[j] - vb[j]);
                }
                if a < nf {
                    let o = &mut out[a * rows..(a + 1) * rows];
                    for j in 0..rows {
                        o[j] -= ist[j];
                    }
                }
                if b < nf {
                    let o = &mut out[b * rows..(b + 1) * rows];
                    for j in 0..rows {
                        o[j] += ist[j];
                    }
                }
            }
            Stamp::Isrc { dst, p0 } => {
                let i = &p[p0 * rows..(p0 + 1) * rows];
                let o = &mut out[dst * rows..(dst + 1) * rows];
                for j in 0..rows {
                    o[j] += i[j];
                }
            }
        }
    }
}

/// Advance a whole block through the schedule and return the full-rate
/// trace, laid out `trace[(s * nf + k) * rows + j]`.  `block.v` holds
/// the final (or frozen) state afterward; `block.retired` /
/// `block.retire_step` report which rows exited early and when.
pub fn run_block(
    t: &Template,
    mode: Integrator,
    k_substeps: usize,
    sched: &Schedule,
    block: &mut Block,
    exit: ExitPolicy,
) -> Vec<f64> {
    let rows = block.rows;
    let (nf, ns) = (t.nf, t.ns);
    let steps = sched.dt.len();
    let v = &mut block.v;
    let cinv = &block.cinv;
    let p = &block.p;
    let amp = &block.amp;
    let thresh = &block.thresh;
    let retired = &mut block.retired;
    let retire_step = &mut block.retire_step;

    let mut trace = vec![0.0; steps * nf * rows];
    let mut i1 = vec![0.0; nf * rows];
    let mut i2 = vec![0.0; nf * rows];
    let mut v1 = vec![0.0; nf * rows];
    let mut vs = vec![0.0; ns * rows];
    let mut dvs = vec![0.0; ns * rows];
    let mut ist = vec![0.0; rows];
    let mut vprev = vec![0.0; nf * rows];
    let mut live = retired.iter().filter(|r| !**r).count();

    for s in 0..steps {
        // stimulus columns change until quiescence, then stay cached
        if s <= sched.stim_const_from {
            for sc in 0..ns {
                let w = sched.wave[s][sc];
                let dw = sched.dwave[s][sc];
                let a = &amp[sc * rows..(sc + 1) * rows];
                let vsd = &mut vs[sc * rows..(sc + 1) * rows];
                let dvd = &mut dvs[sc * rows..(sc + 1) * rows];
                for j in 0..rows {
                    vsd[j] = w * a[j];
                    dvd[j] = dw * a[j];
                }
            }
        }
        let check_settle = exit == ExitPolicy::Settle && s >= sched.fixed_from && live > 0;
        if check_settle {
            vprev.copy_from_slice(v);
        }
        let dt = sched.dt[s];
        for _ in 0..k_substeps {
            match mode {
                Integrator::Heun => {
                    rhs_soa(t, rows, v, &vs, &dvs, p, &mut ist, &mut i1);
                    for k in 0..nf {
                        let vk = &v[k * rows..(k + 1) * rows];
                        let ck = &cinv[k * rows..(k + 1) * rows];
                        let ik = &i1[k * rows..(k + 1) * rows];
                        let v1k = &mut v1[k * rows..(k + 1) * rows];
                        for j in 0..rows {
                            let upd = vk[j] + dt * ik[j] * ck[j];
                            v1k[j] = if ck[j] == 0.0 { vk[j] } else { upd };
                        }
                    }
                    rhs_soa(t, rows, &v1, &vs, &dvs, p, &mut ist, &mut i2);
                    for k in 0..nf {
                        let vk = &mut v[k * rows..(k + 1) * rows];
                        let ck = &cinv[k * rows..(k + 1) * rows];
                        let ak = &i1[k * rows..(k + 1) * rows];
                        let bk = &i2[k * rows..(k + 1) * rows];
                        for j in 0..rows {
                            let upd = vk[j] + 0.5 * dt * (ak[j] + bk[j]) * ck[j];
                            let keep = ck[j] == 0.0 || retired[j];
                            vk[j] = if keep { vk[j] } else { upd };
                        }
                    }
                }
                Integrator::ExpDecay => {
                    rhs_soa(t, rows, v, &vs, &dvs, p, &mut ist, &mut i1);
                    // pass 1 (vectorizable): dv and the decay factor;
                    // the exp argument is clamped to <= 0 so the factor
                    // is well-formed even where the branch won't use it
                    for k in 0..nf {
                        let vk = &v[k * rows..(k + 1) * rows];
                        let ck = &cinv[k * rows..(k + 1) * rows];
                        let ik = &i1[k * rows..(k + 1) * rows];
                        let dvk = &mut v1[k * rows..(k + 1) * rows];
                        let ek = &mut i2[k * rows..(k + 1) * rows];
                        for j in 0..rows {
                            let dv = dt * ik[j] * ck[j];
                            dvk[j] = dv;
                            ek[j] = exp_fast((dv / vk[j].max(1e-6)).min(0.0));
                        }
                    }
                    // pass 2: the same branch structure as the scalar
                    // integrator, as selects over precomputed values
                    for k in 0..nf {
                        let vk = &mut v[k * rows..(k + 1) * rows];
                        let ck = &cinv[k * rows..(k + 1) * rows];
                        let dvk = &v1[k * rows..(k + 1) * rows];
                        let ek = &i2[k * rows..(k + 1) * rows];
                        for j in 0..rows {
                            let vj = vk[j];
                            let dv = dvk[j];
                            let vnew = if dv < 0.0 && vj > 0.0 {
                                vj * ek[j]
                            } else if vj <= 0.0 {
                                (vj + dv).max(vj).min(0.0)
                            } else {
                                vj + dv
                            };
                            let keep = ck[j] == 0.0 || retired[j];
                            vk[j] = if keep { vj } else { vnew };
                        }
                    }
                }
            }
        }
        let base = s * nf * rows;
        trace[base..base + nf * rows].copy_from_slice(v);
        match exit {
            ExitPolicy::None => {}
            ExitPolicy::Settle => {
                if check_settle {
                    for j in 0..rows {
                        if !retired[j]
                            && (0..nf)
                                .all(|k| v[k * rows + j].to_bits() == vprev[k * rows + j].to_bits())
                        {
                            retired[j] = true;
                            retire_step[j] = s;
                            live -= 1;
                        }
                    }
                }
            }
            ExitPolicy::FallingCross { node } => {
                for j in 0..rows {
                    if retired[j] {
                        continue;
                    }
                    let crossed = v[node * rows + j] <= thresh[j];
                    let quiesced = s >= sched.stim_const_from
                        && (0..nf).all(|k| i1[k * rows + j] == 0.0);
                    if crossed || quiesced {
                        retired[j] = true;
                        retire_step[j] = s;
                        live -= 1;
                    }
                }
            }
        }
        if live == 0 && s + 1 < steps {
            // whole block retired: forward-fill the frozen state
            for s2 in s + 1..steps {
                trace.copy_within(base..base + nf * rows, s2 * nf * rows);
            }
            break;
        }
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim;
    use crate::tech::cards::sg40;

    #[test]
    fn exp_fast_tracks_libm() {
        let mut x = -707.5;
        while x < 0.0 {
            let (got, want) = (exp_fast(x), x.exp());
            assert!(
                (got - want).abs() <= 1e-13 * want,
                "exp_fast({x}) = {got}, libm = {want}"
            );
            x += 0.373;
        }
        assert_eq!(exp_fast(0.0), 1.0);
        // below the clamp the true value has underflowed anyway
        assert!(exp_fast(-800.0) < 1e-307);
    }

    #[test]
    fn sl_fast_tracks_scalar_soft_clamp() {
        // includes the scalar's +/-30 clamp region, where the scalar
        // itself truncates by ~e^-30 — the fast kernel is the *more*
        // accurate of the two there
        let mut x = -40.0;
        while x < 40.0 {
            let got = sl_fast(x);
            let want = x.exp().ln_1p();
            assert!(
                (got - want).abs() <= 1e-12 * want,
                "sl_fast({x}) = {got}, ref = {want}"
            );
            x += 0.217;
        }
    }

    #[test]
    fn mos_ids_fast_matches_scalar_ekv_closely() {
        let c = sg40::SI_NMOS;
        for &(vd, vg, vs) in &[
            (0.7, 0.9, 0.2),
            (1.1, 1.1, 0.0),
            (1.1, 0.0, 0.0),
            (0.05, 0.45, 0.0),
            (0.0, 0.0, 0.0),
        ] {
            let a = mos_ids_fast(vd, vg, vs, c.kp, c.vt, c.n, c.lam, 2.0, 1.0);
            let b = sim::mos_ids(vd, vg, vs, c.kp, c.vt, c.n, c.lam, 2.0, 1.0);
            assert!(
                (a - b).abs() <= 1e-9 * b.abs().max(1e-30),
                "ids({vd},{vg},{vs}): fast {a} vs scalar {b}"
            );
        }
        // zero-param (padding) rows produce an exact zero even though
        // vp is NaN internally
        let z = mos_ids_fast(0.6, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0);
        assert_eq!(z, 0.0);
    }

    #[test]
    fn schedule_horizons_from_backward_scans() {
        // wave rows 0..=3 ramp, rows 4.. are identical: quiescent from 4
        let mut wave = vec![vec![0.0, 1.0]; 10];
        let dwave = vec![vec![0.0, 0.0]; 10];
        for (i, w) in wave.iter_mut().enumerate().take(4) {
            w[0] = 1.0 + i as f64;
        }
        let dt_uniform = vec![1e-12; 10];
        let s = Schedule::new(&wave, &dwave, &dt_uniform);
        assert_eq!(s.stim_const_from, 4);
        assert_eq!(s.fixed_from, 4);
        let dt_log: Vec<f64> = (0..10).map(|i| 1e-12 * 1.082f64.powi(i)).collect();
        let s = Schedule::new(&wave, &dwave, &dt_log);
        assert_eq!(s.stim_const_from, 4);
        assert_eq!(s.fixed_from, 9, "growing dt must disable settle checks");
        let zeros = vec![vec![0.0, 0.0]; 10];
        let s = Schedule::new(&zeros, &zeros, &dt_log);
        assert_eq!(s.stim_const_from, 0, "all-quiet stimulus is constant from step 0");
    }

    /// A retention block over `n` (vt, v0) points on the real Si card.
    fn retention_block(pts: &[(f64, f64)]) -> (Template, Block) {
        let t = sim::retention_template();
        let rows = pts.len();
        let mut b = Block::new(rows, t.nf, t.ns, t.npar);
        let si = sg40::SI_NMOS;
        for (j, &(vt, v0)) in pts.iter().enumerate() {
            for (c, val) in [si.kp, vt, si.n, si.lam, 2.0, 1.0, 1e-16, 0.0].iter().enumerate() {
                b.p[c * rows + j] = *val;
            }
            b.v[j] = v0;
            b.cinv[j] = 1.0 / 1.2e-15;
            b.thresh[j] = 0.3;
        }
        (t, b)
    }

    fn retention_grid(steps: usize) -> Vec<f64> {
        let mut dt = Vec::with_capacity(steps);
        let mut d = 1e-12;
        for _ in 0..steps {
            dt.push(d);
            d *= 1.082;
        }
        dt
    }

    #[test]
    fn batched_block_is_bitwise_equal_to_single_row_blocks() {
        let pts = [(0.35, 0.6), (0.45, 0.6), (0.55, 0.5), (0.38, 0.7)];
        let dt = retention_grid(448);
        let wave = vec![vec![0.0; 4]; dt.len()];
        let sched = Schedule::new(&wave, &wave, &dt);
        let (t, mut all) = retention_block(&pts);
        let trace = run_block(&t, Integrator::ExpDecay, 4, &sched, &mut all, ExitPolicy::None);
        for (j, &pt) in pts.iter().enumerate() {
            let (_, mut one) = retention_block(&[pt]);
            let tr1 = run_block(&t, Integrator::ExpDecay, 4, &sched, &mut one, ExitPolicy::None);
            for s in 0..dt.len() {
                assert_eq!(
                    trace[s * pts.len() + j].to_bits(),
                    tr1[s].to_bits(),
                    "row {j} step {s} diverged between batch sizes"
                );
            }
        }
    }

    #[test]
    fn falling_cross_exit_keeps_exact_crossings_and_sentinels() {
        // rows 0..3 cross 0.3; the last row watches an unreachable
        // threshold and must stay live (BIG_TIME-style sentinel)
        let pts = [(0.35, 0.6), (0.45, 0.6), (0.55, 0.5), (0.38, 0.7)];
        let dt = retention_grid(448);
        let wave = vec![vec![0.0; 4]; dt.len()];
        let sched = Schedule::new(&wave, &wave, &dt);
        let times: Vec<f64> = dt
            .iter()
            .scan(0.0, |acc, &d| {
                *acc += d * 4.0;
                Some(*acc)
            })
            .collect();
        let (t, mut free) = retention_block(&pts);
        let full = run_block(&t, Integrator::ExpDecay, 4, &sched, &mut free, ExitPolicy::None);
        let (_, mut gated) = retention_block(&pts);
        gated.thresh[3] = -1.0; // unreachable: the row never retires by crossing
        let rows = pts.len();
        let masked = run_block(
            &t,
            Integrator::ExpDecay,
            4,
            &sched,
            &mut gated,
            ExitPolicy::FallingCross { node: 0 },
        );
        for j in 0..rows {
            let want = sim::cross_time_at(&times, dt.len(), |s| full[s * rows + j], 0.3, false);
            let got = sim::cross_time_at(&times, dt.len(), |s| masked[s * rows + j], 0.3, false);
            match (want, got) {
                (Some(a), Some(b)) => assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "row {j}: frozen tail moved the first crossing"
                ),
                (None, None) => {}
                other => panic!("row {j}: crossing disagreement {other:?}"),
            }
        }
        assert!(gated.retired[0] && gated.retired[1] && gated.retired[2]);
        assert!(!gated.retired[3], "unreachable threshold must not retire");
        // the retiring rows exited well before the end of the grid
        assert!(gated.retire_step[0] < dt.len() - 1);
    }

    #[test]
    fn settle_exit_is_bitwise_identical_on_uniform_grids() {
        // a write-template block driven to steady state: settle
        // retirement at bitwise fixed points must not change one bit of
        // the trace
        let t = sim::write_template();
        let rows = 3;
        let steps = 384;
        let si_n = sg40::SI_NMOS;
        let si_p = sg40::SI_PMOS;
        let mk = || {
            let mut b = Block::new(rows, t.nf, t.ns, t.npar);
            for j in 0..rows {
                let vt = 0.4 + 0.05 * j as f64;
                let cols = [
                    si_n.kp, vt, si_n.n, si_n.lam, 2.0, 1.0, // mwr
                    si_p.kp, si_p.vt, si_p.n, si_p.lam, 8.0, -1.0, // mdrvp
                    si_n.kp, si_n.vt, si_n.n, si_n.lam, 4.0, 1.0, // mdrvn
                    0.15e-15, 1e-9, // cwwl_sn.c, gwbl.g
                ];
                for (c, val) in cols.iter().enumerate() {
                    b.p[c * rows + j] = *val;
                }
                b.cinv[j] = 1.0 / 1.2e-15;
                b.cinv[rows + j] = 1.0 / 20e-15;
                for (sc, a) in [1.1, 0.0, 1.1, 0.0].iter().enumerate() {
                    b.amp[sc * rows + j] = *a;
                }
            }
            b
        };
        let dt = vec![6e-9 / (steps as f64 * 4.0); steps];
        let mut wave = vec![vec![0.0, 1.0, 1.0, 0.0]; steps];
        let mut dwave = vec![vec![0.0; 4]; steps];
        for (i, (w, dw)) in wave.iter_mut().zip(dwave.iter_mut()).enumerate() {
            if i >= 20 {
                w[0] = 1.0;
            } else if i >= 10 {
                w[0] = (i - 10) as f64 / 10.0;
                dw[0] = 1.0 / (10.0 * 4.0 * dt[0]);
            }
        }
        let sched = Schedule::new(&wave, &dwave, &dt);
        assert!(sched.fixed_from < steps - 1, "pulse must quiesce for the test to bite");
        let mut plain = mk();
        let full = run_block(&t, Integrator::Heun, 4, &sched, &mut plain, ExitPolicy::None);
        let mut gated = mk();
        let masked = run_block(&t, Integrator::Heun, 4, &sched, &mut gated, ExitPolicy::Settle);
        assert_eq!(full.len(), masked.len());
        for (i, (a, b)) in full.iter().zip(&masked).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "sample {i} diverged under settle exit");
        }
    }

    #[test]
    fn pre_retired_rows_hold_v0_exactly() {
        // a mixed block: one live row, one zero-param padding row
        let pts = [(0.45, 0.6), (0.0, 0.6)];
        let dt = retention_grid(64);
        let wave = vec![vec![0.0; 4]; dt.len()];
        let sched = Schedule::new(&wave, &wave, &dt);
        let (t, mut b) = retention_block(&pts);
        let rows = pts.len();
        for c in 0..t.npar {
            b.p[c * rows + 1] = 0.0;
        }
        b.retired[1] = true;
        let trace = run_block(&t, Integrator::ExpDecay, 4, &sched, &mut b, ExitPolicy::None);
        for s in 0..dt.len() {
            assert_eq!(trace[s * rows + 1].to_bits(), 0.6f64.to_bits(), "padding row moved");
        }
        assert!(trace[(dt.len() - 1) * rows] < 0.6, "live row must still decay");
    }
}
