//! Native transient simulator: the "HSPICE stand-in" reference.
//!
//! Mirrors the python stack 1:1 — same EKV device expression
//! ([`mos_ids`], see `python/compile/device.py`), same stamped
//! fixed-topology circuits, same Heun / exponential-decay integrators —
//! so the XLA artifacts can be cross-checked against an independent
//! implementation (`tests/parity.rs`), and so single design points can
//! be simulated without the PJRT runtime (leakage sums, spot checks,
//! the GEMTOO-style analytical-vs-transient ablation bench).
//!
//! This module is the **scalar reference**: one row at a time, libm
//! transcendentals, allocation-free inner loops (via [`StepScratch`]).
//! The batched production hot path lives in [`soa`], which advances a
//! whole row-block per time step over the same templates and is pinned
//! against this implementation by `tests/parity.rs`.

use crate::tech::DeviceCard;

pub mod soa;

/// Thermal voltage at 300 K (mirror of device.PHI_T).
pub const PHI_T: f64 = 0.02585;

fn softlog1pexp(x: f64) -> f64 {
    // ln(1 + e^x), stable
    if x > 30.0 {
        x
    } else if x < -30.0 {
        x.exp()
    } else {
        x.exp().ln_1p()
    }
}

/// EKV drain current (A), d->s positive.  Mirrors device.mos_ids.
pub fn mos_ids(vd: f64, vg: f64, vs: f64, kp: f64, vt: f64, n: f64, lam: f64, w_over_l: f64, sign: f64) -> f64 {
    let (vd_, vg_, vs_) = (sign * vd, sign * vg, sign * vs);
    let vp = (vg_ - vt) / n;
    let i_f = softlog1pexp((vp - vs_) / (2.0 * PHI_T)).powi(2);
    let i_r = softlog1pexp((vp - vd_) / (2.0 * PHI_T)).powi(2);
    let i_spec = 2.0 * n * kp * w_over_l * PHI_T * PHI_T;
    let clm = 1.0 + lam * (vd_ - vs_).abs();
    sign * i_spec * (i_f - i_r) * clm
}

/// Card-based wrapper.
pub fn ids_card(card: &DeviceCard, w_over_l: f64, vd: f64, vg: f64, vs: f64) -> f64 {
    mos_ids(vd, vg, vs, card.kp, card.vt, card.n, card.lam, w_over_l, card.sign())
}

/// Off-state leakage of a device at VGS=0, VDS=vdd (A).
pub fn ioff(card: &DeviceCard, w_over_l: f64, vdd: f64) -> f64 {
    match card.sign() as i64 {
        1 => ids_card(card, w_over_l, vdd, 0.0, 0.0),
        _ => -ids_card(card, w_over_l, -vdd, 0.0, 0.0),
    }
}

/// On-state current at VGS=VDS=vdd (A).
pub fn ion(card: &DeviceCard, w_over_l: f64, vdd: f64) -> f64 {
    match card.sign() as i64 {
        1 => ids_card(card, w_over_l, vdd, vdd, 0.0),
        _ => -ids_card(card, w_over_l, -vdd, -vdd, 0.0),
    }
}

// ---------------------------------------------------------------------------
// Stamped circuits (mirror of python/compile/circuits.py)
// ---------------------------------------------------------------------------

/// Stamp referencing node indices in the concatenated [free|stim] space
/// and parameter columns in the design-point vector.
#[derive(Debug, Clone, Copy)]
pub enum Stamp {
    /// EKV device: 6 param columns [kp, vt, n, lam, wl, sign] at p0.
    Mos { d: usize, g: usize, s: usize, p0: usize },
    /// Coupling cap from stimulus node `src` into free node `dst`.
    CapC { src: usize, dst: usize, p0: usize },
    /// Linear conductance.
    Res { a: usize, b: usize, p0: usize },
    /// Constant current into `dst`.
    Isrc { dst: usize, p0: usize },
}

/// A stamped fixed-topology template.
#[derive(Debug, Clone)]
pub struct Template {
    pub name: &'static str,
    pub nf: usize,
    pub ns: usize,
    pub npar: usize,
    pub stamps: Vec<Stamp>,
}

impl Template {
    /// Net current into each free node.
    pub fn rhs(&self, v: &[f64], vs: &[f64], dvs: &[f64], p: &[f64], out: &mut [f64]) {
        let col = |i: usize| if i < self.nf { v[i] } else { vs[i - self.nf] };
        out.iter_mut().for_each(|o| *o = 0.0);
        for st in &self.stamps {
            match *st {
                Stamp::Mos { d, g, s, p0 } => {
                    let i = mos_ids(col(d), col(g), col(s), p[p0], p[p0 + 1], p[p0 + 2], p[p0 + 3], p[p0 + 4], p[p0 + 5]);
                    if d < self.nf {
                        out[d] -= i;
                    }
                    if s < self.nf {
                        out[s] += i;
                    }
                }
                Stamp::CapC { src, dst, p0 } => out[dst] += p[p0] * dvs[src],
                Stamp::Res { a, b, p0 } => {
                    let i = p[p0] * (col(a) - col(b));
                    if a < self.nf {
                        out[a] -= i;
                    }
                    if b < self.nf {
                        out[b] += i;
                    }
                }
                Stamp::Isrc { dst, p0 } => out[dst] += p[p0],
            }
        }
    }
}

/// Integrator selection (mirrors the kernel's `mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Integrator {
    Heun,
    ExpDecay,
}

/// Reusable scratch buffers for [`step`]: the `i1`/`i2`/`v1` work
/// vectors, hoisted out of the per-step hot path so callers allocate
/// them once per transient instead of three times per time step.
#[derive(Debug, Clone)]
pub struct StepScratch {
    i1: Vec<f64>,
    i2: Vec<f64>,
    v1: Vec<f64>,
}

impl StepScratch {
    /// Scratch sized for a template with `nf` free nodes.
    pub fn new(nf: usize) -> StepScratch {
        StepScratch { i1: vec![0.0; nf], i2: vec![0.0; nf], v1: vec![0.0; nf] }
    }
}

/// One K-substep integration step in place.
#[allow(clippy::too_many_arguments)]
pub fn step(
    t: &Template,
    mode: Integrator,
    k_substeps: usize,
    v: &mut [f64],
    vs: &[f64],
    dvs: &[f64],
    p: &[f64],
    cinv: &[f64],
    dt: f64,
    scratch: &mut StepScratch,
) {
    let nf = t.nf;
    let StepScratch { i1, i2, v1 } = scratch;
    for _ in 0..k_substeps {
        match mode {
            Integrator::Heun => {
                t.rhs(v, vs, dvs, p, i1);
                for k in 0..nf {
                    v1[k] = if cinv[k] == 0.0 { v[k] } else { v[k] + dt * i1[k] * cinv[k] };
                }
                t.rhs(v1, vs, dvs, p, i2);
                for k in 0..nf {
                    if cinv[k] != 0.0 {
                        v[k] += 0.5 * dt * (i1[k] + i2[k]) * cinv[k];
                    }
                }
            }
            Integrator::ExpDecay => {
                t.rhs(v, vs, dvs, p, i1);
                for k in 0..nf {
                    if cinv[k] == 0.0 {
                        continue;
                    }
                    let dv = dt * i1[k] * cinv[k];
                    if dv < 0.0 && v[k] > 0.0 {
                        v[k] *= (dv / v[k].max(1e-6)).exp();
                    } else if v[k] <= 0.0 {
                        v[k] = (v[k] + dv).max(v[k]).min(0.0);
                    } else {
                        v[k] += dv;
                    }
                }
            }
        }
    }
}

/// Full transient over a stimulus schedule; returns the trace of free
/// node voltages (steps x nf) and the time axis.
#[allow(clippy::too_many_arguments)]
pub fn transient(
    t: &Template,
    mode: Integrator,
    k_substeps: usize,
    v0: &[f64],
    amp: &[f64],
    p: &[f64],
    cinv: &[f64],
    wave: &[Vec<f64>],
    dwave: &[Vec<f64>],
    dt: &[f64],
) -> (Vec<f64>, Vec<Vec<f64>>) {
    let mut v = v0.to_vec();
    let mut times = Vec::with_capacity(dt.len());
    let mut trace = Vec::with_capacity(dt.len());
    let mut tacc = 0.0;
    let mut vs = vec![0.0; t.ns];
    let mut dvs = vec![0.0; t.ns];
    let mut scratch = StepScratch::new(t.nf);
    for (i, &dti) in dt.iter().enumerate() {
        for s in 0..t.ns {
            vs[s] = wave[i][s] * amp[s];
            dvs[s] = dwave[i][s] * amp[s];
        }
        step(t, mode, k_substeps, &mut v, &vs, &dvs, p, cinv, dti, &mut scratch);
        tacc += dti * k_substeps as f64;
        times.push(tacc);
        trace.push(v.clone());
    }
    (times, trace)
}

/// First threshold crossing with linear interpolation (mirror of
/// model._cross_time); `None` if never crossed.
pub fn cross_time(times: &[f64], sig: &[f64], thresh: f64, rising: bool) -> Option<f64> {
    cross_time_at(times, sig.len(), |i| sig[i], thresh, rising)
}

/// [`cross_time`] over an indexed signal view: `at(i)` yields sample
/// `i` of `n`.  The SoA measurement path reads strided trace columns
/// through this without copying them into a `Vec` first; keeping one
/// implementation guarantees the interpolation arithmetic is bitwise
/// identical across both layouts.
pub fn cross_time_at(
    times: &[f64],
    n: usize,
    at: impl Fn(usize) -> f64,
    thresh: f64,
    rising: bool,
) -> Option<f64> {
    for i in 0..n {
        let si = at(i);
        let above = if rising { si >= thresh } else { si <= thresh };
        if above {
            if i == 0 {
                return Some(0.0);
            }
            let (v0, v1) = (at(i - 1), si);
            let frac = if (v1 - v0).abs() > 1e-12 { ((thresh - v0) / (v1 - v0)).clamp(0.0, 1.0) } else { 1.0 };
            return Some(times[i - 1] + frac * (times[i] - times[i - 1]));
        }
    }
    None
}

// Canonical templates (must match python/compile/circuits.py layouts).

/// retention: free `[sn]`; stim `[wwl, wbl, gnd, vth]`; params
/// `[mwr(6), gleak.g, idist.i]`.
pub fn retention_template() -> Template {
    Template {
        name: "retention",
        nf: 1,
        ns: 4,
        npar: 8,
        stamps: vec![
            Stamp::Mos { d: 0, g: 1, s: 2, p0: 0 },
            Stamp::Res { a: 0, b: 3, p0: 6 },
            Stamp::Isrc { dst: 0, p0: 7 },
        ],
    }
}

/// write: free [sn, wbl]; stim [wwl, dinb, vdd, gnd]; params
/// [mwr(6), mdrvp(6), mdrvn(6), cwwl_sn.c, gwbl.g].
pub fn write_template() -> Template {
    Template {
        name: "write",
        nf: 2,
        ns: 4,
        npar: 20,
        stamps: vec![
            Stamp::Mos { d: 0, g: 2, s: 1, p0: 0 },
            Stamp::Mos { d: 1, g: 3, s: 4, p0: 6 },
            Stamp::Mos { d: 1, g: 3, s: 5, p0: 12 },
            Stamp::CapC { src: 0, dst: 0, p0: 18 },
            Stamp::Res { a: 1, b: 5, p0: 19 },
        ],
    }
}

/// read: free [sn, rbl]; stim [rwl, rwl_idle, snu, gnd]; params
/// [mrd(6), mrbl_leak(6), crwl_sn.c, grbl.g].
pub fn read_template() -> Template {
    Template {
        name: "read",
        nf: 2,
        ns: 4,
        npar: 14,
        stamps: vec![
            Stamp::Mos { d: 1, g: 0, s: 2, p0: 0 },
            Stamp::Mos { d: 1, g: 4, s: 3, p0: 6 },
            Stamp::CapC { src: 0, dst: 0, p0: 12 },
            Stamp::Res { a: 1, b: 5, p0: 13 },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::cards::sg40;

    #[test]
    fn device_polarity_and_magnitude() {
        let n = sg40::SI_NMOS;
        let i_on = ion(&n, 1.0, 1.1);
        let i_off = ioff(&n, 1.0, 1.1);
        assert!(i_on > 1e-5 && i_on < 1e-3, "{i_on}");
        assert!(i_off > 1e-13 && i_off < 1e-9, "{i_off}");
        assert!(i_on / i_off > 1e4);
        // pmos mirror
        let p = sg40::SI_PMOS;
        assert!(ion(&p, 1.0, 1.1) > 0.0);
        assert!(ioff(&p, 1.0, 1.1) > 0.0);
        // OS HVT hits the paper's <1e-18 A/um class
        assert!(ioff(&sg40::OS_NMOS_HVT, 1.0, 1.1) < 1e-18);
    }

    #[test]
    fn ds_antisymmetry() {
        let c = sg40::SI_NMOS;
        let a = mos_ids(0.7, 0.9, 0.2, c.kp, c.vt, c.n, 0.0, 2.0, 1.0);
        let b = mos_ids(0.2, 0.9, 0.7, c.kp, c.vt, c.n, 0.0, 2.0, 1.0);
        assert!((a + b).abs() < 1e-9 * a.abs().max(1e-18));
    }

    #[test]
    fn retention_matches_physics() {
        // Si cell ~ tens of microseconds; OS ~ milliseconds (Fig. 8)
        let t = retention_template();
        let mut p = vec![0.0; t.npar];
        let run = |p: &[f64]| {
            let steps = 440;
            let mut dt = Vec::with_capacity(steps);
            let mut d = 1e-12;
            for _ in 0..steps {
                dt.push(d);
                d *= 1.082;
            }
            let wave = vec![vec![0.0; 4]; steps];
            let (times, trace) = transient(
                &t,
                Integrator::ExpDecay,
                4,
                &[0.6],
                &[0.0; 4],
                p,
                &[1.0 / 1.2e-15],
                &wave,
                &wave,
                &dt,
            );
            let sn: Vec<f64> = trace.iter().map(|r| r[0]).collect();
            cross_time(&times, &sn, 0.3, false).unwrap_or(f64::INFINITY)
        };
        let si = sg40::SI_NMOS;
        p[0..6].copy_from_slice(&[si.kp, si.vt, si.n, si.lam, 2.0, 1.0]);
        p[6] = 1e-16;
        let t_si = run(&p);
        assert!(t_si > 1e-6 && t_si < 1e-3, "{t_si}");
        let os = sg40::OS_NMOS;
        p[0..6].copy_from_slice(&[os.kp, os.vt, os.n, os.lam, 2.0, 1.0]);
        let t_os = run(&p);
        assert!(t_os > 1e-3 && t_os < 1.0, "{t_os}");
        assert!(t_os > 10.0 * t_si);
    }

    #[test]
    fn write_reaches_vdd_minus_vt() {
        let t = write_template();
        let mut p = vec![0.0; t.npar];
        let si_n = sg40::SI_NMOS;
        let si_p = sg40::SI_PMOS;
        p[0..6].copy_from_slice(&[si_n.kp, si_n.vt, si_n.n, si_n.lam, 2.0, 1.0]);
        p[6..12].copy_from_slice(&[si_p.kp, si_p.vt, si_p.n, si_p.lam, 8.0, -1.0]);
        p[12..18].copy_from_slice(&[si_n.kp, si_n.vt, si_n.n, si_n.lam, 4.0, 1.0]);
        p[18] = 0.15e-15;
        p[19] = 1e-9;
        let steps = 256;
        let dt = vec![5e-12; steps];
        let mut wave = vec![vec![0.0, 0.0, 1.0, 0.0]; steps];
        let mut dwave = vec![vec![0.0; 4]; steps];
        // wwl rises at step 10 over 5 steps, stays high
        for (i, (w, dw)) in wave.iter_mut().zip(dwave.iter_mut()).enumerate() {
            if i >= 15 {
                w[0] = 1.0;
            } else if i >= 10 {
                w[0] = (i - 10) as f64 / 5.0;
                dw[0] = 1.0 / (5.0 * 4.0 * 5e-12);
            }
        }
        let (_, trace) = transient(
            &t,
            Integrator::Heun,
            4,
            &[0.0, 0.0],
            &[1.1, 0.0, 1.1, 0.0],
            &p,
            &[1.0 / 1.2e-15, 1.0 / 20e-15],
            &wave,
            &dwave,
            &dt,
        );
        let sn_final = trace.last().unwrap()[0];
        assert!((sn_final - (1.1 - 0.45)).abs() < 0.15, "{sn_final}");
    }

    #[test]
    fn cross_time_interpolates() {
        let t = cross_time(&[1.0, 2.0, 3.0], &[0.0, 0.2, 0.6], 0.4, true).unwrap();
        assert!((t - 2.5).abs() < 1e-9, "{t}");
        assert!(cross_time(&[1.0], &[0.0], 0.5, true).is_none());
    }
}
