//! Workload-driven heterogeneous composition: close the loop from the
//! Table-I cache demands to a selected per-level bank portfolio.
//!
//! The paper's end goal is "performance-tailored memory blocks that
//! meet diverse application requirements", and the follow-on work
//! (GainSight; heterogeneous memory design exploration with a gain
//! cell compiler) shows the payoff: a *different* GCRAM flavor per
//! cache level and per workload.  This module is that layer:
//!
//! 1. profile the full L1/L2 demand grid of a machine
//!    ([`crate::workloads::all_demands`] plus the per-level
//!    [`crate::workloads::envelope`]);
//! 2. run **one cross-flavor mega-sweep** — every flavor in
//!    [`FLAVORS`] over the co-optimizer's size/VT grid
//!    ([`crate::dse::grid_configs`]) — through a single shared
//!    [`EvalCache`] and one
//!    [`dse::evaluate_all_batched_cached`] pass, so all flavors'
//!    transient points pack into shared padded artifact batches
//!    (retention always packs; write/read pack per window bucket);
//! 3. per demand: the feasible set
//!    ([`dse::shmoo_verdict`] passes), a multi-objective Pareto front
//!    over area/leakage/f_op among *feasible points only*
//!    ([`pareto_area_leak_fop`]), and a minimum-cost selection under
//!    [`CostWeights`] whose frequency/lifetime floors are the demand
//!    itself.
//!
//! The result is a [`Composition`]: per (task, level) the chosen
//! flavor/geometry/VT with its margins, per cache level the envelope
//! choice, and portfolio area/leakage totals.
//!
//! # Packing model (the KPI)
//!
//! Because the whole grid goes through one batched sweep, the sweep
//! issues `ceil(total transient points / batch_cap)` retention
//! executions ([`crate::characterize::calls_for`]) — **not**
//! per-flavor x per-design.  [`plan`] computes that packing plan
//! without any runtime (compile + `CharPlan` window bits only), and
//! [`mock_retention_calls`] drives the same grouping through a
//! counting mock coordinator executor — the CI "mock-coordinator"
//! smoke mode (`opengcram compose --plan`) asserts both with no
//! artifacts on disk.  `benches/fig10_shmoo.rs` asserts the same KPI
//! against the real runtime's call counters.
//!
//! # Determinism
//!
//! The grid order (flavor-major, then size x VT row-major), the
//! order-preserving batched sweep, and first-minimum tie-breaking make
//! the selection a pure function of the evaluated figures; at window
//! resolution `0` those are bitwise-reproducible, so the composition
//! is pinned by `tests/integration.rs`.

use crate::characterize::{self, calls_for};
use crate::compiler::{CellFlavor, CompileCache, Config, ConfigKey};
use crate::coordinator::{BatchExec, Coordinator};
use crate::dse::{self, CostWeights, EvalCache, Evaluated};
use crate::report;
use crate::runtime::{RunHealth, SharedRuntime};
use crate::tech::Tech;
use crate::util::eng;
use crate::variation;
use crate::workloads::{self, CacheLevel, Demand, Machine};
use std::collections::{BTreeMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Every cell flavor the composition engine sweeps, in grid order.
pub const FLAVORS: [CellFlavor; 4] = [
    CellFlavor::GcSiSiNp,
    CellFlavor::GcSiSiNn,
    CellFlavor::GcOsOs,
    CellFlavor::Sram6t,
];

/// The cross-flavor design grid: [`dse::grid_configs`] (size x
/// write-VT) per gain-cell flavor, sizes only for the 6T SRAM baseline
/// (the VT axis modulates the *write transistor*, which SRAM does not
/// have — keeping the overrides would add identical-by-construction
/// design points).  Deterministic order: [`FLAVORS`]-major.
pub fn design_grid() -> Vec<Config> {
    let mut out = Vec::new();
    for flavor in FLAVORS {
        let grid = dse::grid_configs(flavor);
        if flavor == CellFlavor::Sram6t {
            out.extend(grid.into_iter().filter(|c| c.write_vt.is_none()));
        } else {
            out.extend(grid);
        }
    }
    out
}

/// Composition request: the machine whose demands to serve, the sweep
/// resolution, and the selection cost weights (the frequency/lifetime
/// floors come from each demand, not from here).
#[derive(Debug, Clone)]
pub struct ComposeSpec {
    pub machine: &'static Machine,
    /// Window-quantization resolution of the mega-sweep
    /// ([`characterize::DEFAULT_WINDOW_RESOLUTION`] by default; `0.0`
    /// for bitwise-reproducible selections).
    pub window_resolution: f64,
    pub w_delay: f64,
    pub w_area: f64,
    pub w_power: f64,
    /// Parallel-compile fan-out of the sweep.
    pub workers: usize,
    /// `Some(model)` switches the sweep to Monte-Carlo mode: every
    /// grid point expands into `model.samples` variants via
    /// [`variation::yield_sweep_health`] and feasibility becomes
    /// `yield >= yield_target` instead of the nominal shmoo verdict.
    pub mc: Option<variation::VariationModel>,
    /// Demand-joint yield a design must reach to count as feasible in
    /// Monte-Carlo mode (point estimate; the Wilson interval is
    /// reported, not gated on — see [`variation::DesignYield`]).
    pub yield_target: f64,
}

impl ComposeSpec {
    pub fn new(machine: &'static Machine) -> ComposeSpec {
        ComposeSpec {
            machine,
            window_resolution: characterize::DEFAULT_WINDOW_RESOLUTION,
            w_delay: 1.0,
            w_area: 0.5,
            w_power: 0.5,
            workers: crate::util::default_workers(),
            mc: None,
            yield_target: variation::DEFAULT_YIELD_TARGET,
        }
    }
}

/// The winning design point for one demand.
#[derive(Debug, Clone)]
pub struct Chosen {
    pub eval: Evaluated,
    /// [`dse::cost`] under the demand-floored weights (finite).
    pub cost: f64,
    /// `f_op / demanded read frequency` (>= 1 for a feasible choice).
    pub freq_margin: f64,
    /// `retention / demanded lifetime` (>= 1; infinite for SRAM).
    pub retention_margin: f64,
    /// Demand-joint yield point estimate of the chosen design
    /// (Monte-Carlo selections only; `None` on the nominal path).
    pub yield_p: Option<f64>,
}

/// Feasible-set / front / selection summary for one demand.
#[derive(Debug, Clone)]
pub struct Selection {
    pub demand: Demand,
    /// True for the per-level envelope rows (the demand's `task` then
    /// names only the frequency-critical task).
    pub envelope: bool,
    /// Number of grid points passing the shmoo verdict.
    pub feasible: usize,
    /// Size of the area/leakage/f_op Pareto front among feasible points.
    pub front: usize,
    /// Minimum-cost point on that front; `None` iff nothing is feasible.
    pub choice: Option<Chosen>,
}

/// The heterogeneous composition report for one machine.
#[derive(Debug, Clone)]
pub struct Composition {
    pub machine: &'static str,
    /// Per (task, level) selections in [`workloads::all_demands`] order.
    pub per_demand: Vec<Selection>,
    /// Per cache level (L1 then L2): the envelope selection — one bank
    /// that serves every task at that level.
    pub per_level: Vec<Selection>,
    /// Distinct design points in the shared sweep cache.
    pub distinct: usize,
    /// Cache hits / underlying pipeline evaluations paid by *this*
    /// composition (a second composition over a shared cache pays 0).
    pub cache_hits: usize,
    pub cache_misses: usize,
    /// Fault-isolation report of the mega-sweep this composition paid
    /// (clean when fully served from a shared cache).  Quarantined
    /// design points are simply infeasible for every demand.
    pub health: RunHealth,
}

impl Composition {
    /// Portfolio area over the per-level envelope choices; `None` when
    /// some level found no feasible single bank.
    pub fn total_area_um2(&self) -> Option<f64> {
        self.per_level.iter().map(|s| s.choice.as_ref().map(|c| c.eval.area_um2)).sum()
    }

    /// Portfolio leakage over the per-level envelope choices.
    pub fn total_leakage_w(&self) -> Option<f64> {
        self.per_level.iter().map(|s| s.choice.as_ref().map(|c| c.eval.perf.leakage_w)).sum()
    }
}

/// The composition-layer Pareto front: minimize area and leakage,
/// maximize f_op.  Delegates to [`dse::pareto_front`], which also
/// drops electrically non-functional and NaN-fielded points — the
/// selection must never propagate an infeasible survivor into chosen
/// hardware.
pub fn pareto_area_leak_fop(points: &[Evaluated]) -> Vec<usize> {
    dse::pareto_front(
        points,
        &[dse::objectives::area, dse::objectives::leakage, dse::objectives::neg_f_op],
    )
}

/// Feasible set -> front -> minimum-cost selection for one demand.
/// Deterministic: ties in cost resolve to the earliest front index,
/// and the front preserves `evals` order.
pub fn select_for(
    evals: &[Evaluated],
    d: &Demand,
    w_delay: f64,
    w_area: f64,
    w_power: f64,
) -> Selection {
    let feasible: Vec<Evaluated> = evals
        .iter()
        .filter(|e| dse::shmoo_verdict(e, d).pass())
        .cloned()
        .collect();
    let front = pareto_area_leak_fop(&feasible);
    let w = CostWeights {
        w_delay,
        w_area,
        w_power,
        f_min_hz: d.read_freq_hz,
        t_retain_min_s: d.lifetime_s,
    };
    let choice = front
        .iter()
        .map(|&i| (i, dse::cost(&w, &feasible[i])))
        .filter(|(_, c)| c.is_finite())
        .min_by(|a, b| a.1.partial_cmp(&b.1).expect("finite costs compare"))
        .map(|(i, c)| {
            let e = feasible[i].clone();
            Chosen {
                freq_margin: e.perf.f_op_hz / d.read_freq_hz,
                retention_margin: e.perf.retention_s / d.lifetime_s,
                cost: c,
                eval: e,
                yield_p: None,
            }
        });
    Selection {
        demand: *d,
        envelope: false,
        feasible: feasible.len(),
        front: front.len(),
        choice,
    }
}

/// Statistical (Monte-Carlo) counterpart of [`select_for`]: a design
/// is feasible iff its demand-joint yield point estimate
/// ([`variation::DesignYield::yield_for`]) reaches `target` —
/// quarantined variants already counted against that yield — and the
/// front/cost ranking runs over the yield-adjusted points
/// ([`variation::DesignYield::adjusted`]: per-metric means over
/// functional samples), so selection optimizes the distribution's
/// center, not the nominal's optimism.  A yield-adjusted mean can
/// still miss a demand floor ([`dse::cost`] goes infinite); such a
/// design stays in `feasible` but cannot be chosen.
pub fn select_for_yield(
    dys: &[variation::DesignYield],
    d: &Demand,
    w_delay: f64,
    w_area: f64,
    w_power: f64,
    target: f64,
) -> Selection {
    let feasible: Vec<(f64, Evaluated)> = dys
        .iter()
        .filter_map(|dy| {
            let est = dy.yield_for(d);
            (est.p >= target).then(|| (est.p, dy.adjusted(target)))
        })
        .collect();
    let evals: Vec<Evaluated> = feasible.iter().map(|(_, e)| e.clone()).collect();
    let front = pareto_area_leak_fop(&evals);
    let w = CostWeights {
        w_delay,
        w_area,
        w_power,
        f_min_hz: d.read_freq_hz,
        t_retain_min_s: d.lifetime_s,
    };
    let choice = front
        .iter()
        .map(|&i| (i, dse::cost(&w, &evals[i])))
        .filter(|(_, c)| c.is_finite())
        .min_by(|a, b| a.1.partial_cmp(&b.1).expect("finite costs compare"))
        .map(|(i, c)| {
            let e = evals[i].clone();
            Chosen {
                freq_margin: e.perf.f_op_hz / d.read_freq_hz,
                retention_margin: e.perf.retention_s / d.lifetime_s,
                cost: c,
                eval: e,
                yield_p: Some(feasible[i].0),
            }
        });
    Selection {
        demand: *d,
        envelope: false,
        feasible: feasible.len(),
        front: front.len(),
        choice,
    }
}

/// Compose with throwaway sweep/structure caches — see [`compose_cached`].
pub fn compose(tech: &Tech, rt: &SharedRuntime, spec: &ComposeSpec) -> crate::Result<Composition> {
    compose_cached(tech, rt, spec, &EvalCache::new(), &CompileCache::new())
}

/// Run the cross-flavor mega-sweep through `cache` (one
/// [`dse::evaluate_all_batched_cached`] pass over [`design_grid`])
/// and select per-demand and per-level banks for `spec.machine`.
/// Passing one cache to several compositions (e.g. H100 then GT520M —
/// `bin/figures` does this) re-uses every evaluation: the demands only
/// change the selection, not the sweep.  The cache binds to
/// `spec.window_resolution` on first use ([`EvalCache::bind_resolution`]).
/// `structs` shares compiled geometry across the grid's VT axis (and
/// with any other sweep the caller runs), so the mega-sweep pays the
/// distinct-structure census — |{struct_key}| compiles, not |configs|.
pub fn compose_cached(
    tech: &Tech,
    rt: &SharedRuntime,
    spec: &ComposeSpec,
    cache: &EvalCache,
    structs: &CompileCache,
) -> crate::Result<Composition> {
    if let Some(model) = &spec.mc {
        // Monte-Carlo mode: sampled variants share their design's
        // ConfigKey, so the point cache cannot distinguish them — the
        // MC sweep bypasses it entirely (cache_hits reports 0).
        return compose_mc(tech, rt, spec, model, structs);
    }
    let configs = design_grid();
    let (h0, m0) = cache.stats();
    let (evals, health) = dse::evaluate_all_batched_cached_health(
        tech,
        rt,
        &configs,
        spec.workers,
        cache,
        structs,
        spec.window_resolution,
    )?;
    let (h1, m1) = cache.stats();
    let mut per_demand = Vec::new();
    for d in workloads::all_demands(spec.machine) {
        per_demand.push(select_for(&evals, &d, spec.w_delay, spec.w_area, spec.w_power));
    }
    let mut per_level = Vec::new();
    for level in [CacheLevel::L1, CacheLevel::L2] {
        let env = workloads::envelope(level, spec.machine);
        let mut s = select_for(&evals, &env, spec.w_delay, spec.w_area, spec.w_power);
        s.envelope = true;
        per_level.push(s);
    }
    Ok(Composition {
        machine: spec.machine.name,
        per_demand,
        per_level,
        distinct: cache.len(),
        cache_hits: h1 - h0,
        cache_misses: m1 - m0,
        health,
    })
}

/// Yield-aware composition: expand the whole design grid into
/// `model.samples` variants per design via one
/// [`variation::yield_sweep_health`] mega-batch (grouped-ceiling
/// execution counts across **all** `K x D` variants) and select
/// per-demand / per-level banks with [`select_for_yield`] at
/// `spec.yield_target`.  `cache_misses` reports the underlying
/// pipeline evaluations paid (`distinct * (K + 1)`: nominal plus K
/// samples per design); `cache_hits` is 0 by construction.
pub fn compose_mc(
    tech: &Tech,
    rt: &SharedRuntime,
    spec: &ComposeSpec,
    model: &variation::VariationModel,
    structs: &CompileCache,
) -> crate::Result<Composition> {
    let configs = design_grid();
    let (dys, health) = variation::yield_sweep_health(
        tech,
        rt,
        &configs,
        model,
        spec.workers,
        spec.window_resolution,
        structs,
    )?;
    let mut per_demand = Vec::new();
    for d in workloads::all_demands(spec.machine) {
        per_demand.push(select_for_yield(
            &dys,
            &d,
            spec.w_delay,
            spec.w_area,
            spec.w_power,
            spec.yield_target,
        ));
    }
    let mut per_level = Vec::new();
    for level in [CacheLevel::L1, CacheLevel::L2] {
        let env = workloads::envelope(level, spec.machine);
        let mut s = select_for_yield(
            &dys,
            &env,
            spec.w_delay,
            spec.w_area,
            spec.w_power,
            spec.yield_target,
        );
        s.envelope = true;
        per_level.push(s);
    }
    Ok(Composition {
        machine: spec.machine.name,
        per_demand,
        per_level,
        distinct: dys.len(),
        cache_hits: 0,
        cache_misses: dys.len() * (model.samples + 1),
        health,
    })
}

/// Runtime-free packing plan of the cross-flavor mega-sweep, computed
/// from the designs' own `CharPlan` window bits (compile + plan only;
/// no artifacts needed).  `retention_cap` is the retention artifact's
/// manifest batch size (256 for the shipped artifacts).
#[derive(Debug, Clone)]
pub struct SweepPlan {
    /// Distinct design points after [`ConfigKey`] dedup.
    pub distinct: usize,
    /// Transient-backed (gain-cell) design points: one write + one
    /// retention point and two read points each.
    pub transient: usize,
    /// Flavors contributing transient points.
    pub transient_flavors: usize,
    /// Write/read execution groups at the plan's resolution
    /// ([`characterize::window_group_counts`]).
    pub write_groups: usize,
    pub read_groups: usize,
    /// Retention executions the shared sweep issues: the grouped
    /// ceiling over **all** flavors' points in one batch sequence.
    pub retention_calls: usize,
    /// What per-flavor batching would have paid instead (the KPI
    /// baseline the `compose --plan` smoke asserts against).
    pub retention_calls_per_flavor: usize,
}

/// Compute the [`SweepPlan`] for `configs` at `window_resolution`.
pub fn plan(
    tech: &Tech,
    configs: &[Config],
    window_resolution: f64,
    retention_cap: usize,
) -> crate::Result<SweepPlan> {
    let mut seen: HashSet<ConfigKey> = HashSet::new();
    let mut distinct_cfgs: Vec<&Config> = Vec::new();
    for cfg in configs {
        let key = cfg.key();
        if !seen.contains(&key) {
            seen.insert(key);
            distinct_cfgs.push(cfg);
        }
    }
    // same structure-deduped compile fan-out as the real sweep (pure
    // geometry: the grid's VT axis shares compiled structures)
    let banks: Vec<_> =
        CompileCache::new().compile_all(tech, &distinct_cfgs, crate::util::default_workers())?;
    let (write_groups, read_groups) =
        characterize::window_group_counts(tech, &banks, window_resolution);
    let mut per_flavor: BTreeMap<CellFlavor, usize> = BTreeMap::new();
    for b in &banks {
        if b.config.flavor.is_gc() {
            *per_flavor.entry(b.config.flavor).or_insert(0) += 1;
        }
    }
    let transient: usize = per_flavor.values().sum();
    Ok(SweepPlan {
        distinct: banks.len(),
        transient,
        transient_flavors: per_flavor.len(),
        write_groups,
        read_groups,
        retention_calls: calls_for(transient, retention_cap),
        retention_calls_per_flavor: per_flavor
            .values()
            .map(|&n| calls_for(n, retention_cap))
            .sum(),
    })
}

/// Drive `points` retention-class jobs through a counting mock
/// coordinator executor (no artifacts, real batching machinery) and
/// return the executions it issued — by the coordinator's batching
/// invariants this equals [`calls_for`]`(points, cap)`.  The CI
/// "mock-coordinator" smoke (`opengcram compose --plan`) asserts it
/// against [`SweepPlan::retention_calls`].
pub fn mock_retention_calls(points: usize, cap: usize) -> crate::Result<usize> {
    struct CountingExec {
        cap: usize,
        calls: Arc<AtomicUsize>,
    }
    impl BatchExec<usize, usize> for CountingExec {
        fn run(&mut self, jobs: &[usize]) -> crate::Result<Vec<usize>> {
            self.calls.fetch_add(1, Ordering::SeqCst);
            Ok(jobs.to_vec())
        }
        fn max_batch(&self) -> usize {
            self.cap
        }
    }
    let calls = Arc::new(AtomicUsize::new(0));
    let c = Coordinator::spawn(CountingExec { cap: cap.max(1), calls: calls.clone() });
    let res = c.run_all((0..points).collect())?;
    anyhow::ensure!(res.len() == points, "mock coordinator lost jobs");
    Ok(calls.load(Ordering::SeqCst))
}

/// Render the composition as the terminal table `opengcram compose`
/// and `bin/figures` print: one row per (task, level) demand plus the
/// per-level envelope rows.
pub fn table(c: &Composition) -> String {
    let mut t = report::Table::new(&[
        "level", "task", "need MHz", "need life", "flavor", "bank", "vt", "f_op MHz",
        "bw Gb/s", "area um2", "leak nW", "xf", "xr", "feas", "front",
    ]);
    for s in c.per_demand.iter().chain(c.per_level.iter()) {
        t.row(&selection_row(s));
    }
    t.render()
}

fn selection_row(s: &Selection) -> Vec<String> {
    let d = &s.demand;
    let mut row = vec![
        format!("{:?}", d.level),
        if s.envelope { "(all tasks)".to_string() } else { d.task.name.to_string() },
        report::mhz(d.read_freq_hz),
        eng(d.lifetime_s, "s"),
    ];
    match &s.choice {
        None => {
            for _ in 0..9 {
                row.push("-".into());
            }
        }
        Some(ch) => {
            let cfg = &ch.eval.config;
            row.push(crate::cli::flavor_name(cfg.flavor).to_string());
            row.push(format!("{}x{}", cfg.word_size, cfg.num_words));
            row.push(cfg.write_vt.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()));
            row.push(report::mhz(ch.eval.perf.f_op_hz));
            row.push(report::gbps(ch.eval.perf.bandwidth_bps));
            row.push(report::um2(ch.eval.area_um2));
            row.push(format!("{:.1}", ch.eval.perf.leakage_w * 1e9));
            row.push(format!("{:.1}", ch.freq_margin));
            // SRAM retention is infinite; cap the printed margin so the
            // column stays narrow (the CSV carries the raw value)
            row.push(format!("{:.0}", ch.retention_margin.min(9999.0)));
        }
    }
    row.push(s.feasible.to_string());
    row.push(s.front.to_string());
    row
}

/// Machine-readable CSV of the composition (raw values, no rounding of
/// the demand columns).
pub fn csv(c: &Composition) -> String {
    let mut rows = Vec::new();
    for s in c.per_demand.iter().chain(c.per_level.iter()) {
        let d = &s.demand;
        let mut row = vec![
            c.machine.to_string(),
            format!("{:?}", d.level),
            d.task.name.to_string(),
            (s.envelope as u8).to_string(),
            report::sci(d.read_freq_hz),
            report::sci(d.lifetime_s),
        ];
        match &s.choice {
            None => row.extend(std::iter::repeat(String::new()).take(11)),
            Some(ch) => {
                let cfg = &ch.eval.config;
                row.push(crate::cli::flavor_name(cfg.flavor).to_string());
                row.push(cfg.word_size.to_string());
                row.push(cfg.num_words.to_string());
                row.push(cfg.write_vt.map(|v| format!("{v}")).unwrap_or_default());
                row.push(report::sci(ch.eval.perf.f_op_hz));
                row.push(report::gbps(ch.eval.perf.bandwidth_bps));
                row.push(report::um2(ch.eval.area_um2));
                row.push(report::sci(ch.eval.perf.leakage_w));
                row.push(report::sci(ch.freq_margin));
                row.push(report::sci(ch.retention_margin));
                row.push(report::sci(ch.cost));
            }
        }
        row.push(s.feasible.to_string());
        row.push(s.front.to_string());
        rows.push(row);
    }
    report::csv(
        &[
            "machine", "level", "task", "envelope", "demand_hz", "lifetime_s", "flavor",
            "word", "words", "vt", "f_op_hz", "bw_gbps", "area_um2", "leak_w",
            "freq_margin", "retention_margin", "cost", "feasible", "front",
        ],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::characterize::BankPerf;
    use crate::tech::sg40;

    fn fake(flavor: CellFlavor, f: f64, ret: f64, area: f64, leak: f64) -> Evaluated {
        Evaluated {
            config: Config::new(32, 32, flavor),
            perf: BankPerf {
                f_read_hz: f,
                f_write_hz: f,
                f_op_hz: f,
                bandwidth_bps: 64.0 * f,
                retention_s: ret,
                leakage_w: leak,
                e_read_j: 1e-12,
                t_decoder_s: 1e-10,
                t_cell_read_s: 1e-10,
                stored_one_v: 0.6,
                functional: true,
            },
            area_um2: area,
            quarantine: None,
        }
    }

    fn demand(f: f64, life: f64) -> Demand {
        Demand {
            task: workloads::TASKS[0],
            level: CacheLevel::L1,
            machine: "test",
            read_freq_hz: f,
            lifetime_s: life,
        }
    }

    #[test]
    fn design_grid_covers_all_flavors_without_duplicates() {
        let grid = design_grid();
        let keys: HashSet<_> = grid.iter().map(|c| c.key()).collect();
        assert_eq!(keys.len(), grid.len(), "duplicate design points");
        for f in FLAVORS {
            assert!(grid.iter().any(|c| c.flavor == f), "{f:?} missing");
        }
        // SRAM has no write transistor, so no VT axis
        assert!(grid
            .iter()
            .filter(|c| c.flavor == CellFlavor::Sram6t)
            .all(|c| c.write_vt.is_none()));
        let transient = grid.iter().filter(|c| c.flavor.is_gc()).count();
        assert_eq!(transient, 75, "3 GC flavors x 5 sizes x 5 VTs");
        assert_eq!(grid.len() - transient, 5, "SRAM sweeps sizes only");
    }

    #[test]
    fn plan_packs_cross_flavor_retention_into_one_shared_batch() {
        let t = sg40();
        // trim to the smallest size: the packing arithmetic is
        // size-independent and 16x16 compiles keep the test fast
        let grid: Vec<Config> =
            design_grid().into_iter().filter(|c| c.word_size == 16).collect();
        let p = plan(&t, &grid, characterize::DEFAULT_WINDOW_RESOLUTION, 256).unwrap();
        assert_eq!(p.distinct, 16);
        assert_eq!(p.transient, 15);
        assert_eq!(p.transient_flavors, 3);
        assert_eq!(p.retention_calls, 1, "one shared retention batch");
        assert_eq!(p.retention_calls_per_flavor, 3, "per-flavor batching pays one per flavor");
        assert!(p.write_groups >= 1 && p.write_groups <= p.transient);
        assert!(p.read_groups >= 1 && p.read_groups <= p.transient);
        // duplicated configs dedup before compiling
        let doubled: Vec<Config> = grid.iter().chain(grid.iter()).cloned().collect();
        let p2 = plan(&t, &doubled, characterize::DEFAULT_WINDOW_RESOLUTION, 256).unwrap();
        assert_eq!(p2.distinct, p.distinct);
        assert_eq!(p2.retention_calls, p.retention_calls);
    }

    #[test]
    fn mock_coordinator_issues_grouped_ceiling() {
        assert_eq!(mock_retention_calls(75, 256).unwrap(), 1);
        assert_eq!(mock_retention_calls(300, 256).unwrap(), 2);
        assert_eq!(mock_retention_calls(0, 256).unwrap(), 0);
    }

    #[test]
    fn selection_picks_min_cost_on_the_feasible_front() {
        let d = demand(1e9, 1e-4);
        let mut dead = fake(CellFlavor::Sram6t, 3e9, f64::INFINITY, 2e3, 1e-8);
        dead.perf.functional = false;
        let evals = vec![
            fake(CellFlavor::GcSiSiNp, 2e9, 1e-3, 1e4, 1e-6), // feasible
            fake(CellFlavor::GcOsOs, 1.5e9, 1e-2, 5e3, 5e-7), // feasible, cheaper overall
            fake(CellFlavor::GcSiSiNn, 0.5e9, 1e-3, 1e3, 1e-7), // too slow
            dead, // would dominate everything, but non-functional
        ];
        let s = select_for(&evals, &d, 1.0, 0.5, 0.5);
        assert_eq!(s.feasible, 2);
        assert!(s.front >= 1 && s.front <= s.feasible);
        let ch = s.choice.expect("two feasible points");
        assert_eq!(ch.eval.config.flavor, CellFlavor::GcOsOs, "min-cost point");
        assert!(ch.freq_margin >= 1.0 && ch.retention_margin >= 1.0);
        assert!(ch.cost.is_finite());
        // an unservable demand yields an empty selection, not a panic
        let none = select_for(&evals, &demand(1e12, 1.0), 1.0, 0.5, 0.5);
        assert_eq!((none.feasible, none.front), (0, 0));
        assert!(none.choice.is_none());
    }

    fn fake_yield(flavor: CellFlavor, f: f64, ret: f64, area: f64, pass: usize, k: usize) -> variation::DesignYield {
        // `pass` samples meet everything, the rest fail margin
        let mut samples = Vec::new();
        for i in 0..k {
            let mut e = fake(flavor, f, ret, area, 1e-7);
            if i >= pass {
                e.perf.functional = false;
            }
            samples.push(e);
        }
        let functional = pass;
        let stats = variation::YieldStats {
            functional: variation::wilson(functional, k, variation::WILSON_Z),
            f_op_hz: variation::metric_stats(&vec![f; pass.max(1)]),
            retention_s: variation::metric_stats(&vec![ret; pass.max(1)]),
            leakage_w: variation::metric_stats(&[1e-7]),
            stored_one_v: variation::metric_stats(&[0.6]),
            quarantined: Vec::new(),
        };
        variation::DesignYield {
            config: Config::new(32, 32, flavor),
            area_um2: area,
            nominal: fake(flavor, f, ret, area, 1e-7),
            samples,
            stats,
        }
    }

    #[test]
    fn yield_selection_gates_on_target_and_ranks_adjusted_means() {
        let d = demand(1e9, 1e-4);
        let dys = vec![
            fake_yield(CellFlavor::GcSiSiNp, 2e9, 1e-3, 1e4, 8, 8), // yield 1.0
            fake_yield(CellFlavor::GcOsOs, 2e9, 1e-2, 5e3, 6, 8),   // yield 0.75
        ];
        // strict target: only the perfect design survives
        let s = select_for_yield(&dys, &d, 1.0, 0.5, 0.5, 0.99);
        assert_eq!(s.feasible, 1);
        let ch = s.choice.expect("one yield-feasible design");
        assert_eq!(ch.eval.config.flavor, CellFlavor::GcSiSiNp);
        assert_eq!(ch.yield_p, Some(1.0));
        // lax target: both survive, the smaller/cooler OS point wins
        let s = select_for_yield(&dys, &d, 1.0, 0.5, 0.5, 0.5);
        assert_eq!(s.feasible, 2);
        let ch = s.choice.expect("both feasible");
        assert_eq!(ch.eval.config.flavor, CellFlavor::GcOsOs);
        assert_eq!(ch.yield_p, Some(0.75));
        // nothing reaches an impossible demand
        let s = select_for_yield(&dys, &demand(1e12, 1.0), 1.0, 0.5, 0.5, 0.5);
        assert_eq!((s.feasible, s.front), (0, 0));
        assert!(s.choice.is_none());
    }

    #[test]
    fn totals_need_every_level_served() {
        let d = demand(1e9, 1e-4);
        let chosen = Selection {
            demand: d,
            envelope: true,
            feasible: 1,
            front: 1,
            choice: Some(Chosen {
                eval: fake(CellFlavor::GcSiSiNp, 2e9, 1e-3, 1e4, 1e-6),
                cost: 1.0,
                freq_margin: 2.0,
                retention_margin: 10.0,
                yield_p: None,
            }),
        };
        let empty = Selection { demand: d, envelope: true, feasible: 0, front: 0, choice: None };
        let c = Composition {
            machine: "test",
            per_demand: vec![],
            per_level: vec![chosen.clone(), empty],
            distinct: 0,
            cache_hits: 0,
            cache_misses: 0,
            health: RunHealth::default(),
        };
        assert!(c.total_area_um2().is_none());
        assert!(c.total_leakage_w().is_none());
        let c2 = Composition { per_level: vec![chosen.clone(), chosen], ..c };
        assert_eq!(c2.total_area_um2(), Some(2e4));
        assert!((c2.total_leakage_w().unwrap() - 2e-6).abs() < 1e-18);
    }

    #[test]
    fn table_and_csv_render_selection_and_empty_rows() {
        let d = demand(1e9, 1e-4);
        let sel = select_for(
            &[fake(CellFlavor::GcOsOs, 2e9, 1e-2, 5e3, 5e-7)],
            &d,
            1.0,
            0.5,
            0.5,
        );
        let none = select_for(&[], &d, 1.0, 0.5, 0.5);
        let mut env = none.clone();
        env.envelope = true;
        let c = Composition {
            machine: "test",
            per_demand: vec![sel, none],
            per_level: vec![env.clone(), env],
            distinct: 1,
            cache_hits: 0,
            cache_misses: 1,
            health: RunHealth::default(),
        };
        let t = table(&c);
        assert!(t.contains("os"), "{t}");
        assert!(t.contains("(all tasks)"), "{t}");
        // header + separator + 4 rows
        assert_eq!(t.lines().count(), 6, "{t}");
        let s = csv(&c);
        assert_eq!(s.lines().count(), 5, "{s}");
        assert!(s.starts_with("machine,level,task,envelope"), "{s}");
        // every row has the full column count, selected or not
        let cols = s.lines().next().unwrap().split(',').count();
        for line in s.lines() {
            assert_eq!(line.split(',').count(), cols, "{line}");
        }
    }
}
