//! Design-rule types.  Numeric rules only (width / spacing / area /
//! enclosure / extension) -- exactly the rule classes the paper lists
//! for the OS-OS cell ("the layout meets the basic FEOL design rules
//! regarding width, space, enclosure and extension", Fig. 3 caption).

use super::LayerRole;
use std::collections::BTreeMap;

/// Same-layer rules.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerRules {
    pub min_width_nm: i64,
    pub min_space_nm: i64,
    /// Minimum polygon area in nm^2 (0 = unchecked).
    pub min_area_nm2: i64,
}

/// Enclosure axis: full enclosure, or extension along one axis only
/// (gate-extension rules: the gate must extend past the channel in its
/// long axis but does not cover it side-to-side).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EncAxis {
    #[default]
    Both,
    X,
    Y,
}

/// `outer` must enclose `inner` by at least `margin_nm` (per `axis`)
/// wherever `inner` overlaps the outer layer (conditional enclosure:
/// a contact on poly is not checked against active).
#[derive(Debug, Clone, Copy)]
pub struct EnclosureRule {
    pub outer: LayerRole,
    pub inner: LayerRole,
    pub margin_nm: i64,
    pub axis: EncAxis,
}

/// Cross-layer spacing (e.g. poly to unrelated active).
#[derive(Debug, Clone, Copy)]
pub struct SpacingRule {
    pub a: LayerRole,
    pub b: LayerRole,
    pub space_nm: i64,
}

/// The full rule deck for a node.
#[derive(Debug, Clone, Default)]
pub struct DrcRules {
    per_layer: BTreeMap<LayerRole, LayerRules>,
    pub enclosures: Vec<EnclosureRule>,
    pub cross_spacings: Vec<SpacingRule>,
}

impl DrcRules {
    pub fn set(&mut self, role: LayerRole, rules: LayerRules) {
        self.per_layer.insert(role, rules);
    }

    /// Rules for a layer; zeroed default means "unchecked layer".
    pub fn layer(&self, role: LayerRole) -> LayerRules {
        self.per_layer.get(&role).copied().unwrap_or_default()
    }

    pub fn checked_layers(&self) -> impl Iterator<Item = (&LayerRole, &LayerRules)> {
        self.per_layer.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_layer_is_unchecked() {
        let r = DrcRules::default();
        assert_eq!(r.layer(LayerRole::Metal3).min_width_nm, 0);
    }

    #[test]
    fn set_then_get() {
        let mut r = DrcRules::default();
        r.set(
            LayerRole::Poly,
            LayerRules { min_width_nm: 40, min_space_nm: 120, min_area_nm2: 0 },
        );
        assert_eq!(r.layer(LayerRole::Poly).min_space_nm, 120);
    }
}
