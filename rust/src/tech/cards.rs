//! Device cards: the EKV-style compact-model parameters.
//!
//! These MUST mirror `python/compile/device.py` parameter-for-parameter;
//! the cross-language parity is enforced by an integration test that
//! executes the `idvg` HLO artifact and compares it with
//! [`crate::sim::mos_ids`] over a voltage grid.

/// Polarity / channel material of a card.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    SiNmos,
    SiPmos,
    /// Back-end-of-line oxide-semiconductor NMOS (ITO-like).
    OsNmos,
}

/// EKV card: `[kp, vt, n, lam, w_over_l, sign]` is the wire format used
/// by the XLA artifacts (see manifest `card_cols`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceCard {
    pub kind: DeviceKind,
    /// Transconductance factor for W/L = 1, A/V^2.
    pub kp: f64,
    /// Threshold voltage, V (positive for both polarities).
    pub vt: f64,
    /// Subthreshold slope factor (SS = n * phi_t * ln 10).
    pub n: f64,
    /// Channel-length-modulation coefficient, 1/V.
    pub lam: f64,
}

impl DeviceCard {
    pub fn sign(&self) -> f64 {
        match self.kind {
            DeviceKind::SiPmos => -1.0,
            _ => 1.0,
        }
    }

    /// Pack into the 6-column artifact row for a given geometry.
    pub fn to_row(&self, w_over_l: f64) -> [f32; 6] {
        [
            self.kp as f32,
            self.vt as f32,
            self.n as f32,
            self.lam as f32,
            w_over_l as f32,
            self.sign() as f32,
        ]
    }

    /// Apply a PVT corner.
    pub fn at_corner(&self, c: &super::Corner) -> DeviceCard {
        DeviceCard { kp: self.kp * c.kp_scale, vt: self.vt + c.vt_shift, ..*self }
    }

    /// Copy with a shifted threshold (retention-modulation sweeps,
    /// Fig. 8c).
    pub fn with_vt(&self, vt: f64) -> DeviceCard {
        DeviceCard { vt, ..*self }
    }
}

/// `sg40` cards — numerically identical to python/compile/device.py.
pub mod sg40 {
    use super::{DeviceCard, DeviceKind};

    pub const SI_NMOS: DeviceCard = DeviceCard {
        kind: DeviceKind::SiNmos,
        kp: 320e-6,
        vt: 0.45,
        n: 1.40,
        lam: 0.08,
    };
    pub const SI_PMOS: DeviceCard = DeviceCard {
        kind: DeviceKind::SiPmos,
        kp: 160e-6,
        vt: 0.45,
        n: 1.42,
        lam: 0.10,
    };
    pub const SI_NMOS_HVT: DeviceCard = DeviceCard {
        kind: DeviceKind::SiNmos,
        kp: 280e-6,
        vt: 0.60,
        n: 1.36,
        lam: 0.07,
    };
    pub const SI_NMOS_LVT: DeviceCard = DeviceCard {
        kind: DeviceKind::SiNmos,
        kp: 360e-6,
        vt: 0.32,
        n: 1.45,
        lam: 0.10,
    };
    /// High-|VT| PMOS for the NP gain cell's read transistor: with the
    /// stored '1' at VDD-VTn a nominal-VT PMOS stays weakly on; the HVT
    /// flavor restores the read margin (paper SS V-C).  The value also
    /// folds in the body effect of a source-at-VDD device that the
    /// bulk-referenced EKV mirror does not model explicitly:
    /// vt_eff ~ vt + (n-1)*vdd.
    pub const SI_PMOS_HVT: DeviceCard = DeviceCard {
        kind: DeviceKind::SiPmos,
        kp: 140e-6,
        vt: 0.90,
        n: 1.38,
        lam: 0.08,
    };
    pub const OS_NMOS: DeviceCard = DeviceCard {
        kind: DeviceKind::OsNmos,
        kp: 12e-6,
        vt: 0.35,
        n: 1.10,
        lam: 0.02,
    };
    pub const OS_NMOS_HVT: DeviceCard = DeviceCard {
        kind: DeviceKind::OsNmos,
        kp: 9e-6,
        vt: 0.95,
        n: 1.08,
        lam: 0.02,
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_layout_matches_manifest_card_cols() {
        let r = sg40::SI_PMOS.to_row(2.0);
        assert_eq!(r[0], 160e-6_f32);
        assert_eq!(r[1], 0.45);
        assert_eq!(r[4], 2.0);
        assert_eq!(r[5], -1.0);
    }

    #[test]
    fn corner_shifts_apply() {
        let c = crate::tech::Corner {
            name: "ss",
            kp_scale: 0.9,
            vt_shift: 0.05,
            vdd: 1.0,
            temp_c: 125.0,
        };
        let d = sg40::SI_NMOS.at_corner(&c);
        assert!((d.kp - 288e-6).abs() < 1e-9);
        assert!((d.vt - 0.50).abs() < 1e-12);
    }

    #[test]
    fn vt_override() {
        let d = sg40::OS_NMOS.with_vt(0.8);
        assert_eq!(d.vt, 0.8);
        assert_eq!(d.kp, 12e-6);
    }
}
