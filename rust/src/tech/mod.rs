//! Technology (PDK) infrastructure: layer stacks, design rules, device
//! cards, wire parasitics and PVT corners.
//!
//! The paper ports OpenRAM to TSMC 40 nm (under NDA).  We ship `sg40`, a
//! *synthetic generic 40 nm* node whose rule set exercises the identical
//! compiler code paths (layer math -> layout generation -> DRC), plus
//! `sg130`, a relaxed synthetic 130 nm-class node that demonstrates the
//! Fig. 1(a) porting methodology: a new node is nothing but a new
//! [`Tech`] value built through [`TechBuilder`].
//!
//! Everything is data: no compiler code matches on a technology name.

pub mod cards;
pub mod rules;

pub use cards::{DeviceCard, DeviceKind};
pub use rules::{DrcRules, EnclosureRule, LayerRules, SpacingRule};

use std::collections::BTreeMap;

/// Process layer kind; drives DRC selection and GDS export.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LayerKind {
    /// Front-end-of-line: diffusion, wells, poly, implants.
    Feol,
    /// Contacts and vias.
    Cut,
    /// Metal routing layers.
    Metal,
    /// Back-end-of-line oxide-semiconductor device layers (the OS-OS
    /// gain cell is fabricated between tight-pitched metals and can be
    /// 3D-stacked over FEOL, paper §V-A).
    OsDevice,
    /// Non-physical annotation (pins, labels, boundary).
    Annotation,
}

/// One mask layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    pub name: &'static str,
    /// GDSII layer number.
    pub gds: i16,
    /// GDSII datatype.
    pub datatype: i16,
    pub kind: LayerKind,
}

/// Canonical layer indices used by the generators (indexes into
/// `Tech::layers`).  Generators refer to layers via these roles so a new
/// node only has to *provide* the roles, not renumber code.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LayerRole {
    Nwell,
    Active,
    Poly,
    Nimplant,
    Pimplant,
    Contact,
    Metal1,
    Via1,
    Metal2,
    Via2,
    Metal3,
    /// BEOL oxide-semiconductor channel.
    OsChannel,
    /// BEOL OS gate electrode.
    OsGate,
    Boundary,
    PinLabel,
}

/// Per-layer wire parasitics for analytical delay (GEMTOO-class model).
#[derive(Debug, Clone, Copy)]
pub struct WireRc {
    /// Sheet resistance, ohm/square.
    pub r_sq: f64,
    /// Area capacitance, F/nm^2.
    pub c_area: f64,
    /// Fringe capacitance, F/nm of perimeter.
    pub c_fringe: f64,
}

/// Process-voltage-temperature corner.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Corner {
    pub name: &'static str,
    /// Multiplier on card `kp` (process speed).
    pub kp_scale: f64,
    /// Additive shift on card `vt` (V).
    pub vt_shift: f64,
    pub vdd: f64,
    pub temp_c: f64,
}

impl Corner {
    pub fn typical(vdd: f64) -> Corner {
        Corner { name: "tt", kp_scale: 1.0, vt_shift: 0.0, vdd, temp_c: 25.0 }
    }
}

/// Per-instance Monte-Carlo variation defaults for one device class
/// ("si" FEOL transistors, "os" BEOL oxide-semiconductor transistors).
/// Corners model systematic die-to-die shift; these sigmas model the
/// *within-die* mismatch sampled per cell instance by the `variation`
/// subsystem.  OS thin-film devices are known to have wider VT spread
/// than crystalline silicon, which is exactly the trade the paper's
/// retention-vs-speed story hinges on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VariationDefaults {
    /// Per-instance VT sigma (V), applied to the cell transistors.
    pub sigma_vt: f64,
    /// Relative sigma on geometry-derived electricals (kp, node and
    /// bitline capacitance) from line-edge/thickness variation.
    pub sigma_geom: f64,
    /// Relative sigma on the local supply seen by the cell (IR droop).
    pub sigma_vdd: f64,
}

impl VariationDefaults {
    /// Conservative fallback used when a node does not declare its own
    /// numbers (keeps `variation` runnable on minimal TechBuilder techs).
    pub fn generic() -> VariationDefaults {
        VariationDefaults { sigma_vt: 0.02, sigma_geom: 0.02, sigma_vdd: 0.01 }
    }
}

/// A full technology description.
#[derive(Debug, Clone)]
pub struct Tech {
    pub name: &'static str,
    /// Feature size tag in nm (documentation only).
    pub node_nm: u32,
    pub vdd: f64,
    pub layers: Vec<Layer>,
    roles: BTreeMap<LayerRole, usize>,
    pub rules: DrcRules,
    pub wires: BTreeMap<LayerRole, WireRc>,
    pub cards: BTreeMap<&'static str, DeviceCard>,
    pub corners: Vec<Corner>,
    /// Monte-Carlo variation defaults per device class ("si", "os").
    pub variation: BTreeMap<&'static str, VariationDefaults>,
    /// Gate capacitance per W/L unit (F); pairs with `cards`.
    pub c_gate_unit: f64,
    /// Drain junction capacitance per W/L unit (F).
    pub c_junction_unit: f64,
}

impl Tech {
    pub fn layer(&self, role: LayerRole) -> usize {
        *self
            .roles
            .get(&role)
            .unwrap_or_else(|| panic!("tech {} missing layer role {role:?}", self.name))
    }

    pub fn has_role(&self, role: LayerRole) -> bool {
        self.roles.contains_key(&role)
    }

    pub fn layer_info(&self, role: LayerRole) -> &Layer {
        &self.layers[self.layer(role)]
    }

    pub fn card(&self, name: &str) -> &DeviceCard {
        self.cards
            .get(name)
            .unwrap_or_else(|| panic!("tech {} missing device card {name}", self.name))
    }

    pub fn wire(&self, role: LayerRole) -> WireRc {
        *self
            .wires
            .get(&role)
            .unwrap_or_else(|| panic!("tech {} missing wire RC for {role:?}", self.name))
    }

    pub fn corner(&self, name: &str) -> Option<&Corner> {
        self.corners.iter().find(|c| c.name == name)
    }

    /// Variation defaults for a device class ("si" / "os"); nodes that
    /// do not declare the class fall back to the generic numbers.
    pub fn variation_for(&self, class: &str) -> VariationDefaults {
        self.variation
            .get(class)
            .copied()
            .unwrap_or_else(VariationDefaults::generic)
    }
}

/// Builder implementing the Fig. 1(a) porting flow: layer definitions,
/// basic design rules, device models, wire parasitics — then validate.
#[derive(Debug, Default)]
pub struct TechBuilder {
    name: Option<&'static str>,
    node_nm: u32,
    vdd: f64,
    layers: Vec<Layer>,
    roles: BTreeMap<LayerRole, usize>,
    rules: DrcRules,
    wires: BTreeMap<LayerRole, WireRc>,
    cards: BTreeMap<&'static str, DeviceCard>,
    corners: Vec<Corner>,
    variation: BTreeMap<&'static str, VariationDefaults>,
    c_gate_unit: f64,
    c_junction_unit: f64,
}

impl TechBuilder {
    pub fn new(name: &'static str, node_nm: u32, vdd: f64) -> Self {
        TechBuilder {
            name: Some(name),
            node_nm,
            vdd,
            c_gate_unit: 1e-15,
            c_junction_unit: 0.5e-15,
            ..Default::default()
        }
    }

    pub fn layer(mut self, role: LayerRole, layer: Layer) -> Self {
        self.roles.insert(role, self.layers.len());
        self.layers.push(layer);
        self
    }

    pub fn layer_rules(mut self, role: LayerRole, r: LayerRules) -> Self {
        self.rules.set(role, r);
        self
    }

    pub fn enclosure(mut self, outer: LayerRole, inner: LayerRole, margin_nm: i64) -> Self {
        self.rules.enclosures.push(EnclosureRule {
            outer,
            inner,
            margin_nm,
            axis: rules::EncAxis::Both,
        });
        self
    }

    /// Extension-style rule: enclosure along one axis only (e.g. gate
    /// extension past the channel).
    pub fn extension(
        mut self,
        outer: LayerRole,
        inner: LayerRole,
        margin_nm: i64,
        axis: rules::EncAxis,
    ) -> Self {
        self.rules.enclosures.push(EnclosureRule { outer, inner, margin_nm, axis });
        self
    }

    pub fn spacing(mut self, a: LayerRole, b: LayerRole, space_nm: i64) -> Self {
        self.rules.cross_spacings.push(SpacingRule { a, b, space_nm });
        self
    }

    pub fn wire(mut self, role: LayerRole, rc: WireRc) -> Self {
        self.wires.insert(role, rc);
        self
    }

    pub fn card(mut self, name: &'static str, card: DeviceCard) -> Self {
        self.cards.insert(name, card);
        self
    }

    pub fn corner(mut self, c: Corner) -> Self {
        self.corners.push(c);
        self
    }

    pub fn variation(mut self, class: &'static str, v: VariationDefaults) -> Self {
        self.variation.insert(class, v);
        self
    }

    pub fn caps(mut self, c_gate_unit: f64, c_junction_unit: f64) -> Self {
        self.c_gate_unit = c_gate_unit;
        self.c_junction_unit = c_junction_unit;
        self
    }

    /// Validate completeness (the "run DRC/LVS and iterate" step of
    /// Fig. 1(a) catches rule gaps; this catches structural gaps).
    pub fn build(self) -> crate::Result<Tech> {
        let name = self.name.unwrap_or("unnamed");
        for role in [
            LayerRole::Active,
            LayerRole::Poly,
            LayerRole::Contact,
            LayerRole::Metal1,
            LayerRole::Metal2,
            LayerRole::Boundary,
        ] {
            anyhow::ensure!(
                self.roles.contains_key(&role),
                "tech {name}: required layer role {role:?} missing"
            );
        }
        anyhow::ensure!(
            !self.cards.is_empty(),
            "tech {name}: no device cards"
        );
        anyhow::ensure!(self.vdd > 0.0, "tech {name}: vdd must be positive");
        let mut corners = self.corners;
        if corners.is_empty() {
            corners.push(Corner::typical(self.vdd));
        }
        Ok(Tech {
            name,
            node_nm: self.node_nm,
            vdd: self.vdd,
            layers: self.layers,
            roles: self.roles,
            rules: self.rules,
            wires: self.wires,
            cards: self.cards,
            corners,
            variation: self.variation,
            c_gate_unit: self.c_gate_unit,
            c_junction_unit: self.c_junction_unit,
        })
    }
}

mod sg130;
mod sg40;

pub use sg130::sg130;
pub use sg40::sg40;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sg40_has_all_roles_and_cards() {
        let t = sg40();
        for role in [
            LayerRole::Nwell,
            LayerRole::Active,
            LayerRole::Poly,
            LayerRole::Contact,
            LayerRole::Metal1,
            LayerRole::Metal2,
            LayerRole::Metal3,
            LayerRole::OsChannel,
            LayerRole::OsGate,
            LayerRole::Boundary,
        ] {
            assert!(t.has_role(role), "{role:?}");
        }
        for card in ["si_nmos", "si_pmos", "si_nmos_hvt", "si_nmos_lvt", "os_nmos", "os_nmos_hvt"] {
            assert!(t.cards.contains_key(card), "{card}");
        }
        assert!(t.vdd > 1.0 && t.vdd < 1.3);
    }

    #[test]
    fn sg130_is_a_relaxed_node() {
        let a = sg40();
        let b = sg130();
        let w40 = a.rules.layer(LayerRole::Metal1).min_width_nm;
        let w130 = b.rules.layer(LayerRole::Metal1).min_width_nm;
        assert!(w130 > w40, "sg130 rules must be looser than sg40");
        assert!(b.vdd > a.vdd);
    }

    #[test]
    fn builder_rejects_incomplete_tech() {
        let r = TechBuilder::new("bad", 40, 1.1).build();
        assert!(r.is_err());
    }

    #[test]
    fn corners_default_to_typical() {
        let t = sg40();
        assert!(t.corner("tt").is_some());
    }

    #[test]
    fn variation_defaults_declared_and_fallback() {
        let t = sg40();
        let si = t.variation_for("si");
        let os = t.variation_for("os");
        assert!(si.sigma_vt > 0.0 && os.sigma_vt > si.sigma_vt, "OS spread wider than Si");
        // unknown class falls back instead of panicking
        assert_eq!(t.variation_for("ge"), VariationDefaults::generic());
    }

    #[test]
    #[should_panic]
    fn missing_card_panics_with_context() {
        let t = sg40();
        t.card("does_not_exist");
    }
}
