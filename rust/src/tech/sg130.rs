//! `sg130`: a relaxed synthetic 130 nm-class node.
//!
//! Exists to *prove* the Fig. 1(a) porting methodology: the whole
//! compiler (cells, banks, DRC, LVS, characterization) runs unmodified
//! on a second node that differs only in data.  `rust/examples/
//! porting_new_tech.rs` walks through the port step by step.

use super::cards::{DeviceCard, DeviceKind};
use super::{Corner, Layer, LayerKind, LayerRole, LayerRules, Tech, TechBuilder, WireRc};

pub fn sg130() -> Tech {
    let si_nmos = DeviceCard { kind: DeviceKind::SiNmos, kp: 170e-6, vt: 0.38, n: 1.35, lam: 0.06 };
    let si_pmos = DeviceCard { kind: DeviceKind::SiPmos, kp: 70e-6, vt: 0.40, n: 1.38, lam: 0.08 };

    TechBuilder::new("sg130", 130, 1.8)
        .layer(LayerRole::Nwell, Layer { name: "nwell", gds: 1, datatype: 0, kind: LayerKind::Feol })
        .layer(LayerRole::Active, Layer { name: "active", gds: 2, datatype: 0, kind: LayerKind::Feol })
        .layer(LayerRole::Poly, Layer { name: "poly", gds: 3, datatype: 0, kind: LayerKind::Feol })
        .layer(LayerRole::Nimplant, Layer { name: "nimplant", gds: 4, datatype: 0, kind: LayerKind::Feol })
        .layer(LayerRole::Pimplant, Layer { name: "pimplant", gds: 5, datatype: 0, kind: LayerKind::Feol })
        .layer(LayerRole::Contact, Layer { name: "contact", gds: 10, datatype: 0, kind: LayerKind::Cut })
        .layer(LayerRole::Metal1, Layer { name: "metal1", gds: 11, datatype: 0, kind: LayerKind::Metal })
        .layer(LayerRole::Via1, Layer { name: "via1", gds: 12, datatype: 0, kind: LayerKind::Cut })
        .layer(LayerRole::Metal2, Layer { name: "metal2", gds: 13, datatype: 0, kind: LayerKind::Metal })
        .layer(LayerRole::Via2, Layer { name: "via2", gds: 14, datatype: 0, kind: LayerKind::Cut })
        .layer(LayerRole::Metal3, Layer { name: "metal3", gds: 15, datatype: 0, kind: LayerKind::Metal })
        .layer(LayerRole::Boundary, Layer { name: "boundary", gds: 63, datatype: 0, kind: LayerKind::Annotation })
        .layer(LayerRole::PinLabel, Layer { name: "pin", gds: 62, datatype: 0, kind: LayerKind::Annotation })
        .layer_rules(LayerRole::Nwell, LayerRules { min_width_nm: 1200, min_space_nm: 1200, min_area_nm2: 0 })
        .layer_rules(LayerRole::Active, LayerRules { min_width_nm: 200, min_space_nm: 300, min_area_nm2: 120_000 })
        .layer_rules(LayerRole::Poly, LayerRules { min_width_nm: 130, min_space_nm: 300, min_area_nm2: 0 })
        .layer_rules(LayerRole::Contact, LayerRules { min_width_nm: 160, min_space_nm: 200, min_area_nm2: 0 })
        .layer_rules(LayerRole::Metal1, LayerRules { min_width_nm: 160, min_space_nm: 180, min_area_nm2: 80_000 })
        .layer_rules(LayerRole::Via1, LayerRules { min_width_nm: 160, min_space_nm: 220, min_area_nm2: 0 })
        .layer_rules(LayerRole::Metal2, LayerRules { min_width_nm: 200, min_space_nm: 210, min_area_nm2: 100_000 })
        .layer_rules(LayerRole::Via2, LayerRules { min_width_nm: 200, min_space_nm: 250, min_area_nm2: 0 })
        .layer_rules(LayerRole::Metal3, LayerRules { min_width_nm: 300, min_space_nm: 300, min_area_nm2: 0 })
        .enclosure(LayerRole::Active, LayerRole::Contact, 60)
        .enclosure(LayerRole::Metal1, LayerRole::Contact, 30)
        .enclosure(LayerRole::Metal1, LayerRole::Via1, 30)
        .enclosure(LayerRole::Metal2, LayerRole::Via1, 30)
        .enclosure(LayerRole::Metal2, LayerRole::Via2, 30)
        .enclosure(LayerRole::Metal3, LayerRole::Via2, 30)
        .spacing(LayerRole::Poly, LayerRole::Contact, 140)
        .spacing(LayerRole::Active, LayerRole::Nwell, 300)
        .wire(LayerRole::Metal1, WireRc { r_sq: 0.08, c_area: 3.0e-26, c_fringe: 5.0e-20 })
        .wire(LayerRole::Metal2, WireRc { r_sq: 0.07, c_area: 2.7e-26, c_fringe: 4.5e-20 })
        .wire(LayerRole::Metal3, WireRc { r_sq: 0.05, c_area: 2.2e-26, c_fringe: 4.0e-20 })
        .wire(LayerRole::Poly, WireRc { r_sq: 7.0, c_area: 8.0e-26, c_fringe: 7.0e-20 })
        .card("si_nmos", si_nmos)
        .card("si_pmos", si_pmos)
        .caps(0.18e-15, 0.12e-15)
        .corner(Corner::typical(1.8))
        .build()
        .expect("sg130 tech must validate")
}
