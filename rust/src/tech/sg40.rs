//! `sg40`: synthetic generic 40 nm logic node.
//!
//! Rule numbers are representative of a 40 nm-class planar process
//! (gate length 40 nm, contacted poly pitch 160 nm, M1 half-pitch
//! 60 nm).  They are NOT any foundry's numbers -- the real TSMC N40
//! deck is NDA'd (paper footnote 1) -- but they exercise every rule
//! class the compiler must satisfy and land the bitcell area ratios of
//! Fig. 3 (Si-Si GC ~ 69 %, OS-OS ~ 11 % of 6T SRAM).
//!
//! M1/M2/via spacing is intentionally permissive (20 nm) to fit the
//! simplified three-layer intra-cell router; the compiler exercises the
//! same rule *classes* either way, and sg130 provides a strict deck.

use super::cards::sg40 as cards;
use super::{Corner, Layer, LayerKind, LayerRole, LayerRules, Tech, TechBuilder, VariationDefaults, WireRc};

pub fn sg40() -> Tech {
    TechBuilder::new("sg40", 40, 1.1)
        // ---- layer stack -------------------------------------------------
        .layer(LayerRole::Nwell, Layer { name: "nwell", gds: 1, datatype: 0, kind: LayerKind::Feol })
        .layer(LayerRole::Active, Layer { name: "active", gds: 2, datatype: 0, kind: LayerKind::Feol })
        .layer(LayerRole::Poly, Layer { name: "poly", gds: 3, datatype: 0, kind: LayerKind::Feol })
        .layer(LayerRole::Nimplant, Layer { name: "nimplant", gds: 4, datatype: 0, kind: LayerKind::Feol })
        .layer(LayerRole::Pimplant, Layer { name: "pimplant", gds: 5, datatype: 0, kind: LayerKind::Feol })
        .layer(LayerRole::Contact, Layer { name: "contact", gds: 10, datatype: 0, kind: LayerKind::Cut })
        .layer(LayerRole::Metal1, Layer { name: "metal1", gds: 11, datatype: 0, kind: LayerKind::Metal })
        .layer(LayerRole::Via1, Layer { name: "via1", gds: 12, datatype: 0, kind: LayerKind::Cut })
        .layer(LayerRole::Metal2, Layer { name: "metal2", gds: 13, datatype: 0, kind: LayerKind::Metal })
        .layer(LayerRole::Via2, Layer { name: "via2", gds: 14, datatype: 0, kind: LayerKind::Cut })
        .layer(LayerRole::Metal3, Layer { name: "metal3", gds: 15, datatype: 0, kind: LayerKind::Metal })
        // BEOL oxide-semiconductor device layers (between M2 and M3;
        // monolithically stackable over FEOL, paper §V-A)
        .layer(LayerRole::OsChannel, Layer { name: "oschannel", gds: 30, datatype: 0, kind: LayerKind::OsDevice })
        .layer(LayerRole::OsGate, Layer { name: "osgate", gds: 31, datatype: 0, kind: LayerKind::OsDevice })
        .layer(LayerRole::Boundary, Layer { name: "boundary", gds: 63, datatype: 0, kind: LayerKind::Annotation })
        .layer(LayerRole::PinLabel, Layer { name: "pin", gds: 62, datatype: 0, kind: LayerKind::Annotation })
        // ---- same-layer rules -------------------------------------------
        .layer_rules(LayerRole::Nwell, LayerRules { min_width_nm: 300, min_space_nm: 300, min_area_nm2: 0 })
        .layer_rules(LayerRole::Active, LayerRules { min_width_nm: 80, min_space_nm: 80, min_area_nm2: 20_000 })
        .layer_rules(LayerRole::Poly, LayerRules { min_width_nm: 40, min_space_nm: 60, min_area_nm2: 0 })
        .layer_rules(LayerRole::Contact, LayerRules { min_width_nm: 60, min_space_nm: 40, min_area_nm2: 0 })
        .layer_rules(LayerRole::Metal1, LayerRules { min_width_nm: 60, min_space_nm: 20, min_area_nm2: 6_000 })
        .layer_rules(LayerRole::Via1, LayerRules { min_width_nm: 60, min_space_nm: 20, min_area_nm2: 0 })
        .layer_rules(LayerRole::Metal2, LayerRules { min_width_nm: 60, min_space_nm: 20, min_area_nm2: 6_000 })
        .layer_rules(LayerRole::Via2, LayerRules { min_width_nm: 30, min_space_nm: 40, min_area_nm2: 0 })
        .layer_rules(LayerRole::Metal3, LayerRules { min_width_nm: 60, min_space_nm: 40, min_area_nm2: 0 })
        // OS device layers live at tight metal pitch: FEOL-class
        // width/space/enclosure/extension rules only (Fig. 3 caption)
        .layer_rules(LayerRole::OsChannel, LayerRules { min_width_nm: 50, min_space_nm: 30, min_area_nm2: 0 })
        .layer_rules(LayerRole::OsGate, LayerRules { min_width_nm: 40, min_space_nm: 30, min_area_nm2: 0 })
        // ---- enclosure / extension rules --------------------------------
        .enclosure(LayerRole::Active, LayerRole::Contact, 20)
        .enclosure(LayerRole::Metal1, LayerRole::Contact, 10)
        .enclosure(LayerRole::Metal1, LayerRole::Via1, 10)
        .enclosure(LayerRole::Metal2, LayerRole::Via1, 10)
        .enclosure(LayerRole::Metal2, LayerRole::Via2, 10)
        .enclosure(LayerRole::Metal3, LayerRole::Via2, 10)
        .enclosure(LayerRole::Nwell, LayerRole::Pimplant, 0)
        // gate extension: osgate must extend past oschannel (long axis)
        .extension(LayerRole::OsGate, LayerRole::OsChannel, 25, crate::tech::rules::EncAxis::Y)
        // ---- cross-layer spacings ----------------------------------------
        .spacing(LayerRole::Poly, LayerRole::Contact, 40)
        .spacing(LayerRole::Nwell, LayerRole::Active, 80)
        // ---- wire parasitics --------------------------------------------
        .wire(LayerRole::Metal1, WireRc { r_sq: 0.25, c_area: 2.0e-26, c_fringe: 4.0e-20 })
        .wire(LayerRole::Metal2, WireRc { r_sq: 0.20, c_area: 1.8e-26, c_fringe: 3.6e-20 })
        .wire(LayerRole::Metal3, WireRc { r_sq: 0.12, c_area: 1.5e-26, c_fringe: 3.2e-20 })
        .wire(LayerRole::Poly, WireRc { r_sq: 8.0, c_area: 6.0e-26, c_fringe: 5.0e-20 })
        // ---- device cards (mirror python/compile/device.py) -------------
        .card("si_nmos", cards::SI_NMOS)
        .card("si_pmos", cards::SI_PMOS)
        .card("si_pmos_hvt", cards::SI_PMOS_HVT)
        .card("si_nmos_hvt", cards::SI_NMOS_HVT)
        .card("si_nmos_lvt", cards::SI_NMOS_LVT)
        .card("os_nmos", cards::OS_NMOS)
        .card("os_nmos_hvt", cards::OS_NMOS_HVT)
        // gate cap ~1 fF/um^2 * (40nm * W) with W/L units folded in
        .caps(0.065e-15, 0.04e-15)
        // ---- PVT corners -------------------------------------------------
        .corner(Corner::typical(1.1))
        .corner(Corner { name: "ff", kp_scale: 1.15, vt_shift: -0.04, vdd: 1.21, temp_c: -40.0 })
        .corner(Corner { name: "ss", kp_scale: 0.87, vt_shift: 0.04, vdd: 0.99, temp_c: 125.0 })
        // ---- per-instance mismatch (Monte-Carlo defaults) ----------------
        // Si: Pelgrom-style AVT/sqrt(WL) at minimum size; OS thin-film
        // devices run ~2x wider VT spread and rougher geometry control.
        .variation("si", VariationDefaults { sigma_vt: 0.018, sigma_geom: 0.02, sigma_vdd: 0.01 })
        .variation("os", VariationDefaults { sigma_vt: 0.040, sigma_geom: 0.04, sigma_vdd: 0.01 })
        .build()
        .expect("sg40 tech must validate")
}
