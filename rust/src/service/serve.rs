//! `opengcram serve` — the long-running socket front end over one
//! shared [`Session`].
//!
//! Protocol: JSON-lines over a Unix domain socket.  One request per
//! line, one response line per request, connections stay open for any
//! number of requests.  Responses always carry `"ok": true|false`; an
//! unparseable or unknown request gets an `"ok": false` response (with
//! the parse context from [`crate::util::json::JsonError`]) and the
//! connection survives.
//!
//! Requests:
//!
//! ```text
//! {"cmd":"char","config":{"word":32,"words":64,"flavor":"gc-np"},"gather":3}
//! {"cmd":"dse","configs":[{...},{...}],"gather":2}
//! {"cmd":"compose","machine":"h100","weights":[1,0.5,0.5]}
//! {"cmd":"drc","config":{...}}
//! {"cmd":"stats"}
//! {"cmd":"shutdown"}
//! ```
//!
//! **Cross-request batching.**  `char`/`dse` requests do not run the
//! pipeline themselves: they enqueue an evaluation job to the single
//! dispatcher thread, which gathers concurrently arriving jobs (queue
//! drain + a bounded gather window) and runs their **union** through
//! one [`Session::evaluate`] call — so N concurrent single-design
//! clients pay the grouped-ceiling execution census of one N-design
//! sweep, not N separate sweeps.  The optional `"gather": N` hint
//! holds the batch open (up to the window) until N party members have
//! arrived, which makes co-batching deterministic for tests and
//! scripted fleets; without hints, co-batching still happens whenever
//! requests queue while an evaluation is in flight.  Every response
//! reports `"party"` (how many requests shared the batch),
//! `"sweep_calls"` (the real per-artifact execution-counter delta of
//! that batch) and `"struct_compiles"` (structure compiles the batch
//! paid — `0` once the session's compile cache holds the geometry,
//! including for VT-only-differing repeats) so the KPIs are
//! assertable from the protocol alone.
//!
//! `compose`/`drc`/`stats` run directly on the connection thread
//! against the same session (the compose mega-sweep shares the same
//! cache tiers; `drc` reuses warm per-design flatten memos).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::Session;
use crate::cli;
use crate::compiler::Config;
use crate::compose;
use crate::dse::Evaluated;
use crate::runtime::RunHealth;
use crate::util::json::{Json, ObjBuilder};

/// Default socket path of `opengcram serve` / `opengcram client`.
pub const DEFAULT_SOCKET: &str = "/tmp/opengcram.sock";

/// Default gather window (ms): long enough for a scripted burst of
/// clients to co-batch, short enough to be invisible interactively.
pub const DEFAULT_GATHER_MS: u64 = 25;

/// Server options.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    pub socket: PathBuf,
    /// Upper bound on how long the dispatcher holds a batch open
    /// waiting for its `"gather"` party to fill.
    pub gather_ms: u64,
}

/// One evaluation request in the dispatcher queue.
struct EvalJob {
    configs: Vec<Config>,
    /// Party-size hint: hold the batch open (up to the gather window)
    /// until this many jobs have joined.
    gather: usize,
    reply: mpsc::Sender<Result<EvalShare, String>>,
}

/// One job's share of a dispatched batch.
struct EvalShare {
    /// This job's evaluations, in its own request order.
    evals: Vec<Evaluated>,
    /// Health of the whole batch (shared by every party member).
    health: RunHealth,
    /// Per-artifact execution-counter delta of the whole batch.
    calls: BTreeMap<String, u64>,
    /// Structure compiles the whole batch paid (compile-cache counter
    /// delta) — the cross-request geometry-sharing KPI.
    struct_compiles: usize,
    /// How many requests shared the batch.
    party: usize,
}

/// Run the server until a `shutdown` request.  The session is
/// borrowed — the caller owns it and keeps its caches after the
/// server exits (tests restart the listener over one warm session).
pub fn serve(session: &Session, opts: &ServeOpts) -> crate::Result<()> {
    let _ = std::fs::remove_file(&opts.socket);
    let listener = UnixListener::bind(&opts.socket)
        .map_err(|e| anyhow::anyhow!("serve: cannot bind {}: {e}", opts.socket.display()))?;
    println!("listening on {} ({} backend)", opts.socket.display(), session.backend_name());
    let stop = AtomicBool::new(false);
    let gather = Duration::from_millis(opts.gather_ms);
    let (job_tx, job_rx) = mpsc::channel::<EvalJob>();
    std::thread::scope(|s| {
        s.spawn(|| dispatcher(session, job_rx, gather));
        for conn in listener.incoming() {
            if stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let tx = job_tx.clone();
                    let stop = &stop;
                    let socket = opts.socket.as_path();
                    s.spawn(move || client_loop(session, stream, tx, stop, socket));
                }
                Err(e) => eprintln!("serve: accept error: {e}"),
            }
        }
        // the accept loop's sender dies here; the dispatcher exits
        // once every client thread has dropped its clone
        drop(job_tx);
    });
    let _ = std::fs::remove_file(&opts.socket);
    Ok(())
}

/// Gather concurrently arriving evaluation jobs and run their union
/// through one [`Session::evaluate`] — the cross-request batching
/// core.  Single jobs with no party hint and an idle queue run
/// immediately (no added latency).
fn dispatcher(session: &Session, rx: mpsc::Receiver<EvalJob>, gather: Duration) {
    while let Ok(first) = rx.recv() {
        let mut jobs = vec![first];
        // opportunistic drain: anything already queued joins for free
        while let Ok(j) = rx.try_recv() {
            jobs.push(j);
        }
        // party hints hold the batch open, bounded by the window
        let deadline = Instant::now() + gather;
        loop {
            let target = jobs.iter().map(|j| j.gather.max(1)).max().unwrap_or(1);
            if jobs.len() >= target {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => jobs.push(j),
                Err(_) => break,
            }
        }
        let union: Vec<Config> =
            jobs.iter().flat_map(|j| j.configs.iter().cloned()).collect();
        let party = jobs.len();
        let before = session.runtime().call_counts();
        let (_, compiles_before) = session.struct_stats();
        match session.evaluate(&union) {
            Ok((evals, health)) => {
                let after = session.runtime().call_counts();
                let calls = counter_delta(&before, &after);
                let (_, compiles_after) = session.struct_stats();
                let struct_compiles = compiles_after - compiles_before;
                let mut evals = evals.into_iter();
                for job in jobs {
                    let share = EvalShare {
                        evals: evals.by_ref().take(job.configs.len()).collect(),
                        health: health.clone(),
                        calls: calls.clone(),
                        struct_compiles,
                        party,
                    };
                    let _ = job.reply.send(Ok(share));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for job in jobs {
                    let _ = job.reply.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// `after - before`, per artifact (names absent from `before` count
/// from zero; unchanged counters are omitted).
fn counter_delta(
    before: &BTreeMap<String, u64>,
    after: &BTreeMap<String, u64>,
) -> BTreeMap<String, u64> {
    after
        .iter()
        .filter_map(|(name, &n)| {
            let d = n - before.get(name).copied().unwrap_or(0);
            (d > 0).then(|| (name.clone(), d))
        })
        .collect()
}

fn client_loop(
    session: &Session,
    stream: UnixStream,
    jobs: mpsc::Sender<EvalJob>,
    stop: &AtomicBool,
    socket: &Path,
) {
    let reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut writer = stream;
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let (response, shutdown) = handle_line(session, &jobs, &line);
        let mut out = response.dump();
        out.push('\n');
        if writer.write_all(out.as_bytes()).and_then(|()| writer.flush()).is_err() {
            break;
        }
        if shutdown {
            stop.store(true, Ordering::SeqCst);
            // unblock the accept loop so it observes the stop flag
            let _ = UnixStream::connect(socket);
            break;
        }
    }
}

/// Dispatch one request line.  Returns the response and whether this
/// request shuts the server down.
fn handle_line(session: &Session, jobs: &mpsc::Sender<EvalJob>, line: &str) -> (Json, bool) {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return (err_response(&format!("bad request: {e}")), false),
    };
    let cmd = match req.get("cmd").and_then(Json::as_str) {
        Some(c) => c.to_string(),
        None => return (err_response("missing \"cmd\""), false),
    };
    if cmd == "shutdown" {
        let resp = ObjBuilder::new()
            .put("ok", Json::Bool(true))
            .put("cmd", Json::Str("shutdown".into()))
            .build();
        return (resp, true);
    }
    let res = match cmd.as_str() {
        "char" => handle_char(jobs, &req),
        "dse" => handle_dse(jobs, &req),
        "compose" => handle_compose(session, &req),
        "drc" => handle_drc(session, &req),
        "stats" => Ok(stats_json(session)),
        other => Err(anyhow::anyhow!(
            "unknown cmd '{other}' (expected char|dse|compose|drc|stats|shutdown)"
        )),
    };
    match res {
        Ok(j) => (j, false),
        Err(e) => (err_response(&format!("{e:#}")), false),
    }
}

fn err_response(msg: &str) -> Json {
    ObjBuilder::new()
        .put("ok", Json::Bool(false))
        .put("error", Json::Str(msg.to_string()))
        .build()
}

/// Enqueue one evaluation job and wait for the dispatcher's answer.
fn submit(
    jobs: &mpsc::Sender<EvalJob>,
    configs: Vec<Config>,
    gather: usize,
) -> crate::Result<EvalShare> {
    let (tx, rx) = mpsc::channel();
    jobs.send(EvalJob { configs, gather, reply: tx })
        .map_err(|_| anyhow::anyhow!("dispatcher is gone"))?;
    match rx.recv() {
        Ok(Ok(share)) => Ok(share),
        Ok(Err(msg)) => Err(anyhow::anyhow!(msg)),
        Err(_) => Err(anyhow::anyhow!("dispatcher dropped the reply")),
    }
}

fn gather_hint(req: &Json) -> usize {
    req.get("gather").and_then(Json::as_usize).unwrap_or(1)
}

fn handle_char(jobs: &mpsc::Sender<EvalJob>, req: &Json) -> crate::Result<Json> {
    let cfg = config_from_json(
        req.get("config").ok_or_else(|| anyhow::anyhow!("char: missing \"config\""))?,
    )?;
    let share = submit(jobs, vec![cfg], gather_hint(req))?;
    let e = &share.evals[0];
    Ok(ObjBuilder::new()
        .put("ok", Json::Bool(true))
        .put("eval", eval_json(e))
        .put("party", Json::Num(share.party as f64))
        .put("sweep_calls", calls_json(&share.calls))
        .put("struct_compiles", Json::Num(share.struct_compiles as f64))
        .put("health", health_json(&share.health))
        .build())
}

fn handle_dse(jobs: &mpsc::Sender<EvalJob>, req: &Json) -> crate::Result<Json> {
    let arr = req
        .get("configs")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("dse: missing \"configs\" array"))?;
    anyhow::ensure!(!arr.is_empty(), "dse: \"configs\" is empty");
    let configs = arr.iter().map(config_from_json).collect::<crate::Result<Vec<_>>>()?;
    let share = submit(jobs, configs, gather_hint(req))?;
    Ok(ObjBuilder::new()
        .put("ok", Json::Bool(true))
        .put("evals", Json::Arr(share.evals.iter().map(eval_json).collect()))
        .put("party", Json::Num(share.party as f64))
        .put("sweep_calls", calls_json(&share.calls))
        .put("struct_compiles", Json::Num(share.struct_compiles as f64))
        .put("health", health_json(&share.health))
        .build())
}

fn handle_compose(session: &Session, req: &Json) -> crate::Result<Json> {
    let machine =
        cli::machine_by_name(req.get("machine").and_then(Json::as_str).unwrap_or("h100"))?;
    let mut spec = compose::ComposeSpec::new(machine);
    spec.window_resolution = session.window_resolution();
    if let Some(w) = req.get("weights").and_then(Json::as_arr) {
        anyhow::ensure!(w.len() == 3, "compose: \"weights\" needs [delay, area, power]");
        let vals = w
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| anyhow::anyhow!("compose: non-numeric weight")))
            .collect::<crate::Result<Vec<f64>>>()?;
        spec.w_delay = vals[0];
        spec.w_area = vals[1];
        spec.w_power = vals[2];
    }
    let c = session.compose(&spec)?;
    let levels: Vec<Json> = c
        .per_level
        .iter()
        .map(|s| {
            let choice = match &s.choice {
                None => Json::Null,
                Some(ch) => ObjBuilder::new()
                    .put("config", config_json(&ch.eval.config))
                    .put("area_um2", Json::Num(ch.eval.area_um2))
                    .put("leakage_w", Json::Num(ch.eval.perf.leakage_w))
                    .put("f_op_hz", Json::Num(ch.eval.perf.f_op_hz))
                    .put("cost", Json::Num(ch.cost))
                    .put("freq_margin", Json::Num(ch.freq_margin))
                    .put("retention_margin", Json::Num(ch.retention_margin))
                    .build(),
            };
            ObjBuilder::new()
                .put("level", Json::Str(format!("{:?}", s.demand.level)))
                .put("feasible", Json::Num(s.feasible as f64))
                .put("front", Json::Num(s.front as f64))
                .put("choice", choice)
                .build()
        })
        .collect();
    Ok(ObjBuilder::new()
        .put("ok", Json::Bool(true))
        .put("machine", Json::Str(c.machine.to_string()))
        .put("distinct", Json::Num(c.distinct as f64))
        .put("cache_hits", Json::Num(c.cache_hits as f64))
        .put("cache_misses", Json::Num(c.cache_misses as f64))
        .put("levels", Json::Arr(levels))
        .put("health", health_json(&c.health))
        .build())
}

fn handle_drc(session: &Session, req: &Json) -> crate::Result<Json> {
    let cfg = config_from_json(
        req.get("config").ok_or_else(|| anyhow::anyhow!("drc: missing \"config\""))?,
    )?;
    let report = session.drc_check(&cfg)?;
    Ok(ObjBuilder::new()
        .put("ok", Json::Bool(true))
        .put("clean", Json::Bool(report.clean()))
        .put("violations", Json::Num(report.violations.len() as f64))
        .put("rects_checked", Json::Num(report.rects_checked as f64))
        .build())
}

fn stats_json(session: &Session) -> Json {
    let s = session.stats();
    let store = match s.store {
        None => Json::Null,
        Some(st) => ObjBuilder::new()
            .put("hits", Json::Num(st.hits as f64))
            .put("misses", Json::Num(st.misses as f64))
            .put("rejects", Json::Num(st.rejects as f64))
            .put("write_errors", Json::Num(st.write_errors as f64))
            .build(),
    };
    ObjBuilder::new()
        .put("ok", Json::Bool(true))
        .put("backend", Json::Str(s.backend.to_string()))
        .put("window_res", Json::Num(session.window_resolution()))
        .put("cache_entries", Json::Num(s.cache_entries as f64))
        .put("cache_hits", Json::Num(s.cache_hits as f64))
        .put("cache_misses", Json::Num(s.cache_misses as f64))
        .put("store", store)
        .put(
            "compile",
            ObjBuilder::new()
                .put("structures", Json::Num(s.structures as f64))
                .put("hits", Json::Num(s.struct_hits as f64))
                .put("compiles", Json::Num(s.struct_compiles as f64))
                .build(),
        )
        .put("flatten_configs", Json::Num(s.flatten_configs as f64))
        .put("calls", calls_json(&s.call_counts))
        .build()
}

fn calls_json(calls: &BTreeMap<String, u64>) -> Json {
    Json::Obj(calls.iter().map(|(k, &v)| (k.clone(), Json::Num(v as f64))).collect())
}

fn health_json(h: &RunHealth) -> Json {
    let quarantined: Vec<Json> = h
        .quarantined
        .iter()
        .map(|q| {
            ObjBuilder::new()
                .put("index", Json::Num(q.index as f64))
                .put("design", Json::Str(q.design.clone()))
                .put("stage", Json::Str(q.stage.to_string()))
                .put("reason", Json::Str(q.reason.clone()))
                .build()
        })
        .collect();
    ObjBuilder::new()
        .put("retries", Json::Num(h.retries as f64))
        .put("bisect_execs", Json::Num(h.bisect_execs as f64))
        .put("failovers", Json::Num(h.failovers as f64))
        .put("quarantined", Json::Arr(quarantined))
        .put("summary", Json::Str(h.summary()))
        .build()
}

/// Protocol encoding of one design config — round-trips through
/// [`config_from_json`].  Optional knobs serialize as `null` when
/// unset.
pub fn config_json(cfg: &Config) -> Json {
    ObjBuilder::new()
        .put("word", Json::Num(cfg.word_size as f64))
        .put("words", Json::Num(cfg.num_words as f64))
        .put("flavor", Json::Str(cli::flavor_name(cfg.flavor).to_string()))
        .put("wwlls", Json::Bool(cfg.wwlls))
        .put(
            "mux",
            match cfg.mux_factor {
                Some(m) => Json::Num(m as f64),
                None => Json::Null,
            },
        )
        .put(
            "vt",
            match cfg.write_vt {
                Some(v) => Json::Num(v),
                None => Json::Null,
            },
        )
        .build()
}

/// Parse a protocol config object.  `word`/`words` are required;
/// `flavor` defaults to `gc-np` and parses strictly via
/// [`cli::parse_flavor`]; `wwlls`/`mux`/`vt` are optional.
pub fn config_from_json(j: &Json) -> crate::Result<Config> {
    let word = j
        .get("word")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("config: missing or non-integer \"word\""))?;
    let words = j
        .get("words")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("config: missing or non-integer \"words\""))?;
    let flavor = match j.get("flavor") {
        None | Some(Json::Null) => crate::compiler::CellFlavor::GcSiSiNp,
        Some(f) => cli::parse_flavor(
            f.as_str().ok_or_else(|| anyhow::anyhow!("config: \"flavor\" must be a string"))?,
        )?,
    };
    let mut cfg = Config::new(word, words, flavor);
    cfg.wwlls = j.get("wwlls").and_then(Json::as_bool).unwrap_or(false);
    cfg.mux_factor = j.get("mux").and_then(Json::as_usize);
    cfg.write_vt = j.get("vt").and_then(Json::as_f64);
    Ok(cfg)
}

/// Protocol encoding of one evaluation (decimal f64s — Rust's
/// shortest-round-trip `Display`, so finite values parse back
/// bit-identically; NaN fields of quarantined points render as
/// `null`).
pub fn eval_json(e: &Evaluated) -> Json {
    let p = &e.perf;
    let perf = ObjBuilder::new()
        .put("f_read_hz", Json::Num(p.f_read_hz))
        .put("f_write_hz", Json::Num(p.f_write_hz))
        .put("f_op_hz", Json::Num(p.f_op_hz))
        .put("bandwidth_bps", Json::Num(p.bandwidth_bps))
        .put("retention_s", Json::Num(p.retention_s))
        .put("leakage_w", Json::Num(p.leakage_w))
        .put("e_read_j", Json::Num(p.e_read_j))
        .put("t_decoder_s", Json::Num(p.t_decoder_s))
        .put("t_cell_read_s", Json::Num(p.t_cell_read_s))
        .put("stored_one_v", Json::Num(p.stored_one_v))
        .put("functional", Json::Bool(p.functional))
        .build();
    ObjBuilder::new()
        .put("config", config_json(&e.config))
        .put("area_um2", Json::Num(e.area_um2))
        .put("perf", perf)
        .put(
            "quarantine",
            match &e.quarantine {
                Some(r) => Json::Str(r.clone()),
                None => Json::Null,
            },
        )
        .build()
}

/// One-shot scripted client: send one request line, return the
/// response line.  Powers `opengcram client` (the CI smoke scripts).
pub fn client_request(socket: &Path, line: &str) -> crate::Result<String> {
    let mut stream = UnixStream::connect(socket)
        .map_err(|e| anyhow::anyhow!("client: cannot connect to {}: {e}", socket.display()))?;
    stream.write_all(line.as_bytes())?;
    if !line.ends_with('\n') {
        stream.write_all(b"\n")?;
    }
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    let n = reader.read_line(&mut resp)?;
    anyhow::ensure!(n > 0, "client: server closed the connection without a response");
    Ok(resp.trim_end().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::CellFlavor;

    #[test]
    fn config_round_trips_through_protocol_json() {
        let mut cfg = Config::new(16, 512, CellFlavor::GcOsOs);
        cfg.wwlls = true;
        cfg.mux_factor = Some(8);
        cfg.write_vt = Some(0.35);
        let j = config_json(&cfg);
        let back = config_from_json(&j).unwrap();
        assert_eq!(back.key(), cfg.key());
        // defaults: bare object gets gc-np, no knobs
        let bare = Json::parse(r#"{"word":32,"words":32}"#).unwrap();
        let c = config_from_json(&bare).unwrap();
        assert_eq!(c.flavor, CellFlavor::GcSiSiNp);
        assert_eq!(c.key(), Config::new(32, 32, CellFlavor::GcSiSiNp).key());
        // strictness: missing word, bad flavor
        assert!(config_from_json(&Json::parse(r#"{"words":32}"#).unwrap()).is_err());
        assert!(config_from_json(
            &Json::parse(r#"{"word":32,"words":32,"flavor":"gc-pn"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn counter_delta_subtracts_and_drops_unchanged() {
        let before: BTreeMap<String, u64> =
            [("write".into(), 2u64), ("read".into(), 5u64)].into_iter().collect();
        let after: BTreeMap<String, u64> =
            [("write".into(), 2u64), ("read".into(), 7u64), ("retention".into(), 1u64)]
                .into_iter()
                .collect();
        let d = counter_delta(&before, &after);
        assert_eq!(d.get("read"), Some(&2));
        assert_eq!(d.get("retention"), Some(&1));
        assert!(!d.contains_key("write"), "unchanged counters are omitted");
    }
}
