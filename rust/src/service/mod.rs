//! Persistent compiler service — the ownership layer between the CLI
//! (or the [`serve`] socket front end) and the pipeline.
//!
//! Historically every `char`/`dse`/`compose` invocation built its own
//! `SharedRuntime`, `EvalCache` and flatten memo and threw them away
//! at exit.  A [`Session`] lifts that state out of `main.rs`: it owns
//! the runtime, the in-memory evaluation cache (bound once to the
//! session's window resolution), an optional on-disk store tier
//! ([`crate::store::DiskStore`]) and per-design warm
//! [`FlattenCache`]s, and the former subcommand bodies become request
//! handlers that **borrow** the session.  One-shot CLI mode is now
//! literally "open session → one request → drop" — on the no-store
//! path each handler replays the exact call sequence the old
//! subcommand made, so its output is bitwise-identical.
//!
//! The payoff is every later request: a second sweep through the same
//! session hits the memory tier, a second *process* hits the disk
//! tier (zero characterization executions for cached points — the
//! warm-restart KPI), and concurrent requests funneled through one
//! session by [`serve`] pack their transient points into shared
//! batches at the grouped ceiling.
//!
//! Tier order on lookup: memory (counts a hit) → disk (validated,
//! promoted via [`EvalCache::adopt`] — *not* counted as a hit or
//! miss, so `EvalCache::stats()` still means "requests served warm /
//! pipeline evaluations paid this process") → pipeline (compile +
//! batched characterize, counted as a miss, written back to both
//! tiers).

pub mod serve;

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::Mutex;

use crate::characterize::{self, BankPerf};
use crate::compiler::{Bank, CompileCache, Config, ConfigKey, StructKey};
use crate::compose::{self, Composition};
use crate::dse::{EvalCache, Evaluated};
use crate::layout::FlattenCache;
use crate::runtime::{RunHealth, SharedRuntime};
use crate::store::{DiskStore, StoreKey, StoreStats};
use crate::tech::Tech;
use crate::variation::{self, DesignYield, VariationModel};

/// Long-lived compiler state: one runtime, one coordinator path, one
/// cache hierarchy.  All request methods take `&self` — the session
/// is shared across server threads by reference
/// (`std::thread::scope`), with interior mutability confined to the
/// caches.
pub struct Session<'t> {
    tech: &'t Tech,
    rt: SharedRuntime,
    cache: EvalCache,
    /// Session-lifetime structure cache: compiled geometry shared
    /// across the electrical axis and across requests, so a repeated
    /// (or VT-only-differing) sweep pays zero structure compiles.
    structs: CompileCache,
    store: Option<DiskStore>,
    /// Warm flatten memos, one per *structure*: [`FlattenCache`] keys
    /// on cell names, and same-named cells (bitcell, drivers, bank)
    /// have different geometry under different structures — sharing
    /// one memo across structures would alias rect lists.  Keying on
    /// [`StructKey`] (not [`ConfigKey`]) makes repeat DRC warm across
    /// VT-only-differing requests while keeping cross-geometry
    /// aliasing impossible.
    flatten: Mutex<HashMap<StructKey, FlattenCache>>,
    window_resolution: f64,
    workers: usize,
}

/// Telemetry snapshot for one [`Session`] lifetime — what the `stats`
/// protocol command reports.
#[derive(Debug, Clone)]
pub struct SessionStats {
    /// Distinct evaluations in the memory tier.
    pub cache_entries: usize,
    /// Requests served from the memory tier.
    pub cache_hits: usize,
    /// Pipeline evaluations paid by this process.
    pub cache_misses: usize,
    /// Disk-tier counters (`None` when the session has no store).
    pub store: Option<StoreStats>,
    /// Distinct compiled structures held by the compile cache.
    pub structures: usize,
    /// Banks served from an already-compiled structure.
    pub struct_hits: usize,
    /// Structure compiles paid by this process.
    pub struct_compiles: usize,
    /// Structures with a warm flatten memo.
    pub flatten_configs: usize,
    /// Cumulative per-artifact execution counters from the runtime —
    /// the ground truth the grouped-ceiling KPIs are asserted on.
    pub call_counts: BTreeMap<String, u64>,
    pub backend: &'static str,
}

impl<'t> Session<'t> {
    /// Open a session.  `window_resolution` is fixed for the session
    /// lifetime and binds the cache immediately — a session can never
    /// alias evaluations across resolutions
    /// ([`EvalCache::bind_resolution`]).
    pub fn new(
        tech: &'t Tech,
        rt: SharedRuntime,
        window_resolution: f64,
    ) -> crate::Result<Session<'t>> {
        let cache = EvalCache::new();
        cache.bind_resolution(window_resolution)?;
        Ok(Session {
            tech,
            rt,
            cache,
            structs: CompileCache::new(),
            store: None,
            flatten: Mutex::new(HashMap::new()),
            window_resolution,
            workers: crate::util::default_workers(),
        })
    }

    /// Attach the on-disk store tier rooted at `dir` (created if
    /// missing).  Entries are keyed by config + tech + resolution +
    /// format version, so many sessions — concurrent or across
    /// process lifetimes — can share one directory safely.
    pub fn with_store(mut self, dir: impl AsRef<std::path::Path>) -> crate::Result<Session<'t>> {
        self.store = Some(DiskStore::open(dir)?);
        Ok(self)
    }

    /// Parallel-compile fan-out for sweep misses (defaults to
    /// [`crate::util::default_workers`]).
    pub fn with_workers(mut self, workers: usize) -> Session<'t> {
        self.workers = workers.max(1);
        self
    }

    pub fn tech(&self) -> &'t Tech {
        self.tech
    }

    pub fn runtime(&self) -> &SharedRuntime {
        &self.rt
    }

    pub fn backend_name(&self) -> &'static str {
        self.rt.backend_name()
    }

    pub fn window_resolution(&self) -> f64 {
        self.window_resolution
    }

    fn store_key(&self, key: &ConfigKey) -> StoreKey {
        StoreKey::new(key.clone(), self.tech.name, self.window_resolution)
    }

    /// The batched sweep — the session-owned replacement for
    /// [`dse::evaluate_all_batched_cached_health`](crate::dse::evaluate_all_batched_cached_health),
    /// with the disk tier spliced between the memory tier and the
    /// pipeline.  Behavior is pinned to the original: same dedup,
    /// same miss order, same compile/characterize call sequence —
    /// with no store attached the results are **bitwise-identical**
    /// (`tests/serve.rs` asserts this), which is what keeps one-shot
    /// CLI output stable across the refactor.
    ///
    /// The health report covers only the pipeline misses this call
    /// paid; a sweep served from either cache tier reports clean.
    pub fn evaluate(&self, configs: &[Config]) -> crate::Result<(Vec<Evaluated>, RunHealth)> {
        self.cache.bind_resolution(self.window_resolution)?;
        // distinct configs not yet in any tier, in first-appearance
        // order.  Allocation-light like the dse sweep: keys move into
        // `seen`, misses are borrowed.
        let mut seen: HashSet<ConfigKey> = HashSet::new();
        let mut miss_cfgs: Vec<&Config> = Vec::new();
        for cfg in configs {
            let key = cfg.key();
            if seen.contains(&key) {
                continue;
            }
            let warm = self.cache.peek(&key).is_some()
                || self.store.as_ref().is_some_and(|store| {
                    store.load(&self.store_key(&key)).map(|e| self.cache.adopt(e)).is_some()
                });
            seen.insert(key);
            if !warm {
                miss_cfgs.push(cfg);
            }
        }
        let banks: Vec<Bank> = self.structs.compile_all(self.tech, &miss_cfgs, self.workers)?;
        let (perfs, health) =
            characterize::characterize_all_health(self.tech, &self.rt, &banks, self.window_resolution)?;
        for (bank, perf) in banks.iter().zip(perfs) {
            let (perf, quarantine) = match perf {
                Ok(p) => (p, None),
                Err(q) => (
                    BankPerf::quarantined(),
                    Some(format!("{} stage: {}", q.stage, q.reason)),
                ),
            };
            let e = Evaluated {
                config: bank.config.clone(),
                perf,
                area_um2: bank.layout.total_area_um2(),
                quarantine,
            };
            if let Some(store) = &self.store {
                store.save(&self.store_key(&e.config.key()), &e);
            }
            self.cache.insert(e);
        }
        let evals = configs
            .iter()
            .map(|cfg| {
                self.cache.resolve(&cfg.key()).ok_or_else(|| {
                    anyhow::anyhow!("config missing from cache after batch evaluation")
                })
            })
            .collect::<crate::Result<Vec<Evaluated>>>()?;
        Ok((evals, health))
    }

    /// Single-design characterization — the `char` subcommand body.
    /// Rides [`Self::evaluate`] (so concurrent `char` requests
    /// co-batch and cached points are free); a quarantined design is
    /// a hard error naming the reason, matching the strict semantics
    /// of the old per-design path.  Use a `0.0`-resolution session
    /// for bitwise parity with direct
    /// [`characterize::characterize`].
    pub fn characterize_config(&self, cfg: &Config) -> crate::Result<Evaluated> {
        let (evals, _health) = self.evaluate(std::slice::from_ref(cfg))?;
        let e = evals.into_iter().next().expect("one config in, one eval out");
        match &e.quarantine {
            Some(reason) => anyhow::bail!("design quarantined: {reason}"),
            None => Ok(e),
        }
    }

    /// The `dse` nominal sweep body: evaluate and keep the session
    /// caches warm for the next request.
    pub fn sweep(&self, configs: &[Config]) -> crate::Result<(Vec<Evaluated>, RunHealth)> {
        self.evaluate(configs)
    }

    /// The `compose` body.  `spec.window_resolution` must equal the
    /// session's (the sweep cache is bound to it).  With a store
    /// attached, the design grid is pre-warmed through
    /// [`Self::evaluate`] first so new evaluations persist to disk
    /// and a restarted service re-composes without re-characterizing;
    /// the pre-warm's health is merged into the composition's.
    /// Monte-Carlo compositions bypass both cache tiers (sampled
    /// variants share their design's [`ConfigKey`]).
    pub fn compose(&self, spec: &compose::ComposeSpec) -> crate::Result<Composition> {
        anyhow::ensure!(
            spec.window_resolution.to_bits() == self.window_resolution.to_bits(),
            "compose spec resolution {} != session resolution {}",
            spec.window_resolution,
            self.window_resolution
        );
        let mut pre_health = RunHealth::default();
        if self.store.is_some() && spec.mc.is_none() {
            let (_evals, h) = self.evaluate(&compose::design_grid())?;
            pre_health = h;
        }
        let mut c = compose::compose_cached(self.tech, &self.rt, spec, &self.cache, &self.structs)?;
        pre_health.merge(std::mem::take(&mut c.health));
        c.health = pre_health;
        Ok(c)
    }

    /// The `dse --mc` body: Monte-Carlo yield sweep.  Sampled
    /// variants share their design's [`ConfigKey`], so neither cache
    /// tier can hold them — the sweep always runs the pipeline (all
    /// `D·(K+1)` variants in one mega-batch at the grouped ceiling).
    pub fn yield_sweep(
        &self,
        configs: &[Config],
        model: &VariationModel,
    ) -> crate::Result<(Vec<DesignYield>, RunHealth)> {
        variation::yield_sweep_health(
            self.tech,
            &self.rt,
            configs,
            model,
            self.workers,
            self.window_resolution,
            &self.structs,
        )
    }

    /// Hierarchical DRC of one design through its warm per-structure
    /// flatten memo: the first check of a structure flattens its
    /// unique cells once; repeat checks — including VT-only-differing
    /// configs, which share the structure — reuse the memo.
    pub fn drc_check(&self, cfg: &Config) -> crate::Result<crate::drc::Report> {
        let bank = self.structs.compile(self.tech, cfg)?;
        let mut memos = self.flatten.lock().unwrap_or_else(|p| p.into_inner());
        let memo = memos.entry(bank.structure.key.clone()).or_default();
        crate::drc::hier::check_hier_cached(self.tech, &bank.library, "bank", memo)
    }

    /// `(hits, compiles)` counters of the session's structure cache —
    /// cheap enough for the serve dispatcher to sample per batch.
    pub fn struct_stats(&self) -> (usize, usize) {
        self.structs.stats()
    }

    pub fn stats(&self) -> SessionStats {
        let (cache_hits, cache_misses) = self.cache.stats();
        let (struct_hits, struct_compiles) = self.structs.stats();
        SessionStats {
            cache_entries: self.cache.len(),
            cache_hits,
            cache_misses,
            store: self.store.as_ref().map(|s| s.stats()),
            structures: self.structs.len(),
            struct_hits,
            struct_compiles,
            flatten_configs: self.flatten.lock().unwrap_or_else(|p| p.into_inner()).len(),
            call_counts: self.rt.call_counts(),
            backend: self.rt.backend_name(),
        }
    }
}
