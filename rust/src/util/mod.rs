//! Small utilities that would normally come from crates.io but must be
//! local because the offline registry only carries the `xla` closure:
//! a JSON parser ([`json`]), a splitmix/xoshiro PRNG ([`rng`]) used by
//! the property tests and workload jitter, and a timing harness
//! ([`bench`]) used by the `harness = false` benches.

pub mod bench;
pub mod json;
pub mod rng;

/// Round up to the next power of two (min 1).
pub fn next_pow2(x: usize) -> usize {
    x.max(1).next_power_of_two()
}

/// Integer ceil division.
pub fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// ceil(log2(x)) for x >= 1.
pub fn ceil_log2(x: usize) -> u32 {
    usize::BITS - x.max(1).saturating_sub(1).leading_zeros()
}

/// Pretty engineering-notation formatter (1.23 µ, 4.5 n, ...).
pub fn eng(v: f64, unit: &str) -> String {
    if v == 0.0 || !v.is_finite() {
        return format!("{v} {unit}");
    }
    let mag = v.abs();
    let (scale, prefix) = if mag >= 1e9 {
        (1e-9, "G")
    } else if mag >= 1e6 {
        (1e-6, "M")
    } else if mag >= 1e3 {
        (1e-3, "k")
    } else if mag >= 1.0 {
        (1.0, "")
    } else if mag >= 1e-3 {
        (1e3, "m")
    } else if mag >= 1e-6 {
        (1e6, "u")
    } else if mag >= 1e-9 {
        (1e9, "n")
    } else if mag >= 1e-12 {
        (1e12, "p")
    } else if mag >= 1e-15 {
        (1e15, "f")
    } else {
        (1e18, "a")
    };
    format!("{:.3} {}{}", v * scale, prefix, unit)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_and_logs() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(64), 64);
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(1024), 10);
        assert_eq!(ceil_div(7, 3), 3);
        assert_eq!(ceil_div(6, 3), 2);
    }

    #[test]
    fn eng_format() {
        assert_eq!(eng(1.5e-9, "s"), "1.500 ns");
        assert_eq!(eng(2.0e9, "Hz"), "2.000 GHz");
        assert_eq!(eng(3.2e-15, "F"), "3.200 fF");
    }
}
