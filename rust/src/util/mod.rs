//! Small utilities that would normally come from crates.io but must be
//! local because the offline registry only carries the `xla` closure:
//! a JSON parser ([`json`]), a splitmix/xoshiro PRNG ([`rng`]) used by
//! the property tests and workload jitter, and a timing harness
//! ([`bench`]) used by the `harness = false` benches.

pub mod bench;
pub mod json;
pub mod rng;

/// Default fan-out width for the parallel helpers: one worker per
/// available core.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Scoped work-stealing parallel map; results keep input order.  The
/// fan-out primitive under the DSE sweeps ([`crate::dse::evaluate_all`]
/// and the parallel compile stage of the batched sweeps), the
/// composition engine's plan compiler ([`crate::compose`]), and the
/// native backend's row-chunked batch execution
/// ([`crate::runtime::native`]).
pub fn par_map<T: Sync, R: Send>(
    items: &[T],
    workers: usize,
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *slots[i].lock().unwrap_or_else(|p| p.into_inner()) = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap_or_else(|p| p.into_inner())
                .expect("worker filled every slot")
        })
        .collect()
}

/// Round up to the next power of two (min 1).
pub fn next_pow2(x: usize) -> usize {
    x.max(1).next_power_of_two()
}

/// Integer ceil division.
pub fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// ceil(log2(x)) for x >= 1.
pub fn ceil_log2(x: usize) -> u32 {
    usize::BITS - x.max(1).saturating_sub(1).leading_zeros()
}

/// Pretty engineering-notation formatter (1.23 µ, 4.5 n, ...).
pub fn eng(v: f64, unit: &str) -> String {
    if v == 0.0 || !v.is_finite() {
        return format!("{v} {unit}");
    }
    let mag = v.abs();
    let (scale, prefix) = if mag >= 1e9 {
        (1e-9, "G")
    } else if mag >= 1e6 {
        (1e-6, "M")
    } else if mag >= 1e3 {
        (1e-3, "k")
    } else if mag >= 1.0 {
        (1.0, "")
    } else if mag >= 1e-3 {
        (1e3, "m")
    } else if mag >= 1e-6 {
        (1e6, "u")
    } else if mag >= 1e-9 {
        (1e9, "n")
    } else if mag >= 1e-12 {
        (1e12, "p")
    } else if mag >= 1e-15 {
        (1e15, "f")
    } else {
        (1e18, "a")
    };
    format!("{:.3} {}{}", v * scale, prefix, unit)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_and_logs() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(64), 64);
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(1024), 10);
        assert_eq!(ceil_div(7, 3), 3);
        assert_eq!(ceil_div(6, 3), 2);
    }

    #[test]
    fn eng_format() {
        assert_eq!(eng(1.5e-9, "s"), "1.500 ns");
        assert_eq!(eng(2.0e9, "Hz"), "2.000 GHz");
        assert_eq!(eng(3.2e-15, "F"), "3.200 fF");
    }
}
