//! Timing harness for the `harness = false` benches (criterion is not in
//! the offline registry).  Median-of-runs with warmup, plus a tiny
//! table printer shared by the figure benches.

use std::time::Instant;

/// Measurement for one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub iters: usize,
}

impl Sample {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.median_s
    }
}

/// Run `f` repeatedly; target roughly `target_s` seconds of total
/// measurement after warmup.  Returns median/min/max of per-iteration
/// wall time.  `f` should return something observable to keep the
/// optimizer honest (we black-box it via `std::hint::black_box`).
pub fn time<T, F: FnMut() -> T>(name: &str, target_s: f64, mut f: F) -> Sample {
    // warmup + calibration
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_s / one).ceil() as usize).clamp(3, 10_000);

    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Sample {
        name: name.to_string(),
        median_s: times[times.len() / 2],
        min_s: times[0],
        max_s: *times.last().unwrap(),
        iters,
    }
}

/// Print one sample in a stable, grep-able format.
pub fn report(s: &Sample) {
    println!(
        "bench {:<44} median {:>12}  min {:>12}  iters {}",
        s.name,
        crate::util::eng(s.median_s, "s"),
        crate::util::eng(s.min_s, "s"),
        s.iters
    );
}

/// Convenience: time + report.
pub fn run<T, F: FnMut() -> T>(name: &str, target_s: f64, f: F) -> Sample {
    let s = time(name, target_s, f);
    report(&s);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_positive_and_ordered() {
        let s = time("noop", 0.01, || 1 + 1);
        assert!(s.min_s <= s.median_s && s.median_s <= s.max_s);
        assert!(s.iters >= 3);
    }
}
