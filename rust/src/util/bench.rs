//! Timing harness for the `harness = false` benches (criterion is not in
//! the offline registry).  Median-of-runs with warmup, plus a tiny
//! table printer shared by the figure benches.

use std::time::Instant;

/// Measurement for one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub iters: usize,
}

impl Sample {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.median_s
    }
}

/// Run `f` repeatedly; target roughly `target_s` seconds of total
/// measurement after warmup.  Returns median/min/max of per-iteration
/// wall time.  `f` should return something observable to keep the
/// optimizer honest (we black-box it via `std::hint::black_box`).
pub fn time<T, F: FnMut() -> T>(name: &str, target_s: f64, mut f: F) -> Sample {
    // warmup + calibration
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_s / one).ceil() as usize).clamp(3, 10_000);

    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Sample {
        name: name.to_string(),
        median_s: times[times.len() / 2],
        min_s: times[0],
        max_s: *times.last().unwrap(),
        iters,
    }
}

/// Print one sample in a stable, grep-able format.
pub fn report(s: &Sample) {
    println!(
        "bench {:<44} median {:>12}  min {:>12}  iters {}",
        s.name,
        crate::util::eng(s.median_s, "s"),
        crate::util::eng(s.min_s, "s"),
        s.iters
    );
}

/// Convenience: time + report.
pub fn run<T, F: FnMut() -> T>(name: &str, target_s: f64, f: F) -> Sample {
    let s = time(name, target_s, f);
    report(&s);
    s
}

/// Write samples as machine-readable JSON (`BENCH_perf.json`) so the
/// perf trajectory is tracked across PRs.  `throughput` is the bench's
/// natural unit (rects/s, banks/s, points/s); pass `s.per_sec()` when
/// there is no better unit.  Bench names are identifier-like, so no
/// string escaping is needed.
pub fn write_json(path: &std::path::Path, samples: &[(Sample, f64)]) -> std::io::Result<()> {
    let mut out = String::from("[\n");
    for (i, (s, tput)) in samples.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"name\": \"{}\", \"median_s\": {:e}, \"min_s\": {:e}, \"max_s\": {:e}, \"iters\": {}, \"throughput\": {:e}}}{}\n",
            s.name,
            s.median_s,
            s.min_s,
            s.max_s,
            s.iters,
            tput,
            if i + 1 < samples.len() { "," } else { "" }
        ));
    }
    out.push_str("]\n");
    std::fs::write(path, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_positive_and_ordered() {
        let s = time("noop", 0.01, || 1 + 1);
        assert!(s.min_s <= s.median_s && s.median_s <= s.max_s);
        assert!(s.iters >= 3);
    }

    #[test]
    fn json_emission_round_trips() {
        let a = time("bench_a", 0.005, || 1 + 1);
        let b = time("bench_b", 0.005, || 2 + 2);
        let tput_a = a.per_sec();
        let path = std::env::temp_dir().join("opengcram_bench_test.json");
        write_json(&path, &[(a, tput_a), (b, 1234.5)]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("bench_a"));
        assert!(arr[0].get("median_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(arr[1].get("throughput").unwrap().as_f64(), Some(1234.5));
        assert!(arr[1].get("iters").unwrap().as_usize().unwrap() >= 3);
        std::fs::remove_file(&path).ok();
    }
}
