//! Deterministic PRNG (xoshiro256**) used by the property tests and the
//! workload-trace jitter.  Local because `rand` is not in the offline
//! registry snapshot (only `rand_core` is, without distributions).

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into full state
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n.max(1) as u64) as usize
    }

    /// Log-uniform in [lo, hi) (both > 0); natural for sweep parameters
    /// that span decades (caps, leakages, retention targets).
    pub fn log_range(&mut self, lo: f64, hi: f64) -> f64 {
        (self.range(lo.ln(), hi.ln())).exp()
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

/// Tiny property-test driver: run `cases` random trials, pretty-print
/// the failing seed so the case can be replayed.  A local stand-in for
/// proptest (not in the offline registry).
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: usize, mut f: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {e:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.range(2.0, 3.0);
            assert!((2.0..3.0).contains(&v));
            let i = r.below(17);
            assert!(i < 17);
        }
    }

    #[test]
    fn log_range_spans_decades() {
        let mut r = Rng::new(1);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = r.log_range(1e-15, 1e-9);
            assert!((1e-15..1e-9).contains(&v));
            lo_seen |= v < 1e-13;
            hi_seen |= v > 1e-11;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn mean_is_centered() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }
}
