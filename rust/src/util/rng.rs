//! Deterministic PRNG (xoshiro256**) used by the property tests and the
//! workload-trace jitter.  Local because `rand` is not in the offline
//! registry snapshot (only `rand_core` is, without distributions).

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into full state
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Derive an independent substream keyed on `label` **without
    /// advancing this stream**: the child state is a splitmix64 mix of
    /// the parent state with an FNV-1a hash of the label.  Because the
    /// parent is untouched, `split` is a pure function of
    /// (parent state, label) — deriving the same labels in any order,
    /// from any number of worker threads, yields bit-identical streams,
    /// which is what makes per-(design, sample) Monte-Carlo draws
    /// reproducible independent of batch order and worker count.
    /// Sibling streams (same parent, different labels) are statistically
    /// independent; the property tests pin both claims plus the first
    /// 64 draws of a reference split as golden values.
    pub fn split(&self, label: &str) -> Rng {
        // FNV-1a over the label bytes
        let mut h: u64 = 0xcbf29ce484222325;
        for b in label.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        // fold each parent state word through splitmix64 seeded by the
        // label hash — same finalizer as `new`, so child quality matches
        let mut x = h;
        let mut mix = |v: u64| {
            x = x.wrapping_add(v).wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        let s = [mix(self.s[0]), mix(self.s[1]), mix(self.s[2]), mix(self.s[3])];
        if s == [0u64; 4] {
            // xoshiro's one forbidden state; unreachable in practice
            return Rng::new(h);
        }
        Rng { s }
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal draw (Box–Muller, trigonometric form; consumes
    /// exactly two `next_u64`s, so stream positions stay predictable).
    pub fn normal(&mut self) -> f64 {
        // u1 in (0, 1] keeps the log finite
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Uniform integer in [0, n).
    ///
    /// Unbiased for every `n` (regression: this was `next_u64() % n`,
    /// which over-weights the low residues whenever `n` does not divide
    /// 2^64 — ~2^-32-level skew for small `n`, but structural bias for
    /// large non-power-of-two `n`).  Classic rejection sampling: draws
    /// landing in the final partial cycle of `2^64 / n` are redrawn, so
    /// every accepted residue is exactly equally likely.  The rejection
    /// probability is `(2^64 mod n) / 2^64` (< 2^-32 for n < 2^32), so
    /// for the sweep-sized `n` used here the draw sequence is the same
    /// as before in practice — one `next_u64` per call.
    pub fn below(&mut self, n: usize) -> usize {
        let n = n.max(1) as u64;
        // 2^64 mod n, computed without overflow
        let partial = (u64::MAX % n).wrapping_add(1) % n;
        loop {
            let v = self.next_u64();
            if partial == 0 || v <= u64::MAX - partial {
                return (v % n) as usize;
            }
        }
    }

    /// Log-uniform in [lo, hi) (both > 0); natural for sweep parameters
    /// that span decades (caps, leakages, retention targets).
    pub fn log_range(&mut self, lo: f64, hi: f64) -> f64 {
        (self.range(lo.ln(), hi.ln())).exp()
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

/// Tiny property-test driver: run `cases` random trials, pretty-print
/// the failing seed so the case can be replayed.  A local stand-in for
/// proptest (not in the offline registry).
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: usize, mut f: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {e:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.range(2.0, 3.0);
            assert!((2.0..3.0).contains(&v));
            let i = r.below(17);
            assert!(i < 17);
        }
    }

    #[test]
    fn log_range_spans_decades() {
        let mut r = Rng::new(1);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = r.log_range(1e-15, 1e-9);
            assert!((1e-15..1e-9).contains(&v));
            lo_seen |= v < 1e-13;
            hi_seen |= v > 1e-11;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn mean_is_centered() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    /// The first 64 draws of `Rng::new(42).split("mc/0")` pinned as
    /// golden values.  Any change to the seeding, the split mixer, or
    /// the xoshiro core shifts every Monte-Carlo stream in the repo —
    /// this test makes that loud instead of silently changing yields.
    #[test]
    fn split_golden_draws_are_stable() {
        const GOLDEN: [u64; 64] = [
            0x5be7d2ff6313f90e, 0xb2f95a9825dc550e, 0xb7902d22206d294d, 0x3410722c61096b76,
            0x842560c4dfe6c0d0, 0xc31b198be0380635, 0xa9ee28e625afd970, 0xaa5273dc86568291,
            0x74b6a86f5f52610e, 0x7e5879702b3f91b0, 0x70a3d65e11f9e513, 0xe005db0ea1f82a69,
            0x5371e95e33f5fe0b, 0xe7537e2a8e7fca74, 0x8e3d3d71ade32b20, 0x40c28ab38053779b,
            0xf2bd29ce276f53c4, 0x9b63443374ad6927, 0x618c0a845d9ea3fd, 0xc817b3dd406959c9,
            0x0e88f9fb4034f47f, 0x1c18435b517234c6, 0xd0e19b9df386de0f, 0xb50d834a0e5af907,
            0x97068b417995f90f, 0x389c4cb90f410829, 0x09918e00c43aa4ef, 0x46f916314a9f37f6,
            0x3525092b426d3d88, 0xd29545c1d4779cc5, 0x75184c1f30837d4e, 0x1f58687df4cde265,
            0x9950ce2255638a0f, 0xfc585f483e34b625, 0x3c92714cf7069148, 0x5d2ab73117a222f5,
            0x297fe2f12f10899d, 0x828040a328abdf24, 0xd6668f9df25e2198, 0xc6cdac02a80e283f,
            0xc2afede47b5949d7, 0xa4e32108b823e277, 0xefb358d7c0ec719c, 0x36cd6b62afeaec08,
            0xbeade98865437273, 0x904341bd0bc67d07, 0x141851d91bb8feb2, 0x2c258ee7c9b0599f,
            0x6830580911e8cbc5, 0xa48327acc6a64caf, 0x339061b176d745f9, 0xc580332efeac1e21,
            0xf23f44e22ff2e2eb, 0xf148259326b509b4, 0x2c0a5db117c823dc, 0x6edf5dcd55ac8bcd,
            0xf7d0a7a7d54ae5fd, 0x6e12ba6d47430490, 0x5f8518259b9c93a5, 0x5d0f5f776e346c01,
            0xbe66cf4423c69941, 0x50cc0f3c14d166d1, 0x5a5b65e60226df16, 0x273a1bc707b246ef,
        ];
        let mut child = Rng::new(42).split("mc/0");
        for (i, want) in GOLDEN.iter().enumerate() {
            assert_eq!(child.next_u64(), *want, "draw {i} diverged from golden");
        }
    }

    /// Split is a pure function of (parent state, label): it must not
    /// advance the parent, so deriving substreams in any order — or
    /// from any partition of labels across worker threads — gives
    /// bit-identical children.
    #[test]
    fn split_is_order_and_worker_independent() {
        let parent = Rng::new(0xDEAD_BEEF);
        let labels: Vec<String> = (0..32).map(|i| format!("d{}/s{}", i % 4, i / 4)).collect();

        // forward vs reverse derivation order
        let fwd: Vec<Vec<u64>> = labels
            .iter()
            .map(|l| {
                let mut c = parent.split(l);
                (0..8).map(|_| c.next_u64()).collect()
            })
            .collect();
        let mut rev: Vec<(usize, Vec<u64>)> = labels
            .iter()
            .enumerate()
            .rev()
            .map(|(i, l)| {
                let mut c = parent.split(l);
                (i, (0..8).map(|_| c.next_u64()).collect())
            })
            .collect();
        rev.sort_by_key(|(i, _)| *i);
        for (i, (_, r)) in rev.into_iter().enumerate() {
            assert_eq!(fwd[i], r, "label {} depends on derivation order", labels[i]);
        }

        // threaded partition (simulates a worker pool splitting the label set)
        let threaded: Vec<Vec<u64>> = std::thread::scope(|scope| {
            let handles: Vec<_> = labels
                .chunks(7)
                .map(|chunk| {
                    let parent = parent.clone();
                    scope.spawn(move || {
                        chunk
                            .iter()
                            .map(|l| {
                                let mut c = parent.split(l);
                                (0..8).map(|_| c.next_u64()).collect::<Vec<u64>>()
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(fwd, threaded);

        // and the parent stream itself is untouched by splitting
        let mut a = Rng::new(0xDEAD_BEEF);
        let mut b = parent.clone();
        assert_eq!(a.next_u64(), b.next_u64());
    }

    /// Sibling streams must be statistically independent: the sample
    /// cross-correlation of their uniform draws stays near zero, and no
    /// sibling reproduces another's draws.
    #[test]
    fn split_siblings_are_uncorrelated() {
        let parent = Rng::new(9);
        let n = 20_000;
        let streams: Vec<Vec<f64>> = (0..4)
            .map(|i| {
                let mut c = parent.split(&format!("sib/{i}"));
                (0..n).map(|_| c.f64()).collect()
            })
            .collect();
        for i in 0..streams.len() {
            for j in (i + 1)..streams.len() {
                let (a, b) = (&streams[i], &streams[j]);
                assert_ne!(a[..64], b[..64], "siblings {i},{j} share draws");
                let (ma, mb) = (
                    a.iter().sum::<f64>() / n as f64,
                    b.iter().sum::<f64>() / n as f64,
                );
                let cov: f64 = a
                    .iter()
                    .zip(b)
                    .map(|(x, y)| (x - ma) * (y - mb))
                    .sum::<f64>()
                    / n as f64;
                // uniform variance is 1/12; |rho| ~ O(1/sqrt(n)) for
                // independent streams, so 0.05 is a ~7-sigma bound
                let rho = cov / (1.0 / 12.0);
                assert!(rho.abs() < 0.05, "siblings {i},{j} correlate: rho={rho}");
            }
        }
    }

    /// Regression for the `below` modulo bias: with rejection sampling
    /// every residue class is equally likely, so a chi-square statistic
    /// over non-power-of-two bins stays under the fixed-seed bound.
    /// (Fixed seeds keep this deterministic — it cannot flake.)
    #[test]
    fn below_is_uniform_chi_square() {
        for (seed, n) in [(11u64, 6usize), (12, 17), (13, 1000)] {
            let mut r = Rng::new(seed);
            let draws = 60_000;
            let mut counts = vec![0u64; n];
            for _ in 0..draws {
                let v = r.below(n);
                assert!(v < n);
                counts[v] += 1;
            }
            let expect = draws as f64 / n as f64;
            let chi2: f64 = counts
                .iter()
                .map(|&c| {
                    let d = c as f64 - expect;
                    d * d / expect
                })
                .sum();
            // df = n-1; 99.9th percentile is ~22.5 (df=5), ~39 (df=16),
            // ~1150 (df=999).  Generous fixed bounds well above those.
            let bound = 2.0 * n as f64 + 30.0;
            assert!(chi2 < bound, "chi2={chi2} for n={n} seed={seed}");
        }
    }

    /// `below` must stay exact at the boundaries the sweeps rely on.
    #[test]
    fn below_edge_cases() {
        let mut r = Rng::new(5);
        assert_eq!(r.below(1), 0);
        assert_eq!(r.below(0), 0, "n=0 clamps to 1");
        for _ in 0..1000 {
            assert!(r.below(2) < 2);
        }
    }

    /// Box–Muller normal: centered, unit variance, deterministic.
    #[test]
    fn normal_moments() {
        let mut r = Rng::new(21);
        let n = 50_000;
        let draws: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = draws.iter().sum::<f64>() / n as f64;
        let var = draws.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
        let mut a = Rng::new(21);
        let mut b = Rng::new(21);
        for _ in 0..100 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        }
    }
}
