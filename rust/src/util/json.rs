//! Minimal recursive-descent JSON parser and serializer.
//!
//! serde_json is not in the offline registry; this module started as
//! the strict parser for `artifacts/manifest.json` and now also does
//! protocol duty for the `serve` front end ([`crate::service::serve`])
//! and the on-disk evaluation store ([`crate::store`]).  Supports the
//! full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null); rejects trailing garbage.  Parse errors carry a
//! snippet of the offending input ([`JsonError::context`]) so a bad
//! request line over the socket is diagnosable from the error alone.
//!
//! [`Json::dump`] is the serializer: compact one-line output, strings
//! escaped per RFC 8259 (quotes, backslashes, all control characters),
//! non-finite numbers emitted as `null` (JSON has no NaN/Infinity).
//! `parse(dump(x)) == x` for every value whose numbers are finite.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array of strings helper (manifest node/param name lists).
    pub fn str_list(&self) -> Option<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_str().map(|s| s.to_string()))
            .collect()
    }

    /// Serialize to compact one-line JSON.  Strings are escaped per
    /// RFC 8259 — `"`, `\`, and **every** control character below
    /// U+0020 (named escapes where they exist, `\u00XX` otherwise) —
    /// so untrusted content round-trips through the line-oriented
    /// serve protocol without ever emitting a raw newline.  Non-finite
    /// numbers serialize as `null`.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Rust's shortest-round-trip `Display` for f64: `parse(dump)` is
/// bit-identical for every finite value, which the on-disk store's
/// textual fields and the serve protocol rely on.
fn write_num(n: f64, out: &mut String) {
    use fmt::Write;
    if n.is_finite() {
        let _ = write!(out, "{n}");
    } else {
        out.push_str("null");
    }
}

fn write_str(s: &str, out: &mut String) {
    use fmt::Write;
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Fluent [`Json::Obj`] builder for response/entry assembly.
#[derive(Default)]
pub struct ObjBuilder(BTreeMap<String, Json>);

impl ObjBuilder {
    pub fn new() -> ObjBuilder {
        ObjBuilder::default()
    }

    pub fn put(mut self, key: &str, value: Json) -> ObjBuilder {
        self.0.insert(key.to_string(), value);
        self
    }

    pub fn build(self) -> Json {
        Json::Obj(self.0)
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
    /// Snippet of the offending input around `pos` (control characters
    /// escaped) — a bad request line over the serve socket must be
    /// diagnosable from the error alone, without server-side logs of
    /// the raw input.
    pub context: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "json error at byte {}: {} (near `{}`)",
            self.pos, self.msg, self.context
        )
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        // a window of the raw input around the failure point; lossy
        // decoding tolerates the window splitting a multi-byte char
        let lo = self.i.saturating_sub(16);
        let hi = (self.i + 16).min(self.b.len());
        let context: String = String::from_utf8_lossy(&self.b[lo..hi])
            .chars()
            .map(|c| if c.is_control() { '\u{fffd}' } else { c })
            .collect();
        JsonError { pos: self.i, msg: msg.to_string(), context }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // surrogate pairs are not needed for the manifest;
                            // map unpaired surrogates to replacement char
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // copy raw utf-8 bytes through
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
        assert!(j.get("c").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn str_list_helper() {
        let j = Json::parse(r#"["sn","wbl"]"#).unwrap();
        assert_eq!(j.str_list().unwrap(), vec!["sn", "wbl"]);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""µs""#).unwrap();
        assert_eq!(j.as_str(), Some("\u{b5}s"));
    }

    #[test]
    fn serializer_escapes_quotes_and_control_characters() {
        // regression: the pre-PR-9 crate had no serializer at all and
        // the bench writer emitted strings raw — a quote or newline in
        // a value would have produced an unparseable document
        assert_eq!(Json::Str("a\"b".into()).dump(), r#""a\"b""#);
        assert_eq!(Json::Str("back\\slash".into()).dump(), r#""back\\slash""#);
        assert_eq!(Json::Str("line\nbreak".into()).dump(), r#""line\nbreak""#);
        assert_eq!(Json::Str("\r\t\u{8}\u{c}".into()).dump(), r#""\r\t\b\f""#);
        // unnamed control chars get \u00XX, so a line-oriented protocol
        // never sees a raw control byte inside a serialized line
        assert_eq!(Json::Str("\u{1}\u{1f}".into()).dump(), "\"\\u0001\\u001f\"");
        assert!(!Json::Str("x\u{0}y".into()).dump().contains('\u{0}'));
        // non-string scalars and containers
        assert_eq!(Json::Null.dump(), "null");
        assert_eq!(Json::Bool(true).dump(), "true");
        assert_eq!(Json::Num(-1500.0).dump(), "-1500");
        assert_eq!(Json::Num(f64::NAN).dump(), "null", "JSON has no NaN");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
        let obj = ObjBuilder::new()
            .put("b", Json::Arr(vec![Json::Num(1.0), Json::Str("x".into())]))
            .put("a", Json::Null)
            .build();
        assert_eq!(obj.dump(), r#"{"a":null,"b":[1,"x"]}"#);
    }

    #[test]
    fn serializer_round_trips_through_parser() {
        // parse(dump(x)) == x, including every escape class and
        // shortest-round-trip float formatting (bit-exact for finite)
        let cases = [
            Json::Str("quote \" slash \\ nl \n tab \t nul \u{0} µ".into()),
            Json::Num(0.1 + 0.2),
            Json::Num(-0.0),
            Json::Num(1e-300),
            Json::parse(r#"{"a":[1,2,{"b":"xy"}],"c":{},"d":null}"#).unwrap(),
        ];
        for v in cases {
            let back = Json::parse(&v.dump()).unwrap();
            assert_eq!(back, v, "round-trip diverged for {}", v.dump());
        }
        // bit-exactness of the float path specifically
        for f in [std::f64::consts::PI, 1.0 / 3.0, 6.02e23, 5e-324] {
            let back = Json::parse(&Json::Num(f).dump()).unwrap();
            assert_eq!(back.as_f64().unwrap().to_bits(), f.to_bits());
        }
    }

    #[test]
    fn parse_errors_carry_the_offending_input() {
        // regression: errors used to report only a byte offset, so a
        // bad request line over the serve socket was undiagnosable
        // without server-side logging of the raw input
        let err = Json::parse(r#"{"word": thirty-two}"#).unwrap_err();
        assert!(err.context.contains("thirty-two"), "{err}");
        assert!(err.to_string().contains("thirty-two"), "{err}");
        let err = Json::parse("[1, 2, oops]").unwrap_err();
        assert!(err.to_string().contains("oops"), "{err}");
        // trailing garbage names the garbage
        let err = Json::parse("{} trailing-junk").unwrap_err();
        assert!(err.to_string().contains("trailing-junk"), "{err}");
        // the snippet is a window, not the whole (possibly huge) input
        let long = format!("[{}oops]", "1,".repeat(10_000));
        let err = Json::parse(&long).unwrap_err();
        assert!(err.context.len() <= 40, "context too large: {}", err.context.len());
        assert!(err.context.contains("oops"), "{err}");
        // control characters in the snippet are sanitized
        let err = Json::parse("{\"a\": \u{1}bad}").unwrap_err();
        assert!(!err.to_string().contains('\u{1}'));
    }
}
