//! Minimal recursive-descent JSON parser for `artifacts/manifest.json`.
//!
//! serde_json is not in the offline registry, and the manifest is the
//! single JSON document the runtime must read, so a ~200-line strict
//! parser is the right tool.  Supports the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, bools, null); rejects trailing
//! garbage.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of strings helper (manifest node/param name lists).
    pub fn str_list(&self) -> Option<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_str().map(|s| s.to_string()))
            .collect()
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // surrogate pairs are not needed for the manifest;
                            // map unpaired surrogates to replacement char
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // copy raw utf-8 bytes through
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
        assert!(j.get("c").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn str_list_helper() {
        let j = Json::parse(r#"["sn","wbl"]"#).unwrap();
        assert_eq!(j.str_list().unwrap(), vec!["sn", "wbl"]);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""µs""#).unwrap();
        assert_eq!(j.as_str(), Some("\u{b5}s"));
    }
}
