//! Design-space exploration (paper §V-E): configuration sweeps, shmoo
//! evaluation against workload demands (Fig. 10), Pareto fronts, and
//! the future-work gradient-descent co-optimizer (§VI).

use crate::characterize::{self, BankPerf};
use crate::compiler::{Bank, CellFlavor, CompileCache, Config, ConfigKey};
use crate::runtime::{RunHealth, SharedRuntime};
use crate::tech::Tech;
use crate::util::{default_workers, par_map};
use crate::workloads::Demand;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct Evaluated {
    pub config: Config,
    pub perf: BankPerf,
    pub area_um2: f64,
    /// `Some(reason)` when the point was quarantined by the
    /// fault-isolation machinery (degenerate input, non-finite output,
    /// bisected poisoned batch) instead of measured; `perf` is then the
    /// all-NaN [`BankPerf::quarantined`] placeholder.  Quarantined
    /// points are infeasible-with-reason: the shmoo verdict is
    /// [`Verdict::Quarantined`] and the Pareto front excludes them.
    pub quarantine: Option<String>,
}

/// Thread-safe (config -> evaluation) memo keyed on
/// [`ConfigKey`].  Shared by `optimize`,
/// shmoo sweeps and Pareto evaluation so a *settled* design point is
/// never compiled or characterized twice.  There is deliberately no
/// in-flight dedup: concurrent first misses on the same config may
/// each evaluate once (eval runs outside the lock so different
/// configs can evaluate in parallel); every later request is a pure
/// hit.  Callers that must avoid even that duplication should dedup
/// the config list before fanning out.
#[derive(Default)]
pub struct EvalCache {
    map: Mutex<HashMap<ConfigKey, Evaluated>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    /// Window-quantization resolution (bit pattern) this cache is
    /// bound to — see [`Self::bind_resolution`].
    resolution_bits: Mutex<Option<u64>>,
}

impl EvalCache {
    pub fn new() -> EvalCache {
        EvalCache::default()
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (cache hits, underlying evaluations) so far.
    pub fn stats(&self) -> (usize, usize) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Cache lookup without evaluation; counts a hit when present.
    /// The read side of the batch-first sweep, which evaluates its
    /// misses out-of-band (see [`evaluate_all_batched_cached`]).
    pub fn peek(&self, key: &ConfigKey) -> Option<Evaluated> {
        let hit = self.lookup(key);
        if hit.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Lookup that leaves `stats()` untouched — for bookkeeping passes
    /// that re-read entries they just inserted (a cold batched sweep
    /// must report 0 hits, not one per resolved config).
    fn lookup(&self, key: &ConfigKey) -> Option<Evaluated> {
        self.map.lock().unwrap_or_else(|p| p.into_inner()).get(key).cloned()
    }

    /// Record an externally produced evaluation; counts a miss (an
    /// underlying pipeline invocation was paid).  First write wins,
    /// matching [`Self::get_or_eval`]'s concurrent-miss semantics.
    pub fn insert(&self, e: Evaluated) {
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.map
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .entry(e.config.key())
            .or_insert(e);
    }

    /// Record an evaluation recovered from *outside* the pipeline —
    /// the on-disk store tier ([`crate::store`]) promoting an entry
    /// into memory.  Unlike [`Self::insert`] no miss is counted: no
    /// pipeline invocation was paid, and `stats()` must keep meaning
    /// "(memory hits, underlying evaluations)" so the warm-restart KPI
    /// (zero evaluations on a store-served sweep) is assertable from
    /// the counters alone.  First write wins.
    pub fn adopt(&self, e: Evaluated) {
        self.map
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .entry(e.config.key())
            .or_insert(e);
    }

    /// Uncounted lookup for bookkeeping passes that re-read entries
    /// they just inserted/adopted — the order-preserving resolution
    /// step of a batched sweep must not report its own writes as
    /// cache hits.  (The counted read is [`Self::peek`].)
    pub fn resolve(&self, key: &ConfigKey) -> Option<Evaluated> {
        self.lookup(key)
    }

    /// Bind the cache to one window-quantization resolution.  Entries
    /// record results *produced at* some resolution but are keyed on
    /// [`ConfigKey`] alone, so a cache shared across resolutions would
    /// silently serve one resolution's evaluation to the other; the
    /// batched sweep entry points call this to turn that mistake into
    /// an error.  The first bind wins; later binds must match bitwise.
    ///
    /// Scope: this guards the *batched* sweeps against each other.
    /// Entries populated through [`Self::insert`] / [`Self::get_or_eval`]
    /// (e.g. an [`evaluate_all_cached`] closure) carry whatever
    /// resolution the caller's eval pipeline used — the cache cannot
    /// see inside the closure, so mixing those with a batched sweep at
    /// a different resolution remains the caller's responsibility.
    pub fn bind_resolution(&self, window_resolution: f64) -> crate::Result<()> {
        let mut bound = self.resolution_bits.lock().unwrap_or_else(|p| p.into_inner());
        match *bound {
            None => {
                *bound = Some(window_resolution.to_bits());
                Ok(())
            }
            Some(bits) => {
                anyhow::ensure!(
                    bits == window_resolution.to_bits(),
                    "EvalCache is bound to window resolution {} but this sweep uses {}; \
                     entries are keyed on the config only — use one cache per resolution",
                    f64::from_bits(bits),
                    window_resolution
                );
                Ok(())
            }
        }
    }

    /// Return the memoized evaluation of `cfg`, running `eval` on miss.
    /// `eval` executes outside the lock so concurrent misses on
    /// *different* configs evaluate in parallel.
    pub fn get_or_eval<F>(&self, cfg: &Config, eval: F) -> crate::Result<Evaluated>
    where
        F: FnOnce(&Config) -> crate::Result<Evaluated>,
    {
        let key = cfg.key();
        if let Some(hit) = self.map.lock().unwrap_or_else(|p| p.into_inner()).get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(hit.clone());
        }
        let e = eval(cfg)?;
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.map
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .entry(key)
            .or_insert_with(|| e.clone());
        Ok(e)
    }
}


/// Evaluate every config concurrently over `std::thread::scope`
/// workers (work-stealing index, so uneven per-config costs balance).
/// Results preserve input order.  The per-config compile+characterize
/// pipeline dominates shmoo (Fig. 10) and Pareto sweep wall-clock, and
/// each evaluation is independent — the embarrassing parallelism the
/// coordinate-descent inner loop cannot exploit.
pub fn evaluate_all<F>(configs: &[Config], workers: usize, eval: F) -> crate::Result<Vec<Evaluated>>
where
    F: Fn(&Config) -> crate::Result<Evaluated> + Sync,
{
    par_map(configs, workers, |c| eval(c)).into_iter().collect()
}

/// [`evaluate_all`] through a shared [`EvalCache`]: repeated configs
/// (shmoo axes overlapping optimizer walks, re-runs across workloads)
/// cost one evaluation once settled — see [`EvalCache`] for the
/// concurrent-first-miss caveat.
pub fn evaluate_all_cached<F>(
    configs: &[Config],
    workers: usize,
    cache: &EvalCache,
    eval: F,
) -> crate::Result<Vec<Evaluated>>
where
    F: Fn(&Config) -> crate::Result<Evaluated> + Sync,
{
    evaluate_all(configs, workers, |cfg| cache.get_or_eval(cfg, &eval))
}

/// Batch-first transient sweep: compile the distinct cache misses in
/// parallel (pure geometry/netlist work — no runtime contention), then
/// characterize them all in one
/// [`characterize_all`](crate::characterize::characterize_all) pass so
/// their transient points pack into shared padded artifact batches.
/// Sweep workers never touch the `SharedRuntime` mutex themselves;
/// only the coordinator executors do, once per batch.  Results
/// preserve input order; repeated configs cost one evaluation.
///
/// `window_resolution` is the window-quantization bucket step
/// ([`characterize::quantize_window`]): at
/// [`characterize::DEFAULT_WINDOW_RESOLUTION`] a mixed-geometry
/// (rows/cols) axis shares write/read artifact executions per bucket;
/// at `0.0` results bitwise-match the per-design path.  The cache is
/// keyed on [`ConfigKey`] only, so **one cache must not be shared
/// across different resolutions** — a hit would silently return the
/// other resolution's evaluation; [`EvalCache::bind_resolution`]
/// enforces this (the first sweep binds the cache, a later mismatch
/// errors).
///
/// `structs` shares compiled [`crate::compiler::BankStructure`]s
/// across the sweep's electrical axis (and across sweeps, when the
/// caller keeps the cache): miss configs are deduped by
/// [`Config::struct_key`] before the parallel compile, so a 5×5
/// size×VT grid pays 5 structure compiles — the distinct-structure
/// census, not the config count.
pub fn evaluate_all_batched_cached(
    tech: &Tech,
    rt: &SharedRuntime,
    configs: &[Config],
    workers: usize,
    cache: &EvalCache,
    structs: &CompileCache,
    window_resolution: f64,
) -> crate::Result<Vec<Evaluated>> {
    let (evals, _health) = evaluate_all_batched_cached_health(
        tech,
        rt,
        configs,
        workers,
        cache,
        structs,
        window_resolution,
    )?;
    Ok(evals)
}

/// [`evaluate_all_batched_cached`] returning the [`RunHealth`] report
/// alongside the evaluations.  Quarantined design points come back as
/// [`Evaluated`] entries with `quarantine: Some(reason)` and the
/// all-NaN placeholder perf (infeasible-with-reason) instead of
/// failing the sweep; they are cached like any other result, so a
/// repeat sweep does not re-pay their (failing) evaluation.  The
/// health report covers only the *miss* evaluations this call paid —
/// a fully cached sweep reports clean.
pub fn evaluate_all_batched_cached_health(
    tech: &Tech,
    rt: &SharedRuntime,
    configs: &[Config],
    workers: usize,
    cache: &EvalCache,
    structs: &CompileCache,
    window_resolution: f64,
) -> crate::Result<(Vec<Evaluated>, RunHealth)> {
    cache.bind_resolution(window_resolution)?;
    // distinct configs not yet cached, in first-appearance order.
    // Allocation-light: keys move into `seen` (no per-occurrence
    // clones) and misses are borrowed, not cloned.
    let mut seen: std::collections::HashSet<ConfigKey> = std::collections::HashSet::new();
    let mut miss_cfgs: Vec<&Config> = Vec::new();
    for cfg in configs {
        let key = cfg.key();
        if seen.contains(&key) {
            continue;
        }
        let cached = cache.peek(&key).is_some();
        seen.insert(key);
        if !cached {
            miss_cfgs.push(cfg);
        }
    }
    let banks: Vec<Bank> = structs.compile_all(tech, &miss_cfgs, workers)?;
    let (perfs, health) =
        characterize::characterize_all_health(tech, rt, &banks, window_resolution)?;
    for (bank, perf) in banks.iter().zip(perfs) {
        let (perf, quarantine) = match perf {
            Ok(p) => (p, None),
            Err(q) => (
                BankPerf::quarantined(),
                Some(format!("{} stage: {}", q.stage, q.reason)),
            ),
        };
        cache.insert(Evaluated {
            config: bank.config.clone(),
            perf,
            area_um2: bank.layout.total_area_um2(),
            quarantine,
        });
    }
    // order-preserving resolution: every key is cached now (uncounted
    // lookup — these reads are bookkeeping, not cache hits)
    let evals = configs
        .iter()
        .map(|cfg| {
            cache
                .lookup(&cfg.key())
                .ok_or_else(|| anyhow::anyhow!("config missing from cache after batch evaluation"))
        })
        .collect::<crate::Result<Vec<Evaluated>>>()?;
    Ok((evals, health))
}

/// [`evaluate_all_batched_cached`] with a throwaway cache (the
/// batch-first replacement for a plain [`evaluate_all`] over a
/// transient-backed closure).
pub fn evaluate_all_batched(
    tech: &Tech,
    rt: &SharedRuntime,
    configs: &[Config],
    workers: usize,
    window_resolution: f64,
) -> crate::Result<Vec<Evaluated>> {
    evaluate_all_batched_cached(
        tech,
        rt,
        configs,
        workers,
        &EvalCache::new(),
        &CompileCache::new(),
        window_resolution,
    )
}

/// [`evaluate_all_batched`] returning the [`RunHealth`] report — the
/// entry point the `dse` CLI prints its health summary from.
pub fn evaluate_all_batched_health(
    tech: &Tech,
    rt: &SharedRuntime,
    configs: &[Config],
    workers: usize,
    window_resolution: f64,
) -> crate::Result<(Vec<Evaluated>, RunHealth)> {
    evaluate_all_batched_cached_health(
        tech,
        rt,
        configs,
        workers,
        &EvalCache::new(),
        &CompileCache::new(),
        window_resolution,
    )
}

/// Shmoo verdict for (config, demand).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Pass,
    /// Too slow for the demanded read frequency.
    FailFreq,
    /// Retention shorter than the demanded lifetime.
    FailRetention,
    /// Electrically non-functional (no sense margin).
    FailMargin,
    /// Quarantined by fault isolation — never measured (see
    /// [`Evaluated::quarantine`] for the reason).
    Quarantined,
}

impl Verdict {
    pub fn pass(&self) -> bool {
        *self == Verdict::Pass
    }
    pub fn glyph(&self) -> char {
        match self {
            Verdict::Pass => 'P',
            Verdict::FailFreq => 'f',
            Verdict::FailRetention => 'r',
            Verdict::FailMargin => 'x',
            Verdict::Quarantined => 'q',
        }
    }
}

/// Evaluate one (design, demand) pair — the Fig. 10 cell.
pub fn shmoo_verdict(e: &Evaluated, d: &Demand) -> Verdict {
    if e.quarantine.is_some() {
        Verdict::Quarantined
    } else if !e.perf.functional {
        Verdict::FailMargin
    } else if e.perf.f_op_hz < d.read_freq_hz {
        Verdict::FailFreq
    } else if e.perf.retention_s < d.lifetime_s {
        Verdict::FailRetention
    } else {
        Verdict::Pass
    }
}

/// The Fig. 10 configuration axis: square banks 16x16 .. 128x128.
pub fn fig10_configs(flavor: CellFlavor) -> Vec<Config> {
    [16usize, 32, 64, 96, 128]
        .iter()
        .map(|&n| Config::new(n, n, flavor))
        .collect()
}

/// Named objective accessors for [`crate::dse::pareto_front`].  Every
/// objective is *minimized*; maximized quantities are negated.
pub mod objectives {
    use super::Evaluated;

    /// Maximize operating frequency.
    pub fn neg_f_op(e: &Evaluated) -> f64 {
        -e.perf.f_op_hz
    }
    /// Maximize retention.
    pub fn neg_retention(e: &Evaluated) -> f64 {
        -e.perf.retention_s
    }
    /// Minimize bank area.
    pub fn area(e: &Evaluated) -> f64 {
        e.area_um2
    }
    /// Minimize leakage power.
    pub fn leakage(e: &Evaluated) -> f64 {
        e.perf.leakage_w
    }
}

/// Multi-objective Pareto front over `points`: indices of the points
/// no other point dominates.  `objs` map a point to values to
/// *minimize* (see [`objectives`]).
///
/// Feasibility guard (regression): electrically non-functional points
/// (`functional == false`) and points with a NaN objective are
/// **excluded from the front and never dominate** — a non-functional
/// point's finite fields still compare, so it used to both survive on
/// the front and evict real designs; NaN fields compare false
/// everywhere, so a NaN point used to survive unconditionally.  The
/// composition layer ([`crate::compose`]) selects from this front, so
/// an infeasible survivor would propagate into chosen hardware.
pub fn pareto_front(points: &[Evaluated], objs: &[fn(&Evaluated) -> f64]) -> Vec<usize> {
    let keys: Vec<Option<Vec<f64>>> = points
        .iter()
        .map(|e| {
            if !e.perf.functional {
                return None;
            }
            let k: Vec<f64> = objs.iter().map(|f| f(e)).collect();
            if k.iter().any(|v| v.is_nan()) {
                None
            } else {
                Some(k)
            }
        })
        .collect();
    let dominates = |a: &Vec<f64>, b: &Vec<f64>| {
        a.iter().zip(b.iter()).all(|(x, y)| x <= y) && a.iter().zip(b.iter()).any(|(x, y)| x < y)
    };
    (0..points.len())
        .filter(|&i| {
            let ki = match &keys[i] {
                Some(k) => k,
                None => return false,
            };
            !keys
                .iter()
                .enumerate()
                .any(|(j, kj)| j != i && kj.as_ref().map_or(false, |kj| dominates(kj, ki)))
        })
        .collect()
}

/// The classic DSE front (maximize f_op, maximize retention, minimize
/// area) — see [`pareto_front`] for the functional/NaN exclusions.
pub fn pareto(points: &[Evaluated]) -> Vec<usize> {
    pareto_front(
        points,
        &[objectives::neg_f_op, objectives::neg_retention, objectives::area],
    )
}

/// Co-optimization target (paper §VI: "area-delay-power co-optimization
/// ... leveraging machine learning algorithms (e.g., gradient descent)").
#[derive(Debug, Clone, Copy)]
pub struct CostWeights {
    pub w_delay: f64,
    pub w_area: f64,
    pub w_power: f64,
    /// Hard frequency floor (Hz); configs below it get +inf cost.
    pub f_min_hz: f64,
    /// Hard lifetime floor (s).
    pub t_retain_min_s: f64,
}

pub fn cost(w: &CostWeights, e: &Evaluated) -> f64 {
    if e.perf.f_op_hz < w.f_min_hz || e.perf.retention_s < w.t_retain_min_s || !e.perf.functional {
        return f64::INFINITY;
    }
    w.w_delay / e.perf.f_op_hz * 1e9 + w.w_area * e.area_um2 / 1e4 + w.w_power * e.perf.leakage_w * 1e6
}

/// Coordinate-descent co-optimizer over (size exponent, write VT).
/// `eval` maps a Config to an Evaluated (the caller decides whether
/// that's analytical or transient-backed).
///
/// Memoized on [`ConfigKey`]: the descent revisits neighbors of every
/// accepted move, and without the cache each revisit re-ran the full
/// compile+characterize pipeline.  `evals` counts *underlying*
/// evaluations (cache misses), so it is also the pipeline invocation
/// count a caller pays for.
pub fn optimize<F>(
    flavor: CellFlavor,
    weights: &CostWeights,
    mut eval: F,
) -> crate::Result<(Evaluated, usize)>
where
    F: FnMut(&Config) -> crate::Result<Evaluated>,
{
    let mut si = 1usize;
    let mut vi = 0usize;
    let cache = EvalCache::new();
    let mut best = cache.get_or_eval(&opt_config(flavor, si, vi), &mut eval)?;
    let mut best_cost = cost(weights, &best);
    // coordinate descent until no single-step move improves
    loop {
        let mut improved = false;
        for (a, b) in opt_moves(si, vi) {
            let e = cache.get_or_eval(&opt_config(flavor, a, b), &mut eval)?;
            let c = cost(weights, &e);
            if c < best_cost {
                best_cost = c;
                best = e;
                si = a;
                vi = b;
                improved = true;
                break;
            }
        }
        // termination: each accepted move strictly decreases cost and
        // the memoized 5x5 grid bounds distinct evaluations at 25, so
        // no separate runaway cap is needed
        if !improved {
            break;
        }
    }
    anyhow::ensure!(best_cost.is_finite(), "no feasible configuration found");
    Ok((best, cache.stats().1))
}

/// The co-optimizer's search grid: square bank sizes x write-VT
/// overrides.  Shared by [`optimize`] and [`optimize_batched`] so the
/// two walks cannot drift apart.
const OPT_SIZES: [usize; 5] = [16, 32, 64, 96, 128];
const OPT_VTS: [Option<f64>; 5] = [None, Some(0.38), Some(0.45), Some(0.52), Some(0.60)];

/// Grid point -> Config (shared by both optimizers).
fn opt_config(flavor: CellFlavor, si: usize, vi: usize) -> Config {
    let mut c = Config::new(OPT_SIZES[si], OPT_SIZES[si], flavor);
    c.write_vt = OPT_VTS[vi];
    c
}

/// The co-optimizer's full (square size x write-VT) grid for one
/// flavor, row-major over `OPT_SIZES` x `OPT_VTS` — 25 configs in
/// deterministic order.  This is the per-flavor scenario axis the
/// composition engine ([`crate::compose`]) sweeps; sharing
/// `opt_config` keeps it aligned with the coordinate-descent walk.
pub fn grid_configs(flavor: CellFlavor) -> Vec<Config> {
    let mut out = Vec::with_capacity(OPT_SIZES.len() * OPT_VTS.len());
    for si in 0..OPT_SIZES.len() {
        for vi in 0..OPT_VTS.len() {
            out.push(opt_config(flavor, si, vi));
        }
    }
    out
}

/// In-bounds single-step neighbor moves in the order both optimizers
/// probe them (the first-improving rule makes this order part of the
/// walk's identity).
fn opt_moves(si: usize, vi: usize) -> Vec<(usize, usize)> {
    [
        (si.wrapping_sub(1), vi),
        (si + 1, vi),
        (si, vi.wrapping_sub(1)),
        (si, vi + 1),
    ]
    .into_iter()
    .filter(|&(a, b)| a < OPT_SIZES.len() && b < OPT_VTS.len())
    .collect()
}

/// [`optimize`] with batch-first transient evaluation: each
/// coordinate-descent iteration evaluates *all* candidate moves in one
/// [`evaluate_all_batched_cached`] pass (their transient points share
/// artifact batches — in particular one retention execution per
/// iteration instead of one per neighbor), then applies the same
/// first-improving-move rule as [`optimize`], so the walk itself is
/// identical.  `evals` counts underlying pipeline invocations (cache
/// misses); batching may prefetch a neighbor the serial walk would
/// have skipped after an early improvement — that prefetch is the
/// batching tradeoff, and it lands in the cache for later iterations.
/// `window_resolution` follows the [`evaluate_all_batched_cached`]
/// contract (the walk's internal cache sees one resolution only).
pub fn optimize_batched(
    tech: &Tech,
    rt: &SharedRuntime,
    flavor: CellFlavor,
    weights: &CostWeights,
    window_resolution: f64,
) -> crate::Result<(Evaluated, usize)> {
    let mut si = 1usize;
    let mut vi = 0usize;
    let cache = EvalCache::new();
    // one structure cache for the whole walk: the VT axis revisits the
    // same array sizes, so neighbor moves along it compile nothing
    let structs = CompileCache::new();
    let workers = default_workers();
    let eval_batch = |cfgs: &[Config]| {
        evaluate_all_batched_cached(tech, rt, cfgs, workers, &cache, &structs, window_resolution)
    };
    let mut best = eval_batch(&[opt_config(flavor, si, vi)])?.remove(0);
    let mut best_cost = cost(weights, &best);
    loop {
        let moves = opt_moves(si, vi);
        let cfgs: Vec<Config> = moves.iter().map(|&(a, b)| opt_config(flavor, a, b)).collect();
        let evs = eval_batch(&cfgs)?;
        let mut improved = false;
        for ((a, b), e) in moves.into_iter().zip(evs) {
            let c = cost(weights, &e);
            if c < best_cost {
                best_cost = c;
                best = e;
                si = a;
                vi = b;
                improved = true;
                break;
            }
        }
        // termination matches `optimize`: each accepted move strictly
        // decreases cost and the memoized 5x5 grid bounds evaluations
        if !improved {
            break;
        }
    }
    anyhow::ensure!(best_cost.is_finite(), "no feasible configuration found");
    Ok((best, cache.stats().1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::characterize::BankPerf;

    fn fake(f: f64, ret: f64, area: f64) -> Evaluated {
        Evaluated {
            config: Config::new(32, 32, CellFlavor::GcSiSiNp),
            perf: BankPerf {
                f_read_hz: f,
                f_write_hz: f,
                f_op_hz: f,
                bandwidth_bps: 64.0 * f,
                retention_s: ret,
                leakage_w: 1e-6,
                e_read_j: 1e-12,
                t_decoder_s: 1e-10,
                t_cell_read_s: 1e-10,
                stored_one_v: 0.6,
                functional: true,
            },
            area_um2: area,
            quarantine: None,
        }
    }

    #[test]
    fn quarantined_points_are_infeasible_with_reason() {
        use crate::workloads::{profile, CacheLevel, H100, TASKS};
        let d = profile(&TASKS[0], CacheLevel::L1, &H100);
        let mut q = fake(1e9, 1.0, 1e4);
        q.perf = BankPerf::quarantined();
        q.quarantine = Some("write stage: degenerate write input: c_sn = 0".to_string());
        assert_eq!(shmoo_verdict(&q, &d), Verdict::Quarantined);
        assert_eq!(shmoo_verdict(&q, &d).glyph(), 'q');
        assert!(!shmoo_verdict(&q, &d).pass());
        // all-NaN perf + functional=false: the Pareto front drops it
        let real = fake(1e9, 1e-3, 1e4);
        assert_eq!(pareto(&[q, real]), vec![1]);
    }

    #[test]
    fn verdict_logic() {
        use crate::workloads::{profile, CacheLevel, H100, TASKS};
        let d = profile(&TASKS[0], CacheLevel::L1, &H100);
        let fast = fake(d.read_freq_hz * 2.0, 1.0, 1e4);
        let slow = fake(d.read_freq_hz * 0.5, 1.0, 1e4);
        let leaky = fake(d.read_freq_hz * 2.0, d.lifetime_s * 0.5, 1e4);
        assert_eq!(shmoo_verdict(&fast, &d), Verdict::Pass);
        assert_eq!(shmoo_verdict(&slow, &d), Verdict::FailFreq);
        assert_eq!(shmoo_verdict(&leaky, &d), Verdict::FailRetention);
    }

    #[test]
    fn pareto_removes_dominated() {
        let pts = vec![
            fake(1e9, 1e-3, 1e4),
            fake(0.5e9, 0.5e-3, 2e4), // dominated by the first
            fake(2e9, 1e-4, 3e4),     // faster but leakier/larger
        ];
        let front = pareto(&pts);
        assert!(front.contains(&0));
        assert!(!front.contains(&1));
        assert!(front.contains(&2));
    }

    #[test]
    fn pareto_excludes_nonfunctional_points() {
        // regression: a non-functional point's finite fields still
        // compare, so it used to stay on the front AND evict the real
        // design it numerically dominated
        let mut broken = fake(10e9, 1.0, 1.0);
        broken.perf.functional = false;
        let real = fake(1e9, 1e-3, 1e4);
        assert_eq!(pareto(&[broken, real]), vec![1]);
    }

    #[test]
    fn pareto_nan_fields_never_dominate() {
        // regression: NaN comparisons are false everywhere, so a
        // NaN-fielded point could neither be dominated nor filtered —
        // it survived on the front unconditionally
        let real = fake(1e9, 1e-3, 1e4);
        let nan_freq = fake(f64::NAN, 1e-3, 1.0);
        assert_eq!(pareto(&[nan_freq, real.clone()]), vec![1]);
        let mut nan_area = fake(10e9, 1.0, 1.0);
        nan_area.area_um2 = f64::NAN;
        assert_eq!(pareto(&[nan_area, real]), vec![1]);
    }

    #[test]
    fn pareto_front_handles_custom_objectives() {
        // the composition front: minimize area + leakage, maximize f_op
        let mut a = fake(1e9, 1e-3, 1e4);
        a.perf.leakage_w = 1e-6;
        let mut b = fake(1e9, 1e-3, 2e4); // dominated by a on all three
        b.perf.leakage_w = 2e-6;
        let mut c = fake(2e9, 1e-3, 2e4); // larger/leakier but faster
        c.perf.leakage_w = 2e-6;
        let front = pareto_front(
            &[a, b, c],
            &[objectives::area, objectives::leakage, objectives::neg_f_op],
        );
        assert_eq!(front, vec![0, 2]);
    }

    #[test]
    fn grid_configs_is_the_full_5x5() {
        let g = grid_configs(CellFlavor::GcSiSiNp);
        assert_eq!(g.len(), 25);
        let keys: std::collections::HashSet<_> = g.iter().map(|c| c.key()).collect();
        assert_eq!(keys.len(), 25, "grid points must be distinct");
        assert!(g.iter().all(|c| c.word_size == c.num_words));
        assert!(g.iter().any(|c| c.write_vt.is_none()), "the no-override VT point is on the grid");
    }

    #[test]
    fn optimizer_converges_on_synthetic_landscape() {
        // cost favors mid-size and higher VT: check it walks there
        let w = CostWeights { w_delay: 1.0, w_area: 1.0, w_power: 1.0, f_min_hz: 0.0, t_retain_min_s: 0.0 };
        let (best, evals) = optimize(CellFlavor::GcSiSiNp, &w, |cfg| {
            let n = cfg.word_size as f64;
            let vt = cfg.write_vt.unwrap_or(0.45);
            // synthetic bowl around n=64, vt=0.52
            let f = 1e9 / (1.0 + ((n - 64.0) / 64.0).powi(2) + (vt - 0.52).abs());
            Ok(fake(f, 1e-3, n * n))
        })
        .unwrap();
        assert!(evals >= 3);
        assert!(best.config.word_size >= 32);
    }

    #[test]
    fn fig10_axis_is_five_square_configs() {
        let cfgs = fig10_configs(CellFlavor::GcSiSiNp);
        assert_eq!(cfgs.len(), 5);
        assert!(cfgs.iter().all(|c| c.word_size == c.num_words));
    }

    #[test]
    fn optimizer_never_reevaluates_a_visited_point() {
        let w = CostWeights { w_delay: 1.0, w_area: 1.0, w_power: 1.0, f_min_hz: 0.0, t_retain_min_s: 0.0 };
        let mut seen: std::collections::HashSet<crate::compiler::ConfigKey> =
            std::collections::HashSet::new();
        let (_, evals) = optimize(CellFlavor::GcSiSiNp, &w, |cfg| {
            assert!(seen.insert(cfg.key()), "config evaluated twice: {cfg:?}");
            let n = cfg.word_size as f64;
            let vt = cfg.write_vt.unwrap_or(0.45);
            let f = 1e9 / (1.0 + ((n - 64.0) / 64.0).powi(2) + (vt - 0.52).abs());
            Ok(fake(f, 1e-3, n * n))
        })
        .unwrap();
        assert_eq!(evals, seen.len());
        // the 5x5 grid bounds the distinct points the walk can touch
        assert!(evals <= 25);
    }

    #[test]
    fn eval_cache_dedupes_concurrent_sweeps() {
        let cache = EvalCache::new();
        let calls = AtomicUsize::new(0);
        // the five fig10 configs, each requested four times
        let mut configs = Vec::new();
        for _ in 0..4 {
            configs.extend(fig10_configs(CellFlavor::GcSiSiNp));
        }
        let run = |cfg: &Config| {
            calls.fetch_add(1, Ordering::Relaxed);
            let mut e = fake(1e9 / cfg.word_size as f64, 1e-3, cfg.bits() as f64);
            e.config = cfg.clone();
            Ok(e)
        };
        let evals = evaluate_all_cached(&configs, 4, &cache, run).unwrap();
        assert_eq!(evals.len(), 20);
        assert_eq!(cache.len(), 5);
        // results preserve input order and resolve to the right config
        for (cfg, e) in configs.iter().zip(&evals) {
            assert_eq!(e.config.word_size, cfg.word_size);
        }
        // a second identical sweep is served entirely from the cache
        let calls_before = calls.load(Ordering::Relaxed);
        let (hits_before, _) = cache.stats();
        let evals2 = evaluate_all_cached(&configs, 4, &cache, run).unwrap();
        assert_eq!(evals2.len(), 20);
        assert_eq!(calls.load(Ordering::Relaxed), calls_before, "second sweep re-evaluated");
        let (hits_after, misses) = cache.stats();
        assert!(hits_after >= hits_before + 20, "hits {hits_before} -> {hits_after}");
        assert_eq!(cache.len(), 5);
        assert!(misses <= calls_before);
    }

    #[test]
    fn evaluate_all_preserves_order_and_propagates_errors() {
        let cfgs: Vec<Config> = (1..=9).map(|i| Config::new(8 * i, 8 * i, CellFlavor::GcSiSiNp)).collect();
        let evals = evaluate_all(&cfgs, 3, |cfg| {
            Ok(fake(1e9, 1e-3, cfg.bits() as f64))
        })
        .unwrap();
        let areas: Vec<f64> = evals.iter().map(|e| e.area_um2).collect();
        let want: Vec<f64> = cfgs.iter().map(|c| c.bits() as f64).collect();
        assert_eq!(areas, want);
        let err = evaluate_all(&cfgs, 3, |cfg| {
            if cfg.word_size == 40 {
                anyhow::bail!("injected failure")
            }
            Ok(fake(1e9, 1e-3, 1.0))
        });
        assert!(err.is_err());
    }

    #[test]
    fn cache_peek_and_insert_back_the_batched_sweep() {
        let cache = EvalCache::new();
        let cfg = Config::new(32, 32, CellFlavor::GcSiSiNp);
        assert!(cache.peek(&cfg.key()).is_none());
        let mut e = fake(1e9, 1e-3, 42.0);
        e.config = cfg.clone();
        cache.insert(e);
        let hit = cache.peek(&cfg.key()).expect("inserted evaluation is visible");
        assert_eq!(hit.area_um2, 42.0);
        // first write wins (concurrent-miss semantics of get_or_eval)
        let mut e2 = fake(2e9, 1e-3, 99.0);
        e2.config = cfg.clone();
        cache.insert(e2);
        assert_eq!(cache.peek(&cfg.key()).unwrap().area_um2, 42.0);
        assert_eq!(cache.len(), 1);
        let (hits, misses) = cache.stats();
        assert_eq!(misses, 2, "inserts count as paid evaluations");
        assert!(hits >= 2);
    }

    #[test]
    fn eval_cache_rejects_mixed_resolutions() {
        let cache = EvalCache::new();
        cache.bind_resolution(0.1).unwrap();
        cache.bind_resolution(0.1).unwrap();
        let err = cache.bind_resolution(0.0);
        assert!(err.is_err(), "a resolution mismatch must not silently alias the cache");
    }

    #[test]
    fn config_key_identity() {
        let a = Config::new(32, 32, CellFlavor::GcSiSiNp);
        let mut b = Config::new(32, 32, CellFlavor::GcSiSiNp);
        assert_eq!(a.key(), b.key());
        b.write_vt = Some(0.5);
        assert_ne!(a.key(), b.key());
        let mut c = Config::new(32, 32, CellFlavor::GcSiSiNp);
        c.wwlls = true;
        assert_ne!(a.key(), c.key());
        assert_ne!(a.key(), Config::new(32, 32, CellFlavor::GcOsOs).key());
    }
}
