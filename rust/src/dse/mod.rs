//! Design-space exploration (paper §V-E): configuration sweeps, shmoo
//! evaluation against workload demands (Fig. 10), Pareto fronts, and
//! the future-work gradient-descent co-optimizer (§VI).

use crate::characterize::BankPerf;
use crate::compiler::{CellFlavor, Config};
use crate::workloads::Demand;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct Evaluated {
    pub config: Config,
    pub perf: BankPerf,
    pub area_um2: f64,
}

/// Shmoo verdict for (config, demand).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Pass,
    /// Too slow for the demanded read frequency.
    FailFreq,
    /// Retention shorter than the demanded lifetime.
    FailRetention,
    /// Electrically non-functional (no sense margin).
    FailMargin,
}

impl Verdict {
    pub fn pass(&self) -> bool {
        *self == Verdict::Pass
    }
    pub fn glyph(&self) -> char {
        match self {
            Verdict::Pass => 'P',
            Verdict::FailFreq => 'f',
            Verdict::FailRetention => 'r',
            Verdict::FailMargin => 'x',
        }
    }
}

/// Evaluate one (design, demand) pair — the Fig. 10 cell.
pub fn shmoo_verdict(e: &Evaluated, d: &Demand) -> Verdict {
    if !e.perf.functional {
        Verdict::FailMargin
    } else if e.perf.f_op_hz < d.read_freq_hz {
        Verdict::FailFreq
    } else if e.perf.retention_s < d.lifetime_s {
        Verdict::FailRetention
    } else {
        Verdict::Pass
    }
}

/// The Fig. 10 configuration axis: square banks 16x16 .. 128x128.
pub fn fig10_configs(flavor: CellFlavor) -> Vec<Config> {
    [16usize, 32, 64, 96, 128]
        .iter()
        .map(|&n| Config::new(n, n, flavor))
        .collect()
}

/// Pareto front (maximize f_op, maximize retention, minimize area).
pub fn pareto(points: &[Evaluated]) -> Vec<usize> {
    let dominates = |a: &Evaluated, b: &Evaluated| {
        let ge = a.perf.f_op_hz >= b.perf.f_op_hz
            && a.perf.retention_s >= b.perf.retention_s
            && a.area_um2 <= b.area_um2;
        let gt = a.perf.f_op_hz > b.perf.f_op_hz
            || a.perf.retention_s > b.perf.retention_s
            || a.area_um2 < b.area_um2;
        ge && gt
    };
    (0..points.len())
        .filter(|&i| !points.iter().enumerate().any(|(j, p)| j != i && dominates(p, &points[i])))
        .collect()
}

/// Co-optimization target (paper §VI: "area-delay-power co-optimization
/// ... leveraging machine learning algorithms (e.g., gradient descent)").
#[derive(Debug, Clone, Copy)]
pub struct CostWeights {
    pub w_delay: f64,
    pub w_area: f64,
    pub w_power: f64,
    /// Hard frequency floor (Hz); configs below it get +inf cost.
    pub f_min_hz: f64,
    /// Hard lifetime floor (s).
    pub t_retain_min_s: f64,
}

pub fn cost(w: &CostWeights, e: &Evaluated) -> f64 {
    if e.perf.f_op_hz < w.f_min_hz || e.perf.retention_s < w.t_retain_min_s || !e.perf.functional {
        return f64::INFINITY;
    }
    w.w_delay / e.perf.f_op_hz * 1e9 + w.w_area * e.area_um2 / 1e4 + w.w_power * e.perf.leakage_w * 1e6
}

/// Coordinate-descent co-optimizer over (size exponent, write VT).
/// `eval` maps a Config to an Evaluated (the caller decides whether
/// that's analytical or transient-backed).
pub fn optimize<F>(
    flavor: CellFlavor,
    weights: &CostWeights,
    mut eval: F,
) -> crate::Result<(Evaluated, usize)>
where
    F: FnMut(&Config) -> crate::Result<Evaluated>,
{
    let sizes = [16usize, 32, 64, 96, 128];
    let vts: Vec<Option<f64>> = vec![None, Some(0.38), Some(0.45), Some(0.52), Some(0.60)];
    let mut si = 1usize;
    let mut vi = 0usize;
    let mk = |si: usize, vi: usize| {
        let mut c = Config::new(sizes[si], sizes[si], flavor);
        c.write_vt = vts[vi];
        c
    };
    let mut best = eval(&mk(si, vi))?;
    let mut best_cost = cost(weights, &best);
    let mut evals = 1usize;
    // coordinate descent until no single-step move improves
    loop {
        let mut improved = false;
        let moves: Vec<(usize, usize)> = [
            (si.wrapping_sub(1), vi),
            (si + 1, vi),
            (si, vi.wrapping_sub(1)),
            (si, vi + 1),
        ]
        .into_iter()
        .filter(|&(a, b)| a < sizes.len() && b < vts.len())
        .collect();
        for (a, b) in moves {
            let e = eval(&mk(a, b))?;
            evals += 1;
            let c = cost(weights, &e);
            if c < best_cost {
                best_cost = c;
                best = e;
                si = a;
                vi = b;
                improved = true;
                break;
            }
        }
        if !improved || evals > 40 {
            break;
        }
    }
    anyhow::ensure!(best_cost.is_finite(), "no feasible configuration found");
    Ok((best, evals))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::characterize::BankPerf;

    fn fake(f: f64, ret: f64, area: f64) -> Evaluated {
        Evaluated {
            config: Config::new(32, 32, CellFlavor::GcSiSiNp),
            perf: BankPerf {
                f_read_hz: f,
                f_write_hz: f,
                f_op_hz: f,
                bandwidth_bps: 64.0 * f,
                retention_s: ret,
                leakage_w: 1e-6,
                e_read_j: 1e-12,
                t_decoder_s: 1e-10,
                t_cell_read_s: 1e-10,
                stored_one_v: 0.6,
                functional: true,
            },
            area_um2: area,
        }
    }

    #[test]
    fn verdict_logic() {
        use crate::workloads::{profile, CacheLevel, H100, TASKS};
        let d = profile(&TASKS[0], CacheLevel::L1, &H100);
        let fast = fake(d.read_freq_hz * 2.0, 1.0, 1e4);
        let slow = fake(d.read_freq_hz * 0.5, 1.0, 1e4);
        let leaky = fake(d.read_freq_hz * 2.0, d.lifetime_s * 0.5, 1e4);
        assert_eq!(shmoo_verdict(&fast, &d), Verdict::Pass);
        assert_eq!(shmoo_verdict(&slow, &d), Verdict::FailFreq);
        assert_eq!(shmoo_verdict(&leaky, &d), Verdict::FailRetention);
    }

    #[test]
    fn pareto_removes_dominated() {
        let pts = vec![
            fake(1e9, 1e-3, 1e4),
            fake(0.5e9, 0.5e-3, 2e4), // dominated by the first
            fake(2e9, 1e-4, 3e4),     // faster but leakier/larger
        ];
        let front = pareto(&pts);
        assert!(front.contains(&0));
        assert!(!front.contains(&1));
        assert!(front.contains(&2));
    }

    #[test]
    fn optimizer_converges_on_synthetic_landscape() {
        // cost favors mid-size and higher VT: check it walks there
        let w = CostWeights { w_delay: 1.0, w_area: 1.0, w_power: 1.0, f_min_hz: 0.0, t_retain_min_s: 0.0 };
        let (best, evals) = optimize(CellFlavor::GcSiSiNp, &w, |cfg| {
            let n = cfg.word_size as f64;
            let vt = cfg.write_vt.unwrap_or(0.45);
            // synthetic bowl around n=64, vt=0.52
            let f = 1e9 / (1.0 + ((n - 64.0) / 64.0).powi(2) + (vt - 0.52).abs());
            Ok(fake(f, 1e-3, n * n))
        })
        .unwrap();
        assert!(evals >= 3);
        assert!(best.config.word_size >= 32);
    }

    #[test]
    fn fig10_axis_is_five_square_configs() {
        let cfgs = fig10_configs(CellFlavor::GcSiSiNp);
        assert_eq!(cfgs.len(), 5);
        assert!(cfgs.iter().all(|c| c.word_size == c.num_words));
    }
}
