//! # OpenGCRAM-RS
//!
//! Reproduction of *"OpenGCRAM: An Open-Source Gain Cell Compiler Enabling
//! Design-Space Exploration for AI Workloads"* as a three-layer
//! rust + JAX/Pallas stack.
//!
//! The crate is the L3 layer: the memory **compiler** itself (technology
//! files, netlist and layout generation, GDSII export, DRC, LVS), the
//! **characterizer** (analytical logical-effort models plus transient
//! characterization via AOT-compiled XLA artifacts executed through
//! PJRT), and the **design-space explorer** driven by an AI-workload
//! profiler.  Python/JAX runs only at build time (`make artifacts`);
//! requests execute either the native in-process EKV solver (default,
//! nothing on disk) or the pre-compiled HLO artifacts through PJRT.
//!
//! Module map (see DESIGN.md §3 for the full inventory):
//!
//! * [`tech`] — process design kits: layers, design rules, device cards.
//! * [`netlist`] — SPICE IR, emitter and parser.
//! * [`layout`] — geometry kernel, cell generators, bank floorplan, GDS.
//! * [`drc`] — design-rule checker.
//! * [`lvs`] — layout-vs-schematic (extraction + graph compare).
//! * [`sim`] — native MNA transient simulator (HSPICE stand-in).
//! * [`runtime`] — pluggable execution backends behind
//!   [`runtime::ExecBackend`]: the native batched EKV solver
//!   ([`runtime::native`], always available), the PJRT
//!   loader/executor for `artifacts/*.hlo.txt` (optional
//!   acceleration, armed with a pjrt→native failover breaker under
//!   `auto`), and deterministic fault injection for chaos runs
//!   ([`runtime::fault`]).
//! * [`coordinator`] — batched DSE job execution over the runtime,
//!   with retry/backoff and batch-bisection fault quarantine.
//! * [`compiler`] — the GCRAM bank compiler (the paper's contribution).
//! * [`characterize`] — area/delay/power/retention characterization,
//!   batch-first: `CharPlan` plan/finish decomposition plus
//!   `characterize_all`, which packs many designs' transient points
//!   into shared padded artifact batches through the coordinator.
//! * [`workloads`] — GainSight-like AI workload profiler (Table I).
//! * [`dse`] — sweeps, shmoo plots, Pareto fronts, co-optimization.
//! * [`compose`] — workload-driven heterogeneous composition: one
//!   cross-flavor mega-sweep, per-demand feasibility/Pareto/min-cost
//!   selection, per-level bank portfolio.
//! * [`variation`] — Monte-Carlo variation engine: sampled per-instance
//!   perturbations ride the batched characterizer as one mega-batch and
//!   reduce to Wilson-bounded yield estimates for yield-aware DSE.
//! * [`service`] — the persistent compiler service: a [`service::Session`]
//!   owns the runtime, cache hierarchy and warm flatten memos, the
//!   former subcommand bodies are request handlers borrowing it, and
//!   [`service::serve`] is the JSON-lines Unix-socket front end with
//!   cross-request batch packing.
//! * [`store`] — content-addressed on-disk evaluation store (config +
//!   tech + window resolution + format version), validated on load,
//!   shared across process lifetimes — the disk tier under
//!   [`dse::EvalCache`].
//! * [`report`] — table/CSV renderers for the paper's figures.
//! * [`cli`] — strict flag parsing shared by the `opengcram` binary.
//! * [`util`] — JSON parsing, PRNG, timing (offline-registry stand-ins).

pub mod characterize;
pub mod cli;
pub mod compiler;
pub mod compose;
pub mod coordinator;
pub mod drc;
pub mod dse;
pub mod layout;
pub mod lvs;
pub mod netlist;
pub mod report;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod store;
pub mod tech;
pub mod util;
pub mod variation;
pub mod workloads;

/// Crate-wide result type (anyhow is in the offline registry closure).
pub type Result<T> = anyhow::Result<T>;
