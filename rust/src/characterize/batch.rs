//! Per-engine [`BatchExec`] implementations over
//! [`crate::runtime::engines`] — the bridge between the job
//! [`crate::coordinator`] and the AOT artifacts.
//!
//! # Homogeneity keys
//!
//! Jobs carry their transient window; the executors re-group whatever
//! batch the coordinator hands them into *runnable* homogeneous calls:
//! points in one artifact execution must share the window (the dt
//! schedule tensor is per-batch, not per-row) and, for reads, the
//! `pull_up` flavor (the RWL waveform is per-batch).  This makes
//! `read_op`'s "mixed read flavors in one batch" `ensure` an invariant
//! the batcher upholds instead of a caller footgun.  The keys are
//! [`write_key`] (window bits) and [`read_key`] (`pull_up` over the
//! window bits); because [`super::CharPlan::with_resolution`]
//! snaps windows onto the quantization bucket grid *before* the jobs
//! are emitted, the window bits the keys see are already the bucket
//! values — designs in one bucket group across the whole sweep with no
//! extra logic here.
//!
//! # Padding and occupancy
//!
//! One artifact execution holds up to `cap` points (the manifest batch
//! size; short batches are zero-padded by the engines).  A group of
//! `n` homogeneous jobs therefore costs [`calls_for`]`(n, cap)` =
//! `ceil(n / cap)` executions, and a whole sweep costs the sum of that
//! over its homogeneity groups — the occupancy model EXPERIMENTS.md
//! tabulates and the fig10/perf benches assert.  Retention points have
//! neither a window nor a flavor (fixed log-time grid; the threshold
//! is a per-row stimulus), so they always pack to full occupancy: a
//! shmoo axis issues `ceil(points / batch)` retention executions, not
//! one per point.

use crate::coordinator::BatchExec;
use crate::runtime::{engines, SharedRuntime};

/// One write-transient job: the design point plus its simulation
/// window.  Jobs with bit-equal windows share an artifact execution —
/// with window quantization the window is a bucket-grid value, so
/// "bit-equal" means "same bucket", not "same geometry".
#[derive(Debug, Clone)]
pub struct WriteJob {
    pub pt: engines::WritePoint,
    pub window_s: f64,
}

/// One read-transient job; groups by `(pull_up, window)` where the
/// window is the (possibly bucket-quantized) plan window.
#[derive(Debug, Clone)]
pub struct ReadJob {
    pub pt: engines::ReadPoint,
    pub window_s: f64,
}

/// One retention job; the retention artifact runs a fixed log-time
/// grid, so every job is group-compatible.
#[derive(Debug, Clone)]
pub struct RetentionJob {
    pub pt: engines::RetentionPoint,
}

/// Homogeneity key of a write job: the (bucket-quantized) window bits.
/// Jobs with equal keys share an artifact execution.
pub fn write_key(j: &WriteJob) -> u128 {
    j.window_s.to_bits() as u128
}

/// Homogeneity key of a read job: `pull_up` in the high bits (the
/// waveform split) and the (bucket-quantized) window bits below.
pub fn read_key(j: &ReadJob) -> u128 {
    ((j.pt.pull_up as u128) << 64) | j.window_s.to_bits() as u128
}

/// Partition job indices into runnable groups by `key`, preserving
/// submission order inside each group and first-seen order across
/// groups.  The scatter side of the executors depends on every index
/// appearing in exactly one group.
pub(crate) fn group_indices<J>(jobs: &[J], mut key: impl FnMut(&J) -> u128) -> Vec<Vec<usize>> {
    let mut map: std::collections::HashMap<u128, usize> = std::collections::HashMap::new();
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for (i, j) in jobs.iter().enumerate() {
        let g = *map.entry(key(j)).or_insert_with(|| {
            groups.push(Vec::new());
            groups.len() - 1
        });
        groups[g].push(i);
    }
    groups
}

/// Expected artifact executions for `points` homogeneous jobs at batch
/// capacity `cap` — the occupancy model documented in EXPERIMENTS.md.
pub fn calls_for(points: usize, cap: usize) -> usize {
    let cap = cap.max(1);
    (points + cap - 1) / cap
}

/// Run `jobs` as grouped, cap-chunked engine calls and scatter the
/// results back to submission order.
fn run_grouped<J, R: Clone>(
    jobs: &[J],
    cap: usize,
    key: impl FnMut(&J) -> u128,
    mut call: impl FnMut(&[usize]) -> crate::Result<Vec<R>>,
) -> crate::Result<Vec<R>> {
    let mut out: Vec<Option<R>> = vec![None; jobs.len()];
    for group in group_indices(jobs, key) {
        for chunk in group.chunks(cap.max(1)) {
            let res = call(chunk)?;
            anyhow::ensure!(
                res.len() == chunk.len(),
                "engine returned {} results for {} points",
                res.len(),
                chunk.len()
            );
            for (&i, r) in chunk.iter().zip(res) {
                out[i] = Some(r);
            }
        }
    }
    Ok(out.into_iter().map(|r| r.expect("grouping covers every job")).collect())
}

/// Write-engine executor: one `write_rows` per (window, cap-chunk).
/// Results are per-row [`engines::RowResult`]s — degenerate or
/// NaN-poisoned rows come back as `Err(RowFault)` without failing the
/// co-batched rows.
pub struct WriteExec<'rt> {
    rt: &'rt SharedRuntime,
    cap: usize,
}

impl<'rt> WriteExec<'rt> {
    pub fn new(rt: &'rt SharedRuntime) -> crate::Result<WriteExec<'rt>> {
        Ok(WriteExec { rt, cap: rt.batch_cap("write")? })
    }
}

impl BatchExec<WriteJob, engines::RowResult<engines::WriteResult>> for WriteExec<'_> {
    fn run(
        &mut self,
        jobs: &[WriteJob],
    ) -> crate::Result<Vec<engines::RowResult<engines::WriteResult>>> {
        run_grouped(jobs, self.cap, write_key, |chunk| {
            let pts: Vec<engines::WritePoint> = chunk.iter().map(|&i| jobs[i].pt.clone()).collect();
            self.rt.with(|r| engines::write_rows(r, &pts, jobs[chunk[0]].window_s))
        })
    }
    fn max_batch(&self) -> usize {
        self.cap
    }
}

/// Read-engine executor: one `read_op` per (pull_up, window, cap-chunk)
/// — the split that turns `read_op`'s homogeneity `ensure` into a
/// batcher invariant.
pub struct ReadExec<'rt> {
    rt: &'rt SharedRuntime,
    cap: usize,
}

impl<'rt> ReadExec<'rt> {
    pub fn new(rt: &'rt SharedRuntime) -> crate::Result<ReadExec<'rt>> {
        Ok(ReadExec { rt, cap: rt.batch_cap("read")? })
    }
}

impl BatchExec<ReadJob, engines::RowResult<engines::ReadResult>> for ReadExec<'_> {
    fn run(
        &mut self,
        jobs: &[ReadJob],
    ) -> crate::Result<Vec<engines::RowResult<engines::ReadResult>>> {
        run_grouped(jobs, self.cap, read_key, |chunk| {
            let pts: Vec<engines::ReadPoint> = chunk.iter().map(|&i| jobs[i].pt.clone()).collect();
            self.rt.with(|r| engines::read_rows(r, &pts, jobs[chunk[0]].window_s))
        })
    }
    fn max_batch(&self) -> usize {
        self.cap
    }
}

/// Retention-engine executor: every job packs; calls = ceil(n / cap).
pub struct RetentionExec<'rt> {
    rt: &'rt SharedRuntime,
    cap: usize,
}

impl<'rt> RetentionExec<'rt> {
    pub fn new(rt: &'rt SharedRuntime) -> crate::Result<RetentionExec<'rt>> {
        Ok(RetentionExec { rt, cap: rt.batch_cap("retention")? })
    }
}

impl BatchExec<RetentionJob, engines::RowResult<engines::RetentionResult>> for RetentionExec<'_> {
    fn run(
        &mut self,
        jobs: &[RetentionJob],
    ) -> crate::Result<Vec<engines::RowResult<engines::RetentionResult>>> {
        run_grouped(jobs, self.cap, |_| 0, |chunk| {
            let pts: Vec<engines::RetentionPoint> =
                chunk.iter().map(|&i| jobs[i].pt.clone()).collect();
            self.rt.with(|r| engines::retention_rows(r, &pts))
        })
    }
    fn max_batch(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::sg40;
    use crate::util::rng::{check, Rng};

    #[test]
    fn group_indices_is_a_partition_preserving_order() {
        check("grouping partition", 20, |rng: &mut Rng| {
            let n = 1 + rng.below(300);
            let keys: Vec<u128> = (0..n).map(|_| rng.below(5) as u128).collect();
            let groups = group_indices(&keys, |k| *k);
            // every index appears exactly once
            let mut seen = vec![false; n];
            for g in &groups {
                // homogeneous and ascending inside each group
                assert!(g.windows(2).all(|w| w[0] < w[1]));
                assert!(g.iter().all(|&i| keys[i] == keys[g[0]]));
                for &i in g {
                    assert!(!seen[i], "index {i} grouped twice");
                    seen[i] = true;
                }
            }
            assert!(seen.into_iter().all(|s| s), "index lost by grouping");
        });
    }

    #[test]
    fn read_key_splits_mixed_pull_up_flavors() {
        // regression scaffold for the read_op "mixed read flavors"
        // bail: NP (pull-up) and NN/OS (pull-down) points sharing a
        // window must land in different groups
        let t = sg40();
        let mk = |pull_up: bool, window_s: f64| ReadJob {
            pt: engines::ReadPoint {
                read_card: *t.card("si_nmos"),
                read_wl: 3.5,
                sn0: 0.05,
                sn_unsel: 0.0,
                rows: 32,
                c_sn: 1.2e-15,
                c_rbl: 20e-15,
                c_rwl_sn: 0.1e-15,
                g_rbl_leak: 1e-9,
                vdd: 1.1,
                pull_up,
            },
            window_s,
        };
        let jobs = vec![mk(true, 6e-9), mk(false, 6e-9), mk(true, 6e-9), mk(false, 8e-9)];
        let groups = group_indices(&jobs, read_key);
        assert_eq!(groups.len(), 3, "{groups:?}");
        assert_eq!(groups[0], vec![0, 2], "pull-up points share one call");
        assert_eq!(groups[1], vec![1], "pull-down split off");
        assert_eq!(groups[2], vec![3], "different window split off");
        for g in &groups {
            let pu = jobs[g[0]].pt.pull_up;
            assert!(g.iter().all(|&i| jobs[i].pt.pull_up == pu), "mixed flavors in a group");
        }
    }

    #[test]
    fn occupancy_model() {
        assert_eq!(calls_for(0, 256), 0);
        assert_eq!(calls_for(1, 256), 1);
        assert_eq!(calls_for(256, 256), 1);
        assert_eq!(calls_for(257, 256), 2);
        assert_eq!(calls_for(1000, 256), 4);
        assert_eq!(calls_for(5, 0), 5, "degenerate cap clamps to 1");
    }

    #[test]
    fn run_grouped_scatters_back_to_submission_order() {
        // identity over a shuffled key pattern: results must come back
        // positionally even though execution is grouped
        let jobs: Vec<u128> = vec![3, 1, 3, 2, 1, 3, 2, 0];
        let res = run_grouped(&jobs, 2, |j| *j, |chunk| {
            assert!(chunk.len() <= 2);
            Ok(chunk.iter().map(|&i| jobs[i] * 10 + i as u128).collect())
        })
        .unwrap();
        let want: Vec<u128> = jobs.iter().enumerate().map(|(i, j)| j * 10 + i as u128).collect();
        assert_eq!(res, want);
    }
}
