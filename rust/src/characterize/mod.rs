//! Bank characterization: the OpenGCRAM "area, delay and power
//! simulations" (paper §V-B/C/D).
//!
//! Two fidelity levels, mirroring the paper's GEMTOO-vs-OpenGCRAM
//! distinction:
//! * [`analytical`] — logical-effort + RC estimates only (GEMTOO-class,
//!   fast, no simulation);
//! * [`characterize`] — cell-level transients executed on the AOT XLA
//!   artifacts through the PJRT runtime (HSPICE-class for the critical
//!   path) combined with analytical periphery delays.

use crate::compiler::{Bank, CellFlavor};
use crate::runtime::{engines, Runtime};
use crate::sim;
use crate::tech::Tech;
use crate::util::ceil_log2;

/// Sense-amp offset margin (V) and timing guardband.
const SENSE_MARGIN_V: f64 = 0.05;
const GUARDBAND: f64 = 1.15;
/// Replica delay-chain stage delay (s), x1 inverter FO4-ish.
pub const TAU_STAGE: f64 = 25e-12;

/// Characterization result for one bank.
#[derive(Debug, Clone, Copy)]
pub struct BankPerf {
    pub f_read_hz: f64,
    pub f_write_hz: f64,
    /// Operating frequency = min(read, write) (paper: read-limited).
    pub f_op_hz: f64,
    /// Effective read+write bandwidth (bits/s); SRAM halves (shared port).
    pub bandwidth_bps: f64,
    pub retention_s: f64,
    pub leakage_w: f64,
    /// Dynamic energy per read access (J).
    pub e_read_j: f64,
    pub t_decoder_s: f64,
    pub t_cell_read_s: f64,
    pub stored_one_v: f64,
    /// true if the stored levels/sense margins resolve (shmoo pass).
    pub functional: bool,
}

/// GEMTOO-class analytical estimate (no simulation).  The ablation
/// bench quantifies its deviation from the transient path (paper
/// reports up to 15 % for GEMTOO).
pub fn analytical(tech: &Tech, bank: &Bank) -> BankPerf {
    let vdd = tech.vdd;
    let p = &bank.parasitics;
    let rows = bank.config.rows();
    let t_dec = decoder_delay(tech, rows);
    let t_wl = 0.38 * p.r_wl * p.c_wl + 20e-12;
    // cell read current estimate: gate at the driving stored level
    // (0 for the pull-up PMOS read, vdd for pull-down NMOS reads)
    let rd = read_card(tech, bank.config.flavor);
    let i_cell = if bank.config.flavor.pull_up_read() {
        sim::ids_card(&rd.0, rd.1, vdd / 2.0, 0.0, vdd).abs()
    } else {
        sim::ion(&rd.0, rd.1, vdd) * 0.4
    };
    // differential SRAM senses at ~150 mV; single-ended GC needs the
    // full excursion to the reference (paper SS V-C)
    let swing = if bank.config.flavor == CellFlavor::Sram6t {
        0.15
    } else {
        vdd / 2.0 + SENSE_MARGIN_V
    };
    let t_cell = p.c_rbl * swing / i_cell;
    let t_sense = 60e-12;
    // same delay-chain quantization as the transient-backed path
    let stages = ((t_wl + t_cell + t_sense) / TAU_STAGE).ceil() + 2.0;
    let t_ctrl = stages * TAU_STAGE;
    let mux_penalty = if bank.config.mux_factor() > 1 { 40e-12 } else { 0.0 };
    let t_read = (t_dec + t_wl + t_ctrl.max(t_cell + t_sense) + mux_penalty) * GUARDBAND;
    let wr_drv = tech.card("si_nmos");
    let t_write = (t_dec + t_wl + 3.0 * p.c_wbl * vdd / sim::ion(wr_drv, 4.0, vdd) + 50e-12) * GUARDBAND;
    let f_read = 1.0 / t_read;
    let f_write = 1.0 / t_write;
    let f_op = f_read.min(f_write);
    let leak = leakage(tech, bank);
    let sn_one = vdd - tech.card("si_nmos").vt;
    BankPerf {
        f_read_hz: f_read,
        f_write_hz: f_write,
        f_op_hz: f_op,
        bandwidth_bps: bandwidth(bank.config.flavor, bank.config.word_size, f_op),
        retention_s: analytical_retention(tech, bank),
        leakage_w: leak,
        e_read_j: p.c_rbl * vdd * vdd * bank.config.word_size as f64,
        t_decoder_s: t_dec,
        t_cell_read_s: t_cell,
        stored_one_v: sn_one,
        functional: true,
    }
}

/// Full characterization: write + read + retention transients on the
/// XLA artifacts, analytical periphery, delay-chain quantization.
pub fn characterize(tech: &Tech, rt: &Runtime, bank: &Bank) -> crate::Result<BankPerf> {
    // the 6T SRAM baseline reads differentially (BL/BLb) -- the GC
    // read template does not model it; the calibrated analytical model
    // is the SRAM reference (its differential sense needs only ~150 mV
    // of swing, which is why SRAM is faster than GCRAM in Fig. 7a)
    if bank.config.flavor == CellFlavor::Sram6t {
        return Ok(analytical(tech, bank));
    }
    let vdd = tech.vdd;
    let cfg = &bank.config;
    let p = &bank.parasitics;
    let flavor = cfg.flavor;
    let rows = cfg.rows();

    let (wr_card, wr_wl) = write_card(tech, flavor, cfg.write_vt);
    let (rd_card, rd_wl) = read_card(tech, flavor);
    let v_wwl = if cfg.wwlls { vdd + 0.4 } else { vdd };

    // --- write transient -------------------------------------------------
    let wr_pts = vec![
        engines::WritePoint {
            write_card: wr_card,
            write_wl: wr_wl,
            drv_p: (*tech.card("si_pmos"), 8.0),
            drv_n: (*tech.card("si_nmos"), 4.0),
            c_sn: p.c_sn,
            c_wbl: p.c_wbl,
            c_wwl_sn: p.c_wwl_sn,
            g_wbl_leak: 1e-9,
            vdd,
            v_wwl,
            one: true,
            sn0: 0.0,
        },
    ];
    // window scales with the WBL RC
    let wr_window = (40.0 * p.c_wbl * vdd / sim::ion(&wr_card, 4.0, vdd)).max(4e-9);
    let wr = engines::write_op(rt, &wr_pts, wr_window)?;
    let stored_one = wr[0].sn_final as f64;
    let t_write_cell = wr[0].t_wr;

    // --- read transient: stored '0' vs stored '1' discrimination ---------
    let pull_up = flavor.pull_up_read();
    let mk_read = |sn0: f64| engines::ReadPoint {
        read_card: rd_card,
        read_wl: rd_wl,
        sn0,
        sn_unsel: if pull_up { stored_one } else { 0.0 },
        rows,
        c_sn: p.c_sn,
        c_rbl: p.c_rbl,
        c_rwl_sn: p.c_rwl_sn,
        g_rbl_leak: 1e-9,
        vdd,
        pull_up,
    };
    let stored_zero = 0.05;
    let rd_window = (60.0 * p.c_rbl * 0.55 / sim::ion(&rd_card, rd_wl, vdd)).max(6e-9);
    let rd = engines::read_op(rt, &[mk_read(stored_zero), mk_read(stored_one)], rd_window)?;
    // driving case crosses first; opposite case must cross later (margin)
    let (t_drive, t_hold) = if pull_up {
        (rd[0].t_rise, rd[1].t_rise)
    } else {
        (rd[1].t_fall, rd[0].t_fall)
    };
    let discriminates = t_hold > 1.3 * t_drive;
    let t_cell_read = t_drive;

    // --- retention ---------------------------------------------------------
    let ret = engines::retention(
        rt,
        &[engines::RetentionPoint {
            write_card: wr_card,
            write_wl: wr_wl,
            c_sn: p.c_sn,
            g_gate_leak: gate_leak(flavor),
            i_disturb: 0.0,
            v0: stored_one.max(0.05),
            vth: 0.0, // relative threshold: decay to half the stored level
        }],
    )?;
    let retention_s = if flavor == CellFlavor::Sram6t { f64::INFINITY } else { ret[0].t_retain };

    // --- compose the cycle --------------------------------------------------
    let t_dec = decoder_delay(tech, rows);
    let t_wl = 0.38 * p.r_wl * p.c_wl + 20e-12;
    let t_sense = 60e-12;
    // replica delay chain quantizes the sense window (Fig. 7a step)
    let stages = ((t_wl + t_cell_read + t_sense) / TAU_STAGE).ceil() as usize + 2;
    let t_ctrl = stages as f64 * TAU_STAGE;
    let mux_penalty = if cfg.mux_factor() > 1 { 40e-12 } else { 0.0 };
    let t_read = (t_dec + t_wl + t_ctrl.max(t_cell_read + t_sense) + mux_penalty) * GUARDBAND;
    let t_write = (t_dec + t_wl + t_write_cell + 50e-12) * GUARDBAND;
    let f_read = 1.0 / t_read;
    let f_write = 1.0 / t_write;
    let f_op = f_read.min(f_write);

    let functional = discriminates && stored_one > sense_floor(vdd);

    Ok(BankPerf {
        f_read_hz: f_read,
        f_write_hz: f_write,
        f_op_hz: f_op,
        bandwidth_bps: bandwidth(flavor, cfg.word_size, f_op),
        retention_s,
        leakage_w: leakage(tech, bank),
        e_read_j: p.c_rbl * vdd * vdd * cfg.word_size as f64,
        t_decoder_s: t_dec,
        t_cell_read_s: t_cell_read,
        stored_one_v: stored_one,
        functional,
    })
}

/// Logical-effort decoder + WL driver delay.
pub fn decoder_delay(tech: &Tech, rows: usize) -> f64 {
    let stages = ceil_log2(rows).max(1) as f64;
    let tau = 18e-12 * 1.1 / tech.vdd;
    // nand2 effort 4/3, fanout ~3 per stage, + driver stage
    stages * tau * (4.0 / 3.0) * 2.2 + 2.0 * tau * 3.0
}

/// Effective bandwidth (paper Fig. 7b): dual-port GC reads and writes
/// concurrently; single-port SRAM shares, halving each.
pub fn bandwidth(flavor: CellFlavor, word_size: usize, f_op: f64) -> f64 {
    let w = word_size as f64;
    match flavor {
        CellFlavor::Sram6t => w * f_op, // f/2 read + f/2 write
        _ => 2.0 * w * f_op,
    }
}

/// Leakage power (paper Fig. 7c): SRAM cells have VDD->GND subthreshold
/// paths; gain cells have none (storage is a floating gate), so only
/// the periphery leaks.
pub fn leakage(tech: &Tech, bank: &Bank) -> f64 {
    let vdd = tech.vdd;
    let cells = bank.config.bits() as f64;
    let cell_leak = match bank.config.flavor {
        CellFlavor::Sram6t => {
            let n = sim::ioff(tech.card("si_nmos"), 3.0, vdd);
            let p = sim::ioff(tech.card("si_pmos"), 2.5, vdd);
            (n + p) * vdd
        }
        // gain cell: no static path; only junction leakage ~ 0
        _ => 0.0,
    };
    // periphery: rough inverter-equivalent count
    let periph_gates = (bank.config.rows() * 3 + bank.config.word_size * 12) as f64;
    let periph_leak = periph_gates
        * (sim::ioff(tech.card("si_nmos"), 2.75, vdd) + sim::ioff(tech.card("si_pmos"), 4.5, vdd))
        * vdd
        * 0.5;
    cells * cell_leak + periph_leak
}

fn analytical_retention(tech: &Tech, bank: &Bank) -> f64 {
    if bank.config.flavor == CellFlavor::Sram6t {
        return f64::INFINITY;
    }
    let (wr, wl) = write_card(tech, bank.config.flavor, bank.config.write_vt);
    let i = sim::ioff(&wr, wl, 0.6) + gate_leak(bank.config.flavor) * 0.6;
    bank.parasitics.c_sn * 0.3 / i.max(1e-30)
}

/// Cards per flavor (write transistor may carry a VT override).
pub fn write_card(tech: &Tech, flavor: CellFlavor, vt: Option<f64>) -> (crate::tech::DeviceCard, f64) {
    let base = match flavor {
        CellFlavor::GcOsOs => *tech.card("os_nmos"),
        _ => *tech.card("si_nmos"),
    };
    let card = vt.map(|v| base.with_vt(v)).unwrap_or(base);
    (card, if flavor == CellFlavor::GcOsOs { 1.0 } else { 2.5 })
}

pub fn read_card(tech: &Tech, flavor: CellFlavor) -> (crate::tech::DeviceCard, f64) {
    match flavor {
        CellFlavor::GcSiSiNp => (*tech.card("si_pmos_hvt"), 3.5),
        CellFlavor::GcOsOs => (*tech.card("os_nmos"), 1.2),
        _ => (*tech.card("si_nmos"), 3.5),
    }
}

fn gate_leak(flavor: CellFlavor) -> f64 {
    match flavor {
        CellFlavor::GcOsOs => 1e-17, // thick BEOL gate dielectric
        _ => 1e-16,
    }
}

fn sense_floor(vdd: f64) -> f64 {
    0.35 * vdd
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, Config};
    use crate::tech::sg40;

    #[test]
    fn analytical_scales_with_size() {
        let t = sg40();
        let small = compile(&t, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap();
        let large = compile(&t, &Config::new(64, 256, CellFlavor::GcSiSiNp)).unwrap();
        let ps = analytical(&t, &small);
        let pl = analytical(&t, &large);
        assert!(ps.f_op_hz > pl.f_op_hz, "small banks are faster");
        assert!(ps.f_op_hz > 1e8 && ps.f_op_hz < 5e9, "{}", ps.f_op_hz);
    }

    #[test]
    fn sram_leaks_gc_does_not() {
        let t = sg40();
        let sr = compile(&t, &Config::new(64, 64, CellFlavor::Sram6t)).unwrap();
        let gc = compile(&t, &Config::new(64, 64, CellFlavor::GcSiSiNp)).unwrap();
        let l_sr = leakage(&t, &sr);
        let l_gc = leakage(&t, &gc);
        assert!(l_sr > 5.0 * l_gc, "sram {l_sr} vs gc {l_gc}");
    }

    #[test]
    fn bandwidth_policy() {
        assert_eq!(bandwidth(CellFlavor::Sram6t, 32, 1e9), 32e9);
        assert_eq!(bandwidth(CellFlavor::GcSiSiNp, 32, 1e9), 64e9);
    }

    #[test]
    fn decoder_delay_grows_with_rows() {
        let t = sg40();
        assert!(decoder_delay(&t, 256) > decoder_delay(&t, 16));
    }
}
