//! Bank characterization: the OpenGCRAM "area, delay and power
//! simulations" (paper §V-B/C/D).
//!
//! Two fidelity levels, mirroring the paper's GEMTOO-vs-OpenGCRAM
//! distinction:
//! * [`analytical`] — logical-effort + RC estimates only (GEMTOO-class,
//!   fast, no simulation);
//! * [`characterize`] — cell-level transients executed on an
//!   [`ExecBackend`] (the native in-process EKV solver, or the AOT XLA
//!   artifacts through the PJRT runtime; HSPICE-class for the critical
//!   path) combined with analytical periphery delays.
//!
//! Characterization is *batch-first*: a [`CharPlan`] decomposes one
//! design into its transient jobs (plan) and folds the results back
//! into a [`BankPerf`] (finish); [`characterize`] runs one plan with
//! singleton batches, while [`characterize_all`] packs the jobs of
//! many designs into shared padded artifact batches through the
//! [`crate::coordinator`] — the DSE sweep cost is then paid per batch,
//! not per design.
//!
//! # Window quantization
//!
//! Write/read transient points can only share an artifact execution
//! when they share the simulation window (the dt-schedule tensor is
//! per-batch, not per-row), so a sweep that varies geometry — and with
//! it `c_wbl`/`c_rbl`, and with them the windows — would degenerate to
//! one execution per design.  [`CharPlan::with_resolution`] therefore
//! snaps each computed window *up* to the ceiling of a geometric
//! bucket grid via [`quantize_window`]: at resolution `r`, bucket `k`
//! sits at `(1+r)^k`, mirroring the replica delay-chain quantization
//! the cycle composition already applies ([`TAU_STAGE`]).  Designs
//! whose exact windows fall inside the same bucket get bit-identical
//! quantized windows and share executions ([`batch::write_key`] /
//! [`batch::read_key`] group on the window bits).
//!
//! The accuracy contract, asserted by the unit and integration tests:
//!
//! * **Conservative** — the quantized window is `>= ` the exact window
//!   (settle time only grows) and `<= (1+r)` times it (one bucket
//!   step), monotone and idempotent in the window.
//! * **Resolution 0 is exact** — `with_resolution(.., 0.0)` returns
//!   the window unchanged, bit for bit, so a resolution-0 batched
//!   sweep reproduces the unquantized singleton path bitwise.
//! * **Bounded deviation** — the quantized window feeds the measured
//!   transients (stimulus edges scale with the window at <= 8 % of it,
//!   and crossing times are linearly interpolated, so the shift is
//!   first-order bounded by the stretch): every window-dependent
//!   [`BankPerf`] field stays within one resolution step (relative)
//!   of the resolution-0 result, while the window-independent fields
//!   (`leakage_w`, `t_decoder_s`, `e_read_j`) are bitwise unchanged.
//!
//! [`DEFAULT_WINDOW_RESOLUTION`] (10 % per step) is the sweep entry
//! points' default trade: a fine size axis collapses to a handful of
//! buckets while the measured figures move by a few percent at most.

pub mod batch;

/// Re-exported at the module root: the occupancy model is part of the
/// characterization contract, and the composition layer
/// ([`crate::compose`]) computes its packing plans from it.
pub use batch::calls_for;

use crate::compiler::{Bank, CellFlavor, Config};
use crate::coordinator;
use crate::runtime::{engines, ExecBackend, QuarantinedPoint, RunHealth, SharedRuntime};
use crate::sim;
use crate::tech::{DeviceCard, Tech};
use crate::util::ceil_log2;

/// Sense-amp offset margin (V) and timing guardband.
const SENSE_MARGIN_V: f64 = 0.05;
const GUARDBAND: f64 = 1.15;
/// Replica delay-chain stage delay (s), x1 inverter FO4-ish.
pub const TAU_STAGE: f64 = 25e-12;
/// Stored-'0' probe level for the read discrimination transient.
const STORED_ZERO: f64 = 0.05;

/// Default window-quantization resolution for the batch-first sweep
/// entry points: ~10 % bucket steps (see the module docs for the
/// accuracy contract).  Pass `0.0` anywhere a resolution is accepted
/// to recover the exact, unquantized windows bitwise.
pub const DEFAULT_WINDOW_RESOLUTION: f64 = 0.10;

/// Snap `window_s` up to the ceiling of the geometric bucket grid
/// `(1+resolution)^k` — the resolution-bounded quantization that lets
/// mixed-geometry sweeps share write/read artifact executions.
///
/// Guarantees (property-tested in this module):
///
/// * `resolution <= 0` (or a non-finite/non-positive window) returns
///   `window_s` unchanged, bit for bit; so does a resolution so fine
///   (below ~2e-7 at nanosecond windows) that the bucket grid would
///   be finer than f64 can represent — identity is exact there;
/// * otherwise the result is the smallest grid value `>= window_s`,
///   so it is conservative (`>= window_s`), within one step
///   (`<= window_s * (1 + resolution)`, up to one ulp of `powi`),
///   monotone in `window_s`, and idempotent;
/// * every window inside a bucket maps to the *bit-identical* grid
///   value (`powi` of the same integer exponent), which is what makes
///   the bucket usable as a batch homogeneity key.
pub fn quantize_window(window_s: f64, resolution: f64) -> f64 {
    if !(resolution > 0.0) || !(window_s > 0.0) || !window_s.is_finite() {
        return window_s;
    }
    let step = 1.0 + resolution;
    let est = window_s.ln() / step.ln();
    // sub-ulp grids (tiny resolutions push the exponent beyond i32)
    // degrade to the exact identity instead of overflowing `powi`
    if !est.is_finite() || est.abs() > 1e8 {
        return window_s;
    }
    // smallest integer k with step^k >= window; the ln estimate is
    // within one ulp of the true exponent, the loops correct it
    let mut k = est.ceil() as i32;
    while step.powi(k) < window_s {
        k += 1;
    }
    while step.powi(k - 1) >= window_s {
        k -= 1;
    }
    step.powi(k)
}

/// Characterization result for one bank.
#[derive(Debug, Clone, Copy)]
pub struct BankPerf {
    pub f_read_hz: f64,
    pub f_write_hz: f64,
    /// Operating frequency = min(read, write) (paper: read-limited).
    pub f_op_hz: f64,
    /// Effective read+write bandwidth (bits/s); SRAM halves (shared port).
    pub bandwidth_bps: f64,
    pub retention_s: f64,
    pub leakage_w: f64,
    /// Dynamic energy per read access (J).
    pub e_read_j: f64,
    pub t_decoder_s: f64,
    pub t_cell_read_s: f64,
    pub stored_one_v: f64,
    /// true if the stored levels/sense margins resolve (shmoo pass).
    pub functional: bool,
}

impl BankPerf {
    /// Placeholder perf for a quarantined design: every figure is NaN
    /// and the design is non-functional, so it can ride through
    /// Pareto/shmoo plumbing (which treats it as infeasible) without
    /// masquerading as a real measurement.
    pub fn quarantined() -> BankPerf {
        BankPerf {
            f_read_hz: f64::NAN,
            f_write_hz: f64::NAN,
            f_op_hz: f64::NAN,
            bandwidth_bps: f64::NAN,
            retention_s: f64::NAN,
            leakage_w: f64::NAN,
            e_read_j: f64::NAN,
            t_decoder_s: f64::NAN,
            t_cell_read_s: f64::NAN,
            stored_one_v: f64::NAN,
            functional: false,
        }
    }
}

/// GEMTOO-class analytical estimate (no simulation).  The ablation
/// bench quantifies its deviation from the transient path (paper
/// reports up to 15 % for GEMTOO).
pub fn analytical(tech: &Tech, bank: &Bank) -> BankPerf {
    let vdd = tech.vdd;
    let p = &bank.parasitics;
    let rows = bank.config.rows();
    let t_dec = decoder_delay(tech, rows);
    let t_wl = 0.38 * p.r_wl * p.c_wl + 20e-12;
    // cell read current estimate: gate at the driving stored level
    // (0 for the pull-up PMOS read, vdd for pull-down NMOS reads)
    let rd = read_card(tech, bank.config.flavor);
    let i_cell = if bank.config.flavor.pull_up_read() {
        sim::ids_card(&rd.0, rd.1, vdd / 2.0, 0.0, vdd).abs()
    } else {
        sim::ion(&rd.0, rd.1, vdd) * 0.4
    };
    // differential SRAM senses at ~150 mV; single-ended GC needs the
    // full excursion to the reference (paper SS V-C)
    let swing = if bank.config.flavor == CellFlavor::Sram6t {
        0.15
    } else {
        vdd / 2.0 + SENSE_MARGIN_V
    };
    let t_cell = p.c_rbl * swing / i_cell;
    let t_sense = 60e-12;
    // same delay-chain quantization as the transient-backed path
    let stages = ((t_wl + t_cell + t_sense) / TAU_STAGE).ceil() + 2.0;
    let t_ctrl = stages * TAU_STAGE;
    let mux_penalty = if bank.config.mux_factor() > 1 { 40e-12 } else { 0.0 };
    let t_read = (t_dec + t_wl + t_ctrl.max(t_cell + t_sense) + mux_penalty) * GUARDBAND;
    let wr_drv = tech.card("si_nmos");
    let t_write = (t_dec + t_wl + 3.0 * p.c_wbl * vdd / sim::ion(wr_drv, 4.0, vdd) + 50e-12) * GUARDBAND;
    let f_read = 1.0 / t_read;
    let f_write = 1.0 / t_write;
    let f_op = f_read.min(f_write);
    let leak = leakage(tech, bank);
    let sn_one = vdd - tech.card("si_nmos").vt;
    BankPerf {
        f_read_hz: f_read,
        f_write_hz: f_write,
        f_op_hz: f_op,
        bandwidth_bps: bandwidth(bank.config.flavor, bank.config.word_size, f_op),
        retention_s: analytical_retention(tech, bank),
        leakage_w: leak,
        e_read_j: p.c_rbl * vdd * vdd * bank.config.word_size as f64,
        t_decoder_s: t_dec,
        t_cell_read_s: t_cell,
        stored_one_v: sn_one,
        functional: true,
    }
}

/// Staged decomposition of [`characterize`].
///
/// * `new` extracts everything the transients need from (tech, bank) —
///   pure, no runtime access;
/// * [`CharPlan::write_jobs`] emits stage 1 ([`engines::WritePoint`]);
/// * [`CharPlan::absorb_writes`] folds the write results in (the read
///   and retention points start from the written stored-'1' level);
/// * [`CharPlan::read_jobs`] / [`CharPlan::retention_jobs`] emit
///   stage 2;
/// * [`CharPlan::finish`] folds the transient results into a
///   [`BankPerf`].
///
/// Results are positional with the emitted job lists.  Both
/// [`characterize`] (singleton batches) and [`characterize_all`]
/// (shared cross-design batches) run exactly this plan, so the two
/// paths are equivalent by construction: a singleton
/// `characterize_all` issues byte-identical artifact calls.
#[derive(Debug, Clone)]
pub struct CharPlan {
    kind: PlanKind,
}

#[derive(Debug, Clone)]
enum PlanKind {
    /// No transient jobs: the 6T SRAM baseline reads differentially
    /// (BL/BLb), which the GC read template does not model; the
    /// calibrated analytical model is the SRAM reference (its
    /// differential sense needs only ~150 mV of swing, which is why
    /// SRAM is faster than GCRAM in Fig. 7a).
    Analytical(BankPerf),
    Transient(Box<TransientPlan>),
}

#[derive(Debug, Clone)]
struct TransientPlan {
    flavor: CellFlavor,
    word_size: usize,
    mux_gt1: bool,
    rows: usize,
    vdd: f64,
    // write stage
    wr_pt: engines::WritePoint,
    /// Write window scales with the WBL RC.
    wr_window: f64,
    // read stage (points need the write result's stored level)
    rd_card: DeviceCard,
    rd_wl: f64,
    rd_window: f64,
    pull_up: bool,
    // retention stage
    g_gate_leak: f64,
    // parasitics the later stages re-use
    c_sn: f64,
    c_rbl: f64,
    c_rwl_sn: f64,
    // analytical periphery terms (precomputed: finish has no tech)
    t_dec: f64,
    t_wl: f64,
    leakage_w: f64,
    // filled by absorb_writes
    wr: Option<engines::WriteResult>,
}

/// One sampled per-instance perturbation applied on top of a design's
/// nominal plan by [`CharPlan::with_variation`] (the Monte-Carlo
/// variation subsystem, [`crate::variation`]).  Shifts act on the
/// *cell* transients only — the write/read cell transistors, the
/// storage/bitline capacitances and the local supply — while the
/// analytical periphery terms (decoder, wordline RC, leakage) stay
/// nominal: mismatch is a minimum-size-device effect that averages out
/// over the wide periphery gates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Perturb {
    /// Additive VT shift on the write transistor (V) — the paper's
    /// retention-critical device.
    pub vt_shift_wr: f64,
    /// Additive VT shift on the read transistor (V).
    pub vt_shift_rd: f64,
    /// Multiplier on the cell cards' `kp` (process speed / temperature
    /// proxy, carries the corner's `kp_scale`).
    pub kp_scale: f64,
    /// Multiplier on the cell capacitances (geometry delta:
    /// line-edge/thickness variation on `c_sn`, `c_wbl`, `c_rbl` and
    /// the coupling caps).
    pub c_scale: f64,
    /// Multiplier on the supply seen by the cell (IR droop / corner
    /// VDD).
    pub vdd_scale: f64,
}

impl Perturb {
    /// The identity perturbation.
    pub const NONE: Perturb = Perturb {
        vt_shift_wr: 0.0,
        vt_shift_rd: 0.0,
        kp_scale: 1.0,
        c_scale: 1.0,
        vdd_scale: 1.0,
    };

    /// True for the identity (f64 `==`, so a `-0.0` shift from a
    /// zero-sigma draw still counts as identity).
    pub fn is_identity(&self) -> bool {
        *self == Perturb::NONE
    }
}

impl CharPlan {
    /// Build the job plan for one bank (pure; no runtime access) with
    /// exact, unquantized transient windows — shorthand for
    /// [`CharPlan::with_resolution`] at resolution `0.0`.
    pub fn new(tech: &Tech, bank: &Bank) -> CharPlan {
        CharPlan::with_resolution(tech, bank, 0.0)
    }

    /// Build the job plan for one bank with its write/read windows
    /// snapped up to the `window_resolution` bucket grid (see
    /// [`quantize_window`] and the module docs for the accuracy
    /// contract).  Resolution `0.0` keeps the exact windows bitwise.
    pub fn with_resolution(tech: &Tech, bank: &Bank, window_resolution: f64) -> CharPlan {
        CharPlan::with_variation(tech, bank, window_resolution, &Perturb::NONE)
    }

    /// [`CharPlan::with_resolution`] with a sampled per-instance
    /// [`Perturb`] folded into the cell-level plan: cell cards shift
    /// (`vt + shift`, `kp * scale`), cell caps and the local supply
    /// scale, and the transient windows are recomputed from the
    /// perturbed values before quantization.  The identity perturbation
    /// returns the nominal plan **bitwise** (it takes the exact same
    /// construction path), which is what makes a zero-sigma Monte-Carlo
    /// run bit-equal to the non-MC path.
    pub fn with_variation(
        tech: &Tech,
        bank: &Bank,
        window_resolution: f64,
        perturb: &Perturb,
    ) -> CharPlan {
        if bank.config.flavor == CellFlavor::Sram6t {
            // the SRAM reference is analytical (no transient jobs); the
            // cell-level perturbation has nothing to act on
            return CharPlan { kind: PlanKind::Analytical(analytical(tech, bank)) };
        }
        let cfg = &bank.config;
        let p = &bank.parasitics;
        let flavor = cfg.flavor;
        let rows = cfg.rows();
        let (wr_base, wr_wl) = write_card(tech, flavor, cfg.write_vt);
        let (rd_base, rd_wl) = read_card(tech, flavor);
        let (vdd, wr_card, rd_card, c_sn, c_wbl, c_rbl, c_wwl_sn, c_rwl_sn) =
            if perturb.is_identity() {
                (tech.vdd, wr_base, rd_base, p.c_sn, p.c_wbl, p.c_rbl, p.c_wwl_sn, p.c_rwl_sn)
            } else {
                (
                    tech.vdd * perturb.vdd_scale,
                    DeviceCard {
                        kp: wr_base.kp * perturb.kp_scale,
                        vt: wr_base.vt + perturb.vt_shift_wr,
                        ..wr_base
                    },
                    DeviceCard {
                        kp: rd_base.kp * perturb.kp_scale,
                        vt: rd_base.vt + perturb.vt_shift_rd,
                        ..rd_base
                    },
                    p.c_sn * perturb.c_scale,
                    p.c_wbl * perturb.c_scale,
                    p.c_rbl * perturb.c_scale,
                    p.c_wwl_sn * perturb.c_scale,
                    p.c_rwl_sn * perturb.c_scale,
                )
            };
        let v_wwl = if cfg.wwlls { vdd + 0.4 } else { vdd };
        let wr_pt = engines::WritePoint {
            write_card: wr_card,
            write_wl: wr_wl,
            drv_p: (*tech.card("si_pmos"), 8.0),
            drv_n: (*tech.card("si_nmos"), 4.0),
            c_sn,
            c_wbl,
            c_wwl_sn,
            g_wbl_leak: 1e-9,
            vdd,
            v_wwl,
            one: true,
            sn0: 0.0,
        };
        CharPlan {
            kind: PlanKind::Transient(Box::new(TransientPlan {
                flavor,
                word_size: cfg.word_size,
                mux_gt1: cfg.mux_factor() > 1,
                rows,
                vdd,
                wr_window: quantize_window(
                    (40.0 * c_wbl * vdd / sim::ion(&wr_card, 4.0, vdd)).max(4e-9),
                    window_resolution,
                ),
                wr_pt,
                rd_card,
                rd_wl,
                rd_window: quantize_window(
                    (60.0 * c_rbl * 0.55 / sim::ion(&rd_card, rd_wl, vdd)).max(6e-9),
                    window_resolution,
                ),
                pull_up: flavor.pull_up_read(),
                g_gate_leak: gate_leak(flavor),
                c_sn,
                c_rbl,
                c_rwl_sn,
                t_dec: decoder_delay(tech, rows),
                t_wl: 0.38 * p.r_wl * p.c_wl + 20e-12,
                leakage_w: leakage(tech, bank),
                wr: None,
            })),
        }
    }

    /// The `(write, read)` transient-window bit patterns this plan will
    /// execute with (`None` for the analytical SRAM plan).  These are
    /// exactly the bits [`batch::write_key`] / [`batch::read_key`]
    /// group on, so two plans with equal bits share write (and, per
    /// `pull_up` flavor, read) artifact executions — the benches and
    /// tests use this to compute the expected grouped-ceiling call
    /// counts without reaching into the executors.
    pub fn window_bits(&self) -> Option<(u64, u64)> {
        match &self.kind {
            PlanKind::Analytical(_) => None,
            PlanKind::Transient(t) => Some((t.wr_window.to_bits(), t.rd_window.to_bits())),
        }
    }

    /// Stage-1 write-transient jobs (empty for the analytical plan).
    pub fn write_jobs(&self) -> Vec<batch::WriteJob> {
        match &self.kind {
            PlanKind::Analytical(_) => Vec::new(),
            PlanKind::Transient(t) => {
                vec![batch::WriteJob { pt: t.wr_pt.clone(), window_s: t.wr_window }]
            }
        }
    }

    /// Fold the stage-1 results in (positional with
    /// [`Self::write_jobs`]).
    pub fn absorb_writes(&mut self, res: &[engines::WriteResult]) -> crate::Result<()> {
        match &mut self.kind {
            PlanKind::Analytical(_) => {
                anyhow::ensure!(res.is_empty(), "analytical plan expected no write results");
            }
            PlanKind::Transient(t) => {
                anyhow::ensure!(res.len() == 1, "plan emitted 1 write job, got {} results", res.len());
                t.wr = Some(res[0]);
            }
        }
        Ok(())
    }

    /// Stage-2 read jobs: stored-'0' vs stored-'1' discrimination.
    /// Needs [`Self::absorb_writes`] first (the '1' probe and the
    /// unselected-cell level start from the written `sn_final`).
    pub fn read_jobs(&self) -> crate::Result<Vec<batch::ReadJob>> {
        let t = match &self.kind {
            PlanKind::Analytical(_) => return Ok(Vec::new()),
            PlanKind::Transient(t) => t,
        };
        let wr = t.wr.ok_or_else(|| anyhow::anyhow!("read_jobs before absorb_writes"))?;
        let stored_one = wr.sn_final as f64;
        let mk = |sn0: f64| engines::ReadPoint {
            read_card: t.rd_card,
            read_wl: t.rd_wl,
            sn0,
            sn_unsel: if t.pull_up { stored_one } else { 0.0 },
            rows: t.rows,
            c_sn: t.c_sn,
            c_rbl: t.c_rbl,
            c_rwl_sn: t.c_rwl_sn,
            g_rbl_leak: 1e-9,
            vdd: t.vdd,
            pull_up: t.pull_up,
        };
        Ok(vec![
            batch::ReadJob { pt: mk(STORED_ZERO), window_s: t.rd_window },
            batch::ReadJob { pt: mk(stored_one), window_s: t.rd_window },
        ])
    }

    /// Stage-2 retention job.  Needs [`Self::absorb_writes`] first
    /// (decay starts from the written level).
    pub fn retention_jobs(&self) -> crate::Result<Vec<batch::RetentionJob>> {
        let t = match &self.kind {
            PlanKind::Analytical(_) => return Ok(Vec::new()),
            PlanKind::Transient(t) => t,
        };
        let wr = t.wr.ok_or_else(|| anyhow::anyhow!("retention_jobs before absorb_writes"))?;
        Ok(vec![batch::RetentionJob {
            pt: engines::RetentionPoint {
                write_card: t.wr_pt.write_card,
                write_wl: t.wr_pt.write_wl,
                c_sn: t.c_sn,
                g_gate_leak: t.g_gate_leak,
                i_disturb: 0.0,
                v0: (wr.sn_final as f64).max(0.05),
                vth: 0.0, // relative threshold: decay to half the stored level
            },
        }])
    }

    /// Fold the stage-2 results (positional with the job lists) into
    /// the final [`BankPerf`]: discrimination margin, delay-chain
    /// quantization, cycle composition.
    pub fn finish(
        &self,
        rd: &[engines::ReadResult],
        ret: &[engines::RetentionResult],
    ) -> crate::Result<BankPerf> {
        let t = match &self.kind {
            PlanKind::Analytical(perf) => {
                anyhow::ensure!(
                    rd.is_empty() && ret.is_empty(),
                    "analytical plan expected no transient results"
                );
                return Ok(*perf);
            }
            PlanKind::Transient(t) => t,
        };
        let wr = t.wr.ok_or_else(|| anyhow::anyhow!("finish before absorb_writes"))?;
        anyhow::ensure!(rd.len() == 2, "plan emitted 2 read jobs, got {} results", rd.len());
        anyhow::ensure!(ret.len() == 1, "plan emitted 1 retention job, got {} results", ret.len());
        let stored_one = wr.sn_final as f64;
        let t_write_cell = wr.t_wr;
        // driving case crosses first; opposite case must cross later
        // (margin)
        let (t_drive, t_hold) = if t.pull_up {
            (rd[0].t_rise, rd[1].t_rise)
        } else {
            (rd[1].t_fall, rd[0].t_fall)
        };
        let discriminates = t_hold > 1.3 * t_drive;
        let t_cell_read = t_drive;
        let retention_s = ret[0].t_retain;

        // --- compose the cycle ---------------------------------------
        let t_sense = 60e-12;
        // replica delay chain quantizes the sense window (Fig. 7a step)
        let stages = ((t.t_wl + t_cell_read + t_sense) / TAU_STAGE).ceil() as usize + 2;
        let t_ctrl = stages as f64 * TAU_STAGE;
        let mux_penalty = if t.mux_gt1 { 40e-12 } else { 0.0 };
        let t_read =
            (t.t_dec + t.t_wl + t_ctrl.max(t_cell_read + t_sense) + mux_penalty) * GUARDBAND;
        let t_write = (t.t_dec + t.t_wl + t_write_cell + 50e-12) * GUARDBAND;
        let f_read = 1.0 / t_read;
        let f_write = 1.0 / t_write;
        let f_op = f_read.min(f_write);
        let functional = discriminates && stored_one > sense_floor(t.vdd);
        Ok(BankPerf {
            f_read_hz: f_read,
            f_write_hz: f_write,
            f_op_hz: f_op,
            bandwidth_bps: bandwidth(t.flavor, t.word_size, f_op),
            retention_s,
            leakage_w: t.leakage_w,
            e_read_j: t.c_rbl * t.vdd * t.vdd * t.word_size as f64,
            t_decoder_s: t.t_dec,
            t_cell_read_s: t_cell_read,
            stored_one_v: stored_one,
            functional,
        })
    }
}

/// Full characterization: write + read + retention transients on any
/// execution backend (native solver or XLA artifacts), analytical
/// periphery, delay-chain quantization.
/// Runs one [`CharPlan`] with singleton batches; sweeps should prefer
/// [`characterize_all`], which packs the same jobs across designs.
pub fn characterize(tech: &Tech, rt: &dyn ExecBackend, bank: &Bank) -> crate::Result<BankPerf> {
    characterize_plan(rt, CharPlan::new(tech, bank))
}

/// Run one prebuilt [`CharPlan`] with singleton batches.  This is the
/// reference path the parity pins compare the packed runs against; the
/// variation tests use it with [`CharPlan::with_variation`] plans to
/// check that a sampled variant inside a mega-batch bitwise-matches its
/// own singleton run.
pub fn characterize_plan(rt: &dyn ExecBackend, mut plan: CharPlan) -> crate::Result<BankPerf> {
    let wj = plan.write_jobs();
    if wj.is_empty() {
        return plan.finish(&[], &[]);
    }
    let wr_pts: Vec<engines::WritePoint> = wj.iter().map(|j| j.pt.clone()).collect();
    let wr = engines::write_op(rt, &wr_pts, wj[0].window_s)?;
    plan.absorb_writes(&wr)?;
    let rj = plan.read_jobs()?;
    let rd_pts: Vec<engines::ReadPoint> = rj.iter().map(|j| j.pt.clone()).collect();
    let rd = engines::read_op(rt, &rd_pts, rj[0].window_s)?;
    let tj = plan.retention_jobs()?;
    let ret_pts: Vec<engines::RetentionPoint> = tj.iter().map(|j| j.pt.clone()).collect();
    let ret = engines::retention(rt, &ret_pts)?;
    plan.finish(&rd, &ret)
}

/// Batch-first characterization of many designs: every plan's
/// write/read/retention points are packed into shared padded artifact
/// batches through [`coordinator`] executors ([`batch`]).
///
/// * Read batches are split by `pull_up` flavor inside the executor,
///   so mixed-flavor design lists are fine — the `read_op` homogeneity
///   `ensure` is a batcher invariant here, not a caller obligation.
/// * Write/read points pack across designs whose *quantized* windows
///   coincide: each design's windows are snapped up to the
///   `window_resolution` bucket grid ([`quantize_window`]), so a
///   mixed-geometry size axis shares executions the way a
///   same-geometry write-VT axis always did.  Pass
///   [`DEFAULT_WINDOW_RESOLUTION`] for the standard packing/accuracy
///   trade, or `0.0` for exact windows (designs then pack only when
///   their windows are naturally bit-equal).
/// * Retention points *always* pack — the retention artifact has no
///   per-batch window — so a sweep issues `ceil(points/batch)`
///   retention executions instead of one per design.
/// * For a singleton list at resolution `0.0` the emitted artifact
///   calls are exactly those of [`characterize`], so results
///   bitwise-match the single-design path (`tests/integration.rs`
///   asserts this per flavor); at nonzero resolution the deviation is
///   bounded by the module-level quantization contract.
/// * Strict failure semantics: any quarantined design (degenerate
///   input, NaN/Inf output, coordinator quarantine) fails the whole
///   call with the design index, stage and reason.  Sweeps that want
///   to keep the healthy designs use [`characterize_all_health`].
pub fn characterize_all(
    tech: &Tech,
    rt: &SharedRuntime,
    banks: &[Bank],
    window_resolution: f64,
) -> crate::Result<Vec<BankPerf>> {
    let (res, _health) = characterize_all_health(tech, rt, banks, window_resolution)?;
    res.into_iter()
        .enumerate()
        .map(|(i, r)| {
            r.map_err(|q| {
                anyhow::anyhow!("design {i} quarantined at {} stage: {}", q.stage, q.reason)
            })
        })
        .collect()
}

/// Why one design was quarantined: the characterization stage that
/// rejected it and the per-point cause (degenerate input, non-finite
/// output, or a coordinator-level bisection/worker-death error).
#[derive(Debug, Clone)]
pub struct Quarantine {
    pub stage: &'static str,
    pub reason: String,
}

/// Short human label for a design — what [`QuarantinedPoint::design`]
/// carries in the `RunHealth` report.
pub fn design_label(bank: &Bank) -> String {
    format!(
        "{}x{} {:?}",
        bank.config.word_size, bank.config.num_words, bank.config.flavor
    )
}

/// Flatten one design's span of per-row results: the first faulted row
/// (engine-level `RowFault` or coordinator-level error) quarantines the
/// design at `stage`; a fault-free span yields the plain results.
fn flatten_span<T: Copy>(
    stage: &'static str,
    span: &[crate::Result<engines::RowResult<T>>],
) -> Result<Vec<T>, Quarantine> {
    span.iter()
        .map(|r| match r {
            Ok(Ok(v)) => Ok(*v),
            Ok(Err(f)) => Err(Quarantine { stage, reason: f.reason.clone() }),
            Err(e) => Err(Quarantine { stage, reason: format!("{e:#}") }),
        })
        .collect()
}

/// [`characterize_all`] with per-design fault isolation and a
/// [`RunHealth`] report.
///
/// Healthy designs get their [`BankPerf`] exactly as before — on a
/// fault-free run the emitted artifact calls (and hence the results)
/// are identical to [`characterize_all`]'s, bitwise.  A design whose
/// rows are rejected (degenerate input, NaN/Inf output, coordinator
/// bisection quarantine, worker death) comes back as
/// `Err(`[`Quarantine`]`)` instead of failing the whole sweep; its
/// later-stage jobs are simply not emitted.  The report aggregates the
/// coordinator's retry/bisection counters across all three stage
/// workers, the runtime's pjrt→native failover delta, and one
/// [`QuarantinedPoint`] per rejected design.
pub fn characterize_all_health(
    tech: &Tech,
    rt: &SharedRuntime,
    banks: &[Bank],
    window_resolution: f64,
) -> crate::Result<(Vec<Result<BankPerf, Quarantine>>, RunHealth)> {
    let plans: Vec<CharPlan> = banks
        .iter()
        .map(|b| CharPlan::with_resolution(tech, b, window_resolution))
        .collect();
    let labels: Vec<String> = banks.iter().map(design_label).collect();
    characterize_plans_health(rt, plans, labels)
}

/// The packed-run core shared by [`characterize_all_health`] and the
/// Monte-Carlo variation sweep ([`crate::variation`]): run a list of
/// prebuilt [`CharPlan`]s (any mix of nominal and
/// [`CharPlan::with_variation`]-perturbed plans) through the
/// coordinator with cross-plan batch packing and per-plan fault
/// isolation.  `labels[i]` names plan `i` in the [`RunHealth`]
/// quarantine report.
pub fn characterize_plans_health(
    rt: &SharedRuntime,
    mut plans: Vec<CharPlan>,
    labels: Vec<String>,
) -> crate::Result<(Vec<Result<BankPerf, Quarantine>>, RunHealth)> {
    anyhow::ensure!(
        plans.len() == labels.len(),
        "{} plans but {} labels",
        plans.len(),
        labels.len()
    );
    let failovers_before = rt.failovers();
    let health = std::sync::Arc::new(coordinator::CoordHealth::default());
    let mut quarantine: Vec<Option<Quarantine>> = vec![None; plans.len()];

    // ---- stage 1: write transients, packed across designs ------------
    let mut wr_jobs: Vec<batch::WriteJob> = Vec::new();
    let mut wr_span: Vec<usize> = Vec::with_capacity(plans.len());
    for p in &plans {
        let jobs = p.write_jobs();
        wr_span.push(jobs.len());
        wr_jobs.extend(jobs);
    }
    let wr_res = run_packed(wr_jobs, batch::write_key, |groups| {
        coordinator::scope_with_health(batch::WriteExec::new(rt)?, health.clone(), |sub| {
            sub.run_grouped_each(groups)
        })
    })?;
    let mut off = 0;
    for (i, (p, &n)) in plans.iter_mut().zip(&wr_span).enumerate() {
        let span = &wr_res[off..off + n];
        off += n;
        match flatten_span("write", span) {
            Ok(wr) => p.absorb_writes(&wr)?,
            Err(q) => quarantine[i] = Some(q),
        }
    }

    // ---- stage 2: read + retention, packed across designs ------------
    // (quarantined designs emit no further jobs: zero-length spans)
    let mut rd_jobs: Vec<batch::ReadJob> = Vec::new();
    let mut rd_span: Vec<usize> = Vec::with_capacity(plans.len());
    let mut ret_jobs: Vec<batch::RetentionJob> = Vec::new();
    let mut ret_span: Vec<usize> = Vec::with_capacity(plans.len());
    for (i, p) in plans.iter().enumerate() {
        if quarantine[i].is_some() {
            rd_span.push(0);
            ret_span.push(0);
            continue;
        }
        let jobs = p.read_jobs()?;
        rd_span.push(jobs.len());
        rd_jobs.extend(jobs);
        let jobs = p.retention_jobs()?;
        ret_span.push(jobs.len());
        ret_jobs.extend(jobs);
    }
    let rd_res = run_packed(rd_jobs, batch::read_key, |groups| {
        coordinator::scope_with_health(batch::ReadExec::new(rt)?, health.clone(), |sub| {
            sub.run_grouped_each(groups)
        })
    })?;
    let ret_res = run_packed(ret_jobs, |_| 0, |groups| {
        coordinator::scope_with_health(batch::RetentionExec::new(rt)?, health.clone(), |sub| {
            sub.run_grouped_each(groups)
        })
    })?;

    // ---- finish -------------------------------------------------------
    let (mut ro, mut to) = (0usize, 0usize);
    let mut out: Vec<Result<BankPerf, Quarantine>> = Vec::with_capacity(plans.len());
    for (i, ((p, &nr), &nt)) in plans.iter().zip(&rd_span).zip(&ret_span).enumerate() {
        let rspan = &rd_res[ro..ro + nr];
        ro += nr;
        let tspan = &ret_res[to..to + nt];
        to += nt;
        if let Some(q) = quarantine[i].take() {
            out.push(Err(q));
            continue;
        }
        let staged = flatten_span("read", rspan)
            .and_then(|rd| flatten_span("retention", tspan).map(|ret| (rd, ret)));
        match staged {
            Ok((rd, ret)) => out.push(Ok(p.finish(&rd, &ret)?)),
            Err(q) => out.push(Err(q)),
        }
    }

    let report = RunHealth {
        retries: health.retries(),
        bisect_execs: health.bisect_execs(),
        failovers: rt.failovers().saturating_sub(failovers_before),
        quarantined: out
            .iter()
            .enumerate()
            .filter_map(|(i, r)| {
                r.as_ref().err().map(|q| QuarantinedPoint {
                    index: i,
                    design: labels[i].clone(),
                    stage: q.stage,
                    reason: q.reason.clone(),
                })
            })
            .collect(),
    };
    Ok((out, report))
}

/// The pinned-mux fine rows axis the quantization KPI benches and
/// tests share: 32-bit words, `first_words + i * words_step` words
/// each, column mux forced to 1 so rows == words.  On sg40, rows of
/// roughly 150 and above keep both transient windows over their
/// 4 ns / 6 ns floor clamps — below that the exact windows are
/// already bit-equal and any packing is the clamp's doing, not the
/// quantizer's — so callers pin the axis at `first_words >= 180`.
pub fn quantization_axis(n: usize, first_words: usize, words_step: usize) -> Vec<Config> {
    (0..n)
        .map(|i| {
            let mut cfg = Config::new(32, first_words + i * words_step, CellFlavor::GcSiSiNp);
            cfg.mux_factor = Some(1);
            cfg
        })
        .collect()
}

/// Distinct `(write, read)` execution-group counts over `banks` at
/// `resolution`: write groups key on the quantized window bits, read
/// groups on `(pull_up, window bits)` — exactly the homogeneity keys
/// [`batch::write_key`] / [`batch::read_key`] use, so for group sizes
/// under the artifact cap these are the per-engine execution counts a
/// [`characterize_all`] sweep pays (the KPI the benches assert
/// against the runtime's call counters).  Analytical SRAM plans emit
/// no transient jobs and are skipped.
pub fn window_group_counts(tech: &Tech, banks: &[Bank], resolution: f64) -> (usize, usize) {
    let mut wr = std::collections::HashSet::new();
    let mut rd = std::collections::HashSet::new();
    for b in banks {
        if let Some((w, r)) = CharPlan::with_resolution(tech, b, resolution).window_bits() {
            wr.insert(w);
            rd.insert((b.config.flavor.pull_up_read(), r));
        }
    }
    (wr.len(), rd.len())
}

/// Partition `jobs` into their homogeneity groups, hand the groups to
/// `run` (which submits them with group-boundary flushes — see
/// [`crate::coordinator::Submitter::run_grouped`] — so no worker batch
/// ever spans two groups), then restore the results to the original
/// job order.  The artifact-call count is exactly
/// `sum(ceil(group_len / cap))` over the key's groups.
fn run_packed<J: Clone, R>(
    jobs: Vec<J>,
    key: impl FnMut(&J) -> u128,
    run: impl FnOnce(Vec<Vec<J>>) -> crate::Result<Vec<R>>,
) -> crate::Result<Vec<R>> {
    let groups = batch::group_indices(&jobs, key);
    let order: Vec<usize> = groups.iter().flatten().copied().collect();
    let grouped: Vec<Vec<J>> = groups
        .iter()
        .map(|g| g.iter().map(|&i| jobs[i].clone()).collect())
        .collect();
    let res = run(grouped)?;
    anyhow::ensure!(
        res.len() == jobs.len(),
        "packed run returned {} results for {} jobs",
        res.len(),
        jobs.len()
    );
    let mut out: Vec<Option<R>> = std::iter::repeat_with(|| None).take(jobs.len()).collect();
    for (&slot, r) in order.iter().zip(res) {
        out[slot] = Some(r);
    }
    Ok(out.into_iter().map(|r| r.expect("permutation covers every slot")).collect())
}

/// Logical-effort decoder + WL driver delay.
pub fn decoder_delay(tech: &Tech, rows: usize) -> f64 {
    let stages = ceil_log2(rows).max(1) as f64;
    let tau = 18e-12 * 1.1 / tech.vdd;
    // nand2 effort 4/3, fanout ~3 per stage, + driver stage
    stages * tau * (4.0 / 3.0) * 2.2 + 2.0 * tau * 3.0
}

/// Effective bandwidth (paper Fig. 7b): dual-port GC reads and writes
/// concurrently; single-port SRAM shares, halving each.
pub fn bandwidth(flavor: CellFlavor, word_size: usize, f_op: f64) -> f64 {
    let w = word_size as f64;
    match flavor {
        CellFlavor::Sram6t => w * f_op, // f/2 read + f/2 write
        _ => 2.0 * w * f_op,
    }
}

/// Leakage power (paper Fig. 7c): SRAM cells have VDD->GND subthreshold
/// paths; gain cells have none (storage is a floating gate), so only
/// the periphery leaks.
pub fn leakage(tech: &Tech, bank: &Bank) -> f64 {
    let vdd = tech.vdd;
    let cells = bank.config.bits() as f64;
    let cell_leak = match bank.config.flavor {
        CellFlavor::Sram6t => {
            let n = sim::ioff(tech.card("si_nmos"), 3.0, vdd);
            let p = sim::ioff(tech.card("si_pmos"), 2.5, vdd);
            (n + p) * vdd
        }
        // gain cell: no static path; only junction leakage ~ 0
        _ => 0.0,
    };
    // periphery: rough inverter-equivalent count
    let periph_gates = (bank.config.rows() * 3 + bank.config.word_size * 12) as f64;
    let periph_leak = periph_gates
        * (sim::ioff(tech.card("si_nmos"), 2.75, vdd) + sim::ioff(tech.card("si_pmos"), 4.5, vdd))
        * vdd
        * 0.5;
    cells * cell_leak + periph_leak
}

fn analytical_retention(tech: &Tech, bank: &Bank) -> f64 {
    if bank.config.flavor == CellFlavor::Sram6t {
        return f64::INFINITY;
    }
    let (wr, wl) = write_card(tech, bank.config.flavor, bank.config.write_vt);
    let i = sim::ioff(&wr, wl, 0.6) + gate_leak(bank.config.flavor) * 0.6;
    bank.parasitics.c_sn * 0.3 / i.max(1e-30)
}

/// Cards per flavor (write transistor may carry a VT override).
pub fn write_card(tech: &Tech, flavor: CellFlavor, vt: Option<f64>) -> (crate::tech::DeviceCard, f64) {
    let base = match flavor {
        CellFlavor::GcOsOs => *tech.card("os_nmos"),
        _ => *tech.card("si_nmos"),
    };
    let card = vt.map(|v| base.with_vt(v)).unwrap_or(base);
    (card, if flavor == CellFlavor::GcOsOs { 1.0 } else { 2.5 })
}

pub fn read_card(tech: &Tech, flavor: CellFlavor) -> (crate::tech::DeviceCard, f64) {
    match flavor {
        CellFlavor::GcSiSiNp => (*tech.card("si_pmos_hvt"), 3.5),
        CellFlavor::GcOsOs => (*tech.card("os_nmos"), 1.2),
        _ => (*tech.card("si_nmos"), 3.5),
    }
}

fn gate_leak(flavor: CellFlavor) -> f64 {
    match flavor {
        CellFlavor::GcOsOs => 1e-17, // thick BEOL gate dielectric
        _ => 1e-16,
    }
}

fn sense_floor(vdd: f64) -> f64 {
    0.35 * vdd
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, Config};
    use crate::tech::sg40;

    #[test]
    fn analytical_scales_with_size() {
        let t = sg40();
        let small = compile(&t, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap();
        let large = compile(&t, &Config::new(64, 256, CellFlavor::GcSiSiNp)).unwrap();
        let ps = analytical(&t, &small);
        let pl = analytical(&t, &large);
        assert!(ps.f_op_hz > pl.f_op_hz, "small banks are faster");
        assert!(ps.f_op_hz > 1e8 && ps.f_op_hz < 5e9, "{}", ps.f_op_hz);
    }

    #[test]
    fn sram_leaks_gc_does_not() {
        let t = sg40();
        let sr = compile(&t, &Config::new(64, 64, CellFlavor::Sram6t)).unwrap();
        let gc = compile(&t, &Config::new(64, 64, CellFlavor::GcSiSiNp)).unwrap();
        let l_sr = leakage(&t, &sr);
        let l_gc = leakage(&t, &gc);
        assert!(l_sr > 5.0 * l_gc, "sram {l_sr} vs gc {l_gc}");
    }

    #[test]
    fn bandwidth_policy() {
        assert_eq!(bandwidth(CellFlavor::Sram6t, 32, 1e9), 32e9);
        assert_eq!(bandwidth(CellFlavor::GcSiSiNp, 32, 1e9), 64e9);
    }

    #[test]
    fn decoder_delay_grows_with_rows() {
        let t = sg40();
        assert!(decoder_delay(&t, 256) > decoder_delay(&t, 16));
    }

    #[test]
    fn sram_plan_emits_no_jobs_and_finishes_analytically() {
        let t = sg40();
        let bank = compile(&t, &Config::new(32, 32, CellFlavor::Sram6t)).unwrap();
        let plan = CharPlan::new(&t, &bank);
        assert!(plan.write_jobs().is_empty());
        assert!(plan.read_jobs().unwrap().is_empty());
        assert!(plan.retention_jobs().unwrap().is_empty());
        let perf = plan.finish(&[], &[]).unwrap();
        let a = analytical(&t, &bank);
        assert_eq!(perf.f_op_hz.to_bits(), a.f_op_hz.to_bits());
        assert_eq!(perf.leakage_w.to_bits(), a.leakage_w.to_bits());
        assert!(perf.retention_s.is_infinite());
        // transient results handed to an analytical plan are a bug
        let bogus = engines::ReadResult { t_rise: 1e-9, t_fall: 1e-9, rbl_final: 0.0, sn_final: 0.0 };
        assert!(plan.finish(&[bogus, bogus], &[]).is_err());
    }

    #[test]
    fn transient_plan_stages_are_ordered_and_positional() {
        let t = sg40();
        let bank = compile(&t, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap();
        let mut plan = CharPlan::new(&t, &bank);
        // stage order is enforced: reads/retention need the write result
        assert!(plan.read_jobs().is_err());
        assert!(plan.retention_jobs().is_err());
        assert!(plan.finish(&[], &[]).is_err());
        let wj = plan.write_jobs();
        assert_eq!(wj.len(), 1);
        assert!(wj[0].pt.one && wj[0].pt.sn0 == 0.0);
        assert!(wj[0].window_s >= 4e-9);
        let wr = engines::WriteResult { sn_final: 0.62, t_wr: 1.5e-9, sn_peak: 0.7 };
        assert!(plan.absorb_writes(&[wr, wr]).is_err(), "result count must match jobs");
        plan.absorb_writes(&[wr]).unwrap();
        // read jobs: stored-'0' probe first, then the written '1'
        let rj = plan.read_jobs().unwrap();
        assert_eq!(rj.len(), 2);
        assert_eq!(rj[0].pt.sn0, STORED_ZERO);
        assert!((rj[1].pt.sn0 - 0.62).abs() < 1e-12);
        assert!(rj.iter().all(|j| j.pt.pull_up), "NP flavor reads pull-up");
        assert_eq!(rj[0].window_s.to_bits(), rj[1].window_s.to_bits());
        // retention decays from the written level
        let tj = plan.retention_jobs().unwrap();
        assert_eq!(tj.len(), 1);
        assert!((tj[0].pt.v0 - 0.62).abs() < 1e-12);
        // finish folds synthetic transients into a functional BankPerf
        let rd = [
            engines::ReadResult { t_rise: 1.0e-9, t_fall: 9e9, rbl_final: 0.6, sn_final: 0.05 },
            engines::ReadResult { t_rise: 2.0e-9, t_fall: 9e9, rbl_final: 0.1, sn_final: 0.62 },
        ];
        let ret = [engines::RetentionResult { t_retain: 3e-4, sn_final: 0.31 }];
        assert!(plan.finish(&rd[..1], &ret).is_err(), "read results are positional");
        let perf = plan.finish(&rd, &ret).unwrap();
        assert!(perf.functional, "2x margin discriminates: {perf:?}");
        assert_eq!(perf.retention_s, 3e-4);
        assert_eq!(perf.stored_one_v, 0.62);
        assert_eq!(perf.t_cell_read_s, 1.0e-9);
        assert!(perf.f_op_hz > 0.0 && perf.f_op_hz.is_finite());
        // no discrimination margin -> non-functional
        let rd_bad = [
            engines::ReadResult { t_rise: 1.0e-9, t_fall: 9e9, rbl_final: 0.6, sn_final: 0.05 },
            engines::ReadResult { t_rise: 1.1e-9, t_fall: 9e9, rbl_final: 0.5, sn_final: 0.62 },
        ];
        assert!(!plan.finish(&rd_bad, &ret).unwrap().functional);
    }

    #[test]
    fn quantize_window_contract() {
        use crate::util::rng::{check, Rng};
        // resolution 0 (and degenerate inputs) are bitwise identity
        for w in [4e-9, 6.123e-9, 1.0, f64::INFINITY, -1.0, 0.0] {
            assert_eq!(quantize_window(w, 0.0).to_bits(), w.to_bits());
            assert_eq!(quantize_window(w, -0.1).to_bits(), w.to_bits());
            // sub-ulp grid (exponent would overflow i32): exact identity,
            // not a panic or a hang
            assert_eq!(quantize_window(w, 1e-9).to_bits(), w.to_bits());
        }
        check("quantized window is conservative within one step", 50, |rng: &mut Rng| {
            let r = [0.02, 0.05, DEFAULT_WINDOW_RESOLUTION, 0.25][rng.below(4)];
            let w = rng.log_range(1e-10, 1e-6);
            let q = quantize_window(w, r);
            assert!(q >= w, "not conservative: {q} < {w} at r={r}");
            assert!(q <= w * (1.0 + r) * (1.0 + 1e-9), "{q} > one step above {w} at r={r}");
            // buckets are fixed points: re-quantizing lands on the
            // same bits (the grouping key is stable)
            assert_eq!(quantize_window(q, r).to_bits(), q.to_bits(), "not idempotent at {w}");
            // monotone: a longer window never gets a shorter bucket
            let w2 = w * rng.range(1.0, 2.0);
            assert!(quantize_window(w2, r) >= q);
        });
    }

    #[test]
    fn fine_size_axis_collapses_window_buckets() {
        // the tentpole claim at plan level: a rows axis whose exact
        // windows all differ shares buckets once quantized.  (The
        // resolution-0 identity itself is carried by
        // quantize_window_contract and the integration singleton test
        // — CharPlan::new delegates to with_resolution(.., 0.0), so
        // comparing the two here would be a tautology.)
        let t = sg40();
        let banks: Vec<_> = quantization_axis(5, 180, 4)
            .iter()
            .map(|cfg| compile(&t, cfg).unwrap())
            .collect();
        let exact: Vec<(u64, u64)> = banks
            .iter()
            .map(|b| CharPlan::new(&t, b).window_bits().unwrap())
            .collect();
        let quant: Vec<(u64, u64)> = banks
            .iter()
            .map(|b| {
                CharPlan::with_resolution(&t, b, DEFAULT_WINDOW_RESOLUTION).window_bits().unwrap()
            })
            .collect();
        for (&(we, re), &(wq, rq)) in exact.iter().zip(&quant) {
            let (we, re) = (f64::from_bits(we), f64::from_bits(re));
            let (wq, rq) = (f64::from_bits(wq), f64::from_bits(rq));
            assert!(wq >= we && wq <= we * (1.0 + DEFAULT_WINDOW_RESOLUTION) * (1.0 + 1e-9));
            assert!(rq >= re && rq <= re * (1.0 + DEFAULT_WINDOW_RESOLUTION) * (1.0 + 1e-9));
        }
        // above the floors every exact window is distinct — the
        // pre-quantization batcher paid one execution per design here
        let (wr_exact, rd_exact) = window_group_counts(&t, &banks, 0.0);
        assert_eq!(wr_exact, banks.len(), "write floors clamp: axis too small");
        assert_eq!(rd_exact, banks.len(), "read floors clamp: axis too small");
        // rows 180..196 span under two 10 % steps, so the bucket grid
        // holds the axis in <= 3 groups; quantization never adds any
        let (wr_q, rd_q) = window_group_counts(&t, &banks, DEFAULT_WINDOW_RESOLUTION);
        assert!(wr_q <= wr_exact && rd_q <= rd_exact);
        assert!(
            wr_q < banks.len() && rd_q < banks.len(),
            "size axis did not collapse: wr {wr_q} rd {rd_q} of {}",
            banks.len()
        );
    }

    #[test]
    fn pull_down_flavors_plan_pull_down_reads() {
        let t = sg40();
        for flavor in [CellFlavor::GcSiSiNn, CellFlavor::GcOsOs] {
            let bank = compile(&t, &Config::new(32, 32, flavor)).unwrap();
            let mut plan = CharPlan::new(&t, &bank);
            plan.absorb_writes(&[engines::WriteResult { sn_final: 0.6, t_wr: 1e-9, sn_peak: 0.65 }])
                .unwrap();
            let rj = plan.read_jobs().unwrap();
            assert!(rj.iter().all(|j| !j.pt.pull_up), "{flavor:?} reads pull-down");
            assert!(rj.iter().all(|j| j.pt.sn_unsel == 0.0));
        }
    }
}
