//! The GCRAM bank compiler — the paper's primary contribution.
//!
//! From a user [`Config`] (word size, number of words, cell flavor,
//! peripheral options) it generates, exactly like OpenGCRAM:
//! * the full hierarchical SPICE netlist of the bank (bitcell array +
//!   Fig. 4 periphery: port address/data blocks, data DFFs, control
//!   logic with the replica delay chain, optional WWL level shifter and
//!   reference generator),
//! * the bank layout (array tiling, periphery placement, power rings)
//!   ready for GDS export, and
//! * the geometric/electrical summary the characterizer consumes
//!   (bitline/wordline parasitics from real wire geometry).
//!
//! Compilation is split into a **geometry phase** and an **electrical
//! binding**: [`Config::struct_key`] projects out exactly the fields
//! that determine geometry, [`compile_structure`] builds the
//! library/netlist/layout/parasitics once per distinct [`StructKey`],
//! and a [`Bank`] is a thin wrapper binding an `Arc<BankStructure>` to
//! the full electrical [`Config`].  A [`CompileCache`] shares the
//! structure across the electrical axis (e.g. the write-VT sweep of
//! Fig. 8c), so a 5×5 size×VT grid pays 5 structure compiles, not 25.

use std::collections::HashMap;
use std::ops::Deref;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::layout::{bank, cells, Library};
use crate::netlist::{Circuit, Netlist};
use crate::tech::{LayerRole, Tech};
use crate::util::{ceil_div, ceil_log2, next_pow2, par_map};

/// Bit-cell flavor (Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum CellFlavor {
    /// 6T SRAM, single port (the comparison baseline).
    Sram6t,
    /// 2T Si-Si gain cell, NMOS write / PMOS read (compiler default).
    GcSiSiNp,
    /// 2T Si-Si gain cell, NMOS-NMOS (legacy active-low RWL).
    GcSiSiNn,
    /// 2T OS-OS gain cell in the BEOL.
    GcOsOs,
}

impl CellFlavor {
    pub fn is_gc(&self) -> bool {
        !matches!(self, CellFlavor::Sram6t)
    }
    pub fn cell_name(&self) -> &'static str {
        match self {
            CellFlavor::Sram6t => "sram6t",
            CellFlavor::GcSiSiNp => "gc2t_sisi",
            CellFlavor::GcSiSiNn => "gc2t_sisi_nn",
            CellFlavor::GcOsOs => "gc2t_osos",
        }
    }
    /// Predischarge (NP) vs precharge (NN / OS / SRAM) read port.
    pub fn pull_up_read(&self) -> bool {
        matches!(self, CellFlavor::GcSiSiNp)
    }
}

/// User configuration (the OpenRAM-style knobs).
#[derive(Debug, Clone)]
pub struct Config {
    pub word_size: usize,
    pub num_words: usize,
    pub flavor: CellFlavor,
    /// Add the WWL level shifter (boosted write wordline).
    pub wwlls: bool,
    /// Override the column-mux factor (None = policy).
    pub mux_factor: Option<usize>,
    /// Write-transistor VT override (retention modulation, Fig. 8c).
    pub write_vt: Option<f64>,
}

/// Hashable identity of a [`Config`] (the f64 VT override is bit-cast)
/// — the key of the DSE evaluation cache ([`crate::dse::EvalCache`]).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ConfigKey {
    pub word_size: usize,
    pub num_words: usize,
    pub flavor: CellFlavor,
    pub wwlls: bool,
    pub mux_factor: Option<usize>,
    pub write_vt_bits: Option<u64>,
}

impl ConfigKey {
    /// Reconstruct the [`Config`] this key identifies.  Keys are
    /// lossless (the VT override is a bit-cast, not a rounding), so
    /// `cfg.key().to_config().key() == cfg.key()` always — the on-disk
    /// evaluation store ([`crate::store`]) relies on this to rebuild
    /// the config of a persisted entry without storing it twice.
    pub fn to_config(&self) -> Config {
        let &ConfigKey { word_size, num_words, flavor, wwlls, mux_factor, write_vt_bits } = self;
        Config {
            word_size,
            num_words,
            flavor,
            wwlls,
            mux_factor,
            write_vt: write_vt_bits.map(f64::from_bits),
        }
    }
}

/// Geometric identity of a [`Config`]: exactly the fields that
/// determine the compiled structure (library, netlist, layout,
/// parasitics, delay-chain stages).  `write_vt` is deliberately absent
/// — it is an electrical knob consumed only by the characterizer, so
/// configs differing only in VT share one [`BankStructure`].  The
/// mux factor is stored **resolved** (policy applied), so an explicit
/// `mux_factor: Some(m)` and a `None` that resolves to the same `m`
/// alias to the same structure.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StructKey {
    pub word_size: usize,
    pub num_words: usize,
    pub flavor: CellFlavor,
    pub wwlls: bool,
    /// Resolved column-mux factor ([`Config::mux_factor`] policy applied).
    pub mux_factor: usize,
}

impl StructKey {
    /// A representative [`Config`] for this structure (no electrical
    /// overrides).  [`compile_structure`] drives the geometry build
    /// through it so `rows()`/`cols()` policy lives in one place.
    pub fn to_config(&self) -> Config {
        let &StructKey { word_size, num_words, flavor, wwlls, mux_factor } = self;
        Config { word_size, num_words, flavor, wwlls, mux_factor: Some(mux_factor), write_vt: None }
    }
}

impl Config {
    pub fn new(word_size: usize, num_words: usize, flavor: CellFlavor) -> Config {
        Config { word_size, num_words, flavor, wwlls: false, mux_factor: None, write_vt: None }
    }

    /// Cache identity: two configs with equal keys compile to the same
    /// bank and characterize identically.  Exhaustive destructuring:
    /// adding a Config field without extending the key is a compile
    /// error, not a silent cache-aliasing bug.
    pub fn key(&self) -> ConfigKey {
        let &Config { word_size, num_words, flavor, wwlls, mux_factor, write_vt } = self;
        ConfigKey {
            word_size,
            num_words,
            flavor,
            wwlls,
            mux_factor,
            write_vt_bits: write_vt.map(f64::to_bits),
        }
    }

    /// Structure identity: two configs with equal struct keys compile
    /// to bitwise-identical geometry (pinned by `tests/structure.rs`).
    /// Exhaustive destructuring: adding a Config field forces a choice
    /// here — geometric (goes in the key) or electrical (explicitly
    /// discarded) — at compile time, not as a silent aliasing bug.
    pub fn struct_key(&self) -> StructKey {
        let &Config {
            word_size,
            num_words,
            flavor,
            wwlls,
            mux_factor: _, // folded into the resolved policy value below
            write_vt: _,   // electrical only: consumed by the characterizer
        } = self;
        StructKey { word_size, num_words, flavor, wwlls, mux_factor: self.mux_factor() }
    }

    pub fn bits(&self) -> usize {
        self.word_size * self.num_words
    }

    /// Column-mux policy: force the array toward a square organization
    /// (paper §V-C): m = 2^round(log2(sqrt(words/word))), min 1.
    pub fn mux_factor(&self) -> usize {
        if let Some(m) = self.mux_factor {
            return m.max(1);
        }
        if self.num_words <= self.word_size {
            return 1;
        }
        let ratio = (self.num_words as f64 / self.word_size as f64).sqrt();
        next_pow2(ratio.round() as usize).clamp(1, 16)
    }

    pub fn cols(&self) -> usize {
        self.word_size * self.mux_factor()
    }

    pub fn rows(&self) -> usize {
        ceil_div(self.num_words, self.mux_factor())
    }

    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.word_size >= 1, "word_size must be >= 1");
        anyhow::ensure!(self.num_words >= 2, "num_words must be >= 2");
        anyhow::ensure!(
            self.num_words % self.mux_factor() == 0,
            "num_words {} not divisible by mux factor {}",
            self.num_words,
            self.mux_factor()
        );
        anyhow::ensure!(self.bits() <= 1 << 22, "bank too large (> 4 Mb)");
        if self.wwlls {
            anyhow::ensure!(self.flavor.is_gc(), "WWLLS only applies to gain cells");
        }
        Ok(())
    }
}

/// The geometry-phase output: netlist + layout + geometry summary,
/// one per distinct [`StructKey`].  Immutable once built and shared by
/// `Arc` across every electrical variant of the same geometry.
pub struct BankStructure {
    /// The structure identity this was compiled from.
    pub key: StructKey,
    pub netlist: Netlist,
    pub library: Library,
    pub layout: bank::BankLayout,
    pub parasitics: Parasitics,
    /// Replica delay-chain stages in the read control (Fig. 7a step).
    pub delay_chain_stages: usize,
}

/// Compiled bank: the electrical [`Config`] bound to its shared
/// [`BankStructure`].  Derefs to the structure, so consumers keep
/// writing `bank.netlist` / `bank.layout` / `bank.parasitics`.
pub struct Bank {
    pub config: Config,
    pub structure: Arc<BankStructure>,
}

impl Deref for Bank {
    type Target = BankStructure;
    fn deref(&self) -> &BankStructure {
        &self.structure
    }
}

/// Extracted electrical summary used by the characterizer.
#[derive(Debug, Clone, Copy)]
pub struct Parasitics {
    /// Storage node capacitance (F).
    pub c_sn: f64,
    /// Write/read bitline capacitance (F), from real wire geometry.
    pub c_wbl: f64,
    pub c_rbl: f64,
    /// Wordline RC (s) for the analytical WL delay.
    pub r_wl: f64,
    pub c_wl: f64,
    /// WWL->SN and RWL->SN coupling caps (F).
    pub c_wwl_sn: f64,
    pub c_rwl_sn: f64,
}

/// Compile a bank: geometry phase ([`compile_structure`]) plus the
/// electrical binding.  Uncached — every call rebuilds the structure;
/// use a [`CompileCache`] to share structures across a sweep.
pub fn compile(tech: &Tech, cfg: &Config) -> crate::Result<Bank> {
    cfg.validate()?;
    let structure = compile_structure(tech, &cfg.struct_key())?;
    Ok(Bank { config: cfg.clone(), structure })
}

/// The geometry phase: build library, netlist, layout, and extracted
/// parasitics for one distinct structure.  Everything here is a pure
/// function of the [`StructKey`] (pinned bitwise by
/// `tests/structure.rs`), which is what makes sharing the result
/// across the electrical axis sound.
pub fn compile_structure(tech: &Tech, key: &StructKey) -> crate::Result<Arc<BankStructure>> {
    let cfg = key.to_config();
    let cfg = &cfg;
    let rows = cfg.rows();
    let cols = cfg.cols();

    let mut lib = Library::default();
    // leaf cells
    let bitcell = match cfg.flavor {
        CellFlavor::Sram6t => cells::sram6t(tech),
        CellFlavor::GcSiSiNp => cells::gc2t_sisi(tech, false),
        CellFlavor::GcSiSiNn => cells::gc2t_sisi(tech, true),
        CellFlavor::GcOsOs => cells::gc2t_osos(tech),
    };
    let leaf_list = vec![
        bitcell.clone(),
        cells::inverter(tech, 1.0),
        cells::inverter(tech, 2.0),
        cells::nand2(tech),
        cells::sense_amp(tech),
        cells::write_driver(tech),
        cells::precharge(tech),
        cells::predischarge(tech),
        cells::column_mux(tech),
        cells::level_shifter(tech),
        cells::tgate(tech),
    ];
    for lc in &leaf_list {
        lib.add(lc.layout.clone());
    }
    let dff = crate::layout::compose::dff(&mut lib, tech)?;

    // ---- netlist ---------------------------------------------------------
    let mut nl = Netlist::default();
    for lc in &leaf_list {
        nl.add(lc.circuit.clone());
    }
    nl.add(dff.circuit.clone());
    nl.add(array_circuit(cfg, &bitcell.circuit));
    nl.add(port_address_circuit(cfg, "write_port_address", rows));
    if cfg.flavor.is_gc() {
        nl.add(port_address_circuit(cfg, "read_port_address", rows));
    }
    nl.add(write_port_data_circuit(cfg));
    nl.add(read_port_data_circuit(cfg));
    nl.add(control_circuit("ctrl_write"));
    nl.add(control_circuit("ctrl_read"));
    nl.add(bank_circuit(cfg));
    nl.top = "bank".into();

    // ---- layout ----------------------------------------------------------
    let b = tech.layer(LayerRole::Boundary);
    let cell_bb = bitcell
        .layout
        .boundary(b)
        .ok_or_else(|| anyhow::anyhow!("bitcell lacks boundary"))?;
    let info = bank::tile_array(&mut lib, tech, "bitcell_array", cfg.flavor.cell_name(), rows, cols, 16, 400)?;

    // periphery block footprints.  Data blocks pitch-match the ~1 um
    // bitcell columns, so the DFF (2.6 um wide) + write driver + sense
    // amp + mux + control fold into multiple standard-cell rows per
    // column: ~24 um of write-port stack and ~18 um of read-port stack
    // per port.  This is what makes the dual-port GCRAM bank LARGER
    // than single-port SRAM at small sizes (Fig. 6a) until the array
    // amortizes it (Fig. 6c crossover beyond 256 Kb).
    let dec_stages = ceil_log2(rows) as i64;
    let addr_w = 12_000 + dec_stages * 560;
    let (wpa_w, rpa_w) = if cfg.flavor.is_gc() {
        (addr_w + if cfg.wwlls { 1100 } else { 0 }, addr_w)
    } else {
        (addr_w, 0)
    };
    let (wpd_h, rpd_h) = if cfg.flavor.is_gc() { (24_000, 18_000) } else { (24_000, 0) };
    let sizes = bank::PeripherySizes {
        wpa: (wpa_w, info.h),
        rpa: (rpa_w, info.h),
        wpd: (info.w, wpd_h),
        rpd: (info.w, rpd_h),
        ctrl: (wpa_w, wpd_h),
    };
    let ring = bank::RingSpec { rails: if cfg.wwlls { 3 } else { 2 }, ..Default::default() };
    let layout = bank::assemble_bank(
        &mut lib,
        tech,
        "bank",
        "bitcell_array",
        info,
        &bank::BankBlocks::default(),
        sizes,
        ring,
        cfg.flavor == CellFlavor::GcOsOs,
    )?;

    // ---- parasitics from real geometry ------------------------------------
    let m2 = tech.wire(LayerRole::Metal2);
    let m3 = tech.wire(LayerRole::Metal3);
    let m2w = tech.rules.layer(LayerRole::Metal2).min_width_nm as f64;
    let bl_len = info.h as f64;
    let wl_len = info.w as f64;
    // wire cap + one junction/gate load per attached cell
    let c_bl_wire = bl_len * m2w * m2.c_area + 2.0 * bl_len * m2.c_fringe;
    let c_junction = tech.c_junction_unit * 2.0;
    let rows_f = rows as f64;
    let cols_f = cols as f64;
    let c_gate = tech.c_gate_unit * 2.0;
    let parasitics = Parasitics {
        c_sn: 1.2e-15,
        c_wbl: c_bl_wire + rows_f * c_junction,
        c_rbl: c_bl_wire + rows_f * c_junction,
        r_wl: wl_len / (cell_bb.h() as f64) * 0.0 + m3.r_sq * wl_len / 60.0,
        c_wl: wl_len * 60.0 * m3.c_area + 2.0 * wl_len * m3.c_fringe + cols_f * c_gate,
        c_wwl_sn: 0.10e-15, // dummy-WL/GND merge optimization (paper §V-A)
        c_rwl_sn: 0.10e-15,
    };

    // replica delay chain: stages quantize the read timing window
    // (tau_stage from the x2 inverter); count covers the BL time
    // constant estimate with one guard stage
    let tau_stage = 25e-12;
    let t_bl_est = parasitics.c_rbl * 0.55 / 20e-6; // coarse I/C slew
    let delay_chain_stages = (t_bl_est / tau_stage).ceil() as usize + 2;

    Ok(Arc::new(BankStructure {
        key: key.clone(),
        netlist: nl,
        library: lib,
        layout,
        parasitics,
        delay_chain_stages,
    }))
}

/// Session-scoped structure cache: one compiled [`BankStructure`] per
/// (tech, [`StructKey`]), shared by `Arc` across every config that
/// maps to it.  Mirrors [`crate::dse::EvalCache`]'s shape — interior
/// mutability plus real hit/compile counters so sweeps can assert the
/// distinct-structure census (compiles == |{struct_key}|, not
/// |configs|) the way `plan_call_counts` pins transient calls.
#[derive(Default)]
pub struct CompileCache {
    map: Mutex<HashMap<(&'static str, StructKey), Arc<BankStructure>>>,
    hits: AtomicUsize,
    compiles: AtomicUsize,
}

impl CompileCache {
    pub fn new() -> CompileCache {
        CompileCache::default()
    }

    /// Distinct structures currently held.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `(hits, compiles)` counters: `hits` counts banks served from an
    /// already-compiled structure (including fan-out within one
    /// [`CompileCache::compile_all`] call); `compiles` counts geometry
    /// builds actually paid.
    pub fn stats(&self) -> (usize, usize) {
        (self.hits.load(Ordering::Relaxed), self.compiles.load(Ordering::Relaxed))
    }

    fn lookup(&self, tech: &Tech, key: &StructKey) -> Option<Arc<BankStructure>> {
        let map = self.map.lock().unwrap_or_else(|p| p.into_inner());
        map.get(&(tech.name, key.clone())).cloned()
    }

    fn insert(&self, tech: &Tech, structure: Arc<BankStructure>) {
        let mut map = self.map.lock().unwrap_or_else(|p| p.into_inner());
        map.insert((tech.name, structure.key.clone()), structure);
    }

    /// Compile one bank through the cache: the structure is built at
    /// most once per (tech, struct key) and then shared by `Arc`.
    pub fn compile(&self, tech: &Tech, cfg: &Config) -> crate::Result<Bank> {
        cfg.validate()?;
        let key = cfg.struct_key();
        let structure = match self.lookup(tech, &key) {
            Some(s) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                s
            }
            None => {
                let s = compile_structure(tech, &key)?;
                self.compiles.fetch_add(1, Ordering::Relaxed);
                self.insert(tech, s.clone());
                s
            }
        };
        Ok(Bank { config: cfg.clone(), structure })
    }

    /// Compile a batch: dedup by struct key **before** the parallel
    /// geometry phase, compile only the cold distinct structures, then
    /// fan the shared `Arc`s out across the (electrical) batch in
    /// input order.  This is the sweep hot path — a 5×5 size×VT grid
    /// pays exactly 5 compiles here.
    pub fn compile_all(&self, tech: &Tech, cfgs: &[&Config], workers: usize) -> crate::Result<Vec<Bank>> {
        for cfg in cfgs {
            cfg.validate()?;
        }
        let keys: Vec<StructKey> = cfgs.iter().map(|c| c.struct_key()).collect();
        // cold distinct keys, first-appearance order
        let mut cold: Vec<StructKey> = Vec::new();
        for key in &keys {
            if !cold.contains(key) && self.lookup(tech, key).is_none() {
                cold.push(key.clone());
            }
        }
        let built = par_map(&cold, workers, |key| compile_structure(tech, key));
        for s in built {
            let s = s?;
            self.compiles.fetch_add(1, Ordering::Relaxed);
            self.insert(tech, s);
        }
        self.hits.fetch_add(cfgs.len() - cold.len(), Ordering::Relaxed);
        keys.into_iter()
            .zip(cfgs)
            .map(|(key, cfg)| {
                let structure = self
                    .lookup(tech, &key)
                    .expect("structure compiled or cached above");
                Ok(Bank { config: (*cfg).clone(), structure })
            })
            .collect()
    }
}

fn array_circuit(cfg: &Config, bitcell: &Circuit) -> Circuit {
    let rows = cfg.rows();
    let cols = cfg.cols();
    let mut c = Circuit::new("bitcell_array", &[]);
    let gc = cfg.flavor.is_gc();
    let mut ports: Vec<String> = Vec::new();
    for r in 0..rows {
        if gc {
            ports.push(format!("wwl{r}"));
            ports.push(format!("rwl{r}"));
        } else {
            ports.push(format!("wl{r}"));
        }
    }
    for col in 0..cols {
        if gc {
            ports.push(format!("wbl{col}"));
            ports.push(format!("rbl{col}"));
        } else {
            ports.push(format!("bl{col}"));
            ports.push(format!("blb{col}"));
        }
    }
    ports.push("vdd".into());
    ports.push("gnd".into());
    c.ports = ports;
    for r in 0..rows {
        for col in 0..cols {
            let pins: Vec<String> = if gc {
                // bitcell ports: wbl, wwl, rbl, rwl [, gnd]
                let mut p = vec![
                    format!("wbl{col}"),
                    format!("wwl{r}"),
                    format!("rbl{col}"),
                    format!("rwl{r}"),
                ];
                if bitcell.ports.len() == 5 {
                    p.push("gnd".into());
                }
                p
            } else {
                // sram ports: bl, blb, wl, vdd, gnd
                vec![
                    format!("bl{col}"),
                    format!("blb{col}"),
                    format!("wl{r}"),
                    "vdd".into(),
                    "gnd".into(),
                ]
            };
            c.inst_owned(format!("x{r}_{col}"), &bitcell.name, pins);
        }
    }
    c
}

fn port_address_circuit(cfg: &Config, name: &str, rows: usize) -> Circuit {
    // decoder tree (nand2 + inv per row) + wl drivers (+ level shifter)
    let mut c = Circuit::new(name, &["vdd", "gnd"]);
    let abits = ceil_log2(rows).max(1) as usize;
    for i in 0..abits {
        c.ports.push(format!("a{i}"));
    }
    for r in 0..rows {
        c.ports.push(format!("wl{r}"));
    }
    c.ports.push("en".into());
    for r in 0..rows {
        c.inst(
            format!("xdec{r}"),
            "nand2",
            &[&format!("a{}", r % abits), "en", &format!("dec{r}"), "vdd", "gnd"],
        );
        if cfg.wwlls && name.starts_with("write") {
            c.inst(
                format!("xls{r}"),
                "level_shifter",
                &[&format!("dec{r}"), &format!("dec{r}"), &format!("wl{r}"), "vpp", "gnd"],
            );
        } else {
            c.inst(
                format!("xdrv{r}"),
                "inv_x2",
                &[&format!("dec{r}"), &format!("wl{r}"), "vdd", "gnd"],
            );
        }
    }
    c
}

fn write_port_data_circuit(cfg: &Config) -> Circuit {
    let mut c = Circuit::new("write_port_data", &["clk", "en", "vdd", "gnd"]);
    for i in 0..cfg.word_size {
        c.ports.push(format!("din{i}"));
        c.ports.push(format!("wbl{i}"));
    }
    for i in 0..cfg.word_size {
        c.inst(
            format!("xdff{i}"),
            "dff",
            &[&format!("din{i}"), "clk", &format!("d{i}"), "vdd", "gnd"],
        );
        c.inst(
            format!("xinv{i}"),
            "inv_x1",
            &[&format!("d{i}"), &format!("db{i}"), "vdd", "gnd"],
        );
        c.inst(
            format!("xwd{i}"),
            "write_driver",
            &[&format!("db{i}"), "en", &format!("wbl{i}"), "vdd", "gnd"],
        );
    }
    c
}

fn read_port_data_circuit(cfg: &Config) -> Circuit {
    let mut c = Circuit::new("read_port_data", &["en", "vref", "vdd", "gnd"]);
    let mux = cfg.mux_factor();
    for i in 0..cfg.word_size {
        c.ports.push(format!("rbl{i}"));
        c.ports.push(format!("dout{i}"));
    }
    let pre_cell = if cfg.flavor.pull_up_read() { "predischarge" } else { "precharge" };
    for i in 0..cfg.word_size {
        c.inst(
            format!("xpre{i}"),
            pre_cell,
            &["en", &format!("rbl{i}"), "vdd", "gnd"],
        );
        if mux > 1 {
            c.inst(
                format!("xmux{i}"),
                "column_mux",
                &["en", &format!("rbl{i}"), &format!("mbl{i}"), "vdd", "gnd"],
            );
            c.inst(
                format!("xsa{i}"),
                "sense_amp",
                &[&format!("mbl{i}"), "vref", "en", &format!("dout{i}"), "vdd", "gnd"],
            );
        } else {
            c.inst(
                format!("xsa{i}"),
                "sense_amp",
                &[&format!("rbl{i}"), "vref", "en", &format!("dout{i}"), "vdd", "gnd"],
            );
        }
    }
    c
}

fn control_circuit(name: &str) -> Circuit {
    // clock buffer + replica delay chain of 6 inverters (netlist view;
    // the stage count used for timing is computed per-bank)
    let mut c = Circuit::new(name, &["clk", "en", "sae", "vdd", "gnd"]);
    c.inst("xbuf", "inv_x2", &["clk", "clkb", "vdd", "gnd"]);
    c.inst("xen", "inv_x2", &["clkb", "en", "vdd", "gnd"]);
    let mut prev = "en".to_string();
    for i in 0..6 {
        let next = if i == 5 { "sae".to_string() } else { format!("dly{i}") };
        c.inst(format!("xd{i}"), "inv_x1", &[&prev, &next, "vdd", "gnd"]);
        prev = next;
    }
    c
}

fn bank_circuit(cfg: &Config) -> Circuit {
    let mut c = Circuit::new("bank", &["clk", "vdd", "gnd"]);
    let gc = cfg.flavor.is_gc();
    let rows = cfg.rows();
    let cols = cfg.cols();
    let abits = ceil_log2(rows).max(1) as usize;
    for i in 0..abits {
        c.ports.push(format!("addr{i}"));
    }
    for i in 0..cfg.word_size {
        c.ports.push(format!("din{i}"));
        c.ports.push(format!("dout{i}"));
    }
    // array
    let mut pins: Vec<String> = Vec::new();
    for r in 0..rows {
        if gc {
            pins.push(format!("wwl{r}"));
            pins.push(format!("rwl{r}"));
        } else {
            pins.push(format!("wl{r}"));
        }
    }
    for col in 0..cols {
        if gc {
            pins.push(format!("wbl{col}"));
            pins.push(format!("rbl{col}"));
        } else {
            pins.push(format!("bl{col}"));
            pins.push(format!("blb{col}"));
        }
    }
    pins.push("vdd".into());
    pins.push("gnd".into());
    c.inst_owned("xarr", "bitcell_array", pins);
    // address ports
    let mut wpa_pins: Vec<String> = vec!["vdd".into(), "gnd".into()];
    for i in 0..abits {
        wpa_pins.push(format!("addr{i}"));
    }
    for r in 0..rows {
        wpa_pins.push(if gc { format!("wwl{r}") } else { format!("wl{r}") });
    }
    wpa_pins.push("wen".into());
    c.inst_owned("xwpa", "write_port_address", wpa_pins);
    if gc {
        let mut rpa_pins: Vec<String> = vec!["vdd".into(), "gnd".into()];
        for i in 0..abits {
            rpa_pins.push(format!("addr{i}"));
        }
        for r in 0..rows {
            rpa_pins.push(format!("rwl{r}"));
        }
        rpa_pins.push("ren".into());
        c.inst_owned("xrpa", "read_port_address", rpa_pins);
    }
    // data ports
    let mut wpd_pins: Vec<String> = vec!["clk".into(), "wen".into(), "vdd".into(), "gnd".into()];
    for i in 0..cfg.word_size {
        wpd_pins.push(format!("din{i}"));
        wpd_pins.push(if gc { format!("wbl{i}") } else { format!("bl{i}") });
    }
    c.inst_owned("xwpd", "write_port_data", wpd_pins);
    let mut rpd_pins: Vec<String> = vec!["ren".into(), "vref".into(), "vdd".into(), "gnd".into()];
    for i in 0..cfg.word_size {
        rpd_pins.push(if gc { format!("rbl{i}") } else { format!("blb{i}") });
        rpd_pins.push(format!("dout{i}"));
    }
    c.inst_owned("xrpd", "read_port_data", rpd_pins);
    // control
    c.inst("xcw", "ctrl_write", &["clk", "wen", "wsae", "vdd", "gnd"]);
    c.inst("xcr", "ctrl_read", &["clk", "ren", "vref", "vdd", "gnd"]);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::sg40;

    #[test]
    fn config_policy() {
        // square config: no mux
        let c = Config::new(32, 32, CellFlavor::GcSiSiNp);
        assert_eq!(c.mux_factor(), 1);
        assert_eq!((c.rows(), c.cols()), (32, 32));
        // tall config: mux folds words into columns
        let c = Config::new(8, 512, CellFlavor::GcSiSiNp);
        assert!(c.mux_factor() >= 4);
        assert_eq!(c.rows() * c.cols(), c.bits());
        // invalid configs rejected
        assert!(Config::new(0, 32, CellFlavor::Sram6t).validate().is_err());
        let mut bad = Config::new(32, 32, CellFlavor::Sram6t);
        bad.wwlls = true;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn compile_small_gc_bank() {
        let t = sg40();
        let cfg = Config::new(16, 16, CellFlavor::GcSiSiNp);
        let bank = compile(&t, &cfg).unwrap();
        // netlist is complete and flattenable
        let flat = bank.netlist.flatten().unwrap();
        // 256 cells x 2T plus periphery
        assert!(flat.mos_count() > 512, "{}", flat.mos_count());
        // layout summary sane
        assert!(bank.layout.total_area_um2() > bank.layout.array_area_um2());
        assert!(bank.parasitics.c_rbl > 1e-15);
        assert!(bank.delay_chain_stages >= 2);
    }

    #[test]
    fn sram_bank_netlist_flattens() {
        let t = sg40();
        let cfg = Config::new(16, 16, CellFlavor::Sram6t);
        let bank = compile(&t, &cfg).unwrap();
        let flat = bank.netlist.flatten().unwrap();
        assert!(flat.mos_count() > 256 * 6);
    }

    #[test]
    fn wwlls_adds_ring_area() {
        let t = sg40();
        let base = compile(&t, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap();
        let mut cfg = Config::new(32, 32, CellFlavor::GcSiSiNp);
        cfg.wwlls = true;
        let ls = compile(&t, &cfg).unwrap();
        assert!(ls.layout.total_area_um2() > base.layout.total_area_um2());
    }

    #[test]
    fn os_bank_is_smaller_than_sram_bank() {
        // Fig. 6(a): OS-OS banks < SRAM banks (BEOL array over periphery)
        let t = sg40();
        let os = compile(&t, &Config::new(32, 32, CellFlavor::GcOsOs)).unwrap();
        let sr = compile(&t, &Config::new(32, 32, CellFlavor::Sram6t)).unwrap();
        assert!(os.layout.total_area_um2() < sr.layout.total_area_um2());
    }

    #[test]
    fn bitline_cap_grows_with_rows() {
        let t = sg40();
        let small = compile(&t, &Config::new(32, 32, CellFlavor::GcSiSiNp)).unwrap();
        let tall = compile(&t, &Config::new(32, 128, CellFlavor::GcSiSiNp)).unwrap();
        assert!(tall.parasitics.c_rbl > small.parasitics.c_rbl);
    }
}
