//! SPICE netlist IR: hierarchical circuits, flattening, diffing, and a
//! SPICE-text emitter/parser ([`spice`]).
//!
//! Net and instance names are plain strings; hierarchy flattening uses
//! `inst.net` dotted names like OpenRAM's trimmed netlists.  Ports
//! connect positionally, SPICE-style.

pub mod spice;

use std::collections::BTreeMap;

/// A primitive device instance.
#[derive(Debug, Clone, PartialEq)]
pub enum Device {
    /// MOSFET: drain, gate, source, bulk + card name + geometry.
    Mos {
        name: String,
        d: String,
        g: String,
        s: String,
        b: String,
        card: String,
        w_over_l: f64,
    },
    Res {
        name: String,
        a: String,
        b: String,
        ohms: f64,
    },
    Cap {
        name: String,
        a: String,
        b: String,
        farads: f64,
    },
    /// Subcircuit instance: pins connect positionally to the
    /// referenced circuit's ports.
    Inst {
        name: String,
        cell: String,
        pins: Vec<String>,
    },
}

impl Device {
    pub fn name(&self) -> &str {
        match self {
            Device::Mos { name, .. }
            | Device::Res { name, .. }
            | Device::Cap { name, .. }
            | Device::Inst { name, .. } => name,
        }
    }
}

/// One circuit (SPICE .subckt).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Circuit {
    pub name: String,
    pub ports: Vec<String>,
    pub devices: Vec<Device>,
}

impl Circuit {
    pub fn new(name: impl Into<String>, ports: &[&str]) -> Circuit {
        Circuit {
            name: name.into(),
            ports: ports.iter().map(|s| s.to_string()).collect(),
            devices: Vec::new(),
        }
    }

    pub fn mos(
        &mut self,
        name: impl Into<String>,
        d: &str,
        g: &str,
        s: &str,
        b: &str,
        card: &str,
        w_over_l: f64,
    ) {
        self.devices.push(Device::Mos {
            name: name.into(),
            d: d.into(),
            g: g.into(),
            s: s.into(),
            b: b.into(),
            card: card.into(),
            w_over_l,
        });
    }

    pub fn cap(&mut self, name: impl Into<String>, a: &str, b: &str, farads: f64) {
        self.devices.push(Device::Cap { name: name.into(), a: a.into(), b: b.into(), farads });
    }

    pub fn res(&mut self, name: impl Into<String>, a: &str, b: &str, ohms: f64) {
        self.devices.push(Device::Res { name: name.into(), a: a.into(), b: b.into(), ohms });
    }

    pub fn inst(&mut self, name: impl Into<String>, cell: &str, pins: &[&str]) {
        self.devices.push(Device::Inst {
            name: name.into(),
            cell: cell.into(),
            pins: pins.iter().map(|s| s.to_string()).collect(),
        });
    }

    pub fn inst_owned(&mut self, name: impl Into<String>, cell: &str, pins: Vec<String>) {
        self.devices.push(Device::Inst { name: name.into(), cell: cell.into(), pins });
    }

    /// Count primitive devices (non-recursive).
    pub fn primitive_count(&self) -> usize {
        self.devices.iter().filter(|d| !matches!(d, Device::Inst { .. })).count()
    }

    pub fn mos_count(&self) -> usize {
        self.devices.iter().filter(|d| matches!(d, Device::Mos { .. })).count()
    }
}

/// A library of circuits with a designated top.
#[derive(Debug, Clone, Default)]
pub struct Netlist {
    pub cells: BTreeMap<String, Circuit>,
    pub top: String,
}

impl Netlist {
    pub fn add(&mut self, c: Circuit) {
        self.cells.insert(c.name.clone(), c);
    }

    pub fn top_circuit(&self) -> Option<&Circuit> {
        self.cells.get(&self.top)
    }

    /// Fully flatten `top` into a circuit of primitives only.
    /// Internal nets of instance `x1` become `x1.<net>`.
    pub fn flatten(&self) -> crate::Result<Circuit> {
        let top = self
            .cells
            .get(&self.top)
            .ok_or_else(|| anyhow::anyhow!("top cell '{}' not found", self.top))?;
        let mut out = Circuit::new(format!("{}_flat", top.name), &[]);
        out.ports = top.ports.clone();
        let mut stack: Vec<String> = vec![self.top.clone()];
        self.flatten_into(top, "", &mut out, &mut stack)?;
        Ok(out)
    }

    fn flatten_into(
        &self,
        c: &Circuit,
        prefix: &str,
        out: &mut Circuit,
        stack: &mut Vec<String>,
    ) -> crate::Result<()> {
        anyhow::ensure!(stack.len() <= 64, "hierarchy too deep (cycle?): {stack:?}");
        let map_net = |n: &str, port_map: Option<&BTreeMap<String, String>>| -> String {
            if let Some(pm) = port_map {
                if let Some(mapped) = pm.get(n) {
                    return mapped.clone();
                }
            }
            if prefix.is_empty() {
                n.to_string()
            } else {
                format!("{prefix}.{n}")
            }
        };
        for d in &c.devices {
            match d {
                Device::Inst { name, cell, pins } => {
                    let sub = self
                        .cells
                        .get(cell)
                        .ok_or_else(|| anyhow::anyhow!("instance {name}: cell '{cell}' not found"))?;
                    anyhow::ensure!(
                        sub.ports.len() == pins.len(),
                        "instance {name} of {cell}: {} pins vs {} ports",
                        pins.len(),
                        sub.ports.len()
                    );
                    // map sub's ports to this level's nets
                    let pm: BTreeMap<String, String> = sub
                        .ports
                        .iter()
                        .cloned()
                        .zip(pins.iter().map(|p| map_net(p, None)))
                        .collect();
                    let sub_prefix = if prefix.is_empty() {
                        name.clone()
                    } else {
                        format!("{prefix}.{name}")
                    };
                    stack.push(cell.clone());
                    self.flatten_inst(sub, &sub_prefix, &pm, out, stack)?;
                    stack.pop();
                }
                prim => out.devices.push(rename_prim(prim, prefix, &|n| map_net(n, None))),
            }
        }
        Ok(())
    }

    fn flatten_inst(
        &self,
        c: &Circuit,
        prefix: &str,
        port_map: &BTreeMap<String, String>,
        out: &mut Circuit,
        stack: &mut Vec<String>,
    ) -> crate::Result<()> {
        anyhow::ensure!(stack.len() <= 64, "hierarchy too deep (cycle?): {stack:?}");
        let map_net = |n: &str| -> String {
            if let Some(mapped) = port_map.get(n) {
                mapped.clone()
            } else {
                format!("{prefix}.{n}")
            }
        };
        for d in &c.devices {
            match d {
                Device::Inst { name, cell, pins } => {
                    let sub = self
                        .cells
                        .get(cell)
                        .ok_or_else(|| anyhow::anyhow!("instance {name}: cell '{cell}' not found"))?;
                    anyhow::ensure!(
                        sub.ports.len() == pins.len(),
                        "instance {name} of {cell}: {} pins vs {} ports",
                        pins.len(),
                        sub.ports.len()
                    );
                    let pm: BTreeMap<String, String> = sub
                        .ports
                        .iter()
                        .cloned()
                        .zip(pins.iter().map(|p| map_net(p)))
                        .collect();
                    let sub_prefix = format!("{prefix}.{name}");
                    stack.push(cell.clone());
                    self.flatten_inst(sub, &sub_prefix, &pm, out, stack)?;
                    stack.pop();
                }
                prim => out.devices.push(rename_prim(prim, prefix, &map_net)),
            }
        }
        Ok(())
    }

    /// Total primitive count after (virtual) flattening.
    pub fn flat_device_count(&self) -> crate::Result<usize> {
        Ok(self.flatten()?.devices.len())
    }
}

fn rename_prim(d: &Device, prefix: &str, map_net: &dyn Fn(&str) -> String) -> Device {
    let pname = |n: &str| {
        if prefix.is_empty() {
            n.to_string()
        } else {
            format!("{prefix}.{n}")
        }
    };
    match d {
        Device::Mos { name, d, g, s, b, card, w_over_l } => Device::Mos {
            name: pname(name),
            d: map_net(d),
            g: map_net(g),
            s: map_net(s),
            b: map_net(b),
            card: card.clone(),
            w_over_l: *w_over_l,
        },
        Device::Res { name, a, b, ohms } => Device::Res {
            name: pname(name),
            a: map_net(a),
            b: map_net(b),
            ohms: *ohms,
        },
        Device::Cap { name, a, b, farads } => Device::Cap {
            name: pname(name),
            a: map_net(a),
            b: map_net(b),
            farads: *farads,
        },
        Device::Inst { .. } => unreachable!("instances handled by caller"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inv() -> Circuit {
        let mut c = Circuit::new("inv", &["a", "y", "vdd", "gnd"]);
        c.mos("mp", "y", "a", "vdd", "vdd", "si_pmos", 2.0);
        c.mos("mn", "y", "a", "gnd", "gnd", "si_nmos", 1.0);
        c
    }

    #[test]
    fn flatten_two_levels() {
        let mut nl = Netlist::default();
        nl.add(inv());
        let mut buf = Circuit::new("buf", &["a", "y", "vdd", "gnd"]);
        buf.inst("x1", "inv", &["a", "mid", "vdd", "gnd"]);
        buf.inst("x2", "inv", &["mid", "y", "vdd", "gnd"]);
        nl.add(buf);
        let mut top = Circuit::new("top", &["in", "out", "vdd", "gnd"]);
        top.inst("xb", "buf", &["in", "out", "vdd", "gnd"]);
        nl.add(top);
        nl.top = "top".into();

        let flat = nl.flatten().unwrap();
        assert_eq!(flat.devices.len(), 4);
        // port nets survive, internal nets are dotted
        let nets: Vec<String> = flat
            .devices
            .iter()
            .filter_map(|d| match d {
                Device::Mos { d, .. } => Some(d.clone()),
                _ => None,
            })
            .collect();
        assert!(nets.contains(&"xb.mid".to_string()), "{nets:?}");
        assert!(nets.contains(&"out".to_string()));
    }

    #[test]
    fn flatten_detects_missing_cell() {
        let mut nl = Netlist::default();
        let mut top = Circuit::new("top", &[]);
        top.inst("x1", "nope", &[]);
        nl.add(top);
        nl.top = "top".into();
        assert!(nl.flatten().is_err());
    }

    #[test]
    fn flatten_detects_pin_mismatch() {
        let mut nl = Netlist::default();
        nl.add(inv());
        let mut top = Circuit::new("top", &[]);
        top.inst("x1", "inv", &["a", "y"]); // wrong arity
        nl.add(top);
        nl.top = "top".into();
        assert!(nl.flatten().is_err());
    }

    #[test]
    fn flatten_preserves_device_count() {
        let mut nl = Netlist::default();
        nl.add(inv());
        let mut arr = Circuit::new("arr", &["vdd", "gnd"]);
        for i in 0..10 {
            arr.inst(format!("x{i}"), "inv", &["in", "out", "vdd", "gnd"]);
        }
        nl.add(arr);
        nl.top = "arr".into();
        assert_eq!(nl.flat_device_count().unwrap(), 20);
    }
}
