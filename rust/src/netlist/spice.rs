//! SPICE text emitter and a parser for importing custom cells
//! (OpenRAM's "users can import customized memory cells" flow,
//! paper §III-A).
//!
//! Emitted format: one `.subckt` per circuit, `M`/`R`/`C`/`X` cards,
//! `W/L` expressed as a dimensionless `wl=` parameter matched to the
//! device-card convention.  The parser accepts the same dialect plus
//! `+` continuation lines, `*` comments, and unit suffixes
//! (f, p, n, u, m, k, meg, g).

use super::{Circuit, Device, Netlist};

/// Emit a whole netlist (referenced cells first, top last).
pub fn emit(nl: &Netlist) -> String {
    let mut out = String::new();
    out.push_str("* OpenGCRAM-RS generated netlist\n");
    // deterministic order: non-top cells alphabetically, then top
    for (name, c) in &nl.cells {
        if *name != nl.top {
            emit_circuit(c, &mut out);
        }
    }
    if let Some(top) = nl.cells.get(&nl.top) {
        emit_circuit(top, &mut out);
    }
    out
}

pub fn emit_circuit(c: &Circuit, out: &mut String) {
    out.push_str(&format!(".subckt {} {}\n", c.name, c.ports.join(" ")));
    for d in &c.devices {
        match d {
            Device::Mos { name, d, g, s, b, card, w_over_l } => {
                out.push_str(&format!("M{name} {d} {g} {s} {b} {card} wl={w_over_l}\n"));
            }
            Device::Res { name, a, b, ohms } => {
                out.push_str(&format!("R{name} {a} {b} {}\n", fmt_si(*ohms)));
            }
            Device::Cap { name, a, b, farads } => {
                out.push_str(&format!("C{name} {a} {b} {}\n", fmt_si(*farads)));
            }
            Device::Inst { name, cell, pins } => {
                out.push_str(&format!("X{name} {} {cell}\n", pins.join(" ")));
            }
        }
    }
    out.push_str(&format!(".ends {}\n", c.name));
}

/// SI-suffixed value formatter for R/C cards.
fn fmt_si(v: f64) -> String {
    let (s, suf) = if v == 0.0 {
        (0.0, "")
    } else {
        let a = v.abs();
        if a >= 1e9 {
            (v / 1e9, "g")
        } else if a >= 1e6 {
            (v / 1e6, "meg")
        } else if a >= 1e3 {
            (v / 1e3, "k")
        } else if a >= 1.0 {
            (v, "")
        } else if a >= 1e-3 {
            (v * 1e3, "m")
        } else if a >= 1e-6 {
            (v * 1e6, "u")
        } else if a >= 1e-9 {
            (v * 1e9, "n")
        } else if a >= 1e-12 {
            (v * 1e12, "p")
        } else {
            (v * 1e15, "f")
        }
    };
    format!("{s}{suf}")
}

/// Parse an SI-suffixed number ("4.5p", "10k", "2meg").
pub fn parse_si(s: &str) -> Option<f64> {
    let low = s.to_ascii_lowercase();
    let (num, mult) = if let Some(p) = low.strip_suffix("meg") {
        (p, 1e6)
    } else if let Some(p) = low.strip_suffix('f') {
        (p, 1e-15)
    } else if let Some(p) = low.strip_suffix('p') {
        (p, 1e-12)
    } else if let Some(p) = low.strip_suffix('n') {
        (p, 1e-9)
    } else if let Some(p) = low.strip_suffix('u') {
        (p, 1e-6)
    } else if let Some(p) = low.strip_suffix('m') {
        (p, 1e-3)
    } else if let Some(p) = low.strip_suffix('k') {
        (p, 1e3)
    } else if let Some(p) = low.strip_suffix('g') {
        (p, 1e9)
    } else {
        (low.as_str(), 1.0)
    };
    num.parse::<f64>().ok().map(|v| v * mult)
}

/// Parse SPICE text into a [`Netlist`] (top = last .subckt).
pub fn parse(text: &str) -> crate::Result<Netlist> {
    let mut nl = Netlist::default();
    let mut cur: Option<Circuit> = None;

    // join continuation lines
    let mut lines: Vec<String> = Vec::new();
    for raw in text.lines() {
        let line = raw.trim();
        if line.starts_with('+') {
            if let Some(last) = lines.last_mut() {
                last.push(' ');
                last.push_str(line.trim_start_matches('+'));
            }
        } else {
            lines.push(line.to_string());
        }
    }

    for (ln, line) in lines.iter().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('*') {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        let kw = toks[0].to_ascii_lowercase();
        if kw == ".subckt" {
            anyhow::ensure!(cur.is_none(), "line {}: nested .subckt", ln + 1);
            anyhow::ensure!(toks.len() >= 2, "line {}: .subckt needs a name", ln + 1);
            let mut c = Circuit::new(toks[1], &[]);
            c.ports = toks[2..].iter().map(|s| s.to_string()).collect();
            cur = Some(c);
        } else if kw.starts_with(".ends") {
            let c = cur.take().ok_or_else(|| anyhow::anyhow!("line {}: .ends without .subckt", ln + 1))?;
            nl.top = c.name.clone();
            nl.add(c);
        } else if let Some(c) = cur.as_mut() {
            parse_card(c, &toks, ln + 1)?;
        } else {
            anyhow::bail!("line {}: device card outside .subckt: {line}", ln + 1);
        }
    }
    anyhow::ensure!(cur.is_none(), "unterminated .subckt");
    Ok(nl)
}

fn parse_card(c: &mut Circuit, toks: &[&str], ln: usize) -> crate::Result<()> {
    let head = toks[0];
    let kind = head.chars().next().unwrap().to_ascii_uppercase();
    let name = &head[1..];
    match kind {
        'M' => {
            anyhow::ensure!(toks.len() >= 6, "line {ln}: MOS card needs d g s b model");
            let mut wl = 1.0;
            for t in &toks[6..] {
                if let Some(v) = t.to_ascii_lowercase().strip_prefix("wl=") {
                    wl = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("line {ln}: bad wl= value"))?;
                }
            }
            c.mos(name, toks[1], toks[2], toks[3], toks[4], toks[5], wl);
        }
        'R' => {
            anyhow::ensure!(toks.len() >= 4, "line {ln}: R card needs a b value");
            let v = parse_si(toks[3]).ok_or_else(|| anyhow::anyhow!("line {ln}: bad R value"))?;
            c.res(name, toks[1], toks[2], v);
        }
        'C' => {
            anyhow::ensure!(toks.len() >= 4, "line {ln}: C card needs a b value");
            let v = parse_si(toks[3]).ok_or_else(|| anyhow::anyhow!("line {ln}: bad C value"))?;
            c.cap(name, toks[1], toks[2], v);
        }
        'X' => {
            anyhow::ensure!(toks.len() >= 2, "line {ln}: X card needs pins + cell");
            let cell = toks[toks.len() - 1];
            let pins: Vec<&str> = toks[1..toks.len() - 1].to_vec();
            c.inst(name, cell, &pins);
        }
        _ => anyhow::bail!("line {ln}: unsupported card '{head}'"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Netlist {
        let mut nl = Netlist::default();
        let mut c = Circuit::new("gc2t", &["wbl", "wwl", "rbl", "rwl", "gnd"]);
        c.mos("mw", "sn", "wwl", "wbl", "gnd", "si_nmos", 2.0);
        c.mos("mr", "rbl", "sn", "rwl", "gnd", "si_pmos", 2.0);
        c.cap("csn", "sn", "gnd", 1.2e-15);
        nl.add(c);
        nl.top = "gc2t".into();
        nl
    }

    #[test]
    fn roundtrip() {
        let nl = sample();
        let text = emit(&nl);
        let back = parse(&text).unwrap();
        assert_eq!(back.top, "gc2t");
        let c = back.top_circuit().unwrap();
        assert_eq!(c.ports, nl.top_circuit().unwrap().ports);
        assert_eq!(c.devices, nl.top_circuit().unwrap().devices);
    }

    #[test]
    fn si_suffixes() {
        assert_eq!(parse_si("1.5k").unwrap(), 1500.0);
        assert_eq!(parse_si("2meg").unwrap(), 2e6);
        assert!((parse_si("4.5p").unwrap() - 4.5e-12).abs() < 1e-24);
        assert!((parse_si("1.2f").unwrap() - 1.2e-15).abs() < 1e-27);
        assert_eq!(parse_si("10").unwrap(), 10.0);
        assert!(parse_si("abc").is_none());
    }

    #[test]
    fn continuation_and_comments() {
        let text = "* hello\n.subckt t a b\nMx1 a b\n+ 0 0 si_nmos wl=3\n.ends t\n";
        let nl = parse(text).unwrap();
        let c = nl.top_circuit().unwrap();
        match &c.devices[0] {
            Device::Mos { w_over_l, card, .. } => {
                assert_eq!(*w_over_l, 3.0);
                assert_eq!(card, "si_nmos");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse(".subckt a\nMx a\n.ends").is_err());
        assert!(parse("Mx a b c d m").is_err());
        assert!(parse(".subckt a b\n").is_err());
        assert!(parse(".subckt a\nQ1 a b c\n.ends").is_err());
    }

    #[test]
    fn emit_is_deterministic() {
        let a = emit(&sample());
        let b = emit(&sample());
        assert_eq!(a, b);
    }
}
