//! Table / figure renderers: ASCII tables for the terminal and CSV
//! series matching the paper's figures (the benches tee these).

use std::fmt::Write as _;

/// Simple fixed-width ASCII table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "| {:width$} ", c, width = widths[i]);
            }
            out.push_str("|\n");
        };
        line(&mut out, &self.headers);
        for (i, w) in widths.iter().enumerate() {
            let _ = write!(out, "|{}", "-".repeat(w + 2));
            if i == widths.len() - 1 {
                out.push_str("|\n");
            }
        }
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }
}

/// CSV writer for figure series.
pub fn csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = headers.join(",");
    out.push('\n');
    for r in rows {
        out.push_str(&r.join(","));
        out.push('\n');
    }
    out
}

/// Format helpers used across benches.
pub fn mhz(f_hz: f64) -> String {
    format!("{:.1}", f_hz / 1e6)
}

/// Bandwidth in **gigabits** per second.  The `bandwidth_bps` figures
/// are bits/s and every table labels this column Gb/s (regression: the
/// divisor was `8e9` — gigabytes — which is why all call sites had
/// bypassed the helper with an inline `/ 1e9`).
pub fn gbps(bps: f64) -> String {
    format!("{:.2}", bps / 1e9)
}

pub fn um2(a: f64) -> String {
    format!("{:.0}", a)
}

pub fn sci(v: f64) -> String {
    if !v.is_finite() {
        return "inf".into();
    }
    format!("{v:.3e}")
}

/// `mean ± sigma` engineering-notation band — the sigma-band cell the
/// Monte-Carlo yield tables and `bin/figures` print.  A NaN mean (no
/// functional samples) renders as a bare dash; a NaN or zero sigma
/// collapses to the mean alone (e.g. SRAM's infinite retention, or a
/// zero-sigma model).
pub fn band(mean: f64, sigma: f64, unit: &str) -> String {
    if mean.is_nan() {
        return "-".into();
    }
    if sigma.is_nan() || sigma == 0.0 {
        return crate::util::eng(mean, unit);
    }
    format!("{} ± {}", crate::util::eng(mean, unit), crate::util::eng(sigma, unit))
}

/// A yield fraction as a percentage with one decimal (`0.9961` →
/// `"99.6%"`).
pub fn pct(p: f64) -> String {
    if p.is_nan() {
        return "-".into();
    }
    format!("{:.1}%", p * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["size", "freq"]);
        t.row(&["1 Kb".into(), "812.0".into()]);
        t.row(&["16 Kb".into(), "401.5".into()]);
        let s = t.render();
        assert!(s.contains("| size"));
        assert!(s.lines().count() == 4);
        let lens: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    fn gbps_is_gigabits_not_gigabytes() {
        // 64e9 bits/s is 64 Gb/s, not 8 "Gb/s"-labeled gigabytes
        assert_eq!(gbps(64e9), "64.00");
        assert_eq!(gbps(1.5e9), "1.50");
    }

    #[test]
    fn csv_shape() {
        let s = csv(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(s, "a,b\n1,2\n");
    }

    #[test]
    fn band_and_pct_handle_degenerate_stats() {
        assert_eq!(band(f64::NAN, f64::NAN, "s"), "-");
        assert_eq!(band(1e-3, f64::NAN, "s"), crate::util::eng(1e-3, "s"));
        assert_eq!(band(1e-3, 0.0, "s"), crate::util::eng(1e-3, "s"));
        let b = band(1e-3, 1e-5, "s");
        assert!(b.contains('±'), "{b}");
        assert!(b.contains(&crate::util::eng(1e-5, "s")), "{b}");
        assert_eq!(pct(0.9961), "99.6%");
        assert_eq!(pct(1.0), "100.0%");
        assert_eq!(pct(f64::NAN), "-");
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
