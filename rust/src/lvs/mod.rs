//! Layout-vs-schematic: device/connectivity extraction from flattened
//! geometry, plus a graph-isomorphism-style netlist comparison.
//!
//! Extraction follows the drawing conventions of [`crate::layout::cells`]:
//! * a transistor is a vertical gate stripe (poly / osgate) crossing a
//!   horizontal conductor strip (active / oschannel); the strip is split
//!   at each crossing into source/drain segments;
//! * contacts connect {active|poly} <-> metal1; via1 connects m1 <-> m2;
//!   via2 connects any of {m2, m3, oschannel, osgate} it overlaps;
//! * device polarity comes from nwell coverage (Si) or the device
//!   layers themselves (OS);
//! * net names come from the top cell's pin shapes.

use crate::drc::Grid;
use crate::layout::{Pin, Rect};
use crate::netlist::{Circuit, Device};
use crate::tech::{LayerRole, Tech};
use std::collections::{BTreeMap, HashMap};

/// An extracted transistor before net naming.
#[derive(Debug, Clone)]
struct RawMos {
    s_node: usize,
    g_node: usize,
    d_node: usize,
    card: &'static str,
    w_over_l: f64,
}

/// Extraction result.
#[derive(Debug)]
pub struct Extracted {
    pub circuit: Circuit,
    pub net_count: usize,
}

/// Union-find.
struct Uf(Vec<usize>);

impl Uf {
    fn new(n: usize) -> Uf {
        Uf((0..n).collect())
    }
    fn find(&mut self, i: usize) -> usize {
        let mut i = i;
        while self.0[i] != i {
            self.0[i] = self.0[self.0[i]];
            i = self.0[i];
        }
        i
    }
    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            self.0[ra] = rb;
        }
    }
}

/// Extract a circuit from flattened rects + top-level pins.
pub fn extract(tech: &Tech, rects: &[Rect], pins: &[Pin], name: &str) -> crate::Result<Extracted> {
    let l = |r: LayerRole| tech.layer(r);
    let poly = l(LayerRole::Poly);
    let active = l(LayerRole::Active);
    let m1 = l(LayerRole::Metal1);
    let m2 = l(LayerRole::Metal2);
    let m3 = l(LayerRole::Metal3);
    let contact = l(LayerRole::Contact);
    let via1 = l(LayerRole::Via1);
    let os_ch = tech.has_role(LayerRole::OsChannel).then(|| l(LayerRole::OsChannel));
    let os_gate = tech.has_role(LayerRole::OsGate).then(|| l(LayerRole::OsGate));
    let via2 = tech.has_role(LayerRole::Via2).then(|| l(LayerRole::Via2));
    let nwell = tech.has_role(LayerRole::Nwell).then(|| l(LayerRole::Nwell));

    // spatial hash over the raw rects (shared drc::Grid): gate-crossing
    // and nwell lookups query a strip's neighborhood instead of
    // rescanning the full rect list per device strip — the former
    // quadratic term at array-scale extraction
    let rect_grid = Grid::build(rects, 0);
    let mut rcands: Vec<usize> = Vec::new();

    // --- split device strips at gate crossings -------------------------
    let mut pieces: Vec<Rect> = Vec::new();
    let mut devices: Vec<(Rect, Rect, bool)> = Vec::new(); // (strip, gate, is_os)

    let gates_for = |strip: &Rect, gate_layer: usize, cands: &mut Vec<usize>| -> Vec<Rect> {
        rect_grid.query_into(strip, cands);
        let mut g: Vec<Rect> = cands
            .iter()
            .map(|&k| rects[k])
            .filter(|r| r.layer == gate_layer && r.overlaps(strip) && r.h() > strip.h())
            .collect();
        g.sort_by_key(|r| r.x0);
        g
    };

    for r in rects {
        if r.layer == active || Some(r.layer) == os_ch {
            let gate_layer = if r.layer == active { poly } else { os_gate.unwrap() };
            let gates = gates_for(r, gate_layer, &mut rcands);
            if gates.is_empty() {
                pieces.push(*r);
                continue;
            }
            let mut x = r.x0;
            for gt in &gates {
                if gt.x0 > x {
                    pieces.push(Rect::new(r.layer, x, r.y0, gt.x0, r.y1));
                }
                devices.push((*r, *gt, r.layer != active));
                x = gt.x1;
            }
            if x < r.x1 {
                pieces.push(Rect::new(r.layer, x, r.y0, r.x1, r.y1));
            }
        } else {
            pieces.push(*r);
        }
    }

    // --- connectivity over pieces ---------------------------------------
    let conductors: Vec<usize> = {
        let mut v = vec![active, poly, m1, m2, m3];
        if let Some(c) = os_ch {
            v.push(c);
        }
        if let Some(g) = os_gate {
            v.push(g);
        }
        v
    };
    let is_cond: Vec<bool> = pieces.iter().map(|p| conductors.contains(&p.layer)).collect();
    let idx: Vec<usize> = (0..pieces.len()).filter(|&i| is_cond[i]).collect();
    // spatial hash over the split pieces: same-layer touching, cut
    // connectivity, pin naming and S/D assembly all query it instead
    // of walking the conductor list (the old x-sorted sweep degenerates
    // on column-aligned array geometry, like drc::group_touching did)
    let piece_grid = Grid::build(&pieces, 0);
    let mut pcands: Vec<usize> = Vec::new();
    let mut uf = Uf::new(pieces.len());
    // same-layer touching
    for &i in &idx {
        piece_grid.query_into(&pieces[i], &mut pcands);
        for &j in &pcands {
            if j <= i || !is_cond[j] {
                continue;
            }
            if pieces[i].layer == pieces[j].layer && pieces[i].touches(&pieces[j]) {
                uf.union(i, j);
            }
        }
    }
    // cut layers
    for r in rects {
        let connected: Vec<usize> = if r.layer == contact {
            vec![active, poly, m1]
        } else if r.layer == via1 {
            vec![m1, m2]
        } else if Some(r.layer) == via2 {
            let mut v = vec![m2, m3];
            if let Some(c) = os_ch {
                v.push(c);
            }
            if let Some(g) = os_gate {
                v.push(g);
            }
            v
        } else {
            continue;
        };
        piece_grid.query_into(r, &mut pcands);
        let mut touched: Vec<usize> = Vec::new();
        for &i in &pcands {
            if is_cond[i] && connected.contains(&pieces[i].layer) && pieces[i].overlaps(r) {
                touched.push(i);
            }
        }
        for w in touched.windows(2) {
            uf.union(w[0], w[1]);
        }
    }

    // --- name nets from pins --------------------------------------------
    let mut net_names: HashMap<usize, String> = HashMap::new();
    for pin in pins {
        piece_grid.query_into(&pin.rect, &mut pcands);
        for &i in &pcands {
            if is_cond[i] && pieces[i].layer == pin.rect.layer && pieces[i].touches(&pin.rect) {
                let root = uf.find(i);
                net_names.entry(root).or_insert_with(|| pin.name.clone());
            }
        }
    }
    let mut anon = 0usize;
    let mut name_of = |root: usize, names: &mut HashMap<usize, String>| -> String {
        if let Some(n) = names.get(&root) {
            n.clone()
        } else {
            anon += 1;
            let n = format!("n{anon}");
            names.insert(root, n.clone());
            n
        }
    };

    // --- assemble devices --------------------------------------------------
    let mut raw: Vec<RawMos> = Vec::new();
    let mut scands: Vec<usize> = Vec::new();
    for (strip, gate, is_os) in &devices {
        // candidate pieces come from the strip's grid neighborhood
        // (the S/D segments lie inside the strip's own extent)
        piece_grid.query_into(strip, &mut scands);
        // nearest same-strip S/D piece left/right of the gate
        let side = |left: bool| -> Option<usize> {
            let mut best: Option<(i64, usize)> = None;
            for &i in &scands {
                if !is_cond[i] {
                    continue;
                }
                let p = &pieces[i];
                if p.layer != strip.layer || p.y0 != strip.y0 || p.y1 != strip.y1 {
                    continue;
                }
                if p.x0 < strip.x0 || p.x1 > strip.x1 {
                    continue;
                }
                let d = if left {
                    if p.x1 > gate.x0 {
                        continue;
                    }
                    gate.x0 - p.x1
                } else {
                    if p.x0 < gate.x1 {
                        continue;
                    }
                    p.x0 - gate.x1
                };
                if best.map(|(bd, _)| d < bd).unwrap_or(true) {
                    best = Some((d, i));
                }
            }
            best.map(|(_, i)| i)
        };
        let (Some(s_i), Some(d_i)) = (side(true), side(false)) else {
            anyhow::bail!("device at ({}, {}) lacks S/D pieces", gate.x0, strip.y0);
        };
        piece_grid.query_into(gate, &mut scands);
        let g_i = scands
            .iter()
            .copied()
            .find(|&i| is_cond[i] && pieces[i].layer == gate.layer && pieces[i].touches(gate))
            .ok_or_else(|| anyhow::anyhow!("gate stripe not in conductor set"))?;
        let card: &'static str = if *is_os {
            "os_nmos"
        } else {
            let in_nwell = nwell
                .map(|nw| {
                    rect_grid.query_into(strip, &mut rcands);
                    rcands.iter().any(|&k| rects[k].layer == nw && rects[k].overlaps(strip))
                })
                .unwrap_or(false);
            if in_nwell {
                "si_pmos"
            } else {
                "si_nmos"
            }
        };
        let w = strip.h().min(gate.h()) as f64;
        let len = gate.w() as f64;
        raw.push(RawMos {
            s_node: uf.find(s_i),
            g_node: uf.find(g_i),
            d_node: uf.find(d_i),
            card,
            w_over_l: w / len,
        });
    }

    // --- build circuit -------------------------------------------------------
    let mut c = Circuit::new(name, &[]);
    c.ports = pins.iter().map(|p| p.name.clone()).collect::<Vec<_>>();
    c.ports.dedup();
    for (k, m) in raw.iter().enumerate() {
        let s = name_of(m.s_node, &mut net_names);
        let g = name_of(m.g_node, &mut net_names);
        let d = name_of(m.d_node, &mut net_names);
        c.mos(format!("m{k}"), &d, &g, &s, "gnd", m.card, m.w_over_l);
    }
    let roots: std::collections::BTreeSet<usize> = idx.iter().map(|&i| uf.find(i)).collect();
    Ok(Extracted { circuit: c, net_count: roots.len() })
}

// ---------------------------------------------------------------------------
// Comparison
// ---------------------------------------------------------------------------

/// LVS comparison report.
#[derive(Debug)]
pub struct CompareReport {
    pub matched: bool,
    pub detail: String,
}

/// Compare two *flat* circuits by iterative neighborhood refinement.
/// Bulk terminals and exact internal net names are ignored; S/D are
/// symmetric; W/L must agree within the 5 % bucket.
pub fn compare(a: &Circuit, b: &Circuit) -> CompareReport {
    let sig_a = signature(a);
    let sig_b = signature(b);
    if sig_a == sig_b {
        CompareReport { matched: true, detail: "clean".into() }
    } else {
        let only_a: Vec<&String> = sig_a.keys().filter(|k| !sig_b.contains_key(*k)).collect();
        let only_b: Vec<&String> = sig_b.keys().filter(|k| !sig_a.contains_key(*k)).collect();
        CompareReport {
            matched: false,
            detail: format!(
                "{} vs {} devices; unmatched classes layout={only_a:?} schematic={only_b:?}",
                a.mos_count(),
                b.mos_count()
            ),
        }
    }
}

/// Canonical multiset of device signatures after color refinement.
fn signature(c: &Circuit) -> BTreeMap<String, usize> {
    let mut nets: BTreeMap<String, u64> = BTreeMap::new();
    let mut mos: Vec<(&str, &str, &str, &str, f64)> = Vec::new();
    for d in &c.devices {
        if let Device::Mos { d, g, s, card, w_over_l, .. } = d {
            for n in [d, g, s] {
                nets.entry(n.clone()).or_insert(1);
            }
            mos.push((d, g, s, card, *w_over_l));
        }
    }
    // port nets seed with their name so ports must correspond by name
    for p in &c.ports {
        if let Some(v) = nets.get_mut(p) {
            *v = hash_str(p);
        }
    }
    for _ in 0..8 {
        let mut next: BTreeMap<String, u64> = BTreeMap::new();
        for (net, col) in &nets {
            let mut inc: Vec<u64> = Vec::new();
            for (d, g, s, card, wl) in &mos {
                let dev_col = device_color(&nets, d, g, s, card, *wl);
                if d == net || s == net {
                    inc.push(dev_col.wrapping_mul(3));
                }
                if g == net {
                    inc.push(dev_col.wrapping_mul(7));
                }
            }
            inc.sort_unstable();
            let mut h = *col;
            for v in inc {
                h = h.wrapping_mul(0x100000001b3).wrapping_add(v);
            }
            next.insert(net.clone(), h);
        }
        nets = next;
    }
    let mut out: BTreeMap<String, usize> = BTreeMap::new();
    for (d, g, s, card, wl) in &mos {
        let col = device_color(&nets, d, g, s, card, *wl);
        *out.entry(format!("{card}:{col:x}")).or_insert(0) += 1;
    }
    out
}

fn device_color(nets: &BTreeMap<String, u64>, d: &str, g: &str, s: &str, card: &str, wl: f64) -> u64 {
    let mut sd = [nets[d], nets[s]];
    sd.sort_unstable();
    let wl_bucket = (wl * 20.0).round() as u64;
    hash_str(card)
        .wrapping_mul(31)
        .wrapping_add(sd[0])
        .wrapping_mul(31)
        .wrapping_add(sd[1])
        .wrapping_mul(31)
        .wrapping_add(nets[g])
        .wrapping_mul(31)
        .wrapping_add(wl_bucket)
}

fn hash_str(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Full LVS: flatten layout, extract, compare against the schematic.
pub fn check(
    tech: &Tech,
    lib: &crate::layout::Library,
    cell: &str,
    schematic: &Circuit,
) -> crate::Result<CompareReport> {
    let (rects, pins) = lib.flatten_with_pins(cell)?;
    let ext = extract(tech, &rects, &pins, cell)?;
    Ok(compare(&ext.circuit, schematic))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::{cells, Library};
    use crate::tech::sg40;

    fn lvs_leaf(lc: cells::LeafCell) -> CompareReport {
        let t = sg40();
        let mut lib = Library::default();
        let name = lc.layout.name.clone();
        lib.add(lc.layout);
        check(&t, &lib, &name, &lc.circuit).unwrap()
    }

    #[test]
    fn bitcells_extract_clean() {
        let t = sg40();
        for lc in [
            cells::gc2t_sisi(&t, false),
            cells::gc2t_sisi(&t, true),
            cells::sram6t(&t),
            cells::gc2t_osos(&t),
        ] {
            let name = lc.layout.name.clone();
            let rep = lvs_leaf(lc);
            assert!(rep.matched, "{name}: {}", rep.detail);
        }
    }

    #[test]
    fn periphery_extracts_clean() {
        let t = sg40();
        for lc in [
            cells::inverter(&t, 1.0),
            cells::inverter(&t, 2.0),
            cells::nand2(&t),
            cells::sense_amp(&t),
            cells::write_driver(&t),
            cells::precharge(&t),
            cells::predischarge(&t),
            cells::level_shifter(&t),
            cells::column_mux(&t),
            cells::tgate(&t),
        ] {
            let name = lc.layout.name.clone();
            let rep = lvs_leaf(lc);
            assert!(rep.matched, "{name}: {}", rep.detail);
        }
    }

    #[test]
    fn composed_dff_extracts_clean() {
        let t = sg40();
        let mut lib = Library::default();
        let d = crate::layout::compose::dff(&mut lib, &t).unwrap();
        let mut nl = crate::netlist::Netlist::default();
        nl.add(cells::inverter(&t, 1.0).circuit);
        nl.add(cells::tgate(&t).circuit);
        nl.add(d.circuit.clone());
        nl.top = "dff".into();
        let flat = nl.flatten().unwrap();
        let rep = check(&t, &lib, "dff", &flat).unwrap();
        assert!(rep.matched, "{}", rep.detail);
    }

    #[test]
    fn detects_missing_device() {
        let t = sg40();
        let lc = cells::gc2t_sisi(&t, false);
        let mut broken = lc.circuit.clone();
        broken.devices.pop();
        let mut lib = Library::default();
        lib.add(lc.layout);
        let rep = check(&t, &lib, "gc2t_sisi", &broken).unwrap();
        assert!(!rep.matched);
    }

    #[test]
    fn detects_wrong_connection() {
        let t = sg40();
        let lc = cells::gc2t_sisi(&t, false);
        let mut broken = lc.circuit.clone();
        if let Device::Mos { g, .. } = &mut broken.devices[1] {
            *g = "wwl".into(); // read gate belongs on sn
        }
        let mut lib = Library::default();
        lib.add(lc.layout);
        let rep = check(&t, &lib, "gc2t_sisi", &broken).unwrap();
        assert!(!rep.matched);
    }

    #[test]
    fn detects_wrong_polarity() {
        let t = sg40();
        let lc = cells::gc2t_sisi(&t, false);
        let mut broken = lc.circuit.clone();
        if let Device::Mos { card, .. } = &mut broken.devices[1] {
            *card = "si_nmos".into(); // layout draws a pmos read tx
        }
        let mut lib = Library::default();
        lib.add(lc.layout);
        let rep = check(&t, &lib, "gc2t_sisi", &broken).unwrap();
        assert!(!rep.matched);
    }
}

#[cfg(test)]
mod debug_dump {
    use super::*;
    use crate::layout::{cells, Library};
    use crate::tech::sg40;

    #[test]
    #[ignore]
    fn dump_extracted() {
        let t = sg40();
        for lc in [cells::level_shifter(&t), cells::gc2t_osos(&t)] {
            let mut lib = Library::default();
            let name = lc.layout.name.clone();
            lib.add(lc.layout);
            let (rects, pins) = lib.flatten_with_pins(&name).unwrap();
            let ext = extract(&t, &rects, &pins, &name).unwrap();
            println!("== {name} extracted:");
            let mut s = String::new();
            crate::netlist::spice::emit_circuit(&ext.circuit, &mut s);
            println!("{s}");
            println!("-- schematic:");
            let mut s2 = String::new();
            crate::netlist::spice::emit_circuit(&lc.circuit, &mut s2);
            println!("{s2}");
        }
    }
}

#[cfg(test)]
mod probe {
    use super::*;
    use crate::layout::{cells, Library};
    use crate::tech::sg40;

    #[test]
    #[ignore]
    fn find_bridge() {
        let t = sg40();
        let lc = cells::level_shifter(&t);
        let mut lib = Library::default();
        lib.add(lc.layout);
        let (rects, _pins) = lib.flatten_with_pins("level_shifter").unwrap();
        // out pin rect center (mp2.d.x, T2); outb track center
        // brute force: BFS from the 'out' pin rect over touching/cut
        // connectivity, print each newly reached rect
        let m2 = t.layer(crate::tech::LayerRole::Metal2);
        let start = Rect::new(m2, 910, 450, 990, 510);
        let mut frontier = vec![start];
        let mut seen: Vec<Rect> = vec![start];
        let cut_layers = [t.layer(crate::tech::LayerRole::Contact), t.layer(crate::tech::LayerRole::Via1)];
        let m1 = t.layer(crate::tech::LayerRole::Metal1);
        while let Some(cur) = frontier.pop() {
            for r in &rects {
                if seen.contains(r) { continue; }
                let connected = if r.layer == cur.layer && r.touches(&cur) {
                    true
                } else if cut_layers.contains(&r.layer) && r.overlaps(&cur) {
                    true
                } else if cut_layers.contains(&cur.layer) && cur.overlaps(r) && (r.layer == m1 || r.layer == m2 || r.layer == t.layer(crate::tech::LayerRole::Poly) || r.layer == t.layer(crate::tech::LayerRole::Active)) {
                    true
                } else { false };
                if connected {
                    println!("reach {:?} {} via {:?}", t.layers[r.layer].name, format!("({},{})..({},{})", r.x0, r.y0, r.x1, r.y1), (cur.x0, cur.y0, t.layers[cur.layer].name));
                    seen.push(*r);
                    frontier.push(*r);
                }
            }
        }
    }
}

#[cfg(test)]
mod probe_os {
    use super::*;
    use crate::layout::{cells, Library};
    use crate::tech::sg40;

    #[test]
    #[ignore]
    fn os_groups() {
        let t = sg40();
        let lc = cells::gc2t_osos(&t);
        let mut lib = Library::default();
        lib.add(lc.layout);
        let (rects, _p) = lib.flatten_with_pins("gc2t_osos").unwrap();
        let m2 = t.layer(crate::tech::LayerRole::Metal2);
        let m3 = t.layer(crate::tech::LayerRole::Metal3);
        let osg = t.layer(crate::tech::LayerRole::OsGate);
        let v2 = t.layer(crate::tech::LayerRole::Via2);
        // BFS from the write-gate column
        let start = Rect::new(osg, 200, 145, 250, 245);
        let mut frontier = vec![start];
        let mut seen = vec![start];
        while let Some(cur) = frontier.pop() {
            for r in &rects {
                if seen.contains(r) { continue; }
                let conn = if r.layer == cur.layer && r.touches(&cur) { true }
                else if r.layer == v2 && (cur.layer == m2 || cur.layer == m3 || cur.layer == osg || t.layers[cur.layer].name == "oschannel") && r.overlaps(&cur) { true }
                else if cur.layer == v2 && (r.layer == m2 || r.layer == m3 || r.layer == osg || t.layers[r.layer].name == "oschannel") && cur.overlaps(r) { true }
                else { false };
                if conn {
                    println!("reach {} ({},{})..({},{})", t.layers[r.layer].name, r.x0, r.y0, r.x1, r.y1);
                    seen.push(*r); frontier.push(*r);
                }
            }
        }
    }
}
