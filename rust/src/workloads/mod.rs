//! GainSight-style AI-workload profiler (paper Table I / Fig. 9).
//!
//! The paper extracts per-cache read-frequency and data-lifetime
//! demands with the GainSight framework on an NVIDIA H100, scaled to a
//! GeForce GT 520M.  We model the same quantities analytically: each
//! workload is characterized by per-SM traffic intensity and data reuse
//! distance; demands are derived from the machine model.  The absolute
//! numbers are representative, the *orderings* (L2 demands exceed L1
//! because L2 is shared by all SMs; stable-diffusion's L2 lifetime
//! exceeds Si-Si retention; conv kernels are traffic-heavy) reproduce
//! the paper's observations.

/// Cache level under evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheLevel {
    L1,
    L2,
}

/// GPU machine model.
#[derive(Debug, Clone, Copy)]
pub struct Machine {
    pub name: &'static str,
    pub sms: usize,
    pub clock_hz: f64,
    /// L2 slices serving the shared traffic.
    pub l2_banks: usize,
    /// Fraction of peak issue rate a cache must absorb.
    pub cache_pressure: f64,
}

pub const H100: Machine = Machine {
    name: "H100",
    sms: 132,
    clock_hz: 1.8e9,
    l2_banks: 32,
    cache_pressure: 0.55,
};

/// Scaled-down target (paper Fig. 9: "scaled for GeForce GT 520M").
pub const GT520M: Machine = Machine {
    name: "GT520M",
    sms: 1,
    clock_hz: 0.74e9,
    l2_banks: 2,
    cache_pressure: 0.45,
};

/// One AI task (Table I).
#[derive(Debug, Clone, Copy)]
pub struct Task {
    pub id: usize,
    pub name: &'static str,
    pub suite: &'static str,
    /// L1 accesses per SM-cycle (traffic intensity).
    l1_apc: f64,
    /// Fraction of L1 traffic missing to L2.
    l2_miss: f64,
    /// Activation reuse window in cycles (L1 lifetime).
    l1_reuse_cycles: f64,
    /// Working-set residence at L2 (seconds at H100 clock).
    l2_lifetime_s: f64,
}

/// Table I: the seven evaluated workloads.
pub const TASKS: [Task; 7] = [
    Task { id: 1, name: "2dconvolution", suite: "PolyBench", l1_apc: 0.9, l2_miss: 0.30, l1_reuse_cycles: 2_000.0, l2_lifetime_s: 8e-6 },
    Task { id: 2, name: "3dconvolution", suite: "PolyBench", l1_apc: 1.0, l2_miss: 0.35, l1_reuse_cycles: 3_000.0, l2_lifetime_s: 1.2e-5 },
    Task { id: 3, name: "llama-3.2-1b", suite: "ML Inference", l1_apc: 0.55, l2_miss: 0.45, l1_reuse_cycles: 9_000.0, l2_lifetime_s: 4e-5 },
    Task { id: 4, name: "llama-3.2-11b-vision", suite: "ML Inference", l1_apc: 0.62, l2_miss: 0.50, l1_reuse_cycles: 12_000.0, l2_lifetime_s: 6e-5 },
    Task { id: 5, name: "resnet-18", suite: "ML Inference", l1_apc: 0.8, l2_miss: 0.25, l1_reuse_cycles: 4_000.0, l2_lifetime_s: 1.5e-5 },
    Task { id: 6, name: "bert-uncased-110m", suite: "ML Inference", l1_apc: 0.5, l2_miss: 0.40, l1_reuse_cycles: 8_000.0, l2_lifetime_s: 3e-5 },
    Task { id: 7, name: "stable-diffusion-3.5b", suite: "ML Inference", l1_apc: 0.7, l2_miss: 0.55, l1_reuse_cycles: 20_000.0, l2_lifetime_s: 5e-4 },
];

/// Cache demand: what a memory bank must sustain (Fig. 9 axes).
#[derive(Debug, Clone, Copy)]
pub struct Demand {
    pub task: Task,
    pub level: CacheLevel,
    pub machine: &'static str,
    /// Required read frequency per bank (Hz).
    pub read_freq_hz: f64,
    /// Required data lifetime (s) — must fit within retention.
    pub lifetime_s: f64,
}

/// Profile one task at one cache level on a machine.
pub fn profile(task: &Task, level: CacheLevel, m: &Machine) -> Demand {
    match level {
        CacheLevel::L1 => Demand {
            task: *task,
            level,
            machine: m.name,
            // private cache: per-SM issue rate x pressure
            read_freq_hz: task.l1_apc * m.clock_hz * m.cache_pressure,
            lifetime_s: task.l1_reuse_cycles / m.clock_hz,
        },
        CacheLevel::L2 => {
            // shared cache: all SMs' miss traffic funnels into the L2
            // slices — this is why L2 demands EXCEED L1 (paper §V-E)
            let total = task.l1_apc * task.l2_miss * m.sms as f64 * m.clock_hz;
            Demand {
                task: *task,
                level,
                machine: m.name,
                read_freq_hz: total / m.l2_banks as f64 * m.cache_pressure,
                // Table I records L2 residence in seconds *at the H100
                // clock*; other machines rescale by clock ratio.  The
                // reference clock is the machine model's, not a
                // literal, so retuning H100 cannot silently skew it.
                lifetime_s: task.l2_lifetime_s * (H100.clock_hz / m.clock_hz),
            }
        }
    }
}

/// All demands for a machine (Fig. 9 data).
pub fn all_demands(m: &Machine) -> Vec<Demand> {
    let mut out = Vec::new();
    for t in &TASKS {
        out.push(profile(t, CacheLevel::L1, m));
        out.push(profile(t, CacheLevel::L2, m));
    }
    out
}

/// The strictest demand a *single* bank must meet to serve **every**
/// Table-I task at `level` on `m`: the maximum required read frequency
/// and the maximum required lifetime over all tasks.  The composition
/// layer ([`crate::compose`]) sizes one bank per cache level against
/// this envelope.  `task` records the frequency-critical task (the
/// lifetime maximum may come from a different one — e.g. on H100 L2
/// the frequency is set by a conv kernel while the lifetime outlier is
/// stable-diffusion).
pub fn envelope(level: CacheLevel, m: &Machine) -> Demand {
    let mut out = profile(&TASKS[0], level, m);
    for t in &TASKS[1..] {
        let d = profile(t, level, m);
        if d.read_freq_hz > out.read_freq_hz {
            out.task = d.task;
            out.read_freq_hz = d.read_freq_hz;
        }
        out.lifetime_s = out.lifetime_s.max(d.lifetime_s);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_one_has_seven_tasks() {
        assert_eq!(TASKS.len(), 7);
        assert_eq!(TASKS[2].name, "llama-3.2-1b");
        assert!(TASKS.iter().all(|t| t.id >= 1 && t.id <= 7));
    }

    #[test]
    fn l2_demands_exceed_l1_on_h100() {
        // the paper's "counterintuitive" observation (§V-E)
        for t in &TASKS {
            let l1 = profile(t, CacheLevel::L1, &H100);
            let l2 = profile(t, CacheLevel::L2, &H100);
            assert!(
                l2.read_freq_hz > l1.read_freq_hz,
                "{}: L2 {} <= L1 {}",
                t.name,
                l2.read_freq_hz,
                l1.read_freq_hz
            );
        }
    }

    #[test]
    fn gt520m_is_much_lighter_than_h100() {
        for t in &TASKS {
            for lvl in [CacheLevel::L1, CacheLevel::L2] {
                let big = profile(t, lvl, &H100);
                let small = profile(t, lvl, &GT520M);
                assert!(small.read_freq_hz < big.read_freq_hz);
            }
        }
    }

    #[test]
    fn stable_diffusion_l2_lifetime_is_the_outlier() {
        // Fig. 10: Si-Si retention suffices except SD's L2 (paper §V-E)
        let sd = profile(&TASKS[6], CacheLevel::L2, &H100);
        for t in TASKS.iter().take(6) {
            let d = profile(t, CacheLevel::L2, &H100);
            assert!(sd.lifetime_s > 5.0 * d.lifetime_s, "{}", t.name);
        }
        assert!(sd.lifetime_s > 1e-4);
    }

    #[test]
    fn l2_lifetime_rescale_tracks_the_machine_model() {
        // regression: the rescale used the literal `1.8e9`, so retuning
        // H100.clock_hz would have silently skewed every machine's L2
        // lifetimes.  The law: lifetime scales as H100.clock / m.clock.
        let half = Machine {
            name: "half-clock",
            sms: 4,
            clock_hz: H100.clock_hz / 2.0,
            l2_banks: 2,
            cache_pressure: 0.5,
        };
        for t in &TASKS {
            let d = profile(t, CacheLevel::L2, &half);
            assert_eq!(d.lifetime_s.to_bits(), (t.l2_lifetime_s * 2.0).to_bits(), "{}", t.name);
            // and at the H100 itself, Table I is reproduced exactly
            let h = profile(t, CacheLevel::L2, &H100);
            assert_eq!(h.lifetime_s.to_bits(), t.l2_lifetime_s.to_bits(), "{}", t.name);
        }
    }

    #[test]
    fn envelope_is_the_per_level_maximum() {
        for m in [&H100, &GT520M] {
            for lvl in [CacheLevel::L1, CacheLevel::L2] {
                let env = envelope(lvl, m);
                let mut max_f: f64 = 0.0;
                let mut max_l: f64 = 0.0;
                for t in &TASKS {
                    let d = profile(t, lvl, m);
                    max_f = max_f.max(d.read_freq_hz);
                    max_l = max_l.max(d.lifetime_s);
                }
                assert_eq!(env.read_freq_hz, max_f, "{} {lvl:?}", m.name);
                assert_eq!(env.lifetime_s, max_l, "{} {lvl:?}", m.name);
                assert_eq!(env.level, lvl);
                assert_eq!(env.machine, m.name);
            }
        }
        // the H100 L2 lifetime envelope is the stable-diffusion outlier
        let env = envelope(CacheLevel::L2, &H100);
        assert_eq!(env.lifetime_s, profile(&TASKS[6], CacheLevel::L2, &H100).lifetime_s);
    }

    #[test]
    fn lifetimes_are_microseconds_class_at_l1() {
        for t in &TASKS {
            let d = profile(t, CacheLevel::L1, &H100);
            assert!(d.lifetime_s > 1e-7 && d.lifetime_s < 1e-3);
        }
    }
}
