//! OpenGCRAM-RS command-line interface (hand-rolled args; clap is not
//! in the offline registry).
//!
//!   opengcram compile  --word 32 --words 32 [--flavor gc-np|gc-nn|os|sram]
//!                      [--wwlls] [--gds out.gds] [--spice out.sp]
//!   opengcram char     ... (adds transient characterization)
//!   opengcram dse      --level l1|l2 --machine h100|gt520m [--window-res 0.1]
//!                      [--store DIR] [--mc [K] [--yield 0.99] [--mc-seed S]
//!                      [--sigma-vt V] [--corners tt,ss]]
//!   opengcram compose  --machine h100|gt520m [--window-res 0.1]
//!                      [--weights delay,area,power] [--csv out.csv] [--store DIR]
//!                      [--plan [--cap 256]] [--mc [K] [--yield 0.99] ...]
//!   opengcram serve    [--socket /tmp/opengcram.sock] [--window-res 0.1]
//!                      [--store DIR] [--gather-ms 25] [--backend ...]
//!   opengcram client   --json '<request>' [--socket /tmp/opengcram.sock]
//!
//! Every subcommand now runs through an `opengcram::service::Session`:
//! one-shot
//! mode is "open session → one request → drop" (results on the
//! no-store path are identical to the historical per-command
//! pipelines), while `serve` keeps the session alive as a long-running
//! process accepting concurrent JSON-lines requests over a Unix
//! socket — concurrent clients' characterization points pack into
//! shared batches through the one coordinator (grouped-ceiling
//! executions, not per-client), and `--store DIR` adds the
//! content-addressed on-disk evaluation store so a restarted service
//! (or a repeat `dse --store`) serves previously characterized points
//! with zero executions.  `client` sends one request line and prints
//! the response (exit 1 on an `"ok": false` reply) — the scripting
//! surface the CI smoke steps drive.
//!
//! `--mc` switches `dse`/`compose` to Monte-Carlo mode: each design
//! expands into K sampled per-instance variants (VT mismatch, geometry
//! deltas, VDD droop, optional corner mix — `opengcram::variation`)
//! riding the batched characterizer as one mega-batch, and feasibility
//! becomes "demand-joint yield >= --yield" with Wilson 95 % intervals
//! reported.  Same seed, same yields — regardless of worker count or
//! batch order.  (MC variants share their design's cache key, so they
//! bypass both cache tiers by construction.)
//!
//! Every transient-backed subcommand takes `--backend native|pjrt|auto`
//! (default `auto`): `native` runs the in-process EKV solver — no
//! `artifacts/` directory, no external toolchain — while `pjrt`
//! demands the AOT XLA artifacts; `auto` prefers pjrt when the
//! artifacts load and falls back to native.
//!
//! Flag values parse **strictly** through `opengcram::cli`: an unparseable
//! number or an unknown flavor/machine/level/backend is a hard error
//! naming the offending string, never a silent fallback to a default.
//!
//! `--window-res` sets the transient window-quantization resolution
//! (bucket step) of the batched sweeps: larger packs mixed-geometry
//! designs into fewer artifact executions, `0` reproduces the exact
//! unquantized windows.  Default: `characterize::DEFAULT_WINDOW_RESOLUTION`.
//! A session (and its on-disk store entries) is bound to one
//! resolution; `--store` entries recorded at another resolution are
//! simply misses, never aliases.
//!
//! `compose` runs the cross-flavor mega-sweep and selects a bank per
//! cache demand and per cache level; `compose --plan` is the
//! runtime-free mock-coordinator mode — it compiles the grid, derives
//! the packing plan from the designs' own window bits, drives the
//! retention grouping through a counting mock executor, and asserts
//! the grouped-ceiling KPI (CI runs it on every push, no artifacts).

use opengcram::cli;
use opengcram::compiler::{compile, CellFlavor, Config};
use opengcram::service::{serve, Session};
use opengcram::tech::sg40;
use opengcram::util::eng;
use opengcram::util::json::Json;
use opengcram::{characterize, compose, dse, report, workloads};
use std::path::{Path, PathBuf};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Open the session a transient-backed subcommand runs against:
/// backend from `--backend`, optional disk tier from `--store DIR`.
fn open_session(
    tech: &opengcram::tech::Tech,
    args: &[String],
    window_resolution: f64,
) -> opengcram::Result<Session<'_>> {
    let rt = cli::parse_backend(args)?.load(Path::new("artifacts"))?;
    let mut session = Session::new(tech, rt, window_resolution)?;
    if let Some(dir) = cli::flag_value(args, "--store") {
        session = session.with_store(dir)?;
    }
    Ok(session)
}

fn run() -> opengcram::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let tech = sg40();
    match cmd {
        "compile" | "char" => {
            let word: usize = cli::parse_or(&args, "--word", 32)?;
            let words: usize = cli::parse_or(&args, "--words", 32)?;
            let flavor = cli::parse_flavor_flag(&args, CellFlavor::GcSiSiNp)?;
            let mut cfg = Config::new(word, words, flavor);
            cfg.wwlls = cli::has_flag(&args, "--wwlls");
            let bank = compile(&tech, &cfg)?;
            println!(
                "bank {}x{} {:?}: rows={} cols={} mux={} area={} um^2 (array {} um^2, eff {:.1} %)",
                word,
                words,
                flavor,
                cfg.rows(),
                cfg.cols(),
                cfg.mux_factor(),
                report::um2(bank.layout.total_area_um2()),
                report::um2(bank.layout.array_area_um2()),
                100.0 * bank.layout.array_efficiency()
            );
            if let Some(path) = cli::flag_value(&args, "--gds") {
                opengcram::layout::gds::write_file(&bank.library, &tech, "opengcram", Path::new(&path))?;
                println!("wrote {path}");
            }
            if let Some(path) = cli::flag_value(&args, "--spice") {
                std::fs::write(&path, opengcram::netlist::spice::emit(&bank.netlist))?;
                println!("wrote {path}");
            }
            let a = characterize::analytical(&tech, &bank);
            println!(
                "analytical: f_op {}  bw {} Gb/s  leak {}",
                eng(a.f_op_hz, "Hz"),
                report::gbps(a.bandwidth_bps),
                eng(a.leakage_w, "W")
            );
            if cmd == "char" {
                // exact-window session (resolution 0.0): single-design
                // characterization through the session is bitwise the
                // historical per-design path
                let session = open_session(&tech, &args, 0.0)?;
                let e = session.characterize_config(&cfg)?;
                let c = &e.perf;
                println!(
                    "transient ({}):  f_op {}  retention {}  stored1 {:.3} V  functional {}",
                    session.backend_name(),
                    eng(c.f_op_hz, "Hz"),
                    eng(c.retention_s, "s"),
                    c.stored_one_v,
                    c.functional
                );
            }
        }
        "dse" => {
            let machine = cli::parse_machine(&args)?;
            let level = cli::parse_level(&args)?;
            let window_res: f64 =
                cli::parse_or(&args, "--window-res", characterize::DEFAULT_WINDOW_RESOLUTION)?;
            let mc = cli::parse_mc(&args, &tech)?;
            let session = open_session(&tech, &args, window_res)?;
            let configs = dse::fig10_configs(CellFlavor::GcSiSiNp);
            if let Some(model) = mc {
                // statistical mode: every size expands into K sampled
                // variants riding one mega-batch; a cell passes when its
                // demand-joint yield reaches the --yield target
                let target = cli::parse_yield(&args)?;
                let (dys, health) = session.yield_sweep(&configs, &model)?;
                let mut table =
                    report::Table::new(&["task", "demand MHz", "16", "32", "64", "96", "128"]);
                for task in &workloads::TASKS {
                    let d = workloads::profile(task, level, machine);
                    let mut row = vec![task.name.to_string(), report::mhz(d.read_freq_hz)];
                    for dy in &dys {
                        row.push(dy.yield_verdict(&d, target).glyph().to_string());
                    }
                    table.row(&row);
                }
                println!("{}", table.render());
                println!(
                    "P=yield>={target} f=too slow r=retention x=no margin q=quarantined \
                     (K={} seed={:#x}, {} {:?}, {} backend)",
                    model.samples,
                    model.seed,
                    machine.name,
                    level,
                    session.backend_name()
                );
                let mut yt = report::Table::new(&[
                    "design", "functional", "95% CI", "f_op", "retention", "ret q05..q95",
                ]);
                for dy in &dys {
                    let s = &dy.stats;
                    yt.row(&[
                        format!(
                            "{}x{}",
                            dy.config.word_size, dy.config.num_words
                        ),
                        format!("{}/{}", s.functional.passed, s.functional.samples),
                        format!("[{:.3}, {:.3}]", s.functional.lo, s.functional.hi),
                        report::band(s.f_op_hz.mean, s.f_op_hz.sigma, "Hz"),
                        report::band(s.retention_s.mean, s.retention_s.sigma, "s"),
                        format!(
                            "{}..{}",
                            eng(s.retention_s.q05, "s"),
                            eng(s.retention_s.q95, "s")
                        ),
                    ]);
                }
                println!("{}", yt.render());
                println!("run health: {}", health.summary());
                for q in &health.quarantined {
                    println!(
                        "  quarantined [{}] {} — {} stage: {}",
                        q.index, q.design, q.stage, q.reason
                    );
                }
                let st = session.stats();
                println!(
                    "compile cache: {} structures, {} hits, {} compiles",
                    st.structures, st.struct_hits, st.struct_compiles
                );
                return Ok(());
            }
            let mut table = report::Table::new(&["task", "demand MHz", "16", "32", "64", "96", "128"]);
            // batch-first sweep through the session: compile in
            // parallel, characterize in shared padded artifact batches,
            // serve repeats from the cache tiers (--store persists them)
            let (evals, health) = session.sweep(&configs)?;
            for task in &workloads::TASKS {
                let d = workloads::profile(task, level, machine);
                let mut row = vec![task.name.to_string(), report::mhz(d.read_freq_hz)];
                for e in &evals {
                    row.push(dse::shmoo_verdict(e, &d).glyph().to_string());
                }
                table.row(&row);
            }
            println!("{}", table.render());
            println!(
                "P=pass f=too slow r=retention x=no margin q=quarantined (Fig. 10, {} {:?}, {} backend)",
                machine.name,
                level,
                session.backend_name()
            );
            println!("run health: {}", health.summary());
            for q in &health.quarantined {
                println!("  quarantined [{}] {} — {} stage: {}", q.index, q.design, q.stage, q.reason);
            }
            let st = session.stats();
            println!(
                "compile cache: {} structures, {} hits, {} compiles",
                st.structures, st.struct_hits, st.struct_compiles
            );
        }
        "compose" => {
            let machine = cli::parse_machine(&args)?;
            let window_res: f64 =
                cli::parse_or(&args, "--window-res", characterize::DEFAULT_WINDOW_RESOLUTION)?;
            let (w_delay, w_area, w_power) = cli::parse_weights(&args, (1.0, 0.5, 0.5))?;
            if cli::has_flag(&args, "--plan") {
                // mock-coordinator mode: no artifacts, real batching
                let cap: usize = cli::parse_or(&args, "--cap", 256)?;
                let plan = compose::plan(&tech, &compose::design_grid(), window_res, cap)?;
                let mock = compose::mock_retention_calls(plan.transient, cap)?;
                println!(
                    "plan: {} distinct designs ({} transient over {} flavors)",
                    plan.distinct, plan.transient, plan.transient_flavors
                );
                println!(
                    "      write groups {}  read groups {}  retention executions {} \
                     (per-flavor batching would pay {})",
                    plan.write_groups,
                    plan.read_groups,
                    plan.retention_calls,
                    plan.retention_calls_per_flavor
                );
                anyhow::ensure!(
                    mock == plan.retention_calls,
                    "mock coordinator issued {mock} retention executions, plan says {}",
                    plan.retention_calls
                );
                // the grouped ceiling never exceeds per-flavor batching;
                // at small experimental --cap values the two can tie
                // legitimately (each flavor already fills whole batches),
                // so equality there is success, not failure
                anyhow::ensure!(
                    plan.retention_calls <= plan.retention_calls_per_flavor,
                    "cross-flavor sweep did not pack: {} executions vs {} per-flavor",
                    plan.retention_calls,
                    plan.retention_calls_per_flavor
                );
                // when one batch holds every point (the default cap does),
                // the shared sweep must be strictly better: 1 execution
                // vs one per transient flavor
                anyhow::ensure!(
                    cap < plan.transient
                        || plan.retention_calls < plan.retention_calls_per_flavor,
                    "shared sweep must beat per-flavor batching at cap {cap}: {} vs {}",
                    plan.retention_calls,
                    plan.retention_calls_per_flavor
                );
                println!(
                    "cross-flavor packing OK: one shared batch sequence, grouped ceiling {}",
                    plan.retention_calls
                );
                return Ok(());
            }
            let session = open_session(&tech, &args, window_res)?;
            println!("# {} backend", session.backend_name());
            let mut spec = compose::ComposeSpec::new(machine);
            spec.window_resolution = window_res;
            spec.w_delay = w_delay;
            spec.w_area = w_area;
            spec.w_power = w_power;
            spec.mc = cli::parse_mc(&args, &tech)?;
            if spec.mc.is_some() {
                spec.yield_target = cli::parse_yield(&args)?;
            }
            let c = session.compose(&spec)?;
            println!("{}", compose::table(&c));
            if let Some(model) = &spec.mc {
                println!(
                    "yield-aware selection: K={} seed={:#x} target {}",
                    model.samples, model.seed, spec.yield_target
                );
                for s in c.per_demand.iter().chain(c.per_level.iter()) {
                    if let Some(ch) = &s.choice {
                        if let Some(p) = ch.yield_p {
                            let label = if s.envelope {
                                format!("{:?} (all tasks)", s.demand.level)
                            } else {
                                format!("{:?} {}", s.demand.level, s.demand.task.name)
                            };
                            println!("  {label}: chosen yield {p:.4}");
                        }
                    }
                }
            }
            match (c.total_area_um2(), c.total_leakage_w()) {
                (Some(area), Some(leak)) => println!(
                    "portfolio (per-level envelopes): {} um^2 total, {} leakage",
                    report::um2(area),
                    eng(leak, "W")
                ),
                _ => println!("portfolio: some level has no feasible single bank (see table)"),
            }
            println!(
                "sweep: {} distinct design points, {} pipeline evaluations, {} cache hits",
                c.distinct, c.cache_misses, c.cache_hits
            );
            println!("run health: {}", c.health.summary());
            for q in &c.health.quarantined {
                println!("  quarantined [{}] {} — {} stage: {}", q.index, q.design, q.stage, q.reason);
            }
            let st = session.stats();
            println!(
                "compile cache: {} structures, {} hits, {} compiles",
                st.structures, st.struct_hits, st.struct_compiles
            );
            if let Some(path) = cli::flag_value(&args, "--csv") {
                std::fs::write(&path, compose::csv(&c))?;
                println!("wrote {path}");
            }
        }
        "serve" => {
            let window_res: f64 =
                cli::parse_or(&args, "--window-res", characterize::DEFAULT_WINDOW_RESOLUTION)?;
            let gather_ms: u64 = cli::parse_or(&args, "--gather-ms", serve::DEFAULT_GATHER_MS)?;
            let socket = cli::flag_value(&args, "--socket")
                .unwrap_or_else(|| serve::DEFAULT_SOCKET.to_string());
            let session = open_session(&tech, &args, window_res)?;
            let opts = serve::ServeOpts { socket: PathBuf::from(socket), gather_ms };
            serve::serve(&session, &opts)?;
            println!("shutdown complete");
        }
        "client" => {
            let socket = cli::flag_value(&args, "--socket")
                .unwrap_or_else(|| serve::DEFAULT_SOCKET.to_string());
            let line = cli::flag_value(&args, "--json").ok_or_else(|| {
                anyhow::anyhow!("client: --json '<request line>' required (see README protocol)")
            })?;
            let resp = serve::client_request(Path::new(&socket), &line)?;
            println!("{resp}");
            let ok = Json::parse(&resp)
                .ok()
                .and_then(|j| j.get("ok").and_then(Json::as_bool))
                .unwrap_or(false);
            anyhow::ensure!(ok, "server returned an error response");
        }
        _ => {
            println!(
                "usage: opengcram <compile|char|dse|compose|serve|client> [flags] — see README.md"
            );
        }
    }
    Ok(())
}
