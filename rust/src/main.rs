//! OpenGCRAM-RS command-line interface (hand-rolled args; clap is not
//! in the offline registry).
//!
//!   opengcram compile  --word 32 --words 32 [--flavor gc-np|gc-nn|os|sram]
//!                      [--wwlls] [--gds out.gds] [--spice out.sp]
//!   opengcram char     ... (adds transient characterization; needs artifacts/)
//!   opengcram dse      --level l1|l2 --machine h100|gt520m [--window-res 0.1]
//!
//! `--window-res` sets the transient window-quantization resolution
//! (bucket step) of the batched sweep: larger packs mixed-geometry
//! designs into fewer artifact executions, `0` reproduces the exact
//! unquantized windows.  Default: `characterize::DEFAULT_WINDOW_RESOLUTION`.

use opengcram::compiler::{compile, CellFlavor, Config};
use opengcram::runtime::{Runtime, SharedRuntime};
use opengcram::tech::sg40;
use opengcram::util::eng;
use opengcram::{characterize, dse, report, workloads};
use std::path::Path;

fn parse_flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn flavor_of(s: &str) -> CellFlavor {
    match s {
        "sram" => CellFlavor::Sram6t,
        "gc-nn" => CellFlavor::GcSiSiNn,
        "os" => CellFlavor::GcOsOs,
        _ => CellFlavor::GcSiSiNp,
    }
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> opengcram::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let tech = sg40();
    match cmd {
        "compile" | "char" => {
            let word: usize = parse_flag(&args, "--word").and_then(|v| v.parse().ok()).unwrap_or(32);
            let words: usize = parse_flag(&args, "--words").and_then(|v| v.parse().ok()).unwrap_or(32);
            let flavor = flavor_of(&parse_flag(&args, "--flavor").unwrap_or_default());
            let mut cfg = Config::new(word, words, flavor);
            cfg.wwlls = has_flag(&args, "--wwlls");
            let bank = compile(&tech, &cfg)?;
            println!(
                "bank {}x{} {:?}: rows={} cols={} mux={} area={} um^2 (array {} um^2, eff {:.1} %)",
                word,
                words,
                flavor,
                cfg.rows(),
                cfg.cols(),
                cfg.mux_factor(),
                report::um2(bank.layout.total_area_um2()),
                report::um2(bank.layout.array_area_um2()),
                100.0 * bank.layout.array_efficiency()
            );
            if let Some(path) = parse_flag(&args, "--gds") {
                opengcram::layout::gds::write_file(&bank.library, &tech, "opengcram", Path::new(&path))?;
                println!("wrote {path}");
            }
            if let Some(path) = parse_flag(&args, "--spice") {
                std::fs::write(&path, opengcram::netlist::spice::emit(&bank.netlist))?;
                println!("wrote {path}");
            }
            let a = characterize::analytical(&tech, &bank);
            println!(
                "analytical: f_op {}  bw {:.1} Gb/s  leak {}",
                eng(a.f_op_hz, "Hz"),
                a.bandwidth_bps / 1e9,
                eng(a.leakage_w, "W")
            );
            if cmd == "char" {
                let rt = Runtime::load(Path::new("artifacts"))?;
                let c = characterize::characterize(&tech, &rt, &bank)?;
                println!(
                    "transient:  f_op {}  retention {}  stored1 {:.3} V  functional {}",
                    eng(c.f_op_hz, "Hz"),
                    eng(c.retention_s, "s"),
                    c.stored_one_v,
                    c.functional
                );
            }
        }
        "dse" => {
            let rt = SharedRuntime::load(Path::new("artifacts"))?;
            let machine = match parse_flag(&args, "--machine").as_deref() {
                Some("gt520m") => &workloads::GT520M,
                _ => &workloads::H100,
            };
            let level = match parse_flag(&args, "--level").as_deref() {
                Some("l2") => workloads::CacheLevel::L2,
                _ => workloads::CacheLevel::L1,
            };
            let window_res: f64 = parse_flag(&args, "--window-res")
                .and_then(|v| v.parse().ok())
                .unwrap_or(characterize::DEFAULT_WINDOW_RESOLUTION);
            let mut table = report::Table::new(&["task", "demand MHz", "16", "32", "64", "96", "128"]);
            // batch-first sweep: compile in parallel, characterize in
            // shared padded artifact batches via the coordinator
            let evals = dse::evaluate_all_batched(
                &tech,
                &rt,
                &dse::fig10_configs(CellFlavor::GcSiSiNp),
                dse::default_workers(),
                window_res,
            )?;
            for task in &workloads::TASKS {
                let d = workloads::profile(task, level, machine);
                let mut row = vec![task.name.to_string(), report::mhz(d.read_freq_hz)];
                for e in &evals {
                    row.push(dse::shmoo_verdict(e, &d).glyph().to_string());
                }
                table.row(&row);
            }
            println!("{}", table.render());
            println!("P=pass f=too slow r=retention x=no margin (Fig. 10, {} {:?})", machine.name, level);
        }
        _ => {
            println!("usage: opengcram <compile|char|dse> [flags] — see README.md");
        }
    }
    Ok(())
}
