//! Regenerate every table/figure of the paper's evaluation in one run
//! (EXPERIMENTS.md is produced from this output).
//!
//!   cargo run --release --bin figures [-- --backend native|pjrt|auto]
//!
//! The default `auto` backend executes the AOT artifacts when they
//! load and the native in-process solver otherwise, so the full figure
//! set regenerates on a clean checkout.

use opengcram::cli;
use opengcram::compiler::{compile, CellFlavor, CompileCache, Config};
use opengcram::layout::{cells, Library};
use opengcram::runtime::engines;
use opengcram::tech::{sg40, LayerRole};
use opengcram::util::eng;
use opengcram::{characterize, compose, dse, report, variation, workloads};
use std::path::Path;

fn main() -> opengcram::Result<()> {
    let tech = sg40();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rt = cli::parse_backend(&args)?.load(Path::new("artifacts"))?;
    println!("# execution backend: {}", rt.backend_name());

    // ---- Fig. 3: cell areas ------------------------------------------------
    println!("== Fig. 3: bitcell areas (logic rules) ==");
    let b = tech.layer(LayerRole::Boundary);
    let area = |lc: &cells::LeafCell| {
        let r = lc.layout.boundary(b).unwrap();
        r.w() as f64 * r.h() as f64 * 1e-6
    };
    let a_sram = area(&cells::sram6t(&tech));
    let a_sisi = area(&cells::gc2t_sisi(&tech, false));
    let a_osos = area(&cells::gc2t_osos(&tech));
    let mut t3 = report::Table::new(&["cell", "um^2", "vs 6T (paper)"]);
    t3.row(&["6T SRAM".into(), format!("{a_sram:.3}"), "100 % (100 %)".into()]);
    t3.row(&["2T Si-Si".into(), format!("{a_sisi:.3}"), format!("{:.0} % (69 %)", 100.0 * a_sisi / a_sram)]);
    t3.row(&["2T OS-OS".into(), format!("{a_osos:.3}"), format!("{:.0} % (11 %)", 100.0 * a_osos / a_sram)]);
    println!("{}", t3.render());

    // ---- Fig. 6: bank/array area vs size -----------------------------------
    println!("== Fig. 6: area comparison (1/4/16 Kb + extrapolation) ==");
    let mut t6 = report::Table::new(&[
        "bits", "sram bank", "gc bank", "gc+wwlls", "os bank", "gc array", "sram array", "gc eff %", "gc/sram",
    ]);
    let sizes: [(usize, usize); 5] = [(32, 32), (64, 64), (128, 128), (256, 256), (512, 512)];
    for (w, n) in sizes {
        let bits = w * n;
        let sram = compile(&tech, &Config::new(w, n, CellFlavor::Sram6t))?;
        let gc = compile(&tech, &Config::new(w, n, CellFlavor::GcSiSiNp))?;
        let mut cfg_ls = Config::new(w, n, CellFlavor::GcSiSiNp);
        cfg_ls.wwlls = true;
        let gcls = compile(&tech, &cfg_ls)?;
        let os = compile(&tech, &Config::new(w, n, CellFlavor::GcOsOs))?;
        t6.row(&[
            format!("{} Kb", bits / 1024),
            report::um2(sram.layout.total_area_um2()),
            report::um2(gc.layout.total_area_um2()),
            report::um2(gcls.layout.total_area_um2()),
            report::um2(os.layout.total_area_um2()),
            report::um2(gc.layout.array_area_um2()),
            report::um2(sram.layout.array_area_um2()),
            format!("{:.1}", 100.0 * gc.layout.array_efficiency()),
            format!("{:.3}", gc.layout.total_area_um2() / sram.layout.total_area_um2()),
        ]);
    }
    println!("{}", t6.render());

    // ---- Fig. 7: frequency / bandwidth / leakage ----------------------------
    // one batch-first characterization pass over all 15 designs: the
    // transient points pack into shared artifact batches
    println!("== Fig. 7: frequency, bandwidth, leakage (transient-backed, batched) ==");
    let mut t7 = report::Table::new(&[
        "config", "flavor", "f_op MHz", "bw Gb/s", "leak nW", "stages",
    ]);
    let mut t7_meta: Vec<(String, String)> = Vec::new();
    let mut t7_banks = Vec::new();
    for (w, n, label) in [
        (16usize, 16usize, "256 b 1:1"),
        (32, 32, "1 Kb 1:1"),
        (64, 64, "4 Kb 1:1"),
        (128, 32, "4 Kb 4:1"),
        (128, 128, "16 Kb 1:1"),
    ] {
        for flavor in [CellFlavor::Sram6t, CellFlavor::GcSiSiNp] {
            t7_banks.push(compile(&tech, &Config::new(w, n, flavor))?);
            t7_meta.push((label.into(), format!("{flavor:?}")));
        }
        // WWLLS variant
        let mut cfg = Config::new(w, n, CellFlavor::GcSiSiNp);
        cfg.wwlls = true;
        t7_banks.push(compile(&tech, &cfg)?);
        t7_meta.push((label.into(), "GcSiSiNp+LS".into()));
    }
    // figure regeneration runs at resolution 0 (exact windows): the
    // published numbers should not move with the packing trade, and
    // the 15-design batch gains little from quantization anyway
    let t7_perfs = characterize::characterize_all(&tech, &rt, &t7_banks, 0.0)?;
    for (((label, flavor), bank), perf) in t7_meta.iter().zip(&t7_banks).zip(&t7_perfs) {
        t7.row(&[
            label.clone(),
            flavor.clone(),
            report::mhz(perf.f_op_hz),
            report::gbps(perf.bandwidth_bps),
            format!("{:.1}", perf.leakage_w * 1e9),
            format!("{}", bank.delay_chain_stages),
        ]);
    }
    println!("{}", t7.render());

    // ---- Fig. 8: Id-Vg + retention -----------------------------------------
    println!("== Fig. 8: device curves and retention ==");
    let cards = [
        ("si_nmos", 2.0),
        ("si_pmos", 2.0),
        ("os_nmos", 1.5),
        ("os_nmos_hvt", 1.5),
    ];
    let card_list: Vec<_> = cards.iter().map(|(n, wl)| (*tech.card(n), *wl)).collect();
    let (vg, ids) = rt.with(|r| engines::idvg(r, &card_list, -0.2, 1.2, 1.1))?;
    for ((name, _), row) in cards.iter().zip(&ids) {
        let at = |x: f64| {
            let i = vg.iter().position(|&v| v >= x).unwrap_or(vg.len() - 1);
            row[i].abs()
        };
        println!("  {name:12} |I(0V)| = {:>12}  |I(1.1V)| = {:>12}", eng(at(0.0), "A"), eng(at(1.1), "A"));
    }
    let mk_ret = |card: &str, vt: Option<f64>| engines::RetentionPoint {
        write_card: vt.map(|v| tech.card(card).with_vt(v)).unwrap_or(*tech.card(card)),
        write_wl: 2.5,
        c_sn: 1.2e-15,
        g_gate_leak: if card.starts_with("os") { 1e-17 } else { 1e-16 },
        i_disturb: 0.0,
        v0: 0.6,
        vth: 0.3,
    };
    let pts = vec![
        mk_ret("si_nmos", None),
        mk_ret("si_nmos", Some(0.55)),
        mk_ret("si_nmos", Some(0.65)),
        mk_ret("os_nmos", None),
        mk_ret("os_nmos_hvt", None),
    ];
    let rets = rt.with(|r| engines::retention(r, &pts))?;
    let labels = ["Si-Si (vt .45)", "Si-Si vt .55", "Si-Si vt .65", "OS-OS", "OS-OS HVT"];
    for (l, r) in labels.iter().zip(&rets) {
        println!("  retention {l:16} = {}", eng(r.t_retain, "s"));
    }

    // ---- Fig. 9: workload demands -------------------------------------------
    println!("\n== Fig. 9 / Table I: cache demands ==");
    for m in [&workloads::H100, &workloads::GT520M] {
        let mut t9 = report::Table::new(&["task", "L1 MHz", "L1 life", "L2 MHz", "L2 life"]);
        for task in &workloads::TASKS {
            let l1 = workloads::profile(task, workloads::CacheLevel::L1, m);
            let l2 = workloads::profile(task, workloads::CacheLevel::L2, m);
            t9.row(&[
                task.name.into(),
                report::mhz(l1.read_freq_hz),
                eng(l1.lifetime_s, "s"),
                report::mhz(l2.read_freq_hz),
                eng(l2.lifetime_s, "s"),
            ]);
        }
        println!("-- {} --\n{}", m.name, t9.render());
    }

    // ---- Fig. 10: shmoo -------------------------------------------------------
    println!("== Fig. 10: shmoo (GCRAM bank configs vs tasks, batch-first sweep) ==");
    // resolution 0: canonical figure output stays bitwise-exact
    let evals = dse::evaluate_all_batched(
        &tech,
        &rt,
        &dse::fig10_configs(CellFlavor::GcSiSiNp),
        opengcram::util::default_workers(),
        0.0,
    )?;
    for (level, machine) in [
        (workloads::CacheLevel::L1, &workloads::GT520M),
        (workloads::CacheLevel::L2, &workloads::H100),
    ] {
        let mut t10 = report::Table::new(&["task", "16x16", "32x32", "64x64", "96x96", "128x128"]);
        for task in &workloads::TASKS {
            let d = workloads::profile(task, level, machine);
            let mut row = vec![task.name.to_string()];
            for e in &evals {
                row.push(dse::shmoo_verdict(e, &d).glyph().to_string());
            }
            t10.row(&row);
        }
        println!("-- {:?} on {} --\n{}", level, machine.name, t10.render());
    }
    println!("P=pass f=frequency r=retention x=margin");

    // ---- Monte-Carlo variation: sigma bands + yield shmoo ---------------------
    // small K keeps figure regeneration fast; the variants still ride
    // one mega-batch (grouped-ceiling executions, visible in the KPI
    // counter dump at the bottom of this run)
    println!("\n== Monte-Carlo variation: retention/f_op sigma bands (K=24) ==");
    let model = variation::VariationModel::from_tech(&tech, 24, variation::DEFAULT_SEED);
    let (dys, mc_health) = variation::yield_sweep_health(
        &tech,
        &rt,
        &dse::fig10_configs(CellFlavor::GcSiSiNp),
        &model,
        opengcram::util::default_workers(),
        0.0,
        &CompileCache::new(),
    )?;
    let mut tmc = report::Table::new(&[
        "design", "yield", "95% CI", "f_op", "retention", "ret q05..q95", "nominal ret",
    ]);
    for dy in &dys {
        let s = &dy.stats;
        tmc.row(&[
            format!("{}x{}", dy.config.word_size, dy.config.num_words),
            report::pct(s.functional.p),
            format!("[{}, {}]", report::pct(s.functional.lo), report::pct(s.functional.hi)),
            report::band(s.f_op_hz.mean, s.f_op_hz.sigma, "Hz"),
            report::band(s.retention_s.mean, s.retention_s.sigma, "s"),
            format!("{}..{}", eng(s.retention_s.q05, "s"), eng(s.retention_s.q95, "s")),
            eng(dy.nominal.perf.retention_s, "s"),
        ]);
    }
    println!("{}", tmc.render());
    let mut ty = report::Table::new(&["demand", "16x16", "32x32", "64x64", "96x96", "128x128"]);
    for (level, machine) in [
        (workloads::CacheLevel::L1, &workloads::GT520M),
        (workloads::CacheLevel::L2, &workloads::H100),
    ] {
        let env = workloads::envelope(level, machine);
        let mut row = vec![format!("{:?} {} envelope", level, machine.name)];
        for dy in &dys {
            row.push(dy.yield_verdict(&env, 0.99).glyph().to_string());
        }
        ty.row(&row);
    }
    println!("{}", ty.render());
    println!("P=yield>=0.99 f=frequency r=retention x=margin q=quarantined");
    println!("mc health: {}", mc_health.summary());

    // ---- heterogeneous composition (GainSight follow-on) ---------------------
    println!("\n== Composition: workload-driven heterogeneous bank selection ==");
    // one cross-flavor mega-sweep shared by both machines: the second
    // composition is served entirely from the EvalCache (the demands
    // change the selection, not the sweep)
    let comp_cache = dse::EvalCache::new();
    let comp_structs = CompileCache::new();
    for m in [&workloads::H100, &workloads::GT520M] {
        let mut spec = compose::ComposeSpec::new(m);
        // canonical figure output stays bitwise-exact
        spec.window_resolution = 0.0;
        let c = compose::compose_cached(&tech, &rt, &spec, &comp_cache, &comp_structs)?;
        println!("-- {} --\n{}", m.name, compose::table(&c));
        match (c.total_area_um2(), c.total_leakage_w()) {
            (Some(area), Some(leak)) => println!(
                "portfolio: {} um^2, {} leakage ({} evals, {} cache hits)\n",
                report::um2(area),
                eng(leak, "W"),
                c.cache_misses,
                c.cache_hits
            ),
            _ => println!(
                "portfolio: some level has no feasible single bank ({} evals, {} cache hits)\n",
                c.cache_misses, c.cache_hits
            ),
        }
    }

    // ---- bank LVS/DRC status (Fig. 5 claim) ----------------------------------
    println!("\n== Fig. 5: DRC/LVS status of a generated 32x32 bank array ==");
    let bank = compile(&tech, &Config::new(32, 32, CellFlavor::GcSiSiNp))?;
    let rects = bank.library.flatten("bitcell_array")?;
    let drc = opengcram::drc::check(&tech, &rects);
    println!("  array DRC: {} ({} rects)", if drc.clean() { "CLEAN" } else { "VIOLATIONS" }, drc.rects_checked);
    let mut lib2 = Library::default();
    let lc = cells::gc2t_sisi(&tech, false);
    lib2.add(lc.layout.clone());
    let lvs = opengcram::lvs::check(&tech, &lib2, "gc2t_sisi", &lc.circuit)?;
    println!("  bitcell LVS: {}", if lvs.matched { "CLEAN" } else { "MISMATCH" });

    // ---- batching KPI: artifact executions for the whole run ------------------
    println!("\n== PJRT artifact executions (batch-first pipeline) ==");
    for (name, calls) in rt.call_counts() {
        println!("  {name:10} {calls}");
    }
    Ok(())
}
