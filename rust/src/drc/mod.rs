//! Design-rule checker: width / spacing / area / enclosure / extension
//! checks over a flattened rect soup.
//!
//! The engine is the scanline-bucketed pairwise checker a memory
//! compiler needs: rects are merged per layer into connected groups
//! first (so abutting wire segments of one net do not flag spacing),
//! then same-layer spacing runs over a sorted sweep with an active set,
//! and enclosure rules run via point-in-group queries.

use crate::layout::Rect;
use crate::tech::Tech;
#[cfg(test)]
use crate::tech::LayerRole;
use std::collections::BTreeMap;

/// One DRC violation.
#[derive(Debug, Clone, PartialEq)]
pub struct Violation {
    pub rule: String,
    pub layer: &'static str,
    pub at: Rect,
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} on {} at ({},{})..({},{}): {}",
            self.rule, self.layer, self.at.x0, self.at.y0, self.at.x1, self.at.y1, self.detail
        )
    }
}

/// DRC report.
#[derive(Debug, Default)]
pub struct Report {
    pub violations: Vec<Violation>,
    pub rects_checked: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Run all rules of `tech` over a flattened layout.
pub fn check(tech: &Tech, rects: &[Rect]) -> Report {
    let mut report = Report { violations: Vec::new(), rects_checked: rects.len() };

    // bucket by layer index
    let mut by_layer: BTreeMap<usize, Vec<Rect>> = BTreeMap::new();
    for r in rects {
        by_layer.entry(r.layer).or_default().push(*r);
    }

    for (role, rules) in tech.rules.checked_layers() {
        if !tech.has_role(*role) {
            continue;
        }
        let li = tech.layer(*role);
        let lname = tech.layers[li].name;
        let Some(lr) = by_layer.get(&li) else { continue };

        // 1. width: every rect's short side >= min_width
        if rules.min_width_nm > 0 {
            for r in lr {
                let min_side = r.w().min(r.h());
                if min_side < rules.min_width_nm {
                    report.violations.push(Violation {
                        rule: "min_width".into(),
                        layer: lname,
                        at: *r,
                        detail: format!("{} < {}", min_side, rules.min_width_nm),
                    });
                }
            }
        }

        // merge touching rects into groups (same net by geometry)
        let groups = group_touching(lr);

        // 2. spacing between different groups
        if rules.min_space_nm > 0 {
            check_spacing(lr, &groups, rules.min_space_nm, lname, &mut report);
        }

        // 3. area per group (merged area approximated by rect-union sum;
        //    exact for the disjoint decomposition our generators emit)
        if rules.min_area_nm2 > 0 {
            let mut group_area: BTreeMap<usize, i64> = BTreeMap::new();
            let mut group_repr: BTreeMap<usize, Rect> = BTreeMap::new();
            for (i, r) in lr.iter().enumerate() {
                *group_area.entry(groups[i]).or_insert(0) += r.area_nm2();
                group_repr.entry(groups[i]).or_insert(*r);
            }
            for (gid, area) in group_area {
                if area < rules.min_area_nm2 {
                    report.violations.push(Violation {
                        rule: "min_area".into(),
                        layer: lname,
                        at: group_repr[&gid],
                        detail: format!("{} < {}", area, rules.min_area_nm2),
                    });
                }
            }
        }
    }

    // 4. enclosure / extension rules.  Conditional: an inner rect is
    //    checked only where it overlaps the outer layer at all (a
    //    contact on poly is governed by the poly rule, not the active
    //    rule).  Axis-restricted rules model gate extension.
    for er in &tech.rules.enclosures {
        if !tech.has_role(er.outer) || !tech.has_role(er.inner) {
            continue;
        }
        let (oi, ii) = (tech.layer(er.outer), tech.layer(er.inner));
        let iname = tech.layers[ii].name;
        let empty = Vec::new();
        let outers = by_layer.get(&oi).unwrap_or(&empty);
        let grid = Grid::build(outers, 0);
        for inner in by_layer.get(&ii).unwrap_or(&empty) {
            let cands = grid.query(inner);
            let related = cands.iter().any(|&k| outers[k].overlaps(inner));
            if !related {
                continue;
            }
            let ok = cands
                .iter()
                .any(|&k| encloses_axis(&outers[k], inner, er.margin_nm, er.axis));
            if !ok {
                report.violations.push(Violation {
                    rule: format!("enclosure({}>{})", tech.layers[oi].name, iname),
                    layer: iname,
                    at: *inner,
                    detail: format!("needs {} nm margin ({:?})", er.margin_nm, er.axis),
                });
            }
        }
    }

    // 5. cross-layer spacing.  Pairs where the b-rect lands on an
    //    a-layer shape *connected* to the tested rect are exempt (e.g.
    //    a gate-pad contact 10 nm from its own poly column).
    for sr in &tech.rules.cross_spacings {
        if !tech.has_role(sr.a) || !tech.has_role(sr.b) {
            continue;
        }
        let (ai, bi) = (tech.layer(sr.a), tech.layer(sr.b));
        let empty = Vec::new();
        let al = by_layer.get(&ai).unwrap_or(&empty);
        let bl = by_layer.get(&bi).unwrap_or(&empty);
        let a_groups = group_touching(al);
        let a_grid = Grid::build(al, sr.space_nm);
        for (ia, ra) in al.iter().enumerate() {
            let cands = a_grid.query(ra); // a-rects near ra (for grouping)
            for rb in bl {
                let dxq = (rb.x0 - ra.x1).max(ra.x0 - rb.x1);
                let dyq = (rb.y0 - ra.y1).max(ra.y0 - rb.y1);
                if dxq >= sr.space_nm || dyq >= sr.space_nm {
                    continue; // beyond reach: no violation possible
                }
                // exempt if rb overlaps any a-rect in ra's group
                let same_construct = cands.iter().any(|&j| {
                    a_groups[j] == a_groups[ia] && al[j].overlaps(rb)
                });
                if same_construct {
                    continue;
                }
                // skip related shapes (touching = same construct, e.g.
                // the gate contact pad ON its poly)
                let dx = (rb.x0 - ra.x1).max(ra.x0 - rb.x1);
                let dy = (rb.y0 - ra.y1).max(ra.y0 - rb.y1);
                if dx <= 0 && dy <= 0 {
                    continue; // overlapping/touching: not a spacing issue
                }
                let dist = if dx > 0 && dy > 0 {
                    // diagonal: use max-norm (manhattan rules)
                    dx.max(dy)
                } else {
                    dx.max(dy)
                };
                if dist < sr.space_nm {
                    report.violations.push(Violation {
                        rule: format!(
                            "spacing({},{})",
                            tech.layers[ai].name, tech.layers[bi].name
                        ),
                        layer: tech.layers[ai].name,
                        at: *ra,
                        detail: format!("{} < {}", dist, sr.space_nm),
                    });
                }
            }
        }
    }

    report
}

/// Coarse spatial hash over rects: bucket size 2 um; rects are inserted
/// into every bucket they overlap so point/overlap queries only scan
/// their own bucket neighborhood.  Turns the enclosure / cross-spacing
/// passes from O(inner x outer) into ~O(inner) on array-scale layouts
/// (89 s -> well under a second on a 1 Kb array; EXPERIMENTS.md SS Perf).
struct Grid {
    cell: i64,
    map: BTreeMap<(i64, i64), Vec<usize>>,
}

impl Grid {
    fn build(rects: &[Rect], pad: i64) -> Grid {
        let cell = 2_000;
        let mut map: BTreeMap<(i64, i64), Vec<usize>> = BTreeMap::new();
        for (i, r) in rects.iter().enumerate() {
            let (x0, x1) = ((r.x0 - pad).div_euclid(cell), (r.x1 + pad).div_euclid(cell));
            let (y0, y1) = ((r.y0 - pad).div_euclid(cell), (r.y1 + pad).div_euclid(cell));
            for bx in x0..=x1 {
                for by in y0..=y1 {
                    map.entry((bx, by)).or_default().push(i);
                }
            }
        }
        Grid { cell, map }
    }

    /// Candidate indices whose padded extent may touch `r`.
    fn query(&self, r: &Rect) -> Vec<usize> {
        let (x0, x1) = (r.x0.div_euclid(self.cell), r.x1.div_euclid(self.cell));
        let (y0, y1) = (r.y0.div_euclid(self.cell), r.y1.div_euclid(self.cell));
        let mut out = Vec::new();
        for bx in x0..=x1 {
            for by in y0..=y1 {
                if let Some(v) = self.map.get(&(bx, by)) {
                    out.extend_from_slice(v);
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }
}

/// Axis-aware enclosure test (see [`crate::tech::rules::EncAxis`]).
fn encloses_axis(o: &Rect, i: &Rect, m: i64, axis: crate::tech::rules::EncAxis) -> bool {
    use crate::tech::rules::EncAxis;
    let x_ok = o.x0 + m <= i.x0 && o.x1 - m >= i.x1;
    let y_ok = o.y0 + m <= i.y0 && o.y1 - m >= i.y1;
    match axis {
        EncAxis::Both => x_ok && y_ok,
        EncAxis::X => x_ok,
        EncAxis::Y => y_ok,
    }
}

/// Union-find grouping of touching same-layer rects.
fn group_touching(rects: &[Rect]) -> Vec<usize> {
    let n = rects.len();
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(p: &mut Vec<usize>, i: usize) -> usize {
        let mut i = i;
        while p[i] != i {
            p[i] = p[p[i]];
            i = p[i];
        }
        i
    }
    // sweep by x to bound pair checks
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| rects[i].x0);
    for (oi, &i) in order.iter().enumerate() {
        for &j in order.iter().skip(oi + 1) {
            if rects[j].x0 > rects[i].x1 {
                break;
            }
            if rects[i].touches(&rects[j]) {
                let (ri, rj) = (find(&mut parent, i), find(&mut parent, j));
                if ri != rj {
                    parent[ri] = rj;
                }
            }
        }
    }
    (0..n).map(|i| find(&mut parent, i)).collect()
}

/// Spacing check between rects of *different* groups via x-sweep.
fn check_spacing(
    rects: &[Rect],
    groups: &[usize],
    min_space: i64,
    lname: &'static str,
    report: &mut Report,
) {
    let n = rects.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| rects[i].x0);
    for (oi, &i) in order.iter().enumerate() {
        for &j in order.iter().skip(oi + 1) {
            // prune: beyond reach in x
            if rects[j].x0 - rects[i].x1 >= min_space {
                break;
            }
            if groups[i] == groups[j] {
                continue;
            }
            let (a, b) = (&rects[i], &rects[j]);
            let dx = (b.x0 - a.x1).max(a.x0 - b.x1).max(0);
            let dy = (b.y0 - a.y1).max(a.y0 - b.y1).max(0);
            // euclidean corner-to-corner per standard DRC semantics is
            // overkill for manhattan decks; use max-projection distance
            let dist = dx.max(dy);
            if dist < min_space {
                report.violations.push(Violation {
                    rule: "min_space".into(),
                    layer: lname,
                    at: *a,
                    detail: format!("{} < {} (vs rect at {},{})", dist, min_space, b.x0, b.y0),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::sg40;

    fn m1(t: &Tech) -> usize {
        t.layer(LayerRole::Metal1)
    }

    #[test]
    fn clean_pair_passes() {
        let t = sg40();
        let l = m1(&t);
        let rects = vec![
            Rect::new(l, 0, 0, 200, 200),
            Rect::new(l, 300, 0, 500, 200),
        ];
        let rep = check(&t, &rects);
        assert!(rep.clean(), "{:?}", rep.violations);
    }

    #[test]
    fn width_violation_detected() {
        let t = sg40();
        let rects = vec![Rect::new(m1(&t), 0, 0, 30, 500)];
        let rep = check(&t, &rects);
        assert!(rep.violations.iter().any(|v| v.rule == "min_width"));
    }

    #[test]
    fn spacing_violation_detected_and_touching_exempt() {
        let t = sg40();
        let l = m1(&t);
        // 10 nm gap < the m1 spacing rule
        let rects = vec![
            Rect::new(l, 0, 0, 200, 200),
            Rect::new(l, 210, 0, 400, 200),
        ];
        let rep = check(&t, &rects);
        assert!(rep.violations.iter().any(|v| v.rule == "min_space"));
        // abutting rects are one group: exempt
        let rects2 = vec![
            Rect::new(l, 0, 0, 200, 200),
            Rect::new(l, 200, 0, 400, 200),
        ];
        let rep2 = check(&t, &rects2);
        assert!(rep2.clean(), "{:?}", rep2.violations);
    }

    #[test]
    fn area_violation_detected() {
        let t = sg40();
        // m1 min_area 6_000 nm^2: a 60x90 rect = 5_400 fails
        let rects = vec![Rect::new(m1(&t), 0, 0, 60, 90)];
        let rep = check(&t, &rects);
        assert!(rep.violations.iter().any(|v| v.rule == "min_area"));
    }

    #[test]
    fn enclosure_violation_detected() {
        let t = sg40();
        let c = t.layer(LayerRole::Contact);
        let a = t.layer(LayerRole::Active);
        // contact sticking out of active
        let rects = vec![
            Rect::new(a, 0, 0, 100, 100),
            Rect::new(c, 60, 20, 120, 80),
        ];
        let rep = check(&t, &rects);
        assert!(rep
            .violations
            .iter()
            .any(|v| v.rule.starts_with("enclosure")), "{:?}", rep.violations);
    }

    #[test]
    fn generated_cells_are_drc_clean() {
        let t = sg40();
        use crate::layout::{cells, Library};
        for lc in [
            cells::sram6t(&t),
            cells::gc2t_sisi(&t, false),
            cells::gc2t_sisi(&t, true),
            cells::gc2t_osos(&t),
            cells::inverter(&t, 1.0),
            cells::inverter(&t, 4.0),
            cells::nand2(&t),
            cells::sense_amp(&t),
            cells::write_driver(&t),
            cells::precharge(&t),
            cells::predischarge(&t),
            cells::level_shifter(&t),
            cells::column_mux(&t),
            cells::tgate(&t),
        ] {
            let mut lib = Library::default();
            let name = lc.layout.name.clone();
            lib.add(lc.layout);
            let rects = lib.flatten(&name).unwrap();
            let rep = check(&t, &rects);
            assert!(
                rep.clean(),
                "cell {name} has {} violations; first: {}",
                rep.violations.len(),
                rep.violations[0]
            );
        }
    }

    #[test]
    fn injected_violations_in_clean_cell_are_caught() {
        // failure injection: shrink a rule-clean cell's wire to 30 nm
        let t = sg40();
        use crate::layout::{cells, Library};
        let lc = cells::inverter(&t, 1.0);
        let mut lib = Library::default();
        lib.add(lc.layout);
        let mut rects = lib.flatten("inv_x1").unwrap();
        rects.push(Rect::new(m1(&t), 5000, 5000, 5030, 5400));
        let rep = check(&t, &rects);
        assert!(!rep.clean());
    }
}

#[cfg(test)]
mod dump {
    use super::*;
    use crate::tech::sg40;
    #[test]
    #[ignore]
    fn dump_all_violations() {
        let t = sg40();
        use crate::layout::{cells, Library};
        for lc in [
            cells::sram6t(&t),
            cells::gc2t_sisi(&t, false),
            cells::gc2t_sisi(&t, true),
            cells::gc2t_osos(&t),
            cells::inverter(&t, 1.0),
            cells::nand2(&t),
            cells::sense_amp(&t),
            cells::write_driver(&t),
            cells::precharge(&t),
            cells::predischarge(&t),
            cells::level_shifter(&t),
            cells::column_mux(&t),
            cells::tgate(&t),
        ] {
            let mut lib = Library::default();
            let name = lc.layout.name.clone();
            lib.add(lc.layout);
            let rects = lib.flatten(&name).unwrap();
            let rep = check(&t, &rects);
            let mut counts: std::collections::BTreeMap<String, usize> = Default::default();
            for v in &rep.violations {
                *counts.entry(format!("{} {}", v.rule, v.layer)).or_insert(0) += 1;
            }
            println!("== {name}: {} violations", rep.violations.len());
            for (k, c) in counts { println!("   {k}: {c}"); }
            for v in rep.violations.iter().take(3) { println!("   e.g. {v}"); }
        }
    }
}
