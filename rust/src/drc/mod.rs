//! Design-rule checker: width / spacing / area / enclosure / extension
//! checks over a flattened rect soup, plus a hierarchical mode
//! ([`hier`]) that checks each unique cell once and only re-examines
//! instance-boundary halo regions.
//!
//! Every pairwise pass is grid-accelerated: candidates come from a
//! coarse spatial hash ([`Grid`]) instead of scanning the full rect
//! list, so same-layer spacing, cross-layer spacing, enclosure and the
//! touching-group union-find are all ~O(n) on array-scale layouts
//! (the generators emit bounded-density geometry).

pub mod hier;

use crate::layout::Rect;
use crate::tech::Tech;
#[cfg(test)]
use crate::tech::LayerRole;
use std::collections::BTreeMap;

/// One DRC violation.
#[derive(Debug, Clone, PartialEq)]
pub struct Violation {
    pub rule: String,
    pub layer: &'static str,
    pub at: Rect,
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} on {} at ({},{})..({},{}): {}",
            self.rule, self.layer, self.at.x0, self.at.y0, self.at.x1, self.at.y1, self.detail
        )
    }
}

/// DRC report.
#[derive(Debug, Default)]
pub struct Report {
    pub violations: Vec<Violation>,
    pub rects_checked: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Run all rules of `tech` over a flattened layout.
pub fn check(tech: &Tech, rects: &[Rect]) -> Report {
    let mut report = Report { violations: Vec::new(), rects_checked: rects.len() };

    // bucket by layer index
    let mut by_layer: BTreeMap<usize, Vec<Rect>> = BTreeMap::new();
    for r in rects {
        by_layer.entry(r.layer).or_default().push(*r);
    }

    for (role, rules) in tech.rules.checked_layers() {
        if !tech.has_role(*role) {
            continue;
        }
        let li = tech.layer(*role);
        let lname = tech.layers[li].name;
        let Some(lr) = by_layer.get(&li) else { continue };

        // 1. width: every rect's short side >= min_width
        if rules.min_width_nm > 0 {
            for r in lr {
                let min_side = r.w().min(r.h());
                if min_side < rules.min_width_nm {
                    report.violations.push(Violation {
                        rule: "min_width".into(),
                        layer: lname,
                        at: *r,
                        detail: format!("{} < {}", min_side, rules.min_width_nm),
                    });
                }
            }
        }

        // merge touching rects into groups (same net by geometry)
        let groups = group_touching(lr);

        // 2. spacing between different groups
        if rules.min_space_nm > 0 {
            check_spacing(lr, &groups, None, rules.min_space_nm, lname, 1, &mut report);
        }

        // 3. area per group (merged area approximated by rect-union sum;
        //    exact for the disjoint decomposition our generators emit)
        if rules.min_area_nm2 > 0 {
            let mut group_area: BTreeMap<usize, i64> = BTreeMap::new();
            let mut group_repr: BTreeMap<usize, Rect> = BTreeMap::new();
            for (i, r) in lr.iter().enumerate() {
                *group_area.entry(groups[i]).or_insert(0) += r.area_nm2();
                group_repr.entry(groups[i]).or_insert(*r);
            }
            for (gid, area) in group_area {
                if area < rules.min_area_nm2 {
                    report.violations.push(Violation {
                        rule: "min_area".into(),
                        layer: lname,
                        at: group_repr[&gid],
                        detail: format!("{} < {}", area, rules.min_area_nm2),
                    });
                }
            }
        }
    }

    // 4. enclosure / extension rules.
    for er in &tech.rules.enclosures {
        if !tech.has_role(er.outer) || !tech.has_role(er.inner) {
            continue;
        }
        let (oi, ii) = (tech.layer(er.outer), tech.layer(er.inner));
        let empty = Vec::new();
        let outers = by_layer.get(&oi).unwrap_or(&empty);
        let inners = by_layer.get(&ii).unwrap_or(&empty);
        check_enclosure(tech, er, oi, ii, outers, inners, None, 1, &mut report);
    }

    // 5. cross-layer spacing.
    for sr in &tech.rules.cross_spacings {
        if !tech.has_role(sr.a) || !tech.has_role(sr.b) {
            continue;
        }
        let (ai, bi) = (tech.layer(sr.a), tech.layer(sr.b));
        let empty = Vec::new();
        let al = by_layer.get(&ai).unwrap_or(&empty);
        let bl = by_layer.get(&bi).unwrap_or(&empty);
        check_cross_spacing(tech, ai, bi, al, bl, None, sr.space_nm, 1, &mut report);
    }

    report
}

/// Coarse spatial hash over rects: bucket size 2 um; rects are inserted
/// into every bucket they overlap (after `pad` expansion) so
/// point/overlap queries only scan their own bucket neighborhood.
/// Turns every pairwise DRC pass from O(n x m) into ~O(n) on
/// array-scale layouts (EXPERIMENTS.md, Hot paths).
pub struct Grid {
    cell: i64,
    map: std::collections::HashMap<(i64, i64), Vec<usize>>,
}

impl Grid {
    /// Index `rects`, expanding each by `pad` so a later `query(r)`
    /// returns every rect within `pad` of `r` (superset).
    pub fn build(rects: &[Rect], pad: i64) -> Grid {
        let cell = 2_000;
        let mut map: std::collections::HashMap<(i64, i64), Vec<usize>> =
            std::collections::HashMap::new();
        for (i, r) in rects.iter().enumerate() {
            let (x0, x1) = ((r.x0 - pad).div_euclid(cell), (r.x1 + pad).div_euclid(cell));
            let (y0, y1) = ((r.y0 - pad).div_euclid(cell), (r.y1 + pad).div_euclid(cell));
            for bx in x0..=x1 {
                for by in y0..=y1 {
                    map.entry((bx, by)).or_default().push(i);
                }
            }
        }
        Grid { cell, map }
    }

    /// Candidate indices whose padded extent may touch `r`, sorted
    /// ascending and deduplicated.
    pub fn query(&self, r: &Rect) -> Vec<usize> {
        let mut out = Vec::new();
        self.query_into(r, &mut out);
        out
    }

    /// [`Self::query`] into a reusable buffer (cleared first).
    pub fn query_into(&self, r: &Rect, out: &mut Vec<usize>) {
        out.clear();
        let (x0, x1) = (r.x0.div_euclid(self.cell), r.x1.div_euclid(self.cell));
        let (y0, y1) = (r.y0.div_euclid(self.cell), r.y1.div_euclid(self.cell));
        for bx in x0..=x1 {
            for by in y0..=y1 {
                if let Some(v) = self.map.get(&(bx, by)) {
                    out.extend_from_slice(v);
                }
            }
        }
        out.sort_unstable();
        out.dedup();
    }
}

/// Axis-aware enclosure test (see [`crate::tech::rules::EncAxis`]).
fn encloses_axis(o: &Rect, i: &Rect, m: i64, axis: crate::tech::rules::EncAxis) -> bool {
    use crate::tech::rules::EncAxis;
    let x_ok = o.x0 + m <= i.x0 && o.x1 - m >= i.x1;
    let y_ok = o.y0 + m <= i.y0 && o.y1 - m >= i.y1;
    match axis {
        EncAxis::Both => x_ok && y_ok,
        EncAxis::X => x_ok,
        EncAxis::Y => y_ok,
    }
}

fn uf_find(parent: &mut Vec<usize>, i: usize) -> usize {
    let mut i = i;
    while parent[i] != i {
        parent[i] = parent[parent[i]];
        i = parent[i];
    }
    i
}

/// Union-find grouping of touching same-layer rects.  Grid-bucketed:
/// each rect is only tested against spatial-hash neighbors, replacing
/// the old x-sorted sweep that degenerated to O(n^2) on column-aligned
/// geometry (bitline stacks share x0, defeating the x-window prune).
pub(crate) fn group_touching(rects: &[Rect]) -> Vec<usize> {
    let n = rects.len();
    let mut parent: Vec<usize> = (0..n).collect();
    let grid = Grid::build(rects, 0);
    let mut cands = Vec::new();
    for (i, r) in rects.iter().enumerate() {
        grid.query_into(r, &mut cands);
        for &j in &cands {
            if j <= i {
                continue;
            }
            if r.touches(&rects[j]) {
                let (ri, rj) = (uf_find(&mut parent, i), uf_find(&mut parent, j));
                if ri != rj {
                    parent[ri] = rj;
                }
            }
        }
    }
    (0..n).map(|i| uf_find(&mut parent, i)).collect()
}

/// Append the hierarchical-replication multiplier to a detail string.
fn with_mult(detail: String, mult: usize) -> String {
    if mult > 1 {
        format!("{detail} (x{mult} instance pairs)")
    } else {
        detail
    }
}

/// Spacing check between rects of *different* groups.  Candidates come
/// from a `min_space`-padded grid; emission order matches the legacy
/// x-sorted sweep (outer rect ascending by x0, partner ascending by
/// x0-rank) so the violation set is byte-identical to the old engine.
/// With `owners`, only cross-owner pairs are reported (hier seams).
fn check_spacing(
    rects: &[Rect],
    groups: &[usize],
    owners: Option<&[usize]>,
    min_space: i64,
    lname: &'static str,
    mult: usize,
    report: &mut Report,
) {
    let n = rects.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| rects[i].x0);
    let mut rank = vec![0usize; n];
    for (k, &i) in order.iter().enumerate() {
        rank[i] = k;
    }
    let grid = Grid::build(rects, min_space);
    let mut cands = Vec::new();
    let mut js: Vec<usize> = Vec::new();
    for &i in &order {
        grid.query_into(&rects[i], &mut cands);
        js.clear();
        js.extend(cands.iter().copied().filter(|&j| rank[j] > rank[i]));
        js.sort_by_key(|&j| rank[j]);
        for &j in &js {
            if groups[i] == groups[j] {
                continue;
            }
            if let Some(ow) = owners {
                if ow[i] == ow[j] {
                    continue;
                }
            }
            let (a, b) = (&rects[i], &rects[j]);
            let dx = (b.x0 - a.x1).max(a.x0 - b.x1).max(0);
            let dy = (b.y0 - a.y1).max(a.y0 - b.y1).max(0);
            // euclidean corner-to-corner per standard DRC semantics is
            // overkill for manhattan decks; use max-projection distance
            let dist = dx.max(dy);
            if dist < min_space {
                report.violations.push(Violation {
                    rule: "min_space".into(),
                    layer: lname,
                    at: *a,
                    detail: with_mult(
                        format!("{} < {} (vs rect at {},{})", dist, min_space, b.x0, b.y0),
                        mult,
                    ),
                });
            }
        }
    }
}

/// Conditional enclosure: an inner rect is checked only where it
/// overlaps the outer layer at all (a contact on poly is governed by
/// the poly rule, not the active rule).  Axis-restricted rules model
/// gate extension.  With `owners` = (inner owners, outer owners), an
/// inner is only examined when it overlaps an outer of a *different*
/// owner (same-owner context is covered by that cell's own pass).
#[allow(clippy::too_many_arguments)]
fn check_enclosure(
    tech: &Tech,
    er: &crate::tech::rules::EnclosureRule,
    oi: usize,
    ii: usize,
    outers: &[Rect],
    inners: &[Rect],
    owners: Option<(&[usize], &[usize])>,
    mult: usize,
    report: &mut Report,
) {
    let iname = tech.layers[ii].name;
    let grid = Grid::build(outers, 0);
    let mut cands = Vec::new();
    for (ki, inner) in inners.iter().enumerate() {
        grid.query_into(inner, &mut cands);
        let related = cands.iter().any(|&k| outers[k].overlaps(inner));
        if !related {
            continue;
        }
        if let Some((io, oo)) = owners {
            let cross = cands
                .iter()
                .any(|&k| outers[k].overlaps(inner) && oo[k] != io[ki]);
            if !cross {
                continue;
            }
        }
        let ok = cands
            .iter()
            .any(|&k| encloses_axis(&outers[k], inner, er.margin_nm, er.axis));
        if !ok {
            report.violations.push(Violation {
                rule: format!("enclosure({}>{})", tech.layers[oi].name, iname),
                layer: iname,
                at: *inner,
                detail: with_mult(format!("needs {} nm margin ({:?})", er.margin_nm, er.axis), mult),
            });
        }
    }
}

/// Cross-layer spacing.  Pairs where the b-rect lands on an a-layer
/// shape *connected* to the tested rect are exempt (e.g. a gate-pad
/// contact 10 nm from its own poly column).  The b-side candidates come
/// from a padded grid instead of the old full scan over every b-rect.
#[allow(clippy::too_many_arguments)]
fn check_cross_spacing(
    tech: &Tech,
    ai: usize,
    bi: usize,
    al: &[Rect],
    bl: &[Rect],
    owners: Option<(&[usize], &[usize])>,
    space_nm: i64,
    mult: usize,
    report: &mut Report,
) {
    if al.is_empty() || bl.is_empty() {
        return;
    }
    let a_groups = group_touching(al);
    let a_grid = Grid::build(al, space_nm);
    let b_grid = Grid::build(bl, space_nm);
    let mut bcands = Vec::new();
    let mut acands = Vec::new();
    for (ia, ra) in al.iter().enumerate() {
        b_grid.query_into(ra, &mut bcands);
        let mut have_acands = false;
        for &ib in &bcands {
            let rb = &bl[ib];
            if let Some((ao, bo)) = owners {
                if ao[ia] == bo[ib] {
                    continue;
                }
            }
            let dx = (rb.x0 - ra.x1).max(ra.x0 - rb.x1);
            let dy = (rb.y0 - ra.y1).max(ra.y0 - rb.y1);
            if dx >= space_nm || dy >= space_nm {
                continue; // beyond reach: no violation possible
            }
            // overlapping/touching = same construct (e.g. the gate
            // contact pad ON its poly): not a spacing issue
            let dist = dx.max(dy);
            if dist <= 0 {
                continue;
            }
            // exempt if rb overlaps any a-rect in ra's group
            if !have_acands {
                a_grid.query_into(ra, &mut acands);
                have_acands = true;
            }
            let same_construct = acands
                .iter()
                .any(|&j| a_groups[j] == a_groups[ia] && al[j].overlaps(rb));
            if same_construct {
                continue;
            }
            // dist < space_nm is guaranteed here: both axis gaps passed
            // the beyond-reach check above
            report.violations.push(Violation {
                rule: format!("spacing({},{})", tech.layers[ai].name, tech.layers[bi].name),
                layer: tech.layers[ai].name,
                at: *ra,
                detail: with_mult(format!("{} < {}", dist, space_nm), mult),
            });
        }
    }
}

/// Owner-tagged interaction check used by the hierarchical engine:
/// runs same-layer spacing, enclosure and cross-layer spacing over a
/// window of rects, reporting only cross-owner findings (intra-owner
/// geometry is covered by that cell's own frame pass).
pub(crate) fn check_window(
    tech: &Tech,
    rects: &[Rect],
    owners: &[usize],
    mult: usize,
    report: &mut Report,
) {
    debug_assert_eq!(rects.len(), owners.len());
    report.rects_checked += rects.len();
    let mut by_layer: BTreeMap<usize, (Vec<Rect>, Vec<usize>)> = BTreeMap::new();
    for (r, &o) in rects.iter().zip(owners) {
        let slot = by_layer.entry(r.layer).or_default();
        slot.0.push(*r);
        slot.1.push(o);
    }

    for (role, rules) in tech.rules.checked_layers() {
        if !tech.has_role(*role) || rules.min_space_nm == 0 {
            continue;
        }
        let li = tech.layer(*role);
        let Some((lr, lo)) = by_layer.get(&li) else { continue };
        let groups = group_touching(lr);
        check_spacing(lr, &groups, Some(lo), rules.min_space_nm, tech.layers[li].name, mult, report);
    }

    for er in &tech.rules.enclosures {
        if !tech.has_role(er.outer) || !tech.has_role(er.inner) {
            continue;
        }
        let (oi, ii) = (tech.layer(er.outer), tech.layer(er.inner));
        let (Some((ol, oo)), Some((il, io))) = (by_layer.get(&oi), by_layer.get(&ii)) else {
            continue;
        };
        check_enclosure(tech, er, oi, ii, ol, il, Some((io, oo)), mult, report);
    }

    for sr in &tech.rules.cross_spacings {
        if !tech.has_role(sr.a) || !tech.has_role(sr.b) {
            continue;
        }
        let (ai, bi) = (tech.layer(sr.a), tech.layer(sr.b));
        let (Some((al, ao)), Some((bl, bo))) = (by_layer.get(&ai), by_layer.get(&bi)) else {
            continue;
        };
        check_cross_spacing(tech, ai, bi, al, bl, Some((ao, bo)), sr.space_nm, mult, report);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::sg40;

    fn m1(t: &Tech) -> usize {
        t.layer(LayerRole::Metal1)
    }

    #[test]
    fn clean_pair_passes() {
        let t = sg40();
        let l = m1(&t);
        let rects = vec![
            Rect::new(l, 0, 0, 200, 200),
            Rect::new(l, 300, 0, 500, 200),
        ];
        let rep = check(&t, &rects);
        assert!(rep.clean(), "{:?}", rep.violations);
    }

    #[test]
    fn width_violation_detected() {
        let t = sg40();
        let rects = vec![Rect::new(m1(&t), 0, 0, 30, 500)];
        let rep = check(&t, &rects);
        assert!(rep.violations.iter().any(|v| v.rule == "min_width"));
    }

    #[test]
    fn spacing_violation_detected_and_touching_exempt() {
        let t = sg40();
        let l = m1(&t);
        // 10 nm gap < the m1 spacing rule
        let rects = vec![
            Rect::new(l, 0, 0, 200, 200),
            Rect::new(l, 210, 0, 400, 200),
        ];
        let rep = check(&t, &rects);
        assert!(rep.violations.iter().any(|v| v.rule == "min_space"));
        // abutting rects are one group: exempt
        let rects2 = vec![
            Rect::new(l, 0, 0, 200, 200),
            Rect::new(l, 200, 0, 400, 200),
        ];
        let rep2 = check(&t, &rects2);
        assert!(rep2.clean(), "{:?}", rep2.violations);
    }

    #[test]
    fn area_violation_detected() {
        let t = sg40();
        // m1 min_area 6_000 nm^2: a 60x90 rect = 5_400 fails
        let rects = vec![Rect::new(m1(&t), 0, 0, 60, 90)];
        let rep = check(&t, &rects);
        assert!(rep.violations.iter().any(|v| v.rule == "min_area"));
    }

    #[test]
    fn enclosure_violation_detected() {
        let t = sg40();
        let c = t.layer(LayerRole::Contact);
        let a = t.layer(LayerRole::Active);
        // contact sticking out of active
        let rects = vec![
            Rect::new(a, 0, 0, 100, 100),
            Rect::new(c, 60, 20, 120, 80),
        ];
        let rep = check(&t, &rects);
        assert!(rep
            .violations
            .iter()
            .any(|v| v.rule.starts_with("enclosure")), "{:?}", rep.violations);
    }

    #[test]
    fn generated_cells_are_drc_clean() {
        let t = sg40();
        use crate::layout::{cells, Library};
        for lc in [
            cells::sram6t(&t),
            cells::gc2t_sisi(&t, false),
            cells::gc2t_sisi(&t, true),
            cells::gc2t_osos(&t),
            cells::inverter(&t, 1.0),
            cells::inverter(&t, 4.0),
            cells::nand2(&t),
            cells::sense_amp(&t),
            cells::write_driver(&t),
            cells::precharge(&t),
            cells::predischarge(&t),
            cells::level_shifter(&t),
            cells::column_mux(&t),
            cells::tgate(&t),
        ] {
            let mut lib = Library::default();
            let name = lc.layout.name.clone();
            lib.add(lc.layout);
            let rects = lib.flatten(&name).unwrap();
            let rep = check(&t, &rects);
            assert!(
                rep.clean(),
                "cell {name} has {} violations; first: {}",
                rep.violations.len(),
                rep.violations[0]
            );
        }
    }

    #[test]
    fn injected_violations_in_clean_cell_are_caught() {
        // failure injection: shrink a rule-clean cell's wire to 30 nm
        let t = sg40();
        use crate::layout::{cells, Library};
        let lc = cells::inverter(&t, 1.0);
        let mut lib = Library::default();
        lib.add(lc.layout);
        let mut rects = lib.flatten("inv_x1").unwrap();
        rects.push(Rect::new(m1(&t), 5000, 5000, 5030, 5400));
        let rep = check(&t, &rects);
        assert!(!rep.clean());
    }

    /// Grid correctness for rects that straddle bucket boundaries: any
    /// pair within `pad` of each other must co-appear in a query.
    #[test]
    fn grid_query_covers_bucket_straddlers() {
        // bucket size is 2000; place rects ON and ACROSS the seams
        let rects = vec![
            Rect::new(0, 1990, 0, 2010, 50),      // straddles x seam
            Rect::new(0, 2015, 0, 2100, 50),      // 5 nm right of it
            Rect::new(0, -60, -60, -40, -40),     // negative-coord bucket
            Rect::new(0, -30, -60, 10, -40),      // straddles origin seam
            Rect::new(0, 0, 1990, 50, 6100),      // tall: many y buckets
            Rect::new(0, 70, 3990, 120, 4020),    // beside the tall one
            Rect::new(0, 10_000, 10_000, 10_050, 10_050), // far away
        ];
        let pad = 40;
        let grid = Grid::build(&rects, pad);
        let near = |a: &Rect, b: &Rect| {
            let dx = (b.x0 - a.x1).max(a.x0 - b.x1);
            let dy = (b.y0 - a.y1).max(a.y0 - b.y1);
            dx <= pad && dy <= pad
        };
        for (i, r) in rects.iter().enumerate() {
            let cands = grid.query(r);
            // completeness: every rect within pad must be returned
            for (j, o) in rects.iter().enumerate() {
                if near(r, o) {
                    assert!(cands.contains(&j), "rect {j} missing from query({i})");
                }
            }
            // sanity: the far rect is not a candidate of the origin ones
            if i < 4 {
                assert!(!cands.contains(&6), "far rect leaked into query({i})");
            }
            // sorted + deduplicated contract
            let mut sorted = cands.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(cands, sorted);
        }
    }

    /// Property: grid-backed group_touching matches the brute-force
    /// O(n^2) union-find on random rect soups.
    #[test]
    fn group_touching_matches_bruteforce() {
        use crate::util::rng::{check as prop, Rng};
        fn brute(rects: &[Rect]) -> Vec<usize> {
            let n = rects.len();
            let mut parent: Vec<usize> = (0..n).collect();
            for i in 0..n {
                for j in i + 1..n {
                    if rects[i].touches(&rects[j]) {
                        let (ri, rj) = (uf_find(&mut parent, i), uf_find(&mut parent, j));
                        if ri != rj {
                            parent[ri] = rj;
                        }
                    }
                }
            }
            (0..n).map(|i| uf_find(&mut parent, i)).collect()
        }
        fn canon(groups: &[usize]) -> Vec<usize> {
            // relabel group ids by first appearance so different union
            // orders compare equal
            let mut map = std::collections::BTreeMap::new();
            groups
                .iter()
                .map(|g| {
                    let next = map.len();
                    *map.entry(*g).or_insert(next)
                })
                .collect()
        }
        prop("group_touching", 25, |rng: &mut Rng| {
            let n = 2 + rng.below(120);
            let rects: Vec<Rect> = (0..n)
                .map(|_| {
                    let x0 = rng.below(8_000) as i64 - 2_000;
                    let y0 = rng.below(8_000) as i64 - 2_000;
                    let w = 20 + rng.below(2_500) as i64;
                    let h = 20 + rng.below(2_500) as i64;
                    Rect::new(0, x0, y0, x0 + w, y0 + h)
                })
                .collect();
            assert_eq!(canon(&group_touching(&rects)), canon(&brute(&rects)));
        });
    }

    /// The grid-accelerated cross-spacing pass must report exactly what
    /// the old full-scan loop reported, including the same-construct
    /// exemption.
    #[test]
    fn cross_spacing_matches_legacy_semantics() {
        let t = sg40();
        let poly = t.layer(LayerRole::Poly);
        let cont = t.layer(LayerRole::Contact);
        // contact 10 nm from an unrelated poly rect: violation (rule 40)
        let rects = vec![
            Rect::new(poly, 0, 0, 40, 400),
            Rect::new(cont, 50, 100, 110, 160),
        ];
        let rep = check(&t, &rects);
        assert!(
            rep.violations.iter().any(|v| v.rule == "spacing(poly,contact)"),
            "{:?}",
            rep.violations
        );
        // same contact ON a poly pad connected to the column: exempt
        let rects2 = vec![
            Rect::new(poly, 0, 0, 40, 400),
            Rect::new(poly, 40, 100, 140, 200), // pad touching the column
            Rect::new(cont, 60, 120, 120, 180), // on the pad
        ];
        let rep2 = check(&t, &rects2);
        assert!(
            !rep2.violations.iter().any(|v| v.rule.starts_with("spacing(")),
            "{:?}",
            rep2.violations
        );
    }

    #[test]
    fn generated_array_is_drc_clean_via_flat_and_hier() {
        let t = sg40();
        use crate::layout::{bank, cells, Library};
        let mut lib = Library::default();
        lib.add(cells::gc2t_sisi(&t, false).layout);
        bank::tile_array(&mut lib, &t, "arr", "gc2t_sisi", 8, 8, 4, 400).unwrap();
        let rects = lib.flatten("arr").unwrap();
        let flat = check(&t, &rects);
        assert!(flat.clean(), "flat: {:?}", flat.violations.first());
        let hrep = hier::check_hier(&t, &lib, "arr").unwrap();
        assert!(hrep.clean(), "hier: {:?}", hrep.violations.first());
    }
}

#[cfg(test)]
mod dump {
    use super::*;
    use crate::tech::sg40;
    #[test]
    #[ignore]
    fn dump_all_violations() {
        let t = sg40();
        use crate::layout::{cells, Library};
        for lc in [
            cells::sram6t(&t),
            cells::gc2t_sisi(&t, false),
            cells::gc2t_sisi(&t, true),
            cells::gc2t_osos(&t),
            cells::inverter(&t, 1.0),
            cells::nand2(&t),
            cells::sense_amp(&t),
            cells::write_driver(&t),
            cells::precharge(&t),
            cells::predischarge(&t),
            cells::level_shifter(&t),
            cells::column_mux(&t),
            cells::tgate(&t),
        ] {
            let mut lib = Library::default();
            let name = lc.layout.name.clone();
            lib.add(lc.layout);
            let rects = lib.flatten(&name).unwrap();
            let rep = check(&t, &rects);
            let mut counts: std::collections::BTreeMap<String, usize> = Default::default();
            for v in &rep.violations {
                *counts.entry(format!("{} {}", v.rule, v.layer)).or_insert(0) += 1;
            }
            println!("== {name}: {} violations", rep.violations.len());
            for (k, c) in counts { println!("   {k}: {c}"); }
            for v in rep.violations.iter().take(3) { println!("   e.g. {v}"); }
        }
    }
}
