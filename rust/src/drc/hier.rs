//! Hierarchical DRC: check each unique cell once, then only re-examine
//! geometry near instance boundaries ("halo" regions).
//!
//! The flat checker re-verifies the identical bitcell interior ~16k
//! times on a 128x128 bank.  This engine decomposes the work into:
//!
//! 1. **Interior pass** — every unique cell reachable from `top` gets
//!    one full flat [`super::check`] over its *local* rects (leaf cells
//!    therefore get exactly the flat treatment, once).
//! 2. **Parent-local seams** — per instance, child rects within the
//!    rule halo of any parent-local rect (power straps, rings, routed
//!    tracks, vias) are promoted into the parent frame and checked
//!    cross-owner against those local rects.
//! 3. **Instance-pair seams** — overlapping-halo instance pairs are
//!    deduplicated by `(cell_a, orient_a, cell_b, orient_b, rel_dx,
//!    rel_dy)`: a uniform array has only a handful of distinct
//!    neighbor configurations, so one representative pair is checked
//!    per configuration and findings carry an `xN` multiplier.
//!
//! # Invariants
//!
//! Interactions are strictly pairwise cross-owner (intra-cell geometry
//! is rule 1's job), and violations inside a repeated cell are
//! reported once — the point of the mode.  Seam findings carry an
//! `xN` multiplier for the `N` instance pairs sharing the checked
//! relative configuration, so the violation *count* stays comparable
//! to the flat checker even though the work is per-configuration.
//!
//! # Conservative approximations
//!
//! Known approximations, each *conservative* (they can over-report,
//! never under-report) for the generators in this crate:
//!
//! * `min_area` is evaluated per cell — a polygon meeting the rule
//!   only via merging across instances would over-report;
//! * exemption connectivity inside a seam window is limited to
//!   promoted rects;
//! * the interior pass sees a cell's local rects without child
//!   context, so a conditional-rule exemption that only holds via
//!   child geometry (e.g. a parent-local contact whose same-construct
//!   poly pad lives inside a child) would over-report.
//!
//! None of this crate's generators draw FEOL layers as parent-local
//! rects, and the flat-vs-hier equivalence tests plus the perf bench's
//! sanity assert guard the agreement on generated layouts.

use super::{check, check_window, Grid, Report};
use crate::layout::{FlattenCache, Library, Rect};
use crate::tech::Tech;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

/// Maximum distance at which any rule of `tech` can relate two rects
/// (the halo width).
pub fn rule_reach(tech: &Tech) -> i64 {
    let mut h = 1i64;
    for (_, lr) in tech.rules.checked_layers() {
        h = h.max(lr.min_space_nm);
    }
    for er in &tech.rules.enclosures {
        h = h.max(er.margin_nm);
    }
    for sr in &tech.rules.cross_spacings {
        h = h.max(sr.space_nm);
    }
    h
}

/// Max-norm rect distance strictly below `halo` (overlap counts).
fn near(a: &Rect, b: &Rect, halo: i64) -> bool {
    let dx = (b.x0 - a.x1).max(a.x0 - b.x1);
    let dy = (b.y0 - a.y1).max(a.y0 - b.y1);
    dx < halo && dy < halo
}

fn bbox_of(rects: &[Rect]) -> Option<Rect> {
    let mut it = rects.iter();
    let first = *it.next()?;
    Some(it.fold(first, |a, b| a.union_bbox(b)))
}

/// Per-layer flag: does the layer participate in any rule at all?
/// (Annotation layers like `boundary` never need promotion.)
fn ruled_layers(tech: &Tech) -> Vec<bool> {
    let mut v = vec![false; tech.layers.len()];
    for (role, lr) in tech.rules.checked_layers() {
        if tech.has_role(*role)
            && (lr.min_width_nm > 0 || lr.min_space_nm > 0 || lr.min_area_nm2 > 0)
        {
            v[tech.layer(*role)] = true;
        }
    }
    for er in &tech.rules.enclosures {
        if tech.has_role(er.outer) && tech.has_role(er.inner) {
            v[tech.layer(er.outer)] = true;
            v[tech.layer(er.inner)] = true;
        }
    }
    for sr in &tech.rules.cross_spacings {
        if tech.has_role(sr.a) && tech.has_role(sr.b) {
            v[tech.layer(sr.a)] = true;
            v[tech.layer(sr.b)] = true;
        }
    }
    v
}

/// Hierarchically check `top` (fresh flatten memo).
pub fn check_hier(tech: &Tech, lib: &Library, top: &str) -> crate::Result<Report> {
    let mut cache = FlattenCache::default();
    check_hier_cached(tech, lib, top, &mut cache)
}

/// Hierarchically check `top`, sharing a caller-owned flatten memo
/// (sweeps re-checking many banks over the same cell library).
pub fn check_hier_cached(
    tech: &Tech,
    lib: &Library,
    top: &str,
    cache: &mut FlattenCache,
) -> crate::Result<Report> {
    let halo = rule_reach(tech);
    let ruled = ruled_layers(tech);

    // unique cells reachable from top
    let mut order: Vec<String> = Vec::new();
    let mut seen: BTreeSet<String> = BTreeSet::new();
    let mut stack = vec![top.to_string()];
    while let Some(name) = stack.pop() {
        if !seen.insert(name.clone()) {
            continue;
        }
        let c = lib.get(&name)?;
        for i in &c.insts {
            stack.push(i.cell.clone());
        }
        order.push(name);
    }

    let mut report = Report::default();
    for name in &order {
        check_cell_frame(tech, lib, name, halo, &ruled, cache, &mut report)?;
    }
    Ok(report)
}

fn check_cell_frame(
    tech: &Tech,
    lib: &Library,
    name: &str,
    halo: i64,
    ruled: &[bool],
    cache: &mut FlattenCache,
    report: &mut Report,
) -> crate::Result<()> {
    let c = lib.get(name)?;

    // 1. interior: full flat rule set over this cell's local rects
    let local_rep = check(tech, &c.rects);
    report.rects_checked += local_rep.rects_checked;
    for v in local_rep.violations {
        report.violations.push(super::Violation {
            detail: format!("{} [cell {name}]", v.detail),
            ..v
        });
    }

    if c.insts.is_empty() {
        return Ok(());
    }

    // placed flattened geometry per instance; flat lists AND their
    // local bboxes are memoized per (cell, orient) — an array frame
    // has ~16k instances of a handful of distinct children
    let mut flats: Vec<Arc<Vec<Rect>>> = Vec::with_capacity(c.insts.len());
    let mut bbs: Vec<Rect> = Vec::with_capacity(c.insts.len());
    let mut bb_memo: BTreeMap<(&str, usize), Option<Rect>> = BTreeMap::new();
    for i in &c.insts {
        let flat = lib.flatten_oriented(&i.cell, i.orient, cache)?;
        let local_bb = *bb_memo
            .entry((i.cell.as_str(), i.orient.idx()))
            .or_insert_with(|| bbox_of(&flat));
        let bb = local_bb
            .map(|b| b.translated(i.dx, i.dy))
            // empty cells interact with nothing; park a point far away
            .unwrap_or(Rect { layer: 0, x0: i64::MIN / 4, y0: i64::MIN / 4, x1: i64::MIN / 4, y1: i64::MIN / 4 });
        flats.push(flat);
        bbs.push(bb);
    }

    // 2. parent-local rects vs each instance's promoted halo rects
    let ruled_local: Vec<Rect> = c.rects.iter().copied().filter(|r| ruled[r.layer]).collect();
    if !ruled_local.is_empty() {
        let lgrid = Grid::build(&ruled_local, halo);
        let mut cands = Vec::new();
        for (k, inst) in c.insts.iter().enumerate() {
            // bbox-level early-out: most instances of an array frame are
            // nowhere near any parent-local rect (straps/rings/tracks)
            lgrid.query_into(&bbs[k], &mut cands);
            if !cands.iter().any(|&q| near(&ruled_local[q], &bbs[k], halo)) {
                continue;
            }
            let mut window: Vec<Rect> = Vec::new();
            let mut owners: Vec<usize> = Vec::new();
            for r in flats[k].iter() {
                if !ruled[r.layer] {
                    continue;
                }
                let rt = r.translated(inst.dx, inst.dy);
                lgrid.query_into(&rt, &mut cands);
                if cands.iter().any(|&q| near(&ruled_local[q], &rt, halo)) {
                    window.push(rt);
                    owners.push(1);
                }
            }
            if window.is_empty() {
                continue;
            }
            for lr in &ruled_local {
                if near(lr, &bbs[k], halo) {
                    window.push(*lr);
                    owners.push(0);
                }
            }
            check_window(tech, &window, &owners, 1, report);
        }
    }

    // 3. instance-pair seams, deduplicated by relative configuration.
    // Cell names are interned to per-frame ids so the dedup key is
    // all-integer (no String allocation per candidate pair).
    let mut cell_ids: BTreeMap<&str, usize> = BTreeMap::new();
    for i in &c.insts {
        let next = cell_ids.len();
        cell_ids.entry(i.cell.as_str()).or_insert(next);
    }
    type PairKey = (usize, usize, usize, usize, i64, i64);
    let mut pairs: BTreeMap<PairKey, (usize, usize, usize)> = BTreeMap::new();
    let pair_grid = Grid::build(&bbs, halo);
    let mut cands = Vec::new();
    for (k, bk) in bbs.iter().enumerate() {
        pair_grid.query_into(bk, &mut cands);
        for &j in &cands {
            if j <= k || !near(bk, &bbs[j], halo) {
                continue;
            }
            let (a, b) = (&c.insts[k], &c.insts[j]);
            let key: PairKey = (
                cell_ids[a.cell.as_str()],
                a.orient.idx(),
                cell_ids[b.cell.as_str()],
                b.orient.idx(),
                b.dx - a.dx,
                b.dy - a.dy,
            );
            pairs
                .entry(key)
                .and_modify(|e| e.2 += 1)
                .or_insert((k, j, 1));
        }
    }
    for (k, j, count) in pairs.into_values() {
        let mut window: Vec<Rect> = Vec::new();
        let mut owners: Vec<usize> = Vec::new();
        let (ik, ij) = (&c.insts[k], &c.insts[j]);
        for r in flats[k].iter() {
            if !ruled[r.layer] {
                continue;
            }
            let rt = r.translated(ik.dx, ik.dy);
            if near(&rt, &bbs[j], halo) {
                window.push(rt);
                owners.push(1);
            }
        }
        for r in flats[j].iter() {
            if !ruled[r.layer] {
                continue;
            }
            let rt = r.translated(ij.dx, ij.dy);
            if near(&rt, &bbs[k], halo) {
                window.push(rt);
                owners.push(2);
            }
        }
        if !window.is_empty() {
            check_window(tech, &window, &owners, count, report);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::{bank, cells, Cell, Library, Orient};
    use crate::tech::{sg40, LayerRole};

    #[test]
    fn rule_reach_covers_the_widest_rule() {
        let t = sg40();
        // sg40's widest reach is the 300 nm nwell spacing
        assert_eq!(rule_reach(&t), 300);
    }

    #[test]
    fn hier_matches_flat_on_clean_array_and_dff() {
        let t = sg40();
        let mut lib = Library::default();
        lib.add(cells::gc2t_sisi(&t, false).layout);
        bank::tile_array(&mut lib, &t, "arr", "gc2t_sisi", 16, 16, 8, 400).unwrap();
        crate::layout::compose::dff(&mut lib, &t).unwrap();
        for top in ["arr", "dff"] {
            let flat = check(&t, &lib.flatten(top).unwrap());
            let hier = check_hier(&t, &lib, top).unwrap();
            assert!(flat.clean(), "{top} flat: {:?}", flat.violations.first());
            assert!(hier.clean(), "{top} hier: {:?}", hier.violations.first());
        }
    }

    #[test]
    fn interior_violation_reported_once_not_per_instance() {
        let t = sg40();
        let mut lib = Library::default();
        let mut lc = cells::gc2t_sisi(&t, false);
        // inject a skinny m1 sliver deep inside the bitcell
        let m1 = t.layer(LayerRole::Metal1);
        lc.layout.add(Rect::new(m1, 500, 300, 530, 700));
        lib.add(lc.layout);
        bank::tile_array(&mut lib, &t, "arr", "gc2t_sisi", 8, 8, 0, 0).unwrap();

        let flat = check(&t, &lib.flatten("arr").unwrap());
        let flat_widths = flat.violations.iter().filter(|v| v.rule == "min_width").count();
        assert_eq!(flat_widths, 64, "flat re-reports per instance");

        let hier = check_hier(&t, &lib, "arr").unwrap();
        let hier_widths = hier.violations.iter().filter(|v| v.rule == "min_width").count();
        assert_eq!(hier_widths, 1, "hier reports the unique cell once: {:?}", hier.violations);
    }

    #[test]
    fn seam_violation_across_instances_is_caught_and_deduped() {
        let t = sg40();
        let m1 = t.layer(LayerRole::Metal1);
        let b = t.layer(LayerRole::Boundary);
        let mut lib = Library::default();
        let mut leaf = Cell::new("pad");
        leaf.add(Rect::new(m1, 0, 0, 200, 200));
        leaf.add(Rect::new(b, 0, 0, 210, 200));
        lib.add(leaf);
        // row of pads 10 nm apart: m1 spacing rule is 20 nm -> seam
        // violations between every adjacent pair, one configuration
        let mut row = Cell::new("row");
        for i in 0..8 {
            row.place(format!("p{i}"), "pad", i * 210, 0, Orient::R0);
        }
        lib.add(row);

        let flat = check(&t, &lib.flatten("row").unwrap());
        assert_eq!(flat.violations.iter().filter(|v| v.rule == "min_space").count(), 7);

        let hier = check_hier(&t, &lib, "row").unwrap();
        let seams: Vec<_> = hier.violations.iter().filter(|v| v.rule == "min_space").collect();
        assert_eq!(seams.len(), 1, "{:?}", hier.violations);
        assert!(seams[0].detail.contains("x7 instance pairs"), "{}", seams[0].detail);
    }

    #[test]
    fn parent_local_strap_interaction_is_checked() {
        let t = sg40();
        let m1 = t.layer(LayerRole::Metal1);
        let b = t.layer(LayerRole::Boundary);
        let mut lib = Library::default();
        let mut leaf = Cell::new("bit");
        leaf.add(Rect::new(m1, 0, 100, 400, 200));
        leaf.add(Rect::new(b, 0, 0, 400, 300));
        lib.add(leaf);
        // parent strap 10 nm below the child's m1: cross-owner violation
        let mut top = Cell::new("top");
        top.place("b0", "bit", 0, 0, Orient::R0);
        top.add(Rect::new(m1, 0, 0, 400, 90));
        lib.add(top);
        let hier = check_hier(&t, &lib, "top").unwrap();
        assert!(
            hier.violations.iter().any(|v| v.rule == "min_space"),
            "{:?}",
            hier.violations
        );
        let flat = check(&t, &lib.flatten("top").unwrap());
        assert!(flat.violations.iter().any(|v| v.rule == "min_space"));
    }
}
