//! Content-addressed on-disk evaluation store — the persistent tier
//! under [`dse::EvalCache`](crate::dse::EvalCache).
//!
//! One evaluated design point ([`dse::Evaluated`](crate::dse::Evaluated))
//! is one small JSON file whose *identity* is the full provenance of
//! the measurement, not just the design: [`StoreKey`] combines the
//! [`ConfigKey`], the technology name, the window-quantization
//! resolution (bit pattern — resolution changes measured windows, so
//! entries must never alias across resolutions) and [`FORMAT_VERSION`].
//! The canonical key string is stored **verbatim inside the entry**
//! and re-checked on load, so a hash collision, a renamed file, or an
//! entry copied between stores is rejected instead of silently served
//! as someone else's evaluation.
//!
//! Numeric payloads (`area_um2`, every [`BankPerf`] figure) are
//! encoded as 16-hex-digit `f64::to_bits` strings, so persistence is
//! **bitwise** — including the all-NaN quarantine placeholder, which a
//! plain decimal round-trip would corrupt (`NaN` has no JSON literal).
//! That is what lets a warm restart reproduce a sweep bit-identically
//! with zero characterization executions.
//!
//! Writes are atomic (`.tmp` + rename) and best-effort: a read-only
//! store directory degrades to a cache miss on every load, never an
//! error.  Validation failures of any kind (unparseable bytes, version
//! bump, key mismatch) count as `rejects` in [`StoreStats`] and the
//! caller recomputes — corruption costs a re-evaluation, not wrong
//! data.
//!
//! [`BankPerf`]: crate::characterize::BankPerf

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::characterize::BankPerf;
use crate::compiler::ConfigKey;
use crate::dse::Evaluated;
use crate::util::json::{Json, ObjBuilder};

/// Bump on ANY change to the entry encoding or to the semantics of
/// what a stored figure means; old entries are then rejected (and
/// recomputed) rather than misread.
pub const FORMAT_VERSION: u64 = 1;

/// Full provenance identity of one stored evaluation.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct StoreKey {
    pub config: ConfigKey,
    /// [`Tech::name`](crate::tech::Tech::name) the point was
    /// characterized under.
    pub tech: String,
    /// `window_resolution.to_bits()` — the quantization step changes
    /// the measured transient windows, so it is part of identity.
    pub window_res_bits: u64,
}

impl StoreKey {
    pub fn new(config: ConfigKey, tech: &str, window_resolution: f64) -> StoreKey {
        StoreKey { config, tech: tech.to_string(), window_res_bits: window_resolution.to_bits() }
    }

    /// Canonical, human-greppable key string.  This exact string is
    /// hashed for the filename AND embedded verbatim in the entry;
    /// equality of the embedded copy is what validates a load.
    pub fn canonical(&self) -> String {
        let ConfigKey { word_size, num_words, flavor, wwlls, mux_factor, write_vt_bits } =
            &self.config;
        let mux = match mux_factor {
            Some(m) => m.to_string(),
            None => "none".to_string(),
        };
        let vt = match write_vt_bits {
            Some(b) => format!("{b:016x}"),
            None => "none".to_string(),
        };
        format!(
            "v{}|tech={}|res={:016x}|word={}|words={}|flavor={}|wwlls={}|mux={}|vt={}",
            FORMAT_VERSION,
            self.tech,
            self.window_res_bits,
            word_size,
            num_words,
            crate::cli::flavor_name(*flavor),
            wwlls,
            mux,
            vt,
        )
    }

    /// Entry filename: FNV-1a of the canonical string.  Collisions are
    /// harmless (the embedded key check rejects the impostor and the
    /// point is recomputed), so a 64-bit hash is plenty.
    pub fn filename(&self) -> String {
        format!("{:016x}.json", fnv1a64(self.canonical().as_bytes()))
    }
}

/// FNV-1a 64-bit — tiny, dependency-free, stable across platforms
/// (unlike `DefaultHasher`, whose output is explicitly unspecified
/// between releases and therefore unusable for on-disk names).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Load/save/reject counters for one [`DiskStore`] lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Entries served (validated) from disk.
    pub hits: usize,
    /// Lookups with no file on disk.
    pub misses: usize,
    /// Files present but rejected: parse failure, version mismatch,
    /// canonical-key mismatch, or malformed payload.
    pub rejects: usize,
    /// Best-effort saves that failed (e.g. read-only directory).
    pub write_errors: usize,
}

/// The on-disk tier.  Thread-safe (`&self` everywhere); concurrent
/// saves of the same key are benign because writes are atomic renames
/// of identical content.
#[derive(Debug)]
pub struct DiskStore {
    dir: PathBuf,
    hits: AtomicUsize,
    misses: AtomicUsize,
    rejects: AtomicUsize,
    write_errors: AtomicUsize,
}

impl DiskStore {
    /// Open (creating the directory if needed).  Fails only if the
    /// directory cannot be created — an *unwritable* but existing
    /// directory opens fine and degrades to a read-only store.
    pub fn open(dir: impl AsRef<Path>) -> crate::Result<DiskStore> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .map_err(|e| anyhow::anyhow!("store: cannot create {}: {e}", dir.display()))?;
        Ok(DiskStore {
            dir,
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            rejects: AtomicUsize::new(0),
            write_errors: AtomicUsize::new(0),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn stats(&self) -> StoreStats {
        StoreStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            rejects: self.rejects.load(Ordering::Relaxed),
            write_errors: self.write_errors.load(Ordering::Relaxed),
        }
    }

    /// Load and validate one entry.  `None` (and the appropriate
    /// counter) on missing file or any validation failure — the caller
    /// recomputes; this method never fabricates or aliases data.
    pub fn load(&self, key: &StoreKey) -> Option<Evaluated> {
        let path = self.dir.join(key.filename());
        let bytes = match std::fs::read_to_string(&path) {
            Ok(b) => b,
            Err(_) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        match decode_entry(&bytes, key) {
            Some(e) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e)
            }
            None => {
                self.rejects.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Persist one entry, best-effort.  Atomic (`.tmp` + rename) so a
    /// crashed or concurrent writer can never leave a torn entry for
    /// [`Self::load`] to reject later.
    pub fn save(&self, key: &StoreKey, e: &Evaluated) {
        let line = encode_entry(key, e);
        let path = self.dir.join(key.filename());
        let tmp = self.dir.join(format!("{}.tmp.{}", key.filename(), std::process::id()));
        let res = std::fs::write(&tmp, line.as_bytes()).and_then(|()| std::fs::rename(&tmp, &path));
        if res.is_err() {
            let _ = std::fs::remove_file(&tmp);
            self.write_errors.fetch_add(1, Ordering::Relaxed);
        }
    }
}

fn hex_bits(v: f64) -> Json {
    Json::Str(format!("{:016x}", v.to_bits()))
}

fn parse_bits(j: &Json) -> Option<f64> {
    let s = j.as_str()?;
    if s.len() != 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok().map(f64::from_bits)
}

/// One-line JSON encoding of an entry.  Every `f64` is a
/// 16-hex-digit bit pattern (bitwise round-trip incl. NaN); the
/// canonical key rides along verbatim for load-time validation.
pub fn encode_entry(key: &StoreKey, e: &Evaluated) -> String {
    let p = &e.perf;
    let perf = ObjBuilder::new()
        .put("f_read_hz", hex_bits(p.f_read_hz))
        .put("f_write_hz", hex_bits(p.f_write_hz))
        .put("f_op_hz", hex_bits(p.f_op_hz))
        .put("bandwidth_bps", hex_bits(p.bandwidth_bps))
        .put("retention_s", hex_bits(p.retention_s))
        .put("leakage_w", hex_bits(p.leakage_w))
        .put("e_read_j", hex_bits(p.e_read_j))
        .put("t_decoder_s", hex_bits(p.t_decoder_s))
        .put("t_cell_read_s", hex_bits(p.t_cell_read_s))
        .put("stored_one_v", hex_bits(p.stored_one_v))
        .put("functional", Json::Bool(p.functional))
        .build();
    let quarantine = match &e.quarantine {
        Some(r) => Json::Str(r.clone()),
        None => Json::Null,
    };
    ObjBuilder::new()
        .put("version", Json::Num(FORMAT_VERSION as f64))
        .put("key", Json::Str(key.canonical()))
        .put("area_um2", hex_bits(e.area_um2))
        .put("perf", perf)
        .put("quarantine", quarantine)
        .build()
        .dump()
}

/// Strict decode-and-validate.  `None` unless the bytes parse, the
/// version matches [`FORMAT_VERSION`], the embedded canonical key is
/// byte-identical to `key.canonical()`, and every payload field is
/// well-formed.  The config is rebuilt from the key
/// ([`ConfigKey::to_config`] is lossless), so an entry can never
/// carry a config that disagrees with its identity.
pub fn decode_entry(bytes: &str, key: &StoreKey) -> Option<Evaluated> {
    let j = Json::parse(bytes).ok()?;
    let version = j.get("version")?.as_f64()?;
    if version != FORMAT_VERSION as f64 {
        return None;
    }
    if j.get("key")?.as_str()? != key.canonical() {
        return None;
    }
    let area_um2 = parse_bits(j.get("area_um2")?)?;
    let p = j.get("perf")?;
    let perf = BankPerf {
        f_read_hz: parse_bits(p.get("f_read_hz")?)?,
        f_write_hz: parse_bits(p.get("f_write_hz")?)?,
        f_op_hz: parse_bits(p.get("f_op_hz")?)?,
        bandwidth_bps: parse_bits(p.get("bandwidth_bps")?)?,
        retention_s: parse_bits(p.get("retention_s")?)?,
        leakage_w: parse_bits(p.get("leakage_w")?)?,
        e_read_j: parse_bits(p.get("e_read_j")?)?,
        t_decoder_s: parse_bits(p.get("t_decoder_s")?)?,
        t_cell_read_s: parse_bits(p.get("t_cell_read_s")?)?,
        stored_one_v: parse_bits(p.get("stored_one_v")?)?,
        functional: p.get("functional")?.as_bool()?,
    };
    let quarantine = match j.get("quarantine")? {
        Json::Null => None,
        q => Some(q.as_str()?.to_string()),
    };
    Some(Evaluated { config: key.config.to_config(), perf, area_um2, quarantine })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{CellFlavor, Config};

    fn sample_eval() -> (StoreKey, Evaluated) {
        let mut cfg = Config::new(32, 64, CellFlavor::GcSiSiNp);
        cfg.write_vt = Some(0.42);
        let perf = BankPerf {
            f_read_hz: 1.23e9,
            f_write_hz: 2.5e9,
            f_op_hz: 1.23e9,
            bandwidth_bps: 3.9e10,
            retention_s: 1.0 / 3.0,
            leakage_w: 5e-324, // subnormal: stresses the bit round-trip
            e_read_j: 2.1e-13,
            t_decoder_s: 8.1e-11,
            t_cell_read_s: 3.3e-10,
            stored_one_v: 0.73,
            functional: true,
        };
        let e = Evaluated { config: cfg.clone(), perf, area_um2: 1234.5678, quarantine: None };
        (StoreKey::new(cfg.key(), "sg40", 0.1), e)
    }

    #[test]
    fn canonical_key_distinguishes_every_identity_axis() {
        let (key, _) = sample_eval();
        let base = key.canonical();
        let mut tech = key.clone();
        tech.tech = "sg28".into();
        let mut res = key.clone();
        res.window_res_bits = 0.2f64.to_bits();
        let mut cfg = key.clone();
        cfg.config.word_size = 16;
        for other in [tech, res, cfg] {
            assert_ne!(base, other.canonical());
            assert_ne!(key.filename(), other.filename());
        }
        assert!(base.starts_with(&format!("v{FORMAT_VERSION}|tech=sg40|")));
    }

    #[test]
    fn encode_decode_is_bitwise_including_nan_quarantine() {
        let (key, e) = sample_eval();
        let line = encode_entry(&key, &e);
        let back = decode_entry(&line, &key).expect("round-trip");
        assert_eq!(back.config.key(), e.config.key());
        assert_eq!(back.area_um2.to_bits(), e.area_um2.to_bits());
        assert_eq!(back.perf.retention_s.to_bits(), e.perf.retention_s.to_bits());
        assert_eq!(back.perf.leakage_w.to_bits(), e.perf.leakage_w.to_bits());
        assert_eq!(back.quarantine, None);

        // quarantined entry: all-NaN perf must survive bit-for-bit
        let q = Evaluated {
            config: e.config.clone(),
            perf: BankPerf::quarantined(),
            area_um2: f64::NAN,
            quarantine: Some("write stage: poisoned".into()),
        };
        let back = decode_entry(&encode_entry(&key, &q), &key).expect("round-trip");
        assert_eq!(back.area_um2.to_bits(), f64::NAN.to_bits());
        assert!(back.perf.f_op_hz.is_nan());
        assert!(!back.perf.functional);
        assert_eq!(back.quarantine.as_deref(), Some("write stage: poisoned"));
    }

    #[test]
    fn decode_rejects_version_and_key_mismatches() {
        let (key, e) = sample_eval();
        let line = encode_entry(&key, &e);
        assert!(decode_entry(&line.replace("\"version\":1", "\"version\":2"), &key).is_none());
        let mut other = key.clone();
        other.tech = "sg28".into();
        assert!(decode_entry(&line, &other).is_none(), "copied entry must not alias");
        assert!(decode_entry("not json at all", &key).is_none());
        assert!(decode_entry(&line.replace("functional", "funktional"), &key).is_none());
    }
}
