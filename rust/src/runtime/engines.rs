//! Typed batched entry points over any [`ExecBackend`] — the AOT XLA
//! artifacts and the native in-process solver alike.
//!
//! Each function pads the design-point list to the artifact batch size,
//! assembles the input tensors per the manifest's param/stim/node
//! layouts (column names, never hard-coded indices) and parses the
//! output tuple back into per-design results.  Both backends expose the
//! same manifest layout ([`super::native::native_manifest`] mirrors
//! `python/compile/aot.py`), so this module is backend-agnostic.

use super::stimulus as st;
use super::{ArtifactMeta, ExecBackend, Tensor};
use crate::tech::DeviceCard;

/// Why one design point's row was rejected — a degenerate input caught
/// before execution (e.g. `c_sn <= 0`, which would otherwise become a
/// silent `1/0` in the inverse-capacitance tensor) or a non-finite
/// solver output caught by the per-row NaN/Inf scan — while the rest of
/// its batch stayed healthy.
#[derive(Debug, Clone)]
pub struct RowFault {
    pub reason: String,
}

/// Per-row result of a batched op: healthy rows carry the op's result,
/// degenerate/poisoned rows carry a [`RowFault`].  The `*_rows` entry
/// points return these so one bad design point quarantines itself
/// instead of failing its whole shared batch.
pub type RowResult<T> = Result<T, RowFault>;

fn require_pos(name: &str, v: f64) -> Result<(), String> {
    if v.is_finite() && v > 0.0 {
        Ok(())
    } else {
        Err(format!("{name} = {v} (must be finite and > 0)"))
    }
}

fn require_finite(name: &str, v: f64) -> Result<(), String> {
    if v.is_finite() {
        Ok(())
    } else {
        Err(format!("{name} = {v} (must be finite)"))
    }
}

fn input_fault(op: &str, checks: impl IntoIterator<Item = Result<(), String>>) -> Option<RowFault> {
    for c in checks {
        if let Err(why) = c {
            return Some(RowFault { reason: format!("degenerate {op} input: {why}") });
        }
    }
    None
}

/// Per-row output scan: any NaN/Inf scalar quarantines the row (the
/// `big_time` "never crossed" sentinel is finite and passes).
fn output_fault(op: &str, fields: &[(&str, f64)]) -> Option<RowFault> {
    for (name, v) in fields {
        if !v.is_finite() {
            return Some(RowFault { reason: format!("non-finite {op} output: {name} = {v}") });
        }
    }
    None
}

/// Collapse per-row results into the legacy all-or-nothing form: the
/// first faulted row fails the call with its index and reason.
fn collect_rows<T>(op: &str, rows: Vec<RowResult<T>>) -> crate::Result<Vec<T>> {
    rows.into_iter()
        .enumerate()
        .map(|(i, r)| r.map_err(|f| anyhow::anyhow!("{op} point {i}: {}", f.reason)))
        .collect()
}

/// Resolve a named output tensor from an execute() tuple, validating
/// the tuple length against the manifest — output positions follow the
/// manifest's `outputs` list by name, never hard-coded indices.
fn out_col<'a>(
    op: &str,
    meta: &ArtifactMeta,
    out: &'a [Tensor],
    name: &str,
) -> crate::Result<&'a Tensor> {
    anyhow::ensure!(
        out.len() == meta.outputs.len(),
        "{op}: backend returned {} outputs, manifest declares {} ({:?})",
        out.len(),
        meta.outputs.len(),
        meta.outputs
    );
    let i = meta
        .outputs
        .iter()
        .position(|o| o == name)
        .ok_or_else(|| anyhow::anyhow!("{op}: output '{name}' not in manifest {:?}", meta.outputs))?;
    Ok(&out[i])
}

/// One write-path design point.
#[derive(Debug, Clone)]
pub struct WritePoint {
    pub write_card: DeviceCard,
    pub write_wl: f64,
    pub drv_p: (DeviceCard, f64),
    pub drv_n: (DeviceCard, f64),
    pub c_sn: f64,
    pub c_wbl: f64,
    pub c_wwl_sn: f64,
    pub g_wbl_leak: f64,
    pub vdd: f64,
    /// WWL high level (vdd, or vdd + boost with WWLLS).
    pub v_wwl: f64,
    /// true: write '1' (dinb low); false: write '0'.
    pub one: bool,
    /// initial SN level (previous stored value).
    pub sn0: f64,
}

/// Write-path result.
#[derive(Debug, Clone, Copy)]
pub struct WriteResult {
    /// Stored level after the WWL fall (includes coupling droop).
    pub sn_final: f64,
    /// Write completion time (s).
    pub t_wr: f64,
    pub sn_peak: f64,
}

/// Run the write artifact over design points (padded to batch),
/// failing on the first degenerate/poisoned row — see
/// [`write_rows`] for the fault-isolating per-row form.
pub fn write_op(rt: &dyn ExecBackend, pts: &[WritePoint], window_s: f64) -> crate::Result<Vec<WriteResult>> {
    collect_rows("write", write_rows(rt, pts, window_s)?)
}

/// Run the write artifact over design points (padded to batch) with
/// per-row fault isolation: degenerate inputs and non-finite outputs
/// quarantine their own row only.
pub fn write_rows(
    rt: &dyn ExecBackend,
    pts: &[WritePoint],
    window_s: f64,
) -> crate::Result<Vec<RowResult<WriteResult>>> {
    let meta = rt.manifest().get("write")?.clone();
    let (b, nf, ns, np, steps) = (meta.batch, meta.nf(), meta.ns(), meta.npar(), meta.steps);
    anyhow::ensure!(
        pts.len() <= b,
        "write: batch overflow: {} points > artifact batch cap {b}",
        pts.len()
    );
    let faults: Vec<Option<RowFault>> = pts
        .iter()
        .map(|pt| {
            input_fault(
                "write",
                [
                    require_pos("c_sn", pt.c_sn),
                    require_pos("c_wbl", pt.c_wbl),
                    require_finite("c_wwl_sn", pt.c_wwl_sn),
                    require_finite("g_wbl_leak", pt.g_wbl_leak),
                    require_finite("vdd", pt.vdd),
                    require_finite("v_wwl", pt.v_wwl),
                    require_finite("sn0", pt.sn0),
                ],
            )
        })
        .collect();

    let mut params = Tensor::zeros(vec![b as i64, np as i64]);
    let mut cinv = Tensor::zeros(vec![b as i64, nf as i64]);
    let mut amp = Tensor::zeros(vec![b as i64, ns as i64]);
    let mut v0 = Tensor::zeros(vec![b as i64, nf as i64]);

    let set_card = |t: &mut Tensor, row: usize, base: usize, card: &DeviceCard, wl: f64| {
        for (k, v) in card.to_row(wl).iter().enumerate() {
            t.set2(row, base + k, *v);
        }
    };
    let p_mwr = meta.pcol("mwr.kp")?;
    let p_drvp = meta.pcol("mdrvp.kp")?;
    let p_drvn = meta.pcol("mdrvn.kp")?;
    let p_cc = meta.pcol("cwwl_sn.c")?;
    let p_gl = meta.pcol("gwbl.g")?;
    let (s_wwl, s_dinb, s_vdd) = (meta.stim("wwl")?, meta.stim("dinb")?, meta.stim("vdd")?);
    let (n_sn, n_wbl) = (meta.free("sn")?, meta.free("wbl")?);

    for (i, pt) in pts.iter().enumerate() {
        if faults[i].is_some() {
            continue; // degenerate row rides along as padding
        }
        set_card(&mut params, i, p_mwr, &pt.write_card, pt.write_wl);
        set_card(&mut params, i, p_drvp, &pt.drv_p.0, pt.drv_p.1);
        set_card(&mut params, i, p_drvn, &pt.drv_n.0, pt.drv_n.1);
        params.set2(i, p_cc, pt.c_wwl_sn as f32);
        params.set2(i, p_gl, pt.g_wbl_leak as f32);
        cinv.set2(i, n_sn, (1.0 / pt.c_sn) as f32);
        cinv.set2(i, n_wbl, (1.0 / pt.c_wbl) as f32);
        amp.set2(i, s_wwl, pt.v_wwl as f32);
        amp.set2(i, s_dinb, if pt.one { 0.0 } else { pt.vdd as f32 });
        amp.set2(i, s_vdd, pt.vdd as f32);
        v0.set2(i, n_sn, pt.sn0 as f32);
    }
    // pad rows (and quarantined rows) keep zero params -> pinned; harmless
    for i in 0..b {
        if i >= pts.len() || faults[i].is_some() {
            cinv.set2(i, n_sn, 1e15);
            cinv.set2(i, n_wbl, 1e14);
        }
    }

    // schedule: wwl rises at 5 % of the window, falls at 75 %
    let dt_step = window_s / (steps as f64 * meta.k_substeps as f64);
    let dt = st::uniform_dt(steps, dt_step);
    let times = st::times_from_dt(&dt, meta.k_substeps);
    let mut wave = st::zeros(steps, ns);
    let mut dwave = st::zeros(steps, ns);
    st::pulse(&mut wave, &mut dwave, &times, s_wwl, 0.05 * window_s, 0.75 * window_s, 0.05 * window_s);
    st::constant(&mut wave, s_vdd, 1.0);
    st::constant(&mut wave, s_dinb, 1.0); // dinb amplitude already 0 for '1'

    let out = rt.execute(
        "write",
        &[
            v0,
            amp,
            params,
            cinv,
            Tensor::new(vec![steps as i64, ns as i64], st::flatten(&wave)),
            Tensor::new(vec![steps as i64, ns as i64], st::flatten(&dwave)),
            Tensor::new(vec![steps as i64], dt.iter().map(|&d| d as f32).collect()),
        ],
    )?;
    let sn_final = out_col("write", &meta, &out, "sn_final")?;
    let t_wr = out_col("write", &meta, &out, "t_wr")?;
    let sn_peak = out_col("write", &meta, &out, "sn_peak")?;
    Ok((0..pts.len())
        .map(|i| {
            if let Some(f) = &faults[i] {
                return Err(f.clone());
            }
            let r = WriteResult {
                sn_final: sn_final.data[i] as f64,
                t_wr: t_wr.data[i] as f64,
                sn_peak: sn_peak.data[i] as f64,
            };
            match output_fault(
                "write",
                &[("sn_final", r.sn_final), ("t_wr", r.t_wr), ("sn_peak", r.sn_peak)],
            ) {
                Some(f) => Err(f),
                None => Ok(r),
            }
        })
        .collect())
}

/// One read-path design point.
#[derive(Debug, Clone)]
pub struct ReadPoint {
    pub read_card: DeviceCard,
    pub read_wl: f64,
    /// Stored SN level at read start.
    pub sn0: f64,
    /// Unselected-cell SN level (bitline leakage worst case).
    pub sn_unsel: f64,
    pub rows: usize,
    pub c_sn: f64,
    pub c_rbl: f64,
    pub c_rwl_sn: f64,
    pub g_rbl_leak: f64,
    pub vdd: f64,
    /// true = NP flavor: predischarged RBL, RWL pulses 0->vdd;
    /// false = NN/OS flavor: precharged RBL, RWL falls vdd->0.
    pub pull_up: bool,
}

/// Read-path result.
#[derive(Debug, Clone, Copy)]
pub struct ReadResult {
    /// RBL crossing vdd/2 upward (s), or BIG if never.
    pub t_rise: f64,
    /// RBL crossing vdd/2 downward.
    pub t_fall: f64,
    pub rbl_final: f64,
    pub sn_final: f64,
}

/// Run the read artifact over design points, failing on the first
/// degenerate/poisoned row — see [`read_rows`] for the fault-isolating
/// per-row form.
pub fn read_op(rt: &dyn ExecBackend, pts: &[ReadPoint], window_s: f64) -> crate::Result<Vec<ReadResult>> {
    collect_rows("read", read_rows(rt, pts, window_s)?)
}

/// Run the read artifact over design points (padded to batch) with
/// per-row fault isolation.
pub fn read_rows(
    rt: &dyn ExecBackend,
    pts: &[ReadPoint],
    window_s: f64,
) -> crate::Result<Vec<RowResult<ReadResult>>> {
    let meta = rt.manifest().get("read")?.clone();
    let (b, nf, ns, np, steps) = (meta.batch, meta.nf(), meta.ns(), meta.npar(), meta.steps);
    anyhow::ensure!(
        pts.len() <= b,
        "read: batch overflow: {} points > artifact batch cap {b}",
        pts.len()
    );
    let faults: Vec<Option<RowFault>> = pts
        .iter()
        .map(|pt| {
            input_fault(
                "read",
                [
                    require_pos("c_sn", pt.c_sn),
                    require_pos("c_rbl", pt.c_rbl),
                    require_finite("c_rwl_sn", pt.c_rwl_sn),
                    require_finite("g_rbl_leak", pt.g_rbl_leak),
                    require_finite("vdd", pt.vdd),
                    require_finite("sn0", pt.sn0),
                    require_finite("sn_unsel", pt.sn_unsel),
                ],
            )
        })
        .collect();

    let mut params = Tensor::zeros(vec![b as i64, np as i64]);
    let mut cinv = Tensor::zeros(vec![b as i64, nf as i64]);
    let mut amp = Tensor::zeros(vec![b as i64, ns as i64]);
    let mut v0 = Tensor::zeros(vec![b as i64, nf as i64]);

    let p_mrd = meta.pcol("mrd.kp")?;
    let p_leak = meta.pcol("mrbl_leak.kp")?;
    let p_cc = meta.pcol("crwl_sn.c")?;
    let p_gl = meta.pcol("grbl.g")?;
    let (s_rwl, s_idle, s_snu) = (meta.stim("rwl")?, meta.stim("rwl_idle")?, meta.stim("snu")?);
    let (n_sn, n_rbl) = (meta.free("sn")?, meta.free("rbl")?);

    // all points in one execution must share the waveform; split by
    // flavor is the caller's job (ensure homogeneous pull_up)
    let pull_up = pts.first().map(|p| p.pull_up).unwrap_or(true);
    anyhow::ensure!(
        pts.iter().all(|p| p.pull_up == pull_up),
        "mixed read flavors in one batch"
    );

    let set_card = |t: &mut Tensor, row: usize, base: usize, card: &DeviceCard, wl: f64| {
        for (k, v) in card.to_row(wl).iter().enumerate() {
            t.set2(row, base + k, *v);
        }
    };
    for (i, pt) in pts.iter().enumerate() {
        if faults[i].is_some() {
            continue; // degenerate row rides along as padding
        }
        set_card(&mut params, i, p_mrd, &pt.read_card, pt.read_wl);
        set_card(&mut params, i, p_leak, &pt.read_card, pt.read_wl * (pt.rows.saturating_sub(1)) as f64);
        params.set2(i, p_cc, pt.c_rwl_sn as f32);
        params.set2(i, p_gl, pt.g_rbl_leak as f32);
        cinv.set2(i, n_sn, (1.0 / pt.c_sn) as f32);
        cinv.set2(i, n_rbl, (1.0 / pt.c_rbl) as f32);
        v0.set2(i, n_sn, pt.sn0 as f32);
        v0.set2(i, n_rbl, if pull_up { 0.0 } else { pt.vdd as f32 });
        amp.set2(i, s_rwl, pt.vdd as f32);
        amp.set2(i, s_idle, if pull_up { 0.0 } else { pt.vdd as f32 });
        amp.set2(i, s_snu, pt.sn_unsel as f32);
    }
    for i in 0..b {
        if i >= pts.len() || faults[i].is_some() {
            cinv.set2(i, n_sn, 1e15);
            cinv.set2(i, n_rbl, 1e14);
        }
    }

    let dt_step = window_s / (steps as f64 * meta.k_substeps as f64);
    let dt = st::uniform_dt(steps, dt_step);
    let times = st::times_from_dt(&dt, meta.k_substeps);
    let mut wave = st::zeros(steps, ns);
    let mut dwave = st::zeros(steps, ns);
    if pull_up {
        st::pulse(&mut wave, &mut dwave, &times, s_rwl, 0.05 * window_s, 10.0 * window_s, 0.03 * window_s);
    } else {
        st::fall(&mut wave, &mut dwave, &times, s_rwl, 0.05 * window_s, 0.03 * window_s);
        st::constant(&mut wave, s_idle, 1.0);
    }
    st::constant(&mut wave, s_snu, 1.0);

    let out = rt.execute(
        "read",
        &[
            v0,
            amp,
            params,
            cinv,
            Tensor::new(vec![steps as i64, ns as i64], st::flatten(&wave)),
            Tensor::new(vec![steps as i64, ns as i64], st::flatten(&dwave)),
            Tensor::new(vec![steps as i64], dt.iter().map(|&d| d as f32).collect()),
        ],
    )?;
    let t_rise = out_col("read", &meta, &out, "t_rise")?;
    let t_fall = out_col("read", &meta, &out, "t_fall")?;
    let rbl_final = out_col("read", &meta, &out, "rbl_final")?;
    let sn_final = out_col("read", &meta, &out, "sn_final")?;
    Ok((0..pts.len())
        .map(|i| {
            if let Some(f) = &faults[i] {
                return Err(f.clone());
            }
            let r = ReadResult {
                t_rise: t_rise.data[i] as f64,
                t_fall: t_fall.data[i] as f64,
                rbl_final: rbl_final.data[i] as f64,
                sn_final: sn_final.data[i] as f64,
            };
            match output_fault(
                "read",
                &[
                    ("t_rise", r.t_rise),
                    ("t_fall", r.t_fall),
                    ("rbl_final", r.rbl_final),
                    ("sn_final", r.sn_final),
                ],
            ) {
                Some(f) => Err(f),
                None => Ok(r),
            }
        })
        .collect())
}

/// One retention design point.
#[derive(Debug, Clone)]
pub struct RetentionPoint {
    pub write_card: DeviceCard,
    pub write_wl: f64,
    pub c_sn: f64,
    /// Read-transistor gate-leak conductance (S).
    pub g_gate_leak: f64,
    /// Extra disturb current (A, discharging when negative).
    pub i_disturb: f64,
    /// Initial stored level.
    pub v0: f64,
    /// Absolute hold threshold (0 -> relative 0.5*v0).
    pub vth: f64,
}

/// Retention result + downsampled decay waveform.
#[derive(Debug, Clone)]
pub struct RetentionResult {
    pub t_retain: f64,
    pub sn_final: f64,
}

/// Run the retention artifact over design points, failing on the first
/// degenerate/poisoned row — see [`retention_rows`] for the
/// fault-isolating per-row form.
pub fn retention(rt: &dyn ExecBackend, pts: &[RetentionPoint]) -> crate::Result<Vec<RetentionResult>> {
    collect_rows("retention", retention_rows(rt, pts)?)
}

/// Run the retention artifact over design points (padded to batch)
/// with per-row fault isolation.
pub fn retention_rows(
    rt: &dyn ExecBackend,
    pts: &[RetentionPoint],
) -> crate::Result<Vec<RowResult<RetentionResult>>> {
    let meta = rt.manifest().get("retention")?.clone();
    let (b, nf, ns, np, steps) = (meta.batch, meta.nf(), meta.ns(), meta.npar(), meta.steps);
    anyhow::ensure!(
        pts.len() <= b,
        "retention: batch overflow: {} points > artifact batch cap {b}",
        pts.len()
    );
    let faults: Vec<Option<RowFault>> = pts
        .iter()
        .map(|pt| {
            input_fault(
                "retention",
                [
                    require_pos("c_sn", pt.c_sn),
                    require_finite("g_gate_leak", pt.g_gate_leak),
                    require_finite("i_disturb", pt.i_disturb),
                    require_finite("v0", pt.v0),
                    require_finite("vth", pt.vth),
                ],
            )
        })
        .collect();

    let mut params = Tensor::zeros(vec![b as i64, np as i64]);
    let mut cinv = Tensor::zeros(vec![b as i64, nf as i64]);
    let mut amp = Tensor::zeros(vec![b as i64, ns as i64]);
    let mut v0 = Tensor::zeros(vec![b as i64, nf as i64]);

    let p_mwr = meta.pcol("mwr.kp")?;
    let p_gl = meta.pcol("gleak.g")?;
    let p_id = meta.pcol("idist.i")?;
    let s_vth = meta.stim("vth")?;
    let n_sn = meta.free("sn")?;

    for (i, pt) in pts.iter().enumerate() {
        if faults[i].is_some() {
            continue; // degenerate row rides along as padding
        }
        for (k, v) in pt.write_card.to_row(pt.write_wl).iter().enumerate() {
            params.set2(i, p_mwr + k, *v);
        }
        params.set2(i, p_gl, pt.g_gate_leak as f32);
        params.set2(i, p_id, pt.i_disturb as f32);
        cinv.set2(i, n_sn, (1.0 / pt.c_sn) as f32);
        v0.set2(i, n_sn, pt.v0 as f32);
        amp.set2(i, s_vth, pt.vth as f32);
    }
    for i in 0..b {
        if i >= pts.len() || faults[i].is_some() {
            cinv.set2(i, n_sn, 1e15);
        }
    }

    // The retention log-time grid contract: sub-steps start at 1 ps
    // (dt0 = 1e-12 — NOT ~1 ns; the old comment drifted) and grow by
    // 1.082x per scan step, so with the artifact's 448 steps and
    // k_substeps = 4 the simulated span reaches ~1e5 s.  The dt tensor
    // is a runtime *input*: both backends (PJRT artifact and
    // runtime::native) integrate exactly this caller-authored grid and
    // interpolate t_retain on it — see the native module docs.
    let dt = st::log_dt(steps, 1e-12, 1.082);
    let wave = st::zeros(steps, ns);

    let out = rt.execute(
        "retention",
        &[
            v0,
            amp,
            params,
            cinv,
            Tensor::new(vec![steps as i64, ns as i64], st::flatten(&wave)),
            Tensor::new(vec![steps as i64, ns as i64], st::flatten(&wave)),
            Tensor::new(vec![steps as i64], dt.iter().map(|&d| d as f32).collect()),
        ],
    )?;
    let t_retain = out_col("retention", &meta, &out, "t_retain")?;
    let sn_final = out_col("retention", &meta, &out, "sn_final")?;
    Ok((0..pts.len())
        .map(|i| {
            if let Some(f) = &faults[i] {
                return Err(f.clone());
            }
            let r = RetentionResult {
                t_retain: t_retain.data[i] as f64,
                sn_final: sn_final.data[i] as f64,
            };
            match output_fault(
                "retention",
                &[("t_retain", r.t_retain), ("sn_final", r.sn_final)],
            ) {
                Some(f) => Err(f),
                None => Ok(r),
            }
        })
        .collect())
}

/// Id-Vg surfaces: cards (<=batch) x gate grid; returns (vg, ids rows).
pub fn idvg(
    rt: &dyn ExecBackend,
    cards: &[(DeviceCard, f64)],
    vg_lo: f64,
    vg_hi: f64,
    vds: f64,
) -> crate::Result<(Vec<f64>, Vec<Vec<f64>>)> {
    let (b, g) = rt.manifest().idvg.unwrap_or((128, 64));
    anyhow::ensure!(
        cards.len() <= b,
        "idvg: batch overflow: {} cards > artifact batch cap {b}",
        cards.len()
    );
    let mut card_t = Tensor::zeros(vec![b as i64, 6]);
    let mut vds_t = Tensor::zeros(vec![b as i64, 1]);
    for (i, (c, wl)) in cards.iter().enumerate() {
        for (k, v) in c.to_row(*wl).iter().enumerate() {
            card_t.set2(i, k, *v);
        }
        vds_t.set2(i, 0, (vds * c.sign()) as f32);
    }
    let vg: Vec<f64> = (0..g)
        .map(|i| vg_lo + (vg_hi - vg_lo) * i as f64 / (g - 1) as f64)
        .collect();
    let vg_t = Tensor::new(vec![g as i64], vg.iter().map(|&v| v as f32).collect());
    let out = rt.execute("idvg", &[card_t, vg_t, vds_t])?;
    let ids = &out[0];
    let rows = (0..cards.len())
        .map(|i| (0..g).map(|j| ids.at2(i, j) as f64).collect())
        .collect();
    Ok((vg, rows))
}
