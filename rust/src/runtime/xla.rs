//! API-compatible stand-in for the `xla` crate (compiled only when the
//! `pjrt` feature is off).
//!
//! The offline registry guarantees only the `anyhow` closure; the
//! native XLA/PJRT closure is an optional extra.  This stub mirrors the
//! exact surface [`super`] uses — `PjRtClient`, `HloModuleProto`,
//! `XlaComputation`, `PjRtLoadedExecutable`, `Literal` — so the crate
//! always compiles, and every entry point fails at *runtime* with a
//! clear message instead.  `Runtime::load` therefore errors out cleanly
//! on the first call and the analytical / geometry / DSE paths (which
//! never touch PJRT) keep working.

#![allow(dead_code)]

pub type Error = String;

const UNLINKED: &str =
    "PJRT backend not linked: build with `--features pjrt` (requires the vendored `xla` crate)";

fn err<T>() -> Result<T, Error> {
    Err(UNLINKED.to_string())
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        err()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        err()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        err()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        err()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        err()
    }
}

#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        err()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        err()
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        err()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        err()
    }
}

pub struct ArrayShape(Vec<i64>);

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.0
    }
}
