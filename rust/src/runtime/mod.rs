//! PJRT runtime: load the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and execute them from the rust hot path.
//!
//! Python never runs at request time: `make artifacts` emits HLO *text*
//! (see aot.py — serialized protos from jax>=0.5 are rejected by
//! xla_extension 0.5.1) plus `manifest.json`; this module parses the
//! manifest ([`Manifest`]), compiles each artifact once on the PJRT CPU
//! client ([`Engine`]), and exposes typed batched entry points
//! ([`engines`]) that the characterizer and DSE coordinator call.

pub mod engines;
pub mod stimulus;

// With `--features pjrt` the `xla::` paths below resolve to the real
// vendored crate; without it this API-compatible stub compiles in and
// Runtime::load fails cleanly at runtime (see src/runtime/xla.rs).
#[cfg(not(feature = "pjrt"))]
mod xla;

// The feature is a wiring point, not a working backend yet: fail with
// a clear diagnostic instead of E0433 path errors until the vendored
// `xla` dependency is added to Cargo.toml (remove this then).
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires the vendored `xla` crate: add it as a dependency in rust/Cargo.toml and delete this compile_error"
);

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Parsed manifest entry for one artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub batch: usize,
    pub steps: usize,
    pub k_substeps: usize,
    pub trace_ds: usize,
    pub big_time: f64,
    pub integrator: String,
    pub free_nodes: Vec<String>,
    pub stim_nodes: Vec<String>,
    pub params: Vec<String>,
    pub outputs: Vec<String>,
}

impl ArtifactMeta {
    pub fn nf(&self) -> usize {
        self.free_nodes.len()
    }
    pub fn ns(&self) -> usize {
        self.stim_nodes.len()
    }
    pub fn npar(&self) -> usize {
        self.params.len()
    }
    pub fn pcol(&self, name: &str) -> crate::Result<usize> {
        self.params
            .iter()
            .position(|p| p == name)
            .ok_or_else(|| anyhow::anyhow!("manifest param '{name}' missing"))
    }
    pub fn stim(&self, name: &str) -> crate::Result<usize> {
        self.stim_nodes
            .iter()
            .position(|p| p == name)
            .ok_or_else(|| anyhow::anyhow!("manifest stim '{name}' missing"))
    }
    pub fn free(&self, name: &str) -> crate::Result<usize> {
        self.free_nodes
            .iter()
            .position(|p| p == name)
            .ok_or_else(|| anyhow::anyhow!("manifest node '{name}' missing"))
    }
}

/// The whole artifacts/manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ArtifactMeta>,
    /// idvg-specific: (batch, grid)
    pub idvg: Option<(usize, usize)>,
}

impl Manifest {
    pub fn load(dir: &Path) -> crate::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("reading manifest in {dir:?}: {e} (run `make artifacts`)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let obj = j.as_obj().ok_or_else(|| anyhow::anyhow!("manifest not an object"))?;
        let mut entries = BTreeMap::new();
        let mut idvg = None;
        for (name, v) in obj {
            let gets = |k: &str| -> crate::Result<String> {
                v.get(k)
                    .and_then(|x| x.as_str())
                    .map(|s| s.to_string())
                    .ok_or_else(|| anyhow::anyhow!("manifest {name}.{k} missing"))
            };
            let getn = |k: &str| v.get(k).and_then(|x| x.as_usize());
            if name == "idvg" {
                idvg = Some((
                    getn("batch").unwrap_or(128),
                    getn("grid").unwrap_or(64),
                ));
                continue;
            }
            entries.insert(
                name.clone(),
                ArtifactMeta {
                    file: gets("file")?,
                    batch: getn("batch").unwrap_or(256),
                    steps: getn("steps").unwrap_or(384),
                    k_substeps: getn("k_substeps").unwrap_or(4),
                    trace_ds: getn("trace_ds").unwrap_or(4),
                    big_time: v.get("big_time").and_then(|x| x.as_f64()).unwrap_or(1e12),
                    integrator: gets("integrator").unwrap_or_else(|_| "heun".into()),
                    free_nodes: v.get("free_nodes").and_then(|x| x.str_list()).unwrap_or_default(),
                    stim_nodes: v.get("stim_nodes").and_then(|x| x.str_list()).unwrap_or_default(),
                    params: v.get("params").and_then(|x| x.str_list()).unwrap_or_default(),
                    outputs: v.get("outputs").and_then(|x| x.str_list()).unwrap_or_default(),
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries, idvg })
    }

    pub fn get(&self, name: &str) -> crate::Result<&ArtifactMeta> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))
    }
}

/// An f32 tensor with shape, the runtime's argument/result currency.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub dims: Vec<i64>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<i64>, data: Vec<f32>) -> Tensor {
        debug_assert_eq!(dims.iter().product::<i64>() as usize, data.len());
        Tensor { dims, data }
    }

    pub fn zeros(dims: Vec<i64>) -> Tensor {
        let n = dims.iter().product::<i64>() as usize;
        Tensor { dims, data: vec![0.0; n] }
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.dims[1] as usize + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.dims[1] as usize + j] = v;
    }
}

/// One compiled artifact on the shared PJRT CPU client.
pub struct Engine {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    /// PJRT executions issued since load — the batching KPI: a
    /// batch-first sweep pays O(points/batch) of these, not O(points).
    calls: AtomicU64,
}

/// The runtime: PJRT client + compiled engines.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    engines: BTreeMap<String, Engine>,
}

impl Runtime {
    /// Load and compile every artifact in the manifest directory.
    pub fn load(dir: &Path) -> crate::Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt: {e:?}"))?;
        let mut engines = BTreeMap::new();
        let mut names: Vec<(String, String)> = manifest
            .entries
            .iter()
            .map(|(k, v)| (k.clone(), v.file.clone()))
            .collect();
        names.push(("idvg".into(), "idvg.hlo.txt".into()));
        for (name, file) in names {
            let path = dir.join(&file);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| anyhow::anyhow!("loading {file}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {file}: {e:?}"))?;
            engines.insert(name.clone(), Engine { exe, name, calls: AtomicU64::new(0) });
        }
        Ok(Runtime { client, manifest, engines })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// PJRT executions issued against artifact `name` since load
    /// (0 for unknown names).
    pub fn call_count(&self, name: &str) -> u64 {
        self.engines
            .get(name)
            .map(|e| e.calls.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Per-artifact execution counts — the DSE batching KPI recorded
    /// by the benches (`BENCH_perf.json`).
    pub fn call_counts(&self) -> BTreeMap<String, u64> {
        self.engines
            .iter()
            .map(|(k, e)| (k.clone(), e.calls.load(Ordering::Relaxed)))
            .collect()
    }

    /// Execute an artifact with the given inputs; returns the tuple of
    /// output tensors.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> crate::Result<Vec<Tensor>> {
        let eng = self
            .engines
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("engine '{name}' not loaded"))?;
        eng.calls.fetch_add(1, Ordering::Relaxed);
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let l = xla::Literal::vec1(&t.data);
                if t.dims.len() == 1 {
                    Ok(l)
                } else {
                    l.reshape(&t.dims).map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
                }
            })
            .collect::<crate::Result<_>>()?;
        let out = eng
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let result = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("sync {name}: {e:?}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("tuple {name}: {e:?}"))?;
        parts
            .into_iter()
            .map(|l| {
                let shape = l.array_shape().map_err(|e| anyhow::anyhow!("{e:?}"))?;
                let dims: Vec<i64> = shape.dims().to_vec();
                let data = l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
                Ok(Tensor { dims, data })
            })
            .collect()
    }
}

/// Thread-shareable wrapper: the xla PJRT client is not Send/Sync
/// (internal Rc), but the CPU client is safe to drive from one thread
/// at a time — SharedRuntime serializes access behind a mutex so tests
/// and the coordinator can share one compiled runtime.
pub struct SharedRuntime(std::sync::Mutex<Runtime>);

// SAFETY: all access is serialized by the mutex; the CPU PJRT client
// performs no thread-local magic between calls.
unsafe impl Send for SharedRuntime {}
unsafe impl Sync for SharedRuntime {}

impl SharedRuntime {
    pub fn load(dir: &Path) -> crate::Result<SharedRuntime> {
        Ok(SharedRuntime(std::sync::Mutex::new(Runtime::load(dir)?)))
    }

    pub fn with<R>(&self, f: impl FnOnce(&Runtime) -> R) -> R {
        let guard = self.0.lock().unwrap_or_else(|p| p.into_inner());
        f(&guard)
    }

    /// See [`Runtime::call_count`].
    pub fn call_count(&self, name: &str) -> u64 {
        self.with(|r| r.call_count(name))
    }

    /// See [`Runtime::call_counts`].
    pub fn call_counts(&self) -> BTreeMap<String, u64> {
        self.with(|r| r.call_counts())
    }

    /// Batch capacity of artifact `name` from the manifest.
    pub fn batch_cap(&self, name: &str) -> crate::Result<usize> {
        self.with(|r| r.manifest.get(name).map(|m| m.batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_parses() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping manifest_parses: no artifacts/ (run `make artifacts`)");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        for k in ["write", "read", "retention"] {
            let e = m.get(k).unwrap();
            assert!(e.batch >= 128);
            assert!(!e.params.is_empty());
            assert_eq!(e.inputs_ok(), true);
        }
        assert!(m.idvg.is_some());
        assert_eq!(m.get("retention").unwrap().integrator, "expdecay");
    }

    impl ArtifactMeta {
        fn inputs_ok(&self) -> bool {
            self.nf() > 0 && self.ns() > 0 && self.npar() > 0
        }
    }

    #[test]
    fn tensor_indexing() {
        let mut t = Tensor::zeros(vec![2, 3]);
        t.set2(1, 2, 5.0);
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.at2(0, 0), 0.0);
    }
}
