//! Execution runtimes for the transient hot path.
//!
//! The characterizer and DSE coordinator speak to an [`ExecBackend`]: a
//! named batched executor (`execute(name, &[Tensor]) -> Vec<Tensor>`)
//! whose input/output layout is described by a [`Manifest`].  Two
//! implementations exist:
//!
//! * [`native::NativeBackend`] — the in-process EKV solver
//!   ([`crate::sim`]) batched over a synthesized manifest with the same
//!   param/stim/free-node column layout the XLA artifacts use, so the
//!   typed entry points ([`engines`]) work unchanged.  Always
//!   available; genuinely `Send + Sync` (no serializing lock).
//! * [`Runtime`] — the PJRT executor for the AOT-compiled HLO artifacts
//!   produced by `python/compile/aot.py`.  Python never runs at request
//!   time: `make artifacts` emits HLO *text* (see aot.py — serialized
//!   protos from jax>=0.5 are rejected by xla_extension 0.5.1) plus
//!   `manifest.json`; this module parses the manifest ([`Manifest`])
//!   and compiles each artifact once on the PJRT CPU client
//!   ([`Engine`]).  Optional acceleration: it needs `artifacts/` on
//!   disk and the vendored `xla` crate linked (`--features pjrt`).
//!
//! [`SharedRuntime`] is the thread-shareable selection of the two —
//! see [`SharedRuntime::native`] / [`SharedRuntime::load`] /
//! [`SharedRuntime::auto`] and the CLI's `--backend` flag
//! ([`crate::cli::parse_backend`]).

pub mod engines;
pub mod fault;
pub mod native;
pub mod stimulus;

pub use native::NativeBackend;

// With `--features pjrt` the `xla::` paths below resolve to the real
// vendored crate; without it this API-compatible stub compiles in and
// Runtime::load fails cleanly at runtime (see src/runtime/xla.rs).
#[cfg(not(feature = "pjrt"))]
mod xla;

// The feature is a wiring point, not a working backend yet: fail with
// a clear diagnostic instead of E0433 path errors until the vendored
// `xla` dependency is added to Cargo.toml (remove this then).
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires the vendored `xla` crate: add it as a dependency in rust/Cargo.toml and delete this compile_error"
);

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Parsed manifest entry for one artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub batch: usize,
    pub steps: usize,
    pub k_substeps: usize,
    pub trace_ds: usize,
    pub big_time: f64,
    pub integrator: String,
    pub free_nodes: Vec<String>,
    pub stim_nodes: Vec<String>,
    pub params: Vec<String>,
    pub outputs: Vec<String>,
}

impl ArtifactMeta {
    pub fn nf(&self) -> usize {
        self.free_nodes.len()
    }
    pub fn ns(&self) -> usize {
        self.stim_nodes.len()
    }
    pub fn npar(&self) -> usize {
        self.params.len()
    }
    pub fn pcol(&self, name: &str) -> crate::Result<usize> {
        self.params
            .iter()
            .position(|p| p == name)
            .ok_or_else(|| anyhow::anyhow!("manifest param '{name}' missing"))
    }
    pub fn stim(&self, name: &str) -> crate::Result<usize> {
        self.stim_nodes
            .iter()
            .position(|p| p == name)
            .ok_or_else(|| anyhow::anyhow!("manifest stim '{name}' missing"))
    }
    pub fn free(&self, name: &str) -> crate::Result<usize> {
        self.free_nodes
            .iter()
            .position(|p| p == name)
            .ok_or_else(|| anyhow::anyhow!("manifest node '{name}' missing"))
    }
}

/// The whole artifacts/manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ArtifactMeta>,
    /// idvg-specific: (batch, grid)
    pub idvg: Option<(usize, usize)>,
}

impl Manifest {
    pub fn load(dir: &Path) -> crate::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("reading manifest in {dir:?}: {e} (run `make artifacts`)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let obj = j.as_obj().ok_or_else(|| anyhow::anyhow!("manifest not an object"))?;
        let mut entries = BTreeMap::new();
        let mut idvg = None;
        for (name, v) in obj {
            let gets = |k: &str| -> crate::Result<String> {
                v.get(k)
                    .and_then(|x| x.as_str())
                    .map(|s| s.to_string())
                    .ok_or_else(|| anyhow::anyhow!("manifest {name}.{k} missing"))
            };
            let getn = |k: &str| v.get(k).and_then(|x| x.as_usize());
            if name == "idvg" {
                idvg = Some((
                    getn("batch").unwrap_or(128),
                    getn("grid").unwrap_or(64),
                ));
                continue;
            }
            entries.insert(
                name.clone(),
                ArtifactMeta {
                    file: gets("file")?,
                    batch: getn("batch").unwrap_or(256),
                    steps: getn("steps").unwrap_or(384),
                    k_substeps: getn("k_substeps").unwrap_or(4),
                    trace_ds: getn("trace_ds").unwrap_or(4),
                    big_time: v.get("big_time").and_then(|x| x.as_f64()).unwrap_or(1e12),
                    integrator: gets("integrator").unwrap_or_else(|_| "heun".into()),
                    free_nodes: v.get("free_nodes").and_then(|x| x.str_list()).unwrap_or_default(),
                    stim_nodes: v.get("stim_nodes").and_then(|x| x.str_list()).unwrap_or_default(),
                    params: v.get("params").and_then(|x| x.str_list()).unwrap_or_default(),
                    outputs: v.get("outputs").and_then(|x| x.str_list()).unwrap_or_default(),
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries, idvg })
    }

    pub fn get(&self, name: &str) -> crate::Result<&ArtifactMeta> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))
    }
}

/// An f32 tensor with shape, the runtime's argument/result currency.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub dims: Vec<i64>,
    pub data: Vec<f32>,
}

impl Tensor {
    /// Build a tensor, validating that the shape covers the buffer —
    /// the fallible twin of [`Tensor::new`] for callers assembling
    /// shapes from external data (manifest entries, parsed files).
    pub fn checked(dims: Vec<i64>, data: Vec<f32>) -> crate::Result<Tensor> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(
            dims.iter().all(|&d| d >= 0) && n as usize == data.len(),
            "tensor shape {dims:?} describes {n} elements but the buffer holds {}",
            data.len()
        );
        Ok(Tensor { dims, data })
    }

    /// Build a tensor; panics if the shape does not cover the buffer.
    /// (This used to be a `debug_assert`, so a bad reshape in a release
    /// build silently mis-indexed row-major order; see
    /// [`Tensor::checked`] for the error-returning variant.)
    pub fn new(dims: Vec<i64>, data: Vec<f32>) -> Tensor {
        match Tensor::checked(dims, data) {
            Ok(t) => t,
            Err(e) => panic!("{e}"),
        }
    }

    pub fn zeros(dims: Vec<i64>) -> Tensor {
        let n = dims.iter().product::<i64>() as usize;
        Tensor { dims, data: vec![0.0; n] }
    }

    /// Row-major index into a rank-2 view; bounds/rank are
    /// `debug_assert`ed (the hot loops stay branch-free in release).
    fn idx2(&self, i: usize, j: usize) -> usize {
        debug_assert!(
            self.dims.len() == 2,
            "at2/set2 on a rank-{} tensor {:?}",
            self.dims.len(),
            self.dims
        );
        debug_assert!(
            i < self.dims[0] as usize && j < self.dims[1] as usize,
            "index ({i}, {j}) out of bounds for shape {:?}",
            self.dims
        );
        i * self.dims[1] as usize + j
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[self.idx2(i, j)]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        let k = self.idx2(i, j);
        self.data[k] = v;
    }
}

/// A named batched executor: the interface the typed entry points
/// ([`engines`]) and everything above them (characterizer, DSE
/// coordinator, composition) are written against.
///
/// The contract, shared by both implementations:
///
/// * [`Self::manifest`] describes every artifact's batch size, step
///   count and param/stim/free-node *column layout*; callers resolve
///   columns by name through [`ArtifactMeta`], never by hard-coded
///   index.
/// * [`Self::execute`] runs artifact `name` over a full padded batch of
///   input tensors and returns its output tuple.
/// * [`Self::call_count`] / [`Self::call_counts`] count executions per
///   artifact since construction — the batching KPI: a batch-first
///   sweep pays `O(points / batch)` executions, not `O(points)`, and
///   the benches assert that against these real counters.
pub trait ExecBackend {
    /// The artifact layout table this backend executes against.
    fn manifest(&self) -> &Manifest;

    /// Execute artifact `name` with the given inputs; returns the tuple
    /// of output tensors.
    fn execute(&self, name: &str, inputs: &[Tensor]) -> crate::Result<Vec<Tensor>>;

    /// Executions issued against artifact `name` since construction
    /// (0 for unknown names).
    fn call_count(&self, name: &str) -> u64;

    /// Per-artifact execution counts — the DSE batching KPI recorded by
    /// the benches (`BENCH_perf.json`).
    fn call_counts(&self) -> BTreeMap<String, u64>;

    /// Human-readable execution platform (e.g. `cpu` for PJRT,
    /// `native-ekv` for the in-process solver).
    fn platform(&self) -> String;

    /// Batch capacity of artifact `name` from the manifest.
    fn batch_cap(&self, name: &str) -> crate::Result<usize> {
        self.manifest().get(name).map(|m| m.batch)
    }
}

/// One compiled artifact on the shared PJRT CPU client.
pub struct Engine {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    /// PJRT executions issued since load — the batching KPI: a
    /// batch-first sweep pays O(points/batch) of these, not O(points).
    calls: AtomicU64,
}

/// The runtime: PJRT client + compiled engines.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    engines: BTreeMap<String, Engine>,
}

impl Runtime {
    /// Load and compile every artifact in the manifest directory.
    pub fn load(dir: &Path) -> crate::Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let mut names: Vec<(String, String)> = manifest
            .entries
            .iter()
            .map(|(k, v)| (k.clone(), v.file.clone()))
            .collect();
        names.push(("idvg".into(), "idvg.hlo.txt".into()));
        // resolve paths up front: the xla loader takes &str, so a
        // non-UTF8 artifact path is a load error (it used to panic on
        // `to_str().unwrap()` mid-compile)
        let mut files: Vec<(String, String, String)> = Vec::with_capacity(names.len());
        for (name, file) in names {
            let path = dir.join(&file);
            let path_str = path
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("artifact path {path:?} is not valid UTF-8"))?
                .to_string();
            files.push((name, file, path_str));
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt: {e:?}"))?;
        let mut engines = BTreeMap::new();
        for (name, file, path_str) in files {
            let proto = xla::HloModuleProto::from_text_file(&path_str)
                .map_err(|e| anyhow::anyhow!("loading {file}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {file}: {e:?}"))?;
            engines.insert(name.clone(), Engine { exe, name, calls: AtomicU64::new(0) });
        }
        Ok(Runtime { client, manifest, engines })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// PJRT executions issued against artifact `name` since load
    /// (0 for unknown names).
    pub fn call_count(&self, name: &str) -> u64 {
        self.engines
            .get(name)
            .map(|e| e.calls.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Per-artifact execution counts — the DSE batching KPI recorded
    /// by the benches (`BENCH_perf.json`).
    pub fn call_counts(&self) -> BTreeMap<String, u64> {
        self.engines
            .iter()
            .map(|(k, e)| (k.clone(), e.calls.load(Ordering::Relaxed)))
            .collect()
    }

    /// Execute an artifact with the given inputs; returns the tuple of
    /// output tensors.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> crate::Result<Vec<Tensor>> {
        let eng = self
            .engines
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("engine '{name}' not loaded"))?;
        eng.calls.fetch_add(1, Ordering::Relaxed);
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let l = xla::Literal::vec1(&t.data);
                if t.dims.len() == 1 {
                    Ok(l)
                } else {
                    l.reshape(&t.dims).map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
                }
            })
            .collect::<crate::Result<_>>()?;
        let out = eng
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let result = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("sync {name}: {e:?}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("tuple {name}: {e:?}"))?;
        parts
            .into_iter()
            .map(|l| {
                let shape = l.array_shape().map_err(|e| anyhow::anyhow!("{e:?}"))?;
                let dims: Vec<i64> = shape.dims().to_vec();
                let data = l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
                Ok(Tensor { dims, data })
            })
            .collect()
    }
}

impl ExecBackend for Runtime {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }
    fn execute(&self, name: &str, inputs: &[Tensor]) -> crate::Result<Vec<Tensor>> {
        Runtime::execute(self, name, inputs)
    }
    fn call_count(&self, name: &str) -> u64 {
        Runtime::call_count(self, name)
    }
    fn call_counts(&self) -> BTreeMap<String, u64> {
        Runtime::call_counts(self)
    }
    fn platform(&self) -> String {
        Runtime::platform(self)
    }
}

/// The PJRT variant of [`SharedRuntime`]: the xla PJRT client is not
/// Send/Sync (internal Rc), but the CPU client is safe to drive from
/// one thread at a time — access is serialized behind a mutex.  The
/// manifest is kept outside the mutex (it is immutable after load) so
/// [`ExecBackend::manifest`] can hand out a plain reference.
pub struct PjrtShared {
    manifest: Manifest,
    inner: std::sync::Mutex<Runtime>,
}

// SAFETY: all access is serialized by the mutex; the CPU PJRT client
// performs no thread-local magic between calls.
unsafe impl Send for PjrtShared {}
unsafe impl Sync for PjrtShared {}

impl PjrtShared {
    fn new(rt: Runtime) -> PjrtShared {
        PjrtShared { manifest: rt.manifest.clone(), inner: std::sync::Mutex::new(rt) }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Runtime> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl ExecBackend for PjrtShared {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }
    fn execute(&self, name: &str, inputs: &[Tensor]) -> crate::Result<Vec<Tensor>> {
        self.lock().execute(name, inputs)
    }
    fn call_count(&self, name: &str) -> u64 {
        self.lock().call_count(name)
    }
    fn call_counts(&self) -> BTreeMap<String, u64> {
        self.lock().call_counts()
    }
    fn platform(&self) -> String {
        self.lock().platform()
    }
}

/// Graceful degradation: a primary backend (PJRT) backed by the
/// parity-pinned [`NativeBackend`].  The first `Err` from a primary
/// execute trips the breaker — that request and **all remaining work**
/// are served by the native fallback, with the downgrade logged once on
/// stderr.  [`SharedRuntime::auto`] wraps a successful PJRT load in
/// this, so a backend that dies mid-sweep degrades a run instead of
/// killing it.
///
/// Failover is only armed when the primary's manifest is
/// shape-compatible with the native one (same batch/steps/node/param
/// layout for every artifact the native solver implements); otherwise
/// primary errors propagate unchanged.
pub struct FailoverBackend {
    primary: Box<dyn ExecBackend + Send + Sync>,
    fallback: NativeBackend,
    armed: bool,
    tripped: std::sync::atomic::AtomicBool,
    failovers: AtomicU64,
}

impl FailoverBackend {
    pub fn new(primary: Box<dyn ExecBackend + Send + Sync>) -> FailoverBackend {
        let fallback = NativeBackend::new();
        let armed = Self::compatible(primary.manifest(), fallback.manifest());
        FailoverBackend {
            primary,
            fallback,
            armed,
            tripped: std::sync::atomic::AtomicBool::new(false),
            failovers: AtomicU64::new(0),
        }
    }

    /// Every artifact the native solver implements must agree on batch
    /// size and column layout, or a failed-over batch would be
    /// mis-shaped for the fallback.
    fn compatible(primary: &Manifest, native: &Manifest) -> bool {
        native.entries.iter().all(|(name, n)| match primary.entries.get(name) {
            Some(p) => {
                p.batch == n.batch
                    && p.steps == n.steps
                    && p.free_nodes == n.free_nodes
                    && p.stim_nodes == n.stim_nodes
                    && p.params == n.params
            }
            None => false,
        })
    }

    /// Has the breaker tripped (all work now served natively)?
    pub fn tripped(&self) -> bool {
        self.tripped.load(Ordering::Relaxed)
    }

    /// Number of failover transitions (0 or 1).
    pub fn failovers(&self) -> u64 {
        self.failovers.load(Ordering::Relaxed)
    }
}

impl ExecBackend for FailoverBackend {
    fn manifest(&self) -> &Manifest {
        if self.tripped() {
            self.fallback.manifest()
        } else {
            self.primary.manifest()
        }
    }
    fn execute(&self, name: &str, inputs: &[Tensor]) -> crate::Result<Vec<Tensor>> {
        if self.tripped() {
            return self.fallback.execute(name, inputs);
        }
        match self.primary.execute(name, inputs) {
            Ok(out) => Ok(out),
            Err(e) if self.armed => {
                if !self.tripped.swap(true, Ordering::SeqCst) {
                    self.failovers.fetch_add(1, Ordering::Relaxed);
                    eprintln!(
                        "warning: {} backend failed executing '{name}' ({e:#}); \
                         failing over remaining work to the native backend",
                        self.primary.platform()
                    );
                }
                self.fallback.execute(name, inputs)
            }
            Err(e) => Err(e),
        }
    }
    fn call_count(&self, name: &str) -> u64 {
        self.primary.call_count(name) + self.fallback.call_count(name)
    }
    fn call_counts(&self) -> BTreeMap<String, u64> {
        let mut counts = self.primary.call_counts();
        for (k, v) in self.fallback.call_counts() {
            *counts.entry(k).or_insert(0) += v;
        }
        counts
    }
    fn platform(&self) -> String {
        if self.tripped() {
            format!("{} (failed over from {})", self.fallback.platform(), self.primary.platform())
        } else {
            self.primary.platform()
        }
    }
}

/// One quarantined design point in a [`RunHealth`] report.
#[derive(Debug, Clone)]
pub struct QuarantinedPoint {
    /// Index of the design in the order it entered the sweep.
    pub index: usize,
    /// Human-readable design label (size/flavor).
    pub design: String,
    /// Characterization stage that rejected it (`write`/`read`/`retention`).
    pub stage: &'static str,
    /// Why the point was quarantined.
    pub reason: String,
}

/// Health report for one batched characterization run: what the
/// fault-isolation machinery did on the way to the results.  Threaded
/// through `characterize_all` / `evaluate_all_batched` and printed by
/// the `dse`/`compose` CLI.  All-zero on a clean run (and a clean run
/// pays **zero** extra executions — retry and bisection only engage on
/// executor errors).
#[derive(Debug, Clone, Default)]
pub struct RunHealth {
    /// Batch retry attempts (transient faults healed invisibly).
    pub retries: u64,
    /// Extra executor runs spent bisecting failing batches
    /// (≤ 2·ceil(log2 batch) per poisoned row).
    pub bisect_execs: u64,
    /// pjrt→native failover transitions.
    pub failovers: u64,
    /// Design points rejected with per-point reasons.
    pub quarantined: Vec<QuarantinedPoint>,
}

impl RunHealth {
    /// No faults fired and nothing was quarantined.
    pub fn is_clean(&self) -> bool {
        self.retries == 0
            && self.bisect_execs == 0
            && self.failovers == 0
            && self.quarantined.is_empty()
    }

    /// Fold another report into this one (multi-stage sweeps).
    pub fn merge(&mut self, other: RunHealth) {
        self.retries += other.retries;
        self.bisect_execs += other.bisect_execs;
        self.failovers += other.failovers;
        self.quarantined.extend(other.quarantined);
    }

    /// One-line summary for the CLI.
    pub fn summary(&self) -> String {
        if self.is_clean() {
            "clean (no retries, no bisection, no failovers, no quarantined points)".to_string()
        } else {
            format!(
                "{} retries, {} bisect executions, {} failovers, {} quarantined",
                self.retries,
                self.bisect_execs,
                self.failovers,
                self.quarantined.len()
            )
        }
    }
}

/// Thread-shareable execution backend handed to the coordinator, the
/// batched sweeps and the benches.
///
/// * [`SharedRuntime::Native`] wraps the in-process solver, which is
///   genuinely `Send + Sync` — [`SharedRuntime::with`] hands the
///   backend out with **no lock**, so coordinator executors and tests
///   sharing one runtime never serialize on a mutex (the old
///   whole-runtime `unsafe impl Send/Sync` now applies only to the
///   PJRT variant, where it is actually needed).
/// * [`SharedRuntime::Pjrt`] serializes the non-`Send` PJRT client
///   behind [`PjrtShared`]'s mutex, exactly as before.
/// * [`SharedRuntime::Failover`] is PJRT with a native circuit breaker
///   ([`FailoverBackend`]) — what [`SharedRuntime::auto`] now returns
///   when artifacts load.
/// * [`SharedRuntime::Fault`] wraps any of the above in deterministic
///   fault injection ([`fault::FaultBackend`]), enabled by the
///   `OPENGCRAM_FAULTS` environment variable or
///   [`SharedRuntime::with_faults`].
pub enum SharedRuntime {
    Native(NativeBackend),
    Pjrt(PjrtShared),
    Failover(FailoverBackend),
    Fault(fault::FaultBackend),
}

impl SharedRuntime {
    /// Load the PJRT backend from an artifact directory (fails cleanly
    /// when artifacts or the linked `xla` crate are absent — see
    /// [`SharedRuntime::auto`] for the fallback policy).
    pub fn load(dir: &Path) -> crate::Result<SharedRuntime> {
        Ok(SharedRuntime::Pjrt(PjrtShared::new(Runtime::load(dir)?)))
    }

    /// The native in-process backend (always available, no artifacts).
    pub fn native() -> SharedRuntime {
        SharedRuntime::Native(NativeBackend::new())
    }

    /// Wrap this runtime in deterministic fault injection: every
    /// execute passes through the plan first (see [`fault`]).
    pub fn with_faults(self, plan: fault::FaultPlan) -> SharedRuntime {
        let inner: Box<dyn ExecBackend + Send + Sync> = match self {
            SharedRuntime::Native(b) => Box::new(b),
            SharedRuntime::Pjrt(p) => Box::new(p),
            SharedRuntime::Failover(f) => Box::new(f),
            SharedRuntime::Fault(f) => Box::new(f),
        };
        SharedRuntime::Fault(fault::FaultBackend::new(inner, plan))
    }

    /// PJRT when `dir` holds loadable artifacts and the `xla` crate is
    /// linked; the native backend otherwise.  The `--backend auto`
    /// policy of the CLI, benches and examples.
    ///
    /// A missing artifact directory falls back silently (the normal
    /// clean-checkout case); artifacts that are *present but fail to
    /// load* are reported on stderr before falling back, so a broken
    /// `make artifacts` output cannot masquerade as a deliberate
    /// native run — pass `--backend pjrt` to make that case a hard
    /// error instead.
    ///
    /// A successful PJRT load is additionally armed with the native
    /// failover breaker ([`FailoverBackend`]): if PJRT later fails an
    /// execute, remaining work degrades to the native backend with a
    /// logged downgrade instead of killing the sweep.
    pub fn auto(dir: &Path) -> SharedRuntime {
        match SharedRuntime::load(dir) {
            Ok(SharedRuntime::Pjrt(p)) => {
                SharedRuntime::Failover(FailoverBackend::new(Box::new(p)))
            }
            Ok(rt) => rt,
            Err(e) => {
                if dir.join("manifest.json").exists() {
                    eprintln!(
                        "warning: artifacts in {dir:?} present but PJRT load failed ({e:#}); \
                         falling back to the native backend"
                    );
                }
                SharedRuntime::native()
            }
        }
    }

    /// Which backend this is: `"native"`, `"pjrt"` (possibly armed with
    /// failover), or `"fault"` (fault-injection wrapper).
    pub fn backend_name(&self) -> &'static str {
        match self {
            SharedRuntime::Native(_) => "native",
            SharedRuntime::Pjrt(_) => "pjrt",
            SharedRuntime::Failover(f) => {
                if f.tripped() {
                    "native"
                } else {
                    "pjrt"
                }
            }
            SharedRuntime::Fault(_) => "fault",
        }
    }

    /// Run `f` against the backend.  Native/failover/fault: direct
    /// call, no lock; PJRT: serialized behind [`PjrtShared`]'s mutex
    /// (held per `execute`, inside its `ExecBackend` impl).
    pub fn with<R>(&self, f: impl FnOnce(&dyn ExecBackend) -> R) -> R {
        match self {
            SharedRuntime::Native(b) => f(b),
            SharedRuntime::Pjrt(p) => f(p),
            SharedRuntime::Failover(b) => f(b),
            SharedRuntime::Fault(b) => f(b),
        }
    }

    /// pjrt→native failover transitions so far (0 when the backend has
    /// no failover breaker).
    pub fn failovers(&self) -> u64 {
        match self {
            SharedRuntime::Native(_) | SharedRuntime::Pjrt(_) => 0,
            SharedRuntime::Failover(f) => f.failovers(),
            // the fault wrapper type-erases its inner backend, so a
            // breaker below it (fault injection over auto()) is not
            // observable here; chaos runs inject over native anyway
            SharedRuntime::Fault(_) => 0,
        }
    }

    /// See [`ExecBackend::call_count`].
    pub fn call_count(&self, name: &str) -> u64 {
        self.with(|r| r.call_count(name))
    }

    /// See [`ExecBackend::call_counts`].
    pub fn call_counts(&self) -> BTreeMap<String, u64> {
        self.with(|r| r.call_counts())
    }

    /// Batch capacity of artifact `name` from the manifest.
    pub fn batch_cap(&self, name: &str) -> crate::Result<usize> {
        self.with(|r| r.batch_cap(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_parses() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping manifest_parses: no artifacts/ (run `make artifacts`)");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        for k in ["write", "read", "retention"] {
            let e = m.get(k).unwrap();
            assert!(e.batch >= 128);
            assert!(!e.params.is_empty());
            assert_eq!(e.inputs_ok(), true);
        }
        assert!(m.idvg.is_some());
        assert_eq!(m.get("retention").unwrap().integrator, "expdecay");
    }

    impl ArtifactMeta {
        fn inputs_ok(&self) -> bool {
            self.nf() > 0 && self.ns() > 0 && self.npar() > 0
        }
    }

    #[test]
    fn tensor_indexing() {
        let mut t = Tensor::zeros(vec![2, 3]);
        t.set2(1, 2, 5.0);
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.at2(0, 0), 0.0);
    }

    #[test]
    fn tensor_shape_is_checked() {
        assert!(Tensor::checked(vec![2, 3], vec![0.0; 6]).is_ok());
        // short buffer: checked errors (and new panics) instead of
        // silently mis-indexing row-major order
        let err = Tensor::checked(vec![2, 3], vec![0.0; 5]).unwrap_err();
        assert!(format!("{err}").contains("[2, 3]"), "{err}");
        assert!(Tensor::checked(vec![-2, 3], vec![0.0; 6]).is_err(), "negative dim");
    }

    #[test]
    #[should_panic(expected = "tensor shape")]
    fn tensor_new_panics_on_bad_reshape() {
        let _ = Tensor::new(vec![4, 4], vec![0.0; 6]);
    }

    #[test]
    #[cfg(debug_assertions)] // the bounds check compiles out in --release
    #[should_panic(expected = "out of bounds")]
    fn tensor_at2_bounds_are_debug_asserted() {
        // an out-of-range column must not alias into the next row
        let t = Tensor::zeros(vec![2, 3]);
        let _ = t.at2(0, 3);
    }

    #[test]
    // linux only: macOS APFS rejects non-UTF8 filenames at creation
    #[cfg(target_os = "linux")]
    fn non_utf8_artifact_path_is_an_error_not_a_panic() {
        use std::ffi::OsString;
        use std::os::unix::ffi::OsStringExt;
        // a real manifest inside a non-UTF8 directory: load must reach
        // the artifact-path step and return a proper error (it used to
        // panic on `path.to_str().unwrap()`); per-process dir name so
        // concurrent checkouts' test runs cannot clobber each other
        let mut name = format!("gcram-{}-", std::process::id()).into_bytes();
        name.extend_from_slice(b"\xff-artifacts");
        let dir = std::env::temp_dir().join(OsString::from_vec(name));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"write": {"file": "write.hlo.txt", "free_nodes": ["sn"], "stim_nodes": ["wwl"], "params": ["mwr.kp"], "outputs": ["sn_final"]}}"#,
        )
        .unwrap();
        let err = format!("{:#}", Runtime::load(&dir).unwrap_err());
        assert!(err.contains("not valid UTF-8"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
