//! Native batched execution backend: the in-process EKV solver
//! ([`crate::sim`]) promoted to a first-class [`ExecBackend`].
//!
//! The backend synthesizes a [`Manifest`] with the **same artifact
//! names, shapes and param/stim/free-node column layouts** the AOT XLA
//! artifacts use (single source of truth: `python/compile/circuits.py`
//! / `aot.py`, mirrored 1:1 by the [`crate::sim`] templates), so the
//! typed entry points in [`crate::runtime::engines`] assemble and parse
//! exactly the same tensors against either backend.  Measurement
//! semantics mirror `python/compile/model.py`: threshold crossings with
//! linear interpolation ([`sim::cross_time`]), `big_time` as the
//! "never crossed" sentinel, and the same per-op output tuples.
//!
//! # Execution model
//!
//! [`ExecBackend::execute`] evaluates the whole padded batch in
//! [`SOA_BLOCK`]-row blocks on the structure-of-arrays stepper
//! ([`sim::soa`]): node voltages, params, `cinv` and amplitudes live
//! in contiguous column-major buffers and **all rows of a block
//! advance per time step**, with blocks chunked across threads via
//! [`crate::util::par_map`].  Early-exit masks retire rows that can no
//! longer change the outputs: zero-param padding rows (pre-retired to
//! their constant `v0`, since every stamp's current scales with a
//! parameter), Heun rows at a bitwise per-step fixed point under
//! constant stimulus ([`sim::soa::ExitPolicy::Settle`]), and retention
//! tails that already crossed their hold threshold or whose rhs is
//! exactly zero ([`sim::soa::ExitPolicy::FallingCross`]).  Measurements
//! and the downsampled trace are read straight out of the SoA buffers
//! through borrowed views — no per-row `Vec<Vec<f64>>` transpose.
//!
//! The original row-at-a-time path is retained as the **scalar
//! reference** ([`NativeBackend::with_scalar_reference`], or env
//! `OPENGCRAM_NATIVE_SCALAR=1`): one [`sim::transient`] per row on
//! libm transcendentals, used by `tests/parity.rs` engine==direct-sim
//! pins and as the baseline of the rows/sec KPI in `perf_hotpaths`.
//!
//! # Determinism and parity
//!
//! All arithmetic runs in `f64` on values decoded from the `f32` input
//! tensors (exact widening) and is rounded to `f32` only at the output
//! boundary.  Per-row work never depends on batch position, block
//! composition, or thread chunking, so a batched execution is
//! **bitwise identical** to per-point singletons *within either mode*
//! — `tests/parity.rs` pins this for both.  Across modes the contract
//! is a documented tolerance, not bitwise equality: the SoA path uses
//! branch-free polynomial `exp`/`ln1p` kernels (~1e-15 relative, far
//! below the f32 output quantization), and a retired retention row's
//! `sn_final`/trace tail freeze at the crossing instead of decaying
//! further (`t_retain` itself is preserved exactly; downstream
//! consumers use only `t_retain`).  The scalar reference remains
//! bitwise-pinned against direct `sim::transient` runs.
//!
//! # Time grids
//!
//! The dt schedule is a runtime *input* (per the manifest contract), so
//! the backend integrates whatever grid the caller authors.  In
//! particular [`engines::retention`](crate::runtime::engines::retention)
//! hands both backends the geometric grid
//! `log_dt(steps, 1e-12, 1.082)` — starting at ~1 **ps** (not ~1 ns)
//! and spanning ~1e5 s after `k_substeps` scaling — and the native
//! backend must reproduce crossings on that grid, not substitute its
//! own.

use super::{ArtifactMeta, ExecBackend, Manifest, Tensor};
use crate::sim;
use crate::sim::soa;
use crate::util;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

/// Transient batch capacity (matches the AOT artifacts' `BATCH`).
pub const NATIVE_BATCH: usize = 256;
/// Id-Vg batch / gate-grid sizes (match `aot.py`).
pub const IDVG_BATCH: usize = 128;
pub const IDVG_GRID: usize = 64;

/// Rows per SoA block: small enough that a block's working set stays
/// cache-resident, large enough to fill SIMD lanes and amortize the
/// per-step stamp dispatch; 256/32 = 8 blocks fan out over
/// [`crate::util::par_map`].
pub const SOA_BLOCK: usize = 32;

const T_WRITE: usize = 384;
const T_READ: usize = 384;
const T_RETENTION: usize = 448;
const K_SUBSTEPS: usize = 4;
const TRACE_DS: usize = 4;
/// "Never crossed" sentinel (mirror of model.BIG_TIME).
pub const BIG_TIME: f64 = 1e12;

/// The synthesized manifest: byte-for-byte the column layout
/// `python/compile/aot.py` writes for the XLA artifacts, so both
/// backends are interchangeable behind [`ExecBackend`].
pub fn native_manifest() -> Manifest {
    fn card_cols(tag: &str) -> Vec<String> {
        ["kp", "vt", "n", "lam", "wl", "sign"].iter().map(|c| format!("{tag}.{c}")).collect()
    }
    fn strs(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }
    fn entry(
        steps: usize,
        integrator: &str,
        free: &[&str],
        stim: &[&str],
        params: Vec<String>,
        outputs: &[&str],
    ) -> ArtifactMeta {
        ArtifactMeta {
            file: "<native>".into(),
            batch: NATIVE_BATCH,
            steps,
            k_substeps: K_SUBSTEPS,
            trace_ds: TRACE_DS,
            big_time: BIG_TIME,
            integrator: integrator.into(),
            free_nodes: strs(free),
            stim_nodes: strs(stim),
            params,
            outputs: strs(outputs),
        }
    }
    let mut entries = BTreeMap::new();
    // write: driver inverter -> WBL -> write tx -> SN (circuits.py)
    let mut p = card_cols("mwr");
    p.extend(card_cols("mdrvp"));
    p.extend(card_cols("mdrvn"));
    p.push("cwwl_sn.c".into());
    p.push("gwbl.g".into());
    entries.insert(
        "write".to_string(),
        entry(
            T_WRITE,
            "heun",
            &["sn", "wbl"],
            &["wwl", "dinb", "vdd", "gnd"],
            p,
            &["times_ds", "trace_ds", "sn_final", "t_wr", "sn_peak"],
        ),
    );
    // read: read tx (source on RWL, gate on SN) drives RBL
    let mut p = card_cols("mrd");
    p.extend(card_cols("mrbl_leak"));
    p.push("crwl_sn.c".into());
    p.push("grbl.g".into());
    entries.insert(
        "read".to_string(),
        entry(
            T_READ,
            "heun",
            &["sn", "rbl"],
            &["rwl", "rwl_idle", "snu", "gnd"],
            p,
            &["times_ds", "trace_ds", "t_rise", "t_fall", "rbl_final", "sn_final"],
        ),
    );
    // retention: SN decay through write-tx subthreshold + gate leak
    let mut p = card_cols("mwr");
    p.push("gleak.g".into());
    p.push("idist.i".into());
    entries.insert(
        "retention".to_string(),
        entry(
            T_RETENTION,
            "expdecay",
            &["sn"],
            &["wwl", "wbl", "gnd", "vth"],
            p,
            &["times_ds", "trace_ds", "t_retain", "sn_final"],
        ),
    );
    Manifest {
        dir: PathBuf::from("<native>"),
        entries,
        idvg: Some((IDVG_BATCH, IDVG_GRID)),
    }
}

/// The native backend: synthesized manifest + per-artifact execution
/// counters.  `Send + Sync` for real (plain data and atomics), so
/// [`super::SharedRuntime::Native`] hands it out without a lock.
pub struct NativeBackend {
    manifest: Manifest,
    calls: BTreeMap<String, AtomicU64>,
    workers: usize,
    scalar_reference: bool,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeBackend {
    /// A backend on the SoA hot path (or the scalar reference when the
    /// `OPENGCRAM_NATIVE_SCALAR` env var is set to anything but `0`).
    pub fn new() -> NativeBackend {
        let manifest = native_manifest();
        let mut calls: BTreeMap<String, AtomicU64> =
            manifest.entries.keys().map(|k| (k.clone(), AtomicU64::new(0))).collect();
        calls.insert("idvg".into(), AtomicU64::new(0));
        let scalar_reference =
            std::env::var("OPENGCRAM_NATIVE_SCALAR").map(|v| v != "0").unwrap_or(false);
        NativeBackend { manifest, calls, workers: util::default_workers(), scalar_reference }
    }

    /// Override the row-chunking fan-out (default: one per core).
    pub fn with_workers(mut self, workers: usize) -> NativeBackend {
        self.workers = workers.max(1);
        self
    }

    /// Force the row-at-a-time scalar reference path (libm
    /// transcendentals, no early exits): the baseline the SoA kernel
    /// is measured and parity-pinned against.
    pub fn with_scalar_reference(mut self) -> NativeBackend {
        self.scalar_reference = true;
        self
    }

    fn transient(&self, op: TransientOp, inputs: &[Tensor]) -> crate::Result<Vec<Tensor>> {
        let meta = self.manifest.get(op.name())?;
        let (b, nf, ns, np, steps) = (meta.batch, meta.nf(), meta.ns(), meta.npar(), meta.steps);
        anyhow::ensure!(inputs.len() == 7, "{}: expected 7 inputs, got {}", op.name(), inputs.len());
        let shapes: [Vec<i64>; 7] = [
            vec![b as i64, nf as i64],
            vec![b as i64, ns as i64],
            vec![b as i64, np as i64],
            vec![b as i64, nf as i64],
            vec![steps as i64, ns as i64],
            vec![steps as i64, ns as i64],
            vec![steps as i64],
        ];
        for (i, want) in shapes.iter().enumerate() {
            anyhow::ensure!(
                &inputs[i].dims == want,
                "{}: input {i} has shape {:?}, expected {:?}",
                op.name(),
                inputs[i].dims,
                want
            );
        }
        let (v0, amp, params, cinv) = (&inputs[0], &inputs[1], &inputs[2], &inputs[3]);
        let wave: Vec<Vec<f64>> = rows_f64(&inputs[4], steps, ns);
        let dwave: Vec<Vec<f64>> = rows_f64(&inputs[5], steps, ns);
        let dt: Vec<f64> = inputs[6].data.iter().map(|&v| v as f64).collect();
        let times = super::stimulus::times_from_dt(&dt, meta.k_substeps);
        let cols = op.columns(meta)?;
        let tmpl = op.template();
        let mode = op.integrator();

        let stride = meta.trace_ds.max(1);
        let per_row: Vec<RowOut> = if self.scalar_reference {
            // scalar reference: one independent sim::transient per row,
            // whole rows chunked across threads; zero-param (padding)
            // rows measure straight off their constant v0 view
            let rows: Vec<usize> = (0..b).collect();
            util::par_map(&rows, self.workers, |&i| {
                let v0r = row_f64(v0, i, nf);
                let ampr = row_f64(amp, i, ns);
                let pr = row_f64(params, i, np);
                let cinvr = row_f64(cinv, i, nf);
                if pr.iter().any(|&p| p != 0.0) {
                    let (_, trace) = sim::transient(
                        &tmpl,
                        mode,
                        meta.k_substeps,
                        &v0r,
                        &ampr,
                        &pr,
                        &cinvr,
                        &wave,
                        &dwave,
                        &dt,
                    );
                    let view = TraceView::Rows(&trace);
                    row_out(op, &cols, meta.big_time, &times, &view, &v0r, &ampr, nf, stride)
                } else {
                    let view = TraceView::Const { v0: &v0r, steps };
                    row_out(op, &cols, meta.big_time, &times, &view, &v0r, &ampr, nf, stride)
                }
            })
        } else {
            // SoA hot path: SOA_BLOCK-row blocks advance all rows per
            // time step; blocks (not rows) are the par_map work items
            let sched = soa::Schedule::new(&wave, &dwave, &dt);
            let exit = match op {
                TransientOp::Retention => soa::ExitPolicy::FallingCross { node: cols.n_sn },
                _ => soa::ExitPolicy::Settle,
            };
            let blocks: Vec<(usize, usize)> =
                (0..b).step_by(SOA_BLOCK).map(|r0| (r0, SOA_BLOCK.min(b - r0))).collect();
            let outs: Vec<Vec<RowOut>> = util::par_map(&blocks, self.workers, |&(r0, n)| {
                let mut blk = soa::Block::new(n, nf, ns, np);
                let mut any_live = false;
                for j in 0..n {
                    let i = r0 + j;
                    for k in 0..nf {
                        blk.v[k * n + j] = v0.data[i * nf + k] as f64;
                        blk.cinv[k * n + j] = cinv.data[i * nf + k] as f64;
                    }
                    for s in 0..ns {
                        blk.amp[s * n + j] = amp.data[i * ns + s] as f64;
                    }
                    let mut live = false;
                    for c in 0..np {
                        let pv = params.data[i * np + c] as f64;
                        blk.p[c * n + j] = pv;
                        live |= pv != 0.0;
                    }
                    blk.retired[j] = !live;
                    any_live |= live;
                    if matches!(op, TransientOp::Retention) {
                        // hold threshold, mirroring measure(): amp[vth]
                        // if positive, else half the initial level
                        let vth_abs = blk.amp[cols.s_a * n + j];
                        blk.thresh[j] =
                            if vth_abs > 0.0 { vth_abs } else { 0.5 * blk.v[cols.n_sn * n + j] };
                    }
                }
                let trace = if any_live {
                    Some(soa::run_block(&tmpl, mode, meta.k_substeps, &sched, &mut blk, exit))
                } else {
                    None // all-padding block: never integrate it
                };
                (0..n)
                    .map(|j| {
                        let v0r = row_f64(v0, r0 + j, nf);
                        let ampr = row_f64(amp, r0 + j, ns);
                        let view = match &trace {
                            Some(buf) => {
                                TraceView::Soa { buf: buf.as_slice(), nf, rows: n, j, steps }
                            }
                            None => TraceView::Const { v0: &v0r, steps },
                        };
                        row_out(op, &cols, meta.big_time, &times, &view, &v0r, &ampr, nf, stride)
                    })
                    .collect()
            });
            outs.into_iter().flatten().collect()
        };

        // assemble the output tuple: times_ds, trace_ds, then the
        // per-op scalar outputs (outputs[2..] in the manifest)
        let t_ds = times.iter().step_by(meta.trace_ds.max(1)).count();
        let times_ds: Vec<f32> =
            times.iter().step_by(meta.trace_ds.max(1)).map(|&t| t as f32).collect();
        let mut trace_ds = vec![0.0f32; t_ds * b * nf];
        for (i, r) in per_row.iter().enumerate() {
            for ti in 0..t_ds {
                for k in 0..nf {
                    trace_ds[(ti * b + i) * nf + k] = r.ds[ti * nf + k];
                }
            }
        }
        let n_scalars = meta.outputs.len().saturating_sub(2);
        let mut out = vec![
            Tensor::new(vec![t_ds as i64], times_ds),
            Tensor::new(vec![t_ds as i64, b as i64, nf as i64], trace_ds),
        ];
        for s in 0..n_scalars {
            out.push(Tensor::new(
                vec![b as i64],
                per_row.iter().map(|r| r.scalars[s] as f32).collect(),
            ));
        }
        Ok(out)
    }

    fn idvg(&self, inputs: &[Tensor]) -> crate::Result<Vec<Tensor>> {
        let (b, g) = self.manifest.idvg.unwrap_or((IDVG_BATCH, IDVG_GRID));
        anyhow::ensure!(inputs.len() == 3, "idvg: expected 3 inputs, got {}", inputs.len());
        anyhow::ensure!(
            inputs[0].dims == [b as i64, 6]
                && inputs[1].dims == [g as i64]
                && inputs[2].dims == [b as i64, 1],
            "idvg: bad input shapes {:?}",
            inputs.iter().map(|t| t.dims.clone()).collect::<Vec<_>>()
        );
        let vg: Vec<f64> = inputs[1].data.iter().map(|&v| v as f64).collect();
        let rows: Vec<usize> = (0..b).collect();
        let ids: Vec<Vec<f32>> = util::par_map(&rows, self.workers, |&i| {
            let c = row_f64(&inputs[0], i, 6);
            let vds = inputs[2].data[i] as f64;
            vg.iter()
                .map(|&v| sim::mos_ids(vds, v, 0.0, c[0], c[1], c[2], c[3], c[4], c[5]) as f32)
                .collect()
        });
        Ok(vec![Tensor::new(
            vec![b as i64, g as i64],
            ids.into_iter().flatten().collect(),
        )])
    }
}

impl ExecBackend for NativeBackend {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn execute(&self, name: &str, inputs: &[Tensor]) -> crate::Result<Vec<Tensor>> {
        let counter = self
            .calls
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("engine '{name}' not loaded"))?;
        counter.fetch_add(1, Ordering::Relaxed);
        match name {
            "write" => self.transient(TransientOp::Write, inputs),
            "read" => self.transient(TransientOp::Read, inputs),
            "retention" => self.transient(TransientOp::Retention, inputs),
            "idvg" => self.idvg(inputs),
            other => anyhow::bail!("engine '{other}' not loaded"),
        }
    }

    fn call_count(&self, name: &str) -> u64 {
        self.calls.get(name).map(|c| c.load(Ordering::Relaxed)).unwrap_or(0)
    }

    fn call_counts(&self) -> BTreeMap<String, u64> {
        self.calls.iter().map(|(k, c)| (k.clone(), c.load(Ordering::Relaxed))).collect()
    }

    fn platform(&self) -> String {
        "native-ekv".to_string()
    }
}

struct RowOut {
    /// Downsampled trace, row-major (t_ds x nf).
    ds: Vec<f32>,
    /// Per-op scalar outputs (manifest `outputs[2..]` order).
    scalars: Vec<f64>,
}

/// Column indices a transient op's measurements need, resolved from the
/// manifest by name (never hard-coded).
struct Columns {
    n_sn: usize,
    /// `rbl` for read; unused otherwise.
    n_rbl: usize,
    /// (`rwl`, `rwl_idle`) for read, (`vth`, 0) for retention.
    s_a: usize,
    s_b: usize,
}

#[derive(Clone, Copy)]
enum TransientOp {
    Write,
    Read,
    Retention,
}

impl TransientOp {
    fn name(self) -> &'static str {
        match self {
            TransientOp::Write => "write",
            TransientOp::Read => "read",
            TransientOp::Retention => "retention",
        }
    }

    fn template(self) -> sim::Template {
        match self {
            TransientOp::Write => sim::write_template(),
            TransientOp::Read => sim::read_template(),
            TransientOp::Retention => sim::retention_template(),
        }
    }

    fn integrator(self) -> sim::Integrator {
        match self {
            TransientOp::Retention => sim::Integrator::ExpDecay,
            _ => sim::Integrator::Heun,
        }
    }

    fn columns(self, meta: &ArtifactMeta) -> crate::Result<Columns> {
        Ok(match self {
            TransientOp::Write => Columns { n_sn: meta.free("sn")?, n_rbl: 0, s_a: 0, s_b: 0 },
            TransientOp::Read => Columns {
                n_sn: meta.free("sn")?,
                n_rbl: meta.free("rbl")?,
                s_a: meta.stim("rwl")?,
                s_b: meta.stim("rwl_idle")?,
            },
            TransientOp::Retention => {
                Columns { n_sn: meta.free("sn")?, n_rbl: 0, s_a: meta.stim("vth")?, s_b: 0 }
            }
        })
    }

    /// The model.py measurement block for one row, on the full-rate
    /// trace **view** — columns are read in place through
    /// [`sim::cross_time_at`], never copied into a fresh `Vec`.
    /// Returns the scalar outputs in manifest order.
    fn measure(
        self,
        cols: &Columns,
        big: f64,
        times: &[f64],
        trace: &TraceView,
        v0r: &[f64],
        ampr: &[f64],
    ) -> Vec<f64> {
        let n = trace.steps();
        match self {
            TransientOp::Write => {
                // sn_final, t_wr (90 %-of-peak rising / 10 %-of-initial
                // falling), sn_peak
                let sn = cols.n_sn;
                let sn0 = v0r[sn];
                let mut sn_peak = f64::NEG_INFINITY;
                for s in 0..n {
                    sn_peak = sn_peak.max(trace.at(s, sn));
                }
                let t_rise = sim::cross_time_at(times, n, |s| trace.at(s, sn), 0.9 * sn_peak, true)
                    .unwrap_or(big);
                let t_fall =
                    sim::cross_time_at(times, n, |s| trace.at(s, sn), 0.1 * sn0.max(1e-3), false)
                        .unwrap_or(big);
                let t_wr = if sn_peak <= sn0 + 0.05 { t_fall } else { t_rise };
                vec![trace.last_or(sn, sn0), t_wr, sn_peak]
            }
            TransientOp::Read => {
                // vref = 0.5 * max(amp[rwl], amp[rwl_idle]) == VDD/2 for
                // every flavor (predischarge swings RWL to VDD,
                // precharge idles the rail at VDD)
                let (rbl, sn) = (cols.n_rbl, cols.n_sn);
                let vref = 0.5 * ampr[cols.s_a].max(ampr[cols.s_b]);
                let t_rise =
                    sim::cross_time_at(times, n, |s| trace.at(s, rbl), vref, true).unwrap_or(big);
                let t_fall =
                    sim::cross_time_at(times, n, |s| trace.at(s, rbl), vref, false).unwrap_or(big);
                vec![t_rise, t_fall, trace.last_or(rbl, 0.0), trace.last_or(sn, 0.0)]
            }
            TransientOp::Retention => {
                // hold threshold: amp[vth] if positive, else 0.5 * v0
                let sn = cols.n_sn;
                let vth_abs = ampr[cols.s_a];
                let vhold = if vth_abs > 0.0 { vth_abs } else { 0.5 * v0r[sn] };
                let t_ret =
                    sim::cross_time_at(times, n, |s| trace.at(s, sn), vhold, false).unwrap_or(big);
                vec![t_ret, trace.last_or(sn, v0r[sn])]
            }
        }
    }
}

/// A borrowed, zero-copy view of one row's full-rate trace, uniform
/// over the three storage layouts the backend produces.
enum TraceView<'a> {
    /// Per-step rows from the scalar reference ([`sim::transient`]).
    Rows(&'a [Vec<f64>]),
    /// A constant-`v0` row (zero-param padding): sample `s` of node
    /// `k` is `v0[k]` for all `steps` steps, never materialized.
    Const { v0: &'a [f64], steps: usize },
    /// Row `j` of an SoA block trace laid out `(s*nf + k)*rows + j`.
    Soa { buf: &'a [f64], nf: usize, rows: usize, j: usize, steps: usize },
}

impl TraceView<'_> {
    fn steps(&self) -> usize {
        match *self {
            TraceView::Rows(t) => t.len(),
            TraceView::Const { steps, .. } => steps,
            TraceView::Soa { steps, .. } => steps,
        }
    }

    /// Sample `s` of free node `k`.
    #[inline]
    fn at(&self, s: usize, k: usize) -> f64 {
        match *self {
            TraceView::Rows(t) => t[s][k],
            TraceView::Const { v0, .. } => v0[k],
            TraceView::Soa { buf, nf, rows, j, .. } => buf[(s * nf + k) * rows + j],
        }
    }

    /// Last sample of node `k`, or `default` on an empty trace.
    fn last_or(&self, k: usize, default: f64) -> f64 {
        let n = self.steps();
        if n == 0 { default } else { self.at(n - 1, k) }
    }
}

/// Measure one row and downsample its trace, straight off the view.
#[allow(clippy::too_many_arguments)]
fn row_out(
    op: TransientOp,
    cols: &Columns,
    big: f64,
    times: &[f64],
    view: &TraceView,
    v0r: &[f64],
    ampr: &[f64],
    nf: usize,
    stride: usize,
) -> RowOut {
    let scalars = op.measure(cols, big, times, view, v0r, ampr);
    let steps = view.steps();
    let mut ds = Vec::with_capacity(steps.div_ceil(stride) * nf);
    let mut s = 0;
    while s < steps {
        for k in 0..nf {
            ds.push(view.at(s, k) as f32);
        }
        s += stride;
    }
    RowOut { ds, scalars }
}

/// One tensor row, widened to f64 (exact).
fn row_f64(t: &Tensor, i: usize, w: usize) -> Vec<f64> {
    t.data[i * w..(i + 1) * w].iter().map(|&v| v as f64).collect()
}

/// All rows of a (rows x w) tensor, widened to f64.
fn rows_f64(t: &Tensor, rows: usize, w: usize) -> Vec<Vec<f64>> {
    (0..rows).map(|i| row_f64(t, i, w)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_mirrors_the_sim_templates() {
        let m = native_manifest();
        for (name, tmpl) in [
            ("write", sim::write_template()),
            ("read", sim::read_template()),
            ("retention", sim::retention_template()),
        ] {
            let e = m.get(name).unwrap();
            assert_eq!(e.nf(), tmpl.nf, "{name}: free-node count");
            assert_eq!(e.ns(), tmpl.ns, "{name}: stimulus count");
            assert_eq!(e.npar(), tmpl.npar, "{name}: param count");
            assert_eq!(e.batch, NATIVE_BATCH);
        }
        // the column names the engines resolve must all exist
        let w = m.get("write").unwrap();
        for p in ["mwr.kp", "mdrvp.kp", "mdrvn.kp", "cwwl_sn.c", "gwbl.g"] {
            w.pcol(p).unwrap();
        }
        for s in ["wwl", "dinb", "vdd"] {
            w.stim(s).unwrap();
        }
        let r = m.get("read").unwrap();
        for p in ["mrd.kp", "mrbl_leak.kp", "crwl_sn.c", "grbl.g"] {
            r.pcol(p).unwrap();
        }
        for s in ["rwl", "rwl_idle", "snu"] {
            r.stim(s).unwrap();
        }
        let ret = m.get("retention").unwrap();
        for p in ["mwr.kp", "gleak.g", "idist.i"] {
            ret.pcol(p).unwrap();
        }
        ret.stim("vth").unwrap();
        assert_eq!(ret.integrator, "expdecay");
        assert_eq!(m.idvg, Some((IDVG_BATCH, IDVG_GRID)));
    }

    #[test]
    fn counters_count_per_artifact_and_unknown_names_error() {
        let b = NativeBackend::new();
        assert_eq!(b.call_count("retention"), 0);
        let err = b.execute("nonesuch", &[]).unwrap_err();
        assert!(format!("{err}").contains("nonesuch"), "{err}");
        assert_eq!(b.call_count("nonesuch"), 0);
        // a malformed call still counts as an issued execution (the
        // PJRT side bumps before executing too)
        assert!(b.execute("retention", &[]).is_err());
        assert_eq!(b.call_count("retention"), 1);
        assert_eq!(b.call_counts().get("retention"), Some(&1));
    }

    #[test]
    fn shape_validation_rejects_malformed_batches() {
        let b = NativeBackend::new();
        let m = b.manifest().get("retention").unwrap().clone();
        let (bt, nf, ns, np, steps) =
            (m.batch as i64, m.nf() as i64, m.ns() as i64, m.npar() as i64, m.steps as i64);
        let good = vec![
            Tensor::zeros(vec![bt, nf]),
            Tensor::zeros(vec![bt, ns]),
            Tensor::zeros(vec![bt, np]),
            Tensor::zeros(vec![bt, nf]),
            Tensor::zeros(vec![steps, ns]),
            Tensor::zeros(vec![steps, ns]),
            Tensor::zeros(vec![steps]),
        ];
        assert!(b.execute("retention", &good).is_ok());
        let mut bad = good;
        bad[2] = Tensor::zeros(vec![bt, np + 1]);
        let err = b.execute("retention", &bad).unwrap_err();
        assert!(format!("{err}").contains("input 2"), "{err}");
    }

    #[test]
    fn zero_param_rows_short_circuit_to_their_initial_state() {
        // an all-zero padded batch: every row's trace is constant v0,
        // t_retain = 0 for v0 = 0 rows (already below the relative
        // threshold) — and crucially execute() fills the full tuple
        let b = NativeBackend::new();
        let m = b.manifest().get("retention").unwrap().clone();
        let (bt, nf, ns, np, steps) = (m.batch, m.nf(), m.ns(), m.npar(), m.steps);
        let mut v0 = Tensor::zeros(vec![bt as i64, nf as i64]);
        v0.set2(3, 0, 0.6); // one pinned row holds its level
        let mut cinv = Tensor::zeros(vec![bt as i64, nf as i64]);
        for i in 0..bt {
            cinv.set2(i, 0, 1e15);
        }
        let inputs = vec![
            v0,
            Tensor::zeros(vec![bt as i64, ns as i64]),
            Tensor::zeros(vec![bt as i64, np as i64]),
            cinv,
            Tensor::zeros(vec![steps as i64, ns as i64]),
            Tensor::zeros(vec![steps as i64, ns as i64]),
            Tensor::new(vec![steps as i64], vec![1e-12; steps]),
        ];
        let out = b.execute("retention", &inputs).unwrap();
        assert_eq!(out.len(), 4, "times_ds, trace_ds, t_retain, sn_final");
        let sn_final = &out[3];
        assert_eq!(sn_final.data[3], 0.6, "constant trace keeps v0");
        assert_eq!(sn_final.data[0], 0.0);
        let t_retain = &out[2];
        // a constant 0.6 level never crosses its 0.3 relative threshold
        assert_eq!(t_retain.data[3], BIG_TIME as f32);

        // padding rows take the same constant-v0 view in both modes,
        // so the scalar reference agrees bitwise on this batch
        let s = NativeBackend::new().with_scalar_reference();
        let sout = s.execute("retention", &inputs).unwrap();
        for (a, b) in out.iter().zip(&sout) {
            assert_eq!(a.dims, b.dims);
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }
}
