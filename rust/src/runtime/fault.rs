//! Deterministic fault injection for the execution pipeline.
//!
//! [`FaultBackend`] wraps any [`ExecBackend`] and injects faults from a
//! [`FaultPlan`]: an executor error on the Nth execute of a named
//! artifact, an executor panic, or NaN poisoning of one batch row of
//! the output tuple.  Every recovery path above the runtime — the
//! coordinator's retry/backoff and batch bisection
//! ([`crate::coordinator`]), the engines' per-row output quarantine
//! ([`super::engines`]), and the pjrt→native failover
//! ([`super::FailoverBackend`]) — is exercised in CI against this
//! wrapper instead of waiting for real hardware flakes.
//!
//! Plans are deterministic: a fault fires on an exact per-artifact
//! attempt ordinal (1-based, counted on the wrapper), so a fixed plan
//! over a fixed job stream reproduces bit-identical failures.  Plans
//! come from three places:
//!
//! * builders ([`FaultPlan::error_on`] / [`FaultPlan::panic_on`] /
//!   [`FaultPlan::poison_row`]) for tests,
//! * [`FaultPlan::seeded`] for randomized-but-replayable chaos runs
//!   (driven by [`crate::util::rng`]),
//! * the `OPENGCRAM_FAULTS` environment variable
//!   ([`FaultPlan::from_env`]) for CLI runs, parsed strictly in the
//!   [`crate::cli`] style.
//!
//! An injected *error* attempt never reaches the inner backend, so the
//! inner call counters keep counting **real executions only**: with an
//! empty plan the wrapper is execution-count-transparent (the chaos
//! parity pin in `tests/fault.rs` asserts this).

use super::{ExecBackend, Manifest, Tensor};
use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// What a fault does when it fires.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// The execute attempt returns `Err` without running the inner
    /// backend.  Because it is pinned to one attempt ordinal, the
    /// coordinator's next retry lands on the following ordinal and
    /// succeeds — i.e. this is a *transient-then-recover* fault.
    Error,
    /// The execute attempt panics (as a flaky executor or poisoned FFI
    /// call would), exercising the coordinator's epitaph path.
    Panic,
    /// The inner backend runs normally, then row `row` of every
    /// batch-length rank-1 output tensor is overwritten with NaN —
    /// a solver blowup confined to one design point.
    PoisonRow { row: usize },
}

/// One planned fault: fire `kind` on the `nth` (1-based) execute
/// attempt of `artifact`, counted on the wrapper since construction.
#[derive(Debug, Clone)]
pub struct Fault {
    pub artifact: String,
    pub nth: u64,
    pub kind: FaultKind,
}

/// A deterministic set of [`Fault`]s.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    faults: Vec<Fault>,
}

impl FaultPlan {
    /// The empty plan (injects nothing; the wrapper is transparent).
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Inject a transient executor error on the `nth` execute of
    /// `artifact`.
    pub fn error_on(mut self, artifact: &str, nth: u64) -> FaultPlan {
        self.faults.push(Fault { artifact: artifact.into(), nth, kind: FaultKind::Error });
        self
    }

    /// Inject an executor panic on the `nth` execute of `artifact`.
    pub fn panic_on(mut self, artifact: &str, nth: u64) -> FaultPlan {
        self.faults.push(Fault { artifact: artifact.into(), nth, kind: FaultKind::Panic });
        self
    }

    /// Poison row `row` of the output tuple of the `nth` execute of
    /// `artifact` with NaN.
    pub fn poison_row(mut self, artifact: &str, nth: u64, row: usize) -> FaultPlan {
        self.faults.push(Fault {
            artifact: artifact.into(),
            nth,
            kind: FaultKind::PoisonRow { row },
        });
        self
    }

    /// A seeded random plan over `artifacts`: `n` faults, each a
    /// transient error or a row poison (never a panic — seeded chaos
    /// runs should exercise recovery, not worker death), with attempt
    /// ordinals in `[1, within_attempts]` and poison rows in
    /// `[0, rows)`.  Same seed ⇒ same plan.
    pub fn seeded(seed: u64, artifacts: &[&str], n: usize, within_attempts: u64, rows: usize) -> FaultPlan {
        let mut rng = Rng::new(seed);
        let mut plan = FaultPlan::new();
        for _ in 0..n {
            let artifact = artifacts[rng.below(artifacts.len().max(1))];
            let nth = 1 + rng.next_u64() % within_attempts.max(1);
            plan = if rng.chance(0.5) {
                plan.error_on(artifact, nth)
            } else {
                plan.poison_row(artifact, nth, rng.below(rows.max(1)))
            };
        }
        plan
    }

    /// Parse the `OPENGCRAM_FAULTS` environment variable.  Returns
    /// `Ok(None)` when unset or empty; a set-but-malformed spec is a
    /// hard error (strict-parsing policy of [`crate::cli`]).
    pub fn from_env() -> crate::Result<Option<FaultPlan>> {
        match std::env::var("OPENGCRAM_FAULTS") {
            Ok(s) if !s.trim().is_empty() => FaultPlan::parse(&s).map(Some),
            _ => Ok(None),
        }
    }

    /// Parse a fault spec: comma-separated `artifact:kind@nth` entries
    /// where `kind` is `err`, `panic` or `nan:<row>` — e.g.
    /// `write:nan:0@1,retention:err@2`.
    pub fn parse(spec: &str) -> crate::Result<FaultPlan> {
        let mut plan = FaultPlan::new();
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (head, nth) = entry.rsplit_once('@').ok_or_else(|| {
                anyhow::anyhow!("fault spec '{entry}': expected 'artifact:kind@nth'")
            })?;
            let nth: u64 = nth
                .parse()
                .map_err(|_| anyhow::anyhow!("fault spec '{entry}': bad attempt ordinal '{nth}'"))?;
            anyhow::ensure!(nth >= 1, "fault spec '{entry}': attempt ordinal is 1-based");
            let mut parts = head.split(':');
            let artifact = parts.next().unwrap_or("");
            anyhow::ensure!(!artifact.is_empty(), "fault spec '{entry}': empty artifact name");
            let kind = match (parts.next(), parts.next(), parts.next()) {
                (Some("err"), None, _) => FaultKind::Error,
                (Some("panic"), None, _) => FaultKind::Panic,
                (Some("nan"), Some(row), None) => FaultKind::PoisonRow {
                    row: row.parse().map_err(|_| {
                        anyhow::anyhow!("fault spec '{entry}': bad poison row '{row}'")
                    })?,
                },
                _ => anyhow::bail!(
                    "fault spec '{entry}': kind must be 'err', 'panic' or 'nan:<row>'"
                ),
            };
            plan.faults.push(Fault { artifact: artifact.into(), nth, kind });
        }
        anyhow::ensure!(!plan.faults.is_empty(), "fault spec '{spec}': no faults");
        Ok(plan)
    }

    /// The planned faults (for reporting).
    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    fn matching(&self, artifact: &str, attempt: u64) -> impl Iterator<Item = &Fault> {
        self.faults
            .iter()
            .filter(move |f| f.artifact == artifact && f.nth == attempt)
    }
}

/// An [`ExecBackend`] wrapper that injects faults from a [`FaultPlan`].
///
/// Attempt ordinals are counted per artifact *on the wrapper*; injected
/// `Error`/`Panic` attempts never reach the inner backend, so the inner
/// call counters stay a census of real executions.
pub struct FaultBackend {
    inner: Box<dyn ExecBackend + Send + Sync>,
    plan: FaultPlan,
    attempts: Mutex<BTreeMap<String, u64>>,
    injected: AtomicU64,
}

impl FaultBackend {
    pub fn new(inner: Box<dyn ExecBackend + Send + Sync>, plan: FaultPlan) -> FaultBackend {
        FaultBackend {
            inner,
            plan,
            attempts: Mutex::new(BTreeMap::new()),
            injected: AtomicU64::new(0),
        }
    }

    /// Faults fired so far (errors + panics + poisoned rows).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Execute attempts seen per artifact (includes faulted attempts).
    pub fn attempts(&self, name: &str) -> u64 {
        let g = self.attempts.lock().unwrap_or_else(|e| e.into_inner());
        g.get(name).copied().unwrap_or(0)
    }
}

impl ExecBackend for FaultBackend {
    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }

    fn execute(&self, name: &str, inputs: &[Tensor]) -> crate::Result<Vec<Tensor>> {
        let attempt = {
            let mut g = self.attempts.lock().unwrap_or_else(|e| e.into_inner());
            let c = g.entry(name.to_string()).or_insert(0);
            *c += 1;
            *c
        };
        let mut poison_rows: Vec<usize> = Vec::new();
        for fault in self.plan.matching(name, attempt) {
            match fault.kind {
                FaultKind::Error => {
                    self.injected.fetch_add(1, Ordering::Relaxed);
                    anyhow::bail!(
                        "injected fault: artifact '{name}' execute attempt #{attempt}"
                    );
                }
                FaultKind::Panic => {
                    self.injected.fetch_add(1, Ordering::Relaxed);
                    panic!("injected panic: artifact '{name}' execute attempt #{attempt}");
                }
                FaultKind::PoisonRow { row } => poison_rows.push(row),
            }
        }
        let mut out = self.inner.execute(name, inputs)?;
        if !poison_rows.is_empty() {
            let batch = self.inner.manifest().get(name).map(|m| m.batch).unwrap_or(0);
            for t in &mut out {
                // poison only the per-row scalar outputs (rank-1,
                // batch-length) — the ones the engines scan per row
                if t.dims.len() == 1 && t.dims[0] as usize == batch {
                    for &row in &poison_rows {
                        if row < t.data.len() {
                            t.data[row] = f32::NAN;
                        }
                    }
                }
            }
            self.injected.fetch_add(poison_rows.len() as u64, Ordering::Relaxed);
        }
        Ok(out)
    }

    fn call_count(&self, name: &str) -> u64 {
        self.inner.call_count(name)
    }

    fn call_counts(&self) -> BTreeMap<String, u64> {
        self.inner.call_counts()
    }

    fn platform(&self) -> String {
        format!("{}+faults", self.inner.platform())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn wrap(plan: FaultPlan) -> FaultBackend {
        FaultBackend::new(Box::new(NativeBackend::new().with_workers(1)), plan)
    }

    fn write_inputs(b: &FaultBackend) -> Vec<Tensor> {
        let m = b.manifest().get("write").unwrap();
        let (batch, steps, nf, ns, np) =
            (m.batch as i64, m.steps as i64, m.nf() as i64, m.ns() as i64, m.npar() as i64);
        vec![
            Tensor::zeros(vec![batch, nf]),              // v0
            Tensor::zeros(vec![batch, ns]),              // amp
            Tensor::zeros(vec![batch, np]),              // params (all-pad)
            Tensor::zeros(vec![batch, nf]),              // cinv
            Tensor::zeros(vec![steps, ns]),              // wave
            Tensor::zeros(vec![steps, ns]),              // dwave
            Tensor::new(vec![steps], vec![1e-12; m.steps]), // dt
        ]
    }

    #[test]
    fn error_fires_only_on_its_ordinal_and_skips_the_inner_backend() {
        let b = wrap(FaultPlan::new().error_on("write", 2));
        let inputs = write_inputs(&b);
        assert!(b.execute("write", &inputs).is_ok());
        let err = b.execute("write", &inputs).unwrap_err();
        assert!(format!("{err}").contains("attempt #2"), "{err}");
        // transient: the next attempt recovers
        assert!(b.execute("write", &inputs).is_ok());
        assert_eq!(b.attempts("write"), 3);
        // the faulted attempt never reached the inner backend
        assert_eq!(b.call_count("write"), 2);
        assert_eq!(b.injected(), 1);
    }

    #[test]
    fn poison_row_nans_exactly_the_planned_row_of_scalar_outputs() {
        let b = wrap(FaultPlan::new().poison_row("write", 1, 3));
        let out = b.execute("write", &write_inputs(&b)).unwrap();
        let batch = b.manifest().get("write").unwrap().batch;
        for t in &out[2..] {
            assert_eq!(t.dims, vec![batch as i64]);
            assert!(t.data[3].is_nan(), "row 3 should be poisoned");
            assert!(t.data[2].is_finite() && t.data[4].is_finite());
        }
        // the big trace tensors are untouched
        assert!(out[1].data.iter().all(|v| v.is_finite()));
        assert_eq!(b.injected(), 1);
    }

    #[test]
    #[should_panic(expected = "injected panic")]
    fn panic_fault_panics() {
        let b = wrap(FaultPlan::new().panic_on("write", 1));
        let _ = b.execute("write", &write_inputs(&b));
    }

    #[test]
    fn empty_plan_is_transparent() {
        let b = wrap(FaultPlan::new());
        assert!(b.execute("write", &write_inputs(&b)).is_ok());
        assert_eq!(b.injected(), 0);
        assert_eq!(b.call_count("write"), 1);
    }

    #[test]
    fn spec_parses_strictly() {
        let plan = FaultPlan::parse("write:nan:0@1, retention:err@2,read:panic@3").unwrap();
        assert_eq!(plan.faults().len(), 3);
        assert_eq!(plan.faults()[0].kind, FaultKind::PoisonRow { row: 0 });
        assert_eq!(plan.faults()[1].kind, FaultKind::Error);
        assert_eq!(plan.faults()[1].nth, 2);
        assert_eq!(plan.faults()[2].kind, FaultKind::Panic);
        for bad in [
            "write",            // no @nth
            "write:err@0",      // 0 is not a valid 1-based ordinal
            "write:err@x",      // bad ordinal
            ":err@1",           // empty artifact
            "write:nan@1",      // nan without a row
            "write:frob@1",     // unknown kind
            "",                 // no faults at all
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "spec '{bad}' should be rejected");
        }
    }

    #[test]
    fn seeded_plans_are_reproducible() {
        let a = FaultPlan::seeded(42, &["write", "read"], 4, 8, 256);
        let b = FaultPlan::seeded(42, &["write", "read"], 4, 8, 256);
        assert_eq!(a.faults().len(), 4);
        for (x, y) in a.faults().iter().zip(b.faults()) {
            assert_eq!(x.artifact, y.artifact);
            assert_eq!(x.nth, y.nth);
            assert_eq!(x.kind, y.kind);
        }
        assert!(a.faults().iter().all(|f| f.kind != FaultKind::Panic));
    }
}
