//! Stimulus-schedule builders (rust mirror of python/compile/stimulus.py
//! — the artifacts take waveforms as runtime inputs, so both sides can
//! author them; keep semantics in sync).

/// Uniform sub-step sizes.
pub fn uniform_dt(steps: usize, dt: f64) -> Vec<f64> {
    vec![dt; steps]
}

/// Geometrically growing sub-steps for retention sweeps.
pub fn log_dt(steps: usize, dt0: f64, growth: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(steps);
    let mut d = dt0;
    for _ in 0..steps {
        out.push(d);
        d *= growth;
    }
    out
}

/// Time at the END of each scan step (each advances k_substeps * dt).
pub fn times_from_dt(dt: &[f64], k_substeps: usize) -> Vec<f64> {
    let mut acc = 0.0;
    dt.iter()
        .map(|d| {
            acc += d * k_substeps as f64;
            acc
        })
        .collect()
}

/// Normalized waveform matrix (steps x ns), all zero.
pub fn zeros(steps: usize, ns: usize) -> Vec<Vec<f64>> {
    vec![vec![0.0; ns]; steps]
}

/// Hold a channel at a constant normalized level.
pub fn constant(wave: &mut [Vec<f64>], ch: usize, level: f64) {
    for w in wave.iter_mut() {
        w[ch] = level;
    }
}

/// Unit pulse with linear edges; slopes are exact derivatives (the
/// coupling-cap stamps integrate C * slope).
pub fn pulse(
    wave: &mut [Vec<f64>],
    dwave: &mut [Vec<f64>],
    times: &[f64],
    ch: usize,
    t_rise: f64,
    t_fall: f64,
    tr: f64,
) {
    for (i, &t) in times.iter().enumerate() {
        let (v, s) = if t < t_rise {
            (0.0, 0.0)
        } else if t < t_rise + tr {
            ((t - t_rise) / tr, 1.0 / tr)
        } else if t < t_fall {
            (1.0, 0.0)
        } else if t < t_fall + tr {
            (1.0 - (t - t_fall) / tr, -1.0 / tr)
        } else {
            (0.0, 0.0)
        };
        wave[i][ch] = v;
        dwave[i][ch] = s;
    }
}

/// Unit level that falls to 0 at `t_fall` (active-low wordlines).
pub fn fall(wave: &mut [Vec<f64>], dwave: &mut [Vec<f64>], times: &[f64], ch: usize, t_fall: f64, tr: f64) {
    for (i, &t) in times.iter().enumerate() {
        let (v, s) = if t < t_fall {
            (1.0, 0.0)
        } else if t < t_fall + tr {
            (1.0 - (t - t_fall) / tr, -1.0 / tr)
        } else {
            (0.0, 0.0)
        };
        wave[i][ch] = v;
        dwave[i][ch] = s;
    }
}

/// Flatten a (steps x ns) waveform into a row-major f32 buffer.
pub fn flatten(wave: &[Vec<f64>]) -> Vec<f32> {
    wave.iter().flatten().map(|&v| v as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_dt_grows_geometrically() {
        let d = log_dt(4, 1e-12, 2.0);
        assert_eq!(d, vec![1e-12, 2e-12, 4e-12, 8e-12]);
        let t = times_from_dt(&d, 4);
        assert!((t[0] - 4e-12).abs() < 1e-20);
        assert!((t[3] - 4e-12 * 15.0).abs() < 1e-20);
    }

    #[test]
    fn pulse_has_exact_slopes() {
        let steps = 100;
        let dt = uniform_dt(steps, 1e-11);
        let times = times_from_dt(&dt, 4);
        let mut w = zeros(steps, 2);
        let mut dw = zeros(steps, 2);
        pulse(&mut w, &mut dw, &times, 0, 1e-9, 3e-9, 2e-10);
        // mid-pulse flat at 1, slopes zero
        let mid = times.iter().position(|&t| t > 2e-9).unwrap();
        assert_eq!(w[mid][0], 1.0);
        assert_eq!(dw[mid][0], 0.0);
        // rising edge slope = 1/tr
        let rise = times.iter().position(|&t| t > 1.05e-9).unwrap();
        assert!((dw[rise][0] - 5e9).abs() < 1.0);
        // untouched channel stays zero
        assert!(w.iter().all(|r| r[1] == 0.0));
    }

    #[test]
    fn fall_goes_low() {
        let steps = 50;
        let dt = uniform_dt(steps, 1e-11);
        let times = times_from_dt(&dt, 4);
        let mut w = zeros(steps, 1);
        let mut dw = zeros(steps, 1);
        fall(&mut w, &mut dw, &times, 0, 5e-10, 1e-10);
        assert_eq!(w[0][0], 1.0);
        assert_eq!(*w.last().unwrap().first().unwrap(), 0.0);
    }
}
