//! Bank floorplanning: bitcell-array tiling, periphery placement around
//! the array (Fig. 4's architecture: Write_Port_Address left,
//! Read_Port_Address right, Write_Port_Data bottom, Read_Port_Data top)
//! and power rings (Fig. 5).

use super::{Cell, Library, Orient, Rect};
use crate::tech::{LayerRole, Tech};

/// Tile `cell` into a rows x cols array cell named `name`.
/// A horizontal power-strap row (full-width metal1) is inserted every
/// `strap_every` rows; this is the "power rail area" whose amortization
/// drives the Fig. 6(b/c) array-efficiency trend.
pub fn tile_array(
    lib: &mut Library,
    tech: &Tech,
    name: &str,
    cell: &str,
    rows: usize,
    cols: usize,
    strap_every: usize,
    strap_h: i64,
) -> crate::Result<ArrayInfo> {
    let b = tech.layer(LayerRole::Boundary);
    let m1 = tech.layer(LayerRole::Metal1);
    let bc = lib.get(cell)?;
    let bbox = bc
        .boundary(b)
        .ok_or_else(|| anyhow::anyhow!("bitcell '{cell}' lacks a boundary rect"))?;
    let (cw, ch) = (bbox.w(), bbox.h());

    let mut arr = Cell::new(name);
    let mut y = 0i64;
    let mut straps = 0usize;
    // fixed edge straps top and bottom + one every `strap_every` rows:
    // the fixed part is what amortizes away as the array grows
    // (Fig. 6(b/c) array-efficiency mechanism)
    if strap_every > 0 {
        arr.add(Rect::new(m1, 0, 0, cols as i64 * cw, strap_h));
        y += strap_h;
        straps += 1;
    }
    for r in 0..rows {
        if strap_every > 0 && r > 0 && r % strap_every == 0 {
            arr.add(Rect::new(m1, 0, y, cols as i64 * cw, y + strap_h));
            y += strap_h;
            straps += 1;
        }
        for c in 0..cols {
            arr.place(format!("b{r}_{c}"), cell, c as i64 * cw, y, Orient::R0);
        }
        y += ch;
    }
    if strap_every > 0 {
        arr.add(Rect::new(m1, 0, y, cols as i64 * cw, y + strap_h));
        y += strap_h;
        straps += 1;
    }
    let (aw, ah) = (cols as i64 * cw, y);
    arr.add(Rect::new(b, 0, 0, aw, ah));
    lib.add(arr);
    Ok(ArrayInfo { w: aw, h: ah, cell_w: cw, cell_h: ch, straps })
}

/// Array tiling result.
#[derive(Debug, Clone, Copy)]
pub struct ArrayInfo {
    pub w: i64,
    pub h: i64,
    pub cell_w: i64,
    pub cell_h: i64,
    pub straps: usize,
}

/// Tile a periphery cell `n` times in a row (horizontal) or column.
pub fn tile_row(
    lib: &mut Library,
    tech: &Tech,
    name: &str,
    cell: &str,
    n: usize,
    horizontal: bool,
) -> crate::Result<(i64, i64)> {
    let b = tech.layer(LayerRole::Boundary);
    let bc = lib.get(cell)?;
    let bbox = bc
        .boundary(b)
        .ok_or_else(|| anyhow::anyhow!("cell '{cell}' lacks a boundary rect"))?;
    let (cw, ch) = (bbox.w(), bbox.h());
    let mut row = Cell::new(name);
    for i in 0..n {
        let (dx, dy) = if horizontal { (i as i64 * cw, 0) } else { (0, i as i64 * ch) };
        row.place(format!("u{i}"), cell, dx, dy, Orient::R0);
    }
    let (w, h) = if horizontal {
        (n as i64 * cw, ch)
    } else {
        (cw, n as i64 * ch)
    };
    row.add(Rect::new(b, 0, 0, w, h));
    lib.add(row);
    Ok((w, h))
}

/// Sizes of the five periphery blocks placed around the array.
#[derive(Debug, Clone, Copy, Default)]
pub struct PeripherySizes {
    /// Write_Port_Address (left of array): w, h
    pub wpa: (i64, i64),
    /// Read_Port_Address (right of array)
    pub rpa: (i64, i64),
    /// Write_Port_Data (below array, includes data DFFs)
    pub wpd: (i64, i64),
    /// Read_Port_Data (above array)
    pub rpd: (i64, i64),
    /// Control logic (corner blocks, one per port)
    pub ctrl: (i64, i64),
}

/// Power-ring parameters (Fig. 5: the bank is enclosed by VDD/GND
/// rings; a WWL level shifter adds a second boosted-rail ring and that
/// is the WWLLS area penalty of Fig. 6(a)).
#[derive(Debug, Clone, Copy)]
pub struct RingSpec {
    pub width: i64,
    pub gap: i64,
    /// Number of ring pairs (2 = VDD+GND; 3 adds VPP for WWLLS).
    pub rails: usize,
}

impl Default for RingSpec {
    fn default() -> RingSpec {
        RingSpec { width: 1_000, gap: 500, rails: 2 }
    }
}

/// Assembled bank summary (geometry in nm).
#[derive(Debug, Clone, Copy)]
pub struct BankLayout {
    pub total_w: i64,
    pub total_h: i64,
    pub array_w: i64,
    pub array_h: i64,
    /// Periphery + ring area in nm^2 (total - array).
    pub periphery_nm2: i64,
}

impl BankLayout {
    pub fn total_area_um2(&self) -> f64 {
        self.total_w as f64 * self.total_h as f64 * 1e-6
    }

    pub fn array_area_um2(&self) -> f64 {
        self.array_w as f64 * self.array_h as f64 * 1e-6
    }

    /// Fig. 6(c) array efficiency: array area / bank area.
    pub fn array_efficiency(&self) -> f64 {
        self.array_area_um2() / self.total_area_um2()
    }
}

/// Place the array and periphery blocks per Fig. 4, draw `rings`, and
/// produce the top bank cell.  The periphery block cells must already
/// be in the library under the given names.
#[allow(clippy::too_many_arguments)]
pub fn assemble_bank(
    lib: &mut Library,
    tech: &Tech,
    name: &str,
    array: &str,
    array_info: ArrayInfo,
    blocks: &BankBlocks,
    sizes: PeripherySizes,
    ring: RingSpec,
    os_array_over_periphery: bool,
) -> crate::Result<BankLayout> {
    let b = tech.layer(LayerRole::Boundary);
    let m3 = tech.layer(LayerRole::Metal3);
    let margin = 400i64; // placement margin between blocks (DRC headroom)

    let mut bank = Cell::new(name);
    // core origin: after left block + margin
    let core_x = sizes.wpa.0 + margin;
    let core_y = sizes.wpd.1 + margin;
    // the OS-OS array is BEOL and monolithically stacked: it consumes
    // no extra silicon footprint beyond max(array, periphery row widths)
    bank.place("array", array, core_x, core_y, Orient::R0);
    if let Some(wpa) = &blocks.wpa {
        bank.place("wpa", wpa, 0, core_y, Orient::R0);
    }
    if let Some(rpa) = &blocks.rpa {
        bank.place("rpa", rpa, core_x + array_info.w + margin, core_y, Orient::R0);
    }
    if let Some(wpd) = &blocks.wpd {
        bank.place("wpd", wpd, core_x, 0, Orient::R0);
    }
    if let Some(rpd) = &blocks.rpd {
        bank.place("rpd", rpd, core_x, core_y + array_info.h + margin, Orient::R0);
    }
    if let Some(ctrl) = &blocks.ctrl {
        bank.place("ctrl_w", ctrl, 0, 0, Orient::R0);
        bank.place("ctrl_r", ctrl, core_x + array_info.w + margin, core_y + array_info.h + margin, Orient::R0);
    }

    // silicon extent of the core
    let (eff_aw, eff_ah) = if os_array_over_periphery {
        // BEOL array over FEOL periphery: silicon core spans only the
        // periphery blocks; the array still bounds routing, so take the
        // max of array width and the data blocks, but no FEOL height
        (array_info.w, array_info.h / 4)
    } else {
        (array_info.w, array_info.h)
    };
    let core_w = sizes.wpa.0 + margin + eff_aw.max(sizes.wpd.0).max(sizes.rpd.0) + margin + sizes.rpa.0;
    let core_h = sizes.wpd.1 + margin + eff_ah + margin + sizes.rpd.1;

    // power rings around the core
    let ring_total = ring.rails as i64 * (ring.width + ring.gap);
    let (w, h) = (core_w + 2 * ring_total, core_h + 2 * ring_total);
    for i in 0..ring.rails {
        let inset = i as i64 * (ring.width + ring.gap);
        let (x0, y0, x1, y1) = (inset, inset, w - inset, h - inset);
        bank.add(Rect::new(m3, x0, y0, x1, y0 + ring.width)); // bottom
        bank.add(Rect::new(m3, x0, y1 - ring.width, x1, y1)); // top
        bank.add(Rect::new(m3, x0, y0, x0 + ring.width, y1)); // left
        bank.add(Rect::new(m3, x1 - ring.width, y0, x1, y1)); // right
    }
    bank.add(Rect::new(b, 0, 0, w, h));
    lib.add(bank);

    let array_nm2 = array_info.w as i64 * array_info.h;
    let silicon_array_nm2 = if os_array_over_periphery { 0 } else { array_nm2 };
    Ok(BankLayout {
        total_w: w,
        total_h: h,
        array_w: array_info.w,
        array_h: array_info.h,
        periphery_nm2: w * h - silicon_array_nm2,
    })
}

/// Names of the periphery block cells (None = port absent, e.g. the
/// single-port SRAM bank shares one address block).
#[derive(Debug, Clone, Default)]
pub struct BankBlocks {
    pub wpa: Option<String>,
    pub rpa: Option<String>,
    pub wpd: Option<String>,
    pub rpd: Option<String>,
    pub ctrl: Option<String>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::cells;
    use crate::tech::sg40;

    #[test]
    fn array_dims_scale_with_rows_cols() {
        let t = sg40();
        let mut lib = Library::default();
        lib.add(cells::gc2t_sisi(&t, false).layout);
        let a = tile_array(&mut lib, &t, "arr8", "gc2t_sisi", 8, 8, 16, 400).unwrap();
        let b = tile_array(&mut lib, &t, "arr16", "gc2t_sisi", 16, 8, 16, 400).unwrap();
        assert_eq!(a.w, b.w);
        assert_eq!(b.h - a.h, 8 * a.cell_h); // 8 extra rows, same straps
        assert_eq!(a.straps, 2);
    }

    #[test]
    fn straps_are_inserted_and_grow_height() {
        let t = sg40();
        let mut lib = Library::default();
        lib.add(cells::gc2t_sisi(&t, false).layout);
        let no = tile_array(&mut lib, &t, "a_nostrap", "gc2t_sisi", 32, 4, 0, 400).unwrap();
        let ws = tile_array(&mut lib, &t, "a_strap", "gc2t_sisi", 32, 4, 16, 400).unwrap();
        assert_eq!(ws.straps, 3);
        assert_eq!(ws.h, no.h + 3 * 400);
    }

    #[test]
    fn strap_fraction_shrinks_with_size() {
        // Fig. 6(b/c) mechanism: power-rail overhead amortizes
        let t = sg40();
        let mut lib = Library::default();
        lib.add(cells::gc2t_sisi(&t, false).layout);
        let small = tile_array(&mut lib, &t, "s", "gc2t_sisi", 32, 32, 16, 400).unwrap();
        let large = tile_array(&mut lib, &t, "l", "gc2t_sisi", 128, 32, 16, 400).unwrap();
        let frac = |a: &ArrayInfo| a.straps as f64 * 400.0 / a.h as f64;
        assert!(frac(&large) <= frac(&small) * 1.05);
    }

    #[test]
    fn bank_assembly_has_rings_and_bigger_bbox() {
        let t = sg40();
        let mut lib = Library::default();
        lib.add(cells::gc2t_sisi(&t, false).layout);
        let info = tile_array(&mut lib, &t, "arr", "gc2t_sisi", 16, 16, 16, 400).unwrap();
        let sizes = PeripherySizes {
            wpa: (3000, info.h),
            rpa: (3000, info.h),
            wpd: (info.w, 2000),
            rpd: (info.w, 2000),
            ctrl: (3000, 2000),
        };
        let lay = assemble_bank(
            &mut lib,
            &t,
            "bank",
            "arr",
            info,
            &BankBlocks::default(),
            sizes,
            RingSpec::default(),
            false,
        )
        .unwrap();
        assert!(lay.total_w > info.w && lay.total_h > info.h);
        assert!(lay.array_efficiency() < 1.0 && lay.array_efficiency() > 0.1);
        // third rail grows the bank (WWLLS penalty)
        let lay3 = assemble_bank(
            &mut lib,
            &t,
            "bank3",
            "arr",
            info,
            &BankBlocks::default(),
            sizes,
            RingSpec { rails: 3, ..Default::default() },
            false,
        )
        .unwrap();
        assert!(lay3.total_w > lay.total_w);
    }
}
