//! Hierarchical cell composition: place leaf cells in a row, bridge
//! their power rails, and route nets between their pins on metal3
//! tracks above the row (m2 risers + via stacks at each pin).
//!
//! This is the OpenRAM-style "module assembly" layer: the Data_DFF, the
//! decoder stages and the port blocks are all compositions of the DRC-
//! clean leaf cells from [`super::cells`], with inter-cell routing kept
//! on m3 where it cannot collide with leaf-internal m1/m2.

use super::cells::LeafCell;
use super::{Cell, Library, Orient, Pin, Rect};
use crate::netlist::Circuit;
use crate::tech::{LayerRole, Tech};

/// A pin reference: (instance index, pin name).
pub type PinRef = (usize, &'static str);

/// Description of one composed module.
pub struct ComposeSpec<'a> {
    pub name: &'a str,
    /// (instance name, cell name) placed left-to-right.
    pub insts: Vec<(String, String)>,
    /// Gap between adjacent instances (nm).
    pub gap: i64,
    /// Routed nets: (net name, pins).  Each net gets one m3 track.
    pub nets: Vec<(String, Vec<PinRef>)>,
    /// Exported ports: (port name, which pin provides the shape); the
    /// port may also name a routed net (the m3 track becomes the pin).
    pub exports: Vec<(String, PinRef)>,
}

/// First m3 routing track sits this far above the tallest subcell.
const TRACK_START: i64 = 60;
const TRACK_PITCH: i64 = 100;
const TRACK_H: i64 = 60;

/// Compose a module.  The subcells must already be in `lib`.  Returns
/// the top cell (with instances) — the caller supplies the matching
/// hierarchical [`Circuit`] (instance order must match `spec.insts`).
pub fn compose(lib: &mut Library, tech: &Tech, spec: &ComposeSpec) -> crate::Result<Rect> {
    let b = tech.layer(LayerRole::Boundary);
    let m1 = tech.layer(LayerRole::Metal1);
    let m2 = tech.layer(LayerRole::Metal2);
    let m3 = tech.layer(LayerRole::Metal3);
    let v2 = tech.layer(LayerRole::Via2);
    let v2w = tech.rules.layer(LayerRole::Via2).min_width_nm;

    let mut top = Cell::new(spec.name);
    // place instances left to right
    let mut x = 0i64;
    let mut max_h = 0i64;
    let mut offsets: Vec<i64> = Vec::new();
    for (iname, cname) in &spec.insts {
        let c = lib.get(cname)?;
        let bb = c
            .boundary(b)
            .ok_or_else(|| anyhow::anyhow!("cell {cname} lacks boundary"))?;
        offsets.push(x);
        top.place(iname.clone(), cname, x, 0, Orient::R0);
        x += bb.w() + spec.gap;
        max_h = max_h.max(bb.h());
    }
    let total_w = x - spec.gap;

    // bridge rails across the gaps (subcell rails are at y 0..60 and
    // max_h-60..max_h by the Std convention)
    top.pin("gnd", Rect::new(m1, 0, 0, total_w, 60));
    top.pin("vdd", Rect::new(m1, 0, max_h - 60, total_w, max_h));

    // resolve a pin's translated rect
    let pin_rect = |lib: &Library, idx: usize, pin: &str| -> crate::Result<Rect> {
        let (_, cname) = &spec.insts[idx];
        let c = lib.get(cname)?;
        let p = c
            .pins
            .iter()
            .find(|p| p.name == pin)
            .ok_or_else(|| anyhow::anyhow!("cell {cname} has no pin '{pin}'"))?;
        Ok(p.rect.translated(offsets[idx], 0))
    };

    // route nets on m3 tracks
    let mut net_tracks: Vec<(String, Rect)> = Vec::new();
    for (ni, (net, pins)) in spec.nets.iter().enumerate() {
        let ty = max_h + TRACK_START + ni as i64 * TRACK_PITCH;
        let mut lo = i64::MAX;
        let mut hi = i64::MIN;
        for (idx, pin) in pins {
            let pr = pin_rect(lib, *idx, pin)?;
            let px = (pr.x0 + pr.x1) / 2;
            lo = lo.min(px);
            hi = hi.max(px);
            // riser: m2 vertical from the pin up to the track
            let py = (pr.y0 + pr.y1) / 2;
            if pr.layer == m1 {
                // via1 + m2 pad on the pin first
                let v1 = tech.layer(LayerRole::Via1);
                let v1w = tech.rules.layer(LayerRole::Via1).min_width_nm;
                top.add(Rect::new(v1, px - v1w / 2, py - v1w / 2, px + v1w / 2, py + v1w / 2));
                top.add(Rect::new(m2, px - 40, py - 40, px + 40, py + 40));
            }
            top.add(Rect::new(m2, px - 30, py.min(ty), px + 30, ty + TRACK_H - 5));
            // via2 into the track (centered, 10 nm margins all around)
            let vy0 = ty + (TRACK_H - v2w) / 2;
            top.add(Rect::new(v2, px - v2w / 2, vy0, px + v2w / 2, vy0 + v2w));
        }
        let track = Rect::new(m3, lo - 40, ty, hi + 40, ty + TRACK_H);
        top.add(track);
        net_tracks.push((net.clone(), track));
    }

    // exports
    for (port, (idx, pin)) in &spec.exports {
        if let Some((_, track)) = net_tracks.iter().find(|(n, _)| n == port) {
            top.pins.push(Pin { name: port.clone(), rect: *track });
        } else {
            let pr = pin_rect(lib, *idx, pin)?;
            top.pins.push(Pin { name: port.clone(), rect: pr });
        }
    }

    let total_h = max_h + TRACK_START + spec.nets.len() as i64 * TRACK_PITCH + 40;
    let bnd = Rect::new(b, 0, 0, total_w, total_h);
    top.add(bnd);
    lib.add(top);
    Ok(bnd)
}

/// The Data_DFF of Fig. 4 as a composition (10T dynamic DFF): inv,
/// tgate, inv, tgate,
/// inv with clk/clkb distribution on m3.  Inserts all needed subcells
/// into `lib` and returns the hierarchical schematic.
pub fn dff(lib: &mut Library, tech: &Tech) -> crate::Result<LeafCell> {
    use super::cells;
    for leaf in [cells::inverter(tech, 1.0), cells::tgate(tech)] {
        lib.add(leaf.layout);
    }
    let spec = ComposeSpec {
        name: "dff",
        insts: vec![
            ("x_ck".into(), "inv_x1".into()),
            ("x_tg1".into(), "tgate".into()),
            ("x_mi".into(), "inv_x1".into()),
            ("x_tg2".into(), "tgate".into()),
            ("x_q".into(), "inv_x1".into()),
        ],
        gap: 400, // keeps adjacent subcells' nwells beyond min spacing
        nets: vec![
            ("clk".into(), vec![(0, "a"), (1, "cp"), (3, "cn")]),
            ("clkb".into(), vec![(0, "y"), (1, "cn"), (3, "cp")]),
            ("m".into(), vec![(1, "b"), (2, "a")]),
            ("mb".into(), vec![(2, "y"), (3, "a")]),
            ("sl".into(), vec![(3, "b"), (4, "a")]),
            ("d".into(), vec![(1, "a")]),
            ("q".into(), vec![(4, "y")]),
        ],
        exports: vec![
            ("d".into(), (1, "a")),
            ("clk".into(), (0, "a")),
            ("q".into(), (4, "y")),
        ],
    };
    compose(lib, tech, &spec)?;

    let mut ckt = Circuit::new("dff", &["d", "clk", "q", "vdd", "gnd"]);
    ckt.inst("x_ck", "inv_x1", &["clk", "clkb", "vdd", "gnd"]);
    ckt.inst("x_tg1", "tgate", &["d", "m", "clkb", "clk", "vdd", "gnd"]);
    ckt.inst("x_mi", "inv_x1", &["m", "mb", "vdd", "gnd"]);
    ckt.inst("x_tg2", "tgate", &["mb", "sl", "clk", "clkb", "vdd", "gnd"]);
    ckt.inst("x_q", "inv_x1", &["sl", "q", "vdd", "gnd"]);

    let layout = lib.get("dff")?.clone();
    Ok(LeafCell { layout, circuit: ckt })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::sg40;

    #[test]
    fn dff_composes_and_flattens() {
        let t = sg40();
        let mut lib = Library::default();
        let d = dff(&mut lib, &t).unwrap();
        assert_eq!(d.layout.insts.len(), 5);
        let rects = lib.flatten("dff").unwrap();
        assert!(rects.len() > 100);
        // hierarchical circuit flattens to 10 transistors (dynamic DFF)
        let mut nl = crate::netlist::Netlist::default();
        let cells_needed = [
            crate::layout::cells::inverter(&t, 1.0).circuit,
            crate::layout::cells::tgate(&t).circuit,
        ];
        for c in cells_needed {
            nl.add(c);
        }
        nl.add(d.circuit.clone());
        nl.top = "dff".into();
        assert_eq!(nl.flatten().unwrap().mos_count(), 10);
    }

    #[test]
    fn compose_rejects_unknown_pin() {
        let t = sg40();
        let mut lib = Library::default();
        lib.add(crate::layout::cells::inverter(&t, 1.0).layout);
        let spec = ComposeSpec {
            name: "bad",
            insts: vec![("x0".into(), "inv_x1".into())],
            gap: 100,
            nets: vec![("n".into(), vec![(0, "nope")])],
            exports: vec![],
        };
        assert!(compose(&mut lib, &t, &spec).is_err());
    }

    #[test]
    fn composed_dff_is_drc_clean() {
        let t = sg40();
        let mut lib = Library::default();
        dff(&mut lib, &t).unwrap();
        let rects = lib.flatten("dff").unwrap();
        let rep = crate::drc::check(&t, &rects);
        assert!(
            rep.clean(),
            "{} violations; first: {}",
            rep.violations.len(),
            rep.violations[0]
        );
    }
}
